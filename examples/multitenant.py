"""Multi-tenancy with sharded DiskANN (§3.3/§4.6, Table 3).

Tenants share one collection; a VectorIndexShardKey gives each tenant its
own logical DiskANN index (disjoint key ranges in the same store). Tenant
queries route to their shard: lower latency, higher recall than filtering
a shared index — the Table 3 effect.

    PYTHONPATH=src python examples/multitenant.py
"""
import numpy as np

from repro.core import GraphConfig
from repro.core import recall as rec
from repro.serve import F, VectorCollectionService, VectorQuery


def main():
    rng = np.random.RandomState(0)
    dim, tenants, per_tenant = 32, 5, 600
    n = tenants * per_tenant

    svc = VectorCollectionService(
        dim=dim,
        graph=GraphConfig(capacity=n + 512, R=12, M=8, L_build=32, L_search=48,
                          bootstrap_sample=256, refine_sample=10**9),
        max_vectors_per_partition=n + 256,
        shard_key_path="tenant",
    )

    all_vecs, docs = [], []
    for t in range(tenants):
        centers = rng.randn(6, dim).astype(np.float32) + 4.0 * t
        vecs = (centers[rng.randint(0, 6, per_tenant)]
                + 0.2 * rng.randn(per_tenant, dim)).astype(np.float32)
        all_vecs.append(vecs)
        docs += [{"id": t * per_tenant + i, "tenant": f"tenant-{t}"}
                 for i in range(per_tenant)]
    vectors = np.concatenate(all_vecs)
    svc.upsert(docs, vectors)
    print(f"ingested {n} docs across {tenants} tenants (sharded indices)")

    # tenant-scoped query through the shard key vs filtering the big index
    t = 2
    tq = all_vecs[t][rng.choice(per_tenant, 16)] + 0.02
    live = np.zeros(n, bool)
    live[t * per_tenant : (t + 1) * per_tenant] = True
    gt = rec.ground_truth(tq, vectors, live, 10)

    sharded_ids, sharded_ru = [], 0.0
    for q in tq:
        res = svc.query(VectorQuery(vector=q, k=10, shard_key=f"tenant-{t}"))
        sharded_ids.append(res.ids)
        sharded_ru += res.ru
    r_sharded = rec.recall_at_k(np.stack(sharded_ids), gt, 10)

    filt_ids, filt_ru = [], 0.0
    for q in tq:
        res = svc.query(VectorQuery(vector=q, k=10,
                                    filter=F.eq("tenant", f"tenant-{t}")))
        filt_ids.append(res.ids)
        filt_ru += res.ru
    r_filt = rec.recall_at_k(np.stack(filt_ids), gt, 10)

    print(f"sharded index : recall@10={r_sharded:.3f} RU/query={sharded_ru/16:.1f}")
    print(f"filtered big  : recall@10={r_filt:.3f} RU/query={filt_ru/16:.1f}")
    print("Table 3's effect: sharded ≥ filtered recall at lower cost:",
          r_sharded >= r_filt - 0.02)


if __name__ == "__main__":
    main()
