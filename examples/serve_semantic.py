"""End-to-end driver: serve a small LM with batched requests, with the
vector index as the semantic-retrieval layer (the paper's workload).

Pipeline per request batch:
  1. encode the query tokens with the LM backbone (mean-pooled hidden
     state = embedding — the stub for a production embedding model);
  2. DiskANN search over the indexed corpus (quantized space + re-rank);
  3. fetch the hit documents;
  4. decode a short continuation with the serving engine.

    PYTHONPATH=src python examples/serve_semantic.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import GraphConfig
from repro.models import model as M
from repro.serve import ServeEngine, VectorCollectionService, VectorQuery


def embed(params, cfg, tokens: np.ndarray) -> np.ndarray:
    """Mean-pooled final hidden state as the document/query embedding."""
    logits, _, _ = M.forward_train(params, cfg, {"tokens": jnp.asarray(tokens)},
                                   remat="none")
    # reuse the pre-head representation via the lm head pseudo-inverse-free
    # trick: just pool logits' top-k energy — cheap and deterministic for the
    # demo; a production system would return the hidden state directly.
    x = jax.nn.softmax(logits, axis=-1) @ params["embed"]
    return np.asarray(x.mean(axis=1), np.float32)


def main():
    cfg = get_smoke_config("smollm-135m")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)

    # corpus: 600 synthetic "documents" of 16 tokens
    corpus = rng.randint(0, cfg.vocab_size, (600, 16)).astype(np.int32)
    t0 = time.time()
    doc_vecs = np.concatenate(
        [embed(params, cfg, corpus[i : i + 64]) for i in range(0, 600, 64)]
    )
    print(f"embedded 600 docs in {time.time()-t0:.1f}s (dim={doc_vecs.shape[1]})")

    svc = VectorCollectionService(
        dim=doc_vecs.shape[1],
        graph=GraphConfig(capacity=1024, R=12, M=8, L_build=32, L_search=48,
                          bootstrap_sample=128, refine_sample=10**9),
        max_vectors_per_partition=1000,
    )
    docs = [{"id": i, "tokens": corpus[i].tolist()} for i in range(600)]
    svc.upsert(docs, doc_vecs)
    print("corpus indexed")

    # batched requests: retrieve + generate
    engine = ServeEngine(cfg, params, batch_slots=4, s_max=64)
    queries = corpus[rng.choice(600, 4)]  # look up near-duplicates
    qv = embed(params, cfg, queries)
    for rid in range(4):
        res = svc.query(VectorQuery(vector=qv[rid], k=3))
        hits = [int(i) for i in res.ids if i >= 0]
        print(f"request {rid}: retrieved docs {hits} (RU={res.ru:.1f})")
        # generation conditioned on the query tokens (retrieval-augmented
        # prompting would concatenate the hit docs; kept short for CPU)
        engine.submit(rid, queries[rid], max_new_tokens=8)
    out = engine.run()
    for rid, toks in sorted(out.items()):
        print(f"request {rid}: generated {toks}")
    print("served", len(out), "requests end-to-end")


if __name__ == "__main__":
    main()
