"""Train a ~135M-param llama-family model (SmolLM-135M arch) for a few
hundred steps with checkpoint/restart — the training-side e2e driver.

Full-size arch on CPU is slow, so the default runs the exact layer stack at
reduced width (--smoke); pass --full for the real 135M config (TPU-ready,
same code path the dry-run lowers for the production meshes).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse

from repro.configs import get_config, get_smoke_config
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="real 135M config (use on TPU; slow on CPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_config("smollm-135m") if args.full else get_smoke_config("smollm-135m")
    out = train(
        cfg,
        steps=args.steps,
        global_batch=8,
        seq_len=128,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=50,
        lr=1e-3,
        log_every=20,
    )
    first = out["losses"][0]
    last = out["final_loss"]
    print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({'DESCENDING ✓' if last < first else 'check hyperparameters'})")


if __name__ == "__main__":
    main()
