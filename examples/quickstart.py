"""Quickstart: create a vector-indexed collection, ingest, query.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import GraphConfig
from repro.core import recall as rec
from repro.serve import F, VectorCollectionService, VectorQuery


def main():
    rng = np.random.RandomState(0)
    dim, n = 48, 3000

    # documents with an embedding path, like the paper's JSON + /embedding
    centers = rng.randn(20, dim).astype(np.float32)
    vectors = (centers[rng.randint(0, 20, n)] + 0.15 * rng.randn(n, dim)).astype(np.float32)
    docs = [{"id": i, "title": f"doc-{i}", "category": i % 5} for i in range(n)]

    svc = VectorCollectionService(
        dim=dim,
        graph=GraphConfig(capacity=n + 256, R=24, M=16, L_build=48, L_search=64,
                          bootstrap_sample=512, refine_sample=10**9),
        max_vectors_per_partition=n + 128,
    )
    ru = svc.upsert(docs, vectors)
    print(f"ingested {n} docs for {ru:.0f} RU ({ru/n:.1f} RU/doc; paper: ~65)")

    # top-k query
    q = vectors[42] + 0.02
    res = svc.query(VectorQuery(vector=q, k=5))
    print(f"query plan={res.plan} RU={res.ru:.1f} ids={res.ids.tolist()}")
    assert 42 in res.ids.tolist()

    # recall against brute force
    queries = vectors[rng.choice(n, 32)] + 0.02 * rng.randn(32, dim).astype(np.float32)
    ids = np.stack([svc.query(VectorQuery(vector=qq, k=10)).ids for qq in queries])
    gt = rec.ground_truth(queries, vectors, np.ones(n, bool), 10)
    print(f"recall@10 = {rec.recall_at_k(ids, gt, 10):.3f}")

    # filtered (hybrid) query — §3.5: a declarative predicate compiles to
    # index-term bitmaps and batches through the engine (same-predicate
    # queries share one compiled bitmap; plan shows filtered-batched[...])
    res = svc.query(VectorQuery(vector=q, k=5, filter=F.eq("category", 2)))
    cats = [svc.docs[int(i)]["category"] for i in res.ids if i >= 0]
    print(f"filtered query -> categories {cats} (all 2), plan={res.plan}")

    # paginated query with a continuation token — §3.5 Continuations.
    # Tokens are versioned schema-checked bytes (never pickle), pages fan
    # out across every physical partition, and each page bills RU through
    # the engine like any other request.
    page1 = svc.query_page(VectorQuery(vector=q, k=5), None, page_size=5)
    page2 = svc.query_page(VectorQuery(vector=q, k=5), page1.continuation, page_size=5)
    print(f"page1={page1.ids.tolist()} RU={page1.ru:.1f}  "
          f"page2={page2.ids.tolist()} RU={page2.ru:.1f} (disjoint, both billed)")


if __name__ == "__main__":
    main()
