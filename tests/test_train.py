"""Training substrate: optimizer, loss descent, checkpoint/restart,
elastic remesh, gradient compression, data determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.train import train
from repro.models import model as M
from repro.train import checkpoint as ckpt
from repro.train.compression import (compress_grads, decompress_grads,
                                     init_residuals, int8_compress,
                                     int8_decompress)
from repro.train.data import SyntheticStream
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state, lr_schedule


def test_loss_descends_smollm(tmp_path):
    out = train(get_smoke_config("smollm-135m"), steps=30, global_batch=4,
                seq_len=64, lr=2e-3, log_every=100)
    losses = out["losses"]
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_checkpoint_restart_bit_identical(tmp_path):
    """Kill-and-resume produces the same final state as an unbroken run."""
    cfg = get_smoke_config("smollm-135m")
    d1 = str(tmp_path / "a")
    # unbroken 20 steps
    r_full = train(cfg, steps=20, global_batch=2, seq_len=32, ckpt_dir=None,
                   lr=1e-3, log_every=100)
    # broken run: killed after 10 steps (checkpoint), then resume to 20.
    # stop_after keeps the LR schedule identical to the unbroken run.
    train(cfg, steps=20, stop_after=10, global_batch=2, seq_len=32, ckpt_dir=d1,
          ckpt_every=10, lr=1e-3, log_every=100)
    r_resumed = train(cfg, steps=20, global_batch=2, seq_len=32, ckpt_dir=d1,
                      ckpt_every=10, lr=1e-3, log_every=100)
    np.testing.assert_allclose(
        r_full["losses"][-5:], r_resumed["losses"][-5:], rtol=1e-4, atol=1e-5
    )


def test_checkpoint_atomicity(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": np.arange(10), "b": {"c": np.ones((2, 2))}}
    ckpt.save(d, 5, tree, extra={"step": 5})
    # a torn write (no manifest) must be ignored
    os.makedirs(os.path.join(d, "step_00000009"), exist_ok=True)
    assert ckpt.latest_step(d) == 5
    restored, extra = ckpt.restore(d, tree)
    np.testing.assert_array_equal(restored["a"], tree["a"])
    assert extra["step"] == 5


def test_elastic_remesh_restore(tmp_path):
    """Save under one sharding, restore under another mesh shape."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import compat
    d = str(tmp_path / "el")
    tree = {"w": np.arange(16, dtype=np.float32).reshape(4, 4)}
    ckpt.save(d, 1, tree, extra={})
    mesh = compat.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = ckpt.restore(d, tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), tree["w"])
    assert restored["w"].sharding == sh["w"]


def test_adamw_descends_quadratic():
    cfg = OptConfig(lr=0.3, warmup_steps=1, total_steps=10000, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params, cfg)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(params, grads, opt, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in [0, 5, 10, 50, 100]]
    assert lrs[1] < lrs[2]  # warmup ascending
    assert lrs[2] >= lrs[3] >= lrs[4]  # cosine descending
    assert lrs[4] >= 0.09  # floor


def test_int8_compression_error_feedback():
    rng = np.random.RandomState(0)
    g = jnp.asarray(rng.randn(1000).astype(np.float32))
    c, resid = int8_compress(g)
    deq = int8_decompress(c, g.shape, g.dtype)
    rel = float(jnp.linalg.norm(deq - g) / jnp.linalg.norm(g))
    assert rel < 0.02, rel  # blockwise int8 ≈ 1% error
    np.testing.assert_allclose(np.asarray(deq + resid), np.asarray(g), rtol=1e-5, atol=1e-6)
    # 4x payload reduction
    assert c.q.nbytes <= g.nbytes // 4 + 64


def test_grad_compression_roundtrip_pytree():
    rng = np.random.RandomState(1)
    grads = {"a": jnp.asarray(rng.randn(37, 5).astype(np.float32)),
             "b": {"c": jnp.asarray(rng.randn(8).astype(np.float32))}}
    for mode in ("none", "bf16", "int8"):
        resid = init_residuals(grads, mode)
        comp, resid = compress_grads(grads, resid, mode)
        out = decompress_grads(comp, grads, mode)
        tol = {"none": 0, "bf16": 1e-2, "int8": 3e-2}[mode]
        for k in ("a",):
            np.testing.assert_allclose(np.asarray(out[k]), np.asarray(grads[k]),
                                       rtol=tol, atol=tol)


def test_data_stream_deterministic_and_resumable():
    cfg = get_smoke_config("smollm-135m")
    s1 = SyntheticStream(cfg, 4, 32, seed=7)
    b1 = [s1.next_batch()["tokens"] for _ in range(3)]
    s2 = SyntheticStream(cfg, 4, 32, seed=7)
    s2.next_batch()
    snap = s2.snapshot()
    s3 = SyntheticStream(cfg, 4, 32, seed=0)
    s3.restore(snap)
    np.testing.assert_array_equal(s3.next_batch()["tokens"], b1[1])
    np.testing.assert_array_equal(s3.next_batch()["tokens"], b1[2])


def test_data_stream_host_sharding():
    cfg = get_smoke_config("smollm-135m")
    full = SyntheticStream(cfg, 8, 16, seed=1, host_id=0, num_hosts=1)
    h0 = SyntheticStream(cfg, 8, 16, seed=1, host_id=0, num_hosts=2)
    h1 = SyntheticStream(cfg, 8, 16, seed=1, host_id=1, num_hosts=2)
    b0, b1 = h0.next_batch()["tokens"], h1.next_batch()["tokens"]
    assert b0.shape == (4, 16) and b1.shape == (4, 16)
    assert not np.array_equal(b0, b1)  # hosts draw different shards
