"""PQ unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from proptest import given, settings
from proptest import strategies as st

from repro.core import pq

from conftest import clustered_data


def test_train_encode_decode_roundtrip(rng):
    data = clustered_data(rng, 2000, 32)
    schema = pq.train_pq(jax.random.PRNGKey(0), jnp.asarray(data), M=8)
    codes = pq.encode(schema, jnp.asarray(data))
    assert codes.shape == (2000, 8) and codes.dtype == jnp.uint8
    recon = pq.decode(schema, codes)
    mse = float(jnp.mean((recon - data) ** 2))
    var = float(np.var(data))
    assert mse < 0.5 * var, f"PQ should beat 50% of variance: {mse} vs {var}"


def test_adc_matches_exact_on_decoded(rng):
    """ADC distance == exact distance to the decoded (reconstructed) vector."""
    data = clustered_data(rng, 500, 16)
    schema = pq.train_pq(jax.random.PRNGKey(1), jnp.asarray(data), M=4)
    codes = pq.encode(schema, jnp.asarray(data))
    q = jnp.asarray(rng.randn(16).astype(np.float32))
    lut = pq.adc_lut(schema, q)
    d_adc = pq.adc_distance(lut, codes)
    d_exact = pq.exact_distance(q[None, :], pq.decode(schema, codes))
    np.testing.assert_allclose(np.asarray(d_adc), np.asarray(d_exact), rtol=2e-3, atol=2e-3)


def test_adc_onehot_equivalence(rng):
    data = clustered_data(rng, 300, 16)
    schema = pq.train_pq(jax.random.PRNGKey(2), jnp.asarray(data), M=4)
    codes = pq.encode(schema, jnp.asarray(data))
    lut = pq.adc_lut(schema, jnp.asarray(rng.randn(16).astype(np.float32)))
    np.testing.assert_allclose(
        np.asarray(pq.adc_distance(lut, codes)),
        np.asarray(pq.adc_distance_onehot(lut, codes)),
        rtol=1e-4, atol=1e-4,
    )


def test_requantization_cross_schema(rng):
    """§3.4: distances remain comparable across old/new schemas."""
    data = clustered_data(rng, 3000, 32)
    s0 = pq.train_pq(jax.random.PRNGKey(3), jnp.asarray(data[:1000]), M=8)
    s1 = pq.refine_pq(jax.random.PRNGKey(4), s0, jnp.asarray(data))
    assert int(s1.version) == int(s0.version) + 1
    codes0 = pq.encode(s0, jnp.asarray(data[:100]))
    codes1 = pq.encode(s1, jnp.asarray(data[:100]))
    q = jnp.asarray(rng.randn(32).astype(np.float32))
    luts = pq.multi_lut((s0, s1), q)
    d0 = pq.adc_distance_versioned(luts, codes0, jnp.zeros(100, jnp.int32))
    d1 = pq.adc_distance_versioned(luts, codes1, jnp.ones(100, jnp.int32))
    # both approximate the same true distances
    d_true = pq.exact_distance(q[None, :], jnp.asarray(data[:100]))
    err0 = float(jnp.mean(jnp.abs(d0 - d_true)))
    err1 = float(jnp.mean(jnp.abs(d1 - d_true)))
    assert err1 <= err0 * 1.5  # refined schema at least comparable
    # mixed batch dispatches per-row
    mixed_codes = jnp.concatenate([codes0[:50], codes1[:50]])
    vers = jnp.concatenate([jnp.zeros(50, jnp.int32), jnp.ones(50, jnp.int32)])
    dm = pq.adc_distance_versioned(luts, mixed_codes, vers)
    np.testing.assert_allclose(np.asarray(dm[:50]), np.asarray(d0[:50]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(dm[50:]), np.asarray(d1[:50]), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([2, 4, 8]),
    n=st.integers(50, 200),
    metric=st.sampled_from(["l2", "ip"]),
)
def test_property_adc_consistency(m, n, metric):
    """Property: ADC(lut(q), encode(x)) == exact(q, decode(encode(x)))."""
    rng = np.random.RandomState(m * 1000 + n)
    dim = m * 4
    data = rng.randn(n, dim).astype(np.float32)
    schema = pq.train_pq(jax.random.PRNGKey(n), jnp.asarray(data), M=m, iters=4)
    codes = pq.encode(schema, jnp.asarray(data))
    q = jnp.asarray(rng.randn(dim).astype(np.float32))
    lut = pq.adc_lut(schema, q, metric)
    d_adc = pq.adc_distance(lut, codes)
    d_ref = pq.exact_distance(q[None, :], pq.decode(schema, codes), metric)
    np.testing.assert_allclose(np.asarray(d_adc), np.asarray(d_ref), rtol=5e-3, atol=5e-3)


def test_pairwise_distance_symmetry(rng):
    a = jnp.asarray(rng.randn(20, 8).astype(np.float32))
    d = pq.pairwise_distance(a, a)
    np.testing.assert_allclose(np.asarray(d), np.asarray(d.T), rtol=1e-4, atol=1e-5)
    assert float(jnp.abs(jnp.diagonal(d)).max()) < 1e-3
