"""Per-arch smoke tests: every assigned architecture instantiates a reduced
config and runs one forward/train step on CPU — shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.shapes import SHAPES, cell_supported
from repro.models import model as M
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state


def _smoke_batch(cfg, rng, B=2, S=16):
    if cfg.input_mode == "tokens":
        return {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.input_mode == "frames":
        return {
            "frames": jnp.asarray(rng.randn(B, S, cfg.d_model), jnp.float32),
            "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32),
        }
    Ni = cfg.num_image_tokens
    return {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S - Ni)), jnp.int32),
        "image_embeds": jnp.asarray(rng.randn(B, Ni, cfg.d_model), jnp.float32),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    rng = np.random.RandomState(hash(arch) % 2**31)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(cfg, rng)

    logits, mask, aux = M.forward_train(params, cfg, batch, remat="none")
    B = next(iter(batch.values())).shape[0]
    S_total = 16
    assert logits.shape == (B, S_total, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    # one real optimizer step decreases nothing catastrophic
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = init_opt_state(params, opt_cfg)
    loss0, grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, cfg, batch, remat="none")[0]
    )(params)
    assert bool(jnp.isfinite(loss0)), arch
    gnorms = [float(jnp.abs(g).max()) for g in jax.tree.leaves(grads)]
    assert all(np.isfinite(gnorms)), f"{arch}: non-finite grads"
    new_params, opt, metrics = adamw_update(params, grads, opt, opt_cfg)
    loss1 = M.loss_fn(new_params, cfg, batch, remat="none")[0]
    assert bool(jnp.isfinite(loss1)), arch


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS])
def test_smoke_decode_consistency(arch):
    cfg = get_smoke_config(arch)
    if not cfg.has_decode:
        pytest.skip("encoder-only")
    rng = np.random.RandomState(0)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    batch = _smoke_batch(cfg, rng, B=2, S=16)
    logits, _, _ = M.forward_train(params, cfg, batch, remat="none")
    cache = M.init_cache(cfg, 2, 32, dtype=jnp.float32)
    pl, cache = M.prefill(params, cfg, batch, cache)
    np.testing.assert_allclose(
        np.asarray(pl[:, 0]), np.asarray(logits[:, -1]), rtol=3e-2, atol=3e-2
    )


def test_full_configs_match_assignment():
    """The full configs carry the exact assigned hyperparameters."""
    spec = {
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
    }
    for arch, (L, dm, H, Hkv, dff, V) in spec.items():
        cfg = get_config(arch)
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == (L, dm, H, Hkv, dff, V), f"{arch}: {got}"
    assert get_config("qwen3-moe-235b-a22b").moe.num_experts == 128
    assert get_config("qwen3-moe-235b-a22b").moe.top_k == 8
    assert get_config("deepseek-v2-lite-16b").mla.kv_lora_rank == 512
    assert get_config("deepseek-v2-lite-16b").moe.top_k == 6
    assert get_config("zamba2-1.2b").ssm.d_state == 64
    assert get_config("rwkv6-7b").family == "ssm"
    assert not get_config("hubert-xlarge").causal


def test_cell_support_matrix():
    """40 cells; skips exactly where the assignment says."""
    total, skipped = 0, []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shp in SHAPES.values():
            total += 1
            ok, why = cell_supported(cfg, shp)
            if not ok:
                skipped.append((arch, shp.name))
    assert total == 40
    assert ("hubert-xlarge", "decode_32k") in skipped
    assert ("hubert-xlarge", "long_500k") in skipped
    long_runners = {a for a in ARCH_IDS
                    if cell_supported(get_config(a), SHAPES["long_500k"])[0]}
    assert long_runners == {"zamba2-1.2b", "rwkv6-7b"}
    assert len(skipped) == 9  # 8 long_500k skips + hubert decode_32k


def test_param_counts_plausible():
    """Analytic param counts within tolerance of the advertised sizes."""
    approx = {
        "starcoder2-15b": 15e9, "chatglm3-6b": 6e9, "qwen3-14b": 14e9,
        "smollm-135m": 135e6, "deepseek-v2-lite-16b": 16e9,
        "paligemma-3b": 3e9, "zamba2-1.2b": 1.2e9, "rwkv6-7b": 7e9,
    }
    for arch, want in approx.items():
        got = get_config(arch).param_count()
        assert 0.4 * want < got < 2.1 * want, f"{arch}: {got:.3g} vs {want:.3g}"
    moe = get_config("qwen3-moe-235b-a22b")
    assert 120e9 < moe.param_count() < 300e9
    assert moe.active_param_count() < 40e9


def test_chunked_attention_equivalence():
    """attn_q_chunk (flash-style blocking) computes identical attention."""
    import dataclasses
    cfg = get_smoke_config("qwen3-14b")
    p = M.init_params(jax.random.PRNGKey(3), cfg)
    rng = np.random.RandomState(3)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 32)), jnp.int32)}
    full = M.forward_train(p, dataclasses.replace(cfg, attn_q_chunk=0), batch, remat="none")[0]
    for qc in (8, 16):
        chunked = M.forward_train(
            p, dataclasses.replace(cfg, attn_q_chunk=qc), batch, remat="none")[0]
        np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                                   rtol=1e-4, atol=1e-4)
    # unrolled chunk loop (dry-run variant path) identical too; force_unroll
    # changes the params *structure* (per-layer segments), so re-init with
    # the same key — per-layer values are identical
    cfg_u = dataclasses.replace(cfg, attn_q_chunk=8, force_unroll=True)
    p_u = M.init_params(jax.random.PRNGKey(3), cfg_u)
    unrolled = M.forward_train(p_u, cfg_u, batch, remat="none")[0]
    np.testing.assert_allclose(np.asarray(full), np.asarray(unrolled),
                               rtol=1e-4, atol=1e-4)


def test_mla_chunked_equivalence():
    import dataclasses
    cfg = get_smoke_config("deepseek-v2-lite-16b")
    p = M.init_params(jax.random.PRNGKey(4), cfg)
    rng = np.random.RandomState(4)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 32)), jnp.int32)}
    full = M.forward_train(p, dataclasses.replace(cfg, attn_q_chunk=0), batch, remat="none")[0]
    chunked = M.forward_train(p, dataclasses.replace(cfg, attn_q_chunk=8), batch, remat="none")[0]
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked), rtol=1e-4, atol=1e-4)
