"""Chunked SSM forms vs recurrent oracles; unrolled-chunk equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm
from repro.models.config import ModelConfig, SSMConfig


def _cfg(kind, chunk=8, unroll=False):
    return ModelConfig(
        name="t", family="ssm", num_layers=1, d_model=32, num_heads=4,
        num_kv_heads=4, d_ff=64, vocab_size=100,
        ssm=SSMConfig(kind=kind, d_state=16, head_dim=8, expand=2, chunk=chunk,
                      unroll_chunks=unroll),
        param_dtype="float32", compute_dtype="float32",
    )


@pytest.mark.parametrize("kind", ["mamba2", "rwkv6"])
@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_chunked_matches_recurrent(kind, chunk):
    cfg = _cfg(kind, chunk)
    init = ssm.mamba2_init if kind == "mamba2" else ssm.rwkv6_init
    fwd = ssm.mamba2_forward if kind == "mamba2" else ssm.rwkv6_forward
    step = ssm.mamba2_step if kind == "mamba2" else ssm.rwkv6_step
    state0 = ssm.mamba2_init_state if kind == "mamba2" else ssm.rwkv6_init_state

    p = init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, 32)) * 0.5
    y = fwd(p, cfg, x)
    st = state0(cfg, B)
    ys = []
    for t in range(S):
        yt, st = step(p, cfg, x[:, t : t + 1], st)
        ys.append(yt)
    y_rec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_rec), rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("kind", ["mamba2", "rwkv6"])
def test_unrolled_chunks_bitwise_equal(kind):
    """The dry-run's unrolled chunk loop computes the same function."""
    cfg_s = _cfg(kind, 8, unroll=False)
    cfg_u = _cfg(kind, 8, unroll=True)
    init = ssm.mamba2_init if kind == "mamba2" else ssm.rwkv6_init
    fwd = ssm.mamba2_forward if kind == "mamba2" else ssm.rwkv6_forward
    p = init(jax.random.PRNGKey(3), cfg_s, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 24, 32)) * 0.5
    np.testing.assert_allclose(
        np.asarray(fwd(p, cfg_s, x)), np.asarray(fwd(p, cfg_u, x)),
        rtol=1e-5, atol=1e-6,
    )


@pytest.mark.parametrize("kind", ["mamba2", "rwkv6"])
def test_prefill_state_handoff(kind):
    """forward(return_state) state == recurrent state after S steps."""
    cfg = _cfg(kind, 8)
    init = ssm.mamba2_init if kind == "mamba2" else ssm.rwkv6_init
    fwd = ssm.mamba2_forward if kind == "mamba2" else ssm.rwkv6_forward
    step = ssm.mamba2_step if kind == "mamba2" else ssm.rwkv6_step
    state0 = ssm.mamba2_init_state if kind == "mamba2" else ssm.rwkv6_init_state
    p = init(jax.random.PRNGKey(5), cfg, jnp.float32)
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(6), (B, S, 32)) * 0.5
    _, st_fwd = fwd(p, cfg, x, return_state=True)
    st = state0(cfg, B)
    for t in range(S):
        _, st = step(p, cfg, x[:, t : t + 1], st)
    for k in st:
        np.testing.assert_allclose(
            np.asarray(st_fwd[k]), np.asarray(st[k]), rtol=2e-3, atol=2e-4
        )
    # continuing decode from the handoff state matches continuing recurrence
    xt = jax.random.normal(jax.random.PRNGKey(7), (B, 1, 32)) * 0.5
    y1, _ = step(p, cfg, xt, st_fwd)
    y2, _ = step(p, cfg, xt, st)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-3, atol=2e-4)


def test_mamba2_decay_monotone():
    """Longer dt → stronger forgetting of the initial state."""
    cfg = _cfg("mamba2", 8)
    p = ssm.mamba2_init(jax.random.PRNGKey(8), cfg, jnp.float32)
    p = dict(p, A_log=jnp.full_like(p["A_log"], 1.0))  # strong decay
    st = ssm.mamba2_init_state(cfg, 1)
    st = dict(st, S=jnp.ones_like(st["S"]))
    x = jnp.zeros((1, 1, 32))
    _, st1 = ssm.mamba2_step(p, cfg, x, st)
    assert float(jnp.abs(st1["S"]).mean()) <= float(jnp.abs(st["S"]).mean())
