"""Serving layer: the vector service end-to-end + LM serve engine."""
import pickle

import numpy as np
import pytest

from repro.core import GraphConfig
from repro.core import recall as rec
from repro.serve import F, VectorCollectionService, VectorQuery

from conftest import clustered_data


@pytest.fixture(scope="module")
def service():
    rng = np.random.RandomState(42)
    N, D = 1200, 24
    g = GraphConfig(capacity=1500, R=16, M=8, L_build=40, L_search=48,
                    bootstrap_sample=128, refine_sample=10**9, batch_size=64)
    svc = VectorCollectionService(dim=D, graph=g, max_vectors_per_partition=1400,
                                  shard_key_path="tenant")
    data = clustered_data(rng, N, D)
    docs = [{"id": i, "tenant": f"t{i % 4}", "category": i % 7} for i in range(N)]
    svc.upsert(docs, data)
    return svc, data


def test_query_end_to_end(service):
    svc, data = service
    rng = np.random.RandomState(1)
    pick = rng.choice(len(data), 8, replace=False)
    for i in pick:
        res = svc.query(VectorQuery(vector=data[i] + 0.01, k=5))
        assert i in res.ids.tolist(), f"doc {i} not found by its own vector"
        assert res.ru > 0


def test_exact_query_is_ground_truth(service):
    svc, data = service
    q = data[3] + 0.02
    res = svc.query(VectorQuery(vector=q, k=10, exact=True))
    gt = rec.ground_truth(q[None], data, np.ones(len(data), bool), 10)[0]
    assert set(res.ids.tolist()) == set(gt.tolist())


def test_filtered_query(service):
    svc, data = service
    q = data[10] + 0.01
    res = svc.query(VectorQuery(vector=q, k=5, filter=F.eq("category", 3)))
    for i in res.ids[res.ids >= 0]:
        assert svc.docs[int(i)]["category"] == 3
    # opaque callables no longer ride a legacy host path — they raise
    with pytest.raises(ValueError, match="callable"):
        svc.query(VectorQuery(vector=q, k=5,
                              filter=lambda d: d["category"] == 3))


def test_sharded_tenant_query(service):
    """Table 3: per-tenant sharded index returns only tenant docs."""
    svc, data = service
    q = data[8] + 0.01  # doc 8 → tenant t0
    res = svc.query(VectorQuery(vector=q, k=5, shard_key="t0"))
    for i in res.ids[res.ids >= 0]:
        assert svc.docs[int(i)]["tenant"] == "t0"
    assert 8 in res.ids.tolist()


def test_pagination_with_continuation_tokens(service):
    svc, data = service
    q = VectorQuery(vector=data[5] + 0.01, k=5)
    r1 = svc.query_page(q, None, page_size=5)
    r2 = svc.query_page(q, r1.continuation, page_size=5)
    ids1 = set(r1.ids[r1.ids >= 0].tolist())
    ids2 = set(r2.ids[r2.ids >= 0].tolist())
    assert ids1 and ids2 and not (ids1 & ids2)


def test_pagination_tokens_serialize_and_never_repeat(service):
    """Continuation tokens are client-side state (§3.5): they must survive
    a full serialize/deserialize round trip (the SDK ships them over the
    wire) and pages must never repeat results, across many pages."""
    svc, data = service
    q = VectorQuery(vector=data[20] + 0.01, k=5)
    seen: set[int] = set()
    token = None
    for _ in range(4):
        r = svc.query_page(q, token, page_size=5)
        assert isinstance(r.continuation, bytes)
        # the client may stash the token anywhere — it round-trips opaquely
        token = pickle.loads(pickle.dumps(r.continuation))
        assert isinstance(token, bytes)
        ids = [i for i in r.ids.tolist() if i >= 0]
        assert ids, "pages over a 1200-doc collection must not run dry here"
        assert not (set(ids) & seen), "a result must never repeat across pages"
        seen.update(ids)
    assert len(seen) == 20


def test_pagination_resumes_identically_from_deserialized_token(service):
    """Resuming from a round-tripped token yields the same next page as
    resuming from the in-memory token (the token IS the whole state)."""
    svc, data = service
    q = VectorQuery(vector=data[33] + 0.01, k=5)
    r1 = svc.query_page(q, None, page_size=5)
    wire = pickle.loads(pickle.dumps(r1.continuation))
    r2a = svc.query_page(q, r1.continuation, page_size=5)
    r2b = svc.query_page(q, wire, page_size=5)
    assert r2a.ids.tolist() == r2b.ids.tolist()


def test_delete_removes_from_results(service):
    svc, data = service
    victim = 777
    svc.delete([victim])
    res = svc.query(VectorQuery(vector=data[victim], k=10))
    assert victim not in res.ids.tolist()


def test_rekeyed_shard_value_moves_tenant_copy(service):
    """Sharded-DiskANN identity includes the shard key: re-upserting a doc
    under a different shard value must remove it from the old tenant's
    index — otherwise that tenant serves the stale copy forever, even
    after a delete."""
    svc, data = service
    doc = 444  # tenant t0 in the fixture (444 % 4 == 0)
    assert svc.docs[doc]["tenant"] == "t0"
    svc.upsert([{"id": doc, "tenant": "t1", "category": doc % 7}],
               data[doc][None, :])
    r0 = svc.query(VectorQuery(vector=data[doc], k=10, shard_key="t0"))
    assert doc not in r0.ids.tolist(), "old tenant must not serve the moved doc"
    r1 = svc.query(VectorQuery(vector=data[doc], k=10, shard_key="t1"))
    assert doc in r1.ids.tolist()
    svc.delete([doc])
    for t in ("t0", "t1"):
        r = svc.query(VectorQuery(vector=data[doc], k=10, shard_key=t))
        assert doc not in r.ids.tolist()


@pytest.fixture(scope="module")
def multi_service():
    """A ≥3-physical-partition service with CUSTOM partition keys — the
    regression surface for pk-routed deletes and per-partition plans."""
    rng = np.random.RandomState(9)
    N, D = 240, 16
    g = GraphConfig(capacity=160, R=12, M=8, L_build=32, L_search=32,
                    bootstrap_sample=32, refine_sample=10**9, batch_size=40)
    svc = VectorCollectionService(dim=D, graph=g,
                                  max_vectors_per_partition=140,
                                  initial_partitions=3)
    data = clustered_data(rng, N, D)
    docs = [{"id": i, "category": i % 7} for i in range(N)]
    svc.upsert(docs, data, partition_keys=[f"user-{i}" for i in range(N)])
    assert len(svc.collection.partitions) >= 3
    return svc, data


def test_delete_routes_by_upsert_partition_key(multi_service):
    """Regression: deletes used to fabricate pks from doc ids, so docs
    upserted under custom partition_keys were routed to the wrong
    partition and never tombstoned."""
    svc, data = multi_service
    victims = [11, 57, 123, 200]
    before = svc.collection.num_docs
    svc.delete(victims)
    assert svc.collection.num_docs == before - len(victims), \
        "custom-keyed docs must actually be tombstoned in their partition"
    for v in victims:
        res = svc.query(VectorQuery(vector=data[v], k=10))
        assert v not in res.ids.tolist()
        assert v not in svc.docs


def test_rekeyed_upsert_moves_document(multi_service):
    """Cosmos identity is (partition key, id): re-upserting an id under a
    key that routes to a DIFFERENT partition must MOVE the document —
    tombstoning the old copy — not leave it live serving stale results."""
    from repro.partition.partitioner import hash_key

    svc, data = multi_service
    doc_id, before = 33, svc.collection.num_docs
    old_owner = svc.collection.owner_of(doc_id)
    new_pk = next(f"rekey-{j}" for j in range(100)
                  if not old_owner.owns(hash_key(f"rekey-{j}")))
    svc.upsert([{"id": doc_id, "category": doc_id % 7}],
               data[doc_id][None, :], partition_keys=[new_pk])
    assert svc.collection.num_docs == before, "a re-key must not duplicate"
    assert svc.collection.owner_of(doc_id) is not old_owner
    svc.delete([doc_id])
    assert svc.collection.num_docs == before - 1
    res = svc.query(VectorQuery(vector=data[doc_id], k=10))
    assert doc_id not in res.ids.tolist()


def test_filtered_plan_aggregates_over_partitions(multi_service):
    """Regression: the filtered path reported only the LAST partition's
    plan; it must aggregate every partition actually searched, and skip
    partitions where the predicate matches nothing. Predicates flow
    through the batched engine path (``filtered-batched[...]`` plans)."""
    svc, data = multi_service
    res = svc.query(VectorQuery(vector=data[30] + 0.01, k=5,
                                filter=F.eq("category", 2)))
    assert res.plan.startswith("filtered-batched[") and "×" in res.plan
    counts = sum(int(part.split("×")[1]) for part in
                 res.plan[len("filtered-batched["):-1].split(","))
    assert 1 <= counts <= len(svc.collection.partitions)
    for i in res.ids[res.ids >= 0]:
        assert svc.docs[int(i)]["category"] == 2

    nothing = svc.query(VectorQuery(vector=data[30] + 0.01, k=5,
                                    filter=F.eq("category", 999)))
    assert nothing.plan == "filtered-batched[empty]"
    assert (nothing.ids < 0).all()
    # a no-match query still bills its posting lookups — but no search ran
    assert 0.0 < nothing.ru < 1.0


def test_serve_engine_decode():
    import jax
    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serve.engine import ServeEngine

    cfg = get_smoke_config("smollm-135m")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, batch_slots=2, s_max=64)
    rng = np.random.RandomState(0)
    for rid in range(3):
        eng.submit(rid, rng.randint(0, cfg.vocab_size, 8), max_new_tokens=6)
    out = eng.run()
    assert set(out) == {0, 1, 2}
    assert all(len(v) == 6 for v in out.values())
    # greedy decode is deterministic: same prompt → same continuation
    eng2 = ServeEngine(cfg, params, batch_slots=2, s_max=64)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, 8) for _ in range(3)]
    eng2.submit(9, prompts[0], max_new_tokens=6)
    out2 = eng2.run()
    assert out2[9] == out[0]
