"""Serving layer: the vector service end-to-end + LM serve engine."""
import pickle

import numpy as np
import pytest

from repro.core import GraphConfig
from repro.core import recall as rec
from repro.serve import VectorCollectionService, VectorQuery

from conftest import clustered_data


@pytest.fixture(scope="module")
def service():
    rng = np.random.RandomState(42)
    N, D = 1200, 24
    g = GraphConfig(capacity=1500, R=16, M=8, L_build=40, L_search=48,
                    bootstrap_sample=128, refine_sample=10**9, batch_size=64)
    svc = VectorCollectionService(dim=D, graph=g, max_vectors_per_partition=1400,
                                  shard_key_path="tenant")
    data = clustered_data(rng, N, D)
    docs = [{"id": i, "tenant": f"t{i % 4}", "category": i % 7} for i in range(N)]
    svc.upsert(docs, data)
    return svc, data


def test_query_end_to_end(service):
    svc, data = service
    rng = np.random.RandomState(1)
    pick = rng.choice(len(data), 8, replace=False)
    for i in pick:
        res = svc.query(VectorQuery(vector=data[i] + 0.01, k=5))
        assert i in res.ids.tolist(), f"doc {i} not found by its own vector"
        assert res.ru > 0


def test_exact_query_is_ground_truth(service):
    svc, data = service
    q = data[3] + 0.02
    res = svc.query(VectorQuery(vector=q, k=10, exact=True))
    gt = rec.ground_truth(q[None], data, np.ones(len(data), bool), 10)[0]
    assert set(res.ids.tolist()) == set(gt.tolist())


def test_filtered_query(service):
    svc, data = service
    q = data[10] + 0.01
    res = svc.query(VectorQuery(vector=q, k=5, filter=lambda d: d["category"] == 3))
    for i in res.ids[res.ids >= 0]:
        assert svc.docs[int(i)]["category"] == 3


def test_sharded_tenant_query(service):
    """Table 3: per-tenant sharded index returns only tenant docs."""
    svc, data = service
    q = data[8] + 0.01  # doc 8 → tenant t0
    res = svc.query(VectorQuery(vector=q, k=5, shard_key="t0"))
    for i in res.ids[res.ids >= 0]:
        assert svc.docs[int(i)]["tenant"] == "t0"
    assert 8 in res.ids.tolist()


def test_pagination_with_continuation_tokens(service):
    svc, data = service
    q = VectorQuery(vector=data[5] + 0.01, k=5)
    r1 = svc.query_page(q, None, page_size=5)
    r2 = svc.query_page(q, r1.continuation, page_size=5)
    ids1 = set(r1.ids[r1.ids >= 0].tolist())
    ids2 = set(r2.ids[r2.ids >= 0].tolist())
    assert ids1 and ids2 and not (ids1 & ids2)


def test_pagination_tokens_serialize_and_never_repeat(service):
    """Continuation tokens are client-side state (§3.5): they must survive
    a full serialize/deserialize round trip (the SDK ships them over the
    wire) and pages must never repeat results, across many pages."""
    svc, data = service
    q = VectorQuery(vector=data[20] + 0.01, k=5)
    seen: set[int] = set()
    token = None
    for _ in range(4):
        r = svc.query_page(q, token, page_size=5)
        assert isinstance(r.continuation, bytes)
        # the client may stash the token anywhere — it round-trips opaquely
        token = pickle.loads(pickle.dumps(r.continuation))
        assert isinstance(token, bytes)
        ids = [i for i in r.ids.tolist() if i >= 0]
        assert ids, "pages over a 1200-doc collection must not run dry here"
        assert not (set(ids) & seen), "a result must never repeat across pages"
        seen.update(ids)
    assert len(seen) == 20


def test_pagination_resumes_identically_from_deserialized_token(service):
    """Resuming from a round-tripped token yields the same next page as
    resuming from the in-memory token (the token IS the whole state)."""
    svc, data = service
    q = VectorQuery(vector=data[33] + 0.01, k=5)
    r1 = svc.query_page(q, None, page_size=5)
    wire = pickle.loads(pickle.dumps(r1.continuation))
    r2a = svc.query_page(q, r1.continuation, page_size=5)
    r2b = svc.query_page(q, wire, page_size=5)
    assert r2a.ids.tolist() == r2b.ids.tolist()


def test_delete_removes_from_results(service):
    svc, data = service
    victim = 777
    svc.delete([victim])
    res = svc.query(VectorQuery(vector=data[victim], k=10))
    assert victim not in res.ids.tolist()


def test_serve_engine_decode():
    import jax
    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serve.engine import ServeEngine

    cfg = get_smoke_config("smollm-135m")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, batch_slots=2, s_max=64)
    rng = np.random.RandomState(0)
    for rid in range(3):
        eng.submit(rid, rng.randint(0, cfg.vocab_size, 8), max_new_tokens=6)
    out = eng.run()
    assert set(out) == {0, 1, 2}
    assert all(len(v) == 6 for v in out.values())
    # greedy decode is deterministic: same prompt → same continuation
    eng2 = ServeEngine(cfg, params, batch_slots=2, s_max=64)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, 8) for _ in range(3)]
    eng2.submit(9, prompts[0], max_new_tokens=6)
    out2 = eng2.run()
    assert out2[9] == out[0]
