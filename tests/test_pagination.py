"""Cross-partition pagination through the engine (§3.5 Continuations).

The contract under test: a paginated query over a multi-partition
collection carries one cursor per physical partition in a client-side
token, merges pages client-side with no repeats and no gaps, bills RU > 0
for every page through the engine's accounting, 429s over-budget tenants
without consuming their budget, and speaks a versioned, schema-checked,
pickle-free token format that rejects tampered or over-versioned bytes.
"""
import struct
import zlib

import numpy as np
import pytest

from repro.core import GraphConfig
from repro.serve import (ContinuationError, Throttled,
                         VectorCollectionService, VectorQuery,
                         decode_continuation)

from conftest import clustered_data

PAGE = 10


def _build(n=360, dim=16, parts=3, seed=0, **svc_kw):
    rng = np.random.RandomState(seed)
    g = GraphConfig(capacity=240, R=12, M=8, L_build=32, L_search=32,
                    bootstrap_sample=32, refine_sample=10**9, batch_size=40)
    svc = VectorCollectionService(
        dim=dim, graph=g, max_vectors_per_partition=200,
        initial_partitions=parts, **svc_kw,
    )
    data = clustered_data(rng, n, dim)
    docs = [{"id": i, "tenant": f"t{i % 2}"} for i in range(n)]
    svc.upsert(docs, data, partition_keys=[f"user{i}" for i in range(n)])
    return svc, data


@pytest.fixture(scope="module")
def service():
    svc, data = _build()
    assert len(svc.collection.partitions) >= 3, "fixture must be multi-partition"
    return svc, data


def _drain(svc, q, page_size=PAGE, max_pages=200):
    """Run query_page to exhaustion. Returns (per-page id lists, per-page
    RU, page count)."""
    token, pages, rus = None, [], []
    for _ in range(max_pages):
        r = svc.query_page(q, token, page_size=page_size)
        assert r.plan == "paginated"
        pages.append([i for i in r.ids.tolist() if i >= 0])
        rus.append(r.ru)
        token = r.continuation
        if token is None:
            return pages, rus
        assert isinstance(token, bytes)
    raise AssertionError("pagination did not exhaust")


def test_drain_matches_single_query_no_repeats_no_gaps(service):
    """Acceptance: over ≥3 physical partitions, draining query_page yields
    exactly the id set of one query with k = pages × page_size."""
    svc, data = service
    q = VectorQuery(vector=data[5] + 0.01)
    pages, rus = _drain(svc, q)

    seen: set[int] = set()
    for ids in pages:
        assert not (set(ids) & seen), "a result repeated across pages"
        seen.update(ids)

    k = len(pages) * PAGE
    one = svc.query(VectorQuery(vector=data[5] + 0.01, k=k))
    oneset = {i for i in one.ids.tolist() if i >= 0}
    assert seen == oneset, "drained pagination must cover exactly the one-shot set"


def test_pages_are_globally_ordered(service):
    """The merged stream ascends across pages and partitions — up to the
    quantized-vs-full-precision jitter inherent to re-ranked ANN pages
    (exact monotonicity would require dropping results, i.e. gaps).
    Page 1 must be the true global head; later pages must not regress on
    average."""
    svc, data = service
    qv = data[40] + 0.01
    token, means = None, []
    first_ids: set[int] = set()
    for page in range(5):
        r = svc.query_page(VectorQuery(vector=qv), token, page_size=PAGE)
        d = [x for x, i in zip(r.dists.tolist(), r.ids.tolist()) if i >= 0]
        assert d, "early pages over 360 docs cannot run dry"
        means.append(float(np.mean(d)))
        if page == 0:
            first_ids = {i for i in r.ids.tolist() if i >= 0}
        token = r.continuation
    assert all(a <= b + 1e-6 for a, b in zip(means, means[1:])), \
        f"page means must not regress: {means}"
    exact = svc.query(VectorQuery(vector=qv, k=PAGE, exact=True))
    top = set(exact.ids.tolist())
    assert len(first_ids & top) >= 8, \
        "page 1 must be (almost exactly) the global top-k across partitions"


def test_every_page_bills_ru_through_engine(service):
    """Acceptance: no more zero-RU continuations — every page charges at
    least the request floor and the charge lands in EngineMetrics."""
    svc, data = service
    eng = svc.engine
    q = VectorQuery(vector=data[77] + 0.01)
    token = None
    for _ in range(4):
        ok0, pages0, ru0 = (eng.metrics.queries_ok, eng.metrics.pages_served,
                            eng.metrics.ru_query_total)
        r = svc.query_page(q, token, page_size=PAGE)
        assert r.ru > 0, "a paged scan must never be free"
        assert r.latency_ms > 0
        assert eng.metrics.queries_ok == ok0 + 1
        assert eng.metrics.pages_served == pages0 + 1
        assert eng.metrics.ru_query_total == pytest.approx(ru0 + r.ru)
        token = r.continuation


def test_token_roundtrip_resumes_identically(service):
    """The token is the whole state: resuming from re-serialized bytes
    yields the same next page as resuming from the original bytes."""
    svc, data = service
    q = VectorQuery(vector=data[33] + 0.01)
    r1 = svc.query_page(q, None, page_size=PAGE)
    wire = bytes(bytearray(r1.continuation))  # copy, as if off the network
    r2a = svc.query_page(q, r1.continuation, page_size=PAGE)
    r2b = svc.query_page(q, wire, page_size=PAGE)
    assert r2a.ids.tolist() == r2b.ids.tolist()
    assert r2a.continuation == r2b.continuation


def test_tampered_and_malformed_tokens_rejected(service):
    svc, data = service
    q = VectorQuery(vector=data[12] + 0.01)
    token = svc.query_page(q, None, page_size=PAGE).continuation

    flipped = bytearray(token)
    flipped[len(flipped) // 2] ^= 0xFF
    for bad in (b"", b"garbage", token[: len(token) // 2], bytes(flipped)):
        with pytest.raises(ContinuationError):
            svc.query_page(q, bad, page_size=PAGE)


def test_forged_state_widths_rejected(service):
    """A WELL-FORMED token whose state arrays carry a different beam width
    must be rejected: array shapes are jit signatures, so accepting
    arbitrary L would let clients mint a fresh compile per request."""
    import jax.numpy as jnp

    from repro.serve import encode_continuation

    svc, data = service
    q = VectorQuery(vector=data[12] + 0.01)
    token = svc.query_page(q, None, page_size=PAGE).continuation
    st = decode_continuation(token)
    cur = next(c for c in st.cursors if c.state is not None)
    pad = lambda a, v: jnp.concatenate([a, jnp.full((8,), v, a.dtype)])
    cur.state = cur.state._replace(
        best_ids=pad(cur.state.best_ids, -1),
        best_dists=pad(cur.state.best_dists, jnp.inf),
        best_expanded=pad(cur.state.best_expanded, True),
    )
    with pytest.raises(ContinuationError, match="beam width"):
        svc.query_page(q, encode_continuation(st), page_size=PAGE)


def test_unsorted_buffer_token_rejected(service):
    """The merge trusts per-partition buffers to be ascending and bounded
    by their high-water mark — a token violating that would silently
    break the no-repeat/no-gap guarantee, so the decoder enforces it."""
    from repro.serve import encode_continuation

    svc, data = service
    q = VectorQuery(vector=data[12] + 0.01)
    token = svc.query_page(q, None, page_size=PAGE).continuation
    st = decode_continuation(token)
    cur = next(c for c in st.cursors if len(c.buf_ids) >= 2)
    cur.buf_dists = cur.buf_dists[::-1].copy()  # descending now
    with pytest.raises(ContinuationError, match="ascending"):
        decode_continuation(encode_continuation(st))

    st = decode_continuation(token)
    cur = next(c for c in st.cursors if len(c.buf_ids) >= 1)
    cur.fetch_hwm = float(cur.buf_dists[-1]) - 1.0  # hwm below buffer
    with pytest.raises(ContinuationError, match="high-water"):
        decode_continuation(encode_continuation(st))


def test_over_versioned_token_rejected(service):
    """A token from a future build must be refused, not guessed at."""
    svc, data = service
    q = VectorQuery(vector=data[12] + 0.01)
    token = bytearray(svc.query_page(q, None, page_size=PAGE).continuation)
    token[4:6] = struct.pack("<H", 7)  # bump the version field
    body = bytes(token[:-4])
    token[-4:] = struct.pack("<I", zlib.crc32(body) & 0xFFFFFFFF)  # re-sign
    with pytest.raises(ContinuationError, match="version"):
        svc.query_page(q, bytes(token), page_size=PAGE)


def test_exhaustion_returns_none_continuation(service):
    """Drains terminate with ``continuation=None`` and cover every doc the
    graph can reach (a handful of construction-time orphans are a graph
    property, not a pagination gap — the one-shot query misses the same
    ones, which test_drain_matches_single_query pins exactly)."""
    svc, data = service
    q = VectorQuery(vector=data[200] + 0.01)
    pages, _ = _drain(svc, q)
    total = sum(len(p) for p in pages)
    assert total >= 0.95 * svc.collection.num_docs
    # deterministic: the same drain finds exactly the same results
    pages2, _ = _drain(svc, VectorQuery(vector=data[200] + 0.01))
    assert sorted(sum(pages2, [])) == sorted(sum(pages, []))


def test_throttled_page_consumes_no_budget(service):
    """Acceptance: an over-budget tenant gets the 429 path on a page
    request — and the rejection must not bleed the tenant's budget."""
    svc, data = service
    eng = svc.engine
    eng.set_tenant_budget("pager-poor", 1.0)
    gov = eng.tenant_governor("pager-poor")
    gov.available = 0.25  # below any admission estimate
    before = gov.available
    q = VectorQuery(vector=data[3] + 0.01, tenant="pager-poor")
    with pytest.raises(Throttled) as ei:
        svc.query_page(q, None, page_size=PAGE)
    assert ei.value.retry_after_s > 0
    assert gov.available == pytest.approx(before), \
        "a 429'd page must not consume budget"


def test_failed_page_body_refunds_reservation(service):
    """An admitted page whose body raises refunds its admission
    reservation in full (engine.execute_host page path)."""
    svc, _ = service
    eng = svc.engine
    gov = eng.tenant_governor("pager-refund")
    gov.refill_to(eng.clock.now())
    before = gov.available

    def boom():
        raise RuntimeError("partition fell over")

    pages_before = eng.metrics.pages_served
    with pytest.raises(RuntimeError):
        eng.execute_host("pager-refund", "paginated", boom, is_page=True)
    assert gov.available == pytest.approx(before)
    assert eng.metrics.pages_served == pages_before, \
        "a failed page must not count as served"


def test_shard_key_routes_paged_queries():
    """Sharded-DiskANN tenants paginate within their own index, and a
    token minted under one shard key cannot resume under another."""
    svc, data = _build(n=160, parts=1, seed=4, shard_key_path="tenant")
    q0 = VectorQuery(vector=data[8] + 0.01, shard_key="t0")
    token, seen = None, []
    for _ in range(3):
        r = svc.query_page(q0, token, page_size=PAGE)
        ids = [i for i in r.ids.tolist() if i >= 0]
        assert ids and all(svc.docs[i]["tenant"] == "t0" for i in ids)
        seen += ids
        token = r.continuation
    assert len(set(seen)) == len(seen)

    q1 = VectorQuery(vector=data[8] + 0.01, shard_key="t1")
    with pytest.raises(ContinuationError, match="routing"):
        svc.query_page(q1, token, page_size=PAGE)


def test_invalid_beam_width_rejected_as_client_error(service):
    """q.beam_width is client input: out-of-range values are rejected up
    front, not left to a bare assert inside the jitted kernel."""
    svc, data = service
    for bad in (100, -1):
        with pytest.raises(ValueError, match="beam_width"):
            svc.query_page(VectorQuery(vector=data[0], beam_width=bad),
                           None, page_size=PAGE)


def test_beam_width_plumbs_to_paged_path(service):
    """q.beam_width reaches the per-partition pagination loop: wider beams
    take measurably fewer sequential rounds for the same first page."""
    svc, data = service
    q = data[150] + 0.01

    def hops_after_two_pages(W):
        qq = VectorQuery(vector=q, beam_width=W)
        ids: set[int] = set()
        r = svc.query_page(qq, None, page_size=PAGE)
        ids.update(i for i in r.ids.tolist() if i >= 0)
        r = svc.query_page(qq, r.continuation, page_size=PAGE)
        ids.update(i for i in r.ids.tolist() if i >= 0)
        st = decode_continuation(r.continuation)
        hops = sum(int(c.state.hops) for c in st.cursors if c.state is not None)
        return ids, hops

    ids1, hops1 = hops_after_two_pages(1)
    ids4, hops4 = hops_after_two_pages(4)
    # W changes exploration order, not what gets found: the two-page sets
    # must agree almost entirely (exact page-level parity is not promised)
    overlap = len(ids1 & ids4) / max(len(ids1 | ids4), 1)
    assert overlap >= 0.8, (overlap, len(ids1), len(ids4))
    assert hops4 < hops1, "W=4 must batch hops (fewer sequential rounds)"
