"""Deterministic, dependency-free property-testing harness.

Drop-in replacement for the `hypothesis` subset this suite uses — the
container has no network access, so tests must collect and run fully
offline. Semantics:

  * every example is drawn from a ``numpy.random.RandomState`` seeded from a
    stable hash of the test's qualified name (override with
    ``settings(seed=...)``) — the same examples run on every machine, every
    time, in collection order;
  * on failure the falsifying example is reported in the exception chain
    (no shrinking — examples are small by construction);
  * ``deadline`` / unknown settings kwargs are accepted and ignored.

Usage (identical shape to hypothesis):

    from proptest import given, settings
    from proptest import strategies as st

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(50, 200), metric=st.sampled_from(["l2", "ip"]))
    def test_something(n, metric): ...
"""
from __future__ import annotations

import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 25


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------


class Strategy:
    """A reproducible example generator: example(rng) -> value."""

    def example(self, rng: np.random.RandomState):
        raise NotImplementedError

    def __repr__(self):  # pragma: no cover — debugging aid
        return f"{type(self).__name__}({self.__dict__!r})"


class _Integers(Strategy):
    def __init__(self, min_value: int, max_value: int):
        assert min_value <= max_value
        self.min_value, self.max_value = int(min_value), int(max_value)

    def example(self, rng):
        return int(rng.randint(self.min_value, self.max_value + 1))


class _Floats(Strategy):
    def __init__(self, min_value: float, max_value: float):
        assert min_value <= max_value
        self.min_value, self.max_value = float(min_value), float(max_value)

    def example(self, rng):
        return float(rng.uniform(self.min_value, self.max_value))


class _Booleans(Strategy):
    def example(self, rng):
        return bool(rng.randint(0, 2))


class _SampledFrom(Strategy):
    def __init__(self, elements):
        self.elements = list(elements)
        assert self.elements

    def example(self, rng):
        return self.elements[int(rng.randint(0, len(self.elements)))]


class _Just(Strategy):
    def __init__(self, value):
        self.value = value

    def example(self, rng):
        return self.value


class _Tuples(Strategy):
    def __init__(self, *elems: Strategy):
        self.elems = elems

    def example(self, rng):
        return tuple(e.example(rng) for e in self.elems)


class _Lists(Strategy):
    def __init__(self, elem: Strategy, min_size: int = 0, max_size: int = 10):
        assert 0 <= min_size <= max_size
        self.elem, self.min_size, self.max_size = elem, min_size, max_size

    def example(self, rng):
        n = int(rng.randint(self.min_size, self.max_size + 1))
        return [self.elem.example(rng) for _ in range(n)]


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` for the subset used."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> Strategy:
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value: float, max_value: float) -> Strategy:
        return _Floats(min_value, max_value)

    @staticmethod
    def booleans() -> Strategy:
        return _Booleans()

    @staticmethod
    def sampled_from(elements) -> Strategy:
        return _SampledFrom(elements)

    @staticmethod
    def just(value) -> Strategy:
        return _Just(value)

    @staticmethod
    def tuples(*elems: Strategy) -> Strategy:
        return _Tuples(*elems)

    @staticmethod
    def lists(elem: Strategy, min_size: int = 0, max_size: int = 10) -> Strategy:
        return _Lists(elem, min_size=min_size, max_size=max_size)


# ---------------------------------------------------------------------------
# decorators
# ---------------------------------------------------------------------------


def _stable_seed(name: str) -> int:
    return zlib.crc32(name.encode("utf-8")) & 0x7FFFFFFF


def settings(*, max_examples: int = DEFAULT_MAX_EXAMPLES, seed: int | None = None,
             **_ignored):
    """Configure an adjacent @given. Order-independent with @given; extra
    hypothesis kwargs (deadline=...) are accepted and dropped."""

    def deco(fn):
        fn._proptest_settings = {"max_examples": max_examples, "seed": seed}
        return fn

    return deco


def given(**strats: Strategy):
    """Run the test once per drawn example, deterministically.

    The wrapper takes no parameters, so pytest never mistakes strategy
    names for fixtures.
    """
    bad = [k for k, s in strats.items() if not isinstance(s, Strategy)]
    if bad:
        raise TypeError(f"given() expects Strategy values, got non-strategies: {bad}")

    def deco(fn):
        def wrapper():
            cfg = getattr(wrapper, "_proptest_settings", None) or getattr(
                fn, "_proptest_settings", None) or {}
            max_examples = cfg.get("max_examples") or DEFAULT_MAX_EXAMPLES
            seed = cfg.get("seed")
            if seed is None:
                seed = _stable_seed(f"{fn.__module__}.{fn.__qualname__}")
            rng = np.random.RandomState(seed)
            for i in range(max_examples):
                example = {k: s.example(rng) for k, s in strats.items()}
                try:
                    fn(**example)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__}: falsifying example {i + 1}/{max_examples} "
                        f"(seed={seed}): {example!r}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
