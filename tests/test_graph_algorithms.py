"""DiskANN algorithm tests: search recall, prune invariants, deletes,
pagination, filters. Uses networkx to check structural graph properties."""
import jax
import jax.numpy as jnp
import networkx as nx
import numpy as np
import pytest
from proptest import given, settings
from proptest import strategies as st

from repro.core import GraphConfig, DiskANNIndex
from repro.core import prune as prmod
from repro.core import recall as rec
from repro.core.graph import bitmap_init, bitmap_set, bitmap_test

from conftest import clustered_data


@pytest.fixture(scope="module")
def built_index():
    rng = np.random.RandomState(7)
    N, D = 2000, 32
    data = clustered_data(rng, N, D)
    cfg = GraphConfig(capacity=N + 64, R=24, M=16, L_build=48, L_search=48,
                      bootstrap_sample=256, refine_sample=10**9, batch_size=64)
    idx = DiskANNIndex(cfg, D, seed=0)
    idx.insert(list(range(N)), data)
    return idx, data, rng


def _queries_from(data, rng, n, noise=0.05):
    """In-distribution queries: perturbed database points (the realistic
    regime; fully out-of-distribution queries are a different benchmark)."""
    pick = rng.choice(len(data), n, replace=False)
    return (data[pick] + noise * rng.randn(n, data.shape[1])).astype(np.float32)


def test_search_recall(built_index):
    idx, data, rng = built_index
    q = _queries_from(data, np.random.RandomState(99), 32)
    ids, dists, stats = idx.search(q, k=10, L=64)
    gt = rec.ground_truth(q, data, np.ones(len(data), bool), 10)
    r = rec.recall_at_k(ids, gt, 10)
    assert r >= 0.85, f"recall@10 {r}"
    assert np.all(np.diff(dists, axis=1) >= -1e-5), "results must be sorted"


def test_search_stats_asymmetry(built_index):
    """§3.2: quantized reads ≫ full-precision reads (the paper's ~70×)."""
    idx, data, rng = built_index
    q = _queries_from(data, np.random.RandomState(5), 8)
    _, _, stats = idx.search(q, k=10, L=64, rerank_multiplier=2.5)
    assert stats.cmps > 4 * stats.full_reads


def test_graph_degree_bound_and_connectivity(built_index):
    idx, data, _ = built_index
    nbrs = idx.pv.neighbors
    deg = (nbrs >= 0).sum(1)
    live = idx.pv.live
    assert deg[live].max() <= idx.cfg.R_slack
    # medoid reaches nearly every live node (graph navigability)
    G = nx.DiGraph()
    for u in np.nonzero(live)[0]:
        for v in nbrs[u][nbrs[u] >= 0]:
            G.add_edge(int(u), int(v))
    reachable = nx.descendants(G, idx.medoid) | {idx.medoid}
    frac = len(reachable & set(map(int, np.nonzero(live)[0]))) / live.sum()
    assert frac > 0.95, f"only {frac:.2%} reachable from medoid"


def test_robust_prune_invariants():
    """Degree ≤ R; closest candidate always kept; no dominated survivor."""
    rng = np.random.RandomState(3)
    C, D, R, alpha = 40, 8, 8, 1.2
    p = rng.randn(D).astype(np.float32)
    cands = rng.randn(C, D).astype(np.float32)
    ids = jnp.arange(C, dtype=jnp.int32)
    kept = np.asarray(prmod.prune_with_vectors(
        jnp.asarray(p), ids, jnp.asarray(cands), alpha=alpha, R=R))
    kept_ids = kept[kept >= 0]
    assert len(kept_ids) <= R
    d = ((cands - p) ** 2).sum(1)
    assert d.argmin() in kept_ids, "nearest candidate must survive"
    # α-RNG property: for every kept q there is no EARLIER kept r with
    # α²·d(r,q) ≤ d(p,q)
    a2 = alpha * alpha
    for i, qi in enumerate(kept_ids):
        for rj in kept_ids[:i]:
            drq = ((cands[qi] - cands[rj]) ** 2).sum()
            assert a2 * drq > d[qi] - 1e-5, (qi, rj)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), alpha=st.floats(1.0, 2.0), r=st.sampled_from([4, 8, 16]))
def test_property_prune_degree_bound(seed, alpha, r):
    rng = np.random.RandomState(seed)
    C, D = 30, 6
    p = rng.randn(D).astype(np.float32)
    cands = rng.randn(C, D).astype(np.float32)
    ids = jnp.asarray(np.where(rng.rand(C) < 0.8, np.arange(C), -1).astype(np.int32))
    kept = np.asarray(prmod.prune_with_vectors(
        jnp.asarray(p), ids, jnp.asarray(cands), alpha=alpha, R=r))
    kept_ids = kept[kept >= 0]
    assert len(kept_ids) <= r
    assert len(set(kept_ids.tolist())) == len(kept_ids), "no duplicates"
    valid = set(np.asarray(ids)[np.asarray(ids) >= 0].tolist())
    assert set(kept_ids.tolist()) <= valid


def test_bitmap_ops():
    bm = bitmap_init(1000)
    ids = jnp.asarray([0, 31, 32, 63, 999, 999, -1], jnp.int32)
    bm = bitmap_set(bm, ids)
    got = np.asarray(bitmap_test(bm, jnp.asarray([0, 1, 31, 32, 63, 64, 999], jnp.int32)))
    np.testing.assert_array_equal(got, [True, False, True, True, True, False, True])


def test_delete_keeps_recall(built_index):
    idx, data, _ = built_index
    snap = idx.snapshot()
    try:
        victims = list(range(100, 300))
        idx.delete(victims, policy="inplace")
        for _ in range(3):
            idx.consolidate()
        live = np.ones(len(data), bool)
        live[victims] = False
        rs = np.random.RandomState(123)
        pick = rs.choice(np.nonzero(live)[0], 24, replace=False)
        q = (data[pick] + 0.05 * rs.randn(24, 32)).astype(np.float32)
        ids, _, _ = idx.search(q, k=10, L=64)
        for row in ids:
            assert not (set(row.tolist()) & set(victims)), "deleted ids returned"
        gt = rec.ground_truth(q, data, live, 10)
        r = rec.recall_at_k(ids, gt, 10)
        assert r >= 0.8, f"post-delete recall {r}"
    finally:
        idx.restore(snap)


def test_replace_updates_results(built_index):
    idx, data, _ = built_index
    snap = idx.snapshot()
    try:
        # move doc 0 on top of doc 1500's vector: searching near it must find 0
        target = data[1500] + 1e-3
        idx.insert([0], target[None, :])  # replace path
        ids, _, _ = idx.search(target[None, :], k=5, L=48)
        assert 0 in ids[0].tolist()
    finally:
        idx.restore(snap)


def test_paginated_search_disjoint_and_ordered(built_index):
    idx, data, _ = built_index
    q = _queries_from(data, np.random.RandomState(55), 1)[0]
    state = idx.start_pagination(q, L=32)
    seen, all_pages = set(), []
    for _ in range(4):
        ids, dists, state = idx.next_page(q, state, k=5, rerank=False)
        page = [i for i in ids.tolist() if i >= 0]
        assert not (set(page) & seen), "pages must not repeat results"
        seen |= set(page)
        all_pages.append(page)
    assert len(seen) >= 15
    # union of pages ≈ prefix of brute-force ranking
    gt = rec.ground_truth(q[None], data, np.ones(len(data), bool), 20)[0]
    overlap = len(seen & set(gt.tolist())) / 20
    assert overlap >= 0.6, overlap


def test_filtered_search_modes(built_index):
    idx, data, _ = built_index
    rng = np.random.RandomState(31)
    doc_filter = np.zeros(idx.cfg.capacity, bool)
    match_slots = rng.choice(len(data), 400, replace=False)
    doc_filter[match_slots] = True
    q = clustered_data(np.random.RandomState(77), 8, 32)
    live = np.zeros(len(data), bool)
    live[match_slots] = True
    gt = rec.ground_truth(q, data, live, 5)
    for mode in ("qflat", "post", "beta"):
        ids, dists, stats = idx.filtered_search(q, k=5, doc_filter=doc_filter, mode=mode)
        valid = ids[ids >= 0]
        assert np.isin(valid, match_slots).all(), f"{mode} returned non-matching docs"
        r = rec.recall_at_k(ids, gt, 5)
        assert r >= 0.5, f"{mode} filtered recall {r}"


def test_filtered_auto_routing(built_index):
    idx, data, _ = built_index
    few = np.zeros(idx.cfg.capacity, bool)
    few[:50] = True  # < QFLAT_MAX_MATCHES → qflat plan
    q = clustered_data(np.random.RandomState(2), 2, 32)
    _, _, stats = idx.filtered_search(q, k=5, doc_filter=few, mode="auto")
    assert stats.plan in ("qflat", "brute")
