"""repro.compat: mesh construction, feature detection, shard_map shim,
abstract-mesh contexts, and Pallas dynamic-slice helpers — exercised on
whatever JAX version is installed (both branches must behave identically
from the caller's point of view)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl
from jax.sharding import PartitionSpec as P

from repro import compat


def test_version_parsing_and_ordering():
    assert compat.JAX_VERSION == compat._version_tuple(jax.__version__)
    assert compat.jax_at_least(0, 4)
    assert not compat.jax_at_least(99, 0)
    # suffixes like "0.4.38.dev20250101" must not crash
    assert compat._version_tuple("0.4.38.dev20250101")[:3] == (0, 4, 38)
    assert compat._version_tuple("garbage") == (0,)
    # pre-release digits must not concatenate: 38rc1 is 38, not 381
    assert compat._version_tuple("0.4.38rc1") == (0, 4, 38)
    assert compat._version_tuple("0.7.0rc1") == (0, 7, 0)


def test_feature_detection_consistency():
    assert compat.supports_axis_types() == compat.has_api(jax.sharding, "AxisType")
    assert compat.supports_abstract_mesh_context() == compat.has_api(
        jax.sharding, "use_abstract_mesh")
    # deprecation-raising getattr must not leak
    class Raises:
        def __getattr__(self, name):
            raise AttributeError(name)
    assert not compat.has_api(Raises(), "anything")


def test_make_mesh_host_devices():
    n = len(jax.devices())
    mesh = compat.make_mesh((n,), ("data",))
    assert isinstance(mesh, jax.sharding.Mesh)
    assert mesh.axis_names == ("data",)
    assert mesh.shape["data"] == n
    # multi-axis on a single device
    mesh2 = compat.make_mesh((1, 1), ("data", "model"))
    assert mesh2.axis_names == ("data", "model")


def test_make_mesh_usable_for_sharding():
    mesh = compat.make_mesh((len(jax.devices()),), ("data",))
    sh = jax.sharding.NamedSharding(mesh, P())
    x = jax.device_put(jnp.arange(8.0), sh)
    np.testing.assert_array_equal(np.asarray(x), np.arange(8.0))


def test_use_abstract_mesh_is_context_manager():
    mesh = compat.make_mesh((len(jax.devices()),), ("data",))
    with compat.use_abstract_mesh(mesh):
        y = jnp.square(jnp.arange(4.0))
    np.testing.assert_array_equal(np.asarray(y), [0.0, 1.0, 4.0, 9.0])


def test_get_abstract_mesh_none_or_mesh():
    m = compat.get_abstract_mesh()
    # outside any mesh context: None, or an empty-axis ambient mesh filtered
    # to None by the helper
    assert m is None or m.axis_names


def test_shard_map_runs_and_matches_reference():
    n = len(jax.devices())
    mesh = compat.make_mesh((n,), ("data",))
    x = jnp.arange(4 * n, dtype=jnp.float32).reshape(n, 4)

    def local(v):
        s = jax.lax.psum(jnp.sum(v), "data")
        return v * 2.0 + s

    fn = jax.jit(compat.shard_map(
        local, mesh, in_specs=P("data"), out_specs=P("data")))
    out = fn(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x) * 2.0 + float(x.sum()))


def test_ds_helpers_build_slices():
    assert isinstance(compat.ds(0, 4), compat.Slice)
    assert isinstance(compat.ds1(3), compat.Slice)
    s = compat.ds1(2)
    assert s.size == 1
    mixed = compat.ds_index(0, compat.ds(1, 2), 5)
    assert all(isinstance(i, compat.Slice) for i in mixed)
    assert (mixed[1].size, mixed[0].size, mixed[2].size) == (2, 1, 1)
    # python slices and non-scalar arrays pass through unchanged
    passthru = compat.ds_index(slice(None), np.arange(3), 1)
    assert passthru[0] == slice(None)
    assert isinstance(passthru[1], np.ndarray)
    assert isinstance(passthru[2], compat.Slice)
    # 0-d traced/array scalars are wrapped like ints
    assert isinstance(compat.ds_index(np.int32(2))[0], compat.Slice)


def test_ds_helpers_in_pallas_interpret():
    """pl.load/pl.store with compat-built indices run under interpret mode
    (raw ints in these index tuples are exactly what 0.4.x rejects)."""

    def kernel(x_ref, o_ref):
        def body(i, _):
            row = pl.load(x_ref, (compat.ds1(0), compat.ds1(i)))
            pl.store(o_ref, compat.ds_index(0, pl.ds(i, 1)), row + 1.0)
            return 0

        jax.lax.fori_loop(0, x_ref.shape[1], body, 0)

    x = jnp.arange(8.0, dtype=jnp.float32).reshape(1, 8)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((1, 8), jnp.float32),
        interpret=True,
    )(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x) + 1.0)


def test_pallas_interpret_default_matches_backend():
    assert compat.pallas_interpret_default() == (jax.default_backend() != "tpu")
