"""Tiered storage subsystem (ISSUE 10): ``store/pages.py`` + the metered
rerank path that threads page misses through RU, latency, and the serve
plane.

The contracts under test:

  * **determinism** — the resident set is a pure function of
    (seed, budget history, touch sequence): two same-seed caches fed the
    same touches are bit-identical; the seeded warm set reproduces when a
    partition is un-tiered and re-tiered.
  * **pin-during-rerank** — a page pinned by an in-flight rerank is never
    an eviction victim, even under transient budget overflow; ``unpin``
    drains the overflow, and an unbalanced unpin is an error.
  * **modelled residency** — search results (ids, distances) are
    bit-identical at every residency level; only the RU/latency bill
    changes, and the bill is exactly ``misses * ru_per_vector_page``.
  * **RU conservation** — at the serve plane, per-tenant registry
    attribution still equals governor settlements with a live paged
    tier, and ``serve_tier_total`` totals equal the page-counter deltas.
  * **crash recovery** — a crash at ``upsert:post_full`` loses the
    uncommitted ``set_full`` replay entirely (all-or-nothing), and
    ``recovery_invariants`` bit-compares the paged tier page by page.
  * **memory accounting** — ``snapshot()["memory"]`` reports per-tier
    bytes and cache occupancy that reconcile with the per-partition
    page-store states.
  * **policy knob (d)** — the cache-sizing knob is dormant on untiered
    collections and moves only on windowed miss-rate evidence, with its
    own cooldown; engine actuation resizes only opted-in partitions.
"""
import numpy as np
import pytest

from repro.core import GraphConfig
from repro.partition.partitioner import (CollectionConfig, PhysicalPartition,
                                         hash_key)
from repro.serve import (AdaptivePolicy, EngineConfig, PolicySignals,
                         VectorCollectionService)
from repro.store.faults import CrashError, FaultPlan, recovery_invariants
from repro.store.pages import PagedVectorStore
from repro.store.provider import StoreProviderSet

from conftest import clustered_data

DIM = 16


# ---------------------------------------------------------------------------
# page cache: determinism, pinning, scan resistance
# ---------------------------------------------------------------------------


def _touch_script(seed, n_touches=200, capacity=640, page_size=64):
    r = np.random.RandomState(seed)
    return [r.randint(0, capacity, size=r.randint(1, 12))
            for _ in range(n_touches)]


def test_eviction_determinism_same_seed():
    """Same seed + same touch sequence → bit-identical cache state: the
    per-touch (hits, misses), the final resident set, the clock hand,
    and every cumulative counter."""
    script = _touch_script(3)
    a, b = (PagedVectorStore(640, DIM, page_size=64, budget_pages=4, seed=7)
            for _ in range(2))
    for slots in script:
        assert a.touch(slots)[:2] == b.touch(slots)[:2]
    assert np.array_equal(a.resident, b.resident)
    assert a.hand == b.hand
    assert a.state() == b.state()
    assert a.evictions > 0, "script must actually exercise eviction"


def test_warm_set_is_seeded_and_reseeds_on_retier():
    """A cold finite-budget cache warms a seeded page subset; un-tiering
    (budget=None) and re-tiering reproduces that exact warm set, and a
    different seed produces a different one."""
    a = PagedVectorStore(640, DIM, page_size=64, budget_pages=5, seed=1)
    warm = a.resident.copy()
    assert warm.sum() == 5
    a.set_budget(None)
    assert a.resident.all()
    a.set_budget(5)
    assert np.array_equal(a.resident, warm)
    b = PagedVectorStore(640, DIM, page_size=64, budget_pages=5, seed=2)
    assert not np.array_equal(b.resident, warm)


def test_pin_during_rerank_never_evicts_inflight_page():
    """An in-flight rerank pins its working set: later misses admitting
    other pages must not evict a pinned page, even when the pin set
    transiently overflows the budget. ``unpin`` drains back to budget."""
    pv = PagedVectorStore(640, DIM, page_size=64, budget_pages=2, seed=0)
    # pin a 3-page working set (overflows budget=2: allowed while pinned)
    _, _, pinned = pv.touch([0, 70, 140], pin=True)
    assert pinned.size == 3 and pv.resident[pinned].all()
    # hammer the other pages; the pinned trio must survive every sweep
    for s in range(200, 640, 30):
        pv.touch([s])
        assert pv.resident[pinned].all(), "evicted a pinned in-flight page"
    pv.unpin(pinned)
    assert pv.n_resident <= 2, "unpin must drain the transient overflow"
    with pytest.raises(AssertionError, match="unpin"):
        pv.unpin(pinned)  # double release: pins would go negative


def test_scan_touches_are_billed_but_never_admitted():
    """``admit=False`` (brute/exact sweeps): misses are counted — the
    fetch is real and billed — but the hot set is scan-resistant."""
    pv = PagedVectorStore(640, DIM, page_size=64, budget_pages=3, seed=4)
    warm = pv.resident.copy()
    hits, misses, _ = pv.touch(np.arange(640), admit=False)
    assert hits == 3 and misses == 7
    assert np.array_equal(pv.resident, warm), "a scan flushed the hot set"
    assert pv.admits == 0 and pv.evictions == 0


def test_zero_budget_never_admits():
    pv = PagedVectorStore(640, DIM, page_size=64, budget_pages=0, seed=0)
    hits, misses, _ = pv.touch(np.arange(640))
    assert hits == 0 and misses == 10 and pv.n_resident == 0


# ---------------------------------------------------------------------------
# modelled residency: bit-identical results, metered bill
# ---------------------------------------------------------------------------


def _partition(rng, n=160):
    g = GraphConfig(capacity=2 * n + 64, R=16, M=8, L_build=32, L_search=32,
                    bootstrap_sample=48, refine_sample=10**9, batch_size=64)
    cc = CollectionConfig(dim=DIM, graph=g, max_vectors_per_partition=2 * n)
    part = PhysicalPartition(cc, 0, 1 << 32, 0)
    data = clustered_data(rng, n, DIM)
    ids = list(range(n))
    part.insert(ids, [hash_key(i) for i in ids], data)
    return part, data


def test_residency_changes_bill_not_results(rng):
    """The tier is modelled: shrinking residency leaves ids/distances
    bit-identical and raises RU by EXACTLY the page-miss bill. frac=1.0
    is indistinguishable from budget=None on every axis."""
    part, data = _partition(rng)
    queries = data[rng.choice(len(data), 16, replace=False)] + 0.01
    pages = part.providers.pages
    ids0, d0, ru0, st0 = part.search_batch(queries, k=10)
    assert st0.tier_misses == 0 and pages.misses == 0

    part.set_residency(1.0)  # finite budget == n_pages: still all-hit
    ids1, d1, ru1, _ = part.search_batch(queries, k=10)
    assert np.array_equal(ids0, ids1) and np.array_equal(d0, d1)
    assert ru1 == ru0 and pages.misses == 0

    part.set_residency(0.25)
    m0 = pages.misses
    ids2, d2, ru2, st2 = part.search_batch(queries, k=10)
    miss_delta = pages.misses - m0
    assert np.array_equal(ids0, ids2) and np.array_equal(d0, d2)
    assert st2.tier_misses > 0 and miss_delta > 0
    assert ru2 - ru0 == pytest.approx(
        miss_delta * part.providers.meter.cfg.ru_per_vector_page, rel=1e-9), \
        "RU delta must be exactly the page-miss bill"
    assert int((pages.pins > 0).sum()) == 0, "rerank left pages pinned"


# ---------------------------------------------------------------------------
# serve plane: RU conservation + tier counter conservation
# ---------------------------------------------------------------------------


def _tiered_service(rng, n=360, parts=3, frac=0.5, **engine_kw):
    g = GraphConfig(capacity=240, R=16, M=8, L_build=32, L_search=32,
                    bootstrap_sample=48, refine_sample=10**9, batch_size=64)
    svc = VectorCollectionService(dim=DIM, graph=g,
                                  max_vectors_per_partition=200,
                                  initial_partitions=parts,
                                  engine_cfg=EngineConfig(**engine_kw))
    data = clustered_data(rng, n, DIM)
    svc.upsert([{"id": i} for i in range(n)], data,
               partition_keys=[f"pk{i}" for i in range(n)])
    svc.set_residency(frac)
    return svc, data


def _page_counters(svc):
    h = sum(p.providers.pages.hits for p in svc.collection.partitions)
    m = sum(p.providers.pages.misses for p in svc.collection.partitions)
    return h, m


def test_tiered_ru_and_tier_counter_conservation(rng):
    """With a live paged tier, the three RU views still agree exactly
    (registry == engine aggregates == governor settlements, miss bill
    included), and ``serve_tier_total{outcome}`` equals the page stores'
    own hit/miss deltas — the registry never invents or drops a fetch."""
    svc, data = _tiered_service(rng, frac=0.5, admission_control=True,
                                tenant_ru_s=10**9)
    eng = svc.engine
    h0, m0 = _page_counters(svc)
    queries = data[rng.choice(len(data), 24, replace=False)] + 0.01
    for i, q in enumerate(queries):
        eng.submit_query(q, k=5, tenant=f"t{i % 2}")
    eng.drain()
    m, obs = eng.metrics, eng.obs
    assert obs.total("serve_ru_total", op="query") == \
        pytest.approx(m.ru_query_total, rel=1e-9)
    for t, gov in eng.tenants.items():
        attributed = sum(obs.total("serve_ru_total", tenant=str(t), op=op)
                         for op in ("query", "page", "hedge"))
        assert attributed == pytest.approx(gov.consumed, rel=1e-9), \
            f"tenant {t}: registry {attributed} vs governor {gov.consumed}"
    dh, dm = (a - b for a, b in zip(_page_counters(svc), (h0, m0)))
    assert dm > 0, "a 0.5-residency run must actually miss"
    assert obs.total("serve_tier_total", outcome="hit") == \
        pytest.approx(dh, rel=1e-6)
    assert obs.total("serve_tier_total", outcome="miss") == \
        pytest.approx(dm, rel=1e-6)


# ---------------------------------------------------------------------------
# crash recovery: set_full replay through the paged tier (satellite a)
# ---------------------------------------------------------------------------


def _crash_at_post_full(seed=29, n0=20, dim=8):
    g = GraphConfig(capacity=96, R=8, M=4, L_build=16, L_search=24,
                    bootstrap_sample=16, refine_sample=10**9, batch_size=8)
    cc = CollectionConfig(dim=dim, graph=g, max_vectors_per_partition=80)
    rng = np.random.RandomState(seed)
    subject, twin = (PhysicalPartition(cc, 0, 1 << 32, 0) for _ in range(2))
    data = rng.randn(n0, dim).astype(np.float32)
    ids = list(range(n0))
    props = [(("cat", i % 3),) for i in ids]
    for p in (subject, twin):
        p.insert(ids, [hash_key(i) for i in ids], data, props=props)
    snap = subject.providers.snapshot_bytes()
    FaultPlan(seed=seed).arm("upsert:post_full").attach(subject.providers)
    with pytest.raises(CrashError):
        subject.insert([n0], [hash_key(n0)],
                       rng.randn(1, dim).astype(np.float32),
                       props=[(("cat", 0),)])
    fresh = StoreProviderSet(
        subject.providers.neighbors.shape[0],
        subject.providers.neighbors.shape[1],
        subject.providers.codes.shape[1],
        subject.providers.vectors.shape[1],
    )
    fresh.recover(snap, subject.providers.wal_bytes())
    # the recovered node fronts its vectors with a paged tier too — the
    # parity check must hold regardless of either side's cache residency
    fresh.pages = PagedVectorStore(fresh.vectors.shape[0],
                                   fresh.vectors.shape[1],
                                   page_size=cc.vector_page_size,
                                   budget_pages=1, seed=0)
    return fresh, twin


def test_post_full_crash_discards_uncommitted_vector_write():
    """A crash AT ``upsert:post_full`` — after the full-precision write
    hit the provider but before commit — must leave no trace: the WAL's
    ``set_full`` replay is transactional, so recovery equals a twin that
    never attempted the op, bit for bit including the paged tier."""
    fresh, twin = _crash_at_post_full()
    checks = recovery_invariants(fresh, twin.providers)
    assert checks["paged_tier"], "paged-tier page compare must have run"


def test_recovery_invariants_catch_stale_paged_vector():
    """The paged-tier check has teeth: a recovered node serving one stale
    vector page (a lost ``set_full`` replay) fails parity by name."""
    fresh, twin = _crash_at_post_full()
    fresh.vectors[3, 0] += 1.0  # one stale slot on page 0
    with pytest.raises(AssertionError, match="paged_tier"):
        recovery_invariants(fresh, twin.providers)


# ---------------------------------------------------------------------------
# memory snapshot (satellite b)
# ---------------------------------------------------------------------------


def test_memory_snapshot_reconciles_with_page_stores(rng):
    svc, data = _tiered_service(rng, frac=None)
    eng = svc.engine
    mem = eng.snapshot()["memory"]
    assert set(mem) == {"resident", "vector_tier", "per_partition"}
    vt = mem["vector_tier"]
    assert not vt["tiered"] and vt["resident_frac"] == 1.0
    assert vt["resident_bytes"] == vt["total_bytes"] > 0
    for key in ("pq_codes_bytes", "adjacency_bytes", "tombstone_bytes"):
        assert mem["resident"][key] > 0
    svc.set_residency(0.25)
    for q in data[:8]:
        eng.submit_query(q + 0.01, k=5)
    eng.drain()
    mem = eng.memory_snapshot()
    vt = mem["vector_tier"]
    states = [p.providers.pages.state()
              for p in svc.collection.partitions]
    assert vt["tiered"]
    assert vt["resident_bytes"] == sum(s["resident_bytes"] for s in states)
    assert vt["capacity_pages"] == sum(s["budget_pages"] for s in states)
    assert vt["resident_pages"] <= vt["capacity_pages"]
    assert vt["hits"] == sum(s["hits"] for s in states)
    assert vt["misses"] == sum(s["misses"] for s in states) > 0
    assert 0.0 <= vt["hit_rate"] <= 1.0
    assert len(mem["per_partition"]) == len(states)


# ---------------------------------------------------------------------------
# policy knob (d): cache sizing (dormant untiered, evidence-driven tiered)
# ---------------------------------------------------------------------------


def _sig(now_s, *, hits=0.0, misses=0.0, frac=0.5, tiered=True, depth=0):
    return PolicySignals(
        now_s=now_s, queue_depth=depth, ingest_backlog_chunks=0,
        ingest_backlog_ops=0, slo_ms=None, stages={}, ru_total=0.0,
        lanes_busy_s=0.0, lane_occupancy=0.0, lanes=1, partitions=1,
        tier_hits=hits, tier_misses=misses, tier_resident_frac=frac,
        tiered=tiered,
    )


def test_cache_knob_dormant_without_a_tier():
    """Untiered signals (every partition fully resident) must never move
    the cache knob, whatever the counters claim — the knob may only act
    on a tier the operator opted into."""
    pol = AdaptivePolicy(EngineConfig(policy="adaptive"))
    for t in range(5):
        dec = pol.tick(_sig(float(t), hits=0.0, misses=100.0 * (t + 1),
                            tiered=False))
        assert dec.cache_step == 0


def test_cache_knob_grows_on_misses_with_cooldown():
    pol = AdaptivePolicy(EngineConfig(policy="adaptive"),
                         cache_cooldown_s=1.0)
    assert pol.tick(_sig(0.0, hits=5.0, misses=95.0)).cache_step == 1
    # within cooldown: held, even under a 100% miss rate
    assert pol.tick(_sig(0.5, hits=5.0, misses=195.0)).cache_step == 0
    assert pol.tick(_sig(1.5, hits=5.0, misses=295.0)).cache_step == 1
    # fully resident already: nothing left to grow
    assert pol.tick(_sig(3.0, hits=5.0, misses=395.0,
                         frac=1.0)).cache_step == 0


def test_cache_knob_shrinks_only_when_idle_and_above_floor():
    pol = AdaptivePolicy(EngineConfig(policy="adaptive"),
                         cache_cooldown_s=0.0)
    # near-zero miss rate but a busy queue: hold (shrinking under load
    # would trade p95 for bytes exactly when latency matters)
    assert pol.tick(_sig(0.0, hits=100.0, misses=1.0,
                         depth=4)).cache_step == 0
    assert pol.tick(_sig(1.0, hits=300.0, misses=2.0)).cache_step == -1
    # at the floor: never shrink below min_frac
    assert pol.tick(_sig(2.0, hits=500.0, misses=3.0,
                         frac=0.1)).cache_step == 0


def test_engine_cache_actuation_resizes_only_opted_in_partitions(rng):
    """``_apply_cache_step`` grows every finite-budget tier by ~10% of
    its pages (clamped), never touches budget=None partitions, and the
    move is attributable in metrics + the labeled registry."""
    svc, _ = _tiered_service(rng, frac=0.5)
    eng = svc.engine
    parts = svc.collection.partitions
    parts[0].set_residency(None)  # opted back out: must stay untouched
    before = [p.providers.pages.budget_pages for p in parts]
    eng._apply_cache_step(+1)
    after = [p.providers.pages.budget_pages for p in parts]
    assert after[0] is None
    assert all(a > b for a, b in zip(after[1:], before[1:]))
    assert eng.metrics.policy_cache_resizes == 1
    assert eng.obs.total("serve_policy_total", knob="cache",
                         action="grow") == 1.0
    eng._apply_cache_step(-1)
    assert [p.providers.pages.budget_pages for p in parts][1:] == before[1:]
    assert eng.obs.total("serve_policy_total", knob="cache",
                         action="shrink") == 1.0
