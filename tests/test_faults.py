"""Fault-tolerance plane: kill-and-recover parity, WAL damage, deadlines,
partial-result degradation, replica rebuild LSN capture."""
import numpy as np
import pytest
from proptest import given, settings
from proptest import strategies as st

from repro.core import GraphConfig
from repro.partition import Collection, CollectionConfig, ReplicaSet
from repro.partition.fanout import AllPartitionsFailed, compile_partition_filter
from repro.partition.partitioner import PhysicalPartition, hash_key
from repro.serve import (DeadlineExceeded, EngineConfig, F, VectorQuery,
                         VectorCollectionService, validate_trace_record)
from repro.serve.vector_engine import VectorServeEngine
from repro.store.codec import WalCorruption
from repro.store.faults import (CrashError, FaultPlan, corrupt_record,
                                recovery_invariants, torn_tail)
from repro.store.provider import StoreProviderSet

UPSERT_BARRIERS = ("upsert:begin", "upsert:post_index", "upsert:pre_commit")
DELETE_BARRIERS = ("delete:begin", "delete:post_props", "delete:pre_commit")
SPLIT_BARRIERS = ("split:begin", "split:mid_rehome", "split:pre_commit")
MERGE_BARRIERS = ("merge:begin", "merge:mid", "merge:pre_commit")

DIM = 8


def _graph(cap=96):
    return GraphConfig(capacity=cap, R=8, M=4, L_build=16, L_search=24,
                       bootstrap_sample=16, refine_sample=10**9, batch_size=8)


def _partitions(seed, n_parts, n0=20):
    """``n_parts`` identically-constructed partitions holding the same n0
    docs (with property terms), plus the rng/data used to build them."""
    cc = CollectionConfig(dim=DIM, graph=_graph(),
                          max_vectors_per_partition=80)
    parts = [PhysicalPartition(cc, 0, 1 << 32, 0) for _ in range(n_parts)]
    rng = np.random.RandomState(seed)
    data = rng.randn(n0, DIM).astype(np.float32)
    ids = list(range(n0))
    hashes = [hash_key(i) for i in ids]
    props = [(("cat", i % 3),) for i in range(n0)]
    for p in parts:
        p.insert(ids, hashes, data, props=props)
    return parts, rng, data


def _fresh_like(pv) -> StoreProviderSet:
    return StoreProviderSet(pv.neighbors.shape[0], pv.neighbors.shape[1],
                            pv.codes.shape[1], pv.vectors.shape[1])


# ---------------------------------------------------------------------------
# kill-and-recover: crash at any barrier → durable state == uncrashed twin
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000),
       barrier=st.sampled_from(list(UPSERT_BARRIERS + DELETE_BARRIERS)),
       extra=st.integers(1, 5))
def test_property_kill_and_recover_upsert_delete(seed, barrier, extra):
    """Crash an upsert/delete at a random barrier: recovery from the
    durable bytes (checkpoint + committed WAL) must equal a twin that
    never attempted the interrupted op — bit for bit, terms included."""
    parts, rng, _data = _partitions(seed, 2)
    subject, twin = parts
    snap = subject.providers.snapshot_bytes()  # checkpoint
    # committed post-checkpoint ops land on BOTH sides
    new_ids = list(range(20, 20 + extra))
    vecs = rng.randn(extra, DIM).astype(np.float32)
    for p in (subject, twin):
        p.insert(new_ids, [hash_key(i) for i in new_ids], vecs,
                 props=[(("cat", i % 3),) for i in new_ids])
    # the victim op runs ONLY on the subject, with a crash armed
    FaultPlan(seed=seed).arm(barrier).attach(subject.providers)
    with pytest.raises(CrashError):
        if barrier.startswith("upsert"):
            v = rng.randn(2, DIM).astype(np.float32)
            subject.insert([40, 41], [hash_key(40), hash_key(41)], v,
                           props=[(("cat", 0),), (("cat", 1),)])
        else:
            subject.delete([new_ids[0]])
    # the process died: only the durable bytes survive
    wal = subject.providers.wal_bytes()
    fresh = _fresh_like(subject.providers)
    applied = fresh.recover(snap, wal)
    assert applied == subject.providers.committed  # crashed op left no record
    recovery_invariants(fresh, twin.providers)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000),
       barrier=st.sampled_from(list(SPLIT_BARRIERS + MERGE_BARRIERS)))
def test_property_split_merge_crash_is_all_or_nothing(seed, barrier):
    """A crash anywhere inside split/merge (before the routing swap) must
    leave the collection untouched: same partitions, same durable state as
    a twin collection that never attempted the operation."""
    def build():
        g = _graph(160)
        cc = CollectionConfig(dim=DIM, graph=g,
                              max_vectors_per_partition=120,
                              initial_partitions=2)
        col = Collection(cc)
        rng = np.random.RandomState(seed)
        data = rng.randn(40, DIM).astype(np.float32)
        col.insert(list(range(40)), [f"pk{i}" for i in range(40)], data,
                   props=[(("cat", i % 2),) for i in range(40)])
        return col

    col, twin = build(), build()
    FaultPlan(seed=seed).arm(barrier).attach(col.partitions[0].providers)
    with pytest.raises(CrashError):
        if barrier.startswith("split"):
            col.split(0)
        else:
            col.merge(0)
    assert len(col.partitions) == 2
    assert col.splits == 0 and col.merges == 0
    assert col.num_docs == twin.num_docs
    for ps, pt in zip(col.partitions, twin.partitions):
        recovery_invariants(ps.providers, pt.providers)


def test_shard_rekey_crash_keeps_old_copy():
    """Re-homing a doc (re-upsert under a pk owned by another partition)
    starts with a delete in the old owner; a crash there must leave the
    committed copy intact — no partition ends up without the doc."""
    g = _graph(160)
    cc = CollectionConfig(dim=DIM, graph=g, max_vectors_per_partition=120,
                          initial_partitions=2)
    col = Collection(cc)
    rng = np.random.RandomState(3)
    data = rng.randn(10, DIM).astype(np.float32)
    pks = [f"pk{i}" for i in range(10)]
    col.insert(list(range(10)), pks, data)
    owner = col.owner_of(0)
    # find a pk the OTHER partition owns → the re-upsert must re-home
    other = next(p for p in col.partitions if p is not owner)
    new_pk = next(f"alt{i}" for i in range(1000)
                  if other.owns(hash_key(f"alt{i}")))
    snap = owner.providers.snapshot_bytes()
    FaultPlan().arm("delete:begin").attach(owner.providers)
    with pytest.raises(CrashError):
        col.insert([0], [new_pk], data[0][None, :])
    fresh = _fresh_like(owner.providers)
    fresh.recover(snap, owner.providers.wal_bytes())
    slot = owner.index.doc_to_slot[0]
    assert fresh.live[slot], "crashed re-key delete must not commit"
    np.testing.assert_array_equal(fresh.vectors[slot],
                                  owner.providers.vectors[slot])


def test_recovered_state_serves_identical_queries():
    """Query / pagination / filtered parity: a node restarted from the
    recovered durable state answers exactly like the uncrashed twin."""
    parts, rng, data = _partitions(17, 3)
    subject, twin, restarted = parts
    snap = subject.providers.snapshot_bytes()
    extra = rng.randn(4, DIM).astype(np.float32)
    ids = [30, 31, 32, 33]
    for p in parts:
        p.insert(ids, [hash_key(i) for i in ids], extra,
                 props=[(("cat", i % 3),) for i in ids])
        p.delete([2])
    FaultPlan().arm("upsert:post_index").attach(subject.providers)
    with pytest.raises(CrashError):
        subject.insert([50], [hash_key(50)],
                       rng.randn(1, DIM).astype(np.float32), props=[()])
    fresh = _fresh_like(subject.providers)
    fresh.recover(snap, subject.providers.wal_bytes())
    recovery_invariants(fresh, twin.providers)
    # graft the recovered durable state into the restarted node (its host
    # state was rebuilt from the same committed prefix)
    rp = restarted.providers
    rp.neighbors[:] = fresh.neighbors
    rp.codes[:] = fresh.codes
    rp.versions[:] = fresh.versions
    rp.live[:] = fresh.live
    rp.vectors[:] = fresh.vectors
    rp.tree = fresh.tree
    rp._dirty()
    q = data[:4] + 0.01
    ids_t, d_t, _, _ = twin.search_batch(q, 5)
    ids_r, d_r, _, _ = restarted.search_batch(q, 5)
    np.testing.assert_array_equal(ids_t, ids_r)
    np.testing.assert_allclose(d_t, d_r)
    # filtered parity
    pred = F.eq("cat", 1)
    mask_t, _, _ = compile_partition_filter(twin, pred)
    mask_r, _, _ = compile_partition_filter(restarted, pred)
    np.testing.assert_array_equal(mask_t, mask_r)
    fids_t, fd_t, _, _ = twin.filtered_search_batch(q, 5, mask_t)
    fids_r, fd_r, _, _ = restarted.filtered_search_batch(q, 5, mask_r)
    np.testing.assert_array_equal(fids_t, fids_r)
    # pagination parity
    st_t = twin.start_pagination(q[0])
    st_r = restarted.start_pagination(q[0])
    pids_t, pd_t, _, _, _ = twin.next_page(q[0], st_t, 5)
    pids_r, pd_r, _, _, _ = restarted.next_page(q[0], st_r, 5)
    np.testing.assert_array_equal(pids_t, pids_r)


# ---------------------------------------------------------------------------
# WAL damage: torn tails truncate, interior corruption is rejected
# ---------------------------------------------------------------------------


def _provider_with_records(n=6):
    pv = StoreProviderSet(64, 8, 4, DIM)
    from repro.core.providers import Context
    ctx = Context()
    snap = pv.snapshot_bytes()
    rng = np.random.RandomState(0)
    for i in range(n):  # each bare write auto-commits one WAL record
        pv.set_full(ctx, np.array([i]), rng.randn(1, DIM).astype(np.float32))
    return pv, snap


def test_torn_tail_truncates_to_last_whole_record():
    pv, snap = _provider_with_records(6)
    wal = pv.wal_bytes()
    torn = torn_tail(wal, np.random.RandomState(1), nbytes=3)
    fresh = _fresh_like(pv)
    applied = fresh.recover(snap, torn)
    assert fresh.recovered_torn_tail
    assert applied == pv.committed - 1
    # the truncated prefix equals a twin that only committed n-1 records
    twin, _ = _provider_with_records(5)
    recovery_invariants(fresh, twin)


def test_corrupted_final_record_is_torn_not_fatal():
    pv, snap = _provider_with_records(4)
    wal = corrupt_record(pv.wal_bytes(), np.random.RandomState(2), index=3)
    fresh = _fresh_like(pv)
    applied = fresh.recover(snap, wal)
    assert fresh.recovered_torn_tail and applied == pv.committed - 1


def test_corrupted_interior_record_raises():
    pv, snap = _provider_with_records(5)
    wal = corrupt_record(pv.wal_bytes(), np.random.RandomState(3), index=1)
    fresh = _fresh_like(pv)
    with pytest.raises(WalCorruption):
        fresh.recover(snap, wal)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 8))
def test_property_torn_tail_always_recovers(seed, n):
    pv, snap = _provider_with_records(n)
    torn = torn_tail(pv.wal_bytes(), np.random.RandomState(seed))
    fresh = _fresh_like(pv)
    applied = fresh.recover(snap, torn)
    assert applied == pv.committed - 1  # at most the final record is lost
    twin, _ = _provider_with_records(n - 1)
    recovery_invariants(fresh, twin)


# ---------------------------------------------------------------------------
# replica rebuild: LSN captured with the bytes, not by fiat
# ---------------------------------------------------------------------------


def test_rebuild_applied_lsn_matches_capture():
    """A rebuild from an old capture must come back AT the capture's LSN —
    behind the set — not claim the set's current LSN by fiat."""
    cc = CollectionConfig(dim=DIM, graph=_graph(),
                          max_vectors_per_partition=80)
    part = PhysicalPartition(cc, 0, 1 << 32, 0)
    rs = ReplicaSet(part, num_replicas=3)
    rng = np.random.RandomState(5)
    rs.insert([0, 1], [hash_key(0), hash_key(1)],
              rng.randn(2, DIM).astype(np.float32))
    rs.insert([2], [hash_key(2)], rng.randn(1, DIM).astype(np.float32))
    cap = rs.capture()
    set_lsn_at_capture, store_lsn_at_capture = cap[2], cap[3]
    rs.insert([3], [hash_key(3)], rng.randn(1, DIM).astype(np.float32))
    rs.kill(1, now_s=0.0)
    fresh = rs.rebuild(1, capture=cap)
    assert rs.replicas[1].applied_lsn == set_lsn_at_capture == 2
    assert rs.replicas[1].applied_lsn < rs.lsn
    assert fresh.committed == store_lsn_at_capture
    assert not fresh.live[part.index.doc_to_slot[3]]  # post-capture write absent


def test_probe_dead_rebuild_matches_live_state():
    """The cooldown re-probe path rebuilds through real recovery; with no
    writes since capture the revived replica is bit-identical to live."""
    cc = CollectionConfig(dim=DIM, graph=_graph(),
                          max_vectors_per_partition=80)
    part = PhysicalPartition(cc, 0, 1 << 32, 0)
    rs = ReplicaSet(part, num_replicas=3, reprobe_after_s=1.0)
    rng = np.random.RandomState(6)
    rs.insert(list(range(8)), [hash_key(i) for i in range(8)],
              rng.randn(8, DIM).astype(np.float32))
    rs.kill(2, now_s=0.0)
    assert rs.probe_dead(now_s=5.0) == [2]
    assert rs.replicas[2].alive and rs.recoveries == 1
    fresh = rs.rebuild(2)
    recovery_invariants(fresh, part.providers)


# ---------------------------------------------------------------------------
# deadlines (408) and partial-result degradation through the engine
# ---------------------------------------------------------------------------


def _service(parts=2, replicas=2, n=60, deadline_ms=None):
    svc = VectorCollectionService(
        dim=DIM, graph=_graph(160), max_vectors_per_partition=200,
        initial_partitions=parts, replicas=replicas,
        engine_cfg=EngineConfig(max_batch=4, default_deadline_ms=deadline_ms),
    )
    rng = np.random.RandomState(9)
    data = rng.randn(n, DIM).astype(np.float32)
    svc.upsert([{"id": i, "cat": i % 3} for i in range(n)], data)
    return svc, data


def test_deadline_expired_in_queue_is_408_with_refund():
    svc, data = _service()
    eng = svc.engine
    gov = eng.tenant_governor("t0")
    rid = eng.submit_query(data[0], k=5, tenant="t0", deadline_ms=5.0)
    consumed_reserved = gov.consumed
    assert consumed_reserved > 0  # reservation taken at admission
    eng.clock.advance(0.050)  # 50 ms > 5 ms budget, still queued
    eng.pump(force=True)
    resp = eng.pop_response(rid)
    assert resp.status == 408 and resp.ids is None
    assert resp.wait_ms >= 5.0 and resp.latency_ms == resp.wait_ms
    assert gov.consumed == 0.0  # reservation fully refunded
    assert gov.refunded == consumed_reserved
    assert eng.metrics.queries_deadline == 1
    assert eng.obs.counter_value("serve_deadline_total", tenant="t0") == 1
    assert eng.obs.counter_value("serve_requests_total", tenant="t0",
                                 kind="query", status="408") == 1
    # the 408 trace reconciles: root spans tile the waited interval
    recs = [r for r in eng.tracer.recorder.records() if r["status"] == 408]
    assert len(recs) == 1
    validate_trace_record(recs[0])
    assert "deadline_exceeded" in recs[0]["anomalies"]
    assert eng.observability_summary()["per_tenant"]["t0"][
        "deadline_exceeded"] == 1


def test_deadline_not_expired_serves_normally():
    svc, data = _service(deadline_ms=10_000.0)
    r = svc.query(VectorQuery(vector=data[1], k=5,
                              deadline_ms=5_000.0))
    assert r.complete and len(r.ids) == 5


def test_deadline_exceeded_raises_through_service():
    svc, data = _service()
    eng = svc.engine
    # arrival back-dated so the budget is already blown at submit+pump
    rid = eng.submit_query(data[2], k=5, arrival_s=eng.clock.now(),
                           deadline_ms=1.0)
    eng.clock.advance(0.01)
    eng.pump(force=True)
    assert eng.pop_response(rid).status == 408
    with pytest.raises(DeadlineExceeded):
        eng.clock.advance(0.01)
        q = VectorQuery(vector=data[2], k=5, deadline_ms=0.0)
        svc.query(q)


def test_degraded_fanout_merges_survivors():
    svc, data = _service(parts=2, replicas=2)
    eng = svc.engine
    down = svc.replica_sets[0]
    for rep in down.replicas:  # total loss of one partition's replica set
        rep.alive = False
    r = svc.query(VectorQuery(vector=data[3], k=5, tenant="t1"))
    assert not r.complete
    assert "+degraded[" in r.plan
    assert (np.asarray(r.ids) >= 0).any()  # survivors still answered
    # returned ids all live in the surviving partition
    up = svc.replica_sets[1].partition
    got = [int(i) for i in np.asarray(r.ids).ravel() if i >= 0]
    assert all(g in up.doc_pk for g in got)
    assert eng.metrics.queries_degraded >= 1
    assert eng.obs.counter_value("serve_degraded_total", tenant="t1") >= 1
    assert eng.observability_summary()["per_tenant"]["t1"]["degraded"] >= 1
    # degraded traces carry the anomaly tag + a failure span per lost pid
    recs = [r2 for r2 in eng.tracer.recorder.records()
            if "degraded" in r2.get("anomalies", ())]
    assert recs
    validate_trace_record(recs[-1])
    fail_spans = [s for s in recs[-1]["spans"]
                  if s["attrs"].get("failed")]
    assert fail_spans and fail_spans[0]["attrs"]["pid"] == down.partition.pid


def test_all_partitions_down_is_hard_error_with_refund():
    svc, data = _service(parts=2, replicas=2)
    eng = svc.engine
    for rs in svc.replica_sets:
        for rep in rs.replicas:
            rep.alive = False
    gov = eng.tenant_governor("t2")
    before = gov.consumed
    with pytest.raises(AllPartitionsFailed):
        svc.query(VectorQuery(vector=data[4], k=5, tenant="t2"))
    assert gov.consumed == before  # reservation refunded on hard failure


def test_degraded_exact_scan():
    svc, data = _service(parts=2, replicas=2)
    for rep in svc.replica_sets[0].replicas:
        rep.alive = False
    r = svc.query(VectorQuery(vector=data[5], k=5, exact=True))
    assert not r.complete and "+degraded[" in r.plan
