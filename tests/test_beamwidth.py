"""Beam-width (W-way hop batching) tests: recall parity across W, round
counts dropping ~W×, sort-based duplicate-mask correctness, pagination on
the shared expansion step, jit-signature stability, and the engine's
oversized-batch splitting."""
import jax.numpy as jnp
import numpy as np
import pytest
from proptest import given, settings
from proptest import strategies as st

from repro.core import DiskANNIndex, GraphConfig
from repro.core import recall as rec
from repro.core import search as smod
from repro.serve import (EngineConfig, ServeRequest, VectorCollectionService,
                         VectorServeEngine)

from conftest import clustered_data


@pytest.fixture(scope="module")
def built_index():
    rng = np.random.RandomState(11)
    N, D = 1200, 24
    data = clustered_data(rng, N, D)
    cfg = GraphConfig(capacity=N + 64, R=20, M=8, L_build=40, L_search=40,
                      bootstrap_sample=200, refine_sample=10**9, batch_size=64)
    idx = DiskANNIndex(cfg, D, seed=0)
    idx.insert(list(range(N)), data)
    return idx, data


def _queries(data, seed, n, noise=0.05):
    rng = np.random.RandomState(seed)
    pick = rng.choice(len(data), n, replace=False)
    return (data[pick] + noise * rng.randn(n, data.shape[1])).astype(np.float32)


# ---------------------------------------------------------------------------
# sort-based duplicate mask
# ---------------------------------------------------------------------------


def _pairwise_dup(ids: np.ndarray) -> np.ndarray:
    """Reference: the former O(n²) mask — True where ids[i] repeats an
    earlier entry (negative ids never marked; they are padding)."""
    out = np.zeros(len(ids), bool)
    seen = set()
    for i, v in enumerate(ids):
        if v >= 0 and v in seen:
            out[i] = True
        seen.add(v)
    return out


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.sampled_from([7, 41, 164]))
def test_mask_duplicates_matches_pairwise(seed, n):
    rng = np.random.RandomState(seed)
    ids = rng.randint(-1, 30, size=n).astype(np.int32)  # dense → many dups
    got = np.asarray(smod.mask_duplicates(jnp.asarray(ids)))
    np.testing.assert_array_equal(got, _pairwise_dup(ids))


# ---------------------------------------------------------------------------
# recall parity + round counts
# ---------------------------------------------------------------------------


def test_recall_parity_and_hops_across_beamwidths(built_index):
    idx, data = built_index
    q = _queries(data, 3, 32)
    gt = rec.ground_truth(q, data, np.ones(len(data), bool), 10)
    res = {}
    for W in (1, 2, 4):
        ids, dists, stats = idx.search(q, k=10, L=48, beam_width=W)
        res[W] = (rec.recall_at_k(ids, gt, 10), stats)
        assert np.all(np.diff(dists, axis=1) >= -1e-5), "results must be sorted"
    r1 = res[1][0]
    for W in (2, 4):
        assert abs(res[W][0] - r1) <= 0.01, f"W={W}: {res[W][0]} vs {r1}"
    # rounds drop ~W×; monotone in W
    h1, h2, h4 = (res[W][1].hops for W in (1, 2, 4))
    assert h4 <= h2 <= h1
    assert h4 <= 0.4 * h1, f"W=4 rounds {h4} vs W=1 {h1}"
    # same candidate-pool semantics: expansions ≈ flat, cmps rise modestly
    assert res[4][1].expansions <= 1.5 * res[1][1].expansions
    assert res[4][1].cmps >= res[1][1].cmps
    assert res[1][1].expansions == pytest.approx(res[1][1].hops)  # W=1 ⇒ equal


def test_filtered_beamwidth_parity(built_index):
    idx, data = built_index
    rng = np.random.RandomState(5)
    match = rng.choice(len(data), 400, replace=False)
    doc_filter = np.zeros(idx.cfg.capacity, bool)
    doc_filter[match] = True
    q = _queries(data[match], 9, 16)
    live = np.zeros(len(data), bool)
    live[match] = True
    gt = rec.ground_truth(q, data, live, 5)
    recs = {}
    for W in (1, 4):
        ids, _, stats = idx.filtered_search(q, k=5, doc_filter=doc_filter,
                                            mode="beta", beam_width=W)
        valid = ids[ids >= 0]
        assert np.isin(valid, match).all(), "non-matching docs returned"
        recs[W] = rec.recall_at_k(ids, gt, 5)
    assert abs(recs[4] - recs[1]) <= 0.01, recs


def test_deleted_nodes_beamwidth(built_index):
    idx, data = built_index
    snap = idx.snapshot()
    try:
        victims = list(range(50, 200))
        idx.delete(victims, policy="inplace")
        live = np.ones(len(data), bool)
        live[victims] = False
        rng = np.random.RandomState(13)
        pick = rng.choice(np.nonzero(live)[0], 24, replace=False)
        q = (data[pick] + 0.05 * rng.randn(24, data.shape[1])).astype(np.float32)
        gt = rec.ground_truth(q, data, live, 10)
        recs = {}
        for W in (1, 4):
            ids, _, _ = idx.search(q, k=10, L=48, beam_width=W)
            for row in ids:
                assert not (set(row.tolist()) & set(victims)), "deleted id returned"
            recs[W] = rec.recall_at_k(ids, gt, 10)
        assert abs(recs[4] - recs[1]) <= 0.01, recs
    finally:
        idx.restore(snap)


def test_pagination_beamwidth(built_index):
    """Pages stay disjoint and cover the brute-force prefix at W=4, and the
    shared W-way step cuts the page's sequential round count."""
    idx, data = built_index
    q = _queries(data, 21, 1)[0]
    states = {}
    for W in (1, 4):
        state = idx.start_pagination(q, L=32)
        seen = set()
        for _ in range(3):
            ids, _, state = idx.next_page(q, state, k=5, rerank=False,
                                          beam_width=W)
            page = [i for i in ids.tolist() if i >= 0]
            assert not (set(page) & seen), "pages must not repeat results"
            seen |= set(page)
        states[W] = (state, seen)
    gt = rec.ground_truth(q[None], data, np.ones(len(data), bool), 15)[0]
    for W in (1, 4):
        overlap = len(states[W][1] & set(gt.tolist())) / 15
        assert overlap >= 0.6, (W, overlap)
    assert int(states[4][0].hops) < int(states[1][0].hops)


# ---------------------------------------------------------------------------
# jit-signature stability + engine integration
# ---------------------------------------------------------------------------


def test_beamwidth_one_compile_per_signature(built_index):
    """Changing beam_width costs exactly one compile per (bucket, L) it is
    used with — and re-use at the same W costs zero."""
    idx, data = built_index
    neighbors, codes, versions, live, _ = idx.pv.materialize(idx.ctx)
    luts = idx._luts(data[:3])  # B=3 → bucket 4

    def run(W):
        return smod.bucketed_batch_greedy_search(
            neighbors, codes, versions, live, luts, jnp.int32(idx.medoid),
            L=33, beam_width=W,  # L=33: a signature nothing else touches
        )

    base = smod.jit_cache_size()
    run(4)
    assert smod.jit_cache_size() == base + 1
    run(4)  # same (bucket, L, W) → cached
    assert smod.jit_cache_size() == base + 1
    run(2)  # new W → exactly one more signature
    assert smod.jit_cache_size() == base + 2
    run(2)
    assert smod.jit_cache_size() == base + 2


@pytest.fixture(scope="module")
def small_service():
    rng = np.random.RandomState(29)
    n, dim = 400, 16
    g = GraphConfig(capacity=n + 256, R=16, M=8, L_build=32, L_search=32,
                    bootstrap_sample=128, refine_sample=10**9, batch_size=64)
    svc = VectorCollectionService(
        dim=dim, graph=g, max_vectors_per_partition=n + 200,
        engine_cfg=EngineConfig(),
    )
    data = clustered_data(rng, n, dim)
    svc.upsert([{"id": i} for i in range(n)], data)
    return svc, data


def test_engine_splits_oversized_batches(small_service):
    """A forced batch beyond the largest bucket dispatches as top-bucket
    chunks — no new padded shape is minted (closes the next_bucket TODO)."""
    svc, data = small_service
    top = max(smod.BATCH_BUCKETS)
    eng = VectorServeEngine(svc.collection,
                            cfg=EngineConfig(max_batch=top + 36))
    rng = np.random.RandomState(1)
    qs = data[rng.randint(0, len(data), top + 16)] + 0.01
    rids = [eng.submit_query(q, k=5) for q in qs]
    eng.pump(force=True)
    resps = [eng.responses[r] for r in rids]
    assert all(r.status == 200 for r in resps)
    sizes = sorted({r.batch_size for r in resps})
    assert sizes == [16, top], sizes  # chunked, not rounded up to 2·top
    assert max(r.batch_size for r in resps) <= top


def test_oversized_batch_failure_refunds_every_chunk(small_service):
    """A chunk failing mid-split must refund the admission reservations of
    the failing chunk AND the undispatched remainder (they were already
    pulled off the queue) — no tenant-budget bleed."""
    svc, data = small_service
    top = max(smod.BATCH_BUCKETS)
    calls = {"n": 0}
    real = svc.collection.partitions

    def flaky_resolver(_sk):
        calls["n"] += 1
        if calls["n"] == 2:  # chunk 1 OK, chunk 2 blows up, chunk 3 orphaned
            raise RuntimeError("partition down")
        return real

    eng = VectorServeEngine(svc.collection,
                            cfg=EngineConfig(max_batch=2 * top + 8),
                            resolver=flaky_resolver)
    n_req = 2 * top + 8
    for i in range(n_req):
        resp = eng.submit(ServeRequest(rid=eng.next_rid(),
                                       vector=data[i % len(data)],
                                       k=5, tenant="t"))
        assert resp is None  # all admitted (reservations taken)
    gov = eng.tenant_governor("t")
    with pytest.raises(RuntimeError):
        eng.pump(force=True)
    served = [r for r in eng.responses.values() if r.status == 200]
    assert len(served) == top  # only chunk 1 dispatched
    # budget reflects ONLY the work actually done (chunk 1's actual RU)
    # plus the refill for simulated time elapsed during its service —
    # chunks 2 and 3 refunded their reservations in full
    refill = gov.clock_s * gov.provisioned
    expected = gov.provisioned - sum(r.ru for r in served) + refill
    assert gov.available == pytest.approx(expected)


def test_engine_beamwidth_config_recall(small_service):
    """W=4 engine serves the same results quality as W=1 (recall vs the
    exact plan) with zero steady-state recompiles."""
    svc, data = small_service
    rng = np.random.RandomState(17)
    qs = data[rng.choice(len(data), 16, replace=False)] + 0.01

    def run(W):
        eng = VectorServeEngine(svc.collection,
                                cfg=EngineConfig(max_batch=16, beam_width=W))
        # warm the signature, then measure
        for q in qs:
            eng.submit_query(q, k=5)
        eng.drain()
        cache0 = eng.metrics.jit_cache_trajectory[-1]
        rids = [eng.submit_query(q, k=5) for q in qs]
        eng.drain()
        assert eng.metrics.jit_cache_trajectory[-1] == cache0, "recompiled"
        return [eng.responses[r].ids for r in rids], eng

    exact = VectorServeEngine(svc.collection, cfg=EngineConfig(max_batch=16))
    gt_rids = [exact.submit_query(q, k=5, exact=True) for q in qs]
    exact.drain()
    gt = [exact.responses[r].ids for r in gt_rids]

    def recall(res):
        hits = sum(len(set(i.tolist()) & set(g.tolist()))
                   for i, g in zip(res, gt))
        return hits / (len(gt) * 5)

    res1, _ = run(1)
    res4, eng4 = run(4)
    assert abs(recall(res4) - recall(res1)) <= 0.01
    assert 0 < eng4.metrics.snapshot(eng4.clock.now())["mean_hops"] < 20
