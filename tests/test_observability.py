"""Observability plane (ISSUE 7): request-lifecycle traces, the labeled
cost-attribution registry, the bounded streaming histogram, flight
recorder retention, and the exporters.

The invariants under test are *conservation laws*, not smoke checks:

  * every served trace's root-span stage durations sum to its recorded
    end-to-end latency (stages tile the request interval — a dashboard
    built on them can never silently leak time), in every dispatch mode;
  * exactly one latency sample per admitted request, even when the lane
    plane hedges a duplicate or retries across faulted lanes;
  * the labeled registry's RU totals reconcile with the engine-global
    aggregates AND with every tenant governor's settled consumption,
    refund paths included.
"""
import json

import numpy as np
import pytest

from repro.core import GraphConfig
from repro.serve import (EngineConfig, ExactHistogram, Histogram,
                         VectorCollectionService, VectorQuery,
                         VectorServeEngine, validate_trace_record)
from repro.serve.trace import (ANOMALY_HEDGE, ANOMALY_THROTTLE,
                               FlightRecorder, Trace, Tracer)
from repro.serve.metrics import SimClock

from conftest import clustered_data


def make_multipart_service(n=360, dim=16, parts=3, seed=11, **engine_kw):
    """Small 3-physical-partition service — fan-out traces need >1 pid."""
    rng = np.random.RandomState(seed)
    g = GraphConfig(capacity=240, R=16, M=8, L_build=32, L_search=32,
                    bootstrap_sample=48, refine_sample=10**9, batch_size=64)
    svc = VectorCollectionService(dim=dim, graph=g,
                                  max_vectors_per_partition=200,
                                  initial_partitions=parts,
                                  engine_cfg=EngineConfig(**engine_kw))
    data = clustered_data(rng, n, dim)
    svc.upsert([{"id": i} for i in range(n)], data,
               partition_keys=[f"pk{i}" for i in range(n)])
    return svc, data, rng


# ---------------------------------------------------------------------------
# streaming histogram (satellite 1)
# ---------------------------------------------------------------------------

def test_streaming_histogram_parity_with_exact():
    """The bounded histogram must agree with the exact reference: count /
    sum / mean / min / max exactly, percentiles within the geometric-bin
    resolution (≤ √GROWTH−1 ≈ 3.4% relative, plus rank-vs-interpolation
    slack at the tails)."""
    rng = np.random.RandomState(5)
    for scale, n in ((1.0, 5000), (40.0, 2000), (0.002, 800)):
        h, ex = Histogram(), ExactHistogram()
        samples = rng.lognormal(mean=np.log(scale), sigma=1.2, size=n)
        for v in samples:
            h.observe(v)
            ex.observe(v)
        assert h.count == ex.count == n
        assert h.sum == pytest.approx(ex.sum, rel=1e-12)
        assert h.mean() == pytest.approx(ex.mean(), rel=1e-12)
        assert h.min == samples.min() and h.max == samples.max()
        srt = np.sort(samples)
        for p in (1, 10, 50, 90, 95, 99, 99.9):
            approx = h.percentile(p)
            # the exact order statistic at the streaming histogram's rank
            # convention (ceil(p% · n)): the geometric binning guarantees
            # the readout within ±(√GROWTH−1) ≈ 3.4% of THAT sample; the
            # np.percentile comparison below adds interpolation slack and
            # so only holds away from the sparse tails
            rank = min(max(1, int(np.ceil(p / 100.0 * n))), n)
            exact = srt[rank - 1]
            if exact <= Histogram.LO:
                # below the resolution floor the underflow bin clamps the
                # readout into [min, LO] — documented, not a parity breach
                assert h.min <= approx <= Histogram.LO
            else:
                assert approx == pytest.approx(exact, rel=0.04), \
                    f"p{p} @scale={scale}: {approx} vs {exact}"
        for p in (10, 50, 90, 95):
            if srt[int(np.ceil(p / 100.0 * n)) - 1] > Histogram.LO:
                assert h.percentile(p) == pytest.approx(ex.percentile(p),
                                                        rel=0.06)


def test_streaming_histogram_bounded_and_monotone():
    """O(1) memory regardless of samples; percentile(p) monotone in p and
    clamped to the exact observed range (incl. sub-LO underflow values)."""
    h = Histogram()
    rng = np.random.RandomState(7)
    for v in rng.exponential(3.0, size=50_000):
        h.observe(v)
    h.observe(1e-7)  # underflow bin
    h.observe(5e8)  # deep tail
    assert h._counts.size == Histogram.NBINS + 2  # never grows
    ps = [h.percentile(p) for p in (0.01, 1, 25, 50, 75, 95, 99, 99.99, 100)]
    assert ps == sorted(ps)
    assert ps[0] >= h.min and ps[-1] <= h.max
    empty = Histogram()
    assert (empty.percentile(50), empty.mean(), empty.count) == (0.0, 0.0, 0)


# ---------------------------------------------------------------------------
# trace reconciliation (tentpole acceptance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["serial", "replica", "spmd"])
def test_stage_sums_reconcile_with_latency(mode):
    """Every admitted query produces a schema-valid trace whose root-span
    stage times sum (within clock resolution) to its recorded latency —
    across all three dispatch planes. The replica plane runs with forced
    stragglers + hedging so the anomalous path reconciles too."""
    svc, data, rng = make_multipart_service()
    hedged = mode == "replica"
    eng = VectorServeEngine(
        svc.collection,
        cfg=EngineConfig(dispatch_mode=mode, lanes=4,
                         admission_control=False, flight_recorder=256,
                         straggler_p=0.5 if hedged else 0.0,
                         hedge_at_ms=0.05 if hedged else None,
                         dispatch_seed=3),
    )
    queries = data[rng.choice(len(data), 24, replace=False)] + 0.01
    rids = [eng.submit_query(q, k=5) for q in queries]
    eng.drain()
    assert all(eng.responses[r].status == 200 for r in rids)

    recs = [r for r in eng.tracer.recorder.records() if r["kind"] == "query"]
    assert len(recs) == len(rids) == eng.metrics.queries_ok
    for rec in recs:
        validate_trace_record(rec)  # raises on any stage-time leak
        stages = {s["stage"] for s in rec["spans"]}
        assert {"admission", "queue", "batch_form", "lane",
                "merge"} <= stages
        # fan-out decomposition: one child span per searched partition
        pids = {s["attrs"]["pid"] for s in rec["spans"]
                if s["stage"] == "partition"}
        assert len(pids) == len(svc.collection.partitions)
    if hedged:
        hedge_recs = [r for r in recs if ANOMALY_HEDGE in r["anomalies"]]
        assert eng.metrics.hedges > 0 and hedge_recs, \
            "replica run must exercise + capture the hedge path"
        assert all(any(s["stage"] == "hedge" for s in r["spans"])
                   for r in hedge_recs)

    # aggregate reconciliation: the per-stage histograms account for ALL
    # the latency the end-to-end histogram recorded
    lat_total = eng.metrics.latency_ms.sum
    stage_total = sum(h.sum for _, h in eng.obs.series("serve_stage_ms"))
    assert stage_total == pytest.approx(lat_total, rel=1e-9)


def test_single_latency_sample_per_request_under_hedge_and_fault():
    """Satellite 2: hedged duplicates and fault retries are lane-plane
    internals — one admitted request yields exactly one response and one
    latency/stage sample, never two (the double-observation bug would
    corrupt every percentile under exactly the loads that matter)."""
    svc, data, rng = make_multipart_service(seed=13)
    eng = VectorServeEngine(
        svc.collection,
        cfg=EngineConfig(dispatch_mode="replica", lanes=4,
                         admission_control=False, straggler_p=0.9,
                         hedge_at_ms=0.01, dispatch_seed=5),
    )
    eng.executor.inject_fault(0)  # a lane fault mid-workload as well
    n = 30
    queries = data[rng.choice(len(data), n, replace=False)] + 0.01
    rids = [eng.submit_query(q, k=5) for q in queries]
    eng.drain()

    assert eng.metrics.hedges > 0, "workload must actually hedge"
    assert eng.executor.retries > 0, "workload must actually retry a fault"
    assert len(rids) == len(set(rids)) == n
    assert sorted(eng.responses) == sorted(rids)
    assert eng.metrics.queries_ok == n
    assert eng.metrics.latency_ms.count == n  # exactly one sample each
    assert eng.metrics.wait_ms.count == n
    h = eng.obs.histogram("serve_latency_ms", tenant="default")
    assert h is not None and h.count == n
    assert eng.obs.total("serve_requests_total", kind="query",
                         status="200") == n


# ---------------------------------------------------------------------------
# RU conservation (satellite 3)
# ---------------------------------------------------------------------------

def test_ru_conservation_registry_engine_governors():
    """The labeled registry, the engine-global aggregates, and the tenant
    governors are three views of the same RU flow and must agree exactly:
    Σ{op=query|page} == ru_query_total, Σ{op=hedge} == hedge_ru_total,
    Σ{op=ingest} == ru_ingest_total, and each tenant's query+page+hedge
    == what its governor settled (reservation + reconciliation + EMA)."""
    svc, data, rng = make_multipart_service(
        seed=17, dispatch_mode="replica", lanes=4, admission_control=True,
        tenant_ru_s=10**9, straggler_p=0.4, hedge_at_ms=0.05,
    )
    eng = svc.engine
    queries = data[rng.choice(len(data), 20, replace=False)] + 0.01
    for i, q in enumerate(queries):
        eng.submit_query(q, k=5, tenant=f"t{i % 2}")
    eng.drain()
    # a paged query (host path) and interleaved ingest ride along
    token = None
    for _ in range(2):
        r = svc.query_page(VectorQuery(vector=data[3] + 0.01, tenant="t0"),
                           token, page_size=5)
        token = r.continuation
    extra = clustered_data(rng, 32, data.shape[1]) + 2.0
    svc.upsert_async([{"id": 10**6 + i} for i in range(len(extra))], extra,
                     tenant="t1")
    eng.flush_ingest()

    m, obs = eng.metrics, eng.obs
    assert obs.total("serve_ru_total", op="query") + \
        obs.total("serve_ru_total", op="page") == \
        pytest.approx(m.ru_query_total, rel=1e-9)
    assert obs.total("serve_ru_total", op="hedge") == \
        pytest.approx(m.hedge_ru_total, rel=1e-9)
    assert m.hedge_ru_total > 0, "conservation must cover the hedge path"
    assert obs.total("serve_ru_total", op="ingest") == \
        pytest.approx(m.ru_ingest_total, rel=1e-9)
    assert m.ru_ingest_total > 0
    # per-tenant attribution == governor settlement (ingest is not
    # governor-metered; refunds never enter the registry)
    for t, gov in eng.tenants.items():
        attributed = sum(obs.total("serve_ru_total", tenant=str(t), op=op)
                         for op in ("query", "page", "hedge"))
        assert attributed == pytest.approx(gov.consumed, rel=1e-9), \
            f"tenant {t}: registry {attributed} vs governor {gov.consumed}"
        assert gov.settlements > 0


def test_failed_dispatch_refunds_reservation():
    """When the lane plane cannot place the work (every lane faulted) the
    admission reservation is handed back: the tenant's settled consumption
    returns to its pre-submit level, the refund is visible in governor
    telemetry, and no RU enters the attribution registry."""
    svc, data, _ = make_multipart_service(
        seed=19, dispatch_mode="replica", lanes=1, admission_control=True,
        tenant_ru_s=10**6,
    )
    eng = svc.engine
    eng.executor.inject_fault(0)  # the only lane → dispatch must fail
    eng.submit_query(data[0] + 0.01, k=5, tenant="t-fail")
    gov = eng.tenant_governor("t-fail")
    reserved = gov.consumed
    assert reserved > 0  # admission reserved the estimate up front
    with pytest.raises(RuntimeError, match="no healthy lanes"):
        eng.drain()
    assert gov.consumed == pytest.approx(0.0, abs=1e-9)
    assert gov.refunded == pytest.approx(reserved, rel=1e-9)
    assert eng.obs.total("serve_ru_total", tenant="t-fail") == 0.0


# ---------------------------------------------------------------------------
# throttle + flight recorder retention
# ---------------------------------------------------------------------------

def test_throttle_traces_always_captured():
    """429s are anomalous by definition: traced (admission span carries
    retry_after), tagged, and counted per tenant in the registry."""
    svc, data, _ = make_multipart_service(
        seed=23, admission_control=True, tenant_ru_s=25.0,
        admission_estimate_ru=20.0,
    )
    eng = svc.engine
    statuses = []
    for i in range(6):  # budget admits the first; the burst throttles
        rid = eng.submit_query(data[i] + 0.01, k=5, tenant="small")
        resp = eng.responses.get(rid)
        statuses.append(429 if resp is not None and resp.status == 429
                        else 200)
    eng.drain()
    n_throttled = statuses.count(429)
    assert n_throttled > 0
    assert eng.metrics.queries_throttled == n_throttled
    assert eng.obs.counter_value("serve_throttled_total",
                                 tenant="small") == n_throttled
    recs = [r for r in eng.tracer.recorder.records() if r["status"] == 429]
    assert len(recs) == n_throttled
    for rec in recs:
        validate_trace_record(rec)
        assert ANOMALY_THROTTLE in rec["anomalies"]
        assert rec["spans"][0]["attrs"]["retry_after_s"] > 0
        assert rec["ru"] == 0.0  # a rejection is never billed


def test_flight_recorder_anomalies_survive_healthy_churn():
    """The healthy ring is bounded; the anomaly ring is separate — a long
    burst of healthy traffic can never evict the interesting evidence."""
    fr = FlightRecorder(capacity=8)

    def rec(tid, anomalies=()):
        return Trace(trace_id=tid, kind="query", tenant="t", rid=tid,
                     status=200, anomalies=list(anomalies))

    fr.record(rec(0, ["hedge"]))
    fr.record(rec(1, ["fault_retry"]))
    for tid in range(2, 500):
        fr.record(rec(tid))
    assert len(fr.ring) == 8 and fr.recorded == 500
    retained = {r["trace_id"] for r in fr.records()}
    assert {0, 1} <= retained, "anomalies evicted by healthy churn"
    assert retained >= set(range(492, 500))  # most recent always present
    assert fr.anomalies_seen == 2


def test_disabled_tracer_is_inert_and_result_identical():
    """cfg.trace=False: bit-identical serving results, nothing allocated,
    nothing retained — the zero-overhead contract."""
    svc, data, rng = make_multipart_service(seed=29)
    queries = data[rng.choice(len(data), 12, replace=False)] + 0.01

    def run(trace):
        eng = VectorServeEngine(
            svc.collection,
            cfg=EngineConfig(admission_control=False, trace=trace))
        rids = [eng.submit_query(q, k=5) for q in queries]
        eng.drain()
        return eng, [eng.responses[r] for r in rids]

    eng_off, r_off = run(False)
    eng_on, r_on = run(True)
    for a, b in zip(r_off, r_on):
        assert a.ids.tolist() == b.ids.tolist()
        assert a.dists.tolist() == b.dists.tolist()
        assert (a.ru, a.latency_ms, a.plan) == (b.ru, b.latency_ms, b.plan)
    assert eng_off.tracer.begin("query", "t", 0) is None
    s = eng_off.tracer.stats()
    assert (s["started"], s["recorded"], s["retained"]) == (0, 0, 0)
    assert eng_on.tracer.stats()["recorded"] == len(queries)


# ---------------------------------------------------------------------------
# page + ingest traces, exporters, registry hygiene
# ---------------------------------------------------------------------------

def test_page_and_ingest_traces(tmp_path):
    """Paged queries trace their per-partition fetch rounds under the lane
    span; ingest mini-batches get single-root-span traces that reconcile
    trivially. The JSONL exporter round-trips the schema and the
    Prometheus exposition carries every family."""
    svc, data, rng = make_multipart_service(seed=31)
    eng = svc.engine
    token, pages = None, 0
    while pages < 3:
        r = svc.query_page(VectorQuery(vector=data[7] + 0.01), token,
                           page_size=5)
        token, pages = r.continuation, pages + 1
        if token is None:
            break
    extra = clustered_data(rng, 16, data.shape[1]) + 2.0
    svc.upsert_async([{"id": 10**6 + i} for i in range(len(extra))], extra)
    eng.flush_ingest()

    recs = eng.tracer.recorder.records()
    page_recs = [r for r in recs if r["kind"] == "page"]
    ingest_recs = [r for r in recs if r["kind"] == "ingest"]
    assert len(page_recs) == pages and ingest_recs
    all_fetches = []
    for rec in page_recs:
        validate_trace_record(rec)
        all_fetches += [s for s in rec["spans"] if s["stage"] == "partition"]
    # a page served entirely from cursor buffers legitimately fetches
    # nothing, but the opening page must fan out to every partition
    first_pids = {s["attrs"]["pid"] for s in page_recs[0]["spans"]
                  if s["stage"] == "partition"}
    assert len(first_pids) == len(svc.collection.partitions)
    assert all(s["name"].startswith("page.fetch[") and
               "round" in s["attrs"] and s["attrs"]["ru"] > 0
               for s in all_fetches)
    for rec in ingest_recs:
        validate_trace_record(rec)
        assert rec["spans"][0]["stage"] == "ingest"
        assert rec["spans"][0]["attrs"]["ru"] == pytest.approx(rec["ru"])

    out = tmp_path / "traces.jsonl"
    n = eng.tracer.dump_jsonl(out)
    lines = out.read_text().splitlines()
    assert len(lines) == n == len(recs)
    for line in lines:
        validate_trace_record(json.loads(line))

    prom = eng.obs.to_prometheus_text()
    for family in ("serve_requests_total", "serve_ru_total",
                   "serve_latency_ms_sum", "serve_stage_ms"):
        assert family in prom
    assert 'op="ingest"' in prom and 'quantile="0.95"' in prom


def test_registry_locks_label_names_and_kinds():
    """A typo'd label key or kind mismatch fails loudly instead of
    silently forking a new series."""
    from repro.serve import MetricsRegistry

    reg = MetricsRegistry()
    reg.inc("x_total", 2.0, tenant="a")
    reg.inc("x_total", 3.0, tenant="b")
    assert reg.total("x_total") == 5.0
    with pytest.raises(ValueError, match="label names"):
        reg.inc("x_total", tenannt="a")
    with pytest.raises(ValueError, match="is a counter"):
        reg.observe("x_total", 1.0, tenant="a")


def test_tracer_slo_tagging_on_simclock():
    """SLO-violating traces are tagged from latency on the shared
    SimClock — the always-capture rule for slow requests."""
    clk = SimClock()
    tr_fast = Tracer(clk, slo_ms=10.0)
    t = tr_fast.begin("query", "t", 1)
    t.span("queue", "queue", 0.0, 0.0)
    t.span("lane", "lane", 0.0, 0.02)
    tr_fast.finish(t, status=200, ru=1.0, latency_ms=20.0, t0_s=0.0,
                   t1_s=0.02)
    rec = tr_fast.recorder.records()[0]
    assert "slo_violation" in rec["anomalies"]
    validate_trace_record(rec)
