"""Bw-Tree analogue, index terms, RU governance, WAL recovery."""
import numpy as np
import pytest
from proptest import given, settings
from proptest import strategies as st

from repro.core.providers import Context
from repro.store import BwTree, TermCodec
from repro.store.provider import StoreProviderSet
from repro.store.ru import OpCounters, ResourceGovernor, RUConfig, RUMeter
from repro.store.terms import merge_adjacency


def test_blind_append_and_merge():
    t = BwTree(merge_fn=merge_adjacency)
    c = TermCodec()
    t.put(c.adj_key(1), c.encode_adjacency([5, 6]))
    t.append(c.adj_key(1), c.encode_adjacency([7]))
    t.append(c.adj_key(1), c.encode_adjacency([6, 8]))  # dup 6 merged away
    assert c.decode_adjacency(t.get(c.adj_key(1))) == [5, 6, 7, 8]


def test_chain_consolidation_bounded():
    t = BwTree(merge_fn=merge_adjacency, max_chain=15)
    c = TermCodec()
    t.put(c.adj_key(1), b"")
    for i in range(100):
        t.append(c.adj_key(1), c.encode_adjacency([i]))
        assert t.chain_length(c.adj_key(1)) <= 15
    assert t.stats.consolidations >= 6


def test_page_split_keeps_order():
    t = BwTree(merge_fn=merge_adjacency, page_capacity=16)
    c = TermCodec()
    ids = np.random.RandomState(0).permutation(200)
    for d in ids:
        t.upsert(c.quant_key(int(d)), c.encode_quant_value(bytes([d % 256]), 0))
    assert t.num_pages > 1
    keys = [c.decode_doc_id(k) for k, _ in t.prefix_seek(c.quant_prefix())]
    assert keys == sorted(keys) and len(keys) == 200


def test_contracts_enforced():
    t = BwTree(merge_fn=merge_adjacency)
    c = TermCodec()
    t.put(c.adj_key(1), b"x")
    with pytest.raises(ValueError):
        t.put(c.adj_key(1), b"y")  # duplicate insert patch
    with pytest.raises(KeyError):
        t.delete(c.adj_key(42))  # delete of non-existent key


@settings(max_examples=25, deadline=None)
@given(ops=st.lists(
    st.tuples(st.sampled_from(["put", "append", "get"]), st.integers(0, 20),
              st.integers(0, 100)),
    min_size=1, max_size=60,
))
def test_property_store_matches_dict(ops):
    """BwTree == model dict under puts/appends/gets (merge = concat-dedup)."""
    t = BwTree(merge_fn=merge_adjacency, page_capacity=8, max_chain=3)
    c = TermCodec()
    model: dict[int, list[int]] = {}
    for op, key, val in ops:
        k = c.adj_key(key)
        if op == "put":
            t.upsert(k, c.encode_adjacency([val]))
            model[key] = [val]
        elif op == "append":
            if key not in model:
                t.upsert(k, c.encode_adjacency([val]))
                model[key] = [val]
            else:
                t.append(k, c.encode_adjacency([val]))
                if val not in model[key]:
                    model[key].append(val)
        else:
            got = t.get(k)
            want = model.get(key)
            if want is None:
                assert got is None
            else:
                assert c.decode_adjacency(got) == want


def test_sharded_term_isolation():
    """Shard-key prefixes isolate tenants in disjoint contiguous ranges."""
    t = BwTree(merge_fn=merge_adjacency)
    c = TermCodec()
    for tenant in ("a", "b"):
        for d in range(10):
            t.upsert(c.quant_key(d, shard=tenant), c.encode_quant_value(b"q", 0))
    a_keys = [k for k, _ in t.prefix_seek(c.quant_prefix(shard="a"))]
    b_keys = [k for k, _ in t.prefix_seek(c.quant_prefix(shard="b"))]
    assert len(a_keys) == 10 and len(b_keys) == 10
    assert not (set(a_keys) & set(b_keys))


def test_ru_calibration_paper_operating_points():
    """Table 1/2: ~70 RU/query and ~65 RU/insert at the paper's counters."""
    m = RUMeter(RUConfig())
    query = OpCounters(quant_reads=3500, adj_reads=100, full_reads=25, cpu_ms=2.0)
    insert = OpCounters(quant_reads=3200, adj_reads=130, adj_writes=33,
                        quant_writes=1, doc_writes=1, cpu_ms=3.0,
                        vector_kb=3.0)
    ru_q, ru_i = m.ru(query), m.ru(insert)
    assert 55 <= ru_q <= 85, ru_q
    assert 50 <= ru_i <= 80, ru_i
    # §4.4 napkin latency: ≈25 ms single-thread insert
    lat = m.latency_ms(insert)
    assert 20 <= lat + 3.0 <= 45, lat


def test_resource_governor_throttles():
    g = ResourceGovernor(provisioned_ru_s=100.0)
    delay = g.request(50)
    assert delay == 0.0
    delay = g.request(200)  # exceeds budget → throttled
    assert delay > 0 and g.throttle_events > 0


def test_wal_recovery_equivalence():
    rng = np.random.RandomState(0)
    pv = StoreProviderSet(64, 8, 4, 16)
    ctx = Context()
    pv.set_full(ctx, np.arange(10), rng.randn(10, 16).astype(np.float32))
    pv.set_quant(ctx, np.arange(10), rng.randint(0, 255, (10, 4)).astype(np.uint8),
                 np.zeros(10, np.uint8))
    snap = pv.snapshot_bytes()
    pv.set_neighbors(ctx, np.arange(3), np.full((3, 8), -1, np.int32))
    pv.append_neighbors(ctx, 0, np.array([1, 2], np.int32))
    pv.set_live(ctx, np.arange(10), True)
    wal = pv.wal_bytes()

    pv2 = StoreProviderSet(64, 8, 4, 16)
    pv2.recover(snap, wal)
    np.testing.assert_array_equal(pv2.vectors, pv.vectors)
    np.testing.assert_array_equal(pv2.codes, pv.codes)
    np.testing.assert_array_equal(pv2.neighbors, pv.neighbors)
    np.testing.assert_array_equal(pv2.live, pv.live)
    assert pv2.read_neighbors_from_store(ctx, 0) == [1, 2]
