"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles,
interpret mode (CPU container; TPU is the lowering target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flat_l2.kernel import flat_l2_pallas
from repro.kernels.flat_l2.ref import flat_l2_ref
from repro.kernels.pq_adc.kernel import pq_adc_pallas
from repro.kernels.pq_adc.ref import pq_adc_ref
from repro.kernels.pq_encode.kernel import pq_encode_pallas
from repro.kernels.pq_encode.ref import pq_encode_ref
from repro.kernels.topk_select.kernel import topk_select_pallas
from repro.kernels.topk_select.ref import topk_select_ref

INTERP = dict(interpret=True)


@pytest.mark.parametrize("B,C,M,K,block", [
    (1, 100, 8, 256, 64),
    (4, 1000, 16, 256, 256),
    (2, 513, 8, 256, 512),   # non-multiple of block
    (3, 64, 4, 16, 128),     # tiny codebook
])
def test_pq_adc_sweep(B, C, M, K, block):
    rng = np.random.RandomState(B * 100 + C)
    lut = jnp.asarray(rng.randn(B, M, K).astype(np.float32))
    codes = jnp.asarray(rng.randint(0, K, (C, M)).astype(np.uint8))
    out = pq_adc_pallas(lut, codes, block_c=block, **INTERP)
    ref = pq_adc_ref(lut, codes)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("N,M,dsub,K,block", [
    (100, 4, 8, 256, 64),
    (257, 8, 4, 256, 128),
    (64, 2, 16, 64, 256),
])
def test_pq_encode_sweep(N, M, dsub, K, block):
    rng = np.random.RandomState(N)
    x = jnp.asarray(rng.randn(N, M * dsub).astype(np.float32))
    cb = jnp.asarray(rng.randn(M, K, dsub).astype(np.float32))
    out = pq_encode_pallas(x, cb, block_n=block, **INTERP)
    ref = pq_encode_ref(x, cb)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("B,N,L,block", [
    (1, 2048, 16, 512),
    (3, 5000, 32, 1024),
    (2, 100, 10, 256),  # N < block
])
def test_topk_sweep(B, N, L, block):
    rng = np.random.RandomState(N + L)
    d = jnp.asarray(rng.randn(B, N).astype(np.float32))
    v1, i1 = topk_select_pallas(d, L=L, block_n=block, **INTERP)
    v2, i2 = topk_select_ref(d, L=L)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)
    # indices must point at the returned values
    dd = np.asarray(d)
    for b in range(B):
        np.testing.assert_allclose(dd[b][np.asarray(i1)[b]], np.asarray(v1)[b], rtol=1e-6)


@pytest.mark.parametrize("B,N,D,metric", [
    (16, 128, 64, "l2"),
    (50, 333, 96, "l2"),
    (8, 64, 32, "ip"),
    (129, 257, 100, "l2"),  # ragged everything
])
def test_flat_l2_sweep(B, N, D, metric):
    rng = np.random.RandomState(B + N + D)
    q = jnp.asarray(rng.randn(B, D).astype(np.float32))
    x = jnp.asarray(rng.randn(N, D).astype(np.float32))
    out = flat_l2_pallas(q, x, block_b=32, block_n=64, block_d=32, metric=metric, **INTERP)
    ref = flat_l2_ref(q, x, metric=metric)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_flat_l2_bf16():
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(16, 64).astype(np.float32)).astype(jnp.bfloat16)
    x = jnp.asarray(rng.randn(64, 64).astype(np.float32)).astype(jnp.bfloat16)
    out = flat_l2_pallas(q, x, block_b=16, block_n=32, block_d=32, **INTERP)
    ref = flat_l2_ref(q, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=5e-2, atol=5e-2)


def test_kernels_integrate_with_search_path():
    """pq_adc output plugs into the same ranking the search core computes."""
    from repro.core import pq as pqmod
    rng = np.random.RandomState(1)
    data = rng.randn(500, 32).astype(np.float32)
    schema = pqmod.train_pq(jax.random.PRNGKey(0), jnp.asarray(data), M=8)
    codes = pqmod.encode(schema, jnp.asarray(data))
    q = jnp.asarray(rng.randn(2, 32).astype(np.float32))
    luts = jax.vmap(lambda qq: pqmod.adc_lut(schema, qq))(q)
    d_kernel = pq_adc_pallas(luts, codes, block_c=256, **INTERP)
    d_core = jax.vmap(lambda l: pqmod.adc_distance(l, codes))(luts)
    np.testing.assert_allclose(np.asarray(d_kernel), np.asarray(d_core), rtol=1e-4, atol=1e-4)
