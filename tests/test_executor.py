"""Dispatch plane: LaneExecutor lanes/hedging/faults, mode parity
(serial == replica == spmd bit-for-bit), queue wait in percentiles,
zero recompiles under spmd, lane-scheduled page refills."""
import numpy as np
import pytest

from repro.core import GraphConfig
from repro.partition.fanout import (paged_fanout_search, spmd_jit_cache_size,
                                    start_paged_fanout)
from repro.serve import EngineConfig, VectorCollectionService, VectorServeEngine
from repro.serve.executor import DISPATCH_MODES, LaneExecutor
from repro.serve.metrics import SimClock

from conftest import clustered_data


@pytest.fixture(scope="module")
def service():
    """≥3 physical partitions so spmd actually shards a partition axis."""
    rng = np.random.RandomState(21)
    N, D = 360, 16
    g = GraphConfig(capacity=220, R=12, M=8, L_build=32, L_search=32,
                    bootstrap_sample=32, refine_sample=10**9, batch_size=40)
    svc = VectorCollectionService(dim=D, graph=g,
                                  max_vectors_per_partition=200,
                                  initial_partitions=3)
    data = clustered_data(rng, N, D)
    svc.upsert([{"id": i, "category": i % 5} for i in range(N)], data)
    return svc, data


def _run_batch(engine, queries, k=5):
    rids = [engine.submit_query(q, k=k) for q in queries]
    engine.drain()
    resps = [engine.pop_response(r) for r in rids]
    assert all(r.status == 200 for r in resps)
    ids = np.stack([r.ids for r in resps])
    dists = np.stack([r.dists for r in resps])
    return ids, dists, resps


# ---------------------------------------------------------------------------
# mode parity — the acceptance bar: spmd is BIT-identical to serial
# ---------------------------------------------------------------------------


def test_dispatch_mode_parity_bit_identical(service):
    svc, data = service
    rng = np.random.RandomState(5)
    queries = data[rng.choice(len(data), 8, replace=False)] + 0.01
    results = {}
    for mode in DISPATCH_MODES:
        eng = VectorServeEngine(
            svc.collection, cfg=EngineConfig(dispatch_mode=mode, lanes=4)
        )
        results[mode] = _run_batch(eng, queries)
    ids0, d0, resps0 = results["serial"]
    assert resps0[0].plan == "graph"
    for mode in ("replica", "spmd"):
        ids, dists, resps = results[mode]
        np.testing.assert_array_equal(ids, ids0)
        # bit-identical, not approximately equal: same numerics, same order
        np.testing.assert_array_equal(dists, d0)
        assert resps[0].ru == pytest.approx(resps0[0].ru)
    assert results["spmd"][2][0].plan == "graph-spmd"


def test_invalid_dispatch_mode_rejected():
    with pytest.raises(ValueError, match="dispatch mode"):
        LaneExecutor(SimClock(), mode="warp")


# ---------------------------------------------------------------------------
# hedging — duplicates bill RU, they are never free
# ---------------------------------------------------------------------------


def test_hedged_dispatch_bills_duplicate_ru(service):
    svc, data = service
    q = data[7] + 0.01
    base = VectorServeEngine(svc.collection, cfg=EngineConfig())
    _, _, (r0,) = _run_batch(base, q[None])

    eng = VectorServeEngine(
        svc.collection,
        cfg=EngineConfig(dispatch_mode="replica", lanes=2, hedge_at_ms=1e-4,
                         straggler_p=1.0, straggler_factor=4.0),
    )
    _, _, (r1,) = _run_batch(eng, q[None])
    assert eng.metrics.hedges == 1
    assert eng.metrics.hedge_ru_total == pytest.approx(r0.ru)
    assert r1.ru == pytest.approx(2 * r0.ru)  # primary + duplicate
    assert eng.executor.snapshot()["hedges"] == 1
    # same straggler on ONE lane: no second lane, no hedge, no extra RU
    solo = VectorServeEngine(
        svc.collection,
        cfg=EngineConfig(dispatch_mode="replica", lanes=1, hedge_at_ms=1e-4,
                         straggler_p=1.0),
    )
    _, _, (r2,) = _run_batch(solo, q[None])
    assert solo.metrics.hedges == 0 and r2.ru == pytest.approx(r0.ru)


# ---------------------------------------------------------------------------
# lane health — faults retry (work runs once), refunds, re-probe revival
# ---------------------------------------------------------------------------


def test_lane_fault_retries_on_another_lane_exactly_once():
    ex = LaneExecutor(SimClock(), lanes=3, mode="replica")
    ex.inject_fault(0)
    calls = []
    out = ex.dispatch(lambda: (calls.append(1) or "ok", 2.0, 1.5))
    assert calls == [1], "retried work must execute exactly once"
    assert out.payload == "ok" and out.lane == 1
    assert out.retried_lanes == (0,)
    assert ex.lanes[0].down and ex.faults == 1 and ex.retries == 1


def test_all_lanes_down_raises_then_reprobe_revives():
    clock = SimClock()
    ex = LaneExecutor(clock, lanes=2, mode="replica", reprobe_after_s=5.0)
    for lane in (0, 1):
        ex.inject_fault(lane)
    with pytest.raises(RuntimeError, match="no healthy lanes"):
        ex.dispatch(lambda: ("x", 1.0, 1.0))
    assert all(ln.down for ln in ex.lanes)
    clock.advance(6.0)  # past the cooldown: lanes re-probe on next dispatch
    out = ex.dispatch(lambda: ("y", 1.0, 1.0))
    assert out.payload == "y" and ex.recoveries == 2
    assert not any(ln.down for ln in ex.lanes)


def test_failed_dispatch_refunds_tenant_budget(service):
    svc, data = service
    eng = VectorServeEngine(
        svc.collection, cfg=EngineConfig(dispatch_mode="replica", lanes=2)
    )
    gov = eng.tenant_governor("default")
    before = gov.available
    for lane in (0, 1):
        eng.executor.inject_fault(lane)
    rid = eng.submit_query(data[3] + 0.01, k=5)
    with pytest.raises(RuntimeError, match="no healthy lanes"):
        eng.drain()
    assert rid not in eng.responses
    assert gov.available == pytest.approx(before), \
        "a failed dispatch must hand its admission reservation back"
    # the plane heals: past the cooldown the same engine serves again
    eng.clock.advance(6.0)
    _, _, (resp,) = _run_batch(eng, (data[3] + 0.01)[None])
    assert resp.status == 200 and eng.executor.recoveries == 2


def test_lane_health_mirrors_into_replica_sets(service):
    """An engine wired with replica sets kills the faulted lane's replica
    (reads stop routing there) and revives it through the re-probe →
    snapshot+WAL rebuild path."""
    svc, data = service
    eng = VectorServeEngine(
        svc.collection,
        cfg=EngineConfig(dispatch_mode="replica", lanes=4,
                         lane_reprobe_after_s=5.0),
        replica_sets=svc.replica_sets,
    )
    reads0 = [rs.read_counts().copy() for rs in svc.replica_sets]
    eng.executor.inject_fault(0)  # fires when lane 0 is selected
    _run_batch(eng, (data[11] + 0.01)[None])
    for rs in svc.replica_sets:
        assert not rs.replicas[0].alive, "lane 0 down → replica 0 down"
        assert rs.primary != 0, "killing the primary replica fails over"
    # the retry lane's reads were attributed to its replica
    assert any(
        sum(rs.read_counts().values()) > sum(r0.values())
        for rs, r0 in zip(svc.replica_sets, reads0)
    )
    eng.clock.advance(6.0)
    _run_batch(eng, (data[12] + 0.01)[None])
    for rs in svc.replica_sets:
        assert rs.replicas[0].alive and rs.recoveries >= 1


# ---------------------------------------------------------------------------
# queue wait — lanes overlap work; one lane queues it
# ---------------------------------------------------------------------------


def test_replica_lanes_cut_queue_wait_and_tail():
    """Same burst, same arrivals: 4 lanes drain it concurrently, 1 lane
    serializes it — queue wait must show up in the percentiles."""
    rng = np.random.RandomState(13)
    n, d = 400, 16
    g = GraphConfig(capacity=n + 200, R=12, M=8, L_build=32, L_search=32,
                    bootstrap_sample=64, refine_sample=10**9, batch_size=64)
    waits, p99s = {}, {}
    for lanes in (1, 4):
        svc = VectorCollectionService(
            dim=d, graph=g, max_vectors_per_partition=n + 100,
            engine_cfg=EngineConfig(dispatch_mode="replica", lanes=lanes,
                                    max_batch=1),
        )
        data = clustered_data(np.random.RandomState(13), n, d)
        svc.upsert([{"id": i} for i in range(n)], data)
        eng = svc.engine
        qs = data[rng.choice(n, 8, replace=False)] + 0.01
        t0 = eng.clock.now()
        for q in qs:  # a burst: everyone arrives at once
            eng.submit_query(q, k=5, arrival_s=t0)
        eng.drain()
        snap = eng.snapshot()
        waits[lanes] = snap["mean_wait_ms"]
        p99s[lanes] = snap["p99_ms"]
        assert snap["dispatch"]["lanes"] == lanes
        rng = np.random.RandomState(13)  # identical picks for both runs
    assert waits[1] > 0, "a serialized burst must queue"
    assert waits[4] < waits[1] / 2
    assert p99s[4] < p99s[1]


# ---------------------------------------------------------------------------
# spmd — one compile per (bucket, signature); steady state stays flat
# ---------------------------------------------------------------------------


def test_spmd_zero_recompiles_steady_state(service):
    svc, data = service
    eng = VectorServeEngine(
        svc.collection, cfg=EngineConfig(dispatch_mode="spmd", max_batch=8)
    )
    rng = np.random.RandomState(31)

    def burst(B):
        qs = data[rng.choice(len(data), B, replace=False)] + 0.01
        _run_batch(eng, qs, k=5)

    burst(8)  # bucket 8: first dispatch compiles
    after_first = spmd_jit_cache_size()
    assert after_first >= 1
    traj0 = len(eng.metrics.jit_cache_trajectory)
    for _ in range(3):
        burst(8)
    burst(5)  # pads into the same bucket — same signature
    traj = eng.metrics.jit_cache_trajectory
    assert traj[-1] == traj[traj0 - 1], f"recompiled in steady state: {traj}"
    assert spmd_jit_cache_size() == after_first
    burst(1)  # bucket 1: ONE new signature, then flat again
    grown = spmd_jit_cache_size()
    assert grown == after_first + 1
    burst(1)
    assert spmd_jit_cache_size() == grown


# ---------------------------------------------------------------------------
# multi-cursor page refills through the executor
# ---------------------------------------------------------------------------


def test_paged_refill_lane_scheduling_parity_and_makespan(service):
    svc, data = service
    parts = svc.collection.partitions
    assert len(parts) >= 3
    q = data[44] + 0.01

    def run_pages(executor):
        pstate = start_paged_fanout(parts, q)
        ids_all, service = [], 0.0
        for _ in range(3):
            ids, _, info = paged_fanout_search(parts, q, pstate, 10,
                                               executor=executor)
            ids_all.append(ids)
            service += info["service_latency_ms"]
            assert info["lane_scheduled"] == (executor is not None)
        return np.concatenate(ids_all), service

    ids_legacy, svc_legacy = run_pages(None)
    ids_1, svc_1 = run_pages(LaneExecutor(SimClock(), lanes=1, mode="replica"))
    ids_n, svc_n = run_pages(
        LaneExecutor(SimClock(), lanes=len(parts), mode="replica"))
    # the fetch sequence never depends on the executor: same pages
    np.testing.assert_array_equal(ids_1, ids_legacy)
    np.testing.assert_array_equal(ids_n, ids_legacy)
    # one lane serializes the host loop; ≥P lanes pay the max fetch per
    # round. Legacy accounting (max of per-partition sums) sits between.
    assert svc_1 >= svc_legacy > 0
    assert svc_n <= svc_1


def test_query_page_uses_engine_lanes(service):
    svc, data = service
    from repro.serve import VectorQuery
    lane_svc = VectorCollectionService(
        dim=16,
        graph=GraphConfig(capacity=220, R=12, M=8, L_build=32, L_search=32,
                          bootstrap_sample=32, refine_sample=10**9,
                          batch_size=40),
        max_vectors_per_partition=200, initial_partitions=3,
        engine_cfg=EngineConfig(dispatch_mode="replica", lanes=4),
    )
    lane_svc.upsert([{"id": i} for i in range(360)], data)
    res = lane_svc.query_page(VectorQuery(vector=data[5] + 0.01), None,
                              page_size=8)
    assert (res.ids >= 0).sum() == 8
    disp = lane_svc.engine.snapshot()["dispatch"]
    assert disp["mode"] == "replica"
    assert sum(disp["lane_dispatches"]) >= 3, \
        "page refills must book one dispatch per partition fetch"
    # serial engines keep the legacy single-executor accounting
    res2 = svc.query_page(VectorQuery(vector=data[5] + 0.01), None,
                          page_size=8)
    np.testing.assert_array_equal(res2.ids, res.ids)
