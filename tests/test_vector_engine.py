"""VectorServeEngine: micro-batching, bucketing/zero-recompiles, RU
admission control, interleaved ingest, deterministic metrics."""
import numpy as np
import pytest

from repro.core import GraphConfig
from repro.serve import (EngineConfig, ServeRequest, Throttled,
                         VectorCollectionService, VectorQuery,
                         VectorServeEngine)
from repro.serve.vector_engine import serving_jit_cache_size
from repro.store.ru import ResourceGovernor

from conftest import clustered_data


def make_service(n=800, dim=24, seed=7, **engine_kw):
    rng = np.random.RandomState(seed)
    g = GraphConfig(capacity=n + 600, R=16, M=8, L_build=32, L_search=32,
                    bootstrap_sample=128, refine_sample=10**9, batch_size=64)
    svc = VectorCollectionService(
        dim=dim, graph=g, max_vectors_per_partition=n + 500,
        engine_cfg=EngineConfig(**engine_kw),
    )
    data = clustered_data(rng, n, dim)
    docs = [{"id": i, "category": i % 5} for i in range(n)]
    svc.upsert(docs, data)
    return svc, data


@pytest.fixture(scope="module")
def service():
    return make_service()


def test_batched_results_match_direct_search(service):
    """One micro-batched dispatch == the per-query index search (padding
    lanes must never leak into real lanes)."""
    svc, data = service
    rng = np.random.RandomState(3)
    pick = rng.choice(len(data), 12, replace=False)
    queries = data[pick] + 0.01
    eng = svc.engine

    rids = [eng.submit_query(q, k=5) for q in queries]
    eng.drain()
    resps = [eng.responses[r] for r in rids]
    assert all(r.status == 200 for r in resps)
    assert resps[0].batch_size == 12  # one dense micro-batch, not 12 singles

    part = svc.collection.partitions[0]
    L = max(5, int(round(eng.cfg.search_list_multiplier * 5)))
    ids_direct, _, _ = part.index.search(queries, k=5, L=L)
    for i, r in enumerate(resps):
        assert r.ids.tolist() == ids_direct[i].tolist()
        assert r.ru > 0 and r.latency_ms > 0


def test_query_facade_routes_through_engine(service):
    svc, data = service
    before = svc.engine.metrics.queries_ok
    res = svc.query(VectorQuery(vector=data[17] + 0.01, k=5))
    assert 17 in res.ids.tolist()
    assert svc.engine.metrics.queries_ok == before + 1
    assert res.latency_ms > 0


def test_bucketing_zero_recompiles_steady_state(service):
    """Varying batch sizes within one bucket reuse ONE compiled signature:
    the jit cache-miss count stays flat after the first dispatch."""
    svc, data = service
    eng = VectorServeEngine(svc.collection, cfg=EngineConfig(max_batch=16))
    rng = np.random.RandomState(11)
    # k=7 → L=35: a signature no other test has touched yet
    sizes = [16, 9, 12, 16, 10, 13, 15, 11]
    for B in sizes:
        pick = rng.choice(len(data), B, replace=False)
        for q in data[pick]:
            eng.submit_query(q + 0.01, k=7)
        eng.drain()
    traj = eng.metrics.jit_cache_trajectory
    assert len(traj) == len(sizes)
    assert traj[-1] == traj[0], f"recompiled in steady state: {traj}"
    assert eng.metrics.recompiles_since(0) == 0
    assert eng.metrics.occupancy.mean() > 0.5  # 9..16 over bucket 16


def test_cross_bucket_batch_compiles_once_each(service):
    svc, data = service
    eng = VectorServeEngine(svc.collection, cfg=EngineConfig(max_batch=4))
    base = serving_jit_cache_size()
    for q in data[:3]:
        eng.submit_query(q, k=9)  # bucket 4, L=45 — fresh signature
    eng.drain()
    first = serving_jit_cache_size()
    for q in data[:4]:
        eng.submit_query(q + 0.02, k=9)  # same bucket → no new compile
    eng.drain()
    assert serving_jit_cache_size() == first > base


def test_admission_reserves_budget_against_bursts(service):
    """A burst of submits BEFORE any dispatch must not all pass admission
    against the same untouched balance: estimates reserve upfront."""
    svc, data = service
    eng = svc.engine
    eng.set_tenant_budget("bursty", 2.5 * eng.cfg.admission_estimate_ru)
    results = [eng.submit(ServeRequest(rid=eng.next_rid(), vector=data[i],
                                       k=5, tenant="bursty"))
               for i in range(5)]  # no pump between submits
    admitted = [r for r in results if r is None]
    rejected = [r for r in results if r is not None]
    assert len(admitted) == 2, "burst must stop once reservations spend the burst"
    assert all(r.status == 429 and r.retry_after_s > 0 for r in rejected)
    eng.drain()


def test_admission_throttles_over_budget_tenant(service):
    svc, data = service
    eng = svc.engine
    eng.set_tenant_budget("cheap", 60.0)  # ~1 query per second of budget
    statuses = []
    for i in range(6):
        resp = eng.submit(ServeRequest(rid=eng.next_rid(), vector=data[i],
                                       k=5, tenant="cheap"))
        if resp is None:
            eng.drain()
            statuses.append(200)
        else:
            statuses.append(resp.status)
            assert resp.retry_after_s > 0
    assert 200 in statuses, "burst capacity should admit the first request"
    assert 429 in statuses, "sustained over-budget traffic must throttle"
    # other tenants are unaffected (isolation, not collective degradation)
    ok = svc.query(VectorQuery(vector=data[0] + 0.01, k=5, tenant="rich"))
    assert ok.ids is not None

    # budget refills with simulated time → admitted again
    gov = eng.tenant_governor("cheap")
    deficit = max(0.0, eng._ru_ema.get("cheap", 20.0) - gov.available)
    eng.clock.advance(deficit / gov.provisioned + 1.0)
    resp = eng.submit(ServeRequest(rid=eng.next_rid(), vector=data[0],
                                   k=5, tenant="cheap"))
    assert resp is None, "refilled tenant must be admitted"
    eng.drain()


def test_service_raises_throttled(service):
    svc, data = service
    svc.engine.set_tenant_budget("tiny", 1.0)
    svc.engine.tenant_governor("tiny").available = 0.5  # burn the burst
    with pytest.raises(Throttled) as ei:
        svc.query(VectorQuery(vector=data[1], k=5, tenant="tiny"))
    assert ei.value.retry_after_s > 0


def test_governor_try_admit_settle():
    gov = ResourceGovernor(100.0)
    d = gov.try_admit(50.0, now_s=0.0)
    assert d.admitted
    gov.settle(120.0, now_s=0.0)  # estimate was low — debt allowed
    assert gov.available < 0
    d = gov.try_admit(10.0, now_s=0.0)
    assert not d.admitted and d.retry_after_s > 0
    gov.refill_to(2.0)  # 200 RU refill, capped at burst=provisioned
    assert gov.available == 100.0
    assert gov.try_admit(10.0, now_s=2.0).admitted


def test_interleaved_ingest_bounded_recall_and_latency():
    """§3.4 / Fig 12-13: queries stay correct and bounded while upserts
    stream through the interleaved ingest queue."""
    svc, data = make_service(n=500, dim=24, seed=19, ingest_chunk=32)
    rng = np.random.RandomState(23)
    pick = rng.choice(500, 16, replace=False)
    queries = data[pick] + 0.01

    def exact_gt():
        return [svc.query(VectorQuery(vector=q, k=10, exact=True)).ids
                for q in queries]

    def recall(results, gts):
        hits = sum(len(set(ids.tolist()) & set(gt.tolist()))
                   for ids, gt in zip(results, gts))
        return hits / (len(results) * 10)

    # query-only pass, scored against the pre-ingest corpus
    gt_only = exact_gt()
    only = [svc.query(VectorQuery(vector=q, k=10)).ids for q in queries]

    # mixed pass: stream 160 new docs through the async ingest queue while
    # the same queries run; the engine alternates query batches with chunks
    extra = clustered_data(rng, 160, 24) + 3.0  # offset cluster
    docs = [{"id": 10_000 + i} for i in range(160)]
    svc.upsert_async(docs, extra)
    assert svc.engine.ingest_backlog > 0
    mixed = [svc.query(VectorQuery(vector=q, k=10)).ids for q in queries]
    svc.engine.flush_ingest()
    assert svc.engine.ingest_backlog == 0
    assert svc.collection.num_docs == 500 + 160

    r_only = recall(only, gt_only)
    r_mixed = recall(mixed, exact_gt())
    assert r_mixed >= r_only - 0.02, (r_only, r_mixed)


def test_metrics_snapshot_sanity(service):
    svc, _ = service
    snap = svc.engine.snapshot()
    assert snap["queries_ok"] > 0
    assert snap["p50_ms"] <= snap["p95_ms"] <= snap["p99_ms"]
    assert snap["qps"] > 0 and snap["ru_per_s"] > 0
    assert 0.0 < snap["mean_occupancy"] <= 1.0
    assert snap["jit_cache_size"] >= 1
    assert snap["queue_depth"] == 0


def test_exact_plan_batched(service):
    svc, data = service
    eng = svc.engine
    rids = [eng.submit_query(data[i], k=5, exact=True) for i in (3, 4, 5)]
    eng.drain()
    for rid, i in zip(rids, (3, 4, 5)):
        r = eng.responses[rid]
        assert r.plan == "exact" and i in r.ids.tolist()
