"""Adaptive serving control plane (ISSUE 9): serve/policy.py + the
engine's actuation paths.

The contracts under test:

  * **disabled = invisible** — ``policy="static"`` (the default) must be
    bit-identical to an engine handed an explicit ``StaticPolicy``: the
    engine short-circuits before signal collection, never ticks, and
    every response (ids, distances, RU, latency) matches.
  * **idle economics** — an adaptive engine under trickle traffic parks
    at W=1 and serves bit-identically to a static W=1 engine (the ladder
    actually reaches the cheapest compiled point, not merely "narrower").
  * **compiled-signature confinement** — every W the policy actuates is
    drawn from ``policy_widths``, and a warmed engine's jit cache does
    not grow while the ladder moves (zero steady-state recompiles).
  * **determinism** — the same seed + arrival schedule reproduces the
    same ``decision_log`` bit for bit (the loop's inputs are the
    deterministic clock + rollup deltas, nothing wall-clock).
  * **hysteresis** — bursts widen W and idle narrows it back (one rung
    per tick, hold band between); topology actions need the overload
    predicate sustained for ``window_s`` AND a ``cooldown_s`` gap, so a
    short burst fires nothing and a sustained one fires exactly once.
  * **ingest yield ledger** — latency pressure defers catch-up chunks
    (debt recorded), idle repays them (catch-up recorded), and the
    backlog always drains to zero.
  * **conservation under actuation** — per-tenant attributed RU still
    equals governor settlements, and every retained trace (including the
    ``policy``-kind topology traces) passes root-span tiling validation,
    while the policy is live.
"""
import numpy as np
import pytest

from repro.core import GraphConfig
from repro.serve import (AdaptivePolicy, EngineConfig, PolicyDecision,
                         StaticPolicy, VectorCollectionService,
                         VectorServeEngine, make_policy,
                         validate_trace_record)
from repro.serve.vector_engine import serving_jit_cache_size

from conftest import clustered_data


def make_service(n=240, dim=16, parts=1, replicas=0, seed=3):
    rng = np.random.RandomState(seed)
    g = GraphConfig(capacity=2 * n + 64, R=16, M=8, L_build=32, L_search=32,
                    bootstrap_sample=48, refine_sample=10**9, batch_size=64)
    kw = dict(replicas=replicas) if replicas else {}
    svc = VectorCollectionService(dim=dim, graph=g,
                                  max_vectors_per_partition=2 * n,
                                  initial_partitions=parts, **kw)
    data = clustered_data(rng, n, dim)
    svc.upsert([{"id": i} for i in range(n)], data,
               partition_keys=[f"pk{i}" for i in range(n)])
    return svc, data, rng


def warm(eng, data, k=10):
    """Compile every (bucket, L, W) signature the policy may actuate —
    widths pinned in DESCENDING order so the ladder ends parked at its
    cheapest rung (the idle state) — then reset the metrics epoch."""
    pol = eng.policy
    widths = eng.cfg.policy_widths if pol.enabled else (eng.cfg.beam_width,)
    for W in sorted(set(widths), reverse=True):
        if pol.enabled:
            pol.pinned_width = W
        for B in (1, 2, 4, 8):
            for q in data[:B]:
                eng.submit_query(q, k=k)
            eng.drain()
    if pol.enabled:
        pol.pinned_width = None
    eng.reset_metrics()


def burst(eng, queries, k=10):
    """Offer every query at once (deep backlog) and drain: the policy
    ticks once per micro-batch while the backlog empties."""
    now = eng.clock.now()
    rids = [eng.submit_query(q, k=k, arrival_s=now) for q in queries]
    eng.drain()
    return [eng.pop_response(r) for r in rids]


def trickle(eng, queries, k=10):
    """One query at a time, fully drained between arrivals: the queue
    never exceeds depth 1, so an adaptive ladder must sit at W=1."""
    out = []
    for q in queries:
        rid = eng.submit_query(q, k=k, arrival_s=eng.clock.now())
        eng.drain()
        out.append(eng.pop_response(rid))
    return out


# ---------------------------------------------------------------------------
# construction + disabled parity
# ---------------------------------------------------------------------------

def test_make_policy_and_unknown_name_raises():
    cfg = EngineConfig()
    assert isinstance(make_policy(cfg), StaticPolicy)
    assert not make_policy(cfg).enabled
    ad = make_policy(EngineConfig(policy="adaptive"))
    assert isinstance(ad, AdaptivePolicy) and ad.enabled
    with pytest.raises(ValueError, match="adative"):
        make_policy(EngineConfig(policy="adative"))


def test_static_policy_is_bit_invisible(rng):
    """Default engine vs an engine handed an explicit StaticPolicy: the
    policy plane must not perturb a single bit of the serving path —
    same ids, distances, RU, latency; zero ticks; static snapshot."""
    svc, data, _ = make_service()
    queries = data[rng.choice(len(data), 24, replace=False)] + 0.01
    resps = []
    for policy in (None, StaticPolicy(EngineConfig(max_batch=8))):
        eng = VectorServeEngine(svc.collection,
                                cfg=EngineConfig(max_batch=8),
                                policy=policy)
        warm(eng, data)
        r = burst(eng, queries[:12]) + trickle(eng, queries[12:])
        resps.append(r)
        assert eng.metrics.policy_ticks == 0
        st = eng.snapshot()["policy"]
        assert st["mode"] == "static" and not st["enabled"]
        assert st["beam_width"] == eng.cfg.beam_width
    for a, b in zip(*resps):
        assert np.array_equal(a.ids, b.ids)
        assert np.array_equal(a.dists, b.dists)
        assert a.ru == b.ru and a.latency_ms == b.latency_ms
        assert a.plan == b.plan


def test_adaptive_idle_parks_at_w1_bit_identical(rng):
    """Trickle traffic through an adaptive engine must serve bit-
    identically to a static W=1 engine: the ladder's idle point IS the
    cheapest compiled configuration, not an approximation of it."""
    svc, data, _ = make_service()
    queries = data[rng.choice(len(data), 16, replace=False)] + 0.01
    eng_w1 = VectorServeEngine(
        svc.collection, cfg=EngineConfig(max_batch=8, beam_width=1))
    eng_ad = VectorServeEngine(
        svc.collection, cfg=EngineConfig(max_batch=8, beam_width=4,
                                         policy="adaptive"))
    warm(eng_w1, data)
    warm(eng_ad, data)
    a, b = trickle(eng_w1, queries), trickle(eng_ad, queries)
    for ra, rb in zip(a, b):
        assert np.array_equal(ra.ids, rb.ids)
        assert np.array_equal(ra.dists, rb.dists)
        assert ra.ru == rb.ru
        # the engines' clocks sit at different absolute times after their
        # different warmups, so t1-t0 carries float rounding at the ULP
        assert ra.latency_ms == pytest.approx(rb.latency_ms, abs=1e-9)
    assert eng_ad.snapshot()["policy"]["beam_width"] == 1
    assert eng_ad.metrics.policy_ticks > 0  # the loop ran; it chose W=1


# ---------------------------------------------------------------------------
# W ladder: confinement, recompiles, hysteresis, determinism
# ---------------------------------------------------------------------------

def test_burst_widens_idle_narrows_confined_no_recompiles(rng):
    """A deep backlog climbs the ladder to its widest rung; the idle
    tail walks it back to W=1. Every decision stays inside
    policy_widths and the warmed jit cache does not grow."""
    svc, data, _ = make_service()
    eng = VectorServeEngine(
        svc.collection, cfg=EngineConfig(max_batch=8, policy="adaptive"))
    warm(eng, data)
    cache0 = serving_jit_cache_size()
    queries = data[rng.choice(len(data), 48, replace=True)] + 0.01
    resps = burst(eng, queries)
    assert all(r.status == 200 for r in resps)
    widths_used = {d[1] for d in eng.policy.decision_log}
    assert max(widths_used) == max(eng.cfg.policy_widths), \
        "the burst never reached the widest rung"
    assert widths_used <= set(eng.cfg.policy_widths)
    trickle(eng, queries[:6])
    assert eng.snapshot()["policy"]["beam_width"] == 1, \
        "idle traffic did not narrow back to W=1"
    assert serving_jit_cache_size() == cache0, \
        "a policy W move minted a steady-state recompile"
    assert eng.metrics.policy_w_changes >= 2  # at least up once + down once


def test_out_of_ladder_decision_is_clamped():
    """A policy bug returning W outside policy_widths must be clamped
    into the compiled set, never dispatched raw."""
    svc, data, _ = make_service(n=120)

    class RogueW:
        enabled = True
        def initial(self):
            return PolicyDecision(beam_width=64, ingest_interleave=1)
        def tick(self, sig):
            return PolicyDecision(beam_width=64, ingest_interleave=1)
        def reset_epoch(self):
            pass

    eng = VectorServeEngine(
        svc.collection, cfg=EngineConfig(max_batch=8, policy="adaptive"),
        policy=RogueW())
    assert eng._chunk_beam_width() == max(eng.cfg.policy_widths)


def test_decision_log_deterministic(rng):
    """Same corpus, same arrivals, two fresh engines → bit-identical
    decision logs (timestamps included)."""
    svc, data, _ = make_service()
    queries = data[rng.choice(len(data), 40, replace=True)] + 0.01
    logs = []
    for _ in range(2):
        eng = VectorServeEngine(
            svc.collection, cfg=EngineConfig(max_batch=8, policy="adaptive"))
        warm(eng, data)
        for _ in range(3):
            eng.submit_ingest("upsert", lambda: 10.0, 4)
        burst(eng, queries)
        trickle(eng, queries[:4])
        logs.append(list(eng.policy.decision_log))
    assert logs[0] == logs[1]
    assert len(logs[0]) >= 3  # the run actually moved knobs


# ---------------------------------------------------------------------------
# ingest yield: deferral debt + idle catch-up
# ---------------------------------------------------------------------------

def test_ingest_yield_defers_under_pressure_then_repays(rng):
    """Chunks queued at a burst's front edge must NOT drain while the
    queue is deep (deferred debt recorded); the idle tail repays the
    debt at the catch-up rate and the backlog reaches zero."""
    svc, data, _ = make_service()
    eng = VectorServeEngine(
        svc.collection, cfg=EngineConfig(max_batch=8, policy="adaptive"))
    warm(eng, data)
    queries = data[rng.choice(len(data), 48, replace=True)] + 0.01
    now = eng.clock.now()
    rids = [eng.submit_query(q, k=10, arrival_s=now) for q in queries]
    for _ in range(10):
        eng.submit_ingest("upsert", lambda: 10.0, 4)
    while eng.queue:
        eng.pump(force=not eng.pump())
    debt_mid = eng.snapshot()["policy"]["ingest_debt"]
    assert debt_mid["deferred_chunks"] > 0, \
        "the burst never deferred an ingest chunk"
    eng.drain()  # idle: catch-up repays the debt
    debt = eng.snapshot()["policy"]["ingest_debt"]
    assert debt["catchup_chunks"] > 0, "idle never repaid deferred debt"
    assert debt["backlog_chunks"] == 0 and debt["backlog_ops"] == 0
    assert eng.metrics.ingest_batches == 10  # every chunk applied exactly once
    assert all(eng.pop_response(r).status == 200 for r in rids)


def test_static_ingest_interleave_unchanged(rng):
    """The static path must keep the pre-policy behavior: exactly
    ``ingest_interleave`` chunks drain after each batch, debt counters
    stay zero."""
    svc, data, _ = make_service()
    eng = VectorServeEngine(svc.collection, cfg=EngineConfig(max_batch=8))
    warm(eng, data)
    for _ in range(4):
        eng.submit_ingest("upsert", lambda: 10.0, 4)
    burst(eng, data[:8] + 0.01)
    debt = eng.snapshot()["policy"]["ingest_debt"]
    assert debt["deferred_chunks"] == 0 and debt["catchup_chunks"] == 0
    assert eng.ingest_backlog == 0


# ---------------------------------------------------------------------------
# topology: split / scale-out with hysteresis
# ---------------------------------------------------------------------------

def _overload_policy(cfg, **kw):
    """A policy tuned so a sustained in-test burst trips the overload
    predicate quickly, with a cooldown long enough that a second action
    within the run would be a hysteresis failure."""
    kw.setdefault("window_s", 0.005)
    kw.setdefault("cooldown_s", 10.0)
    kw.setdefault("overload_backlog", 16)
    kw.setdefault("overload_occupancy", 0.3)
    return AdaptivePolicy(cfg, **kw)


def test_sustained_overload_splits_exactly_once(rng):
    """Serial plane: sustained overload fires ONE partition split (the
    hottest partition halves) and the cooldown swallows the rest of the
    burst — no flapping. Uses a local service: the split mutates it."""
    svc, data, _ = make_service(n=200, parts=1, seed=7)
    cfg = EngineConfig(max_batch=8, policy="adaptive")
    eng = VectorServeEngine(svc.collection, cfg=cfg,
                            policy=_overload_policy(cfg))
    warm(eng, data)
    parts0 = len(svc.collection.partitions)
    queries = data[rng.choice(len(data), 220, replace=True)] + 0.01
    resps = burst(eng, queries)
    assert all(r.status == 200 for r in resps)
    st = eng.snapshot()["policy"]
    assert st["splits"] == 1, f"expected exactly one split, got {st['splits']}"
    assert len(svc.collection.partitions) == parts0 + 1
    assert st["last_scale"]["action"] == "split"
    assert "depth=" in st["last_scale"]["reason"]
    assert eng.obs.total("serve_policy_total", knob="topology",
                         action="split") == 1.0


def test_short_burst_fires_no_topology_action(rng):
    """Hysteresis: a burst shorter than the persistence window must not
    split — the overload predicate has to HOLD, not merely occur."""
    svc, data, _ = make_service(n=200, parts=1, seed=7)
    cfg = EngineConfig(max_batch=8, policy="adaptive")
    eng = VectorServeEngine(svc.collection, cfg=cfg,
                            policy=_overload_policy(cfg, window_s=60.0))
    warm(eng, data)
    queries = data[rng.choice(len(data), 220, replace=True)] + 0.01
    burst(eng, queries)
    st = eng.snapshot()["policy"]
    assert st["splits"] == 0 and st["lanes_added"] == 0
    assert st["last_scale"] is None
    assert len(svc.collection.partitions) == 1


def test_replica_overload_scales_out_lanes(rng):
    """Replica plane: sustained overload grows the dispatch plane — one
    executor lane plus one replica per set — instead of splitting."""
    svc, data, _ = make_service(n=200, parts=1, replicas=2, seed=9)
    cfg = EngineConfig(max_batch=8, dispatch_mode="replica", lanes=2,
                       policy="adaptive")
    eng = VectorServeEngine(svc.collection, cfg=cfg,
                            replica_sets=svc.replica_sets,
                            policy=_overload_policy(cfg))
    warm(eng, data)
    lanes0 = len(eng.executor.lanes)
    reps0 = [len(rs.replicas) for rs in svc.replica_sets]
    queries = data[rng.choice(len(data), 220, replace=True)] + 0.01
    resps = burst(eng, queries)
    assert all(r.status == 200 for r in resps)
    st = eng.snapshot()["policy"]
    assert st["lanes_added"] == 1 and st["splits"] == 0
    assert len(eng.executor.lanes) == lanes0 + 1
    assert [len(rs.replicas) for rs in svc.replica_sets] == \
        [r + 1 for r in reps0]
    assert st["last_scale"]["action"] == "scale_out"


# ---------------------------------------------------------------------------
# conservation + trace validity under a live policy
# ---------------------------------------------------------------------------

def test_ru_conservation_and_trace_tiling_under_policy(rng):
    """The accounting contracts survive actuation: attributed RU equals
    governor settlements per tenant, every retained trace (query AND
    policy kinds) passes root-span tiling, and the knob moves show up in
    the serve_policy_total metric family."""
    svc, data, _ = make_service(n=200, parts=1, seed=5)
    cfg = EngineConfig(max_batch=8, policy="adaptive",
                       admission_control=True, tenant_ru_s=10**9,
                       flight_recorder=512)
    eng = VectorServeEngine(svc.collection, cfg=cfg,
                            policy=_overload_policy(cfg))
    warm(eng, data)
    consumed0 = {t: g.consumed for t, g in eng.tenants.items()}
    queries = data[rng.choice(len(data), 180, replace=True)] + 0.01
    now = eng.clock.now()
    rids = [eng.submit_query(q, k=10, tenant=f"t{i % 2}", arrival_s=now)
            for i, q in enumerate(queries)]
    for _ in range(4):
        eng.submit_ingest("upsert", lambda: 10.0, 4, tenant="t0")
    eng.drain()
    assert all(eng.pop_response(r).status == 200 for r in rids)
    for t, gov in eng.tenants.items():
        attributed = sum(
            eng.obs.total("serve_ru_total", tenant=str(t), op=op)
            for op in ("query", "page", "hedge"))
        settled = gov.consumed - consumed0.get(t, 0.0)
        assert abs(attributed - settled) <= 1e-9 * max(abs(settled), 1.0)
    recs = eng.tracer.recorder.records()
    kinds = {t["kind"] for t in recs}
    assert "policy" in kinds, "the split emitted no policy-kind trace"
    for t in recs:
        validate_trace_record(t)
    assert eng.metrics.policy_w_changes > 0
    assert eng.obs.total("serve_policy_total", knob="beam_width",
                         action=f"w{max(cfg.policy_widths)}") >= 1.0
    st = eng.snapshot()["policy"]
    assert st["ticks"] == eng.metrics.policy_ticks > 0
    assert set(st["widths"]) == set(cfg.policy_widths)
