"""Scale-out: partitioning, splits/merges, fan-out merge, replicas, hedging."""
import numpy as np
import pytest

from repro.core import GraphConfig
from repro.core import recall as rec
from repro.partition import Collection, CollectionConfig, ReplicaSet
from repro.partition.fanout import fanout_search, merge_topk

from conftest import clustered_data


def _collection(rng, n=600, dim=16, max_per=300, parts=2):
    g = GraphConfig(capacity=max_per + 128, R=16, M=8, L_build=32, L_search=48,
                    bootstrap_sample=64, refine_sample=10**9, batch_size=40)
    cc = CollectionConfig(dim=dim, graph=g, max_vectors_per_partition=max_per,
                          initial_partitions=parts)
    col = Collection(cc)
    data = clustered_data(rng, n, dim)
    col.insert(list(range(n)), [f"pk{i%11}" for i in range(n)], data)
    return col, data


def test_merge_topk_equals_global():
    """Property: merging per-partition exact top-k == global exact top-k."""
    rng = np.random.RandomState(1)
    q = rng.randn(3, 8).astype(np.float32)
    parts = [rng.randn(50, 8).astype(np.float32) for _ in range(4)]
    ids_l, d_l = [], []
    base = 0
    alld, allid = [], []
    for p in parts:
        d = ((q[:, None, :] - p[None]) ** 2).sum(-1)
        order = np.argsort(d, 1)[:, :5]
        ids_l.append(order + base)
        d_l.append(np.take_along_axis(d, order, 1))
        alld.append(d)
        allid.append(np.arange(base, base + len(p)))
        base += len(p)
    gids, gd = merge_topk(ids_l, d_l, 5)
    full_d = np.concatenate(alld, 1)
    want = np.argsort(full_d, 1)[:, :5]
    np.testing.assert_array_equal(gids, want)


def test_split_preserves_documents_and_recall(rng):
    col, data = _collection(np.random.RandomState(11), n=700, max_per=300, parts=1)
    assert col.splits >= 1 and len(col.partitions) >= 2
    assert col.num_docs == 700
    q = data[rng.choice(700, 8)] + 0.02
    ids, dists, info = fanout_search(col.partitions, q, k=10)
    gt = rec.ground_truth(q, data, np.ones(700, bool), 10)
    assert rec.recall_at_k(ids, gt, 10) >= 0.8


def test_partition_merge_roundtrip():
    col, data = _collection(np.random.RandomState(12), n=500, max_per=400, parts=2)
    n_before = col.num_docs
    col.merge(0)
    assert col.num_docs == n_before
    q = data[:4] + 0.01
    ids, _, _ = fanout_search(col.partitions, q, k=5)
    for i in range(4):
        assert i in ids[i].tolist()


def test_hedged_requests_cut_tail():
    col, data = _collection(np.random.RandomState(13), n=300, max_per=400, parts=2)
    q = data[:2]
    slow = lambda p, rr: float(np.exp(rr.normal(np.log(10), 1.0)))
    r1 = np.random.RandomState(3)
    lats_nohedge = [
        fanout_search(col.partitions, q, 5, latency_model=slow, rng=np.random.RandomState(s))[2]["client_latency_ms"]
        for s in range(30)
    ]
    lats_hedge = [
        fanout_search(col.partitions, q, 5, latency_model=slow, hedge_at_ms=25,
                      rng=np.random.RandomState(s))[2]["client_latency_ms"]
        for s in range(30)
    ]
    assert np.percentile(lats_hedge, 95) <= np.percentile(lats_nohedge, 95)


def test_replica_failover_and_rebuild():
    col, data = _collection(np.random.RandomState(14), n=300, max_per=400, parts=1)
    rs = ReplicaSet(col.partitions[0], num_replicas=4)
    rs.insert([10_000], [123], data[:1])
    primary = rs.primary
    rs.kill(primary)
    assert rs.primary != primary and rs.failovers == 1
    ids, _, _ = rs.search(data[:2], 5)
    assert ids.shape == (2, 5)
    dead = [r.rid for r in rs.replicas if not r.alive][0]
    fresh = rs.rebuild(dead)
    np.testing.assert_array_equal(fresh.vectors, col.partitions[0].providers.vectors)


def test_replica_round_robin_spreads_reads():
    """Regression: the RR cursor used to advance without selecting — read
    spreading was dead code. Reads must rotate across healthy replicas
    and dead replicas must receive none."""
    col, data = _collection(np.random.RandomState(16), n=200, max_per=400, parts=1)
    rs = ReplicaSet(col.partitions[0], num_replicas=4)
    rs.kill(2)  # a secondary dies; primary stays
    for _ in range(9):
        rs.search(data[:1], 3)
    counts = rs.read_counts()
    assert counts[2] == 0, "dead replicas must receive no reads"
    healthy = [counts[r] for r in (0, 1, 3)]
    assert sum(healthy) == 9
    assert max(healthy) - min(healthy) <= 1, f"uneven spread: {counts}"


def test_hedged_duplicates_charge_ru():
    """Regression: a hedge is a second server-side execution — it must
    bill, not just win the latency race for free."""
    col, data = _collection(np.random.RandomState(17), n=200, max_per=400, parts=2)
    q = data[:2]
    always_slow = lambda p, rr: 100.0  # every partition trips the hedge
    _, _, info = fanout_search(col.partitions, q, 5, latency_model=always_slow,
                               hedge_at_ms=10.0)
    assert info["hedges"] == len(col.partitions)
    assert info["hedge_ru"] > 0
    assert info["ru_total"] == pytest.approx(
        sum(info["ru_per_partition"]) + info["hedge_ru"]
    )
    _, _, no_hedge = fanout_search(col.partitions, q, 5,
                                   latency_model=always_slow)
    assert no_hedge["hedges"] == 0 and no_hedge["hedge_ru"] == 0.0
    assert info["ru_total"] > no_hedge["ru_total"]


def test_quorum_loss_raises():
    col, _ = _collection(np.random.RandomState(15), n=200, max_per=400, parts=1)
    rs = ReplicaSet(col.partitions[0], num_replicas=4)
    for rid in range(3):
        rs.kill(rid)
    with pytest.raises(RuntimeError):
        rs.insert([1], [1], np.zeros((1, 16), np.float32))


def test_dead_replica_reprobe_revives_after_cooldown():
    """A dead replica is not dead forever: once its re-probe cooldown
    elapses, probe_dead() rebuilds it through the real snapshot+WAL
    recovery path and it serves reads again."""
    col, data = _collection(np.random.RandomState(18), n=200, max_per=400, parts=1)
    rs = ReplicaSet(col.partitions[0], num_replicas=4, reprobe_after_s=5.0)
    rs.insert([10_001], [77], data[:1])
    rs.kill(2, now_s=100.0)
    rs.kill(2, now_s=101.0)  # double-kill is a no-op (no double failover)
    assert not rs.replicas[2].alive and rs.failovers == 0
    assert rs.probe_dead(now_s=103.0) == []  # cooldown not elapsed
    assert rs.probe_dead(now_s=105.0) == [2]
    assert rs.replicas[2].alive and rs.recoveries == 1
    assert rs.replicas[2].applied_lsn == rs.lsn  # caught up via recovery
    before = rs.read_counts()[2]
    for _ in range(4):
        rs.search(data[:1], 3)
    assert rs.read_counts()[2] > before, "revived replica serves reads"
