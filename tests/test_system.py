"""End-to-end behaviour tests for the paper's system.

A miniature version of §4.5's runbooks: a stream of inserts, deletes and
queries; the in-place-delete policy must keep recall stable while the drop
policy degrades (Fig 13's comparison), and re-quantization must not break
comparability (§3.4).
"""
import numpy as np
import pytest

from repro.core import DiskANNIndex, GraphConfig
from repro.core import recall as rec

from conftest import clustered_data


def _runbook(policy: str, seed: int = 0, steps: int = 6):
    """Expiration-time-style runbook at CPU scale; returns recall per step."""
    rng = np.random.RandomState(seed)
    D, N_max = 24, 2600
    cfg = GraphConfig(capacity=N_max, R=12, M=6, L_build=32, L_search=48,
                      bootstrap_sample=128, refine_sample=10**9, batch_size=64)
    idx = DiskANNIndex(cfg, D, seed=seed)
    pool = clustered_data(rng, 4000, D)
    next_doc = 0
    live_docs: list[int] = []
    recalls = []
    for step in range(steps):
        # insert 300 docs with random expiry, delete ~150 expired
        n_new = 300
        ids = list(range(next_doc, next_doc + n_new))
        idx.insert(ids, pool[[i % 4000 for i in ids]])
        live_docs.extend(ids)
        next_doc += n_new
        if step >= 2:
            expire = rng.choice(live_docs, 150, replace=False).tolist()
            idx.delete(expire, policy=policy)
            live_docs = [d for d in live_docs if d not in set(expire)]
            idx.consolidate()
        if idx._graph_built and step >= 2:
            pick = rng.choice(live_docs, 16, replace=False)
            q = pool[[d % 4000 for d in pick]] + 0.03 * rng.randn(16, D).astype(np.float32)
            ids_r, _, _ = idx.search(q, k=10)
            vecs = idx.pv.vectors
            live = idx.pv.live
            gt = rec.ground_truth(q, vecs, live, 10)
            gt_docs = np.where(gt >= 0, idx.slot_to_doc[np.maximum(gt, 0)], -1)
            recalls.append(rec.recall_at_k(ids_r, gt_docs, 10))
    return recalls


def test_runbook_recall_stability_inplace():
    recalls = _runbook("inplace")
    assert len(recalls) >= 3
    assert min(recalls) >= 0.7, recalls
    assert recalls[-1] >= recalls[0] - 0.15, f"recall drifting down: {recalls}"


def test_inplace_beats_drop_policy():
    """Fig 13: in-place delete ≥ drop policy on recall after churn."""
    r_in = np.mean(_runbook("inplace", seed=3))
    r_drop = np.mean(_runbook("drop", seed=3))
    assert r_in >= r_drop - 0.02, (r_in, r_drop)


def test_requantization_mid_stream():
    """§3.4: re-quantize after more data arrives; search keeps working with
    mixed-schema codes and improves once re-encoding completes."""
    rng = np.random.RandomState(5)
    D = 24
    cfg = GraphConfig(capacity=3000, R=12, M=6, L_build=32, L_search=48,
                      bootstrap_sample=128, refine_sample=1500, batch_size=64)
    idx = DiskANNIndex(cfg, D, seed=1)
    data = clustered_data(rng, 2500, D)
    idx.insert(list(range(2000)), data[:2000])  # triggers requantize at 1500
    assert len(idx.schemas) == 2, "two schemas should coexist mid-transition"
    q = data[rng.choice(2000, 16)] + 0.02
    ids, _, _ = idx.search(q, k=10)
    gt = rec.ground_truth(q, data[:2000], idx.pv.live[:2000], 10)
    gt_docs = np.where(gt >= 0, idx.slot_to_doc[np.maximum(gt, 0)], -1)
    r_mid = rec.recall_at_k(ids, gt_docs, 10)
    assert r_mid >= 0.75, r_mid
    idx.requantize_all()
    assert len(idx.schemas) == 1
    ids2, _, _ = idx.search(q, k=10)
    r_post = rec.recall_at_k(ids2, gt_docs, 10)
    assert r_post >= r_mid - 0.1, (r_mid, r_post)


def test_capacity_exhaustion_raises():
    cfg = GraphConfig(capacity=100, R=8, M=4, bootstrap_sample=32, batch_size=32)
    idx = DiskANNIndex(cfg, 16)
    rng = np.random.RandomState(0)
    with pytest.raises(RuntimeError, match="split required"):
        idx.insert(list(range(200)), rng.randn(200, 16).astype(np.float32))
