"""Multi-device behaviour: shard_map distributed search and sharded train
steps run on 8 faked host devices in a subprocess (the main test process
keeps 1 device, per dryrun.py's isolation rule)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_subprocess(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=560,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_distributed_search_8way_matches_single():
    """8-shard shard_map fan-out == host-merged per-shard results."""
    res = _run_subprocess(textwrap.dedent("""
        import json
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import GraphConfig, DiskANNIndex
        from repro.core import recall as rec
        from repro.partition.fanout import distributed_search_fn

        rng = np.random.RandomState(0)
        P, N_per, D = 8, 250, 16
        centers = rng.randn(12, D).astype(np.float32)
        shards, all_data, all_docs = [], [], []
        for p in range(P):
            data = (centers[rng.randint(0, 12, N_per)]
                    + 0.15 * rng.randn(N_per, D)).astype(np.float32)
            cfg = GraphConfig(capacity=N_per, R=12, M=8, L_build=32, L_search=32,
                              bootstrap_sample=64, refine_sample=10**9,
                              batch_size=50)
            idx = DiskANNIndex(cfg, D, seed=p)
            docs = list(range(p * N_per, (p + 1) * N_per))
            idx.insert(docs, data)
            shards.append(idx)
            all_data.append(data)
            all_docs.extend(docs)
        full = np.concatenate(all_data)

        from repro import compat
        mesh = compat.make_mesh((8,), ("data",))
        fn = distributed_search_fn(mesh, L=32, k=10)
        stack = lambda f: jnp.stack([f(s) for s in shards])
        args = (
            stack(lambda s: jnp.asarray(s.pv.neighbors)),
            stack(lambda s: jnp.asarray(s.pv.codes)),
            stack(lambda s: jnp.asarray(s.pv.versions)),
            stack(lambda s: jnp.asarray(s.pv.live)),
            stack(lambda s: jnp.asarray(s.pv.vectors)),
            stack(lambda s: jnp.asarray(s.slot_to_doc)),
            jnp.asarray([s.medoid for s in shards], jnp.int32),
            stack(lambda s: s.schemas[0].codebooks),
            jnp.asarray(full[rng.choice(len(full), 8)] + 0.02),
        )
        ids, dists = fn(*args)
        q = np.asarray(args[-1])
        gt = rec.ground_truth(q, full, np.ones(len(full), bool), 10)
        r = rec.recall_at_k(np.asarray(ids), gt, 10)
        print(json.dumps({"recall": r,
                          "n_devices": len(jax.devices())}))
    """))
    assert res["n_devices"] == 8
    assert res["recall"] >= 0.7, res


def test_sharded_train_step_8way_matches_single_device():
    """The pjit train step gives the same loss on a (2,4) mesh as on (1,1)."""
    res = _run_subprocess(textwrap.dedent("""
        import json
        import numpy as np, jax, jax.numpy as jnp
        from repro import compat
        from repro.configs import get_smoke_config
        from repro.configs.shapes import ShapeSpec, input_specs
        from repro.models import steps as steps_mod
        from repro.train.optimizer import OptConfig

        cfg = get_smoke_config("qwen3-14b")
        spec = ShapeSpec("t", 32, 4, "train")
        shapes = input_specs(cfg, spec)
        rng = np.random.RandomState(0)
        batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 32)),
                                       jnp.int32)}
        losses = {}
        for ms, ax in (((1, 1), ("data", "model")), ((2, 4), ("data", "model"))):
            mesh = compat.make_mesh(ms, ax)
            b = steps_mod.make_train_step(cfg, mesh, shapes,
                                          OptConfig(lr=1e-3, total_steps=10))
            st = b.init()
            st, m = b.fn(st, batch)
            losses[str(ms)] = float(m["loss"])
        print(json.dumps(losses))
    """))
    a, b = res["(1, 1)"], res["(2, 4)"]
    assert abs(a - b) / a < 2e-2, res


def test_decode_step_sharded_cache():
    """Decode with a sequence-sharded KV cache matches unsharded math."""
    res = _run_subprocess(textwrap.dedent("""
        import json
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models import model as M
        from repro.models import steps as steps_mod

        cfg = get_smoke_config("starcoder2-15b")
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(0)
        batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (8, 16)), jnp.int32)}

        cache = M.init_cache(cfg, 8, 2048, dtype=jnp.float32)
        pl, cache = M.prefill(params, cfg, batch, cache)
        tok = jnp.argmax(pl[:, 0], -1).astype(jnp.int32)[:, None]
        ref_logits, _ = M.decode_step(params, cfg, tok, cache, jnp.int32(16))

        from repro import compat
        mesh = compat.make_mesh((2, 4), ("data", "model"))
        bundle = steps_mod.make_decode_step(cfg, mesh, batch=8, s_max=2048,
                                            cache_dtype=jnp.float32)
        params_sh = jax.device_put(params, bundle.arg_shardings[0])
        cache_sh = jax.device_put(cache, bundle.arg_shardings[1])
        out, _ = bundle.fn(params_sh, cache_sh, jax.device_put(tok, bundle.arg_shardings[2]), jnp.int32(16))
        err = float(jnp.abs(out - ref_logits).max())
        print(json.dumps({"err": err}))
    """))
    assert res["err"] < 1e-2, res
