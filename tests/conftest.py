"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the host's real
device(s); only launch/dryrun.py fakes 512 devices."""
import jax
import numpy as np
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    """Drop compiled executables between test modules. The suite compiles
    hundreds of distinct jit signatures (shape buckets × L × W × meshes);
    keeping them all live in one process eventually segfaults XLA's CPU
    backend_compile partway through the run. Module scope keeps the live
    set bounded without perturbing within-module recompile==0 assertions."""
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


def clustered_data(rng, n, dim, n_clusters=16, spread=0.15):
    """Clustered synthetic vectors — realistic-ish geometry for ANN tests
    (uniform gaussians are adversarial for PQ)."""
    centers = rng.randn(n_clusters, dim).astype(np.float32)
    assign = rng.randint(0, n_clusters, n)
    return (centers[assign] + spread * rng.randn(n, dim)).astype(np.float32)
