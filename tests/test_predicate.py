"""Declarative predicate API: AST canonicalization, the property-term
index (maintenance parity under delete / re-upsert / re-key / split), the
engine's batched filtered path, exact+filter, and filtered pagination."""
import numpy as np
import pytest

from repro.core import GraphConfig
from repro.serve import (F, Predicate, VectorCollectionService, VectorQuery,
                         from_obj, property_items)
from repro.store.props import (COMPILE_CACHE_CAP, PropertyTermIndex,
                               mask_to_words, words_to_mask)

from conftest import clustered_data


# ---------------------------------------------------------------------------
# AST: canonicalization / equality / hashing / serialization
# ---------------------------------------------------------------------------


def test_canonicalization_and_hashing():
    a = F.and_(F.eq("label", 3), F.range("price", 10, 20))
    b = F.and_(F.range("price", 10, 20), F.eq("label", 3))
    assert a == b and hash(a) == hash(b) and a.key() == b.key()

    assert F.in_("x", [3, 1, 2, 3]) == F.in_("x", [1, 2, 3])
    assert F.in_("x", [7]) == F.eq("x", 7)  # single-value in_ → eq
    assert F.not_(F.not_(a)) == a  # double negation cancels
    # nested and flattens + dedups
    assert F.and_(F.eq("x", 1), F.and_(F.eq("y", 2), F.eq("x", 1))) == \
        F.and_(F.eq("y", 2), F.eq("x", 1))
    # or/and are distinct even with the same children
    assert F.and_(F.eq("x", 1), F.eq("y", 2)) != F.or_(F.eq("x", 1), F.eq("y", 2))
    # typed value identity: bool is not int, int is not str
    assert F.eq("x", True) != F.eq("x", 1)
    assert F.eq("x", 1) != F.eq("x", "1")


def test_operator_sugar_and_serialization():
    p = (F.eq("genre", "jazz") | F.eq("genre", "blues")) & ~F.eq("year", 1999)
    assert isinstance(p, Predicate)
    rt = from_obj(p.to_obj())
    assert rt == p and rt.key() == p.key()
    # deterministic: a structurally-reordered build round-trips to same key
    q = ~F.eq("year", 1999) & (F.eq("genre", "blues") | F.eq("genre", "jazz"))
    assert from_obj(q.to_obj()) == p


def test_constructor_validation():
    with pytest.raises(ValueError):
        F.in_("x", [])
    with pytest.raises(ValueError):
        F.and_()
    with pytest.raises(TypeError):
        F.eq("x", [1, 2])  # values must be scalars
    # the doc key is never property-indexed: a predicate over it would
    # silently compile to an always-empty bitmap — reject at construction
    for build in (lambda: F.eq("id", 7), lambda: F.in_("id", [1, 2]),
                  lambda: F.range("id", 0, 9)):
        with pytest.raises(ValueError, match="not property-indexed"):
            build()


def test_matches_reference_semantics():
    doc = {"id": 1, "label": 3, "meta": {"genre": "jazz"}, "tags": ["a", "b"],
           "price": 12.5}
    assert F.eq("label", 3).matches(doc)
    assert not F.eq("label", 4).matches(doc)
    assert F.eq("meta/genre", "jazz").matches(doc)  # nested path
    assert F.eq("tags", "a").matches(doc)  # list membership
    assert F.range("price", 10, 20).matches(doc)
    assert not F.range("label", "a", "z").matches(doc)  # type-incomparable
    assert (~F.eq("missing", 1)).matches(doc)  # absent field passes NOT
    assert not F.eq("missing", 1).matches(doc)


# ---------------------------------------------------------------------------
# PropertyTermIndex: pure bitmap maintenance
# ---------------------------------------------------------------------------


def test_property_index_assign_remove_universe():
    idx = PropertyTermIndex(100)
    idx.assign(3, (("label", 1), ("color", "red")))
    idx.assign(7, (("label", 1),))
    m = idx.mask(idx.compile(F.eq("label", 1)))
    assert set(np.nonzero(m)[0]) == {3, 7}
    # re-assign slot 3 with CHANGED values: old postings must clear
    idx.assign(3, (("label", 2),))
    assert set(np.nonzero(idx.mask(idx.compile(F.eq("label", 1))))[0]) == {7}
    assert set(np.nonzero(idx.mask(idx.compile(F.eq("color", "red"))))[0]) == set()
    # NOT complements within present docs only
    m = idx.mask(idx.compile(~F.eq("label", 1)))
    assert set(np.nonzero(m)[0]) == {3}
    idx.remove(7)
    assert set(np.nonzero(idx.mask(idx.compile(F.eq("label", 1))))[0]) == set()
    assert set(np.nonzero(idx.mask(idx.compile(~F.eq("label", 99))))[0]) == {3}


def test_compile_cache_epoch_invalidation():
    idx = PropertyTermIndex(64)
    idx.assign(1, (("x", 1),))
    pred = F.eq("x", 1)
    idx.compile(pred)
    assert idx.last_compile_reads > 0  # cold compile touched postings
    idx.compile(pred)
    assert idx.last_compile_reads == 0  # cache hit
    idx.assign(2, (("x", 1),))  # mutation bumps epoch
    m = idx.mask(idx.compile(pred))
    assert idx.last_compile_reads > 0  # recompiled
    assert set(np.nonzero(m)[0]) == {1, 2}


def test_compile_cache_bounded_without_ingest():
    """A query-only workload with many distinct predicates must not grow
    the compiled-bitmap cache past its cap (no ingest → no epoch bump to
    clear it)."""
    idx = PropertyTermIndex(64)
    idx.assign(1, (("x", 1),))
    for v in range(COMPILE_CACHE_CAP + 40):
        idx.compile(F.eq("x", v))
    assert len(idx._cache) <= COMPILE_CACHE_CAP


def test_words_mask_roundtrip():
    rng = np.random.RandomState(0)
    mask = rng.rand(1000) < 0.3
    assert (words_to_mask(mask_to_words(mask), 1000) == mask).all()


# ---------------------------------------------------------------------------
# service-level maintenance parity: posting bitmaps must track doc_to_slot
# exactly through delete / re-upsert / re-key / split
# ---------------------------------------------------------------------------

PREDS = [
    F.eq("label", 1),
    F.in_("label", [0, 2]),
    F.range("price", 5.0, 30.0),
    F.and_(F.range("price", 0.0, 40.0), ~F.eq("label", 3)),
    F.or_(F.eq("label", 4), F.eq("color", "red")),
]


def _assert_parity(svc, collection=None):
    """Compiled predicate bitmaps == brute-force doc scans, per partition."""
    col = collection or svc.collection
    for p in col.partitions:
        for pred in PREDS:
            got = set(np.nonzero(p.props.mask(p.props.compile(pred)))[0])
            want = {
                slot for doc, slot in p.index.doc_to_slot.items()
                if doc in svc.docs and pred.matches(svc.docs[doc])
                and doc in p.doc_pk  # doc currently homed here
            }
            assert got == want, (p.pid, pred, got ^ want)


def _mk_service(n=300, parts=1, shard=None, cap=400, maxv=350):
    rng = np.random.RandomState(7)
    g = GraphConfig(capacity=cap, R=12, M=8, L_build=24, L_search=32,
                    bootstrap_sample=64, refine_sample=10**9, batch_size=64)
    svc = VectorCollectionService(dim=16, graph=g,
                                  max_vectors_per_partition=maxv,
                                  initial_partitions=parts,
                                  shard_key_path=shard)
    data = clustered_data(rng, n, 16)
    docs = [{"id": i, "label": i % 5, "price": float(i % 45),
             "color": "red" if i % 3 == 0 else "blue",
             **({"tenant": f"t{i % 2}"} if shard else {})}
            for i in range(n)]
    svc.upsert(docs, data)
    return svc, data, docs


def test_property_parity_after_delete_and_reupsert():
    svc, data, docs = _mk_service()
    _assert_parity(svc)
    # delete a slice
    svc.delete(list(range(0, 60, 2)))
    _assert_parity(svc)
    # re-upsert some deleted and some live docs with CHANGED field values
    changed = [{"id": i, "label": 9, "price": 7.5, "color": "green"}
               for i in list(range(0, 30, 2)) + [61, 63]]
    svc.upsert(changed, data[[d["id"] for d in changed]])
    _assert_parity(svc)
    got = svc.query(VectorQuery(vector=data[61] + 0.01, k=8,
                                filter=F.eq("label", 9)))
    assert all(svc.docs[int(i)]["label"] == 9 for i in got.ids[got.ids >= 0])
    # the old values must no longer match the re-upserted docs
    res = svc.query(VectorQuery(vector=data[61] + 0.01, k=300,
                                filter=F.eq("label", 61 % 5), exact=True))
    assert 61 not in res.ids.tolist()


def test_property_parity_after_shard_rekey():
    svc, data, docs = _mk_service(shard="tenant")
    _assert_parity(svc)
    for t in ("t0", "t1"):
        _assert_parity(svc, svc._tenant_collections[t])
    # re-home doc 4 (t0 → t1): the OLD tenant's postings must drop it
    moved = {"id": 4, "label": 4 % 5, "price": 4.0, "color": "blue",
             "tenant": "t1"}
    svc.upsert([moved], data[4:5])
    _assert_parity(svc)
    for t in ("t0", "t1"):
        _assert_parity(svc, svc._tenant_collections[t])
    res = svc.query(VectorQuery(vector=data[4] + 0.001, k=5, shard_key="t0",
                                filter=F.eq("label", 4 % 5)))
    assert 4 not in res.ids.tolist()


def test_property_parity_after_partition_split():
    svc, data, docs = _mk_service(n=200, cap=300, maxv=260)
    assert len(svc.collection.partitions) == 1
    # overflow the partition → split() re-homes docs into new partitions
    rng = np.random.RandomState(8)
    extra_n = 120
    extra = clustered_data(rng, extra_n, 16)
    svc.upsert([{"id": 1000 + i, "label": i % 5, "price": float(i % 45),
                 "color": "red" if i % 3 == 0 else "blue"}
                for i in range(extra_n)], extra)
    assert len(svc.collection.partitions) >= 2, "split did not trigger"
    _assert_parity(svc)


# ---------------------------------------------------------------------------
# engine: batched same-predicate execution
# ---------------------------------------------------------------------------


class _GuardedDict(dict):
    """doc_to_slot guard: predicate queries must never iterate it."""

    def __init__(self, *a):
        super().__init__(*a)
        self.scans = 0

    def items(self):
        self.scans += 1
        return super().items()


@pytest.fixture(scope="module")
def pred_service():
    svc, data, docs = _mk_service(n=380, parts=2, cap=300, maxv=280)
    return svc, data, docs


def test_same_predicate_queries_batch_through_engine(pred_service):
    svc, data, docs = pred_service
    pred = F.in_("label", [0, 1])
    rids = [svc.engine.submit_query(data[i] + 0.01, k=5, predicate=pred)
            for i in range(16)]
    svc.engine.drain()
    resps = [svc.engine.pop_response(r) for r in rids]
    assert all(r.status == 200 for r in resps)
    # ONE micro-batch, through the batched (bucketed) search path
    assert resps[0].batch_size == 16
    assert resps[0].plan.startswith("filtered-batched[")
    for r in resps:
        for i in r.ids[r.ids >= 0]:
            assert svc.docs[int(i)]["label"] in (0, 1)


def test_predicate_path_never_scans_documents(pred_service):
    svc, data, docs = pred_service
    guards = []
    for p in svc.collection.partitions:
        g = _GuardedDict(p.index.doc_to_slot)
        p.index.doc_to_slot = g
        guards.append(g)
    try:
        res = svc.query(VectorQuery(vector=data[3] + 0.01, k=5,
                                    filter=F.eq("label", 2)))
        assert res.plan.startswith("filtered-batched[")
        assert all(g.scans == 0 for g in guards), \
            "predicate path iterated doc_to_slot (document scan)"
        # prove the guard CAN see a scan (it is not a vacuous assertion)
        for _ in guards[0].items():
            pass
        assert sum(g.scans for g in guards) > 0
    finally:
        for p, g in zip(svc.collection.partitions, guards):
            p.index.doc_to_slot = dict(g)


def test_query_rejects_callable_filters(pred_service):
    """The legacy callable-filter host path is retired: an opaque callable
    raises (pointing at the F predicate builder) instead of falling back
    to an O(capacity) document scan — on both the graph and exact paths."""
    svc, data, docs = pred_service
    with pytest.raises(ValueError, match="callable"):
        svc.query(VectorQuery(vector=data[0], k=5,
                              filter=lambda d: d["label"] == 3))
    with pytest.raises(ValueError, match="repro.serve.F"):
        svc.query(VectorQuery(vector=data[0], k=5, exact=True,
                              filter=lambda d: True))


def test_exact_filtered_is_filtered_ground_truth(pred_service):
    svc, data, docs = pred_service
    pred = F.and_(F.eq("label", 1), F.eq("color", "blue"))
    q = data[21] + 0.01
    res = svc.query(VectorQuery(vector=q, k=6, filter=pred, exact=True))
    assert res.plan == "exact-filtered"
    match_ids = [d["id"] for d in docs if pred.matches(d)]
    dists = ((data[match_ids] - q) ** 2).sum(1)
    gt = [match_ids[i] for i in np.argsort(dists)[:6]]
    assert set(res.ids.tolist()) == set(gt)


def test_predicate_no_match_everywhere(pred_service):
    svc, data, docs = pred_service
    res = svc.query(VectorQuery(vector=data[0], k=5,
                                filter=F.eq("label", 777)))
    assert res.plan == "filtered-batched[empty]"
    assert (res.ids < 0).all()


def test_filtered_search_beta_bucketed_padding(pred_service):
    """The β/post graph modes now run through the bucketed batched entry:
    a padded micro-batch (B=3 → bucket 4, filter_bits broadcast + padded)
    must return exactly what the unpadded call returns."""
    svc, data, docs = pred_service
    p = svc.collection.partitions[0]
    mask = np.zeros(p.index.cfg.capacity, bool)
    mask[: p.index.count] = True
    mask[::3] = False
    qs = np.stack([data[1], data[5], data[9]]) + 0.01
    for mode in ("beta", "post"):
        a_ids, a_d, a_st = p.index.filtered_search(
            qs, 5, mask, mode=mode, pad_to_bucket=True
        )
        b_ids, b_d, b_st = p.index.filtered_search(qs, 5, mask, mode=mode)
        assert a_ids.shape == (3, 5)
        np.testing.assert_array_equal(a_ids, b_ids)
        np.testing.assert_allclose(a_d, b_d, rtol=1e-6)
        assert a_st.plan == b_st.plan == mode


# ---------------------------------------------------------------------------
# filtered pagination
# ---------------------------------------------------------------------------


def test_query_page_rejects_callable_filters(pred_service):
    svc, data, docs = pred_service
    with pytest.raises(ValueError, match="callable"):
        svc.query_page(VectorQuery(vector=data[0], filter=lambda d: True),
                       None, page_size=5)


def test_filtered_pagination_drain_parity(pred_service):
    svc, data, docs = pred_service
    pred = F.in_("label", [0, 4])
    q = data[12] + 0.01

    token, seen = None, []
    while True:
        r = svc.query_page(VectorQuery(vector=q, filter=pred), token,
                           page_size=7)
        assert r.plan == "paginated-filtered"
        ids = [i for i in r.ids.tolist() if i >= 0]
        assert all(svc.docs[i]["label"] in (0, 4) for i in ids)
        assert not (set(ids) & set(seen)), "page repeated a result"
        seen.extend(ids)
        token = r.continuation
        if token is None:
            break

    token, unfiltered = None, set()
    while True:
        r = svc.query_page(VectorQuery(vector=q), token, page_size=7)
        unfiltered.update(i for i in r.ids.tolist() if i >= 0)
        token = r.continuation
        if token is None:
            break
    want = {i for i in unfiltered if svc.docs[i]["label"] in (0, 4)}
    assert set(seen) == want, "filtered drain ≠ predicate ∩ unfiltered drain"


def test_filtered_pagination_match_set_gone_empty():
    """Regression: resuming a filtered pagination after ingest emptied the
    predicate's match set must NOT fall back to unfiltered fetches (a
    None slot_filter means 'no filter' downstream) — only rows that
    matched at fetch time may still drain, then the stream ends."""
    svc, data, docs = _mk_service(n=260, cap=350, maxv=340)
    pred = F.eq("label", 2)
    q = data[2] + 0.01
    r1 = svc.query_page(VectorQuery(vector=q, filter=pred), None, page_size=5)
    originally_matching = [d["id"] for d in docs if d["label"] == 2]
    assert r1.continuation is not None
    # re-label EVERY label-2 doc: the match set is now empty
    svc.upsert([{**docs[i], "label": 99} for i in originally_matching],
               data[originally_matching])
    emitted, token = [], r1.continuation
    while token is not None:
        r = svc.query_page(VectorQuery(vector=q, filter=pred), token,
                           page_size=5)
        emitted += [i for i in r.ids.tolist() if i >= 0]
        token = r.continuation
    assert set(emitted) <= set(originally_matching), \
        "never-matching docs leaked into filtered pages after resume"


def test_filtered_pagination_binds_token_to_predicate(pred_service):
    from repro.serve import ContinuationError
    svc, data, docs = pred_service
    q = data[9] + 0.01
    r = svc.query_page(VectorQuery(vector=q, filter=F.eq("label", 0)), None,
                       page_size=5)
    assert r.continuation is not None
    with pytest.raises(ContinuationError):
        svc.query_page(VectorQuery(vector=q, filter=F.eq("label", 1)),
                       r.continuation, page_size=5)
    with pytest.raises(ContinuationError):  # filtered token on unfiltered q
        svc.query_page(VectorQuery(vector=q), r.continuation, page_size=5)


# ---------------------------------------------------------------------------
# property_items extraction
# ---------------------------------------------------------------------------


def test_property_items_extraction():
    doc = {"id": 5, "label": 2, "meta": {"genre": "jazz", "year": 1959},
           "tags": ["hot", "cool"], "emb_note": None}
    items = dict()
    for path, value in property_items(doc):
        items.setdefault(path, []).append(value)
    assert "id" not in items  # the doc key is not a predicate term
    assert items["label"] == [2]
    assert items["meta/genre"] == ["jazz"]
    assert items["meta/year"] == [1959]
    assert sorted(items["tags"]) == ["cool", "hot"]
    assert items["emb_note"] == [None]
