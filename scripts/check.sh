#!/usr/bin/env bash
# One-command verification gate: import-lint every src/repro module, then
# run the tier-1 pytest suite. Future PRs are judged against this script.
#
#   scripts/check.sh            # import lint + tier-1 tests
#   scripts/check.sh --smoke    # ...then bench_serve + bench_query +
#                               # bench_filtered + bench_chaos +
#                               # bench_adaptive + bench_tiered at tiny sizes, so
#                               # benchmarks can't silently rot
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="$PWD/src${PYTHONPATH:+:$PYTHONPATH}"

SMOKE=0
if [[ "${1:-}" == "--smoke" ]]; then
  SMOKE=1
  shift
fi

echo "== import lint: every module under src/repro =="
python - <<'EOF'
import importlib
import pkgutil
import sys

import repro

failures = []
for info in pkgutil.walk_packages(repro.__path__, prefix="repro."):
    try:
        importlib.import_module(info.name)
    except Exception as e:  # noqa: BLE001 — report every broken module
        failures.append((info.name, f"{type(e).__name__}: {e}"))

if failures:
    for name, err in failures:
        print(f"IMPORT FAIL {name}: {err}")
    sys.exit(1)
count = sum(1 for _ in pkgutil.walk_packages(repro.__path__, prefix="repro."))
print(f"ok: {count} modules import cleanly")

# Pickle is banned repo-wide: continuation tokens are client-supplied
# bytes (serve/), and snapshots/WAL are durable state that must survive
# version skew and never execute on load (store/ uses the versioned,
# CRC'd repro.store.codec instead). AST-walk every module under
# src/repro and reject pickle-family imports.
import ast
from pathlib import Path

BANNED = {"pickle", "cPickle", "dill", "shelve"}
hits = []
for path in sorted(Path("src/repro").rglob("*.py")):
    tree = ast.parse(path.read_text(), filename=str(path))
    for node in ast.walk(tree):
        names = []
        if isinstance(node, ast.Import):
            names = [a.name.split(".")[0] for a in node.names]
        elif isinstance(node, ast.ImportFrom) and node.module:
            names = [node.module.split(".")[0]]
        for n in names:
            if n in BANNED:
                hits.append(f"{path}:{node.lineno}: imports {n}")
if hits:
    print("PICKLE LINT FAIL (client bytes and durable state must not "
          "round-trip through pickle):")
    for h in hits:
        print(" ", h)
    sys.exit(1)
print("ok: no pickle-family imports under src/repro")

# Opaque callable filters are retired: they can't batch, can't cache,
# and (historically) rebuilt an O(capacity) bitmap by scanning the doc
# store. The serving layer must never invoke one — filters arrive as
# declarative Predicates compiled to index-term bitmaps. AST-walk serve/
# and reject ANY `<expr>.filter(...)` call.
hits = []
for path in sorted(Path("src/repro/serve").rglob("*.py")):
    tree = ast.parse(path.read_text(), filename=str(path))
    for node in ast.walk(tree):
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "filter"
        ):
            hits.append(f"{path}:{node.lineno}: calls .filter(...)")
if hits:
    print("FILTER LINT FAIL (serve/ must never evaluate callable filters):")
    for h in hits:
        print(" ", h)
    sys.exit(1)
print("ok: serve/ never evaluates callable filters")
EOF

echo "== tier-1 tests =="
python -m pytest -x -q "$@"

if [[ "$SMOKE" == 1 ]]; then
  echo "== smoke benchmarks (tiny sizes; asserts are the contract) =="
  python -m benchmarks.bench_serve --smoke
  python -m benchmarks.bench_query --smoke
  python -m benchmarks.bench_filtered --smoke

  echo "== chaos gate: fault schedule vs availability/recall/RU floors =="
  python -m benchmarks.bench_chaos --smoke

  echo "== adaptive gate: policy loop vs SLO/RU/recompile + chaos floors =="
  # bench_adaptive self-asserts the ISSUE 9 floors (SLO ≥ 99%, idle RU at
  # the static-W1 level, zero steady-state recompiles, ingest ledger
  # closed) AND re-runs the chaos schedule with the policy enabled — its
  # run_chaos(policy="adaptive") call asserts availability ≥ 0.99,
  # recall Δ ≤ 0.01, and exact RU conservation internally.
  python -m benchmarks.bench_adaptive --smoke

  echo "== tiered gate: residency sweep vs recall-flat/hit-rate floors =="
  # bench_tiered self-asserts the ISSUE 10 floors: ids bit-identical at
  # every residency (recall Δ ≤ 0.01), RU/query monotone in shrinking
  # residency, hit rate ≥ 0.8 at 0.5 residency on the skewed mix, p95 at
  # 0.25 residency ≤ 2× fully resident, budget=None zero-miss parity,
  # registry-vs-page-counter conservation, and the chaos schedule green
  # with a 0.5-residency paged tier live.
  python -m benchmarks.bench_tiered --smoke

  echo "== observability gate: trace overhead + exported schema =="
  python - <<'EOF'
# Re-gate the smoke run's observability section from the artifact (the
# bench asserts these too — this keeps the gate honest even if the bench
# file's asserts are edited) and re-validate an actual JSONL trace export.
import json
import sys
import tempfile
from pathlib import Path

out = json.loads(Path("BENCH_serve.smoke.json").read_text())
ob = out["observability"]
fails = []
if ob["overhead_frac"] > 0.05:
    fails.append(f"trace overhead {100 * ob['overhead_frac']:.1f}% > 5%")
if ob["traces"] != ob["queries_ok"]:
    fails.append(f"{ob['traces']} traces for {ob['queries_ok']} queries")
if not (ob["schema_valid"] and ob["jsonl_lines_valid"]):
    fails.append("trace schema validation failed")
if ob["stage_vs_latency_rel_err"] > 1e-6:
    fails.append("stage breakdown does not reconcile with e2e latency")
for mode, row in ob["modes"]["modes"].items():
    if not row["reconciled"]:
        fails.append(f"dispatch mode {mode} failed trace reconciliation")

# live export check: a tiny traced run dumped to JSONL must re-validate
# line by line through the schema contract
import numpy as np
from repro.core import GraphConfig
from repro.serve import (EngineConfig, VectorCollectionService,
                         validate_trace_record)

rng = np.random.RandomState(0)
svc = VectorCollectionService(
    dim=16,
    graph=GraphConfig(capacity=300, R=16, M=8, L_build=32, L_search=32,
                      bootstrap_sample=48, refine_sample=10**9),
    max_vectors_per_partition=300,
    engine_cfg=EngineConfig(admission_control=False),
)
vecs = rng.randn(128, 16).astype(np.float32)
svc.upsert([{"id": i} for i in range(128)], vecs)
for i in range(20):
    svc.engine.submit_query(vecs[i] + 0.01, k=5)
svc.engine.drain()
with tempfile.TemporaryDirectory() as td:
    p = Path(td) / "traces.jsonl"
    n = svc.engine.tracer.dump_jsonl(p)
    lines = p.read_text().splitlines()
    if len(lines) != n or n < 20:
        fails.append(f"JSONL export wrote {len(lines)} lines for {n} records")
    for line in lines:
        try:
            validate_trace_record(json.loads(line))
        except ValueError as e:
            fails.append(f"exported trace line invalid: {e}")
            break

if fails:
    for f in fails:
        print(f"OBSERVABILITY GATE FAIL: {f}")
    sys.exit(1)
print(f"ok: trace overhead {100 * ob['overhead_frac']:+.1f}% (≤ +5%), "
      f"{ob['traces']} traces schema-valid, stage/latency rel err "
      f"{ob['stage_vs_latency_rel_err']:.1e}, all dispatch modes reconciled")
EOF
fi
