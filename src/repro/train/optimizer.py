"""AdamW with warmup-cosine schedule, global-norm clipping, and optional
low-precision second moments (a beyond-paper memory lever recorded in
EXPERIMENTS.md §Perf).

Master arithmetic in f32 regardless of param dtype (bf16 params get f32
updates cast back), which is what makes bf16-parameter training of the
235B MoE fit HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    m_dtype: str = "float32"
    v_dtype: str = "float32"


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


def _dt(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


def init_opt_state(params: Any, cfg: OptConfig) -> OptState:
    m = jax.tree.map(lambda p: jnp.zeros(p.shape, _dt(cfg.m_dtype)), params)
    v = jax.tree.map(lambda p: jnp.zeros(p.shape, _dt(cfg.v_dtype)), params)
    return OptState(m=m, v=v, step=jnp.int32(0))


def lr_schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(grads: Any) -> jax.Array:
    leaves = jax.tree.leaves(grads)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))


def adamw_update(
    params: Any, grads: Any, state: OptState, cfg: OptConfig
) -> tuple[Any, OptState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1.0 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + g * g * (1.0 - cfg.b2)
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (delta + decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([x[0] for x in new])
    new_m = tdef.unflatten([x[1] for x in new])
    new_v = tdef.unflatten([x[2] for x in new])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(m=new_m, v=new_v, step=step), metrics
