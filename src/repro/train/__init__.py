"""repro.train — optimizer, data pipeline, checkpointing, compression."""
from .optimizer import OptConfig, OptState, adamw_update, init_opt_state
from . import checkpoint, compression, data

__all__ = ["OptConfig", "OptState", "adamw_update", "init_opt_state",
           "checkpoint", "compression", "data"]
