"""Gradient compression for the cross-pod all-reduce (beyond-paper).

At 1000+-node scale the `pod` axis crosses DCN, which is an order of
magnitude slower than ICI — the gradient all-reduce dominates the
collective roofline term. Two standard levers, both error-compensated:

  * bf16 cast (2×) — effectively free in accuracy for gradients;
  * int8 blockwise quantization (4×) with per-block scales and a local
    error-feedback accumulator (residual added to the next step's gradient)
    so the quantization noise is unbiased over time.

`compress_for_allreduce` wraps a gradient pytree; the `psum` happens on the
compressed representation for bf16, and on dequantized-but-int8-transported
values for int8 (sum of quantized blocks, scales all-gathered).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


class Int8Compressed(NamedTuple):
    q: jax.Array  # int8 payload
    scale: jax.Array  # f32 per-block scales


def int8_compress(g: jax.Array) -> tuple[Int8Compressed, jax.Array]:
    """Returns (compressed, residual error for feedback)."""
    flat = g.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % BLOCK
    flat_p = jnp.pad(flat, (0, pad))
    blocks = flat_p.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[: flat.shape[0]]
    residual = (flat - deq).reshape(g.shape).astype(g.dtype)
    return Int8Compressed(q=q, scale=scale[:, 0]), residual


def int8_decompress(c: Int8Compressed, shape, dtype) -> jax.Array:
    deq = (c.q.astype(jnp.float32) * c.scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return deq[:n].reshape(shape).astype(dtype)


def compress_grads(grads: Any, residuals: Any, mode: str) -> tuple[Any, Any]:
    """Apply error-feedback compression to a gradient pytree.

    mode: 'none' | 'bf16' | 'int8'. Returns (transportable grads, residuals).
    """
    if mode == "none":
        return grads, residuals
    if mode == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads), residuals

    outs = jax.tree.map(
        lambda g, r: int8_compress(g + r.astype(g.dtype)), grads, residuals
    )
    comp = jax.tree.map(lambda o: o[0], outs,
                        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], Int8Compressed))
    res = jax.tree.map(lambda o: o[1], outs,
                       is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], Int8Compressed))
    return comp, res


def decompress_grads(comp: Any, template: Any, mode: str) -> Any:
    if mode == "none":
        return comp
    if mode == "bf16":
        return jax.tree.map(lambda g, t: g.astype(t.dtype), comp, template)
    return jax.tree.map(
        lambda c, t: int8_decompress(c, t.shape, t.dtype),
        comp,
        template,
        is_leaf=lambda x: isinstance(x, Int8Compressed),
    )


def init_residuals(params: Any, mode: str) -> Any:
    if mode != "int8":
        return jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
