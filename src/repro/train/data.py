"""Deterministic synthetic data pipeline (token / frame / vlm batches).

Production-shaped: sharded per-host loading (each data-parallel host slice
generates only its shard), a resumable cursor that checkpoints with the
train state, and packing-free fixed-length batches. Content is synthetic
(seeded PRNG over a Zipf-ish unigram table) — the substrate the paper's
workloads (embedding corpora) would stream through.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..models.config import ModelConfig


@dataclasses.dataclass
class DataState:
    step: int = 0
    seed: int = 0


class SyntheticStream:
    def __init__(self, cfg: ModelConfig, global_batch: int, seq_len: int,
                 seed: int = 0, host_id: int = 0, num_hosts: int = 1):
        assert global_batch % num_hosts == 0
        self.cfg = cfg
        self.local_batch = global_batch // num_hosts
        self.seq = seq_len
        self.state = DataState(step=0, seed=seed)
        self.host = host_id
        # Zipf-ish unigram distribution for non-degenerate CE losses
        V = cfg.vocab_size
        ranks = np.arange(1, V + 1, dtype=np.float64)
        self._probs = (1.0 / ranks**1.1)
        self._probs /= self._probs.sum()

    def _rng(self) -> np.random.RandomState:
        return np.random.RandomState(
            (self.state.seed * 1_000_003 + self.state.step * 7919 + self.host) % (2**31)
        )

    def next_batch(self) -> dict:
        rng = self._rng()
        self.state.step += 1
        cfg, B, S = self.cfg, self.local_batch, self.seq
        if cfg.input_mode == "tokens":
            tok = rng.choice(cfg.vocab_size, size=(B, S), p=self._probs).astype(np.int32)
            return {"tokens": tok}
        if cfg.input_mode == "frames":
            return {
                "frames": rng.randn(B, S, cfg.d_model).astype(np.float32),
                "labels": rng.randint(0, cfg.vocab_size, (B, S)).astype(np.int32),
            }
        Ni = cfg.num_image_tokens
        return {
            "tokens": rng.choice(cfg.vocab_size, size=(B, S - Ni), p=self._probs).astype(np.int32),
            "image_embeds": rng.randn(B, Ni, cfg.d_model).astype(np.float32),
        }

    # -- checkpointable cursor ------------------------------------------
    def snapshot(self) -> dict:
        return dataclasses.asdict(self.state)

    def restore(self, snap: dict):
        self.state = DataState(**snap)
