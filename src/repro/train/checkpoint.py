"""Sharded checkpointing with atomic manifests and elastic restore.

Fault-tolerance contract:
  * every leaf is written as its own .npy under step_<N>/ with a
    tree-manifest (msgpack) of paths/dtypes/shapes;
  * the manifest is written last and atomically (tmp + rename) — a crash
    mid-write leaves the previous checkpoint intact (restore picks the
    newest *complete* step);
  * restore(..., mesh=...) re-shards leaves onto whatever mesh the restart
    has (elastic scaling: train on 8, resume on 4 or 16 — tested);
  * data-pipeline cursor and RNG state ride along, so restarts are
    bit-deterministic.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}/{k}" if prefix else str(k)))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "shape"):
        if hasattr(tree, "_fields"):  # NamedTuple
            for k, v in zip(tree._fields, tree):
                out.update(_flatten(v, f"{prefix}/{k}" if prefix else str(k)))
        else:
            for i, v in enumerate(tree):
                out.update(_flatten(v, f"{prefix}/{i}" if prefix else str(i)))
    else:
        out[prefix] = tree
    return out


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None):
    """Write one checkpoint. Crash-safe: manifest lands last, atomically."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = d + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for path, leaf in flat.items():
        arr = np.asarray(leaf)
        fname = path.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][path] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    mpath = os.path.join(tmp, "manifest.json.partial")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    os.replace(mpath, os.path.join(tmp, "manifest.json"))
    if os.path.exists(d):
        shutil.rmtree(d)
    os.replace(tmp, d)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            best = max(best or -1, int(m.group(1)))
    return best


def restore(
    ckpt_dir: str,
    template: Any,
    step: Optional[int] = None,
    shardings: Any = None,
) -> tuple[Any, dict]:
    """Restore into `template`'s structure; optionally device_put with new
    shardings (elastic remesh)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no complete checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    flat_t = _flatten(template)
    flat_s = _flatten(shardings) if shardings is not None else {}
    loaded = {}
    for path in flat_t:
        info = manifest["leaves"][path]
        arr = np.load(os.path.join(d, info["file"]))
        if path in flat_s and flat_s[path] is not None:
            loaded[path] = jax.device_put(arr, flat_s[path])
        else:
            loaded[path] = arr

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}/{k}" if prefix else str(k)) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)) and not hasattr(tree, "shape"):
            if hasattr(tree, "_fields"):
                return type(tree)(*[
                    rebuild(v, f"{prefix}/{k}" if prefix else str(k))
                    for k, v in zip(tree._fields, tree)
                ])
            return type(tree)(
                rebuild(v, f"{prefix}/{i}" if prefix else str(i)) for i, v in enumerate(tree)
            )
        return loaded[prefix]

    return rebuild(template), manifest["extra"]
