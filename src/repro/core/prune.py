"""RobustPrune (Algorithm 3) — the α-RNG pruning rule.

Note on the paper's pseudocode: the PDF's Algorithm 3 prints the domination
test as ``α·dist(r,p) < ||x_q − x_r||`` which is inconsistent with the
DiskANN papers it cites ([38], [36]) and with the open-source library. We
implement the canonical rule: scanning candidates q in ascending d(p,q), a
kept neighbor r *dominates* q (q is dropped) iff

    α · d(r, q) ≤ d(p, q)          (α ≥ 1; larger α prunes less)

Distances here are squared L2 (or negated IP), so for L2 the α on the
*metric* becomes α² on the squared values.

Pruning runs in quantized space (§3.2: "computations can also be done on
quantized vectors with moderate compression rates"): candidate coordinates
are the PQ-decoded vectors, matching the paper's use of a moderate-rate
codebook for the prune stage. A full-precision variant is available for the
`prune_precision="full"` config.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import search as smod

INF = jnp.float32(jnp.inf)


@functools.partial(jax.jit, static_argnames=("R", "metric"))
def robust_prune(
    cand_ids: jax.Array,  # (C,) int32, -1 = invalid
    dists_to_p: jax.Array,  # (C,) f32 d(p, candidate), INF for invalid
    pairwise: jax.Array,  # (C, C) f32 d(candidate_i, candidate_j)
    *,
    alpha: float,
    R: int,
    metric: str = "l2",
) -> jax.Array:
    """Select ≤ R candidate *indices'* ids under the α-RNG rule.

    Returns (R,) int32 node ids, -1 padded, in ascending-distance keep order.
    """
    C = cand_ids.shape[0]
    a = jnp.float32(alpha * alpha if metric == "l2" else alpha)

    d = jnp.where(cand_ids >= 0, dists_to_p, INF)
    order = jnp.argsort(d)  # ascending; invalid sink to the end

    class _S(NamedTuple):
        kept_mask: jax.Array  # (C,) over *original* candidate positions
        kept_count: jax.Array

    def body(i, s: _S):
        ci = order[i]
        dom = jnp.any(s.kept_mask & (a * pairwise[:, ci] <= d[ci]))
        ok = (d[ci] < INF) & (~dom) & (s.kept_count < R)
        return _S(
            kept_mask=s.kept_mask.at[ci].set(s.kept_mask[ci] | ok),
            kept_count=s.kept_count + ok.astype(jnp.int32),
        )

    s = jax.lax.fori_loop(0, C, body, _S(jnp.zeros((C,), bool), jnp.int32(0)))

    # compact kept ids in ascending-distance order into an (R,) array —
    # only the top-R slice is consumed, so top_k beats a full argsort
    keep_d = jnp.where(s.kept_mask, d, INF)
    _, take = jax.lax.top_k(-keep_d, R)
    out = jnp.where(jnp.take(s.kept_mask, take), jnp.take(cand_ids, take), -1)
    return out.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("R", "metric"))
def prune_with_vectors(
    p_vec: jax.Array,  # (D,) coordinates of the node being pruned
    cand_ids: jax.Array,  # (C,)
    cand_vecs: jax.Array,  # (C, D) candidate coordinates (decoded PQ or full)
    *,
    alpha: float,
    R: int,
    metric: str = "l2",
    self_id: jax.Array | int = -1,
) -> jax.Array:
    """RobustPrune from raw coordinates: computes d(p,·) and pairwise then
    applies the rule. Excludes `self_id` (E ← E \\ {p} in Alg 3)."""
    valid = cand_ids >= 0
    if metric == "l2":
        diff = cand_vecs - p_vec[None, :]
        d_p = jnp.sum(diff * diff, -1)
        x2 = jnp.sum(cand_vecs * cand_vecs, -1)
        pair = x2[:, None] - 2.0 * cand_vecs @ cand_vecs.T + x2[None, :]
        pair = jnp.maximum(pair, 0.0)
    else:
        d_p = -cand_vecs @ p_vec
        pair = -(cand_vecs @ cand_vecs.T)
    d_p = jnp.where(valid & (cand_ids != self_id), d_p, INF)
    # a candidate must also not duplicate an earlier one (sort-based mask —
    # same pass the search hot path uses for W·R-wide frontiers)
    d_p = jnp.where(smod.mask_duplicates(cand_ids), INF, d_p)
    return robust_prune(cand_ids, d_p, pair, alpha=alpha, R=R, metric=metric)
