"""repro.core — the paper's contribution: stateless DiskANN for databases.

Public API:
    GraphConfig, GraphState          index configuration / state pytree
    DiskANNIndex                     host-side replica orchestrator
    train_pq / encode / adc_lut ...  product quantization (repro.core.pq)
    greedy_search / batch_greedy_search   Algorithm 1 (quantized space)
    robust_prune / prune_with_vectors     Algorithm 3
    insert_batch_jit / insert_candidates  Algorithms 2 & 5
    inplace_delete / consolidate_chunk    Algorithm 6
    next_page / start_pagination          paginated search (Fig 3)
    brute_force / qflat_scan / rerank     Flat & Q-Flat plans + Fig 5 rerank
"""
from .graph import GraphConfig, GraphState, empty_state, compute_medoid
from .index import DiskANNIndex, QueryStats
from .providers import ArrayProviderSet, Context
from . import pq, search, prune, insert, delete, paginate, flat, recall

__all__ = [
    "GraphConfig",
    "GraphState",
    "empty_state",
    "compute_medoid",
    "DiskANNIndex",
    "QueryStats",
    "ArrayProviderSet",
    "Context",
    "pq",
    "search",
    "prune",
    "insert",
    "delete",
    "paginate",
    "flat",
    "recall",
]
