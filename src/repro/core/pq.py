"""Product Quantization (PQ) — §2.1 / §3.4 of the paper.

The paper compresses vectors with PQ so that (a) the navigation structures fit
in fast storage and (b) distance comparisons are cheap. We reproduce:

  * k-means codebook training from a small sample (1000-vector bootstrap
    schema, refined with a 25 000-vector sample — §3.4 "Re-quantization"),
  * encode / decode,
  * ADC (asymmetric distance computation) lookup tables per query,
  * cross-schema distances so vectors encoded under the *old* schema remain
    comparable during re-quantization (§3.4), without a graph rebuild.

TPU adaptation (see DESIGN.md §2): on CPU the ADC inner loop is an L1-cache
table lookup; on TPU we express it as a one-hot × LUT contraction that maps
onto the MXU. The pure-jnp forms here are the reference; the Pallas kernels
in ``repro.kernels`` implement the tiled versions.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

# Paper operating points (§3.4): bootstrap schema after 1000 vectors,
# refine ("re-quantize") after 25 000.
BOOTSTRAP_SAMPLE = 1000
REFINE_SAMPLE = 25000


class PQSchema(NamedTuple):
    """A trained product quantizer.

    codebooks: (M, K, dsub) float32 — M subspaces, K centroids each.
    version:   () int32 — schema version, bumped by re-quantization.
    """

    codebooks: jax.Array
    version: jax.Array

    @property
    def M(self) -> int:
        return self.codebooks.shape[0]

    @property
    def K(self) -> int:
        return self.codebooks.shape[1]

    @property
    def dsub(self) -> int:
        return self.codebooks.shape[2]

    @property
    def dim(self) -> int:
        return self.M * self.dsub


def _split(x: jax.Array, M: int) -> jax.Array:
    """(..., D) -> (..., M, dsub)."""
    *lead, D = x.shape
    return x.reshape(*lead, M, D // M)


# ---------------------------------------------------------------------------
# Training (k-means per subspace, Lloyd iterations)
# ---------------------------------------------------------------------------


def _kmeans_one(key: jax.Array, pts: jax.Array, K: int, iters: int) -> jax.Array:
    """k-means over pts (S, dsub) -> centroids (K, dsub)."""
    S = pts.shape[0]
    init_idx = jax.random.choice(key, S, shape=(K,), replace=S < K)
    init = pts[init_idx]

    def step(cent, _):
        # assign
        d = (
            jnp.sum(pts * pts, -1, keepdims=True)
            - 2.0 * pts @ cent.T
            + jnp.sum(cent * cent, -1)[None, :]
        )  # (S, K)
        assign = jnp.argmin(d, axis=-1)
        onehot = jax.nn.one_hot(assign, K, dtype=pts.dtype)  # (S, K)
        counts = onehot.sum(0)  # (K,)
        sums = onehot.T @ pts  # (K, dsub)
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), cent)
        return new, None

    cent, _ = jax.lax.scan(step, init, None, length=iters)
    return cent


@functools.partial(jax.jit, static_argnames=("M", "K", "iters"))
def train_pq(key: jax.Array, sample: jax.Array, M: int, K: int = 256, iters: int = 12) -> PQSchema:
    """Train a PQ schema from a sample (S, D). D must be divisible by M."""
    S, D = sample.shape
    assert D % M == 0, f"dim {D} not divisible by M={M}"
    sub = _split(sample, M).transpose(1, 0, 2)  # (M, S, dsub)
    keys = jax.random.split(key, M)
    codebooks = jax.vmap(lambda k, p: _kmeans_one(k, p, K, iters))(keys, sub)
    return PQSchema(codebooks=codebooks.astype(jnp.float32), version=jnp.int32(0))


def refine_pq(key: jax.Array, schema: PQSchema, sample: jax.Array, iters: int = 12) -> PQSchema:
    """Re-quantization (§3.4): retrain on a larger sample; bump version.

    The refined schema is "related" to the original (same M/K; warm-started
    from the old centroids so codes drift little), which is what makes
    cross-schema distances meaningful in the paper.
    """
    M, K = schema.M, schema.K
    sub = _split(sample, M).transpose(1, 0, 2)  # (M, S, dsub)

    def one(pts, cent0):
        def step(cent, _):
            d = (
                jnp.sum(pts * pts, -1, keepdims=True)
                - 2.0 * pts @ cent.T
                + jnp.sum(cent * cent, -1)[None, :]
            )
            assign = jnp.argmin(d, axis=-1)
            onehot = jax.nn.one_hot(assign, K, dtype=pts.dtype)
            counts = onehot.sum(0)
            sums = onehot.T @ pts
            return jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), cent), None

        cent, _ = jax.lax.scan(step, cent0, None, length=iters)
        return cent

    codebooks = jax.vmap(one)(sub, schema.codebooks)
    return PQSchema(codebooks=codebooks.astype(jnp.float32), version=schema.version + 1)


# ---------------------------------------------------------------------------
# Encode / decode
# ---------------------------------------------------------------------------


@jax.jit
def encode(schema: PQSchema, x: jax.Array) -> jax.Array:
    """(..., D) float -> (..., M) uint8 codes."""
    sub = _split(x, schema.M)  # (..., M, dsub)
    cent = schema.codebooks  # (M, K, dsub)
    # dists (..., M, K)
    d = (
        jnp.sum(sub * sub, -1, keepdims=True)
        - 2.0 * jnp.einsum("...md,mkd->...mk", sub, cent)
        + jnp.sum(cent * cent, -1)
    )
    return jnp.argmin(d, axis=-1).astype(jnp.uint8)


@jax.jit
def decode(schema: PQSchema, codes: jax.Array) -> jax.Array:
    """(..., M) uint8 -> (..., D) float32 reconstruction."""
    cent = schema.codebooks  # (M, K, dsub)
    gathered = jnp.take_along_axis(
        cent[None], codes.reshape(-1, schema.M)[:, :, None, None].astype(jnp.int32), axis=2
    )  # (N, M, 1, dsub)
    out = gathered[:, :, 0, :].reshape(*codes.shape[:-1], schema.dim)
    return out


# ---------------------------------------------------------------------------
# ADC lookup tables + distances
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("metric",))
def adc_lut(schema: PQSchema, q: jax.Array, metric: str = "l2") -> jax.Array:
    """LUT for query q: (..., M, K) float32.

    l2: squared L2 between query subvector and centroid.
    ip: negative inner product (so smaller = closer, uniformly min-is-best).
    cosine: callers should pre-normalize; then ip == cosine distance - 1.
    """
    sub = _split(q, schema.M)  # (..., M, dsub)
    cent = schema.codebooks  # (M, K, dsub)
    if metric == "l2":
        lut = (
            jnp.sum(sub * sub, -1, keepdims=True)
            - 2.0 * jnp.einsum("...md,mkd->...mk", sub, cent)
            + jnp.sum(cent * cent, -1)
        )
    elif metric in ("ip", "cosine"):
        lut = -jnp.einsum("...md,mkd->...mk", sub, cent)
    else:
        raise ValueError(f"unknown metric {metric!r}")
    return lut.astype(jnp.float32)


@jax.jit
def adc_distance(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """Asymmetric distance from the LUT's query to encoded points.

    lut:   (M, K) float32
    codes: (..., M) uint8
    -> (...) float32
    """
    M = lut.shape[0]
    flat = codes.reshape(-1, M).astype(jnp.int32)  # (C, M)
    picked = jnp.take_along_axis(lut.T, flat, axis=0) if False else None  # noqa
    # gather per subspace: lut[m, code[c, m]]
    d = jnp.take_along_axis(lut[None, :, :], flat[:, :, None], axis=2)[..., 0]  # (C, M)
    return d.sum(-1).reshape(codes.shape[:-1])


@jax.jit
def adc_distance_onehot(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """MXU-friendly ADC: one-hot(codes) · lut — same result as adc_distance.

    This is the TPU formulation the pq_adc Pallas kernel tiles: the table
    lookup becomes a (C, M·K) × (M·K,) contraction on the MXU instead of a
    scalar gather. See DESIGN.md §2.
    """
    M, K = lut.shape
    flat = codes.reshape(-1, M)
    onehot = jax.nn.one_hot(flat, K, dtype=lut.dtype)  # (C, M, K)
    d = jnp.einsum("cmk,mk->c", onehot, lut)
    return d.reshape(codes.shape[:-1])


# ---------------------------------------------------------------------------
# Cross-schema support (re-quantization without rebuild)
# ---------------------------------------------------------------------------


def multi_lut(schemas: tuple[PQSchema, ...], q: jax.Array, metric: str = "l2") -> jax.Array:
    """Stack LUTs for several coexisting schemas: (V, M, K).

    During re-quantization old codes (schema v) and new codes (schema v+1)
    coexist; each vector row is tagged with its schema version and distances
    are computed against the matching LUT. Distances remain comparable
    because both LUTs measure against the *same* query in the original space
    (§3.4: "such distance calculations are meaningful").
    """
    return jnp.stack([adc_lut(s, q, metric) for s in schemas], axis=0)


@jax.jit
def adc_distance_versioned(luts: jax.Array, codes: jax.Array, versions: jax.Array) -> jax.Array:
    """ADC with a per-row schema version.

    luts:     (V, M, K) float32
    codes:    (..., M) uint8
    versions: (...,) int — index into luts
    """
    V, M, K = luts.shape
    flat = codes.reshape(-1, M).astype(jnp.int32)
    ver = versions.reshape(-1).astype(jnp.int32)
    lut_rows = luts[ver]  # (C, M, K)
    d = jnp.take_along_axis(lut_rows, flat[:, :, None], axis=2)[..., 0]
    return d.sum(-1).reshape(codes.shape[:-1])


# ---------------------------------------------------------------------------
# Exact distances (document-store re-rank path)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("metric",))
def exact_distance(q: jax.Array, x: jax.Array, metric: str = "l2") -> jax.Array:
    """q (..., D), x (..., D) -> (...) float32 full-precision distance."""
    if metric == "l2":
        diff = q - x
        return jnp.sum(diff * diff, -1)
    if metric in ("ip", "cosine"):
        return -jnp.sum(q * x, -1)
    raise ValueError(metric)


@functools.partial(jax.jit, static_argnames=("metric",))
def pairwise_distance(a: jax.Array, b: jax.Array, metric: str = "l2") -> jax.Array:
    """a (N, D), b (M, D) -> (N, M)."""
    if metric == "l2":
        return (
            jnp.sum(a * a, -1, keepdims=True)
            - 2.0 * a @ b.T
            + jnp.sum(b * b, -1)[None, :]
        )
    if metric in ("ip", "cosine"):
        return -(a @ b.T)
    raise ValueError(metric)
