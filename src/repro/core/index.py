"""DiskANNIndex — the host-side orchestrator tying the pieces together.

Mirrors the paper's control flow for one replica:

  * documents arrive → full vector to the document store, quantized term
    generated inline (once a schema exists), graph updates applied in
    mini-batches *outside* the transactional path (§3.4);
  * first PQ schema trained after ``bootstrap_sample`` docs; re-quantization
    at ``refine_sample`` docs re-encodes terms in place, old/new schemas
    coexisting via versioned codes (§3.4);
  * queries run in quantized space over the graph, then re-rank
    ``quantizedVectorListMultiplier × k`` candidates with full-precision
    vectors from the document store (§3.5, Fig 5);
  * the query planner routes by selectivity: brute force for tiny
    collections, Q-Flat below ~5000 predicate matches, graph search with
    post-filtering or filter-aware β-search otherwise (§3.5);
  * deletes are in-place (Alg 6) with a background consolidation sweep.

All distance-heavy work is jitted; this class only sequences it and applies
term writes through the Provider interface — the same split as
IndexManager / DiskANN-library / Bw-Tree in Fig 15.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import delete as dmod
from . import flat as fmod
from . import graph as g
from . import insert as imod
from . import paginate as pgmod
from . import pq as pqmod
from . import prune as prmod
from . import search as smod
from .providers import ArrayProviderSet, Context, ProviderSet


# backup-queue capacity for paginated search: one value service-wide, so
# every continuation token carries a single known shape (the serving layer
# validates client tokens against it — an arbitrary width would mint a
# fresh jit signature per forged token)
PAGE_BACKUP_CAP = 512


@dataclasses.dataclass
class QueryStats:
    hops: float = 0.0  # sequential expansion rounds (latency-critical path)
    cmps: float = 0.0  # quantized distance comparisons (≈3500 @ L=100 in paper)
    full_reads: float = 0.0  # full-precision vectors touched (≈50 in paper)
    expansions: float = 0.0  # adjacency rows fetched (= hops·W̄; RU-relevant)
    # paged vector tier (ISSUE 10): rerank-stage page touches, per-query
    # means (= batch page totals / B, the same convention as cmps/hops);
    # a miss costs RU + modelled latency via store/ru.py, a hit is free
    tier_hits: float = 0.0
    tier_misses: float = 0.0
    plan: str = "graph"


class DiskANNIndex:
    def __init__(
        self,
        cfg: g.GraphConfig,
        dim: int,
        providers: Optional[ProviderSet] = None,
        seed: int = 0,
        context: Context = Context(),
    ):
        assert dim % cfg.M == 0, f"dim {dim} must divide into M={cfg.M} subspaces"
        self.cfg = cfg
        self.dim = dim
        self.ctx = context
        self.pv: ProviderSet = providers or ArrayProviderSet(
            cfg.capacity, cfg.R_slack, cfg.M, dim
        )
        self.key = jax.random.PRNGKey(seed)
        self.schemas: list[pqmod.PQSchema] = []  # ≤2 coexisting (§3.4)
        self.count = 0  # slot high-watermark
        self.medoid = 0
        self.doc_to_slot: dict[int, int] = {}
        self.slot_to_doc = np.full((cfg.capacity,), -1, np.int64)
        self._graph_built = False
        self._pending: list[int] = []  # slots awaiting first graph build
        self._requant_cursor = 0  # background re-encode progress
        self._consolidate_cursor = 0
        # tier touches of the most recent next_page() call (pagination has
        # no QueryStats of its own; the partition layer folds these into
        # the page_stats delta)
        self.last_page_tier: tuple[float, float] = (0.0, 0.0)

    # ------------------------------------------------------------------
    # helpers
    # ------------------------------------------------------------------
    @property
    def num_live(self) -> int:
        return int(self.pv.live.sum())

    def _codebook_stack(self) -> jax.Array:
        return jnp.stack([s.codebooks for s in self.schemas], axis=0)

    def _luts(self, queries: np.ndarray) -> jax.Array:
        schemas = tuple(self.schemas)
        q = jnp.asarray(queries, jnp.float32)
        return jax.vmap(lambda qq: pqmod.multi_lut(schemas, qq, self.cfg.metric))(q)

    def _next_key(self) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sub

    # -- paged vector tier (ISSUE 10) ----------------------------------
    def _touch_tier(self, slots, stats: QueryStats, B: int,
                    admit: bool = True, pin: bool = False):
        """Record a rerank-stage access to the paged full-precision tier.

        Folds page-level hit/miss counts into ``stats`` as per-query
        means (batch totals / B). With ``pin=True`` the touched pages
        stay pinned (never evicted mid-rerank) until the returned handle
        is passed to :meth:`_unpin_tier`. ``admit=False`` marks a full
        scan (brute/exact): billed, never cached."""
        pages = getattr(self.pv, "pages", None)
        if pages is None:
            return None
        hits, misses, touched = pages.touch(slots, admit=admit, pin=pin)
        stats.tier_hits += hits / max(B, 1)
        stats.tier_misses += misses / max(B, 1)
        return touched if pin else None

    def _unpin_tier(self, handle) -> None:
        if handle is not None:
            self.pv.pages.unpin(handle)

    # ------------------------------------------------------------------
    # ingestion
    # ------------------------------------------------------------------
    def insert(self, doc_ids: Sequence[int], vectors: np.ndarray) -> QueryStats:
        """Insert documents. Returns aggregate ingest stats."""
        vectors = np.asarray(vectors, np.float32)
        assert vectors.shape[1] == self.dim
        stats = QueryStats(plan="insert")
        for start in range(0, len(doc_ids), self.cfg.batch_size):
            ids = list(doc_ids[start : start + self.cfg.batch_size])
            vecs = vectors[start : start + self.cfg.batch_size]
            self._insert_batch(ids, vecs, stats)
        return stats

    def _alloc(self, n: int) -> np.ndarray:
        if self.count + n > self.cfg.capacity:
            raise RuntimeError(
                f"partition full ({self.count}+{n} > {self.cfg.capacity}); "
                "split required (repro.partition handles this)"
            )
        slots = np.arange(self.count, self.count + n, dtype=np.int64)
        self.count += n
        return slots

    def _insert_batch(self, ids: list[int], vecs: np.ndarray, stats: QueryStats):
        replace_mask = np.array([d in self.doc_to_slot for d in ids])
        if replace_mask.any():
            # Replace = overwrite vector + re-insert (§2.1 "Inserts and
            # Replaces"); old edges cleaned lazily by later prunes.
            keep = ~replace_mask
            for d, v in zip(np.asarray(ids)[replace_mask], vecs[replace_mask]):
                self._replace_one(int(d), v)
            ids = list(np.asarray(ids)[keep])
            vecs = vecs[keep]
            if len(ids) == 0:
                return

        slots = self._alloc(len(ids))
        for d, s in zip(ids, slots):
            self.doc_to_slot[int(d)] = int(s)
            self.slot_to_doc[s] = int(d)
        self.pv.set_full(self.ctx, slots, vecs)
        # crash point right after the full-vector (paged tier) write: a
        # WAL that loses set_full replay would resurface stale vectors
        # at rerank — recovery_invariants bit-compares the tier
        self.pv.barrier("upsert:post_full")

        if not self.schemas:
            self._pending.extend(int(s) for s in slots)
            self.pv.set_live(self.ctx, slots, True)
            if self.count >= min(self.cfg.bootstrap_sample, self.cfg.capacity):
                self._bootstrap_schema()
            return

        # quantized term inline with the document write (§3.4)
        codes = np.asarray(pqmod.encode(self.schemas[-1], jnp.asarray(vecs)))
        ver = np.full((len(slots),), len(self.schemas) - 1, np.uint8)
        self.pv.set_quant(self.ctx, slots, codes, ver)
        self.pv.set_live(self.ctx, slots, True)

        if self._graph_built:
            self._graph_insert(slots, vecs, stats)
        else:
            self._pending.extend(int(s) for s in slots)

        if (
            len(self.schemas) == 1
            and self.count >= min(self.cfg.refine_sample, self.cfg.capacity)
        ):
            self.requantize()

    def _bootstrap_schema(self):
        """Train the first PQ schema from the earliest docs (§3.4), backfill
        quantized terms, then build the graph over the backlog."""
        sample = self.pv.vectors[: min(self.count, self.cfg.bootstrap_sample)]
        self.schemas = [
            pqmod.train_pq(self._next_key(), jnp.asarray(sample), self.cfg.M)
        ]
        backlog = np.asarray(self._pending, np.int64)
        codes = np.asarray(
            pqmod.encode(self.schemas[0], jnp.asarray(self.pv.vectors[backlog]))
        )
        self.pv.set_quant(self.ctx, backlog, codes, np.zeros(len(backlog), np.uint8))
        self._pending = []
        self._build_initial_graph(backlog)

    def _build_initial_graph(self, slots: np.ndarray):
        self.medoid = int(
            g.compute_medoid(jnp.asarray(self.pv.vectors), jnp.asarray(self.pv.live))
        )
        self._graph_built = True
        order = np.random.RandomState(0).permutation(slots)
        st = QueryStats()
        # Ramp-up: batch-inserting into a near-empty graph funnels every new
        # node's single candidate (the medoid) into one overflowing adjacency
        # list — the losers end up with zero in-degree, permanently
        # unreachable. Grow batches 4 → 8 → … so early nodes wire densely.
        i, bs = 0, 4
        while i < len(order):
            batch = order[i : i + bs]
            i += bs
            bs = min(bs * 2, self.cfg.batch_size)
            batch = batch[batch != self.medoid]
            if len(batch) == 0:
                continue
            self._graph_insert(batch, self.pv.vectors[batch], st)
        self.repair_orphans()

    def repair_orphans(self) -> int:
        """Re-insert live nodes with zero in-degree (background maintenance;
        guarantees every vector is reachable from the medoid's side)."""
        nb = self.pv.neighbors[: self.count]
        indeg = np.bincount(nb[nb >= 0], minlength=self.cfg.capacity)
        live = self.pv.live
        orphans = np.nonzero((indeg[: self.count] == 0) & live[: self.count])[0]
        orphans = orphans[orphans != self.medoid]
        if len(orphans) == 0:
            return 0
        st = QueryStats()
        for i in range(0, len(orphans), self.cfg.batch_size):
            batch = orphans[i : i + self.cfg.batch_size]
            self._graph_insert(batch, self.pv.vectors[batch], st)
        return len(orphans)

    def _graph_insert(self, slots: np.ndarray, vecs: np.ndarray, stats: QueryStats):
        """Mini-batch graph update (Alg 5): jitted search+prune, then one
        consolidated reverse-edge append per touched node."""
        cfg = self.cfg
        neighbors, codes, versions, live, _ = self.pv.materialize(self.ctx)
        cand_ids, _cand_d, istats = imod.insert_candidates(
            neighbors, codes, versions, live, self._codebook_stack(),
            jnp.asarray(vecs), jnp.int32(self.medoid),
            L_build=cfg.L_build, metric=cfg.metric,
        )
        nbrs = np.asarray(
            imod.prune_batch(
                codes, versions, self._codebook_stack(), jnp.asarray(vecs),
                cand_ids, R=cfg.R, alpha=cfg.alpha, metric=cfg.metric,
            )
        )  # (B, R)
        stats.hops += float(np.asarray(istats.hops).sum())
        stats.cmps += float(np.asarray(istats.cmps).sum())

        rows = np.full((len(slots), cfg.R_slack), -1, np.int32)
        rows[:, : cfg.R] = nbrs
        self.pv.set_neighbors(self.ctx, slots, rows)

        # group reverse edges by target: ONE consolidated append per node —
        # the Bw-Tree "no duplicate patch for a key" contract (§2.1)
        rev: dict[int, list[int]] = {}
        for i, s in enumerate(slots):
            for b in nbrs[i]:
                if b >= 0 and b != s:
                    rev.setdefault(int(b), []).append(int(s))
        overflow: list[int] = []
        for b, ps in rev.items():
            row = self.pv.neighbors[b]
            existing = set(int(x) for x in row[row >= 0])
            ps = [p for p in dict.fromkeys(ps) if p not in existing]
            if not ps:
                continue
            fitted = self.pv.append_neighbors(self.ctx, b, np.asarray(ps, np.int32))
            if fitted < len(ps):
                row = self.pv.neighbors[b].copy()
                merged = list(dict.fromkeys(list(row[row >= 0]) + ps))
                self._prune_node(b, np.asarray(merged, np.int64))
                overflow.append(b)

    def _decoded(self, ids: np.ndarray) -> np.ndarray:
        """Quantized-space coordinates for pruning (§3.2)."""
        codes, versions = self.pv.get_quant(self.ctx, ids)
        out = np.zeros((len(ids), self.dim), np.float32)
        for v, schema in enumerate(self.schemas):
            m = versions == v
            if m.any():
                out[m] = np.asarray(pqmod.decode(schema, jnp.asarray(codes[m])))
        return out

    def _prune_node(self, node: int, cand: np.ndarray):
        cfg = self.cfg
        cap = cfg.R_slack + cfg.batch_size
        cand = cand[:cap]
        ids = np.full((cap,), -1, np.int64)
        ids[: len(cand)] = cand
        live_mask = self.pv.live[np.maximum(ids, 0)] & (ids >= 0)
        ids = np.where(live_mask, ids, -1)
        pruned = np.asarray(
            prmod.prune_with_vectors(
                jnp.asarray(self._decoded(np.asarray([node]))[0]),
                jnp.asarray(ids.astype(np.int32)),
                jnp.asarray(self._decoded(np.maximum(ids, 0))),
                alpha=cfg.alpha,
                R=cfg.R,
                metric=cfg.metric,
                self_id=node,
            )
        )
        row = np.full((cfg.R_slack,), -1, np.int32)
        row[: cfg.R] = pruned
        self.pv.set_neighbors(self.ctx, np.asarray([node]), row[None, :])

    def _replace_one(self, doc_id: int, vec: np.ndarray):
        slot = self.doc_to_slot[doc_id]
        self.pv.set_full(self.ctx, np.asarray([slot]), vec[None, :])
        self.pv.barrier("upsert:post_full")
        if self.schemas:
            codes = np.asarray(pqmod.encode(self.schemas[-1], jnp.asarray(vec[None, :])))
            self.pv.set_quant(
                self.ctx, np.asarray([slot]), codes,
                np.asarray([len(self.schemas) - 1], np.uint8),
            )
        if self._graph_built:
            st = QueryStats()
            self._graph_insert(np.asarray([slot]), vec[None, :], st)

    # ------------------------------------------------------------------
    # re-quantization (§3.4)
    # ------------------------------------------------------------------
    def requantize(self):
        """Refine the PQ schema from a larger sample; terms re-encode in
        place (background chunks via requantize_step); the graph is NOT
        rebuilt — old/new codes coexist through versioned LUTs."""
        n = min(self.count, self.cfg.refine_sample)
        sample = self.pv.vectors[:n]
        refined = pqmod.refine_pq(self._next_key(), self.schemas[-1], jnp.asarray(sample))
        self.schemas = [self.schemas[-1], refined][-2:]
        self._requant_cursor = 0

    def requantize_step(self, chunk: int = 4096) -> bool:
        """Re-encode one chunk with the newest schema. True when done."""
        if len(self.schemas) < 2:
            return True
        lo = self._requant_cursor
        hi = min(lo + chunk, self.count)
        if lo >= hi:
            # transition complete: retire the old schema
            self.schemas = [self.schemas[-1]]
            self.pv.versions[: self.count] = 0
            self.pv._dirty()
            return True
        ids = np.arange(lo, hi)
        codes = np.asarray(
            pqmod.encode(self.schemas[-1], jnp.asarray(self.pv.vectors[ids]))
        )
        self.pv.set_quant(self.ctx, ids, codes, np.full(len(ids), 1, np.uint8))
        self._requant_cursor = hi
        return False

    def requantize_all(self):
        while not self.requantize_step():
            pass

    # ------------------------------------------------------------------
    # deletion (Alg 6) + background consolidation
    # ------------------------------------------------------------------
    def delete(self, doc_ids: Sequence[int], policy: str = "inplace"):
        cfg = self.cfg
        for d in doc_ids:
            slot = self.doc_to_slot.pop(int(d), None)
            if slot is None:
                continue
            self.slot_to_doc[slot] = -1
            self.pv.set_live(self.ctx, np.asarray([slot]), False)
            if policy == "inplace" and self._graph_built:
                neighbors, _, _, live, _ = self.pv.materialize(self.ctx)
                old_nb = np.array(neighbors)  # copy: kernel donates its input
                decoded = jnp.asarray(self._decoded(np.arange(self.count)))
                pad = jnp.zeros((cfg.capacity - self.count, self.dim), jnp.float32)
                new_nb = dmod.inplace_delete(
                    neighbors, live, jnp.concatenate([decoded, pad]),
                    jnp.int32(slot),
                    R=cfg.R, R_slack=cfg.R_slack, alpha=cfg.alpha,
                    c_replace=cfg.c_replace, metric=cfg.metric,
                )
                self._write_neighbor_diff(old_nb, np.asarray(new_nb))
            if slot == self.medoid and self.num_live:
                self.medoid = int(
                    g.compute_medoid(
                        jnp.asarray(self.pv.vectors), jnp.asarray(self.pv.live)
                    )
                )

    def recompute_medoid(self):
        """Start-point maintenance (FreshDiskANN practice): after heavy
        churn the medoid should track the live distribution."""
        if self.num_live:
            self.medoid = int(
                g.compute_medoid(jnp.asarray(self.pv.vectors), jnp.asarray(self.pv.live))
            )

    def consolidate(self, chunk: int = 1024):
        """One background-sweep step: clear dangling edges to dead nodes."""
        neighbors, _, _, live, _ = self.pv.materialize(self.ctx)
        old_nb = np.array(neighbors)  # copy: kernel donates its input
        new_nb = dmod.consolidate_chunk(
            neighbors, live, jnp.int32(self._consolidate_cursor), chunk
        )
        self._write_neighbor_diff(old_nb, np.asarray(new_nb))
        self._consolidate_cursor = (self._consolidate_cursor + chunk) % max(self.count, 1)

    def _write_neighbor_diff(self, old_nb: np.ndarray, new_nb: np.ndarray):
        """Write only the rows a graph repair changed, through the provider.

        Durable providers log `set_neighbors` to their WAL; a direct
        whole-array store would leave the repair invisible to replay, so
        recovery would resurrect dangling edges the repair had cleared.
        """
        changed = np.nonzero((old_nb != new_nb).any(axis=1))[0]
        if changed.size:
            self.pv.set_neighbors(self.ctx, changed, new_nb[changed])
        # the repair kernels donate the provider's cached device buffer, so
        # the materialize cache is stale even when no row changed
        self.pv._dirty()

    # ------------------------------------------------------------------
    # queries (§3.5)
    # ------------------------------------------------------------------
    def search(
        self,
        queries: np.ndarray,
        k: int,
        L: Optional[int] = None,
        rerank_multiplier: float = fmod.QUANTIZED_LIST_MULTIPLIER,
        pad_to_bucket: bool = False,
        batch_buckets: tuple[int, ...] = smod.BATCH_BUCKETS,
        beam_width: Optional[int] = None,
    ) -> tuple[np.ndarray, np.ndarray, QueryStats]:
        """Top-k ANN: graph search in quantized space + full-precision
        re-rank. Returns (doc_ids (B,k), dists (B,k), stats).

        With ``pad_to_bucket`` the query batch is padded to the next static
        bucket before any jitted stage (LUTs, graph search, re-rank) so the
        serving layer's varying batch sizes map onto a handful of compiled
        signatures; outputs and stats are sliced back to the true batch.
        ``beam_width`` overrides the config's W (frontier nodes expanded
        per round); None → ``cfg.beam_width``.
        """
        W = int(beam_width or self.cfg.beam_width)
        queries = np.asarray(queries, np.float32)
        B = len(queries)
        if pad_to_bucket:
            queries = smod.pad_batch_np(queries, smod.next_bucket(B, batch_buckets))
        L = L or self.cfg.L_search
        stats = QueryStats()
        kprime = max(k, int(round(rerank_multiplier * k)))

        if not self._graph_built:
            stats.plan = "brute_force"
            neighbors, codes, versions, live, vectors = self.pv.materialize(self.ctx)
            ids, dists = fmod.brute_force(
                jnp.asarray(queries), vectors, live, k=k, metric=self.cfg.metric
            )
            stats.full_reads = self.num_live
            # a full sweep reads every live page once for the whole
            # batch; scan-resistant (admit=False) so it can't flush the
            # rerank working set
            self._touch_tier(np.nonzero(self.pv.live)[0], stats, B,
                             admit=False)
            return (
                self._to_doc_ids(np.asarray(ids))[:B],
                np.asarray(dists)[:B],
                stats,
            )

        neighbors, codes, versions, live, vectors = self.pv.materialize(self.ctx)
        luts = self._luts(queries)
        L_eff = max(L, kprime)
        # queries are already bucket-padded above when pad_to_bucket is set,
        # so the wrapper's own pad is a no-op then; it still normalizes any
        # direct unpadded call onto the same static signatures
        res = smod.bucketed_batch_greedy_search(
            neighbors, codes, versions, live, luts, jnp.int32(self.medoid),
            L=L_eff, batch_buckets=batch_buckets, beam_width=W,
        )
        # final rerank is the ONLY stage that reads full-precision
        # vectors: pin the candidate pages (they must not be evicted
        # mid-rerank), fetch misses, release after
        pinned = self._touch_tier(
            np.asarray(res.beam_ids)[:B, :kprime], stats, B, pin=True)
        ids, dists = fmod.rerank(
            jnp.asarray(queries), res.beam_ids[:, :kprime], vectors,
            k=k, metric=self.cfg.metric,
        )
        self._unpin_tier(pinned)
        stats.hops = float(np.asarray(res.n_hops)[:B].mean())
        stats.cmps = float(np.asarray(res.n_cmps)[:B].mean())
        stats.expansions = float(np.asarray(res.n_exp)[:B].mean())
        stats.full_reads = float(kprime)
        return self._to_doc_ids(np.asarray(ids))[:B], np.asarray(dists)[:B], stats

    def _to_doc_ids(self, slots: np.ndarray) -> np.ndarray:
        out = np.where(slots >= 0, self.slot_to_doc[np.maximum(slots, 0)], -1)
        return out

    # -- filtered queries (§3.5, Fig 9) ---------------------------------
    def filtered_search(
        self,
        queries: np.ndarray,
        k: int,
        doc_filter: np.ndarray,  # bool over doc slots (the PES bitmap role)
        L: Optional[int] = None,
        mode: str = "auto",  # auto | post | beta | qflat | brute
        beta: float = 0.3,
        rerank_multiplier: float = fmod.QUANTIZED_LIST_MULTIPLIER,
        beam_width: Optional[int] = None,
        pad_to_bucket: bool = False,
        batch_buckets: tuple[int, ...] = smod.BATCH_BUCKETS,
        filter_words: Optional[np.ndarray] = None,
    ) -> tuple[np.ndarray, np.ndarray, QueryStats]:
        """Query-planner routing by selectivity, then post-filter or
        β-biased graph search.

        With ``pad_to_bucket`` the micro-batch pads to the next static
        bucket before any jitted stage — the serving engine's batched
        filtered path (same-predicate queries share one bitmap broadcast)
        reuses the exact (bucket, L, W) signature set as unfiltered
        serving, so steady-state filtered traffic triggers zero
        recompiles. Outputs and stats slice back to the true batch.
        ``filter_words`` optionally supplies ``doc_filter`` pre-packed in
        the uint32 ``filter_bits`` layout (the predicate compiler's native
        output), skipping the β-branch re-pack."""
        W = int(beam_width or self.cfg.beam_width)
        queries = np.asarray(queries, np.float32)
        B = len(queries)
        if pad_to_bucket:
            queries = smod.pad_batch_np(
                queries, smod.next_bucket(B, batch_buckets)
            )
        L = L or self.cfg.L_search
        matches = int((doc_filter & self.pv.live).sum())
        stats = QueryStats()
        if mode == "auto":
            if self.num_live <= fmod.BRUTE_FORCE_MAX_DOCS or not self._graph_built:
                mode = "brute"
            elif matches < fmod.QFLAT_MAX_MATCHES:
                mode = "qflat"
            else:
                mode = "beta"
        stats.plan = mode
        kprime = max(k, int(round(rerank_multiplier * k)))
        neighbors, codes, versions, live, vectors = self.pv.materialize(self.ctx)
        fmask = jnp.asarray(doc_filter & self.pv.live)

        if mode == "brute":
            ids, dists = fmod.brute_force(
                jnp.asarray(queries), vectors, fmask, k=k, metric=self.cfg.metric
            )
            stats.full_reads = matches
            self._touch_tier(np.nonzero(doc_filter & self.pv.live)[0],
                             stats, B, admit=False)
            return (self._to_doc_ids(np.asarray(ids))[:B],
                    np.asarray(dists)[:B], stats)

        if mode == "qflat":
            luts = self._luts(queries)
            cand, _ = fmod.qflat_scan(
                luts, codes, versions, fmask, kprime=kprime, metric=self.cfg.metric
            )
            pinned = self._touch_tier(np.asarray(cand)[:B], stats, B,
                                      pin=True)
            ids, dists = fmod.rerank(
                jnp.asarray(queries), cand, vectors, k=k, metric=self.cfg.metric
            )
            self._unpin_tier(pinned)
            stats.cmps = matches
            stats.full_reads = kprime
            return (self._to_doc_ids(np.asarray(ids))[:B],
                    np.asarray(dists)[:B], stats)

        luts = self._luts(queries)
        if mode == "post":
            res = smod.bucketed_batch_greedy_search(
                neighbors, codes, versions, live, luts, jnp.int32(self.medoid),
                L=max(L, kprime), batch_buckets=batch_buckets, beam_width=W,
            )
            beam = np.asarray(res.beam_ids)
            passes = doc_filter[np.maximum(beam, 0)] & (beam >= 0)
            beam = np.where(passes, beam, -1)
        else:  # beta (Alg 7)
            fbits = (filter_words if filter_words is not None
                     else self._pack_bits(np.asarray(doc_filter)))
            fb = jnp.asarray(
                np.broadcast_to(fbits, (len(queries),) + fbits.shape)
            )
            res = smod.bucketed_batch_greedy_search(
                neighbors, codes, versions, live, luts, jnp.int32(self.medoid),
                L=max(L, kprime), batch_buckets=batch_buckets,
                filter_bits=fb, beta=beta, beam_width=W,
            )
            beam = np.asarray(res.beam_ids)
            passes = doc_filter[np.maximum(beam, 0)] & (beam >= 0)
            beam = np.where(passes, beam, -1)
        pinned = self._touch_tier(beam[:B, : max(L, kprime)], stats, B,
                                  pin=True)
        ids, dists = fmod.rerank(
            jnp.asarray(queries), jnp.asarray(beam[:, : max(L, kprime)]), vectors,
            k=k, metric=self.cfg.metric,
        )
        self._unpin_tier(pinned)
        stats.hops = float(np.asarray(res.n_hops)[:B].mean())
        stats.cmps = float(np.asarray(res.n_cmps)[:B].mean())
        stats.expansions = float(np.asarray(res.n_exp)[:B].mean())
        stats.full_reads = float(kprime)
        return (self._to_doc_ids(np.asarray(ids))[:B],
                np.asarray(dists)[:B], stats)

    @staticmethod
    def _pack_bits(mask: np.ndarray) -> np.ndarray:
        words = np.zeros(((len(mask) + 31) // 32,), np.uint32)
        idx = np.nonzero(mask)[0]
        np.bitwise_or.at(words, idx >> 5, np.uint32(1) << (idx & 31).astype(np.uint32))
        return words

    # -- pagination (§3.2 / §3.5 Continuations) ---------------------------
    def start_pagination(self, query: np.ndarray, L: Optional[int] = None,
                         backup_cap: int = PAGE_BACKUP_CAP) -> pgmod.PageState:
        L = L or self.cfg.L_search
        _, codes, versions, _, _ = self.pv.materialize(self.ctx)
        lut = self._luts(query[None, :])[0]
        return pgmod.start_pagination(
            self.cfg.capacity, L, backup_cap, codes, versions, lut,
            jnp.int32(self.medoid),
        )

    @staticmethod
    def page_stats(prev: pgmod.PageState, new: pgmod.PageState, k: int,
                   rerank: bool = True) -> QueryStats:
        """Per-page work delta from the cumulative PageState counters —
        feeds the same ``counters_for_ru`` / ``counters_for_latency`` split
        as the main search path, so a page is billed for the quantized
        comparisons and adjacency rows it actually fetched plus the k
        full-precision re-rank reads (a page is never free)."""
        return QueryStats(
            hops=float(int(new.hops) - int(prev.hops)),
            cmps=float(int(new.cmps) - int(prev.cmps)),
            expansions=float(int(new.exp) - int(prev.exp)),
            full_reads=float(k if rerank else 0),
            plan="paginated",
        )

    def next_page(
        self, query: np.ndarray, state: pgmod.PageState, k: int,
        rerank: bool = True, beam_width: Optional[int] = None,
        slot_filter: Optional[np.ndarray] = None,  # bool over doc slots
    ) -> tuple[np.ndarray, np.ndarray, pgmod.PageState]:
        """One page of k results. With ``slot_filter`` (a compiled predicate
        bitmap) non-matching slots are dropped from the page AFTER the
        traversal step, so the visited set still advances and later pages
        surface the matches the traversal hasn't reached yet — a filtered
        page may carry fewer than k rows, but the stream stays
        gap-free/repeat-free (the fan-out merge refetches empty pages)."""
        neighbors, codes, versions, live, vectors = self.pv.materialize(self.ctx)
        lut = self._luts(query[None, :])[0]
        ids, dists, state = pgmod.next_page(
            neighbors, codes, versions, live, lut, state, k=k,
            beam_width=int(beam_width or self.cfg.beam_width),
        )
        if slot_filter is not None:
            arr = np.asarray(ids)
            keep = (arr >= 0) & slot_filter[np.maximum(arr, 0)]
            ids = jnp.asarray(np.where(keep, arr, -1))
            dists = jnp.asarray(np.where(keep, np.asarray(dists), np.inf))
        self.last_page_tier = (0.0, 0.0)
        if rerank:
            tst = QueryStats()
            pinned = self._touch_tier(np.asarray(ids), tst, 1, pin=True)
            rids, rd = fmod.rerank(
                jnp.asarray(query[None, :]), ids[None, :], vectors,
                k=k, metric=self.cfg.metric,
            )
            self._unpin_tier(pinned)
            self.last_page_tier = (tst.tier_hits, tst.tier_misses)
            return self._to_doc_ids(np.asarray(rids))[0], np.asarray(rd)[0], state
        return self._to_doc_ids(np.asarray(ids[None, :]))[0], np.asarray(dists), state

    # ------------------------------------------------------------------
    # persistence (fault tolerance)
    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        return dict(
            neighbors=self.pv.neighbors.copy(),
            codes=self.pv.codes.copy(),
            versions=self.pv.versions.copy(),
            live=self.pv.live.copy(),
            vectors=self.pv.vectors.copy(),
            slot_to_doc=self.slot_to_doc.copy(),
            count=self.count,
            medoid=self.medoid,
            schemas=[np.asarray(s.codebooks) for s in self.schemas],
            graph_built=self._graph_built,
        )

    def restore(self, snap: dict):
        self.pv.neighbors[:] = snap["neighbors"]
        self.pv.codes[:] = snap["codes"]
        self.pv.versions[:] = snap["versions"]
        self.pv.live[:] = snap["live"]
        self.pv.vectors[:] = snap["vectors"]
        self.pv._dirty()
        self.slot_to_doc[:] = snap["slot_to_doc"]
        self.count = snap["count"]
        self.medoid = snap["medoid"]
        self.schemas = [
            pqmod.PQSchema(codebooks=jnp.asarray(cb), version=jnp.int32(i))
            for i, cb in enumerate(snap["schemas"])
        ]
        self._graph_built = snap["graph_built"]
        self.doc_to_slot = {
            int(d): int(s) for s, d in enumerate(self.slot_to_doc) if d >= 0
        }
