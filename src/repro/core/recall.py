"""Ground truth + Recall k@k (§2.1: "how many of the k results returned by a
search are the true top-k nearest neighbors")."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import pq as pqmod


def ground_truth(
    queries: np.ndarray, vectors: np.ndarray, live: np.ndarray, k: int, metric: str = "l2"
) -> np.ndarray:
    """Exact top-k ids per query, (B, k)."""
    q = jnp.asarray(queries)
    v = jnp.asarray(vectors)
    d = pqmod.pairwise_distance(q, v, metric)
    d = jnp.where(jnp.asarray(live)[None, :], d, jnp.inf)
    _, idx = jax.lax.top_k(-d, k)
    return np.asarray(idx)


def recall_at_k(result_ids: np.ndarray, gt_ids: np.ndarray, k: int) -> float:
    """Average |result ∩ gt| / k over the query batch."""
    res = np.asarray(result_ids)[:, :k]
    gt = np.asarray(gt_ids)[:, :k]
    hits = 0
    for r, t in zip(res, gt):
        hits += len(set(int(x) for x in r if x >= 0) & set(int(x) for x in t))
    return hits / (len(res) * k)
