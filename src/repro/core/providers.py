"""Provider traits — the paper's stateless-DiskANN interface (§3.1).

The 2025 rewrite's core idea: the index layout is not visible to the
algorithms. The library reads/writes *index terms* — quantized vectors,
full-precision vectors, neighbor lists — through Provider implementations
owned by the database, addressed by an execution ``Context`` that selects
the target replica/collection (one DiskANN instance serves many indices).

Here the jitted algorithms consume dense arrays (the Bw-Tree page cache's
role), and Providers define where those arrays come from and where updates
are persisted:

  * ``ArrayProviderSet`` — memory-backed terms ("the new library is at least
    as fast as the previous monolithic DiskANN" — §3.1): numpy canonical
    state + a cached jnp materialization for the query path.
  * ``StoreProviderSet`` (repro.store.provider) — terms encoded in the
    Bw-Tree analogue, with RU metering; write-through into the array cache.

The async MaybeDone future of the Rust rewrite has no TPU analogue (device
steps are synchronous); its *purpose* — overlapping slow term fetches —
reappears as batched gathers, and the latency asymmetry it hides is captured
by the RU/latency model in ``repro.store.ru``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Protocol

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Context:
    """Execution context (§3.1): identifies the logical index a call targets
    and carries telemetry identity. The database (not the library) interprets
    it; our store uses it to select term-key prefixes and meter RUs."""

    collection: str = "default"
    replica: int = 0
    shard_key: Optional[int] = None  # sharded-DiskANN logical index (§3.3)
    activity_id: str = ""
    lsn: int = 0


class ProviderSet(Protocol):
    """The union of the paper's Neighbor/QuantVector/FullVector providers."""

    def get_neighbors(self, ctx: Context, ids: np.ndarray) -> np.ndarray: ...
    def set_neighbors(self, ctx: Context, ids: np.ndarray, rows: np.ndarray) -> None: ...
    def append_neighbors(self, ctx: Context, node: int, new_ids: np.ndarray) -> None: ...
    def get_quant(self, ctx: Context, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]: ...
    def set_quant(self, ctx: Context, ids: np.ndarray, codes: np.ndarray, versions: np.ndarray) -> None: ...
    def get_full(self, ctx: Context, ids: np.ndarray) -> np.ndarray: ...
    def set_full(self, ctx: Context, ids: np.ndarray, vecs: np.ndarray) -> None: ...
    def set_live(self, ctx: Context, ids: np.ndarray, value: bool) -> None: ...
    def materialize(self, ctx: Context): ...
    def barrier(self, name: str) -> None: ...


class ArrayProviderSet:
    """Memory-backed providers: numpy canonical state, jnp cache for jit."""

    def __init__(self, capacity: int, R_slack: int, M: int, dim: int):
        # deferred import: store.provider subclasses this module, so a
        # top-level import of store.pages would be circular
        from repro.store.pages import PagedVectorStore

        self.neighbors = np.full((capacity, R_slack), -1, np.int32)
        self.codes = np.zeros((capacity, M), np.uint8)
        self.versions = np.zeros((capacity,), np.uint8)
        self.live = np.zeros((capacity,), bool)
        self.vectors = np.zeros((capacity, dim), np.float32)
        # tiered residency ledger for the full-precision tier (ISSUE 10):
        # budget=None → fully resident → bit-identical pre-tier behaviour
        self.pages = PagedVectorStore(capacity, dim)
        self._cache = None  # jnp materialization
        self.write_count = 0

    def barrier(self, name: str) -> None:
        """Named crash-barrier hook; no-op without an attached FaultPlan
        (StoreProviderSet overrides with the armed version)."""

    # -- invalidation ------------------------------------------------------
    def _dirty(self):
        self._cache = None
        self.write_count += 1

    def materialize(self, ctx: Context = Context()):
        """jnp views of (neighbors, codes, versions, live, vectors) for the
        jitted query/update kernels; rebuilt only after writes."""
        if self._cache is None:
            self._cache = (
                jnp.asarray(self.neighbors),
                jnp.asarray(self.codes),
                jnp.asarray(self.versions),
                jnp.asarray(self.live),
                jnp.asarray(self.vectors),
            )
        return self._cache

    # -- neighbor terms ------------------------------------------------------
    def get_neighbors(self, ctx: Context, ids):
        return self.neighbors[np.asarray(ids)]

    def set_neighbors(self, ctx: Context, ids, rows):
        self.neighbors[np.asarray(ids)] = rows
        self._dirty()

    def append_neighbors(self, ctx: Context, node: int, new_ids):
        """Blind incremental append (the Bw-Tree forward-term fast path)."""
        row = self.neighbors[node]
        deg = int((row >= 0).sum())
        n = min(len(new_ids), row.shape[0] - deg)
        row[deg : deg + n] = new_ids[:n]
        self._dirty()
        return n  # how many fit; caller prunes on overflow

    # -- quantized terms ---------------------------------------------------
    def get_quant(self, ctx: Context, ids):
        ids = np.asarray(ids)
        return self.codes[ids], self.versions[ids]

    def set_quant(self, ctx: Context, ids, codes, versions):
        ids = np.asarray(ids)
        self.codes[ids] = codes
        self.versions[ids] = versions
        self._dirty()

    # -- full-precision vectors (document store role) ----------------------
    def get_full(self, ctx: Context, ids):
        return self.vectors[np.asarray(ids)]

    def set_full(self, ctx: Context, ids, vecs):
        self.vectors[np.asarray(ids)] = vecs
        self._dirty()

    def set_live(self, ctx: Context, ids, value: bool):
        self.live[np.asarray(ids)] = value
        self._dirty()
