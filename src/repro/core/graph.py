"""Graph index state — the DiskANN index terms, decoupled from the algorithms.

The paper's central systems idea (§3.1) is that the DiskANN *algorithms* do
not own the index layout: quantized-vector terms and adjacency-list terms are
read/written through Provider traits, and the database owns persistence.

In this JAX port the "materialized cache" of those terms is a pytree of dense
arrays (`GraphState`) — the form the jitted kernels consume — while
``repro.store`` holds the durable Bw-Tree-analogue encoding of the very same
terms. ``providers.py`` bridges the two.

Conventions:
  * capacity-bounded arrays: N_max rows, a `count` watermark, `live` mask;
  * `neighbors` is (N_max, R_slack) int32, padded with -1;
  * `codes` is (N_max, M) uint8 PQ codes; `versions` tags the PQ schema used
    for each row (re-quantization support, §3.4);
  * `medoid` is the graph entry point (start node s in Algorithms 1-6).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import pq as pqmod


class GraphConfig(NamedTuple):
    """Static index configuration (paper defaults from §4 "Configuration")."""

    capacity: int
    R: int = 32  # degree bound
    slack: float = 1.3  # degree slack before a secondary prune (§4)
    L_build: int = 100  # search list size during construction
    L_search: int = 100  # default search list size for queries
    alpha: float = 1.2  # RobustPrune distance threshold
    M: int = 16  # PQ subspaces (navigation compression)
    metric: str = "l2"
    max_visits: int = 4096  # visited-set capacity for search stats
    batch_size: int = 100  # mini-batch insert size (§2.1: "about 100")
    bootstrap_sample: int = 1000  # §3.4: first PQ schema after this many docs
    refine_sample: int = 25000  # §3.4: re-quantization trigger
    c_replace: int = 3  # Alg 6 replace parameter
    beam_width: int = 4  # query-path beamWidth W (§3.2): frontier nodes
    #   expanded per search round; tuned ~4 — cuts sequential rounds ~W×
    #   at a modest n_cmps increase (see core/search.py)

    @property
    def R_slack(self) -> int:
        return int(self.R * self.slack)


class GraphState(NamedTuple):
    """The mutable index terms as dense arrays (the jit-side cache)."""

    neighbors: jax.Array  # (N_max, R_slack) int32, -1 padded
    codes: jax.Array  # (N_max, M) uint8
    versions: jax.Array  # (N_max,) uint8 PQ schema version per row
    live: jax.Array  # (N_max,) bool
    count: jax.Array  # () int32 high-watermark of allocated slots
    medoid: jax.Array  # () int32 start node

    @property
    def capacity(self) -> int:
        return self.neighbors.shape[0]


def empty_state(cfg: GraphConfig) -> GraphState:
    return GraphState(
        neighbors=jnp.full((cfg.capacity, cfg.R_slack), -1, dtype=jnp.int32),
        codes=jnp.zeros((cfg.capacity, cfg.M), dtype=jnp.uint8),
        versions=jnp.zeros((cfg.capacity,), dtype=jnp.uint8),
        live=jnp.zeros((cfg.capacity,), dtype=bool),
        count=jnp.int32(0),
        medoid=jnp.int32(0),
    )


def degree(state: GraphState) -> jax.Array:
    """Out-degree per node."""
    return (state.neighbors >= 0).sum(axis=-1)


def num_live(state: GraphState) -> jax.Array:
    return state.live.sum()


def compute_medoid(vectors: jax.Array, live: jax.Array) -> jax.Array:
    """Pick the live vector closest to the live centroid as the start node."""
    w = live.astype(vectors.dtype)
    centroid = (vectors * w[:, None]).sum(0) / jnp.maximum(w.sum(), 1.0)
    d = jnp.sum((vectors - centroid) ** 2, -1)
    d = jnp.where(live, d, jnp.inf)
    return jnp.argmin(d).astype(jnp.int32)


# -- packed visited bitmap ---------------------------------------------------
# Alg 1 needs the set V of visited nodes for dedup. On TPU we keep it as a
# packed uint32 bitmap (capacity/32 words) — O(N/8) bytes, constant-time
# test/set via shifts, vmappable across a query batch.


def bitmap_words(capacity: int) -> int:
    return (capacity + 31) // 32


def bitmap_init(capacity: int) -> jax.Array:
    return jnp.zeros((bitmap_words(capacity),), dtype=jnp.uint32)


def bitmap_test(bm: jax.Array, ids: jax.Array) -> jax.Array:
    """ids (K,) int32 -> (K,) bool. ids < 0 report True (treated as seen)."""
    safe = jnp.maximum(ids, 0)
    word = bm[safe >> 5]
    bit = (word >> (safe.astype(jnp.uint32) & 31)) & 1
    return jnp.where(ids < 0, True, bit.astype(bool))


def bitmap_set(bm: jax.Array, ids: jax.Array) -> jax.Array:
    """OR bits for ids (K,) into bm; ids < 0 are ignored. Duplicate-safe.

    K is small on the hot path (one adjacency list, ≤ R_slack), so a
    sequential fori OR is cheap and avoids the scatter-OR-with-duplicates
    hazard (two ids mapping to the same word must not lose bits).
    """
    safe = jnp.maximum(ids, 0)
    words = safe >> 5
    masks = jnp.where(ids < 0, jnp.uint32(0), jnp.uint32(1) << (safe.astype(jnp.uint32) & 31))

    def body(i, acc):
        return acc.at[words[i]].set(acc[words[i]] | masks[i])

    return jax.lax.fori_loop(0, ids.shape[0], body, bm)
