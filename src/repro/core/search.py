"""GreedySearch (Algorithm 1) — batched, fixed-shape, TPU-native.

The paper's search walks the graph one hop at a time with async SSD reads.
On TPU we keep the L-entry search list ("beam") as a sorted array, expand the
best unexpanded node each `lax.while_loop` step, and do all neighbor
processing (visited-set dedup, ADC distances, beam merge) as vectorized ops.
Queries are batched with `vmap`; all lanes advance in lockstep until every
lane's beam is fully expanded.

Search runs in *quantized space* (§3.2): distances come from per-query ADC
LUTs against the uint8 PQ codes; full-precision vectors are only touched by
the re-rank stage (``repro.core.flat.rerank``), preserving the paper's ≈70×
access-frequency asymmetry.

Filter-aware (β) search — Algorithm 7 — is folded in: when a packed filter
bitmap is supplied, distances of filter-passing nodes are scaled by β < 1 so
the frontier drifts toward the filtered region (§3.5, Fig 9).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import graph as g
from . import pq as pqmod

INF = jnp.float32(jnp.inf)


class SearchResult(NamedTuple):
    beam_ids: jax.Array  # (L,) int32, ascending distance, -1 padded
    beam_dists: jax.Array  # (L,) f32 (quantized-space, β-scaled if filtered)
    visited_ids: jax.Array  # (V,) int32 expanded nodes in order, -1 padded
    visited_dists: jax.Array  # (V,) f32
    n_hops: jax.Array  # () int32 — number of expansions
    n_cmps: jax.Array  # () int32 — number of quantized distance comps


class _LoopState(NamedTuple):
    ids: jax.Array
    dists: jax.Array
    expanded: jax.Array
    bitmap: jax.Array
    visited_ids: jax.Array
    visited_dists: jax.Array
    hops: jax.Array
    cmps: jax.Array


def _mask_dup_within(ids: jax.Array) -> jax.Array:
    """True where ids[i] duplicates an earlier entry (ids small: R_slack)."""
    eq = ids[:, None] == ids[None, :]
    earlier = jnp.tril(jnp.ones_like(eq), k=-1)
    return jnp.any(eq & earlier.astype(bool), axis=1)


def _expand_once(
    st: _LoopState,
    neighbors: jax.Array,
    codes: jax.Array,
    versions: jax.Array,
    live: jax.Array,
    luts: jax.Array,
    filter_bits: Optional[jax.Array],
    beta: jax.Array,
) -> _LoopState:
    """Expand the best unexpanded beam entry; merge its neighbors in."""
    L = st.ids.shape[0]
    masked = jnp.where(st.expanded | (st.ids < 0), INF, st.dists)
    p_idx = jnp.argmin(masked)
    p = st.ids[p_idx]
    expanded = st.expanded.at[p_idx].set(True)

    visited_ids = st.visited_ids.at[st.hops % st.visited_ids.shape[0]].set(p)
    visited_dists = st.visited_dists.at[st.hops % st.visited_ids.shape[0]].set(st.dists[p_idx])

    nbrs = neighbors[jnp.maximum(p, 0)]  # (R_slack,)
    safe = jnp.maximum(nbrs, 0)
    valid = (nbrs >= 0) & live[safe] & ~g.bitmap_test(st.bitmap, nbrs)
    valid &= ~_mask_dup_within(nbrs)
    bitmap = g.bitmap_set(st.bitmap, jnp.where(valid, nbrs, -1))

    cand_codes = codes[safe]  # (R_slack, M)
    cand_ver = versions[safe]
    d = pqmod.adc_distance_versioned(luts, cand_codes, cand_ver)  # (R_slack,)
    if filter_bits is not None:
        passes = g.bitmap_test(filter_bits, jnp.where(nbrs >= 0, nbrs, 0)) & (nbrs >= 0)
        d = jnp.where(passes, beta * d, d)
    d = jnp.where(valid, d, INF)

    all_ids = jnp.concatenate([st.ids, jnp.where(valid, nbrs, -1)])
    all_d = jnp.concatenate([st.dists, d])
    all_e = jnp.concatenate([expanded, jnp.zeros_like(valid)])
    order = jnp.argsort(all_d)[:L]
    return _LoopState(
        ids=all_ids[order],
        dists=all_d[order],
        expanded=all_e[order],
        bitmap=bitmap,
        visited_ids=visited_ids,
        visited_dists=visited_dists,
        hops=st.hops + 1,
        cmps=st.cmps + valid.sum(),
    )


@functools.partial(
    jax.jit, static_argnames=("L", "max_hops", "visited_cap", "has_filter")
)
def greedy_search(
    neighbors: jax.Array,
    codes: jax.Array,
    versions: jax.Array,
    live: jax.Array,
    luts: jax.Array,  # (Vschemas, M, K) from pq.multi_lut
    start: jax.Array,  # () int32
    *,
    L: int,
    max_hops: int = 0,
    visited_cap: int = 0,
    has_filter: bool = False,
    filter_bits: Optional[jax.Array] = None,
    beta: jax.Array | float = 1.0,
) -> SearchResult:
    """Single-query GreedySearch. vmap over (luts, filter_bits) for batches."""
    if max_hops == 0:
        max_hops = 2 * L + 16
    if visited_cap == 0:
        visited_cap = max_hops
    if not has_filter:
        filter_bits = None
    beta = jnp.float32(beta)
    cap = neighbors.shape[0]

    start_d = pqmod.adc_distance_versioned(
        luts, codes[start][None], versions[start][None]
    )[0]
    ids0 = jnp.full((L,), -1, jnp.int32).at[0].set(start)
    dists0 = jnp.full((L,), INF).at[0].set(start_d)
    expanded0 = jnp.ones((L,), bool).at[0].set(False)
    bm0 = g.bitmap_set(g.bitmap_init(cap), jnp.array([start], jnp.int32))

    st0 = _LoopState(
        ids=ids0,
        dists=dists0,
        expanded=expanded0,
        bitmap=bm0,
        visited_ids=jnp.full((visited_cap,), -1, jnp.int32),
        visited_dists=jnp.full((visited_cap,), INF),
        hops=jnp.int32(0),
        cmps=jnp.int32(1),
    )

    def cond(st: _LoopState):
        frontier = (~st.expanded) & (st.ids >= 0)
        return jnp.any(frontier) & (st.hops < max_hops)

    def body(st: _LoopState):
        return _expand_once(
            st, neighbors, codes, versions, live, luts, filter_bits, beta
        )

    st = jax.lax.while_loop(cond, body, st0)
    return SearchResult(
        beam_ids=st.ids,
        beam_dists=st.dists,
        visited_ids=st.visited_ids,
        visited_dists=st.visited_dists,
        n_hops=st.hops,
        n_cmps=st.cmps,
    )


@functools.partial(
    jax.jit, static_argnames=("L", "max_hops", "visited_cap", "has_filter")
)
def _batched_search_entry(
    neighbors, codes, versions, live, luts, start, filter_bits, beta,
    *, L: int, max_hops: int, visited_cap: int, has_filter: bool,
) -> SearchResult:
    """Top-level jitted vmap over ``greedy_search``.

    Being the outermost jit matters: its compile cache is keyed by the full
    (batch, L, …) signature, so ``jit_cache_size()`` is a truthful recompile
    counter for the serving hot path (an inner jit under vmap never sees its
    own cache populated — compilation happens in the pjit-primitive path).
    """
    fn = functools.partial(
        greedy_search, neighbors, codes, versions, live,
        L=L, max_hops=max_hops, visited_cap=visited_cap,
        has_filter=has_filter, beta=beta,
    )
    if has_filter:
        return jax.vmap(lambda lut, fb: fn(lut, start, filter_bits=fb))(luts, filter_bits)
    return jax.vmap(lambda lut: fn(lut, start))(luts)


def batch_greedy_search(
    neighbors: jax.Array,
    codes: jax.Array,
    versions: jax.Array,
    live: jax.Array,
    luts: jax.Array,  # (B, Vschemas, M, K)
    start: jax.Array,
    *,
    L: int,
    max_hops: int = 0,
    visited_cap: int = 0,
    filter_bits: Optional[jax.Array] = None,  # (B, Nw) or None
    beta: float = 1.0,
) -> SearchResult:
    """vmapped GreedySearch over a query batch (lockstep beam expansion)."""
    has_filter = filter_bits is not None
    if not has_filter:
        # dummy with a stable shape so the jit signature doesn't churn
        filter_bits = jnp.zeros((luts.shape[0], 1), jnp.uint32)
    return _batched_search_entry(
        neighbors, codes, versions, live, luts, jnp.asarray(start, jnp.int32),
        filter_bits, jnp.float32(beta),
        L=L, max_hops=max_hops, visited_cap=visited_cap, has_filter=has_filter,
    )


def jit_cache_size() -> int:
    """Compiled-signature count of the batched-search entry (recompile
    telemetry for the serving layer; see serve/vector_engine.py)."""
    try:
        return int(_batched_search_entry._cache_size())
    except AttributeError:  # very old/new jit wrappers
        return -1


# ---------------------------------------------------------------------------
# shape bucketing — fixed (batch, L) signatures for the serving layer
# ---------------------------------------------------------------------------

BATCH_BUCKETS: tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64)


def next_bucket(n: int, buckets: tuple[int, ...] = BATCH_BUCKETS) -> int:
    """Smallest bucket ≥ n; beyond the largest, round up to a multiple of it
    (callers should split such batches, but never get a shape explosion)."""
    for b in buckets:
        if n <= b:
            return b
    top = buckets[-1]
    return ((n + top - 1) // top) * top


def pad_batch(arr: jax.Array, bucket: int) -> jax.Array:
    """Pad the leading (batch) axis to `bucket` by repeating row 0 — padded
    lanes redo real work so every lane stays numerically well-formed."""
    b = arr.shape[0]
    if b == bucket:
        return arr
    filler = jnp.broadcast_to(arr[:1], (bucket - b,) + arr.shape[1:])
    return jnp.concatenate([arr, filler], axis=0)


def pad_batch_np(arr: np.ndarray, bucket: int) -> np.ndarray:
    """Host-side twin of ``pad_batch`` — pads query batches before they
    enter any jitted stage (LUTs, search, re-rank share one bucket)."""
    b = len(arr)
    if b == bucket:
        return arr
    return np.concatenate(
        [arr, np.broadcast_to(arr[:1], (bucket - b,) + arr.shape[1:])]
    )


def bucketed_batch_greedy_search(
    neighbors: jax.Array,
    codes: jax.Array,
    versions: jax.Array,
    live: jax.Array,
    luts: jax.Array,  # (B, Vschemas, M, K)
    start: jax.Array,
    *,
    L: int,
    batch_buckets: tuple[int, ...] = BATCH_BUCKETS,
    max_hops: int = 0,
    visited_cap: int = 0,
    filter_bits: Optional[jax.Array] = None,
    beta: float = 1.0,
) -> SearchResult:
    """`batch_greedy_search` padded to a fixed batch bucket, results sliced
    back to the true batch — steady-state traffic whose batch sizes vary
    within one bucket reuses a single compiled executable (zero recompiles)."""
    B = luts.shape[0]
    bucket = next_bucket(B, batch_buckets)
    if bucket != B:
        luts = pad_batch(luts, bucket)
        if filter_bits is not None:
            filter_bits = pad_batch(filter_bits, bucket)
    res = batch_greedy_search(
        neighbors, codes, versions, live, luts, start,
        L=L, max_hops=max_hops, visited_cap=visited_cap,
        filter_bits=filter_bits, beta=beta,
    )
    if bucket != B:
        res = SearchResult(*(a[:B] for a in res))
    return res


def search_candidates(res: SearchResult) -> tuple[jax.Array, jax.Array]:
    """Union of expanded set and final beam — the prune candidate pool used
    by Insert (Algorithm 2 consumes the visited set V)."""
    ids = jnp.concatenate([res.visited_ids, res.beam_ids], axis=-1)
    dists = jnp.concatenate([res.visited_dists, res.beam_dists], axis=-1)
    # dedup: keep first occurrence (visited log wins; beam dupes masked)
    def dedup_one(i, d):
        dup = _mask_dup_within(i)
        return jnp.where(dup, -1, i), jnp.where(dup, INF, d)

    if ids.ndim == 1:
        return dedup_one(ids, dists)
    return jax.vmap(dedup_one)(ids, dists)
