"""GreedySearch (Algorithm 1) — batched, fixed-shape, TPU-native.

The paper's search walks the graph with async SSD reads, amortizing per-hop
cost with a *beamWidth* knob: several frontier nodes expand per round so each
I/O round does more useful work (§3.2). On TPU the same knob pays off for a
different reason: the L-entry search list ("beam") is a sorted array advanced
by a `lax.while_loop`, and under lockstep `vmap` every lane in a micro-batch
waits for the slowest lane's round count. Expanding the W best unexpanded
beam entries per round (``beam_width``) gathers ``W × R_slack`` neighbors in
one shot, computes all their ADC distances in a single call, and merges with
one `lax.top_k` — cutting the sequential trip count ~W× while widening the
vectorized work per dispatch.

Search runs in *quantized space* (§3.2): distances come from per-query ADC
LUTs against the uint8 PQ codes; full-precision vectors are only touched by
the re-rank stage (``repro.core.flat.rerank``), preserving the paper's ≈70×
access-frequency asymmetry.

Filter-aware (β) search — Algorithm 7 — is folded in: when a packed filter
bitmap is supplied, distances of filter-passing nodes are scaled by β < 1 so
the frontier drifts toward the filtered region (§3.5, Fig 9).

Counter semantics with hop batching:
  * ``n_hops`` — sequential rounds (the latency-critical quantity; drops
    ~W× at beam_width W);
  * ``n_exp`` — frontier nodes actually expanded, i.e. adjacency rows
    fetched (the RU-relevant quantity; ≈ n_hops at W=1);
  * ``n_cmps`` — quantized distance comparisons (rises modestly with W:
    a wider frontier visits a few extra neighborhoods).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import graph as g
from . import pq as pqmod

INF = jnp.float32(jnp.inf)


class SearchResult(NamedTuple):
    beam_ids: jax.Array  # (L,) int32, ascending distance, -1 padded
    beam_dists: jax.Array  # (L,) f32 (quantized-space, β-scaled if filtered)
    visited_ids: jax.Array  # (V,) int32 expanded nodes in order, -1 padded
    visited_dists: jax.Array  # (V,) f32
    n_hops: jax.Array  # () int32 — sequential expansion rounds
    n_exp: jax.Array  # () int32 — nodes expanded (adjacency rows fetched)
    n_cmps: jax.Array  # () int32 — number of quantized distance comps


class _LoopState(NamedTuple):
    ids: jax.Array
    dists: jax.Array
    expanded: jax.Array
    bitmap: jax.Array
    visited_ids: jax.Array
    visited_dists: jax.Array
    hops: jax.Array
    exp: jax.Array
    cmps: jax.Array


def mask_duplicates(ids: jax.Array) -> jax.Array:
    """True where ids[i] repeats an earlier (lower-index) entry.

    Sort-based O(n log n): the stable argsort groups equal ids with the
    earliest original position first, so adjacent-equal in sorted order
    marks exactly the later occurrences. Replaces the former O(n²) pairwise
    mask, which would explode at the W·R_slack widths hop batching gathers.
    Negative ids (padding) are never marked — they are invalid anyway.
    """
    order = jnp.argsort(ids)  # stable: ties keep original index order
    s = ids[order]
    dup_sorted = jnp.concatenate([jnp.zeros((1,), bool), s[1:] == s[:-1]])
    dup = jnp.zeros_like(dup_sorted).at[order].set(dup_sorted)
    return dup & (ids >= 0)


def frontier_topw(
    ids: jax.Array, dists: jax.Array, expanded: jax.Array, W: int
) -> tuple[jax.Array, jax.Array]:
    """Positions of the W best unexpanded beam entries.

    Returns (positions (W,), valid (W,)). Lanes beyond the remaining
    frontier are flagged invalid; their positions point at expanded or
    padding entries, so marking them expanded is a no-op.
    """
    masked = jnp.where(expanded | (ids < 0), INF, dists)
    neg, pos = jax.lax.top_k(-masked, W)
    return pos, neg > -INF


def expand_frontier(
    neighbors: jax.Array,
    codes: jax.Array,
    versions: jax.Array,
    live: jax.Array,
    luts: jax.Array,
    bitmap: jax.Array,
    p_ids: jax.Array,  # (W,) frontier node ids
    p_valid: jax.Array,  # (W,) bool
    filter_bits: Optional[jax.Array],
    beta: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """The shared W-way hop: gather all W adjacency rows at once, drop
    already-visited / dead / duplicate candidates with one sort-based pass,
    and compute every ADC distance in a single call.

    Returns (cand_ids (W·R_slack,), cand_dists, new_bitmap, n_new).
    Used by both the greedy-search loop body and the pagination loop.
    """
    nbrs = neighbors[jnp.maximum(p_ids, 0)]  # (W, R_slack)
    nbrs = jnp.where(p_valid[:, None], nbrs, -1).reshape(-1)
    safe = jnp.maximum(nbrs, 0)
    valid = (nbrs >= 0) & live[safe] & ~g.bitmap_test(bitmap, nbrs)
    valid &= ~mask_duplicates(nbrs)
    bitmap = g.bitmap_set(bitmap, jnp.where(valid, nbrs, -1))

    d = pqmod.adc_distance_versioned(luts, codes[safe], versions[safe])
    if filter_bits is not None:
        passes = g.bitmap_test(filter_bits, safe) & (nbrs >= 0)
        d = jnp.where(passes, beta * d, d)
    d = jnp.where(valid, d, INF)
    return jnp.where(valid, nbrs, -1), d, bitmap, valid.sum()


def _expand_w(
    st: _LoopState,
    neighbors: jax.Array,
    codes: jax.Array,
    versions: jax.Array,
    live: jax.Array,
    luts: jax.Array,
    filter_bits: Optional[jax.Array],
    beta: jax.Array,
    W: int,
) -> _LoopState:
    """One round: expand the W best unexpanded beam entries, merge their
    neighbors into the L-beam with a single top-k."""
    L = st.ids.shape[0]
    cap_v = st.visited_ids.shape[0]

    p_pos, p_valid = frontier_topw(st.ids, st.dists, st.expanded, W)
    p_ids = st.ids[p_pos]
    expanded = st.expanded.at[p_pos].set(True)

    # visited log: valid expansions pack contiguously after the running
    # expansion count; invalid lanes scatter out of bounds and drop
    nv = p_valid.astype(jnp.int32)
    vpos = (st.exp + jnp.cumsum(nv) - nv) % cap_v
    vpos = jnp.where(p_valid, vpos, cap_v)
    visited_ids = st.visited_ids.at[vpos].set(p_ids, mode="drop")
    visited_dists = st.visited_dists.at[vpos].set(st.dists[p_pos], mode="drop")

    cand_ids, cand_d, bitmap, n_new = expand_frontier(
        neighbors, codes, versions, live, luts, st.bitmap,
        p_ids, p_valid, filter_bits, beta,
    )

    all_ids = jnp.concatenate([st.ids, cand_ids])
    all_d = jnp.concatenate([st.dists, cand_d])
    all_e = jnp.concatenate([expanded, jnp.zeros(cand_ids.shape, bool)])
    _, order = jax.lax.top_k(-all_d, L)  # ties keep lower index: stays sorted
    return _LoopState(
        ids=all_ids[order],
        dists=all_d[order],
        expanded=all_e[order],
        bitmap=bitmap,
        visited_ids=visited_ids,
        visited_dists=visited_dists,
        hops=st.hops + 1,
        exp=st.exp + nv.sum(),
        cmps=st.cmps + n_new,
    )


@functools.partial(
    jax.jit,
    static_argnames=("L", "max_hops", "visited_cap", "has_filter", "beam_width"),
)
def greedy_search(
    neighbors: jax.Array,
    codes: jax.Array,
    versions: jax.Array,
    live: jax.Array,
    luts: jax.Array,  # (Vschemas, M, K) from pq.multi_lut
    start: jax.Array,  # () int32
    *,
    L: int,
    max_hops: int = 0,
    visited_cap: int = 0,
    has_filter: bool = False,
    filter_bits: Optional[jax.Array] = None,
    beta: jax.Array | float = 1.0,
    beam_width: int = 1,
) -> SearchResult:
    """Single-query GreedySearch. vmap over (luts, filter_bits) for batches.

    ``beam_width`` (the paper's beamWidth, §3.2) expands the W best
    unexpanded beam entries per round. ``max_hops`` bounds *rounds*; its
    default keeps the total expansion budget (~2L+16 nodes) independent of
    W, so W only changes how the same candidate pool is scheduled.
    """
    W = int(beam_width)
    assert 1 <= W <= L, f"beam_width {W} must be in [1, L={L}]"
    if max_hops == 0:
        max_hops = -(-(2 * L + 16) // W)  # ceil: same node budget at any W
    if visited_cap == 0:
        visited_cap = W * max_hops
    if not has_filter:
        filter_bits = None
    beta = jnp.float32(beta)
    cap = neighbors.shape[0]

    start_d = pqmod.adc_distance_versioned(
        luts, codes[start][None], versions[start][None]
    )[0]
    ids0 = jnp.full((L,), -1, jnp.int32).at[0].set(start)
    dists0 = jnp.full((L,), INF).at[0].set(start_d)
    expanded0 = jnp.ones((L,), bool).at[0].set(False)
    bm0 = g.bitmap_set(g.bitmap_init(cap), jnp.array([start], jnp.int32))

    st0 = _LoopState(
        ids=ids0,
        dists=dists0,
        expanded=expanded0,
        bitmap=bm0,
        visited_ids=jnp.full((visited_cap,), -1, jnp.int32),
        visited_dists=jnp.full((visited_cap,), INF),
        hops=jnp.int32(0),
        exp=jnp.int32(0),
        cmps=jnp.int32(1),
    )

    def cond(st: _LoopState):
        frontier = (~st.expanded) & (st.ids >= 0)
        return jnp.any(frontier) & (st.hops < max_hops)

    def body(st: _LoopState):
        return _expand_w(
            st, neighbors, codes, versions, live, luts, filter_bits, beta, W
        )

    st = jax.lax.while_loop(cond, body, st0)
    return SearchResult(
        beam_ids=st.ids,
        beam_dists=st.dists,
        visited_ids=st.visited_ids,
        visited_dists=st.visited_dists,
        n_hops=st.hops,
        n_exp=st.exp,
        n_cmps=st.cmps,
    )


@functools.partial(
    jax.jit,
    static_argnames=("L", "max_hops", "visited_cap", "has_filter", "beam_width"),
)
def _batched_search_entry(
    neighbors, codes, versions, live, luts, start, filter_bits, beta,
    *, L: int, max_hops: int, visited_cap: int, has_filter: bool,
    beam_width: int,
) -> SearchResult:
    """Top-level jitted vmap over ``greedy_search``.

    Being the outermost jit matters: its compile cache is keyed by the full
    (batch, L, beam_width, …) signature, so ``jit_cache_size()`` is a
    truthful recompile counter for the serving hot path (an inner jit under
    vmap never sees its own cache populated — compilation happens in the
    pjit-primitive path). A beam_width change costs exactly one compile per
    (bucket, L) signature it is used with.
    """
    fn = functools.partial(
        greedy_search, neighbors, codes, versions, live,
        L=L, max_hops=max_hops, visited_cap=visited_cap,
        has_filter=has_filter, beta=beta, beam_width=beam_width,
    )
    if has_filter:
        return jax.vmap(lambda lut, fb: fn(lut, start, filter_bits=fb))(luts, filter_bits)
    return jax.vmap(lambda lut: fn(lut, start))(luts)


def batch_greedy_search(
    neighbors: jax.Array,
    codes: jax.Array,
    versions: jax.Array,
    live: jax.Array,
    luts: jax.Array,  # (B, Vschemas, M, K)
    start: jax.Array,
    *,
    L: int,
    max_hops: int = 0,
    visited_cap: int = 0,
    filter_bits: Optional[jax.Array] = None,  # (B, Nw) or None
    beta: float = 1.0,
    beam_width: int = 1,
) -> SearchResult:
    """vmapped GreedySearch over a query batch (lockstep beam expansion).

    W-way hop batching shrinks the lockstep critical path directly: lanes
    wait for the slowest lane's *round* count, and rounds drop ~W×.
    """
    has_filter = filter_bits is not None
    if not has_filter:
        # dummy with a stable shape so the jit signature doesn't churn
        filter_bits = jnp.zeros((luts.shape[0], 1), jnp.uint32)
    return _batched_search_entry(
        neighbors, codes, versions, live, luts, jnp.asarray(start, jnp.int32),
        filter_bits, jnp.float32(beta),
        L=L, max_hops=max_hops, visited_cap=visited_cap, has_filter=has_filter,
        beam_width=int(beam_width),
    )


def jit_cache_size() -> int:
    """Compiled-signature count of the batched-search entry (recompile
    telemetry for the serving layer; see serve/vector_engine.py)."""
    try:
        return int(_batched_search_entry._cache_size())
    except AttributeError:  # very old/new jit wrappers
        return -1


# ---------------------------------------------------------------------------
# shape bucketing — fixed (batch, L) signatures for the serving layer
# ---------------------------------------------------------------------------

BATCH_BUCKETS: tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64)


def next_bucket(n: int, buckets: tuple[int, ...] = BATCH_BUCKETS) -> int:
    """Smallest bucket ≥ n; beyond the largest, round up to a multiple of it
    (the serving engine splits oversized batches into top-bucket chunks —
    ``vector_engine._dispatch`` — so the rounding here is only a safety net
    against shape explosions for direct callers)."""
    for b in buckets:
        if n <= b:
            return b
    top = buckets[-1]
    return ((n + top - 1) // top) * top


def pad_batch(arr: jax.Array, bucket: int) -> jax.Array:
    """Pad the leading (batch) axis to `bucket` by repeating row 0 — padded
    lanes redo real work so every lane stays numerically well-formed."""
    b = arr.shape[0]
    if b == bucket:
        return arr
    filler = jnp.broadcast_to(arr[:1], (bucket - b,) + arr.shape[1:])
    return jnp.concatenate([arr, filler], axis=0)


def pad_batch_np(arr: np.ndarray, bucket: int) -> np.ndarray:
    """Host-side twin of ``pad_batch`` — pads query batches before they
    enter any jitted stage (LUTs, search, re-rank share one bucket)."""
    b = len(arr)
    if b == bucket:
        return arr
    return np.concatenate(
        [arr, np.broadcast_to(arr[:1], (bucket - b,) + arr.shape[1:])]
    )


def bucketed_batch_greedy_search(
    neighbors: jax.Array,
    codes: jax.Array,
    versions: jax.Array,
    live: jax.Array,
    luts: jax.Array,  # (B, Vschemas, M, K)
    start: jax.Array,
    *,
    L: int,
    batch_buckets: tuple[int, ...] = BATCH_BUCKETS,
    max_hops: int = 0,
    visited_cap: int = 0,
    filter_bits: Optional[jax.Array] = None,
    beta: float = 1.0,
    beam_width: int = 1,
) -> SearchResult:
    """`batch_greedy_search` padded to a fixed batch bucket, results sliced
    back to the true batch — steady-state traffic whose batch sizes vary
    within one bucket reuses a single compiled executable (zero recompiles)."""
    B = luts.shape[0]
    bucket = next_bucket(B, batch_buckets)
    if bucket != B:
        luts = pad_batch(luts, bucket)
        if filter_bits is not None:
            filter_bits = pad_batch(filter_bits, bucket)
    res = batch_greedy_search(
        neighbors, codes, versions, live, luts, start,
        L=L, max_hops=max_hops, visited_cap=visited_cap,
        filter_bits=filter_bits, beta=beta, beam_width=beam_width,
    )
    if bucket != B:
        res = SearchResult(*(a[:B] for a in res))
    return res


def search_candidates(res: SearchResult) -> tuple[jax.Array, jax.Array]:
    """Union of expanded set and final beam — the prune candidate pool used
    by Insert (Algorithm 2 consumes the visited set V)."""
    ids = jnp.concatenate([res.visited_ids, res.beam_ids], axis=-1)
    dists = jnp.concatenate([res.visited_dists, res.beam_dists], axis=-1)
    # dedup: keep first occurrence (visited log wins; beam dupes masked)
    def dedup_one(i, d):
        dup = mask_duplicates(i)
        return jnp.where(dup, -1, i), jnp.where(dup, INF, d)

    if ids.ndim == 1:
        return dedup_one(ids, dists)
    return jax.vmap(dedup_one)(ids, dists)
