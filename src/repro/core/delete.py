"""In-place delete (Algorithm 6) + lightweight background consolidation.

The paper (§2.1 "In-place Deletion", Fig 13) shows that rewiring the deleted
node's critical connections keeps recall stable over long update streams,
whereas simply dropping the vector ("Drop Policy") degrades — dramatically so
under distribution shift. We implement both so the runbook benchmarks can
reproduce the comparison.

Alg 6, faithfully:
  * B = in-neighbors of p found within p's two-hop out-neighborhood;
  * every b ∈ B: drop p, splice in the c closest of N_out(p) to b, prune if
    over the degree bound;
  * every b ∈ N_out(p): connect b to its c closest siblings in N_out(p);
  * a background sweep (``consolidate_chunk``) erases remaining dangling
    edges to dead nodes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import prune as prmod

INF = jnp.float32(jnp.inf)


@functools.partial(
    jax.jit,
    static_argnames=("R", "R_slack", "alpha", "c_replace", "metric"),
    donate_argnames=("neighbors",),
)
def inplace_delete(
    neighbors: jax.Array,  # (N, R_slack)
    live: jax.Array,  # (N,) bool — p should already be marked dead
    vectors: jax.Array,  # (N, D) decoded-PQ or full coordinates for pruning
    p: jax.Array,  # () int32 node being deleted
    *,
    R: int,
    R_slack: int,
    alpha: float,
    c_replace: int = 3,
    metric: str = "l2",
) -> jax.Array:
    """Rewire the graph around deleted node p. Returns new neighbors."""
    nout_p = neighbors[p]  # (R_slack,)
    safe_out = jnp.maximum(nout_p, 0)
    valid_out = (nout_p >= 0) & live[safe_out]

    # --- two-hop out-neighborhood ---------------------------------------
    twohop = neighbors[safe_out].reshape(-1)  # (R_slack^2,)
    twohop = jnp.where(jnp.repeat(valid_out, R_slack), twohop, -1)
    hood = jnp.concatenate([nout_p, twohop])  # candidate in-neighbors
    hood = jnp.where(hood == p, -1, hood)

    # --- loop over the hood: b with p ∈ N_out(b) get rewired -------------
    def fix_b(nb, b):
        row = nb[jnp.maximum(b, 0)]
        has_p = jnp.any(row == p) & (b >= 0) & live[jnp.maximum(b, 0)]

        # remove p, compact left
        no_p = jnp.where(row == p, -1, row)
        order = jnp.argsort(jnp.where(no_p >= 0, 0, 1), stable=True)
        no_p = no_p[order]

        # c closest live members of N_out(p) to b, excluding b itself
        b_vec = vectors[jnp.maximum(b, 0)]
        cand_vecs = vectors[safe_out]
        if metric == "l2":
            dd = jnp.sum((cand_vecs - b_vec[None, :]) ** 2, -1)
        else:
            dd = -cand_vecs @ b_vec
        dd = jnp.where(valid_out & (nout_p != b), dd, INF)
        closest = jnp.where(
            jnp.isfinite(jnp.sort(dd)[:c_replace]),
            nout_p[jnp.argsort(dd)[:c_replace]],
            -1,
        )

        merged = jnp.concatenate([no_p, closest])  # (R_slack + c,)
        # dedup + prune to R if above bound, else compact to R_slack
        pruned = prmod.prune_with_vectors(
            b_vec,
            merged,
            vectors[jnp.maximum(merged, 0)],
            alpha=alpha,
            R=R,
            metric=metric,
            self_id=b,
        )
        deg_merged = (merged >= 0).sum() - jnp.sum(
            (merged[:, None] == merged[None, :])
            & (merged[:, None] >= 0)
            & jnp.tril(jnp.ones((merged.shape[0],) * 2, bool), k=-1)
        )
        use_prune = deg_merged > R_slack
        # non-prune path: first R_slack unique entries of merged
        eq = (merged[:, None] == merged[None, :]) & (merged[None, :] >= 0)
        dup = jnp.any(eq & jnp.tril(jnp.ones_like(eq), k=-1).astype(bool), axis=1)
        uniq = jnp.where(dup, -1, merged)
        order2 = jnp.argsort(jnp.where(uniq >= 0, 0, 1), stable=True)
        compacted = uniq[order2][:R_slack]
        padded_prune = jnp.concatenate([pruned, jnp.full((R_slack - R,), -1, jnp.int32)])
        new_row = jnp.where(use_prune, padded_prune, compacted)

        out = jnp.where(has_p, new_row, row)
        return nb.at[jnp.maximum(b, 0)].set(out), None

    neighbors, _ = jax.lax.scan(fix_b, neighbors, hood)

    # --- second loop of Alg 6: stitch N_out(p) among themselves ----------
    def stitch(nb, b):
        ok = (b >= 0) & live[jnp.maximum(b, 0)]
        b_vec = vectors[jnp.maximum(b, 0)]
        cand_vecs = vectors[safe_out]
        if metric == "l2":
            dd = jnp.sum((cand_vecs - b_vec[None, :]) ** 2, -1)
        else:
            dd = -cand_vecs @ b_vec
        dd = jnp.where(valid_out & (nout_p != b), dd, INF)
        closest = jnp.argsort(dd)[:1]  # c=1 sibling link keeps degree churn low
        sib = jnp.where(jnp.isfinite(dd[closest]), nout_p[closest], -1)[0]

        row = nb[jnp.maximum(b, 0)]
        deg = (row >= 0).sum()
        already = jnp.any(row == sib) | (sib < 0)
        appended = jnp.where(jnp.arange(row.shape[0]) == deg, sib, row)
        can = ok & ~already & (deg < row.shape[0])
        return nb.at[jnp.maximum(b, 0)].set(jnp.where(can, appended, row)), None

    neighbors, _ = jax.lax.scan(stitch, neighbors, nout_p)

    # clear p's own list
    neighbors = neighbors.at[p].set(jnp.full((R_slack,), -1, jnp.int32))
    return neighbors


@functools.partial(jax.jit, static_argnames=("chunk",), donate_argnames=("neighbors",))
def consolidate_chunk(
    neighbors: jax.Array, live: jax.Array, start_row: jax.Array, chunk: int = 1024
) -> jax.Array:
    """Background sweep (§2.1): erase edges to dead nodes in rows
    [start_row, start_row + chunk), compacting left."""
    rows = start_row + jnp.arange(chunk)
    rows = jnp.minimum(rows, neighbors.shape[0] - 1)
    block = neighbors[rows]  # (chunk, R_slack)
    dead = ~live[jnp.maximum(block, 0)] | (block < 0)
    cleaned = jnp.where(dead, -1, block)
    order = jnp.argsort(jnp.where(cleaned >= 0, 0, 1), axis=1, stable=True)
    compacted = jnp.take_along_axis(cleaned, order, axis=1)
    return neighbors.at[rows].set(compacted)
