"""Flat, Q-Flat, and full-precision re-ranking (§3 "System Design").

The paper's query planner escalates through three physical plans:
  * brute force over documents        (< ~1000 docs),
  * Flat  — full vectors as contiguous index terms,
  * Q-Flat — exhaustive scan in quantized space + re-rank (< ~5000 matches,
    or small tenants in multi-tenant collections),
  * DiskANN graph search              (everything else).

``rerank`` is shared by Q-Flat and the DiskANN path: fetch full-precision
vectors for k' = multiplier·k candidates from the document store and re-order
by exact distance (Fig 5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import pq as pqmod

INF = jnp.float32(jnp.inf)

# §3.5 defaults
QUANTIZED_LIST_MULTIPLIER = 5.0  # k' = multiplier * k candidates to re-rank
BRUTE_FORCE_MAX_DOCS = 1000
QFLAT_MAX_MATCHES = 5000


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def brute_force(
    queries: jax.Array, vectors: jax.Array, live: jax.Array, *, k: int, metric: str = "l2"
) -> tuple[jax.Array, jax.Array]:
    """Exact top-k by scanning the document store. (B, k) ids, dists.

    When fewer than k entries pass ``live`` (e.g. a highly selective
    predicate mask), the remainder comes back as -1/inf — never as a
    masked-out document smuggled in with an arbitrary distance."""
    d = pqmod.pairwise_distance(queries, vectors, metric)
    d = jnp.where(live[None, :], d, INF)
    neg, idx = jax.lax.top_k(-d, k)
    idx = jnp.where(jnp.isfinite(neg), idx, -1)
    return idx.astype(jnp.int32), -neg


@functools.partial(jax.jit, static_argnames=("kprime", "metric"))
def qflat_scan(
    luts: jax.Array,  # (B, V, M, K)
    codes: jax.Array,  # (N, M)
    versions: jax.Array,  # (N,)
    live: jax.Array,
    *,
    kprime: int,
    metric: str = "l2",
    filter_mask: jax.Array | None = None,  # (B, N) bool predicate matches
) -> tuple[jax.Array, jax.Array]:
    """Exhaustive scan in quantized space: top-k' candidates per query."""

    def one(lut, fm):
        d = pqmod.adc_distance_versioned(lut, codes, versions)  # (N,)
        d = jnp.where(live, d, INF)
        if fm is not None:
            d = jnp.where(fm, d, INF)
        neg, idx = jax.lax.top_k(-d, kprime)
        # fewer matches than k': pad with -1, or the re-rank stage would
        # re-score filtered-OUT docs by true distance and let them win
        idx = jnp.where(jnp.isfinite(neg), idx, -1)
        return idx.astype(jnp.int32), -neg

    if filter_mask is None:
        return jax.vmap(lambda lut: one(lut, None))(luts)
    return jax.vmap(one)(luts, filter_mask)


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def rerank(
    queries: jax.Array,  # (B, D)
    cand_ids: jax.Array,  # (B, C) — -1 padded
    vectors: jax.Array,  # (N, D) document store (full precision)
    *,
    k: int,
    metric: str = "l2",
) -> tuple[jax.Array, jax.Array]:
    """Fig 5: exact re-ranking of quantized-space candidates.

    Fetches C full-precision vectors per query (the rare document-store
    access) and returns exact top-k. Duplicate / -1 candidates excluded.
    """

    def one(q, ids):
        safe = jnp.maximum(ids, 0)
        vecs = vectors[safe]  # (C, D)
        d = pqmod.exact_distance(q[None, :], vecs, metric)
        eq = (ids[:, None] == ids[None, :]) & (ids[None, :] >= 0)
        dup = jnp.any(eq & jnp.tril(jnp.ones_like(eq), k=-1).astype(bool), axis=1)
        d = jnp.where((ids >= 0) & ~dup, d, INF)
        neg, pos = jax.lax.top_k(-d, k)
        return jnp.where(jnp.isfinite(-neg), ids[pos], -1), -neg

    return jax.vmap(one)(queries, cand_ids)
