"""Paginated search (§3.2, Fig 3).

Hybrid queries may need more candidates than one greedy pass returns, and
Cosmos DB preempts backend requests after 5 s, resuming from a continuation
token. Paginated search supports both: two priority queues — ``best`` (size
L, as in standard greedy search) and ``backup`` (unbounded in the paper;
capacity-bounded here, with the drop count surfaced rather than silently
truncated) — plus a visited set that persists across paginations so pages
never repeat results.

Each page: refill ``best`` from ``backup``, expand until every entry of
``best`` is expanded, pop the top-k as the page's results. The expansion
step is the same W-way hop (``search.expand_frontier``) as the main greedy
loop, so ``beam_width`` cuts a page's sequential round count the same ~W×.
The whole ``PageState`` is an explicit pytree — it *is* the continuation
token (the paper returns partial results to the client; we can serialize
this state or hold it server-side, both demonstrated in
`serve/vector_service.py`).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import graph as g
from . import pq as pqmod
from . import search as smod

INF = jnp.float32(jnp.inf)


class PageState(NamedTuple):
    best_ids: jax.Array  # (L,)
    best_dists: jax.Array
    best_expanded: jax.Array
    backup_ids: jax.Array  # (Bcap,) ascending
    backup_dists: jax.Array
    backup_expanded: jax.Array
    bitmap: jax.Array  # visited set, persists across pages
    hops: jax.Array
    cmps: jax.Array
    exp: jax.Array  # adjacency rows fetched (= hops·W̄; RU-relevant)
    dropped: jax.Array  # candidates lost to the backup capacity bound


def start_pagination(
    capacity: int, L: int, backup_cap: int, codes: jax.Array, versions: jax.Array,
    luts: jax.Array, start: jax.Array,
) -> PageState:
    start_d = pqmod.adc_distance_versioned(luts, codes[start][None], versions[start][None])[0]
    return PageState(
        best_ids=jnp.full((L,), -1, jnp.int32).at[0].set(start),
        best_dists=jnp.full((L,), INF).at[0].set(start_d),
        best_expanded=jnp.ones((L,), bool).at[0].set(False),
        backup_ids=jnp.full((backup_cap,), -1, jnp.int32),
        backup_dists=jnp.full((backup_cap,), INF),
        backup_expanded=jnp.ones((backup_cap,), bool),
        bitmap=g.bitmap_set(g.bitmap_init(capacity), jnp.array([start], jnp.int32)),
        hops=jnp.int32(0),
        cmps=jnp.int32(1),
        exp=jnp.int32(0),
        dropped=jnp.int32(0),
    )


@functools.partial(
    jax.jit, static_argnames=("k", "max_hops", "has_filter", "beam_width")
)
def next_page(
    neighbors: jax.Array,
    codes: jax.Array,
    versions: jax.Array,
    live: jax.Array,
    luts: jax.Array,
    state: PageState,
    *,
    k: int,
    max_hops: int = 512,
    has_filter: bool = False,
    filter_bits: Optional[jax.Array] = None,
    beta: jax.Array | float = 1.0,
    beam_width: int = 1,
) -> tuple[jax.Array, jax.Array, PageState]:
    """Produce the next k results. Returns (ids (k,), dists (k,), state)."""
    L = state.best_ids.shape[0]
    Bcap = state.backup_ids.shape[0]
    W = int(beam_width)
    assert 1 <= W <= L, f"beam_width {W} must be in [1, L={L}]"
    beta = jnp.float32(beta)
    if not has_filter:
        filter_bits = None

    def refill(st: PageState) -> PageState:
        pool_ids = jnp.concatenate([st.best_ids, st.backup_ids])
        pool_d = jnp.concatenate([st.best_dists, st.backup_dists])
        pool_e = jnp.concatenate([st.best_expanded, st.backup_expanded])
        order = jnp.argsort(pool_d)  # full sort: both slices are consumed
        pool_ids, pool_d, pool_e = pool_ids[order], pool_d[order], pool_e[order]
        return st._replace(
            best_ids=pool_ids[:L],
            best_dists=pool_d[:L],
            best_expanded=jnp.where(pool_ids[:L] >= 0, pool_e[:L], True),
            backup_ids=pool_ids[L : L + Bcap],
            backup_dists=pool_d[L : L + Bcap],
            backup_expanded=pool_e[L : L + Bcap],
        )

    st = refill(state)
    hop_limit = st.hops + max_hops

    def cond(st: PageState):
        frontier = (~st.best_expanded) & (st.best_ids >= 0)
        return jnp.any(frontier) & (st.hops < hop_limit)

    def body(st: PageState) -> PageState:
        p_pos, p_valid = smod.frontier_topw(
            st.best_ids, st.best_dists, st.best_expanded, W
        )
        p_ids = st.best_ids[p_pos]
        best_expanded = st.best_expanded.at[p_pos].set(True)

        cand_ids, cand_d, bitmap, n_new = smod.expand_frontier(
            neighbors, codes, versions, live, luts, st.bitmap,
            p_ids, p_valid, filter_bits, beta,
        )

        all_ids = jnp.concatenate([st.best_ids, cand_ids])
        all_d = jnp.concatenate([st.best_dists, cand_d])
        all_e = jnp.concatenate([best_expanded, jnp.zeros(cand_ids.shape, bool)])
        # full sort here: BOTH slices are consumed (top-L stays in best, the
        # overflow feeds backup — "vertices popped out of best")
        order = jnp.argsort(all_d)
        all_ids, all_d, all_e = all_ids[order], all_d[order], all_e[order]

        ov_ids, ov_d, ov_e = all_ids[L:], all_d[L:], all_e[L:]
        bk_ids = jnp.concatenate([st.backup_ids, ov_ids])
        bk_d = jnp.concatenate([st.backup_dists, ov_d])
        bk_e = jnp.concatenate([st.backup_expanded, ov_e])
        # only the top-Bcap slice survives → top_k, not a full argsort
        _, bo = jax.lax.top_k(-bk_d, Bcap)
        dropped = st.dropped + (
            jnp.isfinite(bk_d).sum() - jnp.isfinite(bk_d[bo]).sum()
        )

        return st._replace(
            best_ids=all_ids[:L],
            best_dists=all_d[:L],
            best_expanded=jnp.where(all_ids[:L] >= 0, all_e[:L], True),
            backup_ids=bk_ids[bo],
            backup_dists=bk_d[bo],
            backup_expanded=bk_e[bo],
            bitmap=bitmap,
            hops=st.hops + 1,
            cmps=st.cmps + n_new,
            exp=st.exp + p_valid.sum(),
            dropped=dropped,
        )

    st = jax.lax.while_loop(cond, body, st)

    # pop top-k from best as the page results (the remainder is also kept,
    # re-padded — both slices consumed, so the full argsort stays)
    order = jnp.argsort(st.best_dists)
    ids_sorted = st.best_ids[order]
    d_sorted = st.best_dists[order]
    res_ids, res_d = ids_sorted[:k], d_sorted[:k]
    res_ids = jnp.where(jnp.isfinite(res_d), res_ids, -1)

    remaining_ids = ids_sorted.at[:k].set(-1)
    remaining_d = d_sorted.at[:k].set(INF)
    remaining_e = st.best_expanded[order].at[:k].set(True)
    st = st._replace(
        best_ids=remaining_ids, best_dists=remaining_d, best_expanded=remaining_e
    )
    return res_ids, res_d, st


def exhausted(state: PageState) -> jax.Array:
    """True when no further results can be produced."""
    return ~(
        jnp.any(jnp.isfinite(state.best_dists)) | jnp.any(jnp.isfinite(state.backup_dists))
    )
