"""Paginated search (§3.2, Fig 3).

Hybrid queries may need more candidates than one greedy pass returns, and
Cosmos DB preempts backend requests after 5 s, resuming from a continuation
token. Paginated search supports both: two priority queues — ``best`` (size
L, as in standard greedy search) and ``backup`` (unbounded in the paper;
capacity-bounded here, with the drop count surfaced rather than silently
truncated) — plus a visited set that persists across paginations so pages
never repeat results.

Each page: refill ``best`` from ``backup``, expand until every entry of
``best`` is expanded, pop the top-k as the page's results. The whole
``PageState`` is an explicit pytree — it *is* the continuation token (the
paper returns partial results to the client; we can serialize this state or
hold it server-side, both demonstrated in `serve/vector_service.py`).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import graph as g
from . import pq as pqmod
from .search import _mask_dup_within

INF = jnp.float32(jnp.inf)


class PageState(NamedTuple):
    best_ids: jax.Array  # (L,)
    best_dists: jax.Array
    best_expanded: jax.Array
    backup_ids: jax.Array  # (Bcap,) ascending
    backup_dists: jax.Array
    backup_expanded: jax.Array
    bitmap: jax.Array  # visited set, persists across pages
    hops: jax.Array
    cmps: jax.Array
    dropped: jax.Array  # candidates lost to the backup capacity bound


def start_pagination(
    capacity: int, L: int, backup_cap: int, codes: jax.Array, versions: jax.Array,
    luts: jax.Array, start: jax.Array,
) -> PageState:
    start_d = pqmod.adc_distance_versioned(luts, codes[start][None], versions[start][None])[0]
    return PageState(
        best_ids=jnp.full((L,), -1, jnp.int32).at[0].set(start),
        best_dists=jnp.full((L,), INF).at[0].set(start_d),
        best_expanded=jnp.ones((L,), bool).at[0].set(False),
        backup_ids=jnp.full((backup_cap,), -1, jnp.int32),
        backup_dists=jnp.full((backup_cap,), INF),
        backup_expanded=jnp.ones((backup_cap,), bool),
        bitmap=g.bitmap_set(g.bitmap_init(capacity), jnp.array([start], jnp.int32)),
        hops=jnp.int32(0),
        cmps=jnp.int32(1),
        dropped=jnp.int32(0),
    )


@functools.partial(jax.jit, static_argnames=("k", "max_hops", "has_filter"))
def next_page(
    neighbors: jax.Array,
    codes: jax.Array,
    versions: jax.Array,
    live: jax.Array,
    luts: jax.Array,
    state: PageState,
    *,
    k: int,
    max_hops: int = 512,
    has_filter: bool = False,
    filter_bits: Optional[jax.Array] = None,
    beta: jax.Array | float = 1.0,
) -> tuple[jax.Array, jax.Array, PageState]:
    """Produce the next k results. Returns (ids (k,), dists (k,), state)."""
    L = state.best_ids.shape[0]
    Bcap = state.backup_ids.shape[0]
    beta = jnp.float32(beta)
    if not has_filter:
        filter_bits = None

    def refill(st: PageState) -> PageState:
        pool_ids = jnp.concatenate([st.best_ids, st.backup_ids])
        pool_d = jnp.concatenate([st.best_dists, st.backup_dists])
        pool_e = jnp.concatenate([st.best_expanded, st.backup_expanded])
        order = jnp.argsort(pool_d)
        pool_ids, pool_d, pool_e = pool_ids[order], pool_d[order], pool_e[order]
        return st._replace(
            best_ids=pool_ids[:L],
            best_dists=pool_d[:L],
            best_expanded=jnp.where(pool_ids[:L] >= 0, pool_e[:L], True),
            backup_ids=pool_ids[L : L + Bcap],
            backup_dists=pool_d[L : L + Bcap],
            backup_expanded=pool_e[L : L + Bcap],
        )

    st = refill(state)
    hop_limit = st.hops + max_hops

    def cond(st: PageState):
        frontier = (~st.best_expanded) & (st.best_ids >= 0)
        return jnp.any(frontier) & (st.hops < hop_limit)

    def body(st: PageState) -> PageState:
        masked = jnp.where(st.best_expanded | (st.best_ids < 0), INF, st.best_dists)
        p_idx = jnp.argmin(masked)
        p = st.best_ids[p_idx]
        best_expanded = st.best_expanded.at[p_idx].set(True)

        nbrs = neighbors[jnp.maximum(p, 0)]
        safe = jnp.maximum(nbrs, 0)
        valid = (nbrs >= 0) & live[safe] & ~g.bitmap_test(st.bitmap, nbrs)
        valid &= ~_mask_dup_within(nbrs)
        bitmap = g.bitmap_set(st.bitmap, jnp.where(valid, nbrs, -1))

        d = pqmod.adc_distance_versioned(luts, codes[safe], versions[safe])
        if filter_bits is not None:
            passes = g.bitmap_test(filter_bits, safe) & (nbrs >= 0)
            d = jnp.where(passes, beta * d, d)
        d = jnp.where(valid, d, INF)

        R_sl = nbrs.shape[0]
        all_ids = jnp.concatenate([st.best_ids, jnp.where(valid, nbrs, -1)])
        all_d = jnp.concatenate([st.best_dists, d])
        all_e = jnp.concatenate([best_expanded, jnp.zeros((R_sl,), bool)])
        order = jnp.argsort(all_d)
        all_ids, all_d, all_e = all_ids[order], all_d[order], all_e[order]

        # overflow beyond L → backup ("vertices popped out of best")
        ov_ids, ov_d, ov_e = all_ids[L:], all_d[L:], all_e[L:]
        bk_ids = jnp.concatenate([st.backup_ids, ov_ids])
        bk_d = jnp.concatenate([st.backup_dists, ov_d])
        bk_e = jnp.concatenate([st.backup_expanded, ov_e])
        bo = jnp.argsort(bk_d)
        dropped = st.dropped + (jnp.isfinite(bk_d[bo][Bcap:])).sum()

        return st._replace(
            best_ids=all_ids[:L],
            best_dists=all_d[:L],
            best_expanded=jnp.where(all_ids[:L] >= 0, all_e[:L], True),
            backup_ids=bk_ids[bo][:Bcap],
            backup_dists=bk_d[bo][:Bcap],
            backup_expanded=bk_e[bo][:Bcap],
            bitmap=bitmap,
            hops=st.hops + 1,
            cmps=st.cmps + valid.sum(),
            dropped=dropped,
        )

    st = jax.lax.while_loop(cond, body, st)

    # pop top-k from best as the page results
    order = jnp.argsort(st.best_dists)
    ids_sorted = st.best_ids[order]
    d_sorted = st.best_dists[order]
    res_ids, res_d = ids_sorted[:k], d_sorted[:k]
    res_ids = jnp.where(jnp.isfinite(res_d), res_ids, -1)

    remaining_ids = ids_sorted.at[:k].set(-1)
    remaining_d = d_sorted.at[:k].set(INF)
    remaining_e = st.best_expanded[order].at[:k].set(True)
    st = st._replace(
        best_ids=remaining_ids, best_dists=remaining_d, best_expanded=remaining_e
    )
    return res_ids, res_d, st


def exhausted(state: PageState) -> jax.Array:
    """True when no further results can be produced."""
    return ~(
        jnp.any(jnp.isfinite(state.best_dists)) | jnp.any(jnp.isfinite(state.backup_dists))
    )
