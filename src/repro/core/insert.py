"""Insert (Algorithm 2) and MiniBatchInsert (Algorithm 5).

Two implementations, matching the paper's split between the latency-critical
path and background index maintenance (§3.4 "Graph Operations"):

  * ``insert_candidates`` / ``prune_batch`` — the jitted, vmapped pieces
    (GreedySearch in quantized space + RobustPrune), used by the host-side
    orchestrator in ``index.py``. The host applies the reverse-edge updates
    as one consolidated append per touched node — exactly the Bw-Tree
    "no duplicate patches for a key" contract the mini-batch design exists
    to satisfy (§2.1).

  * ``insert_batch_jit`` — a single fully-jitted mini-batch insert (reverse
    edges applied via an in-graph fori loop with prune-on-overflow). This is
    the form the distributed ingest dry-run lowers, and the oracle for the
    host path's tests.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import graph as g
from . import pq as pqmod
from . import prune as prmod
from . import search as smod

INF = jnp.float32(jnp.inf)


class InsertStats(NamedTuple):
    hops: jax.Array  # (B,) search hops per inserted vector
    cmps: jax.Array  # (B,) quantized distance comparisons per insert


@functools.partial(jax.jit, static_argnames=("L_build", "max_hops", "metric"))
def insert_candidates(
    neighbors: jax.Array,
    codes: jax.Array,
    versions: jax.Array,
    live: jax.Array,
    schemas_codebooks: jax.Array,  # (V, M, K, dsub) stacked schema codebooks
    new_vecs: jax.Array,  # (B, D)
    medoid: jax.Array,
    *,
    L_build: int,
    max_hops: int = 0,
    metric: str = "l2",
) -> tuple[jax.Array, jax.Array, InsertStats]:
    """Search phase of Alg 2 for a mini-batch: returns the candidate pool
    (visited ∪ beam) per new vector: ids (B, C), dists (B, C)."""
    V = schemas_codebooks.shape[0]
    schemas = [
        pqmod.PQSchema(codebooks=schemas_codebooks[v], version=jnp.int32(v))
        for v in range(V)
    ]
    luts = jax.vmap(lambda q: pqmod.multi_lut(tuple(schemas), q, metric))(new_vecs)
    res = smod.batch_greedy_search(
        neighbors, codes, versions, live, luts, medoid, L=L_build, max_hops=max_hops
    )
    cand_ids, cand_dists = smod.search_candidates(res)
    return cand_ids, cand_dists, InsertStats(hops=res.n_hops, cmps=res.n_cmps)


@functools.partial(jax.jit, static_argnames=("R", "alpha", "metric"))
def prune_batch(
    codes: jax.Array,
    versions: jax.Array,
    schemas_codebooks: jax.Array,  # (V, M, K, dsub)
    new_vecs: jax.Array,  # (B, D)
    cand_ids: jax.Array,  # (B, C)
    *,
    R: int,
    alpha: float,
    metric: str = "l2",
) -> jax.Array:
    """Prune phase of Alg 2 (quantized-space prune, §3.2): (B, R) ids."""

    def decode_rows(ids):
        safe = jnp.maximum(ids, 0)
        c = codes[safe]  # (C, M)
        v = versions[safe].astype(jnp.int32)  # (C,)
        cb = schemas_codebooks[v]  # (C, M, K, dsub)
        picked = jnp.take_along_axis(
            cb, c[:, :, None, None].astype(jnp.int32), axis=2
        )[:, :, 0, :]  # (C, M, dsub)
        return picked.reshape(ids.shape[0], -1)

    def one(vec, ids):
        cand_vecs = decode_rows(ids)
        return prmod.prune_with_vectors(
            vec, ids, cand_vecs, alpha=alpha, R=R, metric=metric
        )

    return jax.vmap(one)(new_vecs, cand_ids)


# ---------------------------------------------------------------------------
# Fully-jitted mini-batch insert (dry-run / oracle path)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("L_build", "R", "R_slack", "alpha", "metric", "max_hops"),
    donate_argnames=("neighbors", "codes", "versions", "live"),
)
def insert_batch_jit(
    neighbors: jax.Array,
    codes: jax.Array,
    versions: jax.Array,
    live: jax.Array,
    schemas_codebooks: jax.Array,
    new_vecs: jax.Array,  # (B, D)
    slots: jax.Array,  # (B,) destination rows
    medoid: jax.Array,
    *,
    L_build: int,
    R: int,
    R_slack: int,
    alpha: float,
    metric: str = "l2",
    max_hops: int = 0,
):
    """One mini-batch insert as a single XLA program.

    Phase 1 (parallel): candidates + prune for every new node (Alg 5 lines
    1-5). Phase 2 (sequential fori over B·R reverse edges): append the new
    node to each chosen neighbor, pruning to R when the slack degree
    overflows — the "apply to the graph in a single update" step.
    """
    B = new_vecs.shape[0]
    newest_schema = schemas_codebooks.shape[0] - 1

    # register the new codes/liveness first so batch members can see each
    # other through the visited pool (ParlayANN-style batch build).
    schema = pqmod.PQSchema(
        codebooks=schemas_codebooks[newest_schema], version=jnp.int32(newest_schema)
    )
    new_codes = pqmod.encode(schema, new_vecs)
    codes = codes.at[slots].set(new_codes)
    versions = versions.at[slots].set(jnp.uint8(newest_schema))

    cand_ids, cand_dists, stats = insert_candidates(
        neighbors, codes, versions, live, schemas_codebooks, new_vecs, medoid,
        L_build=L_build, max_hops=max_hops, metric=metric,
    )
    nbrs = prune_batch(
        codes, versions, schemas_codebooks, new_vecs, cand_ids,
        R=R, alpha=alpha, metric=metric,
    )  # (B, R)

    pad = jnp.full((B, R_slack - R), -1, jnp.int32)
    neighbors = neighbors.at[slots].set(jnp.concatenate([nbrs, pad], axis=1))
    live = live.at[slots].set(True)

    # --- phase 2: reverse edges ------------------------------------------
    edge_src = jnp.repeat(slots, R)  # (B*R,) the new node p
    edge_dst = nbrs.reshape(-1)  # (B*R,) target b

    def decode_ids(ids):
        safe = jnp.maximum(ids, 0)
        c = codes[safe]
        v = versions[safe].astype(jnp.int32)
        cb = schemas_codebooks[v]
        picked = jnp.take_along_axis(cb, c[:, :, None, None].astype(jnp.int32), axis=2)[:, :, 0, :]
        return picked.reshape(ids.shape[0], -1)

    def body(i, carry):
        nb, = carry
        b = edge_dst[i]
        p = edge_src[i]
        row = nb[jnp.maximum(b, 0)]  # (R_slack,)
        deg = (row >= 0).sum()
        already = jnp.any(row == p)
        can_append = (deg < R_slack) & ~already & (b >= 0)

        appended = jnp.where(
            jnp.arange(R_slack) == deg, p, row
        )
        row_after_append = jnp.where(can_append, appended, row)

        # overflow: prune {row ∪ p} down to R
        cand = jnp.concatenate([row, jnp.array([p])])  # (R_slack+1,)
        cand_vecs = decode_ids(cand)
        b_vec = decode_ids(jnp.array([jnp.maximum(b, 0)]))[0]
        pruned = prmod.prune_with_vectors(
            b_vec, cand, cand_vecs, alpha=alpha, R=R, metric=metric, self_id=b
        )  # (R,)
        pruned_row = jnp.concatenate([pruned, jnp.full((R_slack - R,), -1, jnp.int32)])

        need_prune = (deg >= R_slack) & ~already & (b >= 0)
        new_row = jnp.where(need_prune, pruned_row, row_after_append)
        nb = nb.at[jnp.maximum(b, 0)].set(
            jnp.where((b >= 0), new_row, nb[jnp.maximum(b, 0)])
        )
        return (nb,)

    (neighbors,) = jax.lax.fori_loop(0, B * R, body, (neighbors,))
    return neighbors, codes, versions, live, stats
