"""Logical partitioning and elasticity (§2.2, §4.3).

A Collection hashes each document's partition key into a 32-bit keyspace
split into contiguous ranges, one per PhysicalPartition. Partitions are
capacity-bounded (the paper's 50 GB limit → a vector-count budget here);
when one fills, `split()` halves its hash range and re-homes documents —
the scale-out path that takes collections to a billion vectors across ~50
partitions (Fig 10). `merge()` is the scale-in inverse.

Each PhysicalPartition owns a DiskANN index over *its* documents plus a
store and resource governor — faithfully one-vector-index-per-partition,
queried via fanout.py.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional, Sequence

import numpy as np

from ..core import DiskANNIndex, GraphConfig
from ..core.providers import Context
from ..store.pages import PagedVectorStore
from ..store.props import PropertyTermIndex
from ..store.provider import StoreProviderSet
from ..store.ru import ResourceGovernor, RUMeter, counters_for_ru


def hash_key(key) -> int:
    """32-bit stable hash of a logical partition-key value."""
    return int.from_bytes(
        hashlib.blake2b(repr(key).encode(), digest_size=4).digest(), "big"
    )


@dataclasses.dataclass
class CollectionConfig:
    dim: int
    graph: GraphConfig
    max_vectors_per_partition: int  # the 50 GB limit analogue
    initial_partitions: int = 1
    provisioned_ru_s: float = 10000.0
    vector_path: str = "/embedding"
    shard_key_path: Optional[str] = None  # sharded DiskANN (§3.3) when set
    # tiered storage (ISSUE 10): fraction of each partition's full-
    # precision vector pages kept resident. None → fully resident
    # (bit-identical to the pre-tier engine); e.g. 0.25 keeps PQ codes +
    # adjacency + postings resident and pages the vectors, billing RU +
    # modelled latency per rerank-stage page miss
    resident_frac: Optional[float] = None
    vector_page_size: int = 64


class PhysicalPartition:
    def __init__(self, cfg: CollectionConfig, lo: int, hi: int, pid: int):
        self.cfg = cfg
        self.lo, self.hi = lo, hi  # hash range [lo, hi)
        self.pid = pid
        self.providers = StoreProviderSet(
            cfg.graph.capacity, cfg.graph.R_slack, cfg.graph.M, cfg.dim,
            path=cfg.vector_path,
        )
        self.index = DiskANNIndex(cfg.graph, cfg.dim, providers=self.providers,
                                  seed=pid, context=Context(replica=pid))
        # configure the paged full-precision tier: page size from config,
        # cache seeded per-partition so eviction is deterministic per pid
        self.providers.pages = PagedVectorStore(
            cfg.graph.capacity, cfg.dim, page_size=cfg.vector_page_size,
            seed=pid,
        )
        self.set_residency(cfg.resident_frac)
        self.governor = ResourceGovernor(cfg.provisioned_ru_s)
        self.doc_pk: dict[int, int] = {}  # doc id -> partition key hash
        # inverted property-term postings over THIS partition's slots (the
        # predicate/WHERE index) + each doc's extracted (path, value) items
        # so re-homing (split/merge/re-key) carries the terms along
        self.props = PropertyTermIndex(cfg.graph.capacity, store=self.providers)
        self.doc_props: dict[int, tuple] = {}

    def set_residency(self, frac: Optional[float]) -> None:
        """(Re)size this partition's resident vector budget. ``None`` →
        fully resident (the paged tier never misses); ``frac`` ∈ (0, 1]
        caps the page cache at that fraction of the partition's pages."""
        pages = self.providers.pages
        if frac is None:
            pages.set_budget(None)
        else:
            pages.set_budget(max(1, int(round(float(frac) * pages.n_pages))))

    def owns(self, h: int) -> bool:
        return self.lo <= h < self.hi

    @property
    def num_docs(self) -> int:
        return len(self.doc_pk)

    def insert(self, doc_ids: Sequence[int], pk_hashes: Sequence[int],
               vectors: np.ndarray,
               props: Optional[Sequence[tuple]] = None) -> tuple[float, float]:
        """``props`` aligns with ``doc_ids``: each entry is the doc's
        (path, value) property items (``serve.predicate.property_items``).
        None keeps a replaced doc's existing terms (core-level callers that
        never index properties stay property-free)."""
        self.providers.begin_op()
        self.providers.barrier("upsert:begin")
        self.index.insert(doc_ids, vectors)
        self.providers.barrier("upsert:post_index")
        for j, (d, h) in enumerate(zip(doc_ids, pk_hashes)):
            d = int(d)
            self.doc_pk[d] = int(h)
            items = (tuple(props[j]) if props is not None
                     else self.doc_props.get(d, ()))
            self.props.assign(self.index.doc_to_slot[d], items)
            self.doc_props[d] = items
        self.providers.barrier("upsert:pre_commit")
        ru, lat = self.providers.end_op()
        delay = self.governor.request(ru)
        return ru, lat + delay * 1000.0

    def delete(self, doc_ids: Sequence[int]) -> float:
        self.providers.begin_op()
        self.providers.barrier("delete:begin")
        for d in doc_ids:
            slot = self.index.doc_to_slot.get(int(d))
            if slot is not None:
                self.props.remove(slot)
            self.doc_props.pop(int(d), None)
        self.providers.barrier("delete:post_props")
        self.index.delete(doc_ids)
        for d in doc_ids:
            self.doc_pk.pop(int(d), None)
        self.providers.barrier("delete:pre_commit")
        ru, _ = self.providers.end_op()
        self.governor.request(ru)
        return ru

    def search(self, queries: np.ndarray, k: int, L: Optional[int] = None,
               **kw) -> tuple[np.ndarray, np.ndarray, float]:
        ids, dists, ru, _stats = self.search_batch(queries, k, L, **kw)
        return ids, dists, ru / max(len(queries), 1)

    def search_batch(
        self, queries: np.ndarray, k: int, L: Optional[int] = None, **kw
    ) -> tuple[np.ndarray, np.ndarray, float, "QueryStats"]:
        """Dense multi-query search. Returns (ids, dists, total RU, stats) —
        the serving engine's entry point: stats feed its latency model and
        the total RU feeds per-tenant admission accounting."""
        self.providers.begin_op()
        ids, dists, stats = self.index.search(queries, k, L, **kw)
        # RU charges the adjacency rows actually fetched (expansions), not
        # the round count — W-way hop batching must not deflate the bill
        self.providers.op += counters_for_ru(stats, lanes=len(queries))
        ru, _ = self.providers.end_op()
        self.governor.request(ru)
        return ids, dists, ru, stats

    def filtered_search_batch(
        self, queries: np.ndarray, k: int, doc_filter: np.ndarray,
        L: Optional[int] = None, term_reads: int = 0, **kw
    ) -> tuple[np.ndarray, np.ndarray, float, "QueryStats"]:
        """Dense multi-query FILTERED search — the serving engine's batched
        predicate path. ``doc_filter`` is the compiled predicate mask over
        this partition's slots (shared by every lane of the micro-batch);
        ``term_reads`` is the posting-lookup count the predicate→bitmap
        compilation performed (0 on a bitmap-cache hit), billed as
        property-term reads. Extra ``kw`` (e.g. ``filter_words``,
        ``pad_to_bucket``) pass through to ``DiskANNIndex.filtered_search``."""
        self.providers.begin_op()
        self.providers.op.prop_reads += int(term_reads)
        ids, dists, stats = self.index.filtered_search(
            queries, k, doc_filter, L=L, **kw
        )
        self.providers.op += counters_for_ru(stats, lanes=len(queries))
        ru, _ = self.providers.end_op()
        self.governor.request(ru)
        return ids, dists, ru, stats

    # -- pagination (one partition's slice of a cross-partition page) ----
    def start_pagination(self, query: np.ndarray, L: Optional[int] = None):
        """Open a pagination cursor over THIS partition's index."""
        return self.index.start_pagination(np.asarray(query, np.float32), L=L)

    def next_page(self, query: np.ndarray, state, k: int,
                  beam_width: Optional[int] = None,
                  slot_filter: Optional[np.ndarray] = None):
        """Produce this partition's next page, RU-metered like the main
        search path. Returns (doc_ids, dists, state, ru, stats): RU charges
        the page's quantized comparisons + adjacency fetches + k re-rank
        reads (a paged scan is never free), and the stats feed the
        round-structured latency model. ``slot_filter`` threads a compiled
        predicate bitmap through the page (filtered pagination)."""
        self.providers.begin_op()
        ids, dists, new_state = self.index.next_page(
            query, state, k=k, beam_width=beam_width, slot_filter=slot_filter
        )
        stats = self.index.page_stats(state, new_state, k)
        # fold the page's rerank-stage tier touches (recorded by the index
        # since PageState carries no tier counters) into the billing stats
        stats.tier_hits, stats.tier_misses = self.index.last_page_tier
        self.providers.op += counters_for_ru(stats)
        ru, _ = self.providers.end_op()
        self.governor.request(ru)
        return ids, dists, new_state, ru, stats


class Collection:
    """A scaled-out collection: hash ranges → physical partitions."""

    def __init__(self, cfg: CollectionConfig):
        self.cfg = cfg
        n = cfg.initial_partitions
        span = 1 << 32
        bounds = [span * i // n for i in range(n)] + [span]
        self.partitions: list[PhysicalPartition] = [
            PhysicalPartition(cfg, bounds[i], bounds[i + 1], i) for i in range(n)
        ]
        self._next_pid = n
        self.splits = 0
        self.merges = 0

    # ------------------------------------------------------------------
    def _route(self, pk) -> PhysicalPartition:
        h = hash_key(pk)
        for p in self.partitions:
            if p.owns(h):
                return p
        raise RuntimeError("hash ranges must cover the keyspace")

    def owner_of(self, doc_id: int) -> Optional[PhysicalPartition]:
        """The partition that currently holds ``doc_id`` (each partition
        records the pk hash it ingested every doc under), or None."""
        for p in self.partitions:
            if int(doc_id) in p.doc_pk:
                return p
        return None

    def insert(self, doc_ids: Sequence[int], partition_keys: Sequence,
               vectors: np.ndarray,
               props: Optional[Sequence[tuple]] = None) -> float:
        """Route documents to their partitions; split when full. ``props``
        (aligned with ``doc_ids``) carries each doc's property-term items
        into the owning partition's inverted predicate index."""
        total_ru = 0.0
        by_part: dict[int, list[int]] = {}
        hashes = [hash_key(pk) for pk in partition_keys]
        # Cosmos identity is (partition key, id): re-upserting an id under
        # a key that hashes to a DIFFERENT partition moves the document —
        # tombstone the old copy first, or it lingers live in its old
        # partition serving stale results forever
        for i, h in enumerate(hashes):
            owner = self.owner_of(doc_ids[i])
            if owner is not None and not owner.owns(h):
                total_ru += owner.delete([int(doc_ids[i])])
        for i, h in enumerate(hashes):
            for j, p in enumerate(self.partitions):
                if p.owns(h):
                    by_part.setdefault(j, []).append(i)
                    break
        for j, rows in by_part.items():
            p = self.partitions[j]
            if p.num_docs + len(rows) > self.cfg.max_vectors_per_partition:
                self.split(j)
                # re-route this chunk after the split
                total_ru += self.insert(
                    [doc_ids[i] for i in rows],
                    [partition_keys[i] for i in rows],
                    vectors[rows],
                    props=[props[i] for i in rows] if props is not None else None,
                )
                continue
            ru, _ = p.insert(
                [doc_ids[i] for i in rows], [hashes[i] for i in rows],
                vectors[rows],
                props=[props[i] for i in rows] if props is not None else None,
            )
            total_ru += ru
        return total_ru

    def delete(self, doc_ids: Sequence[int], partition_keys: Sequence) -> float:
        ru = 0.0
        for d, pk in zip(doc_ids, partition_keys):
            ru += self._route(pk).delete([d])
        return ru

    def delete_by_id(self, doc_ids: Sequence[int]) -> float:
        """Delete by locating each doc's OWNING partition — no
        caller-supplied pk, so a delete can never route to the wrong
        partition and silently no-op (unknown ids are skipped, matching
        ``DiskANNIndex.delete`` semantics)."""
        ru = 0.0
        for d in doc_ids:
            p = self.owner_of(d)
            if p is not None:
                ru += p.delete([int(d)])
        return ru

    # ------------------------------------------------------------------
    # elasticity
    # ------------------------------------------------------------------
    def split(self, j: int):
        """Split partition j's hash range in half and re-home documents —
        the paper's partition split behind elastic scaling (§2.2)."""
        old = self.partitions[j]
        # a crash anywhere before the final partition-list swap abandons
        # the half-built children and leaves the collection untouched —
        # split is all-or-nothing at the routing level by construction
        old.providers.barrier("split:begin")
        mid = (old.lo + old.hi) // 2
        left = PhysicalPartition(self.cfg, old.lo, mid, self._next_pid)
        right = PhysicalPartition(self.cfg, mid, old.hi, self._next_pid + 1)
        self._next_pid += 2
        halfway = len(old.doc_pk) // 2
        for i, (doc, h) in enumerate(old.doc_pk.items()):
            if i == halfway:
                old.providers.barrier("split:mid_rehome")
            slot = old.index.doc_to_slot.get(doc)
            if slot is None or not old.providers.live[slot]:
                continue
            vec = old.providers.vectors[slot][None, :]
            dst = left if h < mid else right
            # property terms re-home with the document: the new partition's
            # posting bitmaps must track its doc_to_slot exactly
            dst.insert([doc], [h], vec, props=[old.doc_props.get(doc, ())])
        old.providers.barrier("split:pre_commit")
        self.partitions = (
            self.partitions[:j] + [left, right] + self.partitions[j + 1 :]
        )
        self.splits += 1

    def split_hottest(self) -> tuple[int, tuple]:
        """Split the fullest partition — the control-plane actuation for
        sustained overload (serve/policy.py): more partitions means more
        parallel fan-out lanes and smaller per-partition search cost.
        Returns ``(j, (left, right))`` — the split index and the two new
        partitions that replaced it."""
        j = max(range(len(self.partitions)),
                key=lambda i: self.partitions[i].num_docs)
        self.split(j)
        return j, (self.partitions[j], self.partitions[j + 1])

    def merge(self, j: int):
        """Merge partitions j and j+1 (adjacent ranges) — scale-in."""
        a, b = self.partitions[j], self.partitions[j + 1]
        assert a.hi == b.lo, "only adjacent ranges merge"
        a.providers.barrier("merge:begin")
        big = PhysicalPartition(self.cfg, a.lo, b.hi, self._next_pid)
        self._next_pid += 1
        for src in (a, b):
            if src is b:
                a.providers.barrier("merge:mid")
            for doc, h in src.doc_pk.items():
                slot = src.index.doc_to_slot.get(doc)
                if slot is None or not src.providers.live[slot]:
                    continue
                big.insert([doc], [h], src.providers.vectors[slot][None, :],
                           props=[src.doc_props.get(doc, ())])
        a.providers.barrier("merge:pre_commit")
        self.partitions = self.partitions[:j] + [big] + self.partitions[j + 2 :]
        self.merges += 1

    @property
    def num_docs(self) -> int:
        return sum(p.num_docs for p in self.partitions)
