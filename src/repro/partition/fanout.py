"""Cross-partition query fan-out (§3.5 "SDK Query Plan", §4.3, Fig 10).

Two implementations of the same scatter/gather:

  * ``fanout_search`` — the client-side SDK path: issue the query to every
    physical partition (through its replica set), merge partial top-k
    results, track per-partition RU and the max-latency effect the paper
    highlights ("client end-to-end latency is sensitive to the worst
    latency on the server side"). Includes hedged requests: when a replica
    is slower than the hedge threshold, a duplicate request goes to another
    replica and the fastest answer wins — the standard tail-latency /
    straggler mitigation at fleet scale.

  * ``distributed_search_fn`` — the jitted `shard_map` path: one DiskANN
    shard per device, lockstep beam search over the local shard, local
    re-rank, then a global top-k merge via all_gather. This is what the
    multi-pod dry-run lowers for the production meshes.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .. import compat
from ..core import flat as fmod
from ..core import pq as pqmod
from ..core import search as smod
from ..store.ru import counters_for_latency

INF = jnp.float32(jnp.inf)


# ---------------------------------------------------------------------------
# client-side fan-out (host path)
# ---------------------------------------------------------------------------


def merge_topk(
    ids_list: Sequence[np.ndarray], dists_list: Sequence[np.ndarray], k: int
) -> tuple[np.ndarray, np.ndarray]:
    """Merge per-partition (B, k_i) partial results into global (B, k)."""
    ids = np.concatenate(ids_list, axis=1)
    dists = np.concatenate(dists_list, axis=1)
    dists = np.where(ids >= 0, dists, np.inf)
    order = np.argsort(dists, axis=1)[:, :k]
    return np.take_along_axis(ids, order, 1), np.take_along_axis(dists, order, 1)


def fanout_search(
    partitions,  # Sequence[PhysicalPartition] or Sequence[ReplicaSet]
    queries: np.ndarray,
    k: int,
    L: Optional[int] = None,
    latency_model=None,
    hedge_at_ms: Optional[float] = None,
    rng: Optional[np.random.RandomState] = None,
) -> tuple[np.ndarray, np.ndarray, dict]:
    """Scatter to all partitions, gather, merge. Returns (ids, dists, info).

    info: per-partition RU, modelled server latencies, client latency
    (= max over partitions), hedges issued.
    """
    rng = rng or np.random.RandomState(0)
    ids_l, dists_l, rus, lats = [], [], [], []
    hedges = 0
    for p in partitions:
        ids, dists, ru = p.search(queries, k, L)
        ids_l.append(ids)
        dists_l.append(dists)
        rus.append(ru)
        if latency_model is not None:
            lat = latency_model(p, rng)
            if hedge_at_ms is not None and lat > hedge_at_ms:
                hedges += 1
                lat = min(lat, latency_model(p, rng))  # hedged duplicate
            lats.append(lat)
    ids, dists = merge_topk(ids_l, dists_l, k)
    info = dict(
        ru_per_partition=rus,
        ru_total=float(np.sum(rus)),
        server_latencies_ms=lats,
        client_latency_ms=float(np.max(lats)) if lats else 0.0,
        hedges=hedges,
    )
    return ids, dists, info


def batched_fanout_search(
    partitions,  # Sequence[PhysicalPartition]
    queries: np.ndarray,  # (B, D) — a dense micro-batch of independent queries
    k: int,
    L: Optional[int] = None,
    batch_buckets: Optional[tuple[int, ...]] = None,
    beam_width: Optional[int] = None,
) -> tuple[np.ndarray, np.ndarray, dict]:
    """Multi-query scatter/gather for the serving engine.

    Unlike ``fanout_search`` (one logical query, per-partition bookkeeping),
    this dispatches a whole micro-batch to every partition as ONE
    fixed-shape device call (padded to `batch_buckets`), then merges the
    per-partition top-k. info carries total RU, per-partition RU/stats, and
    the modelled worst-partition latency (client latency tracks the slowest
    partition, §4.3).

    The latency model is *round-structured* (``store.ru
    .counters_for_latency``): a beam-width round's quantized reads issue
    concurrently and its adjacency fetches coalesce into one round trip.
    RU, by contrast, still charges every read (see
    ``PhysicalPartition.search_batch``): W buys latency, not free work.
    """
    kw: dict = {}
    if batch_buckets is not None:
        kw = dict(pad_to_bucket=True, batch_buckets=batch_buckets)
    if beam_width is not None:
        kw["beam_width"] = beam_width
    ids_l, dists_l, rus, lat_ms = [], [], [], []
    stats_l = []
    for p in partitions:
        ids, dists, ru, stats = p.search_batch(queries, k, L, **kw)
        ids_l.append(ids)
        dists_l.append(dists)
        rus.append(ru)
        stats_l.append(stats)
        lat_ms.append(
            p.providers.meter.latency_ms(counters_for_latency(stats))
        )
    ids, dists = merge_topk(ids_l, dists_l, k)
    info = dict(
        ru_per_partition=rus,
        ru_total=float(np.sum(rus)),
        stats_per_partition=stats_l,
        server_latencies_ms=lat_ms,
        service_latency_ms=float(np.max(lat_ms)) if lat_ms else 0.0,
    )
    return ids, dists, info


# ---------------------------------------------------------------------------
# device-parallel fan-out (jitted shard_map path — used by the dry-run)
# ---------------------------------------------------------------------------


def distributed_search_fn(
    mesh: jax.sharding.Mesh,
    *,
    L: int,
    k: int,
    metric: str = "l2",
    shard_axes: tuple[str, ...] = ("data",),
    max_hops: int = 0,
    beam_width: int = 1,
):
    """Build the jitted cross-partition search step for a device mesh.

    The returned fn takes shard-stacked index arrays (leading axis = number
    of shards = product of `shard_axes` sizes) and a replicated query batch;
    each device searches its shard and the results merge with one
    all_gather — the SDK's scatter/gather as collectives.
    """
    spec_sharded = P(shard_axes)
    spec_repl = P()

    def local_search(neighbors, codes, versions, live, vectors, doc_ids,
                     medoid, codebooks, queries):
        # leading shard axis is 1 inside shard_map; codebooks are PER SHARD
        # (each partition quantizes independently, as in the paper — using
        # one shard's schema for all shards silently wrecks distances)
        neighbors, codes, versions = neighbors[0], codes[0], versions[0]
        live, vectors, doc_ids, medoid = live[0], vectors[0], doc_ids[0], medoid[0]

        schema = pqmod.PQSchema(codebooks=codebooks[0], version=jnp.int32(0))
        luts = jax.vmap(lambda q: pqmod.adc_lut(schema, q, metric))(queries)[:, None]
        res = smod.batch_greedy_search(
            neighbors, codes, versions, live, luts, medoid,
            L=L, max_hops=max_hops, beam_width=beam_width,
        )
        lids, ldists = fmod.rerank(queries, res.beam_ids[:, : 2 * k], vectors,
                                   k=k, metric=metric)
        gdoc = jnp.where(lids >= 0, doc_ids[jnp.maximum(lids, 0)], -1)

        # gather partial results from every shard and merge
        all_ids = gdoc
        all_d = jnp.where(lids >= 0, ldists, INF)
        for ax in shard_axes:
            all_ids = jax.lax.all_gather(all_ids, ax, axis=0, tiled=False)
            all_d = jax.lax.all_gather(all_d, ax, axis=0, tiled=False)
            all_ids = all_ids.reshape((-1,) + all_ids.shape[2:]) if all_ids.ndim > 3 else all_ids
            all_d = all_d.reshape((-1,) + all_d.shape[2:]) if all_d.ndim > 3 else all_d
        # (S, B, k) -> (B, S*k) -> top-k
        S = all_d.shape[0]
        flat_d = jnp.moveaxis(all_d, 0, 1).reshape(queries.shape[0], S * k)
        flat_i = jnp.moveaxis(all_ids, 0, 1).reshape(queries.shape[0], S * k)
        neg, pos = jax.lax.top_k(-flat_d, k)
        out_ids = jnp.take_along_axis(flat_i, pos, axis=1)
        return out_ids, -neg

    shmapped = compat.shard_map(
        local_search,
        mesh,
        in_specs=(
            spec_sharded, spec_sharded, spec_sharded, spec_sharded,
            spec_sharded, spec_sharded, spec_sharded, spec_sharded, spec_repl,
        ),
        out_specs=(spec_repl, spec_repl),
        check=False,
    )
    return jax.jit(shmapped)
