"""Cross-partition query fan-out (§3.5 "SDK Query Plan", §4.3, Fig 10).

Two implementations of the same scatter/gather:

  * ``fanout_search`` — the client-side SDK path: issue the query to every
    physical partition (through its replica set), merge partial top-k
    results, track per-partition RU and the max-latency effect the paper
    highlights ("client end-to-end latency is sensitive to the worst
    latency on the server side"). Includes hedged requests: when a replica
    is slower than the hedge threshold, a duplicate request goes to another
    replica and the fastest answer wins — the standard tail-latency /
    straggler mitigation at fleet scale.

  * ``distributed_search_fn`` — the jitted `shard_map` path: one DiskANN
    shard per device, lockstep beam search over the local shard, local
    re-rank, then a global top-k merge via all_gather. This is what the
    multi-pod dry-run lowers for the production meshes.

  * ``SpmdFanout`` — the engine-facing SPMD dispatch
    (``EngineConfig.dispatch_mode="spmd"``): live partitions stack into
    per-partition arrays sharded over a mesh, and ONE jitted shard_map
    program runs every partition's bucketed search + re-rank as a single
    data-parallel call — bit-identical to the serial per-partition loop,
    RU metered on each partition's own meter, zero steady-state
    recompiles (`spmd_jit_cache_size` feeds the serving cache telemetry).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .. import compat
from ..core import flat as fmod
from ..core import paginate as pgmod
from ..core import pq as pqmod
from ..core import search as smod
from ..core.index import QueryStats
from ..store.faults import CrashError
from ..store.props import words_to_mask
from ..store.ru import counters_for_latency, counters_for_ru

INF = jnp.float32(jnp.inf)


class AllPartitionsFailed(RuntimeError):
    """Zero partitions answered a fan-out: nothing to degrade to — the
    only case where partial-result degradation still hard-fails."""


# ---------------------------------------------------------------------------
# client-side fan-out (host path)
# ---------------------------------------------------------------------------


def merge_topk(
    ids_list: Sequence[np.ndarray], dists_list: Sequence[np.ndarray], k: int
) -> tuple[np.ndarray, np.ndarray]:
    """Merge per-partition (B, k_i) partial results into global (B, k)."""
    ids = np.concatenate(ids_list, axis=1)
    dists = np.concatenate(dists_list, axis=1)
    dists = np.where(ids >= 0, dists, np.inf)
    order = np.argsort(dists, axis=1)[:, :k]
    return np.take_along_axis(ids, order, 1), np.take_along_axis(dists, order, 1)


def fanout_search(
    partitions,  # Sequence[PhysicalPartition] or Sequence[ReplicaSet]
    queries: np.ndarray,
    k: int,
    L: Optional[int] = None,
    latency_model=None,
    hedge_at_ms: Optional[float] = None,
    rng: Optional[np.random.RandomState] = None,
) -> tuple[np.ndarray, np.ndarray, dict]:
    """Scatter to all partitions, gather, merge. Returns (ids, dists, info).

    info: per-partition RU, modelled server latencies, client latency
    (= max over partitions), hedges issued.
    """
    rng = rng or np.random.RandomState(0)
    ids_l, dists_l, rus, lats = [], [], [], []
    hedges = 0
    hedge_ru = 0.0
    for p in partitions:
        ids, dists, ru = p.search(queries, k, L)
        ids_l.append(ids)
        dists_l.append(dists)
        rus.append(ru)
        if latency_model is not None:
            lat = latency_model(p, rng)
            if hedge_at_ms is not None and lat > hedge_at_ms:
                hedges += 1
                # a hedge is a SECOND server-side execution on another
                # replica: the fastest answer wins the latency race, but
                # both executions did the work — the duplicate bills too
                hedge_ru += ru
                lat = min(lat, latency_model(p, rng))  # hedged duplicate
            lats.append(lat)
    ids, dists = merge_topk(ids_l, dists_l, k)
    info = dict(
        ru_per_partition=rus,
        ru_total=float(np.sum(rus)) + hedge_ru,
        server_latencies_ms=lats,
        client_latency_ms=float(np.max(lats)) if lats else 0.0,
        hedges=hedges,
        hedge_ru=hedge_ru,
    )
    return ids, dists, info


def batched_fanout_search(
    partitions,  # Sequence[PhysicalPartition]
    queries: np.ndarray,  # (B, D) — a dense micro-batch of independent queries
    k: int,
    L: Optional[int] = None,
    batch_buckets: Optional[tuple[int, ...]] = None,
    beam_width: Optional[int] = None,
    health=None,  # optional callable(partition) -> bool (replica liveness)
) -> tuple[np.ndarray, np.ndarray, dict]:
    """Multi-query scatter/gather for the serving engine.

    Unlike ``fanout_search`` (one logical query, per-partition bookkeeping),
    this dispatches a whole micro-batch to every partition as ONE
    fixed-shape device call (padded to `batch_buckets`), then merges the
    per-partition top-k. info carries total RU, per-partition RU/stats, and
    the modelled worst-partition latency (client latency tracks the slowest
    partition, §4.3).

    The latency model is *round-structured* (``store.ru
    .counters_for_latency``): a beam-width round's quantized reads issue
    concurrently and its adjacency fetches coalesce into one round trip.
    RU, by contrast, still charges every read (see
    ``PhysicalPartition.search_batch``): W buys latency, not free work.
    """
    kw: dict = {}
    if batch_buckets is not None:
        kw = dict(pad_to_bucket=True, batch_buckets=batch_buckets)
    if beam_width is not None:
        kw["beam_width"] = beam_width
    ids_l, dists_l, rus, lat_ms = [], [], [], []
    stats_l = []
    failed: list[tuple[int, str]] = []
    for p in partitions:
        if health is not None and not health(p):
            failed.append((int(p.pid), "replica set down"))
            continue
        try:
            ids, dists, ru, stats = p.search_batch(queries, k, L, **kw)
        except CrashError:
            raise  # an injected process kill is not a partition fault
        except Exception as e:  # noqa: BLE001 — degrade, don't collapse
            failed.append((int(p.pid), f"{type(e).__name__}: {e}"))
            continue
        ids_l.append(ids)
        dists_l.append(dists)
        rus.append(ru)
        stats_l.append(stats)
        lat_ms.append(
            p.providers.meter.latency_ms(counters_for_latency(stats))
        )
    if failed and not ids_l:
        raise AllPartitionsFailed(
            f"all {len(list(partitions))} partitions failed: {failed}"
        )
    if ids_l:
        ids, dists = merge_topk(ids_l, dists_l, k)
    else:  # empty collection: nothing failed, nothing to merge
        ids = np.full((len(queries), k), -1, np.int64)
        dists = np.full((len(queries), k), np.inf, np.float32)
    info = dict(
        partition_ids=[int(p.pid) for p in partitions],
        ru_per_partition=rus,
        ru_total=float(np.sum(rus)) if rus else 0.0,
        stats_per_partition=stats_l,
        server_latencies_ms=lat_ms,
        service_latency_ms=float(np.max(lat_ms)) if lat_ms else 0.0,
        failed_partitions=failed,
        complete=not failed,
    )
    return ids, dists, info


def compile_partition_filter(p, predicate):
    """Compile ``predicate`` against one partition's property-term index.
    Returns (bool slot mask, packed uint32 words, posting reads billed);
    mask and words are None when the predicate matches nothing in this
    partition. Pure bitmap algebra over the inverted PROP_TERM postings,
    cached per (partition, canonical predicate) and invalidated by ingest
    epoch. Never touches the doc store or ``doc_to_slot``. The words are
    already in the ``filter_bits`` layout, so the β-search path consumes
    them directly without a re-pack."""
    words = p.props.compile(predicate)
    nreads = p.props.last_compile_reads
    if not words.any():
        return None, None, nreads
    return words_to_mask(words, p.index.cfg.capacity), words, nreads


def batched_filtered_fanout_search(
    partitions,  # Sequence[PhysicalPartition]
    queries: np.ndarray,  # (B, D) — a micro-batch sharing ONE predicate
    k: int,
    predicate,  # serve.predicate.Predicate (canonical, hashable)
    L: Optional[int] = None,
    batch_buckets: Optional[tuple[int, ...]] = None,
    beam_width: Optional[int] = None,
    health=None,  # optional callable(partition) -> bool (replica liveness)
) -> tuple[np.ndarray, np.ndarray, dict]:
    """Multi-query scatter/gather for FILTERED micro-batches: every lane
    shares the same canonical predicate (the engine groups by predicate
    key), so the predicate compiles to one bitmap per partition —
    broadcast through ``bucketed_batch_greedy_search`` via the
    ``filter_bits`` plumbing — instead of one O(capacity) document scan
    per query per partition (the legacy callable path).

    Empty partitions and partitions where the predicate matches nothing
    are skipped outright (no bitmap minted, no search run). info carries
    the per-partition plan aggregate as ``plan`` (e.g.
    ``filtered-batched[beta×2,qflat×1]``), RU/stats/latency in the same
    shape as ``batched_fanout_search``.
    """
    kw: dict = {}
    if batch_buckets is not None:
        kw = dict(pad_to_bucket=True, batch_buckets=batch_buckets)
    if beam_width is not None:
        kw["beam_width"] = beam_width
    B, k = len(queries), int(k)
    ids_l, dists_l, rus, lat_ms, stats_l = [], [], [], [], []
    pids: list[int] = []
    plans: dict[str, int] = {}
    compile_ru = 0.0
    failed: list[tuple[int, str]] = []
    answered = 0  # searched OR legitimately skipped (known-empty) partitions
    for p in partitions:
        if p.num_docs == 0:
            answered += 1
            continue
        if health is not None and not health(p):
            failed.append((int(p.pid), "replica set down"))
            continue
        try:
            mask, words, nreads = compile_partition_filter(p, predicate)
            if mask is None:
                # the compile still read postings (cache miss) — a no-match
                # partition is skipped, not free
                compile_ru += nreads * p.providers.meter.cfg.ru_per_prop_read
                answered += 1
                continue
            ids, dists, ru, stats = p.filtered_search_batch(
                queries, k, mask, L=L, term_reads=nreads,
                filter_words=words, **kw
            )
        except CrashError:
            raise  # an injected process kill is not a partition fault
        except Exception as e:  # noqa: BLE001 — degrade, don't collapse
            failed.append((int(p.pid), f"{type(e).__name__}: {e}"))
            continue
        answered += 1
        ids_l.append(ids)
        dists_l.append(dists)
        rus.append(ru)
        stats_l.append(stats)
        pids.append(int(p.pid))
        plans[stats.plan] = plans.get(stats.plan, 0) + 1
        lat_ms.append(
            p.providers.meter.latency_ms(counters_for_latency(stats))
        )
    if failed and answered == 0:
        raise AllPartitionsFailed(
            f"all candidate partitions failed: {failed}"
        )
    if not ids_l:  # predicate matches nothing in any answering partition
        ids = np.full((B, k), -1, np.int64)
        dists = np.full((B, k), np.inf, np.float32)
        plan = "filtered-batched[empty]"
    else:
        ids, dists = merge_topk(ids_l, dists_l, k)
        plan = "filtered-batched[" + ",".join(
            f"{name}×{count}" for name, count in sorted(plans.items())
        ) + "]"
    info = dict(
        partition_ids=pids,
        ru_per_partition=rus,
        ru_total=(float(np.sum(rus)) if rus else 0.0) + compile_ru,
        stats_per_partition=stats_l,
        server_latencies_ms=lat_ms,
        service_latency_ms=float(np.max(lat_ms)) if lat_ms else 0.0,
        plan=plan,
        partitions_searched=len(ids_l),
        compile_ru=compile_ru,
        failed_partitions=failed,
        complete=not failed,
    )
    return ids, dists, info


# ---------------------------------------------------------------------------
# cross-partition pagination (§3.5 "Continuations" — client-side merge)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PartitionPageCursor:
    """One partition's slice of a cross-partition pagination.

    ``state`` is the partition-local ``PageState`` (dropped once the
    partition is exhausted, shrinking the token); ``buf_*`` hold results
    already fetched from the partition but not yet emitted in a merged
    page; ``fetch_hwm`` is the partition's high-water mark — the largest
    distance it has produced so far. A partition's page stream is
    ascending, so everything it will produce later is ≥ ``fetch_hwm``;
    the merge exploits that bound through its nonempty-buffer rule (see
    ``paged_fanout_search``), and the token decoder enforces the
    buffer-vs-hwm consistency a resumed token must satisfy.
    """

    pid: int
    state: Optional[pgmod.PageState]
    buf_ids: np.ndarray  # (n,) int64, ascending by buf_dists
    buf_dists: np.ndarray  # (n,) float32
    fetch_hwm: float = -np.inf
    exhausted: bool = False


@dataclasses.dataclass
class PagedQueryState:
    """The whole cross-partition continuation: one cursor per physical
    partition plus global merge bookkeeping. This object IS the token —
    ``serve.continuation`` round-trips it through a versioned, schema-
    checked numpy codec (never pickle: tokens are client-supplied bytes)."""

    shard_fp: int  # fingerprint of (shard_key, partition ids) at start
    emit_hwm: float  # largest distance emitted in any merged page
    pages: int  # merged pages emitted so far
    cursors: list[PartitionPageCursor]

    def exhausted(self) -> bool:
        return all(c.exhausted and len(c.buf_ids) == 0 for c in self.cursors)


def paged_fanout_fingerprint(shard_key, partitions, pred_key=None) -> int:
    """Bind a token to the routing that minted it: resuming under a
    different shard key — or after a split/merge changed the partition
    set, or under a DIFFERENT predicate (``pred_key`` = the predicate's
    canonical key bytes) — is rejected up front, not silently mis-merged."""
    from .partitioner import hash_key

    ident: tuple = (repr(shard_key), tuple(int(p.pid) for p in partitions))
    if pred_key is not None:
        ident += (pred_key,)
    return hash_key(ident)


def start_paged_fanout(partitions, query: np.ndarray, shard_key=None,
                       L: Optional[int] = None, pred_key=None,
                       slot_filters: Optional[Sequence] = None) -> PagedQueryState:
    """Open one pagination cursor per physical partition. With
    ``slot_filters`` (one compiled predicate mask — or None — per
    partition, index-aligned), partitions where the predicate matches
    nothing start exhausted: no cursor state is minted and no page is
    ever fetched from them."""
    query = np.asarray(query, np.float32)
    cursors = []
    for i, p in enumerate(partitions):
        dead = (slot_filters is not None and slot_filters[i] is None) \
            or p.num_docs == 0
        cursors.append(PartitionPageCursor(
            pid=int(p.pid),
            state=None if dead else p.start_pagination(query, L=L),
            buf_ids=np.zeros((0,), np.int64),
            buf_dists=np.zeros((0,), np.float32),
            exhausted=dead,
        ))
    return PagedQueryState(
        shard_fp=paged_fanout_fingerprint(shard_key, partitions, pred_key),
        emit_hwm=-np.inf, pages=0, cursors=cursors,
    )


def _fetch_partition_page(p, cur: PartitionPageCursor, query: np.ndarray,
                          k: int, beam_width: Optional[int],
                          slot_filter=None) -> tuple[float, float]:
    """Pull one page from partition ``p`` into the cursor's buffer.
    Returns (ru, modelled latency ms) for this fetch."""
    ids, dists, state, ru, stats = p.next_page(
        query, cur.state, k=k, beam_width=beam_width, slot_filter=slot_filter
    )
    lat_ms = p.providers.meter.latency_ms(counters_for_latency(stats))
    ids, dists = np.asarray(ids), np.asarray(dists)
    valid = (ids >= 0) & np.isfinite(dists)
    ids = ids[valid].astype(np.int64)
    dists = dists[valid].astype(np.float32)
    cur.state = state
    if len(ids):
        cur.fetch_hwm = max(cur.fetch_hwm, float(dists.max()))
        bi = np.concatenate([cur.buf_ids, ids])
        bd = np.concatenate([cur.buf_dists, dists])
        # re-sort: full-precision re-rank can jitter the tail ordering
        order = np.argsort(bd, kind="stable")
        cur.buf_ids, cur.buf_dists = bi[order], bd[order]
    # an empty page means "done" only on the unfiltered path: a filtered
    # page can legitimately carry zero matches while the traversal still
    # has unvisited region — exhaustion there is the traversal's call
    if (len(ids) == 0 and slot_filter is None) or bool(pgmod.exhausted(state)):
        cur.exhausted = True
        cur.state = None  # nothing left to resume — shrink the token
    return ru, lat_ms


def paged_fanout_search(
    partitions,  # Sequence[PhysicalPartition], index-aligned with cursors
    query: np.ndarray,  # (D,)
    pstate: PagedQueryState,
    page_size: int,
    beam_width: Optional[int] = None,
    slot_filters: Optional[Sequence] = None,  # per-partition masks or None
    executor=None,  # serve.executor.LaneExecutor: lane-scheduled refills
) -> tuple[np.ndarray, np.ndarray, dict]:
    """Produce the next globally-merged page across all partitions.

    Buffered k-way merge: before every emit, each non-exhausted partition
    holds a nonempty buffer, so the global buffer minimum is ≤ every
    partition's ``fetch_hwm`` — nothing still unfetched anywhere can beat
    it. Emitted results therefore never repeat and never skip, and the
    per-partition leftovers ride along in the continuation token.

    Refills run as multi-cursor ROUNDS: every starved partition pulls one
    ``next_page`` per round until all buffers are non-empty. With an
    ``executor`` each round books its fetches across the replica lanes
    and service latency is the lane horizon of the whole page — the max
    fetch per round with ≥P lanes, the host-loop sum with one lane;
    without one, the legacy accounting stands (max of per-partition
    sums). The fetch sequence per partition is identical either way, so
    results, cursors and RU never depend on the executor. info also
    carries the fixed per-request RU floor — a continuation request is
    never free, even when a page is served entirely from the token's
    buffers (§2.2: every request bills at least the request-processing
    charge).
    """
    assert len(partitions) == len(pstate.cursors), \
        "cursors must be index-aligned with the partition routing"
    query = np.asarray(query, np.float32)
    n = len(partitions)
    out_ids: list[int] = []
    out_dists: list[float] = []
    rus = [0.0] * n
    lat_sums = [0.0] * n
    fetches = 0
    exec_ms = 0.0
    rounds = 0
    # per-fetch log (round, pid, ru, lat_ms) — the trace plane turns each
    # entry into one child span of the page's lane span
    fetch_log: list[dict] = []

    def _refill_rounds():
        nonlocal fetches, exec_ms, rounds
        while True:
            round_lats = []
            for i, (p, cur) in enumerate(zip(partitions, pstate.cursors)):
                if cur.exhausted or len(cur.buf_ids):
                    continue
                ru, lat = _fetch_partition_page(
                    p, cur, query, page_size, beam_width,
                    slot_filter=None if slot_filters is None
                    else slot_filters[i],
                )
                rus[i] += ru
                lat_sums[i] += lat
                round_lats.append(lat)
                fetch_log.append(dict(round=rounds, pid=int(p.pid),
                                      ru=float(ru), lat_ms=float(lat)))
                fetches += 1
            if not round_lats:
                return
            rounds += 1
            if executor is not None:
                # schedule_round returns the lane horizon relative to the
                # (unmoving) clock; successive rounds stack on the same
                # lanes, so the LAST horizon is the page's total makespan
                # — taking the max, not the sum, avoids double counting
                exec_ms = max(exec_ms, executor.schedule_round(round_lats))

    while len(out_ids) < page_size:
        _refill_rounds()
        heads = [
            (float(cur.buf_dists[0]), i)
            for i, cur in enumerate(pstate.cursors) if len(cur.buf_ids)
        ]
        if not heads:
            break  # every partition exhausted and drained
        d, i = min(heads)
        cur = pstate.cursors[i]
        out_ids.append(int(cur.buf_ids[0]))
        out_dists.append(d)
        cur.buf_ids = cur.buf_ids[1:]
        cur.buf_dists = cur.buf_dists[1:]
        pstate.emit_hwm = max(pstate.emit_hwm, d)
    pstate.pages += 1

    ids = np.full((page_size,), -1, np.int64)
    dists = np.full((page_size,), np.inf, np.float32)
    ids[: len(out_ids)] = out_ids
    dists[: len(out_dists)] = out_dists
    request_ru = (
        partitions[0].providers.meter.cfg.ru_per_page_request if n else 0.0
    )
    info = dict(
        partition_ids=[int(p.pid) for p in partitions],
        ru_per_partition=rus,
        request_ru=request_ru,
        ru_total=float(np.sum(rus)) + request_ru,
        fetch_log=fetch_log,
        server_latencies_ms=lat_sums,
        service_latency_ms=(exec_ms if executor is not None
                            else float(np.max(lat_sums)) if lat_sums else 0.0),
        lane_scheduled=executor is not None,
        pages_fetched=fetches,
        emit_hwm=pstate.emit_hwm,  # how deep into the result set we are
        exhausted=pstate.exhausted(),
    )
    return ids, dists, info


# ---------------------------------------------------------------------------
# device-parallel fan-out (jitted shard_map path — used by the dry-run)
# ---------------------------------------------------------------------------


def distributed_search_fn(
    mesh: jax.sharding.Mesh,
    *,
    L: int,
    k: int,
    metric: str = "l2",
    shard_axes: tuple[str, ...] = ("data",),
    max_hops: int = 0,
    beam_width: int = 1,
):
    """Build the jitted cross-partition search step for a device mesh.

    The returned fn takes shard-stacked index arrays (leading axis = number
    of shards = product of `shard_axes` sizes) and a replicated query batch;
    each device searches its shard and the results merge with one
    all_gather — the SDK's scatter/gather as collectives.
    """
    spec_sharded = P(shard_axes)
    spec_repl = P()

    def local_search(neighbors, codes, versions, live, vectors, doc_ids,
                     medoid, codebooks, queries):
        # leading shard axis is 1 inside shard_map; codebooks are PER SHARD
        # (each partition quantizes independently, as in the paper — using
        # one shard's schema for all shards silently wrecks distances)
        neighbors, codes, versions = neighbors[0], codes[0], versions[0]
        live, vectors, doc_ids, medoid = live[0], vectors[0], doc_ids[0], medoid[0]

        schema = pqmod.PQSchema(codebooks=codebooks[0], version=jnp.int32(0))
        luts = jax.vmap(lambda q: pqmod.adc_lut(schema, q, metric))(queries)[:, None]
        res = smod.batch_greedy_search(
            neighbors, codes, versions, live, luts, medoid,
            L=L, max_hops=max_hops, beam_width=beam_width,
        )
        lids, ldists = fmod.rerank(queries, res.beam_ids[:, : 2 * k], vectors,
                                   k=k, metric=metric)
        gdoc = jnp.where(lids >= 0, doc_ids[jnp.maximum(lids, 0)], -1)

        # gather partial results from every shard and merge
        all_ids = gdoc
        all_d = jnp.where(lids >= 0, ldists, INF)
        for ax in shard_axes:
            all_ids = jax.lax.all_gather(all_ids, ax, axis=0, tiled=False)
            all_d = jax.lax.all_gather(all_d, ax, axis=0, tiled=False)
            all_ids = all_ids.reshape((-1,) + all_ids.shape[2:]) if all_ids.ndim > 3 else all_ids
            all_d = all_d.reshape((-1,) + all_d.shape[2:]) if all_d.ndim > 3 else all_d
        # (S, B, k) -> (B, S*k) -> top-k
        S = all_d.shape[0]
        flat_d = jnp.moveaxis(all_d, 0, 1).reshape(queries.shape[0], S * k)
        flat_i = jnp.moveaxis(all_ids, 0, 1).reshape(queries.shape[0], S * k)
        neg, pos = jax.lax.top_k(-flat_d, k)
        out_ids = jnp.take_along_axis(flat_i, pos, axis=1)
        return out_ids, -neg

    shmapped = compat.shard_map(
        local_search,
        mesh,
        in_specs=(
            spec_sharded, spec_sharded, spec_sharded, spec_sharded,
            spec_sharded, spec_sharded, spec_sharded, spec_sharded, spec_repl,
        ),
        out_specs=(spec_repl, spec_repl),
        check=False,
    )
    return jax.jit(shmapped)


# ---------------------------------------------------------------------------
# engine-facing SPMD fan-out (EngineConfig.dispatch_mode="spmd")
# ---------------------------------------------------------------------------

_SPMD_PROGRAMS: list = []


def spmd_jit_cache_size() -> int:
    """Compiled-signature count across every SpmdFanout program. Feeds
    ``serve.vector_engine.serving_jit_cache_size`` so the zero-recompile
    contract covers the spmd dispatch path too."""
    n = 0
    for f in _SPMD_PROGRAMS:
        try:
            n += int(f._cache_size())
        except AttributeError:
            pass
    return n


class SpmdFanout:
    """One jitted shard_map dispatch driving every partition's search.

    Where ``batched_fanout_search`` loops partitions on the host — one
    device call per partition — this stacks the live partitions' provider
    arrays along a leading axis, shards that axis over ``mesh``, and runs
    the bucketed graph search + full-precision re-rank for ALL partitions
    as one data-parallel program (inner `vmap` over the device-local
    partitions). The per-partition merge stays on the host, in original
    partition order, so results are **bit-identical** to the serial loop:
    LUTs come from the very same host jitted calls (`DiskANNIndex._luts`
    on the bucket-padded queries), and a vmapped while_loop carries each
    lane's state through `select` once finished — the same numerics the
    serial path runs, just batched one level higher.

    Caching discipline (the zero-recompile contract):
      * programs are cached per (L_eff, k, k', W, metric) closure — shape
        changes (bucket, partition count, V) hit jit's own cache, and
        every program registers in `spmd_jit_cache_size`;
      * the stacked arrays are cached per partition-set and invalidated
        by each partition's ``providers.write_count`` epoch (plus count /
        schema-count / medoid, which can move without a provider write).

    Partitions whose graph isn't built (or that are empty) fall back to
    the host ``search_batch`` — the same call the serial path makes — and
    their results interleave back at their original merge position. RU is
    metered on each partition's own meter/governor exactly like
    ``PhysicalPartition.search_batch`` (work-based counters, per-lane).
    """

    def __init__(self, mesh: jax.sharding.Mesh):
        self.mesh = mesh
        self.n_devices = int(np.prod(mesh.devices.shape))
        self._programs: dict = {}
        self._stacks: dict = {}

    # -- stacked provider arrays (cached per write epoch) ----------------
    def _stacked(self, prog_parts, P_pad: int) -> dict:
        key = tuple(id(p) for p in prog_parts) + (P_pad,)
        stamp = tuple(
            (p.providers.write_count, p.index.count, len(p.index.schemas),
             int(p.index.medoid))
            for p in prog_parts
        )
        hit = self._stacks.get(key)
        if hit is not None and hit[0] == stamp:
            return hit[1]
        # pad the partition axis to the mesh size by repeating partition 0
        # (its results are computed and discarded — never merged)
        all_p = list(prog_parts) + [prog_parts[0]] * (P_pad - len(prog_parts))
        mats = [p.index.pv.materialize(p.index.ctx) for p in all_p]
        arrs = dict(
            neighbors=jnp.stack([m[0] for m in mats]),
            codes=jnp.stack([m[1] for m in mats]),
            versions=jnp.stack([m[2] for m in mats]),
            live=jnp.stack([m[3] for m in mats]),
            vectors=jnp.stack([m[4] for m in mats]),
            # x64 is disabled: the doc-id table rides along as int32 and
            # widens back to int64 on the host
            slot_to_doc=jnp.asarray(np.stack(
                [p.index.slot_to_doc for p in all_p]).astype(np.int32)),
            medoid=jnp.asarray([p.index.medoid for p in all_p], jnp.int32),
        )
        self._stacks[key] = (stamp, arrs)
        return arrs

    # -- the jitted program (cached per static closure) ------------------
    def _program(self, L_eff: int, k: int, kprime: int, W: int, metric: str):
        key = (L_eff, k, kprime, W, metric)
        fn = self._programs.get(key)
        if fn is not None:
            return fn
        axes = tuple(self.mesh.axis_names)
        sh, rep = P(axes), P()

        def local(neighbors, codes, versions, live, vectors, s2d, medoid,
                  luts, queries):
            # block shapes: (P_local, ...) per device; queries replicated
            def one_partition(nb, cd, vr, lv, vc, sd, md, lt):
                res = smod.batch_greedy_search(
                    nb, cd, vr, lv, lt, md, L=L_eff, beam_width=W
                )
                ids, dists = fmod.rerank(
                    queries, res.beam_ids[:, :kprime], vc, k=k, metric=metric
                )
                doc = jnp.where(ids >= 0, sd[jnp.maximum(ids, 0)], -1)
                # beam ids ride back out so the host can meter the paged
                # vector tier on the SAME candidate set the rerank read
                return (doc, dists, res.n_hops, res.n_exp, res.n_cmps,
                        res.beam_ids[:, :kprime])

            return jax.vmap(one_partition)(
                neighbors, codes, versions, live, vectors, s2d, medoid, luts
            )

        fn = jax.jit(compat.shard_map(
            local, self.mesh,
            in_specs=(sh,) * 8 + (rep,),
            out_specs=(sh,) * 6,
            check=False,
        ))
        self._programs[key] = fn
        _SPMD_PROGRAMS.append(fn)
        return fn

    # -- the engine entry point ------------------------------------------
    def search(
        self,
        partitions,  # Sequence[PhysicalPartition]
        queries: np.ndarray,  # (B, D)
        k: int,
        L: Optional[int] = None,
        batch_buckets: tuple[int, ...] = smod.BATCH_BUCKETS,
        beam_width: Optional[int] = None,
        rerank_multiplier: float = fmod.QUANTIZED_LIST_MULTIPLIER,
        health=None,  # optional callable(partition) -> bool
    ) -> tuple[np.ndarray, np.ndarray, dict]:
        """Drop-in for ``batched_fanout_search``: same (ids, dists, info)."""
        parts = list(partitions)
        queries = np.asarray(queries, np.float32)
        B, k = len(queries), int(k)
        n = len(parts)
        failed: list[tuple[int, str]] = []
        down = set()
        for i, p in enumerate(parts):
            if health is not None and not health(p):
                down.add(i)
                failed.append((int(p.pid), "replica set down"))
        prog_idx = [i for i, p in enumerate(parts)
                    if i not in down
                    and p.index._graph_built and p.num_docs > 0]
        in_prog = set(prog_idx)

        ids_by: list = [None] * n
        d_by: list = [None] * n
        rus: list = [0.0] * n
        stats_by: list = [None] * n
        lat_by: list = [0.0] * n

        # host fallback — identical to the serial loop's search_batch call
        W = int(beam_width) if beam_width is not None else None
        for i, p in enumerate(parts):
            if i in in_prog or i in down:
                continue
            kw: dict = dict(pad_to_bucket=True, batch_buckets=batch_buckets)
            if W is not None:
                kw["beam_width"] = W
            try:
                ids, dists, ru, stats = p.search_batch(queries, k, L, **kw)
            except CrashError:
                raise  # an injected process kill is not a partition fault
            except Exception as e:  # noqa: BLE001 — degrade, don't collapse
                down.add(i)
                failed.append((int(p.pid), f"{type(e).__name__}: {e}"))
                continue
            ids_by[i], d_by[i], rus[i], stats_by[i] = ids, dists, ru, stats
            lat_by[i] = p.providers.meter.latency_ms(
                counters_for_latency(stats))

        if prog_idx:
            prog_parts = [parts[i] for i in prog_idx]
            idx0 = prog_parts[0].index
            W_eff = W or idx0.cfg.beam_width
            L_req = int(L or idx0.cfg.L_search)
            kprime = max(k, int(round(rerank_multiplier * k)))
            L_eff = max(L_req, kprime)
            bucket = smod.next_bucket(B, batch_buckets)
            padded = smod.pad_batch_np(queries, bucket)

            # per-partition LUTs from the SAME host jitted calls the serial
            # path makes (identical inputs → identical tables, bit for bit);
            # the V axis pads to the widest schema set by repeating the last
            # table — padded tables are never selected (versions < V_p)
            luts = [p.index._luts(padded) for p in prog_parts]
            V_max = max(lt.shape[1] for lt in luts)
            luts = [
                lt if lt.shape[1] == V_max else jnp.concatenate(
                    [lt, jnp.broadcast_to(
                        lt[:, -1:],
                        (lt.shape[0], V_max - lt.shape[1]) + lt.shape[2:])],
                    axis=1)
                for lt in luts
            ]
            P_n = len(prog_parts)
            P_pad = -(-P_n // self.n_devices) * self.n_devices
            luts_st = jnp.stack(list(luts) + [luts[0]] * (P_pad - P_n))
            arrs = self._stacked(prog_parts, P_pad)
            fn = self._program(L_eff, k, kprime, int(W_eff),
                               idx0.cfg.metric)
            doc, dist, hops, exps, cmps, beams = fn(
                arrs["neighbors"], arrs["codes"], arrs["versions"],
                arrs["live"], arrs["vectors"], arrs["slot_to_doc"],
                arrs["medoid"], luts_st, jnp.asarray(padded),
            )
            doc, dist = np.asarray(doc), np.asarray(dist)
            hops, exps, cmps = (np.asarray(hops), np.asarray(exps),
                                np.asarray(cmps))
            beams = np.asarray(beams)
            for j, i in enumerate(prog_idx):
                p = parts[i]
                st = QueryStats(
                    hops=float(hops[j, :B].mean()),
                    cmps=float(cmps[j, :B].mean()),
                    expansions=float(exps[j, :B].mean()),
                    full_reads=float(kprime),
                    plan="graph-spmd",
                )
                # paged-tier metering on the identical candidate pages the
                # serial path touches (same pin→touch→unpin sequence, so
                # cache state and hit/miss counts match bit for bit)
                pages = getattr(p.providers, "pages", None)
                if pages is not None:
                    th, tm, pinned = pages.touch(beams[j, :B], pin=True)
                    pages.unpin(pinned)
                    st.tier_hits = th / max(B, 1)
                    st.tier_misses = tm / max(B, 1)
                # meter exactly like PhysicalPartition.search_batch: the
                # work ran on the mesh, but it is THIS partition's work
                pv = p.providers
                pv.begin_op()
                pv.op += counters_for_ru(st, lanes=B)
                ru, _ = pv.end_op()
                p.governor.request(ru)
                ids_by[i] = doc[j, :B].astype(np.int64)
                d_by[i] = dist[j, :B]
                rus[i], stats_by[i] = ru, st
                lat_by[i] = pv.meter.latency_ms(counters_for_latency(st))

        ok = [i for i in range(n) if ids_by[i] is not None]
        if failed and not ok:
            raise AllPartitionsFailed(
                f"all {n} partitions failed: {failed}"
            )
        if ok:
            ids, dists = merge_topk([ids_by[i] for i in ok],
                                    [d_by[i] for i in ok], k)
        else:
            ids = np.full((B, k), -1, np.int64)
            dists = np.full((B, k), np.inf, np.float32)
        info = dict(
            partition_ids=[int(p.pid) for p in parts],
            ru_per_partition=[rus[i] for i in ok],
            ru_total=float(np.sum([rus[i] for i in ok])) if ok else 0.0,
            stats_per_partition=[stats_by[i] for i in ok],
            server_latencies_ms=[lat_by[i] for i in ok],
            service_latency_ms=(float(np.max([lat_by[i] for i in ok]))
                                if ok else 0.0),
            spmd=dict(partitions_in_program=len(prog_idx),
                      mesh_devices=self.n_devices),
            failed_partitions=failed,
            complete=not failed,
        )
        return ids, dists, info
