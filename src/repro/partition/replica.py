"""Replica sets — availability and durability (§2.2, §5.3).

Cosmos DB keeps four data replicas per partition by default (vs. one in
Pinecone serverless — a point §5.3 presses). We model the replica-set
control plane faithfully enough to demonstrate the fault-tolerance story:

  * quorum writes: an insert acks after ⌈(R+1)/2⌉ replicas apply it; lagging
    replicas catch up from the WAL;
  * failover: killing the primary promotes the most-caught-up secondary;
    a replacement replica rebuilds from snapshot + WAL replay;
  * read spreading: queries round-robin over healthy replicas, which is
    what fan-out hedging exploits for stragglers.

One authoritative StoreProviderSet holds the data; replicas are modeled as
(applied-LSN, alive) cursors over its WAL — the realistic bookkeeping
without 4× memory. `rebuild()` exercises the real snapshot/WAL recovery
path from repro.store.provider.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ReplicaState:
    rid: int
    alive: bool = True
    applied_lsn: int = 0
    reads: int = 0  # queries served by this replica (read spreading)
    down_since_s: float = 0.0  # when the replica died (re-probe cooldown)


class ReplicaSet:
    def __init__(self, partition, num_replicas: int = 4,
                 reprobe_after_s: float = 5.0):
        self.partition = partition  # PhysicalPartition with StoreProviderSet
        self.replicas = [ReplicaState(i) for i in range(num_replicas)]
        self.primary = 0
        self.lsn = 0
        self.failovers = 0
        self.reprobe_after_s = float(reprobe_after_s)
        self.recoveries = 0
        self._rr = 0

    # ------------------------------------------------------------------
    @property
    def quorum(self) -> int:
        return len(self.replicas) // 2 + 1

    def healthy(self) -> list[ReplicaState]:
        return [r for r in self.replicas if r.alive]

    def add_replica(self) -> ReplicaState:
        """Scale-out actuation (serve/policy.py): a new replica joins at
        the set's current LSN — in this model the authoritative store
        already holds every applied write, so the joiner is immediately
        caught up (the real path would seed it via ``capture()`` +
        WAL replay, which ``rebuild()`` exercises). Quorum grows with
        the set (⌈(R+1)/2⌉ over the new count)."""
        r = ReplicaState(rid=len(self.replicas), applied_lsn=self.lsn)
        self.replicas.append(r)
        return r

    # ------------------------------------------------------------------
    def insert(self, doc_ids, pk_hashes, vectors: np.ndarray, props=None):
        """Write through the primary; ack at quorum."""
        if not self.replicas[self.primary].alive:
            self.failover()
        out = self.partition.insert(doc_ids, pk_hashes, vectors, props=props)
        self.lsn += 1
        acked = 0
        for r in self.healthy():
            r.applied_lsn = self.lsn  # synchronous apply in-model
            acked += 1
        if acked < self.quorum:
            raise RuntimeError(
                f"write cannot reach quorum ({acked}/{self.quorum}) — partition offline"
            )
        return out

    def search(self, queries, k, L=None, **kw):
        """Read-spread across healthy replicas (round robin): the cursor
        actually SELECTS the serving replica — dead replicas receive no
        reads, and per-replica read counts make the spreading observable
        (it is what fan-out hedging exploits for stragglers)."""
        healthy = self.healthy()
        if not healthy:
            raise RuntimeError("no healthy replicas")
        replica = healthy[self._rr % len(healthy)]
        self._rr = (self._rr + 1) % len(healthy)
        replica.reads += 1
        return self.partition.search(queries, k, L, **kw)

    def note_read(self, rid: int):
        """Attribute one externally-routed read (the engine's lane plane
        routes reads itself; this keeps per-replica counts observable)."""
        self.replicas[rid].reads += 1

    def read_counts(self) -> dict[int, int]:
        return {r.rid: r.reads for r in self.replicas}

    # ------------------------------------------------------------------
    # failures
    # ------------------------------------------------------------------
    def kill(self, rid: int, now_s: float = 0.0):
        r = self.replicas[rid]
        if not r.alive:
            return
        r.alive = False
        r.down_since_s = float(now_s)
        if rid == self.primary:
            self.failover()

    def probe_dead(self, now_s: float) -> list[int]:
        """Re-probe dead replicas whose cooldown has elapsed and bring
        them back through the real rebuild path — a dead replica is not
        dead forever. Returns the rids revived this probe."""
        revived = []
        for r in self.replicas:
            if not r.alive and now_s - r.down_since_s >= self.reprobe_after_s:
                self.rebuild(r.rid)
                self.recoveries += 1
                revived.append(r.rid)
        return revived

    def failover(self):
        """Promote the most-caught-up healthy secondary."""
        healthy = self.healthy()
        if not healthy:
            raise RuntimeError("total partition loss")
        self.primary = max(healthy, key=lambda r: r.applied_lsn).rid
        self.failovers += 1

    def capture(self) -> tuple[bytes, bytes, int, int]:
        """Atomically capture ``(snapshot, wal, set_lsn, store_lsn)``: the
        replica-set LSN is read *with* the snapshot/WAL pair, so a rebuild
        finishing later cannot claim writes that landed after the capture."""
        pv = self.partition.providers
        lsn = self.lsn
        snap = pv.snapshot_bytes()
        wal = pv.wal_bytes()
        return snap, wal, lsn, pv.committed

    def rebuild(self, rid: int, capture=None):
        """Replace a dead replica: snapshot + WAL replay through the real
        recovery path. The revived replica's ``applied_lsn`` is the LSN
        captured with the snapshot/WAL pair — NOT the set's current LSN,
        which may have advanced past what the pair contains; a lagging
        rebuild comes back behind and catches up like any other replica."""
        snap, wal, lsn, store_lsn = capture or self.capture()
        pv = self.partition.providers
        fresh = type(pv)(
            pv.neighbors.shape[0], pv.neighbors.shape[1],
            pv.codes.shape[1], pv.vectors.shape[1],
        )
        applied = fresh.recover(snap, wal)
        assert applied == store_lsn, (
            f"rebuild replayed {applied} committed records, capture had "
            f"{store_lsn}"
        )
        if lsn == self.lsn:  # nothing landed since capture: full parity
            assert np.array_equal(fresh.live, pv.live)
        self.replicas[rid].alive = True
        self.replicas[rid].applied_lsn = lsn
        return fresh
