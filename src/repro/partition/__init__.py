"""repro.partition — scale-out: logical→physical partitioning, fan-out.

Cosmos DB collections span physical partitions by hashed partition-key
ranges (§2.2); vector queries fan out to every partition and the SDK merges
partial results client-side (§3.5 "SDK Query Plan", §4.3). Reproduced here:

    partitioner.py  Collection: hash ranges → PhysicalPartition (each its own
                    DiskANN index + store + RU governor), split/merge
                    elasticity, 50 GB-partition-limit analogue
    fanout.py       cross-partition scatter/gather with client-side top-k
                    merge, continuation handling, hedged requests
                    (straggler mitigation), and the jitted `shard_map`
                    device-parallel search used by the multi-pod dry-run
    replica.py      replica sets: quorum writes, failover, read spreading
"""
from .partitioner import Collection, CollectionConfig, PhysicalPartition
from .fanout import (PagedQueryState, PartitionPageCursor,
                     distributed_search_fn, fanout_search,
                     paged_fanout_search, start_paged_fanout)
from .replica import ReplicaSet

__all__ = [
    "Collection",
    "CollectionConfig",
    "PhysicalPartition",
    "fanout_search",
    "distributed_search_fn",
    "paged_fanout_search",
    "start_paged_fanout",
    "PagedQueryState",
    "PartitionPageCursor",
    "ReplicaSet",
]
