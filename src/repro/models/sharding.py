"""Sharding rules: parameters, activations, caches → PartitionSpecs.

Scheme (MaxText-style 2D):
  * `data` axis: FSDP — every ≥2D weight shards its d_model-ish (first big)
    dimension over `data`;
  * `model` axis: TP — heads / ffn / vocab (last big) dimension over `model`;
  * MoE experts shard their leading E dimension over `model` (EP);
  * `pod` axis (multi-pod mesh): pure DP — composes with `data` on the batch
    dimension only, so cross-pod traffic is exactly the gradient all-reduce;
  * decode KV caches shard batch over `data` and the *sequence* dimension
    over `model` (flash-decoding-style split-KV — the only layout that fits
    32k–500k caches in HBM; softmax over the sharded S lowers to partial
    reductions + all-reduce under GSPMD);
  * every dim only shards when divisible by the axis size (e.g. hubert's
    vocab of 504 stays replicated on its V dim rather than failing).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from .. import compat

from .config import ModelConfig


def _axsize(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _fits(dim: int, mesh: Mesh, name: Optional[str]) -> Optional[str]:
    if name is None or name not in mesh.axis_names:
        return None
    return name if dim % _axsize(mesh, name) == 0 else None


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """Batch-sharding axes: ('pod','data') on multi-pod, ('data',) otherwise."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_spec(mesh: Mesh, batch: int, ndim: int) -> P:
    axes = dp_axes(mesh)
    total = int(np.prod([_axsize(mesh, a) for a in axes]))
    first = axes if batch % total == 0 else ()
    return P(first if first else None, *([None] * (ndim - 1)))


def param_spec(path: str, shape: tuple[int, ...], mesh: Mesh, stacked: bool) -> P:
    """Sharding rule for one parameter leaf.

    path: '/'-joined key path (e.g. 'blocks/mixer/wq'); stacked: leading L axis.
    """
    lead: list[Any] = [None] if stacked else []
    dims = shape[1:] if stacked else shape
    name = path.rsplit("/", 1)[-1]

    def spec(*entries):
        return P(*lead, *entries)

    if len(dims) == 0:
        return spec()
    if len(dims) == 1:
        # norms / biases / small vectors: shard over data when divisible
        return spec(_fits(dims[0], mesh, "data"))
    if name == "embed":  # (V, dm)
        return spec(_fits(dims[0], mesh, "model"), _fits(dims[1], mesh, "data"))
    if name == "lm_head":  # (dm, V)
        return spec(_fits(dims[0], mesh, "data"), _fits(dims[1], mesh, "model"))
    if name == "router":  # (dm, E) — replicate E for stable routing math
        return spec(_fits(dims[0], mesh, "data"), None)
    if len(dims) == 3:  # MoE expert stacks (E, dm, ff) / (E, ff, dm)
        return spec(
            _fits(dims[0], mesh, "model"),
            _fits(dims[1], mesh, "data"),
            None,
        )
    if len(dims) == 2:
        if name in ("wo", "w2", "out_proj", "wuk", "wuv"):
            # output-side projections: (big, dm) — model on the input dim
            return spec(_fits(dims[0], mesh, "model"), _fits(dims[1], mesh, "data"))
        # input-side projections: (dm, big)
        return spec(_fits(dims[0], mesh, "data"), _fits(dims[1], mesh, "model"))
    return spec(*([None] * len(dims)))


def param_specs(params: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching `params` (works on ShapeDtypeStructs).

    Every leaf under 'blocks' carries a leading segment-stack axis (see
    model.segments), so block params are always `stacked`."""

    def walk(tree, path, in_blocks):
        if isinstance(tree, dict):
            return {
                k: walk(v, f"{path}/{k}" if path else k, in_blocks or k == "blocks")
                for k, v in tree.items()
            }
        if isinstance(tree, (list, tuple)) and not hasattr(tree, "shape"):
            out = [walk(v, f"{path}/{i}", in_blocks) for i, v in enumerate(tree)]
            return type(tree)(out) if not hasattr(tree, "_fields") else type(tree)(*out)
        shape = tuple(tree.shape)
        return param_spec(path, shape, mesh, stacked=in_blocks)

    return walk(params, "", False)


def cache_specs(cache: Any, cfg: ModelConfig, mesh: Mesh, batch: int) -> Any:
    """KV/SSM cache specs: batch over dp axes, sequence over `model`.

    Caches are lists of per-segment stacks: leaves (seg_len, B, S, ...) or
    (seg_len, B, ...).
    """
    axes = dp_axes(mesh)
    total = int(np.prod([_axsize(mesh, a) for a in axes]))
    b_ax = axes if batch % total == 0 else None
    lead = 1

    def leaf_spec(a):
        shape = tuple(a.shape)
        entries: list[Any] = [None] * len(shape)
        if len(shape) <= lead:
            return P(*entries)
        entries[lead] = b_ax  # batch dim
        # sequence dim: caches (L,B,S,...) with S >= 1024 shard over model
        if len(shape) > lead + 1 and shape[lead + 1] >= 1024:
            entries[lead + 1] = _fits(shape[lead + 1], mesh, "model")
        elif len(shape) > lead + 1:
            # ssm states: (B, nh, hd, ds) — shard heads over model
            entries[lead + 1] = _fits(shape[lead + 1], mesh, "model")
        return P(*entries)

    return jax.tree.map(leaf_spec, cache)


def to_shardings(specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def constrain_batch_dim(x: jax.Array, extra: tuple = ()) -> jax.Array:
    """with_sharding_constraint(x, P(dp_axes, None, ...)) under the ambient
    mesh (steps.py traces inside `jax.sharding.use_mesh`). No-op without a
    mesh or when the batch dim doesn't divide — keeps model code mesh-free.

    Pinning activations' batch dim to the data axes stops GSPMD from
    replicating layer inputs across the mesh (measured: smollm train went
    from fully-replicated compute to properly sharded once constrained).
    """
    m = compat.get_abstract_mesh()
    if m is None or not m.axis_names:
        return x
    axes = tuple(a for a in ("pod", "data") if a in m.axis_names)
    if not axes:
        return x
    total = int(np.prod([m.shape[a] for a in axes]))
    if x.ndim == 0 or x.shape[0] % total != 0:
        return x
    rest = list(extra) + [None] * (x.ndim - 1 - len(extra))
    return jax.lax.with_sharding_constraint(x, P(axes, *rest))
