"""State-space sequence mixers: Mamba2 (SSD) and RWKV6 (Finch).

Both are implemented in the *chunkwise-parallel* form — the TPU-native
formulation where within-chunk interactions are (Q, Q) masked matmuls (MXU
work, visible FLOPs in the HLO) and only the O(S/Q) chunk-carry runs as a
`lax.scan`. Single-token recurrent steps are provided for decode; the pure
recurrent forms also serve as oracles in tests/test_ssm.py.

Mamba2 recurrence (per head h, state S ∈ R^{hd×ds}):
    S_t = exp(dt_t·A_h)·S_{t-1} + dt_t·(x_t ⊗ B_t);   y_t = S_t·C_t + D_h·x_t

RWKV6 recurrence (per head, state S ∈ R^{dk×dv}, per-channel decay w):
    o_t = r_t·(S_{t-1} + diag(u)·k_tᵀv_t);   S_t = diag(w_t)·S_{t-1} + k_tᵀv_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig, SSMConfig
from .layers import dense_init, rmsnorm, rmsnorm_init

def _chunk_scan(step, init, xs, unroll: bool):
    """lax.scan, or a python loop when `unroll` (dry-run cost extraction —
    XLA's cost analysis counts while bodies once; see launch/dryrun.py)."""
    if not unroll:
        return jax.lax.scan(step, init, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    carry, ys = init, []
    for i in range(n):
        carry, y = step(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    return carry, jnp.stack(ys, axis=0)


# ---------------------------------------------------------------------------
# Mamba2
# ---------------------------------------------------------------------------


def mamba2_init(key, cfg: ModelConfig, dtype) -> dict:
    s: SSMConfig = cfg.ssm
    dm = cfg.d_model
    din = s.expand * dm
    nh = din // s.head_dim
    conv_dim = din + 2 * s.d_state
    ks = jax.random.split(key, 4)
    return {
        # projections: z (gate), x, B, C, dt
        "in_proj": dense_init(ks[0], (dm, 2 * din + 2 * s.d_state + nh), dtype),
        "conv_w": dense_init(ks[1], (s.conv_width, conv_dim), dtype, scale=1.0),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),  # A = -exp(A_log) ∈ (-∞,0)
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "out_norm": rmsnorm_init(din, dtype),
        "out_proj": dense_init(ks[2], (din, dm), dtype),
    }


def _split_mamba(cfg: ModelConfig, proj: jax.Array):
    s = cfg.ssm
    din = s.expand * cfg.d_model
    nh = din // s.head_dim
    z, xs, Bc, Cc, dt = jnp.split(
        proj, [din, 2 * din, 2 * din + s.d_state, 2 * din + 2 * s.d_state], axis=-1
    )
    return z, xs, Bc, Cc, dt, din, nh


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq: x (B,S,C), w (W,C)."""
    W = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(W):
        shift = W - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1], :]
        out = out + xi * w[i]
    return jax.nn.silu(out + b)


def mamba2_forward(params: dict, cfg: ModelConfig, x: jax.Array,
                   return_state: bool = False):
    """Full-sequence chunked SSD. x (B, S, dm) -> (B, S, dm)[, final state]."""
    s = cfg.ssm
    B, S, dm = x.shape
    proj = x @ params["in_proj"]
    z, xs, Bc, Cc, dt, din, nh = _split_mamba(cfg, proj)
    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)
    conv_out = _causal_conv(conv_in, params["conv_w"], params["conv_b"])
    xs, Bc, Cc = jnp.split(conv_out, [din, din + s.d_state], axis=-1)

    hd, ds = s.head_dim, s.d_state
    xh = xs.reshape(B, S, nh, hd).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(params["A_log"])  # (nh,)
    Bc = Bc.astype(jnp.float32)
    Cc = Cc.astype(jnp.float32)

    Q = min(s.chunk, S)
    Sp = ((S + Q - 1) // Q) * Q
    if Sp != S:
        assert not return_state, "prefill length must be a chunk multiple"
        xh = jnp.pad(xh, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, Sp - S), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, Sp - S), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, Sp - S), (0, 0)))
    nchunk = Sp // Q

    def chunk_step(S_in, inp):
        xq, bq, cq, dtq = inp  # (B,Q,nh,hd),(B,Q,ds),(B,Q,ds),(B,Q,nh)
        la = jnp.cumsum(dtq * A, axis=1)  # (B,Q,nh) cumulative log-decay ≤0
        # intra-chunk: M_{ijh} = exp(l_i - l_j) · (C_i·B_j) · dt_j, i ≥ j
        cb = jnp.einsum("bis,bjs->bij", cq, bq)  # (B,Q,Q)
        dmat = jnp.exp(la[:, :, None, :] - la[:, None, :, :])  # (B,Q,Q,nh)
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        M = jnp.where(mask[None, :, :, None], dmat * cb[..., None], 0.0)
        M = M * dtq[:, None, :, :]  # dt at the j (source) index
        y = jnp.einsum("bijh,bjhd->bihd", M, xh_c := xq)
        # carry from previous chunks
        y = y + jnp.exp(la)[..., None] * jnp.einsum("bhds,bis->bihd", S_in, cq)
        # new carry state
        wj = dtq * jnp.exp(la[:, -1:, :] - la)  # (B,Q,nh)
        S_out = jnp.exp(la[:, -1])[:, :, None, None] * S_in + jnp.einsum(
            "bjhd,bjs,bjh->bhds", xq, bq, wj
        )
        return S_out, y

    S0 = jnp.zeros((B, nh, hd, ds), jnp.float32)
    inp = (
        xh.reshape(B, nchunk, Q, nh, hd).swapaxes(0, 1),
        Bc.reshape(B, nchunk, Q, ds).swapaxes(0, 1),
        Cc.reshape(B, nchunk, Q, ds).swapaxes(0, 1),
        dt.reshape(B, nchunk, Q, nh).swapaxes(0, 1),
    )
    S_fin, ys = _chunk_scan(chunk_step, S0, inp, s.unroll_chunks)
    y = ys.swapaxes(0, 1).reshape(B, Sp, nh, hd)[:, :S]
    y = y + params["D"][None, None, :, None] * xh[:, :S]
    y = y.reshape(B, S, din).astype(x.dtype)
    y = rmsnorm(params["out_norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    if return_state:
        cw = params["conv_w"].shape[0]
        state = {"S": S_fin, "conv": conv_in[:, S - (cw - 1):, :]}
        return out, state
    return out


def mamba2_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    s = cfg.ssm
    din = s.expand * cfg.d_model
    nh = din // s.head_dim
    conv_dim = din + 2 * s.d_state
    return {
        "S": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
    }


def mamba2_step(params: dict, cfg: ModelConfig, x: jax.Array, state: dict):
    """One-token decode. x (B, 1, dm) -> (y (B, 1, dm), state)."""
    s = cfg.ssm
    B = x.shape[0]
    proj = x[:, 0] @ params["in_proj"]
    z, xs, Bc, Cc, dt, din, nh = _split_mamba(cfg, proj[:, None, :])
    z, xs, Bc, Cc, dt = z[:, 0], xs[:, 0], Bc[:, 0], Cc[:, 0], dt[:, 0]
    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)  # (B, conv_dim)
    window = jnp.concatenate([state["conv"], conv_in[:, None, :]], axis=1)  # (B,W,C)
    conv_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window, params["conv_w"]) + params["conv_b"]
    )
    xs, Bc, Cc = jnp.split(conv_out, [din, din + s.d_state], axis=-1)

    hd, ds = s.head_dim, s.d_state
    xh = xs.reshape(B, nh, hd).astype(jnp.float32)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,nh)
    a = jnp.exp(dtp * (-jnp.exp(params["A_log"])))  # (B,nh)
    S_new = a[:, :, None, None] * state["S"] + jnp.einsum(
        "bhd,bs,bh->bhds", xh, Bc.astype(jnp.float32), dtp
    )
    y = jnp.einsum("bhds,bs->bhd", S_new, Cc.astype(jnp.float32))
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(B, din).astype(x.dtype)
    y = rmsnorm(params["out_norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = (y @ params["out_proj"])[:, None, :]
    return out, {"S": S_new, "conv": window[:, 1:, :]}


# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------


def rwkv6_init(key, cfg: ModelConfig, dtype) -> dict:
    s: SSMConfig = cfg.ssm
    dm = cfg.d_model
    din = s.expand * dm
    ks = jax.random.split(key, 8)
    nh = din // s.head_dim
    return {
        # token-shift interpolation weights per stream
        "mu": jnp.full((5, dm), 0.5, dtype),  # r,k,v,g,w
        "wr": dense_init(ks[0], (dm, din), dtype),
        "wk": dense_init(ks[1], (dm, din), dtype),
        "wv": dense_init(ks[2], (dm, din), dtype),
        "wg": dense_init(ks[3], (dm, din), dtype),
        # data-dependent decay (low-rank, as in Finch): dm -> 64 -> din
        "w_lora_a": dense_init(ks[4], (dm, 64), dtype),
        "w_lora_b": dense_init(ks[5], (64, din), dtype, scale=0.1),
        "w0": jnp.full((din,), -2.0, jnp.float32),
        "u": jnp.zeros((din,), jnp.float32),  # current-token bonus
        "out_norm": rmsnorm_init(din, dtype),
        "wo": dense_init(ks[6], (din, dm), dtype),
    }


def _rwkv_streams(params, x, x_prev):
    """Token-shifted input streams. x (B,S,dm); x_prev (B,1,dm) carry."""
    shifted = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    mu = params["mu"]
    mix = lambda i: x + (shifted - x) * mu[i]
    r_in, k_in, v_in, g_in, w_in = (mix(i) for i in range(5))
    r = r_in @ params["wr"]
    k = k_in @ params["wk"]
    v = v_in @ params["wv"]
    g = jax.nn.silu(g_in @ params["wg"])
    logw = -jnp.exp(
        params["w0"]
        + (jnp.tanh(w_in @ params["w_lora_a"]) @ params["w_lora_b"]).astype(jnp.float32)
    )  # (B,S,din) ≤ 0
    return r, k, v, g, logw


def rwkv6_forward(params: dict, cfg: ModelConfig, x: jax.Array,
                  x_prev: jax.Array | None = None, return_state: bool = False):
    """Full-sequence chunked WKV. x (B,S,dm) -> (B,S,dm)[, final state]."""
    s = cfg.ssm
    B, S, dm = x.shape
    din = s.expand * dm
    hd = s.head_dim
    nh = din // hd
    if x_prev is None:
        x_prev = jnp.zeros((B, 1, dm), x.dtype)
    r, k, v, g, logw = _rwkv_streams(params, x, x_prev)

    rh = r.reshape(B, S, nh, hd).astype(jnp.float32)
    kh = k.reshape(B, S, nh, hd).astype(jnp.float32)
    vh = v.reshape(B, S, nh, hd).astype(jnp.float32)
    lw = logw.reshape(B, S, nh, hd)
    u = params["u"].reshape(nh, hd)

    Q = min(s.chunk, S)
    Sp = ((S + Q - 1) // Q) * Q
    if Sp != S:
        assert not return_state, "prefill length must be a chunk multiple"
        pad = lambda a: jnp.pad(a, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        rh, kh, vh, lw = pad(rh), pad(kh), pad(vh), pad(lw)
    nchunk = Sp // Q

    def chunk_step(S_in, inp):  # S_in (B,nh,hd_k,hd_v)
        rq, kq, vq, lq = inp  # (B,Q,nh,hd)...
        l = jnp.cumsum(lq, axis=1)  # (B,Q,nh,hd) cumulative log decay
        l_prev = l - lq  # l_{i-1} (decay up to but excluding i)
        r_t = rq * jnp.exp(l_prev)  # (B,Q,nh,hd)
        k_t = kq * jnp.exp(-l)
        A = jnp.einsum("bihd,bjhd->bhij", r_t, k_t)  # strict lower part valid
        mask = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
        A = jnp.where(mask[None, None], A, 0.0)
        diag = jnp.einsum("bihd,hd,bihd->bhi", rq, u, kq)  # current-token bonus
        y = jnp.einsum("bhij,bjhd->bihd", A, vq)
        y = y + diag.transpose(0, 2, 1)[..., None] * vq
        # carry
        y = y + jnp.einsum("bihk,bhkv->bihv", rq * jnp.exp(l_prev), S_in)
        # state update
        decay_out = jnp.exp(l[:, -1])  # (B,nh,hd)
        S_out = decay_out[..., None] * S_in + jnp.einsum(
            "bjhk,bjhv->bhkv", kq * jnp.exp(l[:, -1:] - l), vq
        )
        return S_out, y

    S0 = jnp.zeros((B, nh, hd, hd), jnp.float32)
    inp = tuple(
        a.reshape(B, nchunk, Q, nh, hd).swapaxes(0, 1) for a in (rh, kh, vh, lw)
    )
    S_fin, ys = _chunk_scan(chunk_step, S0, inp, s.unroll_chunks)
    y = ys.swapaxes(0, 1).reshape(B, Sp, din)[:, :S].astype(x.dtype)
    y = rmsnorm(params["out_norm"], y, cfg.norm_eps) * g
    out = y @ params["wo"]
    if return_state:
        return out, {"S": S_fin, "shift": x[:, -1:, :]}
    return out


def rwkv6_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    s = cfg.ssm
    din = s.expand * cfg.d_model
    nh = din // s.head_dim
    return {
        "S": jnp.zeros((batch, nh, s.head_dim, s.head_dim), jnp.float32),
        "shift": jnp.zeros((batch, 1, cfg.d_model), dtype),
    }


def rwkv6_step(params: dict, cfg: ModelConfig, x: jax.Array, state: dict):
    """One-token decode. x (B,1,dm)."""
    s = cfg.ssm
    B, _, dm = x.shape
    din = s.expand * dm
    hd = s.head_dim
    nh = din // hd
    r, k, v, g, logw = _rwkv_streams(params, x, state["shift"])
    rh = r.reshape(B, nh, hd).astype(jnp.float32)
    kh = k.reshape(B, nh, hd).astype(jnp.float32)
    vh = v.reshape(B, nh, hd).astype(jnp.float32)
    w = jnp.exp(logw.reshape(B, nh, hd))
    u = params["u"].reshape(nh, hd)

    kv = jnp.einsum("bhk,bhv->bhkv", kh, vh)
    y = jnp.einsum("bhk,bhkv->bhv", rh, state["S"] + u[None, :, :, None] * kv)
    S_new = w[..., None] * state["S"] + kv
    y = y.reshape(B, din).astype(x.dtype)
    y = rmsnorm(params["out_norm"], y, cfg.norm_eps) * g[:, 0]
    out = (y @ params["wo"])[:, None, :]
    return out, {"S": S_new, "shift": x}
