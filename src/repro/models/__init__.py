"""repro.models — the assigned architecture pool as composable JAX models."""
from .config import MLAConfig, ModelConfig, MoEConfig, SSMConfig
from . import model, sharding, steps

__all__ = ["ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "model", "sharding", "steps"]
