"""Model assembly: blocks → stacks → train/prefill/decode applies.

Pre-norm residual blocks. Homogeneous stacks (9 of the 10 assigned archs)
store per-layer params stacked on a leading L axis and run under
``lax.scan`` — HLO size stays O(1) in depth, which keeps 94-layer dry-runs
compilable and is remat-friendly. Heterogeneous stacks (zamba2's
Mamba2-with-periodic-attention pattern) use a python loop over per-layer
param dicts.

Modality frontends are stubs per the assignment: ``audio`` consumes
precomputed frame embeddings (B, S, dm); ``vlm`` consumes precomputed patch
embeddings prepended to the token stream (prefix simplification of
PaliGemma's prefix-LM attention is noted in DESIGN.md).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import ssm as ssmmod
from .attention import KVCache
from .config import ModelConfig
from .layers import dtype_of, embed_init, mlp_apply, mlp_init, rmsnorm, rmsnorm_init
from .moe import moe_apply, moe_init
from .sharding import constrain_batch_dim


def _has_ffn(cfg: ModelConfig, kind: str) -> bool:
    return kind == "attn" or cfg.family == "ssm"


# ---------------------------------------------------------------------------
# per-block init / apply
# ---------------------------------------------------------------------------


def block_init(key, cfg: ModelConfig, kind: str) -> dict:
    dt = dtype_of(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict[str, Any] = {"norm1": rmsnorm_init(cfg.d_model, dt)}
    if kind == "attn":
        p["mixer"] = attn.mla_init(k1, cfg, dt) if cfg.mla else attn.gqa_init(k1, cfg, dt)
    elif kind == "mamba2":
        p["mixer"] = ssmmod.mamba2_init(k1, cfg, dt)
    elif kind == "rwkv6":
        p["mixer"] = ssmmod.rwkv6_init(k1, cfg, dt)
    else:
        raise ValueError(kind)
    if _has_ffn(cfg, kind):
        p["norm2"] = rmsnorm_init(cfg.d_model, dt)
        p["ffn"] = moe_init(k2, cfg, dt) if cfg.moe else mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp, dt)
    return p


def _ffn_apply(p: dict, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    if cfg.moe:
        return moe_apply(p, cfg, x)
    return mlp_apply(p, x, cfg.mlp), jnp.float32(0.0)


def block_train(p: dict, cfg: ModelConfig, kind: str, x, positions):
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind == "attn":
        mix = attn.mla_train(p["mixer"], cfg, h, positions) if cfg.mla else attn.gqa_train(p["mixer"], cfg, h, positions)
    elif kind == "mamba2":
        mix = ssmmod.mamba2_forward(p["mixer"], cfg, h)
    else:
        mix = ssmmod.rwkv6_forward(p["mixer"], cfg, h)
    x = x + mix
    aux = jnp.float32(0.0)
    if _has_ffn(cfg, kind):
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        y, aux = _ffn_apply(p["ffn"], cfg, h)
        x = x + y
    return x, aux


def block_prefill(p: dict, cfg: ModelConfig, kind: str, x, positions, cache):
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind == "attn":
        fn = attn.mla_prefill if cfg.mla else attn.gqa_prefill
        mix, cache = fn(p["mixer"], cfg, h, positions, cache)
    elif kind == "mamba2":
        mix, cache = ssmmod.mamba2_forward(p["mixer"], cfg, h, return_state=True)
    else:
        mix, cache = ssmmod.rwkv6_forward(p["mixer"], cfg, h, return_state=True)
    x = x + mix
    if _has_ffn(cfg, kind):
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        y, _ = _ffn_apply(p["ffn"], cfg, h)
        x = x + y
    return x, cache


def block_decode(p: dict, cfg: ModelConfig, kind: str, x, cache, cache_len):
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind == "attn":
        fn = attn.mla_decode if cfg.mla else attn.gqa_decode
        mix, cache = fn(p["mixer"], cfg, h, cache, cache_len)
    elif kind == "mamba2":
        mix, cache = ssmmod.mamba2_step(p["mixer"], cfg, h, cache)
    else:
        mix, cache = ssmmod.rwkv6_step(p["mixer"], cfg, h, cache)
    x = x + mix
    if _has_ffn(cfg, kind):
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        y, _ = _ffn_apply(p["ffn"], cfg, h)
        x = x + y
    return x, cache


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def segments(cfg: ModelConfig) -> list[tuple[str, int]]:
    """Partition the layer pattern into runs of identical block kinds.

    Each run is stacked on a leading axis and executed with one
    `lax.scan` — uniform archs get a single segment, zamba2 gets
    alternating mamba2/attn segments. With ``force_unroll`` every layer is
    its own length-1 segment (dry-run cost extraction)."""
    pat = cfg.pattern
    if cfg.force_unroll:
        return [(k, 1) for k in pat]
    runs: list[tuple[str, int]] = []
    for k in pat:
        if runs and runs[-1][0] == k:
            runs[-1] = (k, runs[-1][1] + 1)
        else:
            runs.append((k, 1))
    return runs


def init_params(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg.param_dtype)
    keys = jax.random.split(key, cfg.num_layers + 3)
    p: dict[str, Any] = {}
    if cfg.input_mode in ("tokens", "vlm"):
        p["embed"] = embed_init(keys[-1], (cfg.vocab_size, cfg.d_model), dt)
    p["final_norm"] = rmsnorm_init(cfg.d_model, dt)
    if not cfg.tie_embeddings or cfg.input_mode == "frames":
        p["lm_head"] = embed_init(keys[-2], (cfg.d_model, cfg.vocab_size), dt)

    blocks = []
    off = 0
    for kind, ln in segments(cfg):
        blocks.append(
            jax.vmap(lambda k, kind=kind: block_init(k, cfg, kind))(keys[off : off + ln])
        )
        off += ln
    p["blocks"] = blocks
    return p


def _embed_inputs(params, cfg: ModelConfig, batch: dict):
    """Returns (x (B,S,dm), positions (B,S), target_mask (B,S))."""
    cd = dtype_of(cfg.compute_dtype)
    if cfg.input_mode == "tokens":
        tok = batch["tokens"]
        x = params["embed"][tok].astype(cd)
        B, S = tok.shape
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        return x, pos, jnp.ones((B, S), bool)
    if cfg.input_mode == "frames":
        x = batch["frames"].astype(cd)
        B, S = x.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        return x, pos, jnp.ones((B, S), bool)
    # vlm: image embeddings prepended to token embeddings
    img = batch["image_embeds"].astype(cd)  # (B, Ni, dm)
    tok = batch["tokens"]
    xt = params["embed"][tok].astype(cd)
    x = jnp.concatenate([img, xt], axis=1)
    B, S = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    mask = jnp.concatenate(
        [jnp.zeros((B, img.shape[1]), bool), jnp.ones(tok.shape, bool)], axis=1
    )
    return x, pos, mask


def _logits(params, cfg: ModelConfig, x):
    head = params["embed"].T if cfg.tie_embeddings and "embed" in params else params["lm_head"]
    return (x @ head.astype(x.dtype)).astype(jnp.float32)


def _remat_wrap(fn, remat):
    """remat: 'none' | 'full' (save nothing) | 'dots' (save matmul outputs).

    The policy choice is a §Perf lever: 'full' minimizes the memory roofline
    term at the cost of recompute FLOPs; 'dots' trades some memory back for
    a MODEL_FLOPS/HLO_FLOPs ratio closer to 1.
    """
    if remat in (False, "none"):
        return fn
    if remat in (True, "full"):
        return jax.checkpoint(fn)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    raise ValueError(remat)


def _run_blocks_train(params, cfg: ModelConfig, x, positions, remat="full"):
    aux_total = jnp.float32(0.0)
    x = constrain_batch_dim(x)
    for (kind, ln), seg in zip(segments(cfg), params["blocks"]):
        fn = functools.partial(block_train, cfg=cfg, kind=kind)
        f = _remat_wrap(lambda p, xx, fn=fn: fn(p, x=xx, positions=positions), remat)
        if ln == 1:
            x, a = f(jax.tree.map(lambda t: t[0], seg), x)
            x = constrain_batch_dim(x)
            aux_total = aux_total + a
        else:
            def body(carry, layer_params, f=f):
                xc, aux = carry
                xc, a = f(layer_params, xc)
                return (constrain_batch_dim(xc), aux + a), None

            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), seg)
    return x, aux_total


def forward_train(params, cfg: ModelConfig, batch: dict, remat="full"):
    """Returns (logits (B,S,V) f32, target_mask, aux_loss)."""
    x, pos, mask = _embed_inputs(params, cfg, batch)
    x, aux = _run_blocks_train(params, cfg, x, pos, remat)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, cfg, x), mask, aux


def _xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Sharding-friendly CE: the target log-prob comes from a one-hot
    *contraction* over the vocab dim, not a gather — with vocab sharded over
    the `model` axis a gather forces GSPMD to all-gather the full (B,S,V)
    logits (measured: 100s of GB/device at train_4k scale); the contraction
    lowers to a partial sum + tiny all-reduce instead."""
    V = logits.shape[-1]
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, V, dtype=logits.dtype)
    tgt_logit = jnp.einsum("...v,...v->...", logits, onehot)
    return (lse - tgt_logit).mean()


def loss_fn(params, cfg: ModelConfig, batch: dict, remat="full"):
    """Next-token CE for causal archs; frame classification for encoders."""
    logits, mask, aux = forward_train(params, cfg, batch, remat)
    if cfg.causal:
        targets = batch["tokens"]
        if cfg.input_mode == "vlm":
            Ni = batch["image_embeds"].shape[1]
            logits_txt = logits[:, Ni:, :]
        else:
            logits_txt = logits
        loss = _xent(logits_txt[:, :-1], targets[:, 1:])
    else:  # encoder: per-frame classification against labels
        loss = _xent(logits, batch["labels"])
    return loss + 0.01 * aux, (loss, aux)


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, s_max: int, dtype=jnp.bfloat16):
    """Decode state: a list of per-segment stacked pytrees, leaves
    (seg_len, B, ...) — one entry per `segments(cfg)` run."""

    def one(kind: str):
        if kind == "attn":
            if cfg.mla:
                m = cfg.mla
                return KVCache(
                    k=jnp.zeros((batch, s_max, m.kv_lora_rank + m.qk_rope_head_dim), dtype),
                    v=jnp.zeros((batch, 0), dtype),
                )
            return KVCache(
                k=jnp.zeros((batch, s_max, cfg.num_kv_heads, cfg.resolved_head_dim), dtype),
                v=jnp.zeros((batch, s_max, cfg.num_kv_heads, cfg.resolved_head_dim), dtype),
            )
        if kind == "mamba2":
            return ssmmod.mamba2_init_state(cfg, batch, dtype)
        return ssmmod.rwkv6_init_state(cfg, batch, dtype)

    out = []
    for kind, ln in segments(cfg):
        single = one(kind)
        out.append(
            jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (ln,) + a.shape).copy(), single
            )
        )
    return out


def prefill(params, cfg: ModelConfig, batch: dict, cache):
    """Process the prompt; returns (last-position logits, filled cache)."""
    x, pos, _ = _embed_inputs(params, cfg, batch)
    x = constrain_batch_dim(x)
    new_cache = []
    for (kind, ln), seg, cseg in zip(segments(cfg), params["blocks"], cache):
        if ln == 1:
            x, c = block_prefill(
                jax.tree.map(lambda t: t[0], seg), cfg, kind, x, pos,
                jax.tree.map(lambda t: t[0], cseg),
            )
            x = constrain_batch_dim(x)
            new_cache.append(jax.tree.map(lambda t: t[None], c))
        else:
            def body(xc, scan_in, kind=kind):
                layer_params, layer_cache = scan_in
                xo, c = block_prefill(layer_params, cfg, kind, xc, pos, layer_cache)
                return constrain_batch_dim(xo), c

            x, nc = jax.lax.scan(body, x, (seg, cseg))
            new_cache.append(nc)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, cfg, x[:, -1:, :]), new_cache


def decode_step(params, cfg: ModelConfig, tokens, cache, cache_len):
    """One decode step. tokens (B, 1) int32 (or (B,1,dm) frames)."""
    cd = dtype_of(cfg.compute_dtype)
    if cfg.input_mode in ("tokens", "vlm"):
        x = params["embed"][tokens].astype(cd)  # (B,1,dm)
    else:
        x = tokens.astype(cd)

    x = constrain_batch_dim(x)
    new_cache = []
    for (kind, ln), seg, cseg in zip(segments(cfg), params["blocks"], cache):
        if ln == 1:
            x, c = block_decode(
                jax.tree.map(lambda t: t[0], seg), cfg, kind, x,
                jax.tree.map(lambda t: t[0], cseg), cache_len,
            )
            x = constrain_batch_dim(x)
            new_cache.append(jax.tree.map(lambda t: t[None], c))
        else:
            def body(xc, scan_in, kind=kind):
                layer_params, layer_cache = scan_in
                xo, c = block_decode(layer_params, cfg, kind, xc, layer_cache, cache_len)
                return constrain_batch_dim(xo), c

            x, nc = jax.lax.scan(body, x, (seg, cseg))
            new_cache.append(nc)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, cfg, x), new_cache
