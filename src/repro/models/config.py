"""Model configuration for the assigned architecture pool.

One dataclass covers dense GQA transformers, MoE (incl. MLA), encoder-only,
VLM (stub frontend), hybrid SSM+attention, and attention-free (RWKV6)
architectures. Per-layer heterogeneity (zamba2) is expressed with
``block_pattern``; homogeneous stacks use scan-over-layers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    group_size: int = 512  # dispatch group (tokens) — memory/locality knob
    first_dense_layers: int = 0  # deepseek: dense FFN in the first layer(s)
    d_ff_dense: int = 0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"  # mamba2 | rwkv6
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2  # d_inner = expand * d_model
    conv_width: int = 4  # mamba2 causal conv
    chunk: int = 128  # chunked-scan block length (TPU-native formulation)
    # dry-run cost-extraction knob: python-loop the chunk scan so XLA's
    # cost analysis (which counts while bodies once) sees every chunk
    unroll_chunks: bool = False


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | audio | vlm | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # attention flavor
    rope: str = "full"  # full | partial (rotate half dims; chatglm 2d) | none
    rope_theta: float = 10000.0
    qk_norm: bool = False
    causal: bool = True  # False: encoder-only (hubert)
    mlp: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # submodule configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # per-layer pattern for hybrids; entries: "attn" | "mamba2" | "rwkv6".
    # empty -> homogeneous ("attn" unless family == "ssm")
    block_pattern: tuple[str, ...] = ()
    # modality frontend stubs (assignment: frontends are precomputed)
    num_image_tokens: int = 0  # vlm: patch embeddings prepended
    input_mode: str = "tokens"  # tokens | frames (audio) | vlm
    # dtypes / numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # training
    max_seq_len: int = 8192
    # dry-run cost-extraction knob: python-loop the layer stack instead of
    # scan so per-layer cost is visible to XLA's while-body-once analysis
    force_unroll: bool = False
    # context-parallel attention (§Perf lever): shard the attention score /
    # output tensors over the `model` axis along the *query-sequence* dim.
    # For archs whose head counts don't divide the model axis (smollm: 9
    # heads vs 16-way TP) GSPMD otherwise replicates the whole S² attention
    # computation per model shard.
    cp_attn: bool = False
    # flash-style query-block chunking for full-sequence attention: peak
    # scores buffer (B,H,chunk,S) instead of (B,H,S,S). 0 disables.
    attn_q_chunk: int = 1024

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def pattern(self) -> tuple[str, ...]:
        if self.block_pattern:
            assert len(self.block_pattern) == self.num_layers
            return self.block_pattern
        if self.family == "ssm" and self.ssm is not None:
            return (self.ssm.kind,) * self.num_layers
        return ("attn",) * self.num_layers

    @property
    def uniform(self) -> bool:
        """True when all layers share one block type (→ scan-over-layers)."""
        return len(set(self.pattern)) == 1 and not self.force_unroll

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the 500k-token long-context decode shape."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return self.causal  # encoder-only models have no decode step

    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline bookkeeping)."""
        dm, dff, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        n = V * dm  # embedding
        if not self.tie_embeddings:
            n += V * dm
        for kind in self.pattern:
            if kind == "attn":
                if self.mla is not None:
                    m = self.mla
                    qdim = self.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                    n += dm * qdim
                    n += dm * (m.kv_lora_rank + m.qk_rope_head_dim)
                    n += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    n += self.num_heads * m.v_head_dim * dm
                else:
                    n += dm * self.num_heads * hd  # q
                    n += 2 * dm * self.num_kv_heads * hd  # k, v
                    n += self.num_heads * hd * dm  # o
            elif kind in ("mamba2", "rwkv6"):
                s = self.ssm
                din = s.expand * dm
                if kind == "mamba2":
                    n += dm * (2 * din + 2 * s.d_state + din // s.head_dim)
                    n += din * dm
                    n += (din + 2 * s.d_state) * s.conv_width
                else:
                    n += dm * din * 5  # r k v g w projections
                    n += din * dm
            # ffn: attention blocks carry one; pure-SSM families use a
            # channel-mix FFN every layer; hybrid mamba blocks have none
            has_ffn = (kind == "attn") or (self.family == "ssm")
            if has_ffn:
                if self.moe is not None:
                    e = self.moe
                    n += dm * e.num_experts  # router
                    n += e.num_experts * 3 * dm * e.d_ff_expert
                    n += e.num_shared_experts * 3 * dm * e.d_ff_shared
                else:
                    mult = 3 if self.mlp == "swiglu" else 2
                    n += mult * dm * dff
            n += 2 * dm  # norms
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k of routed experts)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        total = self.param_count()
        routed = len(self.pattern) * e.num_experts * 3 * self.d_model * e.d_ff_expert
        active = len(self.pattern) * e.top_k * 3 * self.d_model * e.d_ff_expert
        return total - routed + active
