"""Step factories: jitted train / prefill / decode with explicit shardings.

Each factory returns a ``StepBundle``: the jitted fn, ShapeDtypeStruct trees
for every argument (what the dry-run lowers against), and the NamedShardings.
The real trainer/server uses the same bundle and feeds concrete arrays.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from .. import compat
from ..train.optimizer import OptConfig, OptState, adamw_update, init_opt_state
from . import model as M
from .config import ModelConfig
from .sharding import batch_spec, cache_specs, dp_axes, param_specs, to_shardings


class TrainState(NamedTuple):
    params: Any
    opt: OptState


@dataclasses.dataclass
class StepBundle:
    fn: Callable  # jitted step
    arg_shapes: tuple  # ShapeDtypeStruct trees (lower(*arg_shapes))
    arg_shardings: tuple
    out_shardings: Any
    init: Optional[Callable] = None  # builds real initial state


def _named(specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )


def _batch_shardings(batch_shapes: dict, mesh: Mesh) -> dict:
    out = {}
    for k, v in batch_shapes.items():
        out[k] = NamedSharding(mesh, batch_spec(mesh, v.shape[0], len(v.shape)))
    return out


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def make_train_step(
    cfg: ModelConfig,
    mesh: Mesh,
    batch_shapes: dict,
    opt_cfg: OptConfig = OptConfig(),
    remat: str = "full",
    accum: int = 1,
    seed: int = 0,
) -> StepBundle:
    key = jax.random.PRNGKey(seed)
    param_shapes = jax.eval_shape(lambda k: M.init_params(k, cfg), key)
    opt_shapes = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), param_shapes)
    state_shapes = TrainState(params=param_shapes, opt=opt_shapes)

    pspecs = param_specs(param_shapes, cfg, mesh)
    mspecs = param_specs(opt_shapes.m, cfg, mesh)
    vspecs = param_specs(opt_shapes.v, cfg, mesh)
    state_specs = TrainState(
        params=pspecs, opt=OptState(m=mspecs, v=vspecs, step=P())
    )
    state_sh = _named(state_specs, mesh)
    batch_sh = _batch_shardings(batch_shapes, mesh)

    def step(state: TrainState, batch: dict):
        _ctx = compat.use_abstract_mesh(mesh)
        _ctx.__enter__()
        if accum > 1:
            def micro(c, mb):
                (l, (ce, aux)), g = jax.value_and_grad(
                    lambda p: M.loss_fn(p, cfg, mb, remat), has_aux=True
                )(state.params)
                gsum, lsum = c
                return (jax.tree.map(jnp.add, gsum, g), lsum + l), None

            mb = jax.tree.map(
                lambda a: a.reshape((accum, a.shape[0] // accum) + a.shape[1:]), batch
            )
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (gsum, lsum), _ = jax.lax.scan(micro, (zero, jnp.float32(0.0)), mb)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
        else:
            (loss, (ce, aux)), grads = jax.value_and_grad(
                lambda p: M.loss_fn(p, cfg, batch, remat), has_aux=True
            )(state.params)
        new_p, new_opt, om = adamw_update(state.params, grads, state.opt, opt_cfg)
        metrics = {"loss": loss, **om}
        _ctx.__exit__(None, None, None)
        return TrainState(params=new_p, opt=new_opt), metrics

    fn = jax.jit(
        step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    )

    def init() -> TrainState:
        params = jax.jit(
            lambda k: M.init_params(k, cfg), out_shardings=_named(pspecs, mesh)
        )(key)
        opt = jax.jit(
            lambda p: init_opt_state(p, opt_cfg),
            out_shardings=_named(OptState(m=mspecs, v=vspecs, step=P()), mesh),
        )(params)
        return TrainState(params=params, opt=opt)

    return StepBundle(
        fn=fn,
        arg_shapes=(state_shapes, batch_shapes),
        arg_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        init=init,
    )


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------


def make_prefill_step(
    cfg: ModelConfig,
    mesh: Mesh,
    batch_shapes: dict,
    s_max: int,
    cache_dtype=jnp.bfloat16,
    seed: int = 0,
) -> StepBundle:
    key = jax.random.PRNGKey(seed)
    param_shapes = jax.eval_shape(lambda k: M.init_params(k, cfg), key)
    pspecs = param_specs(param_shapes, cfg, mesh)
    params_sh = _named(pspecs, mesh)
    batch_sh = _batch_shardings(batch_shapes, mesh)
    B = next(iter(batch_shapes.values())).shape[0]

    cache_shapes = jax.eval_shape(
        lambda: M.init_cache(cfg, B, s_max, cache_dtype)
    )
    cspecs = cache_specs(cache_shapes, cfg, mesh, B)
    cache_sh = _named(cspecs, mesh)

    def step(params, batch):
        with compat.use_abstract_mesh(mesh):
            cache = M.init_cache(cfg, B, s_max, cache_dtype)
            logits, cache = M.prefill(params, cfg, batch, cache)
            return logits, cache

    fn = jax.jit(
        step,
        in_shardings=(params_sh, batch_sh),
        out_shardings=(NamedSharding(mesh, batch_spec(mesh, B, 3)), cache_sh),
    )
    return StepBundle(
        fn=fn,
        arg_shapes=(param_shapes, batch_shapes),
        arg_shardings=(params_sh, batch_sh),
        out_shardings=(None, cache_sh),
    )


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def make_decode_step(
    cfg: ModelConfig,
    mesh: Mesh,
    batch: int,
    s_max: int,
    cache_dtype=jnp.bfloat16,
    seed: int = 0,
) -> StepBundle:
    key = jax.random.PRNGKey(seed)
    param_shapes = jax.eval_shape(lambda k: M.init_params(k, cfg), key)
    pspecs = param_specs(param_shapes, cfg, mesh)
    params_sh = _named(pspecs, mesh)

    cache_shapes = jax.eval_shape(lambda: M.init_cache(cfg, batch, s_max, cache_dtype))
    cspecs = cache_specs(cache_shapes, cfg, mesh, batch)
    cache_sh = _named(cspecs, mesh)

    if cfg.input_mode == "frames":
        tok_shape = jax.ShapeDtypeStruct((batch, 1, cfg.d_model), jnp.bfloat16)
    else:
        tok_shape = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    tok_sh = NamedSharding(mesh, batch_spec(mesh, batch, len(tok_shape.shape)))
    len_shape = jax.ShapeDtypeStruct((), jnp.int32)
    len_sh = NamedSharding(mesh, P())

    def step(params, cache, tokens, cache_len):
        with compat.use_abstract_mesh(mesh):
            logits, new_cache = M.decode_step(params, cfg, tokens, cache, cache_len)
            return logits, new_cache

    fn = jax.jit(
        step,
        in_shardings=(params_sh, cache_sh, tok_sh, len_sh),
        out_shardings=(NamedSharding(mesh, batch_spec(mesh, batch, 3)), cache_sh),
        donate_argnums=(1,),
    )
    return StepBundle(
        fn=fn,
        arg_shapes=(param_shapes, cache_shapes, tok_shape, len_shape),
        arg_shardings=(params_sh, cache_sh, tok_sh, len_sh),
        out_shardings=(None, cache_sh),
    )
