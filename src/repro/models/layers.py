"""Shared building blocks: norms, RoPE, MLPs, initializers.

Raw-JAX (no flax): params are nested dicts of arrays; init fns mirror apply
fns. Everything is shape-polymorphic over leading batch/seq dims and uses
``compute_dtype`` internally with f32 accumulations where it matters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: float = 1.0):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm with an f32 *reduction* but no full-tensor f32 materialization.

    Upcasting x wholesale (x.astype(f32)) lets XLA hoist the convert in
    front of the scan-remat save buffer, storing the per-layer residual
    stream in f32 — measured +12.5 GiB/device on qwen3-14b train_4k. The
    einsum accumulates the variance in f32 while x stays bf16.
    """
    var = jnp.einsum(
        "...d,...d->...", x, x, preferred_element_type=jnp.float32
    ) / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps)[..., None].astype(x.dtype)
    return x * inv * params["scale"].astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE — full, partial (chatglm-style "2d": rotate half the head dims)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float, rotate_dims: int) -> jax.Array:
    """inv_freq (rotate_dims/2,)."""
    return 1.0 / (
        theta ** (jnp.arange(0, rotate_dims, 2, dtype=jnp.float32) / rotate_dims)
    )


def apply_rope(
    x: jax.Array,  # (..., S, H, Dh)
    positions: jax.Array,  # (..., S)
    theta: float,
    mode: str = "full",
) -> jax.Array:
    if mode == "none":
        return x
    Dh = x.shape[-1]
    rot = Dh if mode == "full" else Dh // 2
    inv = rope_frequencies(Dh, theta, rot)  # (rot/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., :, None, :]  # (..., S, 1, rot/2)
    sin = sin[..., :, None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    rotated = jnp.stack([out1, out2], axis=-1).reshape(xr.shape).astype(x.dtype)
    if rot == Dh:
        return rotated
    return jnp.concatenate([rotated, x[..., rot:]], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, kind: str, dtype) -> dict:
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "w1": dense_init(ks[0], (d_model, d_ff), dtype),
            "w3": dense_init(ks[1], (d_model, d_ff), dtype),
            "w2": dense_init(ks[2], (d_ff, d_model), dtype),
        }
    return {
        "w1": dense_init(ks[0], (d_model, d_ff), dtype),
        "w2": dense_init(ks[1], (d_ff, d_model), dtype),
    }


def mlp_apply(params: dict, x: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        h = jax.nn.silu(x @ params["w1"]) * (x @ params["w3"])
    else:
        h = jax.nn.gelu(x @ params["w1"])
    return h @ params["w2"]
