"""Mixture-of-Experts: grouped capacity-based dispatch (Switch/Mesh-TF style).

Tokens are processed in groups of ``group_size``; within each group, top-k
routing builds dispatch/combine tensors (G, E, C) with
C = G·k/E·capacity_factor slots per expert. Everything is einsum-shaped so
GSPMD can shard experts over the `model` axis (EP) and groups over `data`
(DP) — token→expert movement lowers to all-to-alls instead of scatters.
Shared experts (DeepSeek) run densely alongside.

Capacity overflow drops tokens (the residual passes through); the router
uses softmax-after-top-k gates normalized over the selected experts, and
an auxiliary load-balancing loss is returned for training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig, MoEConfig
from .layers import dense_init, mlp_apply, mlp_init


def moe_init(key, cfg: ModelConfig, dtype) -> dict:
    e: MoEConfig = cfg.moe
    dm = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (dm, e.num_experts), jnp.float32),
        "w1": dense_init(ks[1], (e.num_experts, dm, e.d_ff_expert), dtype),
        "w3": dense_init(ks[2], (e.num_experts, dm, e.d_ff_expert), dtype),
        "w2": dense_init(ks[3], (e.num_experts, e.d_ff_expert, dm), dtype),
    }
    if e.num_shared_experts:
        p["shared"] = mlp_init(
            ks[4], dm, e.d_ff_shared * e.num_shared_experts, "swiglu", dtype
        )
    return p


def moe_apply(params: dict, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (B, S, dm) -> (y (B, S, dm), aux_loss ())."""
    e = cfg.moe
    B, S, dm = x.shape
    n_tok = B * S
    # decode / small batches: collapse to a single group
    G = e.group_size if n_tok % e.group_size == 0 else n_tok
    ngroups = n_tok // G
    C = max(4, int(G * e.top_k * e.capacity_factor / e.num_experts))
    xg = x.reshape(ngroups, G, dm)

    logits = (xg.astype(jnp.float32) @ params["router"]).astype(jnp.float32)  # (n,G,E)
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k routing: iterate k slots, masking chosen experts
    gates_list, masks_list = [], []
    remaining = probs
    for _ in range(e.top_k):
        gate, idx = jnp.max(remaining, -1), jnp.argmax(remaining, -1)  # (n,G)
        onehot = jax.nn.one_hot(idx, e.num_experts, dtype=jnp.float32)
        gates_list.append(gate)
        masks_list.append(onehot)
        remaining = remaining * (1.0 - onehot)

    # normalize gates over the selected k
    gates = jnp.stack(gates_list, -1)  # (n,G,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # capacity assignment: position of each (token, slot) within its expert
    dispatch = jnp.zeros((ngroups, G, e.num_experts, C), jnp.float32)
    combine = jnp.zeros((ngroups, G, e.num_experts, C), jnp.float32)
    prev_count = jnp.zeros((ngroups, 1, e.num_experts), jnp.float32)
    for j in range(e.top_k):
        m = masks_list[j]  # (n,G,E)
        pos_in_expert = jnp.cumsum(m, axis=1) - m + prev_count  # (n,G,E)
        fits = (pos_in_expert < C) & (m > 0)
        pos_oh = jax.nn.one_hot(pos_in_expert.astype(jnp.int32), C, dtype=jnp.float32)
        d_j = pos_oh * (fits.astype(jnp.float32) * m)[..., None]  # (n,G,E,C)
        dispatch = dispatch + d_j
        combine = combine + d_j * gates[..., j][:, :, None, None]
        prev_count = prev_count + m.sum(axis=1, keepdims=True)

    cd = x.dtype
    x_e = jnp.einsum("ngec,ngd->necd", dispatch.astype(cd), xg)  # (n,E,C,dm)
    h = jax.nn.silu(jnp.einsum("necd,edf->necf", x_e, params["w1"])) * jnp.einsum(
        "necd,edf->necf", x_e, params["w3"]
    )
    y_e = jnp.einsum("necf,efd->necd", h, params["w2"])  # (n,E,C,dm)
    y = jnp.einsum("ngec,necd->ngd", combine.astype(cd), y_e).reshape(B, S, dm)

    if e.num_shared_experts:
        y = y + mlp_apply(params["shared"], x, "swiglu")

    # Switch-style load-balance aux: E · Σ_e (frac_tokens_e · frac_probs_e)
    frac_tokens = jnp.stack(masks_list, 0).sum(0).mean(axis=1)  # (n,E)
    frac_probs = probs.mean(axis=1)  # (n,E)
    aux = e.num_experts * jnp.mean(jnp.sum(frac_tokens * frac_probs, -1)) / e.top_k
    return y, aux.astype(jnp.float32)
