"""Attention: GQA/MQA (with qk_norm, RoPE variants) and MLA (DeepSeek).

Three entry points per flavor:
  * ``*_train``   — full-sequence self-attention (causal or bidirectional);
  * ``*_prefill`` — same, but also returns the KV cache;
  * ``*_decode``  — one new token against a cache of ``cache_len`` tokens.

Decode KV caches can be *sequence-sharded* across the `model` mesh axis
(constraint applied in steps.py): softmax and the PV contraction over a
sharded S dimension lower to partial reductions + all-reduce under GSPMD —
the flash-decoding split-KV scheme expressed declaratively.

MLA decode uses the *absorbed* formulation: W_UK folds into the query and
W_UV into the output, so per-step attention runs entirely in the compressed
kv_lora space and the cache stays (S, kv_lora + rope_dim) per sequence —
the architecture-level analogue of the paper's "navigate in quantized
space, touch full precision rarely".
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import compat
from .config import MLAConfig, ModelConfig
from .layers import apply_rope, dense_init, rmsnorm, rmsnorm_init

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_max, H_kv, Dh)   [MLA: (B, S_max, kv_lora+rope)]
    v: jax.Array  # (B, S_max, H_kv, Dh)   [MLA: unused placeholder (B,0)]


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def gqa_init(key, cfg: ModelConfig, dtype) -> dict:
    dm, H, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (dm, H * Dh), dtype),
        "wk": dense_init(ks[1], (dm, Hkv * Dh), dtype),
        "wv": dense_init(ks[2], (dm, Hkv * Dh), dtype),
        "wo": dense_init(ks[3], (H * Dh, dm), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(Dh, dtype)
        p["k_norm"] = rmsnorm_init(Dh, dtype)
    return p


def _qkv(params, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(B, S, H, Dh)
    k = (x @ params["wk"]).reshape(B, S, Hkv, Dh)
    v = (x @ params["wv"]).reshape(B, S, Hkv, Dh)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope)
    return q, k, v


def _cp_constrain(x: jax.Array, seq_axis: int) -> jax.Array:
    """Shard dim `seq_axis` over the `model` mesh axis (context parallelism)
    under the ambient mesh; no-op without one or when indivisible."""
    m = compat.get_abstract_mesh()
    if m is None or "model" not in (m.axis_names or ()):
        return x
    if x.shape[seq_axis] % m.shape["model"] != 0:
        return x
    spec = [None] * x.ndim
    spec[seq_axis] = "model"
    if x.shape[0] % 16 == 0 and "data" in m.axis_names:
        pass  # leave batch to propagation; over-constraining hurts
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _sdpa_core(q, k, v, H, Hkv, causal: bool, q_offset=0, cp: bool = False):
    """q (B,Sq,H,Dh) × k,v (B,Sk,Hkv,Dh) → (B,Sq,H,Dh). f32 softmax."""
    B, Sq, _, Dh = q.shape
    Sk = k.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, Dh)
    if cp:
        qg = _cp_constrain(qg, 1)  # queries sharded over model on Sq
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(Dh).astype(jnp.float32)
    if causal:
        qpos = q_offset + jnp.arange(Sq)
        mask = qpos[:, None] >= jnp.arange(Sk)[None, :]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    # NOTE (§Perf iteration 6): constraining `scores`/`out` here forces
    # GSPMD to re-shard the S² tensor at the constraint boundaries in the
    # backward pass (+7.3 GiB of all-gathers per layer measured on smollm).
    # Constraining only the (small) query tensor lets the Sq sharding
    # propagate through softmax and the PV contraction for free.
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v)
    return out.reshape(B, Sq, H * Dh)


def _sdpa(q, k, v, H, Hkv, causal: bool, q_offset=0, cp: bool = False,
          q_chunk: int = 0, unroll: bool = False):
    """SDPA with optional query-block chunking (flash-attention's memory
    shape, declaratively): peak scores buffer is (B, H, q_chunk, Sk) instead
    of (B, H, Sq, Sk). On TPU the Pallas flash kernel would replace the
    chunk body; the chunk loop itself is a `lax.scan` (or unrolled for the
    dry-run's cost extraction, like the SSM chunk loops)."""
    B, Sq, _, Dh = q.shape
    if not q_chunk or Sq <= q_chunk or Sq % q_chunk != 0:
        return _sdpa_core(q, k, v, H, Hkv, causal, q_offset, cp)
    nch = Sq // q_chunk
    qs = q.reshape(B, nch, q_chunk, H, Dh).swapaxes(0, 1)  # (nch, B, qc, H, Dh)
    offs = q_offset + jnp.arange(nch) * q_chunk

    def body(qc, off):
        return _sdpa_core(qc, k, v, H, Hkv, causal, off, cp)

    if unroll:
        outs = jnp.stack([body(qs[i], offs[i]) for i in range(nch)])
    else:
        _, outs = jax.lax.scan(lambda c, inp: (c, body(*inp)), None, (qs, offs))
    return outs.swapaxes(0, 1).reshape(B, Sq, H * Dh)


def gqa_train(params, cfg: ModelConfig, x, positions) -> jax.Array:
    q, k, v = _qkv(params, cfg, x, positions)
    out = _sdpa(q, k, v, cfg.num_heads, cfg.num_kv_heads, cfg.causal,
                cp=cfg.cp_attn, q_chunk=cfg.attn_q_chunk,
                unroll=cfg.force_unroll)
    return out @ params["wo"]


def gqa_prefill(params, cfg: ModelConfig, x, positions, cache: KVCache):
    q, k, v = _qkv(params, cfg, x, positions)
    S = x.shape[1]
    cache = KVCache(
        k=jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), 0, 1),
        v=jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), 0, 1),
    )
    out = _sdpa(q, k, v, cfg.num_heads, cfg.num_kv_heads, causal=True,
                cp=cfg.cp_attn, q_chunk=cfg.attn_q_chunk,
                unroll=cfg.force_unroll)
    return out @ params["wo"], cache


def gqa_decode(params, cfg: ModelConfig, x, cache: KVCache, cache_len):
    """x (B, 1, dm); attends to cache[:cache_len] + itself."""
    B = x.shape[0]
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    pos = jnp.full((B, 1), cache_len, jnp.int32)
    q, k, v = _qkv(params, cfg, x, pos)
    k_cache = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, cache_len, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, cache_len, 0, 0))
    S_max = k_cache.shape[1]

    G = H // Hkv
    qg = q.reshape(B, 1, Hkv, G, Dh)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache.astype(q.dtype)).astype(jnp.float32)
    scores = scores / jnp.sqrt(Dh).astype(jnp.float32)
    valid = jnp.arange(S_max)[None, :] <= cache_len  # includes the new token
    scores = jnp.where(valid[:, None, None, None, :][0], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v_cache.astype(q.dtype)).reshape(B, 1, H * Dh)
    return out @ params["wo"], KVCache(k=k_cache, v=v_cache)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------


def mla_init(key, cfg: ModelConfig, dtype) -> dict:
    m: MLAConfig = cfg.mla
    dm, H = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (dm, H * (m.qk_nope_head_dim + m.qk_rope_head_dim)), dtype),
        "wdkv": dense_init(ks[1], (dm, m.kv_lora_rank), dtype),
        "wkr": dense_init(ks[2], (dm, m.qk_rope_head_dim), dtype),
        "kv_norm": rmsnorm_init(m.kv_lora_rank, dtype),
        "wuk": dense_init(ks[3], (m.kv_lora_rank, H * m.qk_nope_head_dim), dtype),
        "wuv": dense_init(ks[4], (m.kv_lora_rank, H * m.v_head_dim), dtype),
        "wo": dense_init(ks[5], (H * m.v_head_dim, dm), dtype),
    }


def _mla_q(params, cfg, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q = (x @ params["wq"]).reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta, "full")
    return q_nope, q_rope


def _mla_attend(q_nope, q_rope, k_nope, k_rope, v, m, q_offset, dtype):
    """One query block of MLA attention: (B,Sq,H,·) vs full keys."""
    Sq, Sk = q_nope.shape[1], k_nope.shape[1]
    scale = 1.0 / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (
        jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
        + jnp.einsum("bqhd,bkxd->bhqk", q_rope, k_rope)
    ).astype(jnp.float32) * scale
    qpos = q_offset + jnp.arange(Sq)
    mask = qpos[:, None] >= jnp.arange(Sk)[None, :]
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def mla_train(params, cfg: ModelConfig, x, positions) -> jax.Array:
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    c_kv = rmsnorm(params["kv_norm"], x @ params["wdkv"], cfg.norm_eps)  # (B,S,r)
    k_rope = apply_rope(
        (x @ params["wkr"])[:, :, None, :], positions, cfg.rope_theta, "full"
    )  # (B,S,1,dr) shared across heads
    k_nope = (c_kv @ params["wuk"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = (c_kv @ params["wuv"]).reshape(B, S, H, m.v_head_dim)

    qc = cfg.attn_q_chunk
    if not qc or S <= qc or S % qc != 0:
        out = _mla_attend(q_nope, q_rope, k_nope, k_rope, v, m, 0, x.dtype)
    else:
        nch = S // qc
        qn = q_nope.reshape(B, nch, qc, H, -1).swapaxes(0, 1)
        qr = q_rope.reshape(B, nch, qc, H, -1).swapaxes(0, 1)
        offs = jnp.arange(nch) * qc

        def body(qnc, qrc, off):
            return _mla_attend(qnc, qrc, k_nope, k_rope, v, m, off, x.dtype)

        if cfg.force_unroll:
            outs = jnp.stack([body(qn[i], qr[i], offs[i]) for i in range(nch)])
        else:
            _, outs = jax.lax.scan(
                lambda c, inp: (c, body(*inp)), None, (qn, qr, offs)
            )
        out = outs.swapaxes(0, 1).reshape(B, S, H, m.v_head_dim)
    out = out.reshape(B, S, H * m.v_head_dim)
    return out @ params["wo"]


def mla_prefill(params, cfg: ModelConfig, x, positions, cache: KVCache):
    """Cache the compressed (c_kv ‖ k_rope) stream — (B, S, r + dr)."""
    m = cfg.mla
    c_kv = rmsnorm(params["kv_norm"], x @ params["wdkv"], cfg.norm_eps)
    k_rope = apply_rope(
        (x @ params["wkr"])[:, :, None, :], positions, cfg.rope_theta, "full"
    )[:, :, 0, :]
    packed = jnp.concatenate([c_kv, k_rope], axis=-1).astype(cache.k.dtype)
    new_k = jax.lax.dynamic_update_slice_in_dim(cache.k, packed, 0, 1)
    out = mla_train(params, cfg, x, positions)
    return out, KVCache(k=new_k, v=cache.v)


def mla_decode(params, cfg: ModelConfig, x, cache: KVCache, cache_len):
    """Absorbed MLA decode: attention entirely in kv_lora space."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    pos = jnp.full((B, 1), cache_len, jnp.int32)
    q_nope, q_rope = _mla_q(params, cfg, x, pos)  # (B,1,H,dn),(B,1,H,dr)

    c_kv_new = rmsnorm(params["kv_norm"], x @ params["wdkv"], cfg.norm_eps)
    k_rope_new = apply_rope(
        (x @ params["wkr"])[:, :, None, :], pos, cfg.rope_theta, "full"
    )[:, :, 0, :]
    packed = jnp.concatenate([c_kv_new, k_rope_new], axis=-1).astype(cache.k.dtype)
    k_cache = jax.lax.dynamic_update_slice(cache.k, packed, (0, cache_len, 0))
    S_max = k_cache.shape[1]
    c_all = k_cache[..., : m.kv_lora_rank].astype(x.dtype)  # (B,S,r)
    r_all = k_cache[..., m.kv_lora_rank :].astype(x.dtype)  # (B,S,dr)

    # absorb W_UK into q: q' (B,1,H,r)
    wuk = params["wuk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope, wuk)
    scale = 1.0 / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (
        jnp.einsum("bqhr,bkr->bhqk", q_abs, c_all)
        + jnp.einsum("bqhd,bkd->bhqk", q_rope, r_all)
    ).astype(jnp.float32) * scale
    valid = jnp.arange(S_max)[None, :] <= cache_len
    scores = jnp.where(valid[:, None, None, :][0], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhqk,bkr->bqhr", w, c_all)  # (B,1,H,r)
    # absorb W_UV on the way out
    wuv = params["wuv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bqhr,rhd->bqhd", ctx, wuv).reshape(B, 1, H * m.v_head_dim)
    return out @ params["wo"], KVCache(k=k_cache, v=cache.v)
