"""Index-term encodings (§3.3, Fig 4, Appendix C).

Two term kinds carry the vector index inside the Bw-Tree:

  * Inverted term (quantized vector):
        TermKey  = pathhash(15B) | 0x17 | [shardhash(8B)] | docid(8B) | codes
        TermValue = dummy PES bitmap
  * Forward term (adjacency list — the new term type this paper adds):
        TermKey  = pathhash(15B) | 0x18 | [shardhash(8B)] | docid(8B)
        TermValue = concatenated 8-byte doc ids, supporting blind appends
                    merged by `merge_adjacency` at consolidation time

Sharded DiskANN (§3.3 "Extending Term Design") prefixes the encoded value
with a shard-key hash so one replica stores a long tail of per-tenant
logical indices in disjoint, contiguous key ranges (cheap to cache, cheap
to scan per tenant).
"""
from __future__ import annotations

import hashlib
import struct
from typing import Iterator, Optional

import numpy as np

QUANT_TERM = 0x17
ADJ_TERM = 0x18
# Inverted property term (the predicate/WHERE-clause term kind, §3.3/§3.5):
#     TermKey  = pathhash(15B) | 0x19 | [shardhash(8B)] | valuehash(8B)
#     TermValue = posting bitmap over the partition's doc slots (packed
#                 uint32 little-endian words — the PES bitmap role, for
#                 real this time: predicates compile to AND/OR/NOT over
#                 these postings with zero document scans)
PROP_TERM = 0x19


def path_hash(path: str) -> bytes:
    """15-byte hash of the indexed JSON path ('/embedding')."""
    return hashlib.blake2b(path.encode(), digest_size=15).digest()


def shard_hash(shard_key) -> bytes:
    """8-byte hash of a shard-key value (tenant id, year, ...)."""
    return hashlib.blake2b(repr(shard_key).encode(), digest_size=8).digest()


def value_token(v) -> bytes:
    """Deterministic typed encoding of a scalar property value — the single
    source of value identity shared by predicate canonical keys
    (serve/predicate.py) and property-term hashes, so True ≠ 1 and
    3 ≠ "3" consistently on both sides of the index."""
    if isinstance(v, bool):  # before int: bool is an int subclass
        return b"b:" + (b"1" if v else b"0")
    if isinstance(v, int):
        return b"i:%d" % v
    if isinstance(v, float):
        return b"f:" + repr(v).encode()
    if isinstance(v, str):
        return b"s:" + v.encode()
    if v is None:
        return b"n:"
    raise TypeError(f"unsupported property value type {type(v).__name__}")


def prop_value_hash(value) -> bytes:
    """8-byte hash of a property value for the PROP_TERM key suffix."""
    return hashlib.blake2b(value_token(value), digest_size=8).digest()


def merge_adjacency(base: Optional[bytes], deltas: list[bytes]) -> bytes:
    """Merge callback for blind adjacency appends (§3.3): concatenate and
    de-duplicate doc ids, preserving first-seen order."""
    raw = (base or b"") + b"".join(deltas)
    seen, out = set(), []
    for (doc,) in struct.iter_unpack(">q", raw):
        if doc not in seen:
            seen.add(doc)
            out.append(doc)
    return b"".join(struct.pack(">q", d) for d in out)


class TermCodec:
    def __init__(self, path: str = "/embedding"):
        self.prefix = path_hash(path)

    # -- keys ---------------------------------------------------------------
    def quant_key(self, doc_id: int, shard=None) -> bytes:
        mid = shard_hash(shard) if shard is not None else b""
        return self.prefix + bytes([QUANT_TERM]) + mid + struct.pack(">q", doc_id)

    def adj_key(self, doc_id: int, shard=None) -> bytes:
        mid = shard_hash(shard) if shard is not None else b""
        return self.prefix + bytes([ADJ_TERM]) + mid + struct.pack(">q", doc_id)

    def quant_prefix(self, shard=None) -> bytes:
        mid = shard_hash(shard) if shard is not None else b""
        return self.prefix + bytes([QUANT_TERM]) + mid

    def adj_prefix(self, shard=None) -> bytes:
        mid = shard_hash(shard) if shard is not None else b""
        return self.prefix + bytes([ADJ_TERM]) + mid

    @staticmethod
    def prop_key(path: str, value, shard=None) -> bytes:
        """Inverted property-term key: the property path is hashed like the
        vector path (each indexed path owns a contiguous key range), the
        value hashed through the SAME typed token as predicate canonical
        keys, so a predicate and the ingest path can never disagree about
        value identity."""
        mid = shard_hash(shard) if shard is not None else b""
        return path_hash(path) + bytes([PROP_TERM]) + mid + prop_value_hash(value)

    # -- values -------------------------------------------------------------
    @staticmethod
    def encode_posting(words) -> bytes:
        """Posting bitmap value: packed uint32 words, little-endian."""
        return np.asarray(words, dtype="<u4").tobytes()

    @staticmethod
    def decode_posting(v: bytes) -> np.ndarray:
        return np.frombuffer(v, dtype="<u4").astype(np.uint32)

    # -- values -------------------------------------------------------------
    @staticmethod
    def encode_quant_value(codes: bytes, version: int) -> bytes:
        return bytes([version]) + codes

    @staticmethod
    def decode_quant_value(v: bytes) -> tuple[bytes, int]:
        return v[1:], v[0]

    @staticmethod
    def encode_adjacency(doc_ids) -> bytes:
        return b"".join(struct.pack(">q", int(d)) for d in doc_ids)

    @staticmethod
    def decode_adjacency(v: bytes) -> list[int]:
        return [doc for (doc,) in struct.iter_unpack(">q", v)]

    @staticmethod
    def decode_doc_id(key: bytes) -> int:
        return struct.unpack(">q", key[-8:])[0]
