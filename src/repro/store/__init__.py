"""repro.store — the Bw-Tree analogue, index-term encodings, RU governance.

The paper stores DiskANN's index terms as key-value pairs in Cosmos DB's
Bw-Tree (§3.3): quantized vectors as *inverted terms*, adjacency lists as a
new *forward term* kind supporting blind incremental appends that are merged
at consolidation time. This package reproduces the pieces the paper's
behaviour depends on:

    bwtree.py    ordered pages + delta chains (blind appends), consolidation
                 at max chain length (15 in §4), page cache with hit/miss
                 accounting, prefix seek / range scan
    terms.py     term-key encodings of Fig 4 / Appendix C (path-hash prefix,
                 type marker, doc id, shard-key prefix for sharded DiskANN)
    ru.py        Request Units: the paper's normalized cost currency, with
                 constants calibrated to §4's published operating points
    provider.py  StoreProviderSet — the Provider traits backed by the store,
                 write-through into the dense-array cache the jitted
                 kernels consume
"""
from .bwtree import BwTree, BwTreeStats
from .terms import TermCodec, QUANT_TERM, ADJ_TERM
from .ru import RUMeter, RUConfig
from .provider import StoreProviderSet

__all__ = [
    "BwTree",
    "BwTreeStats",
    "TermCodec",
    "QUANT_TERM",
    "ADJ_TERM",
    "RUMeter",
    "RUConfig",
    "StoreProviderSet",
]
