"""Paged full-precision vector tier (ISSUE 10).

The DiskANN storage position that the paper's cost story rests on:
quantized codes + graph adjacency + postings stay memory-resident while
full-precision vectors live in a cheaper paged tier, fetched only for
the final rerank stage. This module is the residency ledger for that
tier — a fixed-size-page cache over the partition's vector array with
clock (second-chance) eviction, pin-during-rerank, and deterministic
behaviour under SimClock (no wall clock, no unseeded randomness).

Residency here is *modelled*, not physical: the vectors stay in the
provider's numpy array (so the jitted rerank math is byte-identical at
every residency level), and the cache tracks which pages WOULD be
resident, charging RU + modelled fetch latency for each miss via
``store/ru.py``'s ``vector_page_misses`` counter. ``budget_pages=None``
(the default) keeps every page resident — zero misses, zero cost — so
an untiered partition is bit-identical to the pre-tier engine.

Determinism contract: the resident set is a pure function of
``(seed, budget history, touch sequence)``. The warm set on a cold
finite-budget cache is a seeded permutation; eviction is a clock sweep
from a persistent hand. Two runs issuing identical touch sequences see
identical hits/misses/evictions.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


class PagedVectorStore:
    """Residency ledger for fixed-size pages of full-precision vectors.

    Parameters
    ----------
    capacity : int
        Number of vector slots in the backing array.
    dim : int
        Vector dimensionality (used only for byte accounting).
    page_size : int
        Vectors per page. Slot ``s`` lives on page ``s // page_size``.
    budget_pages : Optional[int]
        Resident-set budget in pages. ``None`` → unbounded (fully
        resident, every touch a free hit). ``0 <= budget <= n_pages``
        caps residency; misses beyond it cost RU + latency.
    seed : int
        Seeds the warm resident set on a cold finite-budget cache.
    """

    def __init__(self, capacity: int, dim: int, *, page_size: int = 64,
                 budget_pages: Optional[int] = None, seed: int = 0):
        if page_size <= 0:
            raise ValueError(f"page_size must be positive, got {page_size}")
        self.capacity = int(capacity)
        self.dim = int(dim)
        self.page_size = int(page_size)
        self.seed = int(seed)
        self.n_pages = max(1, -(-self.capacity // self.page_size))
        # clock state
        self.resident = np.zeros(self.n_pages, dtype=bool)
        self.ref = np.zeros(self.n_pages, dtype=bool)
        self.pins = np.zeros(self.n_pages, dtype=np.int32)
        self.hand = 0
        # cumulative counters (page granularity)
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.admits = 0
        self.budget_pages: Optional[int] = None
        self.set_budget(budget_pages)

    # -- residency -------------------------------------------------------

    def set_budget(self, budget_pages: Optional[int]) -> None:
        """(Re)set the resident budget deterministically.

        ``None`` → everything resident. Shrinking a finite budget clock-
        evicts down (pinned pages are never victims — transient overflow
        drains on ``unpin``). Growing leaves the resident set as-is; new
        room fills on demand. A COLD cache (nothing resident yet) given a
        finite budget gets a seeded warm set, so a freshly-tiered
        partition starts at its budget rather than all-miss.
        """
        if budget_pages is None:
            self.budget_pages = None
            self.resident[:] = True
            return
        budget = int(np.clip(budget_pages, 0, self.n_pages))
        if self.budget_pages is None:
            # transitioning from unbounded: keep a seeded warm subset
            self.resident[:] = False
            if budget > 0:
                warm = np.random.RandomState(self.seed).permutation(
                    self.n_pages)[:budget]
                self.resident[warm] = True
            self.ref[:] = False
        self.budget_pages = budget
        self._evict_to_budget()

    def resize_budget(self, budget_pages: Optional[int]) -> None:
        """Policy-plane alias for :meth:`set_budget`."""
        self.set_budget(budget_pages)

    @property
    def n_resident(self) -> int:
        return int(self.resident.sum())

    # -- the access path -------------------------------------------------

    def touch(self, slots, admit: bool = True, pin: bool = False):
        """Record a rerank-stage access to ``slots`` (any int array-like).

        Returns ``(hits, misses, pages)`` for this touch: page-level
        counts plus the unique page ids accessed (pass ``pages`` back to
        :meth:`unpin` when ``pin=True``). Negative slots (padding
        sentinels) are ignored.

        * ``admit=True`` (graph rerank): missed pages are fetched AND
          admitted, clock-evicting unpinned pages to make room.
        * ``admit=False`` (brute/exact scans): misses are counted and
          billed but never admitted — scan resistance, a full sweep must
          not flush the hot set.
        * ``pin=True``: every touched page is pinned for the duration of
          the rerank; pinned pages are never eviction victims, even if
          that transiently overflows the budget (drained on unpin).
        """
        slots = np.asarray(slots).reshape(-1)
        slots = slots[slots >= 0]
        if slots.size == 0:
            return 0, 0, np.empty(0, dtype=np.int64)
        pages = np.unique(slots // self.page_size).astype(np.int64)
        pages = pages[pages < self.n_pages]
        if self.budget_pages is None:
            # unbounded: everything resident, touches are free hits
            self.hits += int(pages.size)
            self.ref[pages] = True
            if pin:
                self.pins[pages] += 1
            return int(pages.size), 0, pages
        res = self.resident[pages]
        hits = int(res.sum())
        misses = int(pages.size - hits)
        self.hits += hits
        self.misses += misses
        self.ref[pages[res]] = True
        if pin:
            # pin the working set FIRST so room-making can't evict a page
            # this same rerank is about to touch
            self.pins[pages] += 1
        if admit and misses and self.budget_pages > 0:
            for pg in pages[~res]:
                self._make_room()
                self.resident[pg] = True
                self.ref[pg] = True
                self.admits += 1
        return hits, misses, pages

    def unpin(self, pages) -> None:
        """Release a rerank's pins and drain any pin-induced overflow."""
        pages = np.asarray(pages, dtype=np.int64).reshape(-1)
        if pages.size == 0:
            return
        self.pins[pages] -= 1
        if np.any(self.pins < 0):
            raise AssertionError("unpin without matching pin")
        self._evict_to_budget()

    # -- clock eviction --------------------------------------------------

    def _make_room(self) -> None:
        if self.budget_pages is None:
            return
        while self.n_resident >= max(self.budget_pages, 1):
            if not self._evict_one():
                break  # everything pinned: transient overflow allowed

    def _evict_to_budget(self) -> None:
        if self.budget_pages is None:
            return
        while self.n_resident > self.budget_pages:
            if not self._evict_one():
                break

    def _evict_one(self) -> bool:
        """One clock sweep: skip pinned, clear ref on first pass, evict
        the first unreferenced unpinned resident page. Returns False if
        no victim exists (all resident pages pinned)."""
        for _ in range(2 * self.n_pages):
            pg = self.hand
            self.hand = (self.hand + 1) % self.n_pages
            if not self.resident[pg] or self.pins[pg] > 0:
                continue
            if self.ref[pg]:
                self.ref[pg] = False
                continue
            self.resident[pg] = False
            self.evictions += 1
            return True
        return False

    # -- introspection ---------------------------------------------------

    def page_slots(self, pg: int) -> slice:
        """Slot range backing page ``pg`` — residency-independent, used
        by recovery parity checks to bit-compare the paged tier page by
        page regardless of either side's cache state."""
        lo = pg * self.page_size
        return slice(lo, min(lo + self.page_size, self.capacity))

    def state(self) -> dict:
        bytes_per_page = self.page_size * self.dim * 4
        n_res = self.n_resident
        return dict(
            page_size=self.page_size,
            n_pages=self.n_pages,
            budget_pages=self.budget_pages,
            resident_pages=n_res,
            resident_frac=n_res / self.n_pages,
            resident_bytes=n_res * bytes_per_page,
            total_bytes=self.n_pages * bytes_per_page,
            pinned_pages=int((self.pins > 0).sum()),
            hits=self.hits, misses=self.misses,
            evictions=self.evictions, admits=self.admits,
        )
