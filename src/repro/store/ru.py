"""Request Units — Cosmos DB's normalized cost currency (§2.2), calibrated.

RUs abstract CPU, IOPS and memory; the Resource Governance component
guarantees provisioned RU/s per partition and throttles beyond it. The
paper publishes enough operating points to calibrate a linear RU model over
the index-term access counters our store/search paths expose:

    Table 1: ~70 RU per query   (10M × 768D, default settings)
    Table 2: ~65 RU per insert  (768D, R=32, L_build=100)
    §4.4:    ~3500 quantized + ~50 full-precision reads per query;
             each insert touches ≈ R·L_build quantized vectors and ≈L_build
             adjacency lists; 10 µs / 25 µs per quantized / adjacency read;
             ~3 ms CPU in the DiskANN library per insert
    Fig 7/8: query RU grows < 2× for 100× more vectors (logarithmic hops)

With the defaults below the modelled costs land on those points (validated
in benchmarks/bench_cost.py), and RU-vs-L / RU-vs-N curves reproduce the
shapes of Figs 6-8 because the underlying counters do.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class RUConfig:
    ru_per_quant_read: float = 0.0125  # ≈80 quantized-term reads / RU
    ru_per_adj_read: float = 0.10
    ru_per_full_read: float = 0.50  # document-store vector load
    ru_per_quant_write: float = 0.50
    ru_per_adj_write: float = 0.30  # incl. blind appends
    # inverted property-term postings (the predicate/WHERE index): writes
    # are bitmap upserts riding the doc write; reads are the per-leaf-term
    # posting lookups a predicate compilation performs on a bitmap-cache
    # miss (a cache hit costs zero — the hit rate is directly visible in
    # query RU)
    ru_per_prop_write: float = 0.05
    ru_per_prop_read: float = 0.005
    ru_per_doc_write: float = 5.0  # the transactional document write
    ru_per_cpu_ms: float = 0.50
    ru_per_page_read: float = 0.005  # Bw-Tree page touch (cache-miss extra)
    ru_per_cache_miss: float = 0.05
    # upfront vector charge (§3.4 "Upfront charging"): per KB of vector
    ru_upfront_per_kb: float = 1.0
    # minimum charge per continuation/page request (§2.2): Cosmos bills
    # every request at least the request-processing floor, so a paginated
    # query is never free even when a page is answered from buffered state
    ru_per_page_request: float = 1.0
    # tiered vector storage (ISSUE 10): full-precision vectors live in a
    # paged tier; a rerank-stage page miss is a cold fetch billed in RU
    # AND modelled latency, a hit costs neither (the resident set is the
    # cost lever the "Cloud-Native Vector Search" curve sweeps)
    ru_per_vector_page: float = 0.25

    # latency model (paper §4.4 micro-measurements)
    us_per_quant_read: float = 10.0
    us_per_adj_read: float = 25.0
    us_per_full_read: float = 100.0  # random document-store access
    us_per_chain_record: float = 0.8  # extra per delta-chain record walked
    us_per_vector_page: float = 110.0  # cold paged-tier vector fetch


@dataclasses.dataclass
class OpCounters:
    quant_reads: int = 0
    adj_reads: int = 0
    full_reads: int = 0
    quant_writes: int = 0
    adj_writes: int = 0
    prop_writes: int = 0  # property-term posting upserts
    prop_reads: int = 0  # posting lookups (predicate compile, cache miss)
    doc_writes: int = 0
    cpu_ms: float = 0.0
    page_reads: int = 0
    cache_misses: int = 0
    chain_records: int = 0
    vector_kb: float = 0.0
    vector_page_misses: int = 0  # paged-tier cold fetches (rerank stage)

    def __iadd__(self, o: "OpCounters"):
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(o, f.name))
        return self


class RUMeter:
    """Accumulates per-operation counters and converts to RUs / latency."""

    def __init__(self, cfg: RUConfig = RUConfig()):
        self.cfg = cfg
        self.total = OpCounters()

    def charge(self, c: OpCounters) -> float:
        self.total += c
        return self.ru(c)

    def ru(self, c: OpCounters) -> float:
        g = self.cfg
        return (
            g.ru_per_quant_read * c.quant_reads
            + g.ru_per_adj_read * c.adj_reads
            + g.ru_per_full_read * c.full_reads
            + g.ru_per_quant_write * c.quant_writes
            + g.ru_per_adj_write * c.adj_writes
            + g.ru_per_prop_write * c.prop_writes
            + g.ru_per_prop_read * c.prop_reads
            + g.ru_per_doc_write * c.doc_writes
            + g.ru_per_cpu_ms * c.cpu_ms
            + g.ru_per_page_read * c.page_reads
            + g.ru_per_cache_miss * c.cache_misses
            + g.ru_upfront_per_kb * c.vector_kb
            + g.ru_per_vector_page * c.vector_page_misses
        )

    def latency_ms(self, c: OpCounters) -> float:
        """Modelled single-thread latency (the paper's ≈25 ms/insert napkin
        math in §4.4 falls out of these constants)."""
        g = self.cfg
        us = (
            g.us_per_quant_read * c.quant_reads
            + g.us_per_adj_read * c.adj_reads
            + g.us_per_full_read * c.full_reads
            + g.us_per_chain_record * c.chain_records
            + g.us_per_vector_page * c.vector_page_misses
        )
        return us / 1000.0 + c.cpu_ms


def counters_for_ru(stats, lanes: int = 1) -> OpCounters:
    """Work-based counters from search ``QueryStats``: RU charges every
    quantized comparison and every adjacency row actually fetched
    (``expansions``) — beam width buys latency, not free reads."""
    adj = getattr(stats, "expansions", 0.0) or stats.hops
    return OpCounters(
        quant_reads=int(stats.cmps * lanes),
        adj_reads=int(adj * lanes),
        full_reads=int(stats.full_reads * lanes),
        # tier misses in QueryStats are per-query means; RU bills the
        # whole batch's page fetches (work-based), so scale back up
        vector_page_misses=int(
            round(getattr(stats, "tier_misses", 0.0) * lanes)),
    )


def counters_for_latency(stats) -> OpCounters:
    """Critical-path counters from search ``QueryStats``: one beam-width
    round issues its ≤ W·R_slack quantized reads concurrently (the paper's
    beamWidth bang-for-the-buck), so the sequential path sees ``cmps / W̄``
    of them — W̄ = expansions/rounds, measured from the stats so
    partially-filled late rounds are not over-credited. Adjacency fetches
    coalesce into one round trip per round. The single source of truth for
    the round-structured latency model (fanout, serve, benchmarks)."""
    w_bar = max(
        getattr(stats, "expansions", 0.0) / max(stats.hops, 1e-9), 1.0
    )
    return OpCounters(
        quant_reads=int(round(stats.cmps / w_bar)),
        adj_reads=int(stats.hops),
        full_reads=int(stats.full_reads),
        # per-query critical path: this query's own page misses (the
        # batch amortizes fetches, the mean IS the per-query cost)
        vector_page_misses=int(
            round(getattr(stats, "tier_misses", 0.0))),
    )


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    """Outcome of a non-blocking admission check (the 429 path): when not
    admitted, `retry_after_s` is the refill time until the estimate fits."""

    admitted: bool
    retry_after_s: float = 0.0


class ResourceGovernor:
    """Provisioned-throughput governance (§2.2): grants RU budget per
    second of simulated time; callers exceeding it are throttled (made to
    wait), which is how background graph maintenance is paced so it can
    catch up with transactions (§3.4).

    Two client styles coexist:
      * ``request`` — blocking: the caller absorbs the throttle delay
        (background maintenance pacing);
      * ``try_admit`` / ``settle`` — non-blocking: the serving layer asks
        first, rejects over-budget tenants with a retry-after instead of
        degrading everyone, then settles the actual cost post-execution
        (which may push `available` negative — the debt refills over time).
    """

    def __init__(self, provisioned_ru_s: float):
        self.provisioned = provisioned_ru_s
        self.clock_s = 0.0
        self.available = provisioned_ru_s
        self.throttle_events = 0
        self.consumed = 0.0
        # settlement telemetry (cost-attribution reconciliation): every
        # settle/refund event counts, and refunded RU is tracked so
        # `consumed` can be audited against the serving registry
        self.settlements = 0
        self.refunded = 0.0

    def request(self, ru: float) -> float:
        """Consume `ru`; returns seconds of throttle delay incurred."""
        delay = 0.0
        while ru > self.available:
            deficit = ru - self.available
            wait = deficit / self.provisioned
            delay += wait
            self.clock_s += wait
            self.available += wait * self.provisioned
            self.throttle_events += 1
        self.available -= ru
        self.consumed += ru
        return delay

    def advance(self, seconds: float):
        self.clock_s += seconds
        self.available = min(
            self.available + seconds * self.provisioned, self.provisioned
        )

    # ------------------------------------------------------------------
    # non-blocking API (serving-layer admission control)
    # ------------------------------------------------------------------
    def refill_to(self, now_s: float):
        """Advance to absolute simulated time `now_s`, refilling budget
        (burst capacity caps at one second of provisioned throughput)."""
        if now_s > self.clock_s:
            self.advance(now_s - self.clock_s)

    def try_admit(self, ru_estimate: float, now_s: Optional[float] = None) -> AdmissionDecision:
        """Would a request costing ~`ru_estimate` fit the current budget?
        Does NOT consume — pair with ``settle`` after execution."""
        if now_s is not None:
            self.refill_to(now_s)
        if self.available >= ru_estimate:
            return AdmissionDecision(admitted=True)
        self.throttle_events += 1
        deficit = ru_estimate - self.available
        return AdmissionDecision(
            admitted=False, retry_after_s=deficit / self.provisioned
        )

    def settle(self, ru: float, now_s: Optional[float] = None):
        """Record the actual cost of an admitted request. `available` may go
        negative (the estimate was low); the debt pays down on refill."""
        if now_s is not None:
            self.refill_to(now_s)
        self.available -= ru
        self.consumed += ru
        self.settlements += 1

    def refund(self, ru: float, now_s: Optional[float] = None):
        """Hand back an unused admission reservation (failed dispatches,
        throttled page chains): the budget returns and the reservation no
        longer counts as consumption."""
        self.refunded += ru
        self.settle(-ru, now_s=now_s)
