"""StoreProviderSet — Provider traits backed by the Bw-Tree analogue.

The write path mirrors Fig 15: the index orchestrator calls the Provider,
which encodes index terms (terms.py) into the Bw-Tree (durability + RU
metering) and writes through to the dense-array cache the jitted kernels
consume. Reads for the query hot path come from the cache (as in the paper,
where the Bw-Tree cache holds the quantized + adjacency terms, §4); the
store read path exists for cold reads and for benchmarks that need page /
chain-length accounting (Figs 11-12).

A write-ahead log provides crash recovery: `snapshot()` + WAL replay
reconstructs both the store and the cache (tests/test_store.py exercises
kill-and-recover).
"""
from __future__ import annotations

import pickle
from typing import Optional

import numpy as np

from ..core.providers import ArrayProviderSet, Context
from .bwtree import BwTree
from .ru import OpCounters, RUConfig, RUMeter
from .terms import TermCodec, merge_adjacency


class StoreProviderSet(ArrayProviderSet):
    """Write-through providers: Bw-Tree terms + dense cache + RU meter."""

    def __init__(
        self,
        capacity: int,
        R_slack: int,
        M: int,
        dim: int,
        path: str = "/embedding",
        ru: Optional[RUMeter] = None,
        cache_pages: int = 1 << 30,
        wal: bool = True,
    ):
        super().__init__(capacity, R_slack, M, dim)
        self.tree = BwTree(merge_fn=merge_adjacency, cache_pages=cache_pages)
        self.codec = TermCodec(path)
        self.meter = ru or RUMeter(RUConfig())
        self.op = OpCounters()  # counters for the current logical operation
        self._wal: list[tuple] | None = [] if wal else None

    # ------------------------------------------------------------------
    def begin_op(self):
        self.op = OpCounters()

    def end_op(self) -> tuple[float, float]:
        """Returns (RU charge, modelled latency ms) for the finished op."""
        before = (self.tree.stats.page_reads, self.tree.stats.cache_misses,
                  self.tree.stats.delta_traversals)
        self.op.page_reads = self.tree.stats.page_reads
        self.op.cache_misses = self.tree.stats.cache_misses
        self.op.chain_records = self.tree.stats.delta_traversals
        self.tree.stats.reset()
        ru = self.meter.charge(self.op)
        lat = self.meter.latency_ms(self.op)
        return ru, lat

    def _log(self, *entry):
        if self._wal is not None:
            self._wal.append(entry)

    # ------------------------------------------------------------------
    # neighbor (forward) terms
    # ------------------------------------------------------------------
    def set_neighbors(self, ctx: Context, ids, rows):
        super().set_neighbors(ctx, ids, rows)
        rows = np.asarray(rows)
        for i, node in enumerate(np.asarray(ids)):
            row = rows[i]
            docs = [int(x) for x in row[row >= 0]]
            self.tree.upsert(
                self.codec.adj_key(int(node), ctx.shard_key),
                self.codec.encode_adjacency(docs),
            )
            self.op.adj_writes += 1
        self._log("set_neighbors", np.asarray(ids).copy(), rows.copy())

    def append_neighbors(self, ctx: Context, node: int, new_ids):
        fitted = super().append_neighbors(ctx, node, new_ids)
        # blind incremental update — the paper's fast append path
        self.tree.append(
            self.codec.adj_key(int(node), ctx.shard_key),
            self.codec.encode_adjacency([int(x) for x in new_ids[:fitted]]),
        )
        self.op.adj_writes += 1
        self._log("append_neighbors", int(node), np.asarray(new_ids[:fitted]).copy())
        return fitted

    def read_neighbors_from_store(self, ctx: Context, node: int) -> list[int]:
        self.op.adj_reads += 1
        v = self.tree.get(self.codec.adj_key(int(node), ctx.shard_key))
        return self.codec.decode_adjacency(v) if v else []

    # ------------------------------------------------------------------
    # quantized (inverted) terms
    # ------------------------------------------------------------------
    def set_quant(self, ctx: Context, ids, codes, versions):
        super().set_quant(ctx, ids, codes, versions)
        codes = np.asarray(codes)
        versions = np.asarray(versions)
        for i, node in enumerate(np.asarray(ids)):
            self.tree.upsert(
                self.codec.quant_key(int(node), ctx.shard_key),
                self.codec.encode_quant_value(codes[i].tobytes(), int(versions[i])),
            )
            self.op.quant_writes += 1
        self._log("set_quant", np.asarray(ids).copy(), codes.copy(), versions.copy())

    def read_quant_from_store(self, ctx: Context, node: int):
        self.op.quant_reads += 1
        v = self.tree.get(self.codec.quant_key(int(node), ctx.shard_key))
        if v is None:
            return None
        codes, ver = self.codec.decode_quant_value(v)
        return np.frombuffer(codes, np.uint8), ver

    # ------------------------------------------------------------------
    # inverted property terms (predicate postings)
    # ------------------------------------------------------------------
    def write_prop_posting(self, term_key: bytes, words: np.ndarray):
        """Persist one PROP_TERM posting bitmap (store.props write-through):
        the predicate index durably rides the same Bw-Tree as the quantized
        and adjacency terms, and each upsert is RU-metered."""
        self.tree.upsert(term_key, self.codec.encode_posting(words))
        self.op.prop_writes += 1

    def read_prop_posting(self, term_key: bytes) -> Optional[np.ndarray]:
        self.op.prop_reads += 1
        v = self.tree.get(term_key)
        return None if v is None else self.codec.decode_posting(v)

    # ------------------------------------------------------------------
    # document store (full vectors)
    # ------------------------------------------------------------------
    def set_full(self, ctx: Context, ids, vecs):
        super().set_full(ctx, ids, vecs)
        vecs = np.asarray(vecs)
        self.op.doc_writes += len(np.asarray(ids))
        self.op.vector_kb += vecs.nbytes / 1024.0
        self._log("set_full", np.asarray(ids).copy(), vecs.copy())

    def get_full(self, ctx: Context, ids):
        self.op.full_reads += len(np.asarray(ids))
        return super().get_full(ctx, ids)

    def set_live(self, ctx: Context, ids, value: bool):
        super().set_live(ctx, ids, value)
        self._log("set_live", np.asarray(ids).copy(), value)

    # ------------------------------------------------------------------
    # durability: snapshot + WAL replay
    # ------------------------------------------------------------------
    def snapshot_bytes(self) -> bytes:
        state = dict(
            neighbors=self.neighbors,
            codes=self.codes,
            versions=self.versions,
            live=self.live,
            vectors=self.vectors,
            tree=self.tree,  # the durable term state itself
        )
        if self._wal is not None:
            self._wal = []
        return pickle.dumps(state)

    def wal_bytes(self) -> bytes:
        return pickle.dumps(self._wal or [])

    def recover(self, snapshot: bytes, wal: bytes, ctx: Context = Context()):
        state = pickle.loads(snapshot)
        self.neighbors[:] = state["neighbors"]
        self.codes[:] = state["codes"]
        self.versions[:] = state["versions"]
        self.live[:] = state["live"]
        self.vectors[:] = state["vectors"]
        self.tree = state["tree"]
        self._dirty()
        entries = pickle.loads(wal)
        saved_wal, self._wal = self._wal, None  # don't re-log during replay
        try:
            for entry in entries:
                op, *args = entry
                getattr(self, op)(ctx, *args)
        finally:
            self._wal = [] if saved_wal is not None else None
