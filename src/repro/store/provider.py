"""StoreProviderSet — Provider traits backed by the Bw-Tree analogue.

The write path mirrors Fig 15: the index orchestrator calls the Provider,
which encodes index terms (terms.py) into the Bw-Tree (durability + RU
metering) and writes through to the dense-array cache the jitted kernels
consume. Reads for the query hot path come from the cache (as in the paper,
where the Bw-Tree cache holds the quantized + adjacency terms, §4); the
store read path exists for cold reads and for benchmarks that need page /
chain-length accounting (Figs 11-12).

A write-ahead log provides crash recovery: `snapshot()` + WAL replay
reconstructs both the store and the cache (tests/test_store.py and
tests/test_faults.py exercise kill-and-recover). Durability bytes travel
through the pickle-free codec in ``store/codec.py``: the snapshot is
versioned + CRC'd, and each WAL record is one committed transaction with
its own CRC, so recovery truncates a torn tail to the last whole
transaction instead of raising — and rejects interior bit rot instead of
silently losing committed data. Writes made inside a ``begin_op`` /
``end_op`` window commit atomically at ``end_op``; a crash in between
leaves no trace of the interrupted operation in the log.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..core.providers import ArrayProviderSet, Context
from . import codec as storecodec
from .bwtree import BwTree
from .ru import OpCounters, RUConfig, RUMeter
from .terms import TermCodec, merge_adjacency


class StoreProviderSet(ArrayProviderSet):
    """Write-through providers: Bw-Tree terms + dense cache + RU meter."""

    def __init__(
        self,
        capacity: int,
        R_slack: int,
        M: int,
        dim: int,
        path: str = "/embedding",
        ru: Optional[RUMeter] = None,
        cache_pages: int = 1 << 30,
        wal: bool = True,
    ):
        super().__init__(capacity, R_slack, M, dim)
        self._cache_pages = cache_pages
        self.tree = BwTree(merge_fn=merge_adjacency, cache_pages=cache_pages)
        self.codec = TermCodec(path)
        self.meter = ru or RUMeter(RUConfig())
        self.op = OpCounters()  # counters for the current logical operation
        # committed WAL: one record (list of entries) per transaction
        self._wal: list[list[tuple]] | None = [] if wal else None
        self._txn: list[tuple] | None = None  # open (uncommitted) transaction
        self.committed = 0  # committed records since construction/recovery
        self.snapshot_lsn = 0  # `committed` as of the last snapshot
        self.recovered_torn_tail = False
        self.faults = None  # optional store.faults.FaultPlan

    # ------------------------------------------------------------------
    def barrier(self, name: str):
        """Crash-injection point: a no-op unless a FaultPlan is attached."""
        if self.faults is not None:
            self.faults.barrier(name)

    def begin_op(self):
        self.op = OpCounters()
        # open a WAL transaction; an uncommitted one left behind by an
        # injected crash is discarded — exactly what a process kill does
        self._txn = [] if self._wal is not None else None

    def end_op(self) -> tuple[float, float]:
        """Returns (RU charge, modelled latency ms) for the finished op.
        Commits the op's WAL transaction atomically: all entries land as
        one record, or (if the op crashed before reaching here) none do."""
        self.op.page_reads = self.tree.stats.page_reads
        self.op.cache_misses = self.tree.stats.cache_misses
        self.op.chain_records = self.tree.stats.delta_traversals
        self.tree.stats.reset()
        ru = self.meter.charge(self.op)
        lat = self.meter.latency_ms(self.op)
        if self._wal is not None and self._txn:
            self._wal.append(self._txn)
            self.committed += 1
        self._txn = None
        return ru, lat

    def _log(self, *entry):
        if self._wal is None:
            return
        if self._txn is not None:
            self._txn.append(entry)
        else:  # bare write outside a begin_op/end_op window: auto-commit
            self._wal.append([entry])
            self.committed += 1

    # ------------------------------------------------------------------
    # neighbor (forward) terms
    # ------------------------------------------------------------------
    def set_neighbors(self, ctx: Context, ids, rows):
        super().set_neighbors(ctx, ids, rows)
        rows = np.asarray(rows)
        for i, node in enumerate(np.asarray(ids)):
            row = rows[i]
            docs = [int(x) for x in row[row >= 0]]
            self.tree.upsert(
                self.codec.adj_key(int(node), ctx.shard_key),
                self.codec.encode_adjacency(docs),
            )
            self.op.adj_writes += 1
        self._log("set_neighbors", np.asarray(ids).copy(), rows.copy())

    def append_neighbors(self, ctx: Context, node: int, new_ids):
        fitted = super().append_neighbors(ctx, node, new_ids)
        # blind incremental update — the paper's fast append path
        self.tree.append(
            self.codec.adj_key(int(node), ctx.shard_key),
            self.codec.encode_adjacency([int(x) for x in new_ids[:fitted]]),
        )
        self.op.adj_writes += 1
        self._log("append_neighbors", int(node), np.asarray(new_ids[:fitted]).copy())
        return fitted

    def read_neighbors_from_store(self, ctx: Context, node: int) -> list[int]:
        self.op.adj_reads += 1
        v = self.tree.get(self.codec.adj_key(int(node), ctx.shard_key))
        return self.codec.decode_adjacency(v) if v else []

    # ------------------------------------------------------------------
    # quantized (inverted) terms
    # ------------------------------------------------------------------
    def set_quant(self, ctx: Context, ids, codes, versions):
        super().set_quant(ctx, ids, codes, versions)
        codes = np.asarray(codes)
        versions = np.asarray(versions)
        for i, node in enumerate(np.asarray(ids)):
            self.tree.upsert(
                self.codec.quant_key(int(node), ctx.shard_key),
                self.codec.encode_quant_value(codes[i].tobytes(), int(versions[i])),
            )
            self.op.quant_writes += 1
        self._log("set_quant", np.asarray(ids).copy(), codes.copy(), versions.copy())

    def read_quant_from_store(self, ctx: Context, node: int):
        self.op.quant_reads += 1
        v = self.tree.get(self.codec.quant_key(int(node), ctx.shard_key))
        if v is None:
            return None
        codes, ver = self.codec.decode_quant_value(v)
        return np.frombuffer(codes, np.uint8), ver

    # ------------------------------------------------------------------
    # inverted property terms (predicate postings)
    # ------------------------------------------------------------------
    def write_prop_posting(self, term_key: bytes, words: np.ndarray):
        """Persist one PROP_TERM posting bitmap (store.props write-through):
        the predicate index durably rides the same Bw-Tree as the quantized
        and adjacency terms, and each upsert is RU-metered."""
        self.tree.upsert(term_key, self.codec.encode_posting(words))
        self.op.prop_writes += 1
        self._log("write_prop_posting", bytes(term_key),
                  np.asarray(words).copy())

    def read_prop_posting(self, term_key: bytes) -> Optional[np.ndarray]:
        self.op.prop_reads += 1
        v = self.tree.get(term_key)
        return None if v is None else self.codec.decode_posting(v)

    # ------------------------------------------------------------------
    # document store (full vectors)
    # ------------------------------------------------------------------
    def set_full(self, ctx: Context, ids, vecs):
        super().set_full(ctx, ids, vecs)
        vecs = np.asarray(vecs)
        self.op.doc_writes += len(np.asarray(ids))
        self.op.vector_kb += vecs.nbytes / 1024.0
        self._log("set_full", np.asarray(ids).copy(), vecs.copy())

    def get_full(self, ctx: Context, ids):
        self.op.full_reads += len(np.asarray(ids))
        return super().get_full(ctx, ids)

    def set_live(self, ctx: Context, ids, value: bool):
        super().set_live(ctx, ids, value)
        self._log("set_live", np.asarray(ids).copy(), value)

    # ------------------------------------------------------------------
    # durability: snapshot + WAL replay (pickle-free; store/codec.py)
    # ------------------------------------------------------------------
    def snapshot_bytes(self) -> bytes:
        """Checkpoint the durable state (dense caches + every term in the
        Bw-Tree) and clear the committed WAL. Uncommitted transaction
        entries are never captured — they don't exist durably yet."""
        self.snapshot_lsn = self.committed
        if self._wal is not None:
            self._wal = []
        return storecodec.encode_snapshot(
            self.neighbors, self.codes, self.versions, self.live,
            self.vectors, self.tree.dump_items(), self.snapshot_lsn,
        )

    def wal_bytes(self) -> bytes:
        return storecodec.encode_wal(self._wal or [])

    def _check_replay_entry(self, name: str, args: tuple):
        """Schema-check decoded WAL args against THIS provider's topology
        before they touch fancy indexing (recovery bytes are untrusted)."""
        capacity = self.neighbors.shape[0]
        if name == "write_prop_posting":
            return
        ids = np.atleast_1d(args[0])
        if ids.size and (ids.min() < 0 or ids.max() >= capacity):
            raise storecodec.StoreCodecError(f"{name}: doc id out of range")
        want = {
            "set_neighbors": (1, self.neighbors.shape[1]),
            "set_quant": (1, self.codes.shape[1]),
            "set_full": (1, self.vectors.shape[1]),
        }.get(name)
        if want is not None:
            rows = np.asarray(args[1])
            if rows.ndim != 2 or rows.shape[1] != want[1] \
                    or rows.shape[0] != ids.shape[0]:
                raise storecodec.StoreCodecError(f"{name}: row shape mismatch")

    def recover(self, snapshot: bytes, wal: bytes,
                ctx: Context = Context()) -> int:
        """Restore from (snapshot, wal) bytes: validate + load the
        snapshot, rebuild the term tree, then replay committed WAL records
        to the longest consistent prefix. A torn tail is truncated
        (``recovered_torn_tail`` flags it); interior corruption raises.
        Returns the applied LSN (committed-record count)."""
        arrays, tree_items, base_lsn = storecodec.decode_snapshot(
            snapshot, self.neighbors.shape[0], self.neighbors.shape[1],
            self.codes.shape[1], self.vectors.shape[1],
        )
        records, torn = storecodec.decode_wal(wal)  # parse BEFORE mutating
        self.neighbors[:] = arrays["neighbors"].reshape(self.neighbors.shape)
        self.codes[:] = arrays["codes"].reshape(self.codes.shape)
        self.versions[:] = arrays["versions"]
        self.live[:] = arrays["live"].astype(bool)
        self.vectors[:] = arrays["vectors"].reshape(self.vectors.shape)
        tree = BwTree(merge_fn=merge_adjacency, cache_pages=self._cache_pages)
        for key, value in tree_items:
            tree.upsert(key, value)
        self.tree = tree
        self._dirty()
        saved_wal, self._wal = self._wal, None  # don't re-log during replay
        self._txn = None
        try:
            for entries in records:
                for name, *args in entries:
                    self._check_replay_entry(name, tuple(args))
                    if name == "write_prop_posting":
                        self.write_prop_posting(args[0], args[1])
                    elif name == "set_live":
                        self.set_live(ctx, args[0], bool(args[1]))
                    elif name == "append_neighbors":
                        # python int → basic indexing (a 0-d array index
                        # would copy the row instead of viewing it)
                        self.append_neighbors(ctx, int(args[0]), args[1])
                    else:
                        getattr(self, name)(ctx, *args)
        finally:
            self._wal = [] if saved_wal is not None else None
        self.committed = base_lsn + len(records)
        self.snapshot_lsn = base_lsn
        self.recovered_torn_tail = torn
        return self.committed
