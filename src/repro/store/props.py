"""PropertyTermIndex — per-partition inverted property-term postings.

The server-side half of the declarative predicate API (§3.3 "Term Design",
§3.5 Fig 9): for every (path, value) a document carries, the partition
maintains a posting bitmap over its doc *slots* (the same slot space the
DiskANN filter masks and packed ``filter_bits`` use). Predicates compile to
a few bitmap AND/OR/NOT operations over these postings — **no document is
ever scanned on the query path**, unlike the legacy callable-filter path
which rebuilt an O(capacity) mask from the doc store per partition per
query.

Maintained incrementally:
  * ``assign(slot, items)`` on upsert (removes the slot's previous terms
    first, so a re-upsert with changed field values self-corrects);
  * ``remove(slot)`` on delete / re-home (split, merge, shard re-key);
  * every mutation bumps ``epoch`` — the invalidation signal for the
    per-(partition, predicate) compiled-bitmap cache below.

Postings write through to the Bw-Tree as PROP_TERM index terms
(``store.terms``) when a store provider is attached, mirroring how the
quantized and adjacency terms persist, and are RU-metered as property-term
writes.

Layout note: postings are packed uint32 words with bit ``slot`` at word
``slot >> 5``, bit ``slot & 31`` — identical to ``DiskANNIndex._pack_bits``
/ ``core.graph.bitmap_*``, so a compiled predicate bitmap can feed the
β-search ``filter_bits`` directly.
"""
from __future__ import annotations

from typing import Any, Iterator, Optional

import numpy as np

from .terms import TermCodec, value_token

# compiled-bitmap cache bound (canonical predicates per partition),
# enforced on every insert: oldest entry evicted when full, and ingest
# mutations drop the whole (now stale-epoch) cache — the cache is an
# epoch-checked memo, never a correctness requirement
COMPILE_CACHE_CAP = 256


class PropertyTermIndex:
    """Inverted (path, value) → posting-bitmap index over one partition's
    doc slots, plus the predicate→bitmap compiler and its epoch-invalidated
    cache."""

    def __init__(self, capacity: int, store=None, shard=None):
        self.capacity = int(capacity)
        self.nwords = (self.capacity + 31) // 32
        self._store = store  # StoreProviderSet (write-through) or None
        self._shard = shard
        self._postings: dict[bytes, np.ndarray] = {}  # term key → words
        # per path: value token → (value, term key); feeds range compilation
        self._by_path: dict[str, dict[bytes, tuple[Any, bytes]]] = {}
        self._slot_terms: dict[int, tuple[bytes, ...]] = {}
        self._universe = np.zeros((self.nwords,), np.uint32)  # present docs
        self.epoch = 0
        self._cache: dict[bytes, tuple[int, np.ndarray]] = {}
        self.last_compile_reads = 0  # posting lookups by the last compile
        self._reads = 0

    # ------------------------------------------------------------------
    # maintenance (ingest path)
    # ------------------------------------------------------------------
    def _set_bit(self, words: np.ndarray, slot: int, on: bool):
        if on:
            words[slot >> 5] |= np.uint32(1) << np.uint32(slot & 31)
        else:
            words[slot >> 5] &= ~(np.uint32(1) << np.uint32(slot & 31))

    def assign(self, slot: int, items: tuple) -> None:
        """Point the slot's property terms at ``items`` ((path, value)
        pairs): removes whatever the slot carried before, so re-upserts
        with changed fields and slot reuse both self-correct."""
        slot = int(slot)
        self.remove(slot)
        keys = []
        for path, value in items:
            key = TermCodec.prop_key(path, value, self._shard)
            words = self._postings.get(key)
            if words is None:
                words = np.zeros((self.nwords,), np.uint32)
                self._postings[key] = words
                self._by_path.setdefault(str(path), {})[value_token(value)] = (
                    value, key,
                )
            self._set_bit(words, slot, True)
            keys.append(key)
            self._write_through(key, words)
        self._slot_terms[slot] = tuple(keys)
        self._set_bit(self._universe, slot, True)
        self._touch()

    def remove(self, slot: int) -> None:
        """Clear the slot from every posting it appears in (delete /
        re-home / pre-upsert cleanup)."""
        slot = int(slot)
        for key in self._slot_terms.pop(slot, ()):
            words = self._postings.get(key)
            if words is not None:
                self._set_bit(words, slot, False)
                self._write_through(key, words)
        if (self._universe[slot >> 5] >> np.uint32(slot & 31)) & np.uint32(1):
            self._set_bit(self._universe, slot, False)
            self._touch()

    def _write_through(self, key: bytes, words: np.ndarray) -> None:
        if self._store is not None:
            self._store.write_prop_posting(key, words)

    def _touch(self):
        self.epoch += 1
        self._cache.clear()  # every cached bitmap is now stale-epoch

    # ------------------------------------------------------------------
    # compiler interface (consumed by Predicate.compile_words)
    # ------------------------------------------------------------------
    def zeros(self) -> np.ndarray:
        return np.zeros((self.nwords,), np.uint32)

    def universe(self) -> np.ndarray:
        """Bitmap of slots that currently hold a document (the complement
        base for NOT: absent-field docs pass ``~F.eq(path, v)``)."""
        return self._universe.copy()

    def posting(self, path: str, value) -> Optional[np.ndarray]:
        self._reads += 1
        entry = self._by_path.get(str(path), {}).get(value_token(value))
        return None if entry is None else self._postings[entry[1]]

    def values_for(self, path: str) -> Iterator[tuple[Any, np.ndarray]]:
        """(value, posting words) for every distinct value seen at
        ``path`` — range predicates OR the in-bound subset together."""
        for tok, (value, key) in self._by_path.get(str(path), {}).items():
            self._reads += 1
            yield value, self._postings[key]

    # ------------------------------------------------------------------
    # compilation + per-(partition, predicate) cache
    # ------------------------------------------------------------------
    def compile(self, pred) -> np.ndarray:
        """Compile a canonical predicate to packed uint32 words over this
        partition's slots. Cached per canonical key; any ingest mutation
        (epoch bump) invalidates. ``last_compile_reads`` reports how many
        posting lookups the call performed (0 == cache hit) for RU
        metering."""
        key = pred.key()
        hit = self._cache.get(key)
        if hit is not None and hit[0] == self.epoch:
            self.last_compile_reads = 0
            return hit[1]
        self._reads = 0
        words = np.asarray(pred.compile_words(self), np.uint32)
        self.last_compile_reads = self._reads
        # bound the cache on the INSERT path too: a query-only workload
        # (no ingest, many distinct predicates) must not grow it forever
        while len(self._cache) >= COMPILE_CACHE_CAP:
            self._cache.pop(next(iter(self._cache)))
        self._cache[key] = (self.epoch, words)
        return words

    def mask(self, words: np.ndarray) -> np.ndarray:
        """Unpack compiled words to the bool slot mask the filtered search
        planner consumes (vectorized — not a document scan)."""
        return words_to_mask(words, self.capacity)

    @property
    def num_terms(self) -> int:
        return len(self._postings)


def words_to_mask(words: np.ndarray, capacity: int) -> np.ndarray:
    """Packed uint32 words (bit i of word w == slot 32w+i) → bool mask."""
    bits = np.unpackbits(
        np.ascontiguousarray(words, dtype="<u4").view(np.uint8),
        bitorder="little",
    )
    return bits[:capacity].astype(bool)


def mask_to_words(mask: np.ndarray) -> np.ndarray:
    """Inverse of ``words_to_mask`` (shared layout with
    ``DiskANNIndex._pack_bits``)."""
    words = np.zeros(((len(mask) + 31) // 32,), np.uint32)
    idx = np.nonzero(mask)[0]
    np.bitwise_or.at(
        words, idx >> 5, np.uint32(1) << (idx & 31).astype(np.uint32)
    )
    return words
