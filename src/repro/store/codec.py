"""Snapshot/WAL wire codec — versioned, schema-checked, CRC'd, pickle-free.

Recovery bytes are untrusted input exactly like continuation tokens were
before PR 4: a snapshot or WAL handed to ``StoreProviderSet.recover`` may
come off a disk that lost power mid-write, a replication stream that got
truncated, or an attacker. The previous ``pickle.loads`` codec was
arbitrary code execution on whatever those bytes contained; this module
replaces it with fixed binary layouts over raw numpy buffers, in the
style of ``serve/continuation.py``:

    snapshot := MAGIC("CSNP") | VERSION(u16) | base_lsn(u64)
              | capacity(u32) R_slack(u32) M(u32) dim(u32)
              | neighbors(<i4) codes(u1) versions(u1) live(u1) vectors(<f4)
              | ntree(u32) | (klen(u32) key vlen(u32) value)*
              | CRC32(u32)                     # over everything prior

    wal      := MAGIC("CWAL") | VERSION(u16) | record*
    record   := plen(u32) | payload(plen) | CRC32(payload)(u32)
    payload  := nentries(u16) | entry*
    entry    := opcode(u8) | args per the op schema below

Each WAL *record* is one committed transaction (one logical store op), so
a torn tail — the crash interrupting the disk write of the final record —
never splits an operation: either all of its entries replay or none do.
Torn tails (a final frame that runs past the end of the buffer, or whose
CRC fails) are **truncated**; a CRC failure on an *interior* record is bit
rot, not a crash, and raises ``WalCorruption`` instead of silently losing
committed data.
"""
from __future__ import annotations

import struct
import zlib

import numpy as np

MAGIC_SNAPSHOT = b"CSNP"  # Cosmos SNaPshot
MAGIC_WAL = b"CWAL"  # Cosmos Write-Ahead Log
VERSION = 1

_MAX_TREE_ITEMS = 1 << 22
_MAX_KEY = 4096
_MAX_VALUE = 1 << 26
_MAX_RECORD = 1 << 26
_MAX_ENTRIES = 4096
_MAX_ELEMS = 1 << 24

# allow-listed dtypes, explicit little-endian so recovery is portable
_DTYPES = {
    0: np.dtype("<i4"),
    1: np.dtype("<i8"),
    2: np.dtype("<f4"),
    3: np.dtype("u1"),
    4: np.dtype("<u4"),
}
_DTYPE_CODES = {v: k for k, v in _DTYPES.items()}
_TAG_BYTES = 0xFF  # entry-arg tag for a raw bytes field (term keys)

# op schemas: opcode -> (name, number of args). Arg shapes/dtypes are
# checked per-op in decode (and again against collection config by the
# caller before replay).
WAL_OPS = {
    1: ("set_neighbors", 2),  # ids <i8[n], rows <i4[n,R]
    2: ("append_neighbors", 2),  # node <i8[], new_ids <i8[n]
    3: ("set_quant", 3),  # ids <i8[n], codes u1[n,M], versions u1[n]
    4: ("set_full", 2),  # ids <i8[n], vecs <f4[n,dim]
    5: ("set_live", 2),  # ids <i8[n], value u1[]
    6: ("write_prop_posting", 2),  # key bytes, words <u4[n]
}
_OPCODES = {name: (code, nargs) for code, (name, nargs) in WAL_OPS.items()}


class StoreCodecError(ValueError):
    """The snapshot/WAL bytes are malformed, tampered with, or from an
    incompatible version/topology — reject recovery."""


class WalCorruption(StoreCodecError):
    """An *interior* WAL record failed its CRC or schema: committed data
    is damaged (bit rot), which truncation would silently lose."""


# ---------------------------------------------------------------------------
# wire helpers
# ---------------------------------------------------------------------------


def _canonical(a: np.ndarray, dtype) -> np.ndarray:
    a = np.asarray(a)
    if not a.flags["C_CONTIGUOUS"]:
        a = np.copy(a, order="C")
    return a.astype(np.dtype(dtype), copy=False)


def _pack_array(arr: np.ndarray) -> bytes:
    code = _DTYPE_CODES.get(arr.dtype)
    if code is None:
        raise StoreCodecError(f"dtype {arr.dtype} not in WAL schema")
    return b"".join(
        (
            struct.pack("<BB", code, arr.ndim),
            struct.pack(f"<{arr.ndim}I", *arr.shape),
            arr.tobytes(),
        )
    )


def _unpack_array(body: bytes, off: int) -> tuple[np.ndarray, int]:
    if off + 2 > len(body):
        raise StoreCodecError("truncated array header")
    code, ndim = struct.unpack_from("<BB", body, off)
    off += 2
    if code not in _DTYPES or ndim > 2:
        raise StoreCodecError("bad array dtype/ndim")
    if off + 4 * ndim > len(body):
        raise StoreCodecError("truncated array shape")
    shape = struct.unpack_from(f"<{ndim}I", body, off)
    off += 4 * ndim
    dtype = _DTYPES[code]
    n_elem = 1
    for dim in shape:  # python-int product: huge shapes must hit THIS bound
        n_elem *= int(dim)
    if n_elem > _MAX_ELEMS:
        raise StoreCodecError("array too large")
    nbytes = n_elem * dtype.itemsize
    if off + nbytes > len(body):
        raise StoreCodecError("truncated array data")
    arr = np.frombuffer(body, dtype=dtype, count=n_elem, offset=off)
    return arr.reshape(shape).copy(), off + nbytes


# ---------------------------------------------------------------------------
# snapshot
# ---------------------------------------------------------------------------


def encode_snapshot(
    neighbors: np.ndarray,
    codes: np.ndarray,
    versions: np.ndarray,
    live: np.ndarray,
    vectors: np.ndarray,
    tree_items: list[tuple[bytes, bytes]],
    base_lsn: int,
) -> bytes:
    capacity, r_slack = neighbors.shape
    out = [
        MAGIC_SNAPSHOT,
        struct.pack("<HQ", VERSION, base_lsn),
        struct.pack(
            "<IIII", capacity, r_slack, codes.shape[1], vectors.shape[1]
        ),
        _canonical(neighbors, "<i4").tobytes(),
        _canonical(codes, "u1").tobytes(),
        _canonical(versions, "u1").tobytes(),
        _canonical(live, "u1").tobytes(),
        _canonical(vectors, "<f4").tobytes(),
        struct.pack("<I", len(tree_items)),
    ]
    for key, value in tree_items:
        out.append(struct.pack("<I", len(key)))
        out.append(key)
        out.append(struct.pack("<I", len(value)))
        out.append(value)
    payload = b"".join(out)
    return payload + struct.pack("<I", zlib.crc32(payload) & 0xFFFFFFFF)


def decode_snapshot(
    data: bytes, capacity: int, r_slack: int, m: int, dim: int
) -> tuple[dict[str, np.ndarray], list[tuple[bytes, bytes]], int]:
    """Validate + parse a snapshot whose shape header must match the
    recovering provider's configured (capacity, R_slack, M, dim)."""
    if not isinstance(data, (bytes, bytearray)):
        raise StoreCodecError("snapshot must be bytes")
    data = bytes(data)
    if len(data) < 34 or data[:4] != MAGIC_SNAPSHOT:
        raise StoreCodecError("not a store snapshot (bad magic)")
    body, (crc,) = data[:-4], struct.unpack("<I", data[-4:])
    if zlib.crc32(body) & 0xFFFFFFFF != crc:
        raise StoreCodecError("snapshot checksum mismatch (tampered/torn)")
    version, base_lsn = struct.unpack_from("<HQ", body, 4)
    if version < 1 or version > VERSION:
        raise StoreCodecError(
            f"unsupported snapshot version {version} (this build speaks "
            f"≤ {VERSION})"
        )
    shape = struct.unpack_from("<IIII", body, 14)
    if shape != (capacity, r_slack, m, dim):
        raise StoreCodecError(
            f"snapshot topology {shape} does not match provider "
            f"{(capacity, r_slack, m, dim)}"
        )
    off = 30
    arrays: dict[str, np.ndarray] = {}
    for name, dtype, count in (
        ("neighbors", "<i4", capacity * r_slack),
        ("codes", "u1", capacity * m),
        ("versions", "u1", capacity),
        ("live", "u1", capacity),
        ("vectors", "<f4", capacity * dim),
    ):
        dt = np.dtype(dtype)
        nbytes = count * dt.itemsize
        if off + nbytes > len(body):
            raise StoreCodecError(f"snapshot truncated in {name}")
        arrays[name] = np.frombuffer(body, dt, count=count, offset=off).copy()
        off += nbytes
    if off + 4 > len(body):
        raise StoreCodecError("snapshot truncated before term section")
    (ntree,) = struct.unpack_from("<I", body, off)
    off += 4
    if ntree > _MAX_TREE_ITEMS:
        raise StoreCodecError(f"implausible term count {ntree}")
    items: list[tuple[bytes, bytes]] = []
    for _ in range(ntree):
        if off + 4 > len(body):
            raise StoreCodecError("snapshot truncated in term key length")
        (klen,) = struct.unpack_from("<I", body, off)
        off += 4
        if klen == 0 or klen > _MAX_KEY or off + klen + 4 > len(body):
            raise StoreCodecError("bad term key")
        key = body[off : off + klen]
        off += klen
        (vlen,) = struct.unpack_from("<I", body, off)
        off += 4
        if vlen > _MAX_VALUE or off + vlen > len(body):
            raise StoreCodecError("bad term value")
        items.append((key, body[off : off + vlen]))
        off += vlen
    if off != len(body):
        raise StoreCodecError("trailing bytes after last term")
    return arrays, items, base_lsn


# ---------------------------------------------------------------------------
# WAL
# ---------------------------------------------------------------------------


def _encode_entry(entry: tuple) -> bytes:
    name, *args = entry
    if name not in _OPCODES:
        raise StoreCodecError(f"op {name!r} not in WAL schema")
    code, nargs = _OPCODES[name]
    if len(args) != nargs:
        raise StoreCodecError(f"op {name!r}: expected {nargs} args")
    out = [struct.pack("<B", code)]
    for i, a in enumerate(args):
        if isinstance(a, (bytes, bytearray)):
            out.append(struct.pack("<BI", _TAG_BYTES, len(a)))
            out.append(bytes(a))
        else:
            out.append(_pack_array(_canonical_arg(name, i, a)))
    return b"".join(out)


def _canonical_arg(name: str, i: int, a) -> np.ndarray:
    """Pin each op's array args to the wire dtype (see WAL_OPS table)."""
    a = np.asarray(a)
    if name == "set_neighbors" and i == 1:
        return _canonical(a, "<i4")
    if name == "set_quant" and i in (1, 2):
        return _canonical(a, "u1")
    if name == "set_full" and i == 1:
        return _canonical(a, "<f4")
    if name == "set_live" and i == 1:
        return _canonical(a, "u1")
    if name == "write_prop_posting":
        return _canonical(a, "<u4")
    return _canonical(a, "<i8")  # ids / node scalars


def encode_wal(records: list[list[tuple]]) -> bytes:
    out = [MAGIC_WAL, struct.pack("<H", VERSION)]
    for entries in records:
        if len(entries) > _MAX_ENTRIES:
            raise StoreCodecError(f"record too large ({len(entries)} entries)")
        payload = struct.pack("<H", len(entries)) + b"".join(
            _encode_entry(e) for e in entries
        )
        if len(payload) > _MAX_RECORD:
            raise StoreCodecError("record payload too large")
        out.append(struct.pack("<I", len(payload)))
        out.append(payload)
        out.append(struct.pack("<I", zlib.crc32(payload) & 0xFFFFFFFF))
    return b"".join(out)


def _decode_payload(payload: bytes) -> list[tuple]:
    if len(payload) < 2:
        raise StoreCodecError("record payload too short")
    (nentries,) = struct.unpack_from("<H", payload, 0)
    if nentries > _MAX_ENTRIES:
        raise StoreCodecError(f"record claims {nentries} entries")
    off = 2
    entries: list[tuple] = []
    for _ in range(nentries):
        if off + 1 > len(payload):
            raise StoreCodecError("truncated entry opcode")
        code = payload[off]
        off += 1
        if code not in WAL_OPS:
            raise StoreCodecError(f"unknown WAL opcode {code}")
        name, nargs = WAL_OPS[code]
        args: list = []
        for _ in range(nargs):
            if off < len(payload) and payload[off] == _TAG_BYTES:
                if off + 5 > len(payload):
                    raise StoreCodecError("truncated bytes arg")
                (blen,) = struct.unpack_from("<I", payload, off + 1)
                off += 5
                if blen > _MAX_KEY or off + blen > len(payload):
                    raise StoreCodecError("bad bytes arg")
                args.append(payload[off : off + blen])
                off += blen
            else:
                arr, off = _unpack_array(payload, off)
                args.append(arr)
        entries.append((name, *args))
    if off != len(payload):
        raise StoreCodecError("trailing bytes after last entry")
    return entries


def wal_frames(data: bytes) -> list[tuple[int, int]]:
    """(offset, frame_length) of each complete record frame — the byte
    boundaries fault injection needs to tear or flip precisely."""
    frames = []
    off = 6
    while off + 4 <= len(data):
        (plen,) = struct.unpack_from("<I", data, off)
        if plen > _MAX_RECORD or off + 8 + plen > len(data):
            break
        frames.append((off, 8 + plen))
        off += 8 + plen
    return frames


def decode_wal(data: bytes) -> tuple[list[list[tuple]], bool]:
    """Parse WAL bytes into committed records. Returns ``(records,
    torn_tail)``: a final frame that is incomplete or CRC-fails is
    truncated (``torn_tail=True``); an interior one raises
    ``WalCorruption``."""
    if not isinstance(data, (bytes, bytearray)):
        raise StoreCodecError("wal must be bytes")
    data = bytes(data)
    if len(data) < 6 or data[:4] != MAGIC_WAL:
        raise StoreCodecError("not a store WAL (bad magic)")
    (version,) = struct.unpack_from("<H", data, 4)
    if version < 1 or version > VERSION:
        raise StoreCodecError(
            f"unsupported WAL version {version} (this build speaks ≤ {VERSION})"
        )
    records: list[list[tuple]] = []
    off = 6
    torn = False
    while off < len(data):
        if off + 4 > len(data):
            torn = True  # crash mid-length-word
            break
        (plen,) = struct.unpack_from("<I", data, off)
        if plen > _MAX_RECORD or off + 8 + plen > len(data):
            torn = True  # frame runs past the end: crash mid-record
            break
        payload = data[off + 4 : off + 4 + plen]
        (crc,) = struct.unpack_from("<I", data, off + 4 + plen)
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            if off + 8 + plen == len(data):
                torn = True  # final record damaged: torn tail, truncate
                break
            raise WalCorruption(
                f"WAL record at byte {off} failed CRC with committed "
                "records after it (bit rot, not a crash)"
            )
        # CRC-valid but malformed is an encoder bug or forgery, never a
        # torn write — always reject, even at the tail
        records.append(_decode_payload(payload))
        off += 8 + plen
    return records, torn
