"""A Bw-Tree analogue: ordered pages, delta chains, consolidation, cache.

Cosmos DB's Bw-Tree (§2.2) is latch-free and log-structured; what the
paper's vector design *uses* from it is narrower and is what we model:

  * key-ordered logical pages found via a binary-searchable page table;
  * **blind incremental updates**: an append to a key (e.g. new out-edges
    for a graph vertex) is recorded as a delta record without reading the
    base value — O(1) writes, no write amplification;
  * **delta chains** capped at a max length (15 in the paper's experiments);
    reads must traverse the chain, so lookup cost grows with chain length —
    exactly the effect behind Fig 12's declining ingest rate — and
    consolidation merges deltas into the base value via a type-specific
    merge callback (§3.3: "a new corresponding merge callback procedure");
  * a page cache: hot pages pinned in memory with hit/miss accounting,
    feeding the RU/latency model (cold reads = SSD in the paper).

Single-writer semantics (one writer per replica's index-maintenance loop)
make latch-freedom moot here; contracts that matter — *no duplicate insert
patches for a key, no delete patches for a non-existent key* (§2.1) — are
enforced and raise, which is what forces the mini-batch update design.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Callable, Iterator, Optional

PAGE_CAPACITY = 64  # keys per logical page (8KB pages / ~128B terms)
MAX_CHAIN = 15  # paper §4: "Bw-tree max chain length is set to 15"


@dataclasses.dataclass
class BwTreeStats:
    page_reads: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    delta_traversals: int = 0  # chain records walked on reads
    consolidations: int = 0
    writes: int = 0
    splits: int = 0

    def reset(self):
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)


class _Page:
    __slots__ = ("keys", "base", "deltas")

    def __init__(self):
        self.keys: list[bytes] = []  # sorted keys present in base
        self.base: dict[bytes, bytes] = {}
        # delta chain, newest last: (op, key, payload)
        self.deltas: list[tuple[str, bytes, bytes]] = []


class BwTree:
    """Ordered KV store with delta chains and a bounded page cache."""

    def __init__(
        self,
        merge_fn: Optional[Callable[[bytes, list[bytes]], bytes]] = None,
        cache_pages: int = 1 << 30,
        page_capacity: int = PAGE_CAPACITY,
        max_chain: int = MAX_CHAIN,
    ):
        # merge callback for blind appends (§3.3) — default: concatenation
        self.merge_fn = merge_fn or (lambda base, deltas: (base or b"") + b"".join(deltas))
        self.page_capacity = page_capacity
        self.max_chain = max_chain
        self.stats = BwTreeStats()
        self._fences: list[bytes] = [b""]  # lower fence key per page
        self._pages: list[_Page] = [_Page()]
        self._cache_pages = cache_pages
        self._hot: dict[int, int] = {}  # page idx -> last access tick
        self._tick = 0

    # ------------------------------------------------------------------
    def _locate(self, key: bytes) -> int:
        return bisect.bisect_right(self._fences, key) - 1

    def _touch(self, pidx: int):
        self._tick += 1
        self.stats.page_reads += 1
        if pidx in self._hot:
            self.stats.cache_hits += 1
        else:
            self.stats.cache_misses += 1
            if len(self._hot) >= self._cache_pages:
                coldest = min(self._hot, key=self._hot.get)
                del self._hot[coldest]
        self._hot[pidx] = self._tick

    def _maybe_consolidate(self, pidx: int, force: bool = False):
        page = self._pages[pidx]
        if not force and len(page.deltas) <= self.max_chain:
            return
        self.stats.consolidations += 1
        appends: dict[bytes, list[bytes]] = {}
        for op, key, payload in page.deltas:
            if op == "set":
                page.base[key] = payload
                appends.pop(key, None)
                if key not in page.keys:
                    bisect.insort(page.keys, key)
            elif op == "append":
                appends.setdefault(key, []).append(payload)
            elif op == "del":
                page.base.pop(key, None)
                appends.pop(key, None)
                i = bisect.bisect_left(page.keys, key)
                if i < len(page.keys) and page.keys[i] == key:
                    page.keys.pop(i)
        for key, payloads in appends.items():
            page.base[key] = self.merge_fn(page.base.get(key), payloads)
            if key not in page.base or key not in page.keys:
                if key not in page.keys:
                    bisect.insort(page.keys, key)
        page.deltas = []
        self._maybe_split(pidx)

    def _maybe_split(self, pidx: int):
        page = self._pages[pidx]
        if len(page.keys) <= self.page_capacity:
            return
        self.stats.splits += 1
        mid = len(page.keys) // 2
        fence = page.keys[mid]
        right = _Page()
        right.keys = page.keys[mid:]
        page.keys = page.keys[:mid]
        for k in right.keys:
            right.base[k] = page.base.pop(k)
        self._pages.insert(pidx + 1, right)
        self._fences.insert(pidx + 1, fence)
        # cache entries after pidx shift by one
        self._hot = {(i + 1 if i > pidx else i): t for i, t in self._hot.items()}

    # ------------------------------------------------------------------
    # write path
    # ------------------------------------------------------------------
    def put(self, key: bytes, value: bytes):
        pidx = self._locate(key)
        page = self._pages[pidx]
        # contract (§2.1): no duplicate *insert* patches for a key within
        # the un-consolidated chain
        for op, k, _ in page.deltas:
            if op == "set" and k == key:
                raise ValueError(
                    f"duplicate insert patch for key {key!r} before consolidation "
                    "(mini-batch updates must coalesce writes per key)"
                )
        page.deltas.append(("set", key, value))
        self.stats.writes += 1
        self._maybe_consolidate(pidx)

    def append(self, key: bytes, payload: bytes):
        """Blind incremental update — no base read (the fast adjacency path)."""
        pidx = self._locate(key)
        self._pages[pidx].deltas.append(("append", key, payload))
        self.stats.writes += 1
        self._maybe_consolidate(pidx)

    def delete(self, key: bytes):
        pidx = self._locate(key)
        if self.get(key) is None:
            raise KeyError(f"delete patch for non-existent key {key!r} (§2.1 contract)")
        self._pages[pidx].deltas.append(("del", key, b""))
        self.stats.writes += 1
        self._maybe_consolidate(pidx)

    def upsert(self, key: bytes, value: bytes):
        """set-or-replace that satisfies the no-duplicate-patch contract by
        consolidating first when needed."""
        pidx = self._locate(key)
        page = self._pages[pidx]
        if any(op == "set" and k == key for op, k, _ in page.deltas):
            self._maybe_consolidate(pidx, force=True)
        self.put(key, value)

    # ------------------------------------------------------------------
    # read path
    # ------------------------------------------------------------------
    def get(self, key: bytes) -> Optional[bytes]:
        pidx = self._locate(key)
        self._touch(pidx)
        page = self._pages[pidx]
        value = page.base.get(key)
        pending: list[bytes] = []
        deleted = False
        for op, k, payload in page.deltas:  # chain walk, oldest→newest
            self.stats.delta_traversals += 1
            if k != key:
                continue
            if op == "set":
                value, pending, deleted = payload, [], False
            elif op == "append":
                pending.append(payload)
                deleted = False
            elif op == "del":
                value, pending, deleted = None, [], True
        if deleted:
            return None
        if pending:
            return self.merge_fn(value, pending)
        return value

    def prefix_seek(self, prefix: bytes) -> Iterator[tuple[bytes, bytes]]:
        """Range scan over all keys with the given prefix (§3.3 Prefix Seek)."""
        pidx = self._locate(prefix)
        while pidx < len(self._pages):
            self._maybe_consolidate(pidx, force=True)
            self._touch(pidx)
            page = self._pages[pidx]
            i = bisect.bisect_left(page.keys, prefix)
            advanced = False
            for k in page.keys[i:]:
                if not k.startswith(prefix):
                    return
                advanced = True
                yield k, page.base[k]
            pidx += 1
            if pidx < len(self._pages) and not self._fences[pidx].startswith(prefix):
                # next page's fence already beyond the prefix range
                if not advanced and self._fences[pidx] > prefix + b"\xff" * 4:
                    return

    def dump_items(self) -> list[tuple[bytes, bytes]]:
        """Every (key, value) pair in key order, after consolidating all
        delta chains — the logical content a snapshot must capture. Two
        trees with equal dumps answer every read identically."""
        pidx = 0
        while pidx < len(self._pages):  # consolidation may split pages
            self._maybe_consolidate(pidx, force=True)
            pidx += 1
        return [
            (k, page.base[k]) for page in self._pages for k in page.keys
        ]

    def chain_length(self, key: bytes) -> int:
        return len(self._pages[self._locate(key)].deltas)

    @property
    def num_pages(self) -> int:
        return len(self._pages)
