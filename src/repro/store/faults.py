"""Deterministic fault injection + recovery invariants (chaos plumbing).

The paper's availability story (§2.2: the vector index inherits the
database's HA/durability) is only credible if kill-and-recover is
exercised, not assumed. This module provides the three pieces the tests
and ``benchmarks/bench_chaos.py`` drive:

  * ``FaultPlan`` — a seeded crash schedule. Write paths call
    ``providers.barrier("upsert:post_index")`` etc. at named points;
    an armed (or probabilistically tripped) barrier raises
    ``CrashError``, modelling a process kill at exactly that point.
    Determinism comes from the seeded RNG (and the SimClock timestamps
    recorded for each trip), so every chaos run is replayable.
  * WAL damage helpers — ``torn_tail`` (the crash interrupted the disk
    write of the final record) and ``corrupt_record`` (interior bit
    rot), built on the codec's frame boundaries so they tear real
    record edges rather than random garbage.
  * ``recovery_invariants`` — the parity contract after every
    kill-and-recover: doc store (full vectors + tombstones), graph
    adjacency, quantized codes, and every durable index term (adjacency
    / quantized / property postings) must match the uncrashed twin
    bit-for-bit.

A crash at any barrier must leave durable state equal to the committed
transaction prefix: the in-memory arrays die with the process, and the
WAL's record-per-transaction framing (see ``store/codec.py``) guarantees
the interrupted operation is invisible after replay.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from . import codec


class CrashError(RuntimeError):
    """Injected process kill: in-memory state is gone; what survives is
    the last snapshot plus the committed WAL records."""


class FaultPlan:
    """Seeded, deterministic crash schedule over named barriers.

    Two triggering modes compose: ``arm(name, count)`` trips the next
    ``count`` hits of an exact barrier, and ``p_crash`` trips any barrier
    with the given probability from the plan's own seeded RNG.
    """

    def __init__(self, seed: int = 0, p_crash: float = 0.0, clock=None):
        self.rng = np.random.RandomState(seed)
        self.p_crash = float(p_crash)
        self.clock = clock  # optional SimClock for trip timestamps
        self.enabled = True
        self._armed: dict[str, int] = {}
        self.seen: list[str] = []  # every barrier crossed (armed or not)
        self.tripped: list[tuple[str, Optional[float]]] = []

    def arm(self, barrier: str, count: int = 1) -> "FaultPlan":
        self._armed[barrier] = self._armed.get(barrier, 0) + count
        return self

    def attach(self, providers) -> "FaultPlan":
        providers.faults = self
        return self

    def barrier(self, name: str):
        if not self.enabled:
            return
        self.seen.append(name)
        trip = False
        if self._armed.get(name, 0) > 0:
            self._armed[name] -= 1
            trip = True
        elif self.p_crash > 0.0 and self.rng.random_sample() < self.p_crash:
            trip = True
        if trip:
            now = self.clock.now() if self.clock is not None else None
            self.tripped.append((name, now))
            raise CrashError(f"injected crash at barrier {name!r}")


# ---------------------------------------------------------------------------
# WAL damage (what a real crash / bad disk does to the log bytes)
# ---------------------------------------------------------------------------


def torn_tail(wal: bytes, rng: np.random.RandomState,
              nbytes: Optional[int] = None) -> bytes:
    """Chop bytes off the end of the WAL, at most into the final record —
    the on-disk picture of a crash mid-write. Recovery must truncate the
    damaged frame and replay the intact prefix."""
    frames = codec.wal_frames(wal)
    if not frames:
        return wal
    last_off, last_len = frames[-1]
    if nbytes is None:
        nbytes = int(rng.randint(1, last_len + 1))
    nbytes = min(nbytes, last_len)
    return wal[: len(wal) - nbytes]


def corrupt_record(wal: bytes, rng: np.random.RandomState,
                   index: Optional[int] = None) -> bytes:
    """Flip one payload byte of record ``index`` (random interior record
    by default). Interior damage is bit rot: recovery must *reject* it,
    not silently truncate committed data."""
    frames = codec.wal_frames(wal)
    if not frames:
        return wal
    if index is None:
        index = int(rng.randint(0, max(len(frames) - 1, 1)))
    off, flen = frames[index]
    # payload spans [off+4, off+4+plen); flip one byte inside it
    lo, hi = off + 4, off + flen - 4
    pos = int(rng.randint(lo, hi)) if hi > lo else lo
    damaged = bytearray(wal)
    damaged[pos] ^= 0xFF
    return bytes(damaged)


# ---------------------------------------------------------------------------
# recovery invariants
# ---------------------------------------------------------------------------

_ARRAY_CHECKS = (
    ("doc_store", "vectors"),
    ("tombstones", "live"),
    ("graph", "neighbors"),
    ("quantized", "codes"),
    ("quant_versions", "versions"),
)


def recovery_invariants(recovered, twin) -> dict[str, bool]:
    """Assert bit-for-bit parity between a recovered provider set and its
    uncrashed twin: dense caches AND the durable term store (which covers
    adjacency, quantized, and property-posting terms). Raises
    ``AssertionError`` naming every violated invariant."""
    checks: dict[str, bool] = {}
    for label, attr in _ARRAY_CHECKS:
        a, b = getattr(recovered, attr), getattr(twin, attr)
        checks[label] = (
            a.shape == b.shape and a.dtype == b.dtype and np.array_equal(a, b)
        )
    checks["terms"] = recovered.tree.dump_items() == twin.tree.dump_items()
    # the paged full-precision tier, page by page (ISSUE 10): a WAL that
    # loses a ``set_full`` replay would serve stale vectors at rerank.
    # Page CONTENT must match regardless of either side's cache residency
    # (budgets may differ between a recovered replica and its twin), so
    # compare through the residency-independent page→slot mapping.
    pages = getattr(recovered, "pages", None)
    if pages is not None and hasattr(twin, "vectors"):
        bad_pages = [
            pg for pg in range(pages.n_pages)
            if not np.array_equal(recovered.vectors[pages.page_slots(pg)],
                                  twin.vectors[pages.page_slots(pg)])
        ]
        checks["paged_tier"] = not bad_pages
        if bad_pages:
            checks["paged_tier_bad_pages"] = False  # surfaced in the assert
    bad = [name for name, ok in checks.items() if not ok]
    assert not bad, f"recovery parity violated: {bad}"
    return checks
