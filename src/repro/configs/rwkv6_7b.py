"""RWKV6-7B "Finch" [arXiv:2404.05892; hf]: attention-free, data-dependent
decay. Time-mix dim = d_model (expand=1), 64 heads × 64; channel-mix FFN
d_ff=14336 every layer (relu² in the paper; gelu MLP here — DESIGN.md)."""
from ..models.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b",
        family="ssm",
        num_layers=32,
        d_model=4096,
        num_heads=64,
        num_kv_heads=64,
        head_dim=64,
        d_ff=14336,
        vocab_size=65536,
        rope="none",
        mlp="gelu",
        ssm=SSMConfig(kind="rwkv6", head_dim=64, expand=1, chunk=64),
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        rope="none",
        mlp="gelu",
        ssm=SSMConfig(kind="rwkv6", head_dim=16, expand=1, chunk=8),
        param_dtype="float32",
        compute_dtype="float32",
    )
