"""ChatGLM3-6B [arXiv:2406.12793; hf]: dense, GQA(kv=2), 2d/partial RoPE."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b",
        family="dense",
        num_layers=28,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        d_ff=13696,
        vocab_size=65024,
        rope="partial",  # GLM's 2d rope: rotate half the head dims
        mlp="swiglu",
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b-smoke",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=8,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=256,
        rope="partial",
        mlp="swiglu",
        param_dtype="float32",
        compute_dtype="float32",
    )
