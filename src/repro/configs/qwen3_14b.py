"""Qwen3-14B [hf:Qwen/Qwen3-8B family; hf]: dense, GQA(kv=8), qk_norm."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b",
        family="dense",
        num_layers=40,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=17408,
        vocab_size=151936,
        rope="full",
        rope_theta=1000000.0,
        qk_norm=True,
        mlp="swiglu",
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b-smoke",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=8,
        num_kv_heads=4,
        head_dim=16,
        d_ff=256,
        vocab_size=512,
        rope="full",
        qk_norm=True,
        mlp="swiglu",
        param_dtype="float32",
        compute_dtype="float32",
    )
