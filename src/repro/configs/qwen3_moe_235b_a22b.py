"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-30B-A3B family; hf]:
94L MoE, 128 experts top-8, GQA(kv=4), qk_norm."""
from ..models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        num_layers=94,
        d_model=4096,
        num_heads=64,
        num_kv_heads=4,
        head_dim=128,
        d_ff=1536,  # expert FFN width
        vocab_size=151936,
        rope="full",
        rope_theta=1000000.0,
        qk_norm=True,
        mlp="swiglu",
        moe=MoEConfig(
            num_experts=128,
            top_k=8,
            d_ff_expert=1536,
            capacity_factor=1.25,
            group_size=512,
        ),
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke",
        family="moe",
        num_layers=2,
        d_model=128,
        num_heads=8,
        num_kv_heads=2,
        head_dim=16,
        d_ff=64,
        vocab_size=256,
        qk_norm=True,
        mlp="swiglu",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64, group_size=64,
                      capacity_factor=2.0),
        param_dtype="float32",
        compute_dtype="float32",
    )
