"""The paper's own workload: a partitioned DiskANN collection at Cosmos
scale, as a distributed-search dry-run config.

10M Wiki-Cohere-like vectors (768D float32 documents, 96-byte PQ codes,
R=32 graph) sharded one-DiskANN-index-per-device across the production
mesh; the query step is `repro.partition.fanout.distributed_search_fn`
(local beam search + all-gather merge). This is the §4 workload the paper
evaluates, expressed on TPU.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class VectorWorkloadConfig:
    name: str = "cosmosann-10m"
    total_vectors: int = 10_000_000
    dim: int = 768
    M: int = 96  # PQ subspaces (96-byte codes, §2.1's OpenAI example rate)
    K: int = 256
    R_slack: int = 41  # R=32 × slack 1.3
    L_search: int = 100
    k: int = 10
    query_batch: int = 128
    metric: str = "l2"
    beam_width: int = 4  # W-way hop batching on the search loop (§3.2)
    # serving control plane (repro.serve.policy): "static" pins every
    # knob; "adaptive" closes the loop — beam width / ingest yield /
    # topology actuate per pump tick from the observability rollups
    policy: str = "static"
    # the adaptive W ladder; warmup compiles every (bucket, L, W) in it
    # once so policy moves never recompile in steady state
    policy_widths: tuple[int, ...] = (1, 2, 4)


def config() -> VectorWorkloadConfig:
    return VectorWorkloadConfig()


def smoke() -> VectorWorkloadConfig:
    return VectorWorkloadConfig(
        name="cosmosann-smoke", total_vectors=2000, dim=32, M=8, R_slack=13,
        L_search=20, k=5, query_batch=4,
    )


def shard_specs(cfg: VectorWorkloadConfig, num_shards: int) -> dict:
    """ShapeDtypeStructs for the shard-stacked index arrays + queries."""
    n = cfg.total_vectors // num_shards
    S = num_shards
    return dict(
        neighbors=jax.ShapeDtypeStruct((S, n, cfg.R_slack), jnp.int32),
        codes=jax.ShapeDtypeStruct((S, n, cfg.M), jnp.uint8),
        versions=jax.ShapeDtypeStruct((S, n), jnp.uint8),
        live=jax.ShapeDtypeStruct((S, n), jnp.bool_),
        vectors=jax.ShapeDtypeStruct((S, n, cfg.dim), jnp.float32),
        doc_ids=jax.ShapeDtypeStruct((S, n), jnp.int64),
        medoid=jax.ShapeDtypeStruct((S,), jnp.int32),
        codebooks=jax.ShapeDtypeStruct((S, cfg.M, cfg.K, cfg.dim // cfg.M), jnp.float32),
        queries=jax.ShapeDtypeStruct((cfg.query_batch, cfg.dim), jnp.float32),
    )
