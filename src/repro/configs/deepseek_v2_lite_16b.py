"""DeepSeek-V2-Lite-16B [arXiv:2405.04434; hf]: MLA (kv_lora=512) + MoE
(64 routed top-6 + 2 shared experts).

Deviation note (DESIGN.md): the real model uses a dense FFN in layer 1 and
160 fractional-width routed experts in some variants; the assignment line
specifies "MoE 64e top-6 … 2 shared", which we implement uniformly across
layers to keep the stack scannable.
"""
from ..models.config import MLAConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        num_layers=27,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1408,  # expert FFN width
        vocab_size=102400,
        rope="full",
        mlp="swiglu",
        mla=MLAConfig(
            kv_lora_rank=512,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            num_experts=64,
            top_k=6,
            d_ff_expert=1408,
            num_shared_experts=2,
            d_ff_shared=1408,
            capacity_factor=1.25,
            group_size=512,
        ),
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-smoke",
        family="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=64,
        vocab_size=256,
        mlp="swiglu",
        mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
                      v_head_dim=16),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64,
                      num_shared_experts=1, d_ff_shared=64, group_size=64,
                      capacity_factor=2.0),
        param_dtype="float32",
        compute_dtype="float32",
    )
