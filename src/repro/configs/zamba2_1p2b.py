"""Zamba2-1.2B [arXiv:2411.15242; hf]: hybrid Mamba2 + periodic attention.

38 blocks, d_model 2048, ssm_state 64; attention blocks (GQA kv=32 = MHA,
head_dim 64, d_ff 8192) every 6th layer. DESIGN.md notes the simplification
of Zamba2's *shared* attention block (+ LoRA per call-site) to independent
attention blocks at the same positions.
"""
from ..models.config import ModelConfig, SSMConfig

_PATTERN = tuple(
    "attn" if (i % 6 == 5) else "mamba2" for i in range(38)
)


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        num_layers=38,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=32000,
        rope="full",
        mlp="swiglu",
        ssm=SSMConfig(kind="mamba2", d_state=64, head_dim=64, expand=2, chunk=128),
        block_pattern=_PATTERN,
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke",
        family="hybrid",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        rope="full",
        mlp="swiglu",
        ssm=SSMConfig(kind="mamba2", d_state=16, head_dim=16, expand=2, chunk=16),
        block_pattern=("mamba2", "mamba2", "attn", "mamba2"),
        param_dtype="float32",
        compute_dtype="float32",
    )
