"""Assigned input shapes and ShapeDtypeStruct factories for the dry-run.

LM shapes (assignment):
    train_4k     seq 4096 × global_batch 256   → train_step
    prefill_32k  seq 32768 × global_batch 32   → prefill (serve) step
    decode_32k   seq 32768 × global_batch 128  → decode step (1 token, KV=32k)
    long_500k    seq 524288 × global_batch 1   → decode step (sub-quadratic
                                                  archs only)

Skips (recorded, per assignment):
    encoder-only (hubert) has no decode → decode_32k / long_500k N/A;
    long_500k only for SSM/hybrid archs (pure attention would need a
    500k-entry quadratic softmax cache — noted in DESIGN.md).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(supported, reason-if-not) for an (arch × shape) cell."""
    if shape.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "500k decode requires sub-quadratic sequence mixing"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input — no allocation."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32

    def sds(shp, dt):
        return jax.ShapeDtypeStruct(shp, dt)

    if shape.kind == "train":
        if cfg.input_mode == "tokens":
            return {"tokens": sds((B, S), i32)}
        if cfg.input_mode == "frames":
            return {"frames": sds((B, S, cfg.d_model), jnp.bfloat16),
                    "labels": sds((B, S), i32)}
        Ni = cfg.num_image_tokens
        return {"tokens": sds((B, S - Ni), i32),
                "image_embeds": sds((B, Ni, cfg.d_model), jnp.bfloat16)}

    if shape.kind == "prefill":
        if cfg.input_mode == "tokens":
            return {"tokens": sds((B, S), i32)}
        if cfg.input_mode == "frames":
            return {"frames": sds((B, S, cfg.d_model), jnp.bfloat16)}
        Ni = cfg.num_image_tokens
        return {"tokens": sds((B, S - Ni), i32),
                "image_embeds": sds((B, Ni, cfg.d_model), jnp.bfloat16)}

    # decode: one new token against an S-token cache
    if cfg.input_mode == "frames":
        return {"tokens": sds((B, 1, cfg.d_model), jnp.bfloat16)}
    return {"tokens": sds((B, 1), i32)}
