"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M; hf]: llama-arch small."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m",
        family="dense",
        num_layers=30,
        d_model=576,
        num_heads=9,
        num_kv_heads=3,
        head_dim=64,
        d_ff=1536,
        vocab_size=49152,
        rope="full",
        mlp="swiglu",
        tie_embeddings=True,
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m-smoke",
        family="dense",
        num_layers=2,
        d_model=96,
        num_heads=3,
        num_kv_heads=1,
        head_dim=32,
        d_ff=192,
        vocab_size=256,
        rope="full",
        mlp="swiglu",
        tie_embeddings=True,
        param_dtype="float32",
        compute_dtype="float32",
    )
