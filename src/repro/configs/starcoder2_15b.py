"""StarCoder2-15B [arXiv:2402.19173; hf]: dense, GQA(kv=4), RoPE, gelu MLP."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b",
        family="dense",
        num_layers=40,
        d_model=6144,
        num_heads=48,
        num_kv_heads=4,
        head_dim=128,
        d_ff=24576,
        vocab_size=49152,
        rope="full",
        mlp="gelu",
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b-smoke",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=8,
        num_kv_heads=2,
        head_dim=16,
        d_ff=256,
        vocab_size=256,
        rope="full",
        mlp="gelu",
        param_dtype="float32",
        compute_dtype="float32",
    )
