"""Architecture registry: ``--arch <id>`` → config.

Ten assigned architectures (each with full + smoke configs) plus the
paper's own vector-search workload (`cosmosann`). Shapes in shapes.py.
"""
from __future__ import annotations

import importlib

from ..models.config import ModelConfig
from .shapes import SHAPES, ShapeSpec, cell_supported, input_specs

ARCH_IDS = [
    "starcoder2-15b",
    "chatglm3-6b",
    "qwen3-14b",
    "smollm-135m",
    "qwen3-moe-235b-a22b",
    "deepseek-v2-lite-16b",
    "hubert-xlarge",
    "paligemma-3b",
    "zamba2-1.2b",
    "rwkv6-7b",
]

_MODULES = {
    "starcoder2-15b": "starcoder2_15b",
    "chatglm3-6b": "chatglm3_6b",
    "qwen3-14b": "qwen3_14b",
    "smollm-135m": "smollm_135m",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "hubert-xlarge": "hubert_xlarge",
    "paligemma-3b": "paligemma_3b",
    "zamba2-1.2b": "zamba2_1p2b",
    "rwkv6-7b": "rwkv6_7b",
    "cosmosann": "cosmosann",
}


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f".{_MODULES[arch_id]}", __package__)


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).smoke()


__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ShapeSpec",
    "cell_supported",
    "input_specs",
    "get_config",
    "get_smoke_config",
]
