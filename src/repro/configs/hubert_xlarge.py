"""HuBERT-XLarge [arXiv:2106.07447]: encoder-only audio transformer.

The conv-waveform frontend is a STUB per the assignment: `input_specs()`
provides precomputed frame embeddings (B, S, d_model). Training target is
per-frame classification over the 504-unit codebook (masked-prediction
simplified to full-frame CE). No decode step (encoder-only).
"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        family="audio",
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        d_ff=5120,
        vocab_size=504,
        causal=False,
        input_mode="frames",
        rope="none",
        mlp="gelu",
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke",
        family="audio",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=64,
        causal=False,
        input_mode="frames",
        rope="none",
        mlp="gelu",
        param_dtype="float32",
        compute_dtype="float32",
    )
