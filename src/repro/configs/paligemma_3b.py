"""PaliGemma-3B [arXiv:2407.07726; hf]: SigLIP + Gemma decoder (MQA kv=1).

The SigLIP vision tower is a STUB per the assignment: `input_specs()`
provides 256 precomputed patch embeddings per image, prepended to the token
stream. DESIGN.md notes the prefix-LM → causal-mask simplification.
"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b",
        family="vlm",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=257216,
        rope="full",
        mlp="swiglu",  # gemma GeGLU ≈ gated MLP
        input_mode="vlm",
        num_image_tokens=256,
        tie_embeddings=True,
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b-smoke",
        family="vlm",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=1,
        head_dim=32,
        d_ff=256,
        vocab_size=256,
        rope="full",
        mlp="swiglu",
        input_mode="vlm",
        num_image_tokens=8,
        tie_embeddings=True,
        param_dtype="float32",
        compute_dtype="float32",
    )
