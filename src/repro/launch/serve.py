"""Serving launcher: ``python -m repro.launch.serve --arch smollm-135m``.

Brings up the batched LM engine (smoke config on CPU) together with a
vector collection, runs a demo request mix (embed → ANN search → decode),
and prints throughput + RU accounting. The TPU deployment uses the same
StepBundle decode path under the production mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config, get_smoke_config
from ..core import GraphConfig
from ..models import model as M
from ..serve import (EngineConfig, ServeEngine, VectorCollectionService,
                     VectorQuery)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--corpus", type=int, default=500)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--dispatch-mode", default="serial",
                    choices=("serial", "replica", "spmd"),
                    help="engine dispatch plane: serial (one lane), "
                         "replica (N concurrent lanes + hedging), spmd "
                         "(shard_map partition fan-out)")
    ap.add_argument("--lanes", type=int, default=4,
                    help="replica lanes for --dispatch-mode=replica")
    ap.add_argument("--resident-frac", type=float, default=None,
                    metavar="F",
                    help="paged vector tier: keep only F of each "
                         "partition's full-precision pages resident "
                         "(search stays PQ-resident; rerank faults pages "
                         "in). Default: fully resident")
    ap.add_argument("--policy", default="static",
                    choices=("static", "adaptive"),
                    help="serving control plane: static pins beam width / "
                         "ingest yield / topology at their configured "
                         "values; adaptive closes the loop on the "
                         "observability rollups (serve/policy.py)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="dump retained request traces as JSON lines "
                         "(flight recorder + anomaly ring)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="dump the labeled metrics registry in Prometheus "
                         "text exposition format")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only; no decode serving")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)

    # vector side: random embeddings standing in for a production encoder
    dim = 32
    svc = VectorCollectionService(
        dim=dim,
        graph=GraphConfig(capacity=args.corpus + 256, R=16, M=8, L_build=32,
                          L_search=48, bootstrap_sample=128, refine_sample=10**9),
        max_vectors_per_partition=args.corpus + 128,
        engine_cfg=EngineConfig(dispatch_mode=args.dispatch_mode,
                                lanes=args.lanes, policy=args.policy),
    )
    vecs = rng.randn(args.corpus, dim).astype(np.float32)
    svc.upsert([{"id": i} for i in range(args.corpus)], vecs)
    if args.resident_frac is not None:
        svc.set_residency(args.resident_frac)

    engine = ServeEngine(cfg, params, batch_slots=4, s_max=128)
    t0 = time.time()
    total_ru = 0.0
    for rid in range(args.requests):
        res = svc.query(VectorQuery(vector=vecs[rid] + 0.01, k=3))
        total_ru += res.ru
        engine.submit(rid, rng.randint(0, cfg.vocab_size, 12),
                      max_new_tokens=args.max_new_tokens)
    out = engine.run()
    dt = time.time() - t0
    tokens = sum(len(v) for v in out.values())
    print(f"served {len(out)} requests, {tokens} tokens in {dt:.1f}s "
          f"({tokens/dt:.1f} tok/s on CPU), search RU total {total_ru:.0f}")
    snap = svc.engine.snapshot()
    pol = snap["policy"]
    print(f"policy[{pol['mode']}]: W={pol['beam_width']} "
          f"interleave={pol['ingest_interleave']} ticks={pol['ticks']} "
          f"w_changes={pol['w_changes']} last_scale={pol['last_scale']}")
    mem, vt = snap["memory"], snap["memory"]["vector_tier"]
    print(f"memory: pq={mem['resident']['pq_codes_bytes']/1024:.0f}KiB "
          f"adj={mem['resident']['adjacency_bytes']/1024:.0f}KiB resident; "
          f"vector tier {vt['resident_bytes']/1024:.0f}"
          f"/{vt['total_bytes']/1024:.0f}KiB paged "
          f"({vt['resident_pages']}/{vt['capacity_pages']} pages, "
          f"hit rate {vt['hit_rate']:.2f})")

    if args.trace_out:
        n = svc.engine.tracer.dump_jsonl(args.trace_out)
        print(f"wrote {n} trace records to {args.trace_out}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(svc.engine.obs.to_prometheus_text())
        print(f"wrote metrics exposition to {args.metrics_out}")


if __name__ == "__main__":
    main()
