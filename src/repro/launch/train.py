"""Training launcher: ``python -m repro.launch.train --arch smollm-135m``.

Runs real steps on the host's devices (CPU here; the same code path drives
TPU pods — the mesh is the only difference). Fault-tolerance wired in:
checkpoint every N steps (atomic manifests), auto-resume from the newest
complete checkpoint, deterministic data cursor, optional elastic remesh
(resume on a different device count).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..configs.shapes import ShapeSpec, input_specs
from ..models import steps as steps_mod
from ..models.config import ModelConfig
from ..train import checkpoint as ckpt
from ..train.data import SyntheticStream
from ..train.optimizer import OptConfig
from .mesh import make_host_mesh


def train(
    cfg: ModelConfig,
    steps: int = 50,
    global_batch: int = 8,
    seq_len: int = 128,
    ckpt_dir: str | None = None,
    ckpt_every: int = 25,
    stop_after: int | None = None,  # simulate a crash at this step
    resume: bool = True,
    remat: str = "none",
    lr: float = 3e-4,
    log_every: int = 10,
) -> dict:
    mesh = make_host_mesh()
    opt_cfg = OptConfig(lr=lr, warmup_steps=max(steps // 10, 1), total_steps=steps)

    spec = ShapeSpec("train", seq_len, global_batch, "train")
    batch_shapes = input_specs(cfg, spec)
    bundle = steps_mod.make_train_step(cfg, mesh, batch_shapes, opt_cfg, remat=remat)

    stream = SyntheticStream(cfg, global_batch, seq_len)
    state = None
    start_step = 0
    if ckpt_dir and resume and (ckpt.latest_step(ckpt_dir) is not None):
        template = jax.tree.map(
            lambda s: np.zeros(s.shape, s.dtype), bundle.arg_shapes[0]
        )
        state, extra = ckpt.restore(ckpt_dir, template, shardings=bundle.arg_shardings[0])
        start_step = extra["step"]
        stream.restore(extra["data"])
        print(f"resumed from step {start_step}")
    if state is None:
        state = bundle.init()

    losses = []
    t0 = time.time()
    for step in range(start_step, steps):
        batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
        state, metrics = bundle.fn(state, batch)
        losses.append(float(metrics["loss"]))
        if step % log_every == 0 or step == steps - 1:
            print(
                f"step {step:5d} loss {losses[-1]:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"lr {float(metrics['lr']):.2e} "
                f"({(time.time()-t0)/(step-start_step+1):.2f}s/step)",
                flush=True,
            )
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, step + 1, jax.tree.map(np.asarray, state),
                      extra={"step": step + 1, "data": stream.snapshot()})
        if stop_after is not None and step + 1 >= stop_after:
            break  # simulated crash/preemption
    return {"losses": losses, "final_loss": losses[-1] if losses else None}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--remat", default="none", choices=["none", "full", "dots"])
    args = ap.parse_args(argv)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    out = train(cfg, steps=args.steps, global_batch=args.global_batch,
                seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every, remat=args.remat, lr=args.lr)
    print(f"final loss: {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
