"""Production meshes.

Single pod: 16×16 = 256 chips, axes (data, model).
Multi-pod: 2×16×16 = 512 chips, axes (pod, data, model) — `pod` crosses DCN
and carries only the data-parallel gradient all-reduce (see
models/sharding.py). Defined as a function so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS first).

Construction goes through repro.compat.make_mesh: axis types (Auto) are
passed only on JAX versions whose ``jax.make_mesh`` accepts them.
"""
from __future__ import annotations

import jax

from .. import compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(shape: tuple[int, ...] = None, axes: tuple[str, ...] = None):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        shape, axes = (n,), ("data",)
    return compat.make_mesh(shape, axes)


def make_serve_mesh(devices: int = None) -> jax.sharding.Mesh:
    """1-D data mesh for the serving engine's SPMD fan-out
    (`partition.fanout.SpmdFanout`): partitions shard across the single
    ``data`` axis, one stacked-graph search per device slice. Defaults to
    every visible device (1 on a plain CPU host; set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to emulate a
    pod)."""
    n = devices or len(jax.devices())
    return compat.make_mesh((n,), ("data",))
