"""Production meshes.

Single pod: 16×16 = 256 chips, axes (data, model).
Multi-pod: 2×16×16 = 512 chips, axes (pod, data, model) — `pod` crosses DCN
and carries only the data-parallel gradient all-reduce (see
models/sharding.py). Defined as a function so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS first).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(shape: tuple[int, ...] = None, axes: tuple[str, ...] = None):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        shape, axes = (n,), ("data",)
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )
