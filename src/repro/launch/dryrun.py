import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each supported cell this produces, with ZERO device allocation
(ShapeDtypeStruct lowering):

  * proof the sharded program compiles on the production meshes
    (16×16 single-pod and 2×16×16 multi-pod);
  * `memory_analysis()` — per-device bytes (argument/output/temp), proving
    the cell fits a 16 GB v5e;
  * `cost_analysis()` — HLO FLOPs / bytes;
  * a collective inventory parsed from the post-SPMD HLO text
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute, with per-op result bytes);
  * correction variants (L=1, L=2, and chunk-doubling for SSM archs) — XLA's
    cost analysis counts `while` bodies once (verified), so
    benchmarks/roofline.py reconstructs true totals from these deltas.

Results go to results/dryrun/<arch>__<shape>__<mesh>.json, incrementally
(reruns skip completed cells). The paper's own workload (`--arch
cosmosann`) lowers the shard_map distributed vector search instead.
"""
import argparse
import dataclasses
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import (ARCH_IDS, SHAPES, cell_supported, get_config,
                       input_specs)
from ..configs import cosmosann as cosmos_cfg
from ..models import steps as steps_mod
from ..models.config import ModelConfig
from ..partition.fanout import distributed_search_fn
from .mesh import make_production_mesh

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo: str) -> dict:
    """Per-op-kind {count, result_bytes} from post-SPMD HLO text.

    Shapes in the partitioned module are per-device; result bytes of each
    collective instruction approximate the data it moves per device (ring
    factors applied later in roofline.py).
    """
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVE_OPS}
    for line in hlo.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        rhs = rhs.strip()
        m = re.match(r"^(\([^)]*\)|\S+)\s+([a-z0-9-]+)", rhs)
        if not m:
            continue
        op = m.group(2)
        # strip -start/-done variants
        base = op.replace("-start", "").replace("-done", "")
        if base in out and not op.endswith("-done"):
            out[base]["count"] += 1
            out[base]["bytes"] += _shape_bytes(m.group(1))
    return out


def _mem_dict(ma) -> dict:
    keys = (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    )
    return {k: int(getattr(ma, k, 0) or 0) for k in keys}


def _compile_one(build_fn, tag: str, want_memory: bool) -> dict:
    t0 = time.time()
    fn, arg_shapes = build_fn()
    lowered = fn.lower(*arg_shapes)
    compiled = lowered.compile()
    rec: dict = {"tag": tag, "compile_s": round(time.time() - t0, 2)}
    ca = compiled.cost_analysis() or {}
    rec["flops"] = float(ca.get("flops", 0.0))
    rec["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
    rec["collectives"] = parse_collectives(compiled.as_text())
    if want_memory:
        rec["memory"] = _mem_dict(compiled.memory_analysis())
    return rec


def _variant_cfg(cfg: ModelConfig, num_layers: int = None, unroll: bool = False,
                 pattern_kind: str = None) -> ModelConfig:
    """Cost-extraction variants: unrolled layers / chunk loops so every op
    is visible to the while-body-once cost analysis."""
    kw: dict = {}
    if num_layers is not None:
        kw["num_layers"] = num_layers
        if pattern_kind is not None:
            kw["block_pattern"] = (pattern_kind,) * num_layers
        elif cfg.block_pattern:
            kw["block_pattern"] = cfg.block_pattern[:num_layers]
    if unroll:
        kw["force_unroll"] = True
        if cfg.ssm is not None:
            kw["ssm"] = dataclasses.replace(cfg.ssm, unroll_chunks=True)
    return dataclasses.replace(cfg, **kw)


# production trains always microbatch at global_batch 256: activations and
# the (B,S,V) loss block shrink ×ACCUM; roofline.py multiplies the reported
# (single-microbatch) costs back up — microbatches are identical, so this is
# exact up to the optimizer step being counted once per microbatch (<0.1%).
TRAIN_ACCUM = 4

# §Perf experiment hook (benchmarks/perf_experiments.py): step-level knobs
# applied to every compile of a cell. Keys: remat ('full'|'dots'|'none'),
# accum (int), cfg (fn(ModelConfig) -> ModelConfig).
OVERRIDES: dict = {}


def _build_step(cfg: ModelConfig, shape, mesh, seq_override: int = None):
    sh = shape
    if seq_override is not None:
        sh = dataclasses.replace(shape, seq_len=seq_override)
    if OVERRIDES.get("cfg"):
        cfg = OVERRIDES["cfg"](cfg)
    specs = input_specs(cfg, sh)
    if sh.kind == "train":
        bundle = steps_mod.make_train_step(
            cfg, mesh, specs,
            accum=OVERRIDES.get("accum", TRAIN_ACCUM),
            remat=OVERRIDES.get("remat", "full"),
        )
        return bundle.fn, (bundle.arg_shapes[0], specs)
    if sh.kind == "prefill":
        bundle = steps_mod.make_prefill_step(cfg, mesh, specs, s_max=sh.seq_len)
        return bundle.fn, (bundle.arg_shapes[0], specs)
    bundle = steps_mod.make_decode_step(
        cfg, mesh, batch=sh.global_batch, s_max=sh.seq_len
    )
    return bundle.fn, bundle.arg_shapes


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: str,
             force: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    result: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "devices": int(len(mesh.devices.reshape(-1))),
    }

    if arch == "cosmosann":
        result.update(_run_cosmos_cell(mesh))
    else:
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        ok, reason = cell_supported(cfg, shape)
        if not ok:
            result["skipped"] = reason
            _write(path, result)
            return result
        try:
            # Variant plan (cost extraction — see module docstring):
            #   uniform non-SSM archs: L1/L2 fully unrolled at the real
            #       shape → F_true = F(L1) + (L−1)·(F(L2) − F(L1));
            #   uniform SSM archs (rwkv6): same, but at a reduced sequence
            #       S_v = 8·chunk (unrolled chunk loops); everything in an
            #       attention-free arch is linear in S, so roofline.py
            #       rescales by S/S_v;
            #   hetero (zamba2): per-block-type deltas — M1/M2 (all-mamba
            #       pattern, reduced S_v, linear rescale) and A1/A2
            #       (all-attn pattern at the real S: attention is quadratic
            #       in S so it must be compiled at full length) →
            #       F = (ovh + n_mamba·ΔM)·S/S_v + n_attn·ΔA.
            variants: list = [("full", cfg, None)]  # _build_step applies OVERRIDES
            seq_scaled = None
            if cfg.uniform and cfg.ssm is None:
                variants.append(("L1", _variant_cfg(cfg, 1, unroll=True), None))
                variants.append(("L2", _variant_cfg(cfg, 2, unroll=True), None))
            elif cfg.uniform:  # rwkv6-style pure SSM
                if shape.kind in ("train", "prefill"):
                    seq_scaled = min(shape.seq_len, 8 * cfg.ssm.chunk)
                variants.append(("L1", _variant_cfg(cfg, 1, unroll=True), seq_scaled))
                variants.append(("L2", _variant_cfg(cfg, 2, unroll=True), seq_scaled))
            else:  # zamba2 hybrid
                if shape.kind in ("train", "prefill"):
                    seq_scaled = min(shape.seq_len, 8 * cfg.ssm.chunk)
                m1 = _variant_cfg(cfg, 1, unroll=True, pattern_kind="mamba2")
                m2 = _variant_cfg(cfg, 2, unroll=True, pattern_kind="mamba2")
                a1 = _variant_cfg(cfg, 1, unroll=True, pattern_kind="attn")
                a2 = _variant_cfg(cfg, 2, unroll=True, pattern_kind="attn")
                variants += [("M1", m1, seq_scaled), ("M2", m2, seq_scaled),
                             ("A1", a1, None), ("A2", a2, None)]
            result["seq_scaled"] = seq_scaled
            result["accum"] = TRAIN_ACCUM if shape.kind == "train" else 1
            result["records"] = []
            for tag, vcfg, seq in variants:
                rec = _compile_one(
                    lambda vcfg=vcfg, seq=seq: _build_step(vcfg, shape, mesh, seq),
                    tag, want_memory=(tag == "full"),
                )
                result["records"].append(rec)
                print(f"  [{arch}|{shape_name}|{mesh_name}|{tag}] "
                      f"flops={rec['flops']:.3e} compile={rec['compile_s']}s",
                      flush=True)
            result["ok"] = True
            result["model_params"] = cfg.param_count()
            result["active_params"] = cfg.active_param_count()
        except Exception as e:  # noqa: BLE001 — cell failures are data
            result["ok"] = False
            result["error"] = f"{type(e).__name__}: {e}"
            result["traceback"] = traceback.format_exc()[-4000:]
            print(f"  [{arch}|{shape_name}|{mesh_name}] FAILED: {e}", flush=True)
    _write(path, result)
    return result


def _run_cosmos_cell(mesh) -> dict:
    cfg = cosmos_cfg.config()
    n_dev = int(len(mesh.devices.reshape(-1)))
    specs = cosmos_cfg.shard_specs(cfg, n_dev)
    shard_axes = tuple(mesh.axis_names)
    fn = distributed_search_fn(
        mesh, L=cfg.L_search, k=cfg.k, metric=cfg.metric, shard_axes=shard_axes,
        max_hops=-(-2 * cfg.L_search // cfg.beam_width),
        beam_width=cfg.beam_width,
    )
    args = (
        specs["neighbors"], specs["codes"], specs["versions"], specs["live"],
        specs["vectors"], specs["doc_ids"], specs["medoid"],
        specs["codebooks"], specs["queries"],
    )
    rec = _compile_one(lambda: (fn, args), "full", want_memory=True)
    return {"ok": True, "records": [rec], "workload": dataclasses.asdict(cfg)}


def _write(path: str, result: dict):
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(result, f, indent=1)
    os.replace(tmp, path)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help=f"arch id, 'all', or comma list; known: {ARCH_IDS + ['cosmosann']}")
    ap.add_argument("--shape", default="all", help="train_4k|prefill_32k|decode_32k|long_500k|all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    archs = (ARCH_IDS + ["cosmosann"]) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    summary = []
    for arch in archs:
        arch_shapes = ["n/a"] if arch == "cosmosann" else shapes
        for shp in arch_shapes:
            for mesh_name in meshes:
                print(f"=== {arch} × {shp} × {mesh_name} ===", flush=True)
                r = run_cell(arch, shp if arch != "cosmosann" else "query",
                             mesh_name, args.out, force=args.force)
                status = ("SKIP: " + r["skipped"]) if r.get("skipped") else (
                    "OK" if r.get("ok") else "FAIL")
                summary.append((arch, shp, mesh_name, status))
    print("\n=== DRY-RUN SUMMARY ===")
    bad = 0
    for arch, shp, mesh_name, status in summary:
        print(f"{arch:24s} {shp:12s} {mesh_name:6s} {status}")
        bad += status == "FAIL"
    print(f"{len(summary)} cells, {bad} failures")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
