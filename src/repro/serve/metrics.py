"""Deterministic serving metrics: simulated clock + latency/throughput stats.

Everything the serving engine reports is computed against a *simulated*
clock, so tests and benchmarks are bit-reproducible offline: arrivals are
stamped by the workload generator, service time comes from the calibrated
§4.4 access-time model, and queue wait falls out of the two. The same
registry also tracks real recompile telemetry (`core.search.jit_cache_size`)
because compile stalls are the one latency source the model cannot see.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


class SimClock:
    """Monotonic simulated time in seconds. Advanced explicitly by the
    engine (service time) and by workload generators (arrival gaps)."""

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def advance(self, seconds: float) -> float:
        assert seconds >= 0.0, "time only moves forward"
        self._t += seconds
        return self._t


class Histogram:
    """Exact sample store (offline scale) with percentile readout."""

    def __init__(self):
        self._samples: list[float] = []

    def observe(self, v: float):
        self._samples.append(float(v))

    @property
    def count(self) -> int:
        return len(self._samples)

    def mean(self) -> float:
        return float(np.mean(self._samples)) if self._samples else 0.0

    def percentile(self, p: float) -> float:
        return float(np.percentile(self._samples, p)) if self._samples else 0.0


@dataclasses.dataclass
class EngineMetrics:
    """Counters + distributions for one VectorServeEngine lifetime."""

    queries_ok: int = 0
    queries_throttled: int = 0
    pages_served: int = 0  # merged continuation pages (each RU-metered)
    batches: int = 0
    lanes_total: int = 0  # dispatched lanes incl. padding
    lanes_padded: int = 0
    ingest_ops: int = 0
    ingest_batches: int = 0
    ru_query_total: float = 0.0
    ru_ingest_total: float = 0.0
    # per-query sequential search rounds (beam-width telemetry): hop
    # batching shows up here as mean_hops dropping ~W×
    hops_weighted: float = 0.0
    hops_lanes: int = 0
    # dispatch-plane telemetry: hedged duplicates bill RU; lane faults
    # and recoveries mirror the executor's health machine
    hedges: int = 0
    hedges_won: int = 0
    hedge_ru_total: float = 0.0
    started_s: float = 0.0
    latency_ms: Histogram = dataclasses.field(default_factory=Histogram)
    wait_ms: Histogram = dataclasses.field(default_factory=Histogram)
    occupancy: Histogram = dataclasses.field(default_factory=Histogram)
    # trajectory of the batched-search jit cache size, one point per batch:
    # flat in steady state == zero recompiles
    jit_cache_trajectory: list = dataclasses.field(default_factory=list)

    def note_batch(self, true_lanes: int, bucket: int, service_ms: float,
                   ru: float, cache_size: int):
        self.batches += 1
        self.lanes_total += bucket
        self.lanes_padded += bucket - true_lanes
        self.ru_query_total += ru
        self.occupancy.observe(true_lanes / max(bucket, 1))
        self.jit_cache_trajectory.append(int(cache_size))

    def note_hedge(self, won: bool, hedge_ru: float):
        self.hedges += 1
        self.hedges_won += int(won)
        self.hedge_ru_total += hedge_ru

    def note_hops(self, mean_hops: float, true_lanes: int):
        self.hops_weighted += mean_hops * true_lanes
        self.hops_lanes += true_lanes

    def recompiles_since(self, batch_index: int = 0) -> int:
        """Jit cache growth after batch `batch_index` (0 = engine start)."""
        traj = self.jit_cache_trajectory
        if not traj:
            return 0
        base = traj[batch_index] if batch_index < len(traj) else traj[-1]
        return traj[-1] - base

    def snapshot(self, now_s: float) -> dict:
        elapsed = max(now_s - self.started_s, 1e-9)
        return dict(
            queries_ok=self.queries_ok,
            queries_throttled=self.queries_throttled,
            pages_served=self.pages_served,
            batches=self.batches,
            qps=self.queries_ok / elapsed,
            ru_per_s=self.ru_query_total / elapsed,
            ru_query_total=self.ru_query_total,
            ru_ingest_total=self.ru_ingest_total,
            ingest_ops=self.ingest_ops,
            p50_ms=self.latency_ms.percentile(50),
            p95_ms=self.latency_ms.percentile(95),
            p99_ms=self.latency_ms.percentile(99),
            mean_wait_ms=self.wait_ms.mean(),
            p95_wait_ms=self.wait_ms.percentile(95),
            hedges=self.hedges,
            hedges_won=self.hedges_won,
            hedge_ru_total=self.hedge_ru_total,
            mean_hops=self.hops_weighted / max(self.hops_lanes, 1),
            mean_occupancy=self.occupancy.mean(),
            pad_fraction=self.lanes_padded / max(self.lanes_total, 1),
            jit_cache_size=(self.jit_cache_trajectory[-1]
                            if self.jit_cache_trajectory else 0),
            elapsed_s=elapsed,
        )


def poisson_arrivals(rng: np.random.RandomState, n: int, rate_per_s: float,
                     t0: float = 0.0) -> np.ndarray:
    """Deterministic (seeded) Poisson-process arrival times for workloads."""
    gaps = rng.exponential(1.0 / rate_per_s, size=n)
    return t0 + np.cumsum(gaps)
