"""Deterministic serving metrics: simulated clock + latency/throughput stats.

Everything the serving engine reports is computed against a *simulated*
clock, so tests and benchmarks are bit-reproducible offline: arrivals are
stamped by the workload generator, service time comes from the calibrated
§4.4 access-time model, and queue wait falls out of the two. The same
registry also tracks real recompile telemetry (`core.search.jit_cache_size`)
because compile stalls are the one latency source the model cannot see.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np


class SimClock:
    """Monotonic simulated time in seconds. Advanced explicitly by the
    engine (service time) and by workload generators (arrival gaps)."""

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def advance(self, seconds: float) -> float:
        assert seconds >= 0.0, "time only moves forward"
        self._t += seconds
        return self._t


class Histogram:
    """Bounded streaming histogram: O(1) memory regardless of samples.

    Replaces the old unbounded exact sample list. Values land in
    geometric bins (ratio ``GROWTH`` per bin starting at ``LO``), so the
    percentile readout — the geometric midpoint of the target bin,
    clamped to the exact observed [min, max] — carries ≤ √GROWTH−1
    (≈3.4%) relative error while ``count``/``sum``/``mean``/``min``/
    ``max`` stay exact. Percentiles are monotone in p by construction
    (cumulative scan over ordered bins). Parity against the retained
    ``ExactHistogram`` is tested on seeded workloads.
    """

    LO = 1e-3  # lowest resolved value; below lands in the underflow bin
    GROWTH = 1.07
    NBINS = 420  # covers LO … LO·G^NBINS ≈ 2e9; beyond is the overflow bin

    __slots__ = ("_counts", "_count", "_sum", "_min", "_max")

    def __init__(self):
        # [underflow, NBINS geometric bins, overflow]
        self._counts = np.zeros(self.NBINS + 2, dtype=np.int64)
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, v: float):
        v = float(v)
        self._count += 1
        self._sum += v
        if v < self._min:
            self._min = v
        if v > self._max:
            self._max = v
        if v <= self.LO:
            idx = 0
        else:
            idx = min(1 + int(math.log(v / self.LO) / _LOG_GROWTH),
                      self.NBINS + 1)
        self._counts[idx] += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def min(self) -> float:
        return self._min if self._count else 0.0

    @property
    def max(self) -> float:
        return self._max if self._count else 0.0

    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def percentile(self, p: float) -> float:
        if not self._count:
            return 0.0
        target = min(max(1, int(math.ceil(p / 100.0 * self._count))),
                     self._count)
        cum = np.cumsum(self._counts)
        idx = int(np.searchsorted(cum, target))
        if idx == 0:
            val = self._min  # underflow bin: everything ≤ LO
        elif idx == self.NBINS + 1:
            val = self._max  # overflow bin
        else:
            val = self.LO * self.GROWTH ** (idx - 0.5)  # geometric midpoint
        return float(min(max(val, self._min), self._max))


_LOG_GROWTH = math.log(Histogram.GROWTH)


class ExactHistogram:
    """Exact sample store — the reference implementation the streaming
    ``Histogram`` is parity-tested against. Unbounded memory; use only
    where the sample count is small and exactness matters."""

    def __init__(self):
        self._samples: list[float] = []

    def observe(self, v: float):
        self._samples.append(float(v))

    @property
    def count(self) -> int:
        return len(self._samples)

    @property
    def sum(self) -> float:
        return float(np.sum(self._samples)) if self._samples else 0.0

    def mean(self) -> float:
        return float(np.mean(self._samples)) if self._samples else 0.0

    def percentile(self, p: float) -> float:
        return float(np.percentile(self._samples, p)) if self._samples else 0.0


@dataclasses.dataclass
class EngineMetrics:
    """Counters + distributions for one VectorServeEngine lifetime."""

    queries_ok: int = 0
    queries_throttled: int = 0
    queries_deadline: int = 0  # 408s: deadline expired while queued
    queries_degraded: int = 0  # 200s served from a partial partition set
    pages_served: int = 0  # merged continuation pages (each RU-metered)
    batches: int = 0
    lanes_total: int = 0  # dispatched lanes incl. padding
    lanes_padded: int = 0
    ingest_ops: int = 0
    ingest_batches: int = 0
    # RU attribution is disjoint: ru_query_total is the *work* RU of
    # query/page dispatches (hedge duplicates excluded), hedge_ru_total
    # is the hedge surcharge, ru_ingest_total the write path. The three
    # sum to every RU settled against tenant governors (conservation is
    # asserted in tests/test_observability.py).
    ru_query_total: float = 0.0
    ru_ingest_total: float = 0.0
    # per-query sequential search rounds (beam-width telemetry): hop
    # batching shows up here as mean_hops dropping ~W×
    hops_weighted: float = 0.0
    hops_lanes: int = 0
    # dispatch-plane telemetry: hedged duplicates bill RU; lane faults
    # and recoveries mirror the executor's health machine
    hedges: int = 0
    hedges_won: int = 0
    hedge_ru_total: float = 0.0
    # control-plane telemetry (serve.policy): ticks evaluated, beam-width
    # moves, topology actions, and the ingest-yield debt ledger (chunks
    # the policy deferred under latency pressure vs chunks repaid by
    # idle catch-up beyond the static 1-chunk trickle)
    policy_ticks: int = 0
    policy_w_changes: int = 0
    policy_splits: int = 0
    policy_lanes_added: int = 0
    policy_cache_resizes: int = 0  # paged-tier budget moves (ISSUE 10)
    ingest_deferred_chunks: int = 0
    ingest_catchup_chunks: int = 0
    started_s: float = 0.0
    latency_ms: Histogram = dataclasses.field(default_factory=Histogram)
    wait_ms: Histogram = dataclasses.field(default_factory=Histogram)
    occupancy: Histogram = dataclasses.field(default_factory=Histogram)
    # trajectory of the batched-search jit cache size, one point per batch:
    # flat in steady state == zero recompiles
    jit_cache_trajectory: list = dataclasses.field(default_factory=list)

    def note_batch(self, true_lanes: int, bucket: int, service_ms: float,
                   ru: float, cache_size: int):
        self.batches += 1
        self.lanes_total += bucket
        self.lanes_padded += bucket - true_lanes
        self.ru_query_total += ru
        self.occupancy.observe(true_lanes / max(bucket, 1))
        self.jit_cache_trajectory.append(int(cache_size))

    def note_hedge(self, won: bool, hedge_ru: float):
        self.hedges += 1
        self.hedges_won += int(won)
        self.hedge_ru_total += hedge_ru

    def note_hops(self, mean_hops: float, true_lanes: int):
        self.hops_weighted += mean_hops * true_lanes
        self.hops_lanes += true_lanes

    def recompiles_since(self, batch_index: int = 0) -> int:
        """Jit cache growth after batch `batch_index` (0 = engine start)."""
        traj = self.jit_cache_trajectory
        if not traj:
            return 0
        base = traj[batch_index] if batch_index < len(traj) else traj[-1]
        return traj[-1] - base

    def snapshot(self, now_s: float) -> dict:
        elapsed = max(now_s - self.started_s, 1e-9)
        return dict(
            queries_ok=self.queries_ok,
            queries_throttled=self.queries_throttled,
            queries_deadline=self.queries_deadline,
            queries_degraded=self.queries_degraded,
            pages_served=self.pages_served,
            batches=self.batches,
            qps=self.queries_ok / elapsed,
            ru_per_s=(self.ru_query_total + self.hedge_ru_total) / elapsed,
            ru_query_total=self.ru_query_total,
            ru_ingest_total=self.ru_ingest_total,
            ingest_ops=self.ingest_ops,
            p50_ms=self.latency_ms.percentile(50),
            p95_ms=self.latency_ms.percentile(95),
            p99_ms=self.latency_ms.percentile(99),
            mean_wait_ms=self.wait_ms.mean(),
            p95_wait_ms=self.wait_ms.percentile(95),
            hedges=self.hedges,
            hedges_won=self.hedges_won,
            hedge_ru_total=self.hedge_ru_total,
            mean_hops=self.hops_weighted / max(self.hops_lanes, 1),
            mean_occupancy=self.occupancy.mean(),
            pad_fraction=self.lanes_padded / max(self.lanes_total, 1),
            jit_cache_size=(self.jit_cache_trajectory[-1]
                            if self.jit_cache_trajectory else 0),
            elapsed_s=elapsed,
        )


def poisson_arrivals(rng: np.random.RandomState, n: int, rate_per_s: float,
                     t0: float = 0.0) -> np.ndarray:
    """Deterministic (seeded) Poisson-process arrival times for workloads."""
    gaps = rng.exponential(1.0 / rate_per_s, size=n)
    return t0 + np.cumsum(gaps)
