"""Continuation-token codec — versioned, schema-checked, pickle-free.

Continuation tokens are *client-supplied bytes* (§3.5: the SDK hands them
back on every page request), so the decoder must treat them as hostile
input. The previous ``pickle.loads`` codec was arbitrary code execution on
whatever a client mailed in; this module replaces it with a fixed binary
layout over raw numpy buffers:

    token  := MAGIC(4) | VERSION(u16) | NFIELD(u16) | field* | CRC32(u32)
    field  := klen(u16) | key(utf-8) | dtype(u8) | ndim(u8) | dim(u32)*ndim
              | raw little-endian C-order array bytes

Every stage validates: magic + version window (over-versioned tokens from
a future build are rejected, not guessed at), CRC over the whole prefix,
an allow-listed dtype table, bounded field counts/array sizes, exact
length consumption, and finally a field-level schema check that the
decoded arrays assemble into a well-formed ``PagedQueryState`` (consistent
beam/backup widths, aligned buffers, scalar shapes). Anything off raises
``ContinuationError`` — the service maps it to a client error, never a
crash or an exec.
"""
from __future__ import annotations

import struct
import zlib
from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..partition.fanout import PagedQueryState, PartitionPageCursor
from ..core import paginate as pgmod

MAGIC = b"CPGT"  # Cosmos PaGination Token
TOKEN_VERSION = 1
_MAX_FIELDS = 4096
_MAX_KEY = 128
_MAX_ELEMS = 1 << 24  # per-array bound: a token must not be a memory bomb

# allow-listed dtypes, explicit little-endian so tokens are portable
_DTYPES = {
    0: np.dtype("<i4"),
    1: np.dtype("<i8"),
    2: np.dtype("<f4"),
    3: np.dtype("<f8"),
    4: np.dtype("u1"),
    5: np.dtype("<u4"),
    6: np.dtype("?"),
}
_DTYPE_CODES = {v: k for k, v in _DTYPES.items()}


class ContinuationError(ValueError):
    """The token is malformed, tampered with, or from an incompatible
    version/topology — reject the page request."""


# ---------------------------------------------------------------------------
# wire layer: {key: ndarray} <-> bytes
# ---------------------------------------------------------------------------


def _canonical(a: np.ndarray) -> np.ndarray:
    a = np.asarray(a)  # NOT ascontiguousarray: that would promote 0-d to 1-d
    if not a.flags["C_CONTIGUOUS"]:
        a = np.copy(a, order="C")
    dt = a.dtype.newbyteorder("<") if a.dtype.byteorder == ">" else a.dtype
    return a.astype(dt, copy=False)


def encode_arrays(fields: dict[str, np.ndarray]) -> bytes:
    if len(fields) > _MAX_FIELDS:
        raise ContinuationError(f"too many fields ({len(fields)})")
    out = [MAGIC, struct.pack("<HH", TOKEN_VERSION, len(fields))]
    for key, arr in fields.items():
        kb = key.encode("utf-8")
        arr = _canonical(np.asarray(arr))
        code = _DTYPE_CODES.get(arr.dtype)
        if code is None:
            raise ContinuationError(f"dtype {arr.dtype} not in token schema")
        out.append(struct.pack("<H", len(kb)))
        out.append(kb)
        out.append(struct.pack("<BB", code, arr.ndim))
        out.append(struct.pack(f"<{arr.ndim}I", *arr.shape))
        out.append(arr.tobytes())
    payload = b"".join(out)
    return payload + struct.pack("<I", zlib.crc32(payload) & 0xFFFFFFFF)


def decode_arrays(token: bytes) -> dict[str, np.ndarray]:
    if not isinstance(token, (bytes, bytearray)):
        raise ContinuationError("token must be bytes")
    token = bytes(token)
    if len(token) < 12 or token[:4] != MAGIC:
        raise ContinuationError("not a continuation token (bad magic)")
    body, (crc,) = token[:-4], struct.unpack("<I", token[-4:])
    if zlib.crc32(body) & 0xFFFFFFFF != crc:
        raise ContinuationError("token checksum mismatch (tampered/truncated)")
    version, nfields = struct.unpack("<HH", body[4:8])
    if version < 1 or version > TOKEN_VERSION:
        raise ContinuationError(
            f"unsupported token version {version} (this build speaks "
            f"≤ {TOKEN_VERSION})"
        )
    if nfields > _MAX_FIELDS:
        raise ContinuationError(f"too many fields ({nfields})")

    fields: dict[str, np.ndarray] = {}
    off = 8
    for _ in range(nfields):
        if off + 2 > len(body):
            raise ContinuationError("truncated field header")
        (klen,) = struct.unpack_from("<H", body, off)
        off += 2
        if klen == 0 or klen > _MAX_KEY or off + klen + 2 > len(body):
            raise ContinuationError("bad field key")
        key = body[off : off + klen].decode("utf-8", errors="strict")
        off += klen
        code, ndim = struct.unpack_from("<BB", body, off)
        off += 2
        if code not in _DTYPES or ndim > 2:
            raise ContinuationError(f"field {key!r}: bad dtype/ndim")
        if off + 4 * ndim > len(body):
            raise ContinuationError("truncated shape")
        shape = struct.unpack_from(f"<{ndim}I", body, off)
        off += 4 * ndim
        dtype = _DTYPES[code]
        # python-int product: a crafted (huge, huge) shape must hit THIS
        # bound, not wrap an int64 and escape into a raw numpy error
        n_elem = 1
        for dim in shape:
            n_elem *= int(dim)
        if n_elem > _MAX_ELEMS:
            raise ContinuationError(f"field {key!r}: array too large")
        nbytes = n_elem * dtype.itemsize
        if off + nbytes > len(body):
            raise ContinuationError(f"field {key!r}: truncated data")
        arr = np.frombuffer(body, dtype=dtype, count=n_elem, offset=off)
        fields[key] = arr.reshape(shape).copy()
        off += nbytes
    if off != len(body):
        raise ContinuationError("trailing bytes after last field")
    return fields


# ---------------------------------------------------------------------------
# schema layer: PagedQueryState <-> {key: ndarray}
# ---------------------------------------------------------------------------

_STATE_SCHEMA = (
    # (field, dtype, rank group) — groups must agree on length within a state
    ("best_ids", np.int32, "L"),
    ("best_dists", np.float32, "L"),
    ("best_expanded", np.bool_, "L"),
    ("backup_ids", np.int32, "B"),
    ("backup_dists", np.float32, "B"),
    ("backup_expanded", np.bool_, "B"),
    ("bitmap", np.uint32, "W"),
    ("hops", np.int32, None),
    ("cmps", np.int32, None),
    ("exp", np.int32, None),
    ("dropped", np.int32, None),
)


def encode_continuation(pstate: PagedQueryState) -> bytes:
    fields: dict[str, np.ndarray] = {
        "shard_fp": np.int64(pstate.shard_fp),
        "emit_hwm": np.float32(pstate.emit_hwm),
        "pages": np.int32(pstate.pages),
        "n_parts": np.int32(len(pstate.cursors)),
    }
    for i, cur in enumerate(pstate.cursors):
        pre = f"p{i}/"
        fields[pre + "pid"] = np.int32(cur.pid)
        fields[pre + "exhausted"] = np.uint8(cur.exhausted)
        fields[pre + "fetch_hwm"] = np.float32(cur.fetch_hwm)
        fields[pre + "buf_ids"] = np.asarray(cur.buf_ids, np.int64)
        fields[pre + "buf_dists"] = np.asarray(cur.buf_dists, np.float32)
        if cur.state is not None:
            for name, dtype, _ in _STATE_SCHEMA:
                fields[pre + "st/" + name] = np.asarray(
                    getattr(cur.state, name), dtype
                )
    return encode_arrays(fields)


def _take(fields: dict, key: str, dtype, ndim: int) -> np.ndarray:
    if key not in fields:
        raise ContinuationError(f"missing field {key!r}")
    arr = fields.pop(key)
    if arr.dtype != np.dtype(dtype) or arr.ndim != ndim:
        raise ContinuationError(
            f"field {key!r}: expected {np.dtype(dtype).name} rank-{ndim}, "
            f"got {arr.dtype.name} rank-{arr.ndim}"
        )
    return arr


def decode_continuation(token: bytes) -> PagedQueryState:
    """Parse + schema-check a client token into a ``PagedQueryState``.
    Topology binding (shard fingerprint, partition ids, bitmap widths) is
    the service's job — it knows the current routing."""
    fields = decode_arrays(token)
    shard_fp = int(_take(fields, "shard_fp", np.int64, 0))
    emit_hwm = float(_take(fields, "emit_hwm", np.float32, 0))
    if np.isnan(emit_hwm):
        raise ContinuationError("emit high-water mark is NaN")
    pages = int(_take(fields, "pages", np.int32, 0))
    n_parts = int(_take(fields, "n_parts", np.int32, 0))
    if not 1 <= n_parts <= 4096:
        raise ContinuationError(f"implausible partition count {n_parts}")
    if pages < 0:
        raise ContinuationError("negative page count")

    cursors: list[PartitionPageCursor] = []
    for i in range(n_parts):
        pre = f"p{i}/"
        pid = int(_take(fields, pre + "pid", np.int32, 0))
        exhausted = bool(_take(fields, pre + "exhausted", np.uint8, 0))
        fetch_hwm = float(_take(fields, pre + "fetch_hwm", np.float32, 0))
        if np.isnan(fetch_hwm):
            raise ContinuationError(f"p{i}: fetch high-water mark is NaN")
        buf_ids = _take(fields, pre + "buf_ids", np.int64, 1)
        buf_dists = _take(fields, pre + "buf_dists", np.float32, 1)
        if len(buf_ids) != len(buf_dists):
            raise ContinuationError(f"p{i}: buffer id/dist length mismatch")
        # the merge pops buffer heads as per-partition minima and trusts
        # fetch_hwm as the partition's ascending-stream bound — a token
        # violating either would silently break the no-repeat/no-gap
        # guarantee, so reject it here
        if len(buf_dists):
            if np.any(np.diff(buf_dists) < 0):
                raise ContinuationError(f"p{i}: buffer not ascending")
            if not np.isfinite(buf_dists).all():
                raise ContinuationError(f"p{i}: non-finite buffered distance")
            if fetch_hwm < float(buf_dists[-1]) - 1e-5:
                raise ContinuationError(
                    f"p{i}: high-water mark below buffered results"
                )
        state: Optional[pgmod.PageState] = None
        if pre + "st/best_ids" in fields:
            if exhausted:
                raise ContinuationError(
                    f"p{i}: exhausted cursor must not carry search state"
                )
            dims: dict[str, int] = {}
            st = {}
            for name, dtype, group in _STATE_SCHEMA:
                arr = _take(fields, pre + "st/" + name, dtype,
                            0 if group is None else 1)
                if group is not None:
                    dims.setdefault(group, len(arr))
                    if dims[group] != len(arr) or len(arr) == 0:
                        raise ContinuationError(
                            f"p{i}: inconsistent {group}-group length in state"
                        )
                st[name] = jnp.asarray(arr)
            state = pgmod.PageState(**st)
        elif not exhausted:
            raise ContinuationError(
                f"p{i}: live cursor is missing its search state"
            )
        cursors.append(PartitionPageCursor(
            pid=pid, state=state, buf_ids=buf_ids, buf_dists=buf_dists,
            fetch_hwm=fetch_hwm, exhausted=exhausted,
        ))
    if fields:
        raise ContinuationError(
            f"unexpected fields in token: {sorted(fields)[:4]}"
        )
    return PagedQueryState(shard_fp=shard_fp, emit_hwm=emit_hwm,
                           pages=pages, cursors=cursors)
