"""Labeled metrics registry — per-tenant / per-stage cost attribution.

``EngineMetrics`` (serve/metrics.py) keeps engine-global aggregates; this
module adds the *labeled* layer the paper's cost analysis needs: which
tenant spent which RU, and which lifecycle stage each millisecond of
latency went to. Two primitive kinds, Prometheus-style:

  * counters — monotonically increasing floats keyed by a label set
    (e.g. ``serve_ru_total{tenant="t0",op="query"}``)
  * histograms — the bounded streaming ``Histogram`` from serve/metrics,
    one per label set (e.g. ``serve_latency_ms{tenant="t0"}``)

The registry is deliberately schema-free — families are created on first
touch — but label *names* are locked per family on first use so a typo'd
label key fails loudly rather than silently forking a series.

Conservation contracts (asserted in tests/test_observability.py):

  * RU:     Σ serve_ru_total{op=query|page} == EngineMetrics.ru_query_total
            Σ serve_ru_total{op=hedge}      == EngineMetrics.hedge_ru_total
            Σ serve_ru_total{op=ingest}     == EngineMetrics.ru_ingest_total
            and per-tenant query+page+hedge == that tenant's governor
            ``consumed`` (refunded reservations never enter the registry).
  * time:   Σ serve_stage_ms{stage=queue|lane} totals ==
            Σ serve_latency_ms totals (stages tile the request interval).

``to_prometheus_text`` renders the standard text exposition format
(counters, and summary-style quantiles for histograms) for the
``launch/serve.py --metrics-out`` exporter.
"""
from __future__ import annotations

from typing import Optional

from .metrics import Histogram


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels))


class RollupWindow:
    """Differencing window over cumulative rollups (the (count, sum)
    pairs ``observability_summary`` reports per stage/tenant). The
    registry's histograms never decay, so cumulative percentiles go
    sticky under changing load; count/sum *deltas* between reads window
    exactly. ``delta`` returns ``max(new - prev, 0)`` — a value that
    shrank means a metrics-epoch reset (``engine.reset_metrics`` at a
    warmup boundary), so the window re-bases at the new value instead of
    reporting a negative rate. The control plane (serve/policy.py) runs
    on these windows."""

    __slots__ = ("_prev",)

    def __init__(self):
        self._prev: dict = {}

    def delta(self, key: str, value: float) -> float:
        prev = self._prev.get(key, 0.0)
        self._prev[key] = value
        return value - prev if value >= prev else 0.0

    def reset(self) -> None:
        self._prev.clear()


class _Family:
    __slots__ = ("name", "kind", "labelnames", "series")

    def __init__(self, name: str, kind: str, labelnames: tuple):
        self.name = name
        self.kind = kind  # "counter" | "histogram"
        self.labelnames = labelnames
        self.series: dict = {}  # label-value tuple -> float | Histogram


class MetricsRegistry:
    """On-demand families of labeled counters and streaming histograms."""

    def __init__(self):
        self._families: dict[str, _Family] = {}

    def _family(self, name: str, kind: str, labels: dict) -> _Family:
        names = _label_key(labels)
        fam = self._families.get(name)
        if fam is None:
            fam = _Family(name, kind, names)
            self._families[name] = fam
        else:
            if fam.kind != kind:
                raise ValueError(f"metric {name!r} is a {fam.kind}, not a {kind}")
            if fam.labelnames != names:
                raise ValueError(
                    f"metric {name!r} label names {fam.labelnames} != {names}")
        return fam

    @staticmethod
    def _values(fam: _Family, labels: dict) -> tuple:
        return tuple(str(labels[k]) for k in fam.labelnames)

    # ------------------------------------------------------------------
    # write path
    # ------------------------------------------------------------------
    def inc(self, name: str, value: float = 1.0, **labels):
        fam = self._family(name, "counter", labels)
        key = self._values(fam, labels)
        fam.series[key] = fam.series.get(key, 0.0) + float(value)

    def observe(self, name: str, value: float, **labels):
        fam = self._family(name, "histogram", labels)
        key = self._values(fam, labels)
        h = fam.series.get(key)
        if h is None:
            h = fam.series[key] = Histogram()
        h.observe(value)

    # ------------------------------------------------------------------
    # read path
    # ------------------------------------------------------------------
    def counter_value(self, name: str, **labels) -> float:
        fam = self._families.get(name)
        if fam is None:
            return 0.0
        return float(fam.series.get(self._values(fam, labels), 0.0))

    def histogram(self, name: str, **labels) -> Optional[Histogram]:
        fam = self._families.get(name)
        if fam is None:
            return None
        return fam.series.get(self._values(fam, labels))

    def total(self, name: str, **match) -> float:
        """Sum of a counter family over every series matching ``match``
        (a subset of the family's labels); 0.0 for unknown families."""
        fam = self._families.get(name)
        if fam is None:
            return 0.0
        idx = [(fam.labelnames.index(k), str(v)) for k, v in match.items()]
        tot = 0.0
        for key, v in fam.series.items():
            if all(key[i] == want for i, want in idx):
                tot += v
        return tot

    def label_values(self, name: str, label: str) -> list:
        """Sorted distinct values one label takes across a family."""
        fam = self._families.get(name)
        if fam is None or label not in fam.labelnames:
            return []
        i = fam.labelnames.index(label)
        return sorted({key[i] for key in fam.series})

    def series(self, name: str) -> list:
        """[(labels_dict, value_or_histogram)] for one family."""
        fam = self._families.get(name)
        if fam is None:
            return []
        return [(dict(zip(fam.labelnames, key)), v)
                for key, v in sorted(fam.series.items())]

    # ------------------------------------------------------------------
    # exporters
    # ------------------------------------------------------------------
    def summary(self) -> dict:
        """JSON-friendly dump: counters as floats, histograms as stats."""
        out: dict = {}
        for name, fam in sorted(self._families.items()):
            fam_out = {}
            for key, v in sorted(fam.series.items()):
                label = ",".join(f"{k}={val}" for k, val
                                 in zip(fam.labelnames, key)) or "_"
                if fam.kind == "counter":
                    fam_out[label] = v
                else:
                    fam_out[label] = dict(
                        count=v.count, sum=v.sum, mean=v.mean(),
                        p50=v.percentile(50), p95=v.percentile(95),
                        p99=v.percentile(99), max=v.max)
            out[name] = fam_out
        return out

    def to_prometheus_text(self) -> str:
        """Standard Prometheus text exposition. Histograms render as
        summaries (quantile series + ``_sum``/``_count``)."""
        lines = []
        for name, fam in sorted(self._families.items()):
            if fam.kind == "counter":
                lines.append(f"# TYPE {name} counter")
                for key, v in sorted(fam.series.items()):
                    lines.append(f"{name}{_fmt_labels(fam.labelnames, key)} "
                                 f"{_fmt_num(v)}")
            else:
                lines.append(f"# TYPE {name} summary")
                for key, h in sorted(fam.series.items()):
                    for q in (0.5, 0.95, 0.99):
                        lbl = _fmt_labels(fam.labelnames + ("quantile",),
                                          key + (f"{q:g}",))
                        lines.append(f"{name}{lbl} "
                                     f"{_fmt_num(h.percentile(q * 100))}")
                    base = _fmt_labels(fam.labelnames, key)
                    lines.append(f"{name}_sum{base} {_fmt_num(h.sum)}")
                    lines.append(f"{name}_count{base} {h.count}")
        return "\n".join(lines) + ("\n" if lines else "")


def _escape(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")


def _fmt_labels(names: tuple, values: tuple) -> str:
    if not names:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in zip(names, values))
    return "{" + inner + "}"


def _fmt_num(v: float) -> str:
    return repr(float(v))
