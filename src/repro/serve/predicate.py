"""Declarative predicate AST — the WHERE clause of the paper's query model.

The paper's interface is declarative: ``SELECT TOP k ... WHERE <predicate>
ORDER BY VectorDistance(...)`` (§3.3, §3.5, Fig 9), with scalar predicates
answered from index terms in the Bw-Tree, not by scanning documents. This
module is the client-side half of that contract: a small combinator
language

    F.eq("label", 3)                        equality on one indexed path
    F.in_("label", [3, 5])                  membership
    F.range("price", 10, 99)                inclusive range
    F.and_(p, q) / (p & q)                  conjunction
    F.or_(p, q) / (p | q)                   disjunction
    F.not_(p) / (~p)                        complement (over present docs)

whose nodes are **canonicalizable** (commutative operators sort their
children, ``in_`` sorts + dedups, double negation cancels), **hashable**
(`key()` is a deterministic byte encoding of the canonical form — two
semantically-identical predicates batch together in the serving engine's
micro-batcher), and **serializable** (`to_obj()`/`from_obj()` round-trip
through JSON-safe structures).

The server-side half is ``store.props.PropertyTermIndex``: each node
compiles to a few bitmap AND/OR/NOT operations over per-(path, value)
posting bitmaps — ``compile_words`` below — with **zero document scans**.
``matches(doc)`` is the host-side reference semantics (used by tests and
the legacy-callable comparison paths, never by the compiled hot path).

Semantics notes:
  * leaf predicates match only documents that HAVE the path with a
    matching value; ``not_`` complements within the set of present
    documents of a partition (absent-field docs match ``~F.eq(p, v)``);
  * paths address nested fields with ``/`` (``"meta/genre"``); list
    elements index as multi-valued terms (Cosmos array semantics), so
    ``F.eq("tags", "x")`` matches docs whose ``tags`` list contains "x";
  * ``range`` bounds are inclusive on both ends and only match values
    comparable to the bounds (a string value never matches a numeric
    range).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator, Sequence

import numpy as np

from ..store.terms import value_token

Scalar = (str, int, float, bool, type(None))


def _check_scalar(v: Any) -> Any:
    if not isinstance(v, Scalar):
        raise TypeError(
            f"predicate values must be scalars, got {type(v).__name__}"
        )
    return v


class Predicate:
    """Base combinator node. Immutable; equality/hash follow the canonical
    byte key so semantically-identical predicates coalesce in dict/set
    keys (and therefore in the engine's micro-batch groups)."""

    __slots__ = ("_key",)

    # -- combinators -----------------------------------------------------
    def __and__(self, other: "Predicate") -> "Predicate":
        return F.and_(self, other)

    def __or__(self, other: "Predicate") -> "Predicate":
        return F.or_(self, other)

    def __invert__(self) -> "Predicate":
        return F.not_(self)

    # -- identity --------------------------------------------------------
    def key(self) -> bytes:
        """Canonical byte encoding (cached): the batching/caching key."""
        k = getattr(self, "_key", None)
        if k is None:
            k = self.canonical()._encode()
            object.__setattr__(self, "_key", k)
        return k

    def __eq__(self, other) -> bool:
        return isinstance(other, Predicate) and self.key() == other.key()

    def __hash__(self) -> int:
        return hash(self.key())

    # -- interface (per node) -------------------------------------------
    def canonical(self) -> "Predicate":
        return self

    def _encode(self) -> bytes:
        raise NotImplementedError

    def matches(self, doc: dict) -> bool:
        raise NotImplementedError

    def compile_words(self, idx) -> np.ndarray:
        """Packed uint32 bitmap over the index's slots; ``idx`` is a
        ``store.props.PropertyTermIndex`` (or anything exposing its
        ``posting`` / ``values_for`` / ``universe`` / ``zeros``)."""
        raise NotImplementedError

    def to_obj(self):
        raise NotImplementedError


def _resolve(doc: dict, path: str) -> list:
    """All scalar leaf values at ``path`` ('/'-separated; lists fan out)."""
    nodes = [doc]
    for part in path.split("/"):
        nxt = []
        for n in nodes:
            if isinstance(n, dict) and part in n:
                nxt.append(n[part])
        nodes = nxt
    out = []
    for n in nodes:
        if isinstance(n, list):
            out.extend(x for x in n if isinstance(x, Scalar))
        elif isinstance(n, Scalar):
            out.append(n)
    return out


def _cmp_in_range(v, lo, hi) -> bool:
    try:
        return bool(lo <= v <= hi)
    except TypeError:
        return False


@dataclasses.dataclass(frozen=True, eq=False, repr=False)
class Eq(Predicate):
    path: str
    value: Any
    __slots__ = ("path", "value")

    def _encode(self) -> bytes:
        return b"(eq " + self.path.encode() + b" " + value_token(self.value) + b")"

    def matches(self, doc: dict) -> bool:
        t = value_token(self.value)
        return any(value_token(v) == t for v in _resolve(doc, self.path))

    def compile_words(self, idx) -> np.ndarray:
        w = idx.posting(self.path, self.value)
        return w.copy() if w is not None else idx.zeros()

    def to_obj(self):
        return ["eq", self.path, self.value]

    def __repr__(self):
        return f"F.eq({self.path!r}, {self.value!r})"


@dataclasses.dataclass(frozen=True, eq=False, repr=False)
class In(Predicate):
    path: str
    values: tuple
    __slots__ = ("path", "values")

    def canonical(self) -> Predicate:
        uniq = {value_token(v): v for v in self.values}
        if len(uniq) == 1:
            return Eq(self.path, next(iter(uniq.values())))
        ordered = tuple(uniq[t] for t in sorted(uniq))
        return In(self.path, ordered)

    def _encode(self) -> bytes:
        toks = b",".join(value_token(v) for v in self.values)
        return b"(in " + self.path.encode() + b" " + toks + b")"

    def matches(self, doc: dict) -> bool:
        present = {value_token(v) for v in _resolve(doc, self.path)}
        return any(value_token(v) in present for v in self.values)

    def compile_words(self, idx) -> np.ndarray:
        out = idx.zeros()
        for v in self.values:
            w = idx.posting(self.path, v)
            if w is not None:
                out |= w
        return out

    def to_obj(self):
        return ["in", self.path, list(self.values)]

    def __repr__(self):
        return f"F.in_({self.path!r}, {list(self.values)!r})"


@dataclasses.dataclass(frozen=True, eq=False, repr=False)
class Range(Predicate):
    path: str
    lo: Any
    hi: Any
    __slots__ = ("path", "lo", "hi")

    def _encode(self) -> bytes:
        return (b"(range " + self.path.encode() + b" " + value_token(self.lo)
                + b" " + value_token(self.hi) + b")")

    def matches(self, doc: dict) -> bool:
        return any(
            _cmp_in_range(v, self.lo, self.hi)
            for v in _resolve(doc, self.path)
        )

    def compile_words(self, idx) -> np.ndarray:
        out = idx.zeros()
        for v, w in idx.values_for(self.path):
            if _cmp_in_range(v, self.lo, self.hi):
                out |= w
        return out

    def to_obj(self):
        return ["range", self.path, self.lo, self.hi]

    def __repr__(self):
        return f"F.range({self.path!r}, {self.lo!r}, {self.hi!r})"


def _flatten(kind, children: Sequence[Predicate]) -> Iterator[Predicate]:
    for c in children:
        c = c.canonical()
        if isinstance(c, kind):
            yield from c.children
        else:
            yield c


@dataclasses.dataclass(frozen=True, eq=False, repr=False)
class And(Predicate):
    children: tuple
    __slots__ = ("children",)

    def canonical(self) -> Predicate:
        flat = {c._encode(): c for c in _flatten(And, self.children)}
        if len(flat) == 1:
            return next(iter(flat.values()))
        return And(tuple(flat[k] for k in sorted(flat)))

    def _encode(self) -> bytes:
        return b"(and " + b" ".join(c._encode() for c in self.children) + b")"

    def matches(self, doc: dict) -> bool:
        return all(c.matches(doc) for c in self.children)

    def compile_words(self, idx) -> np.ndarray:
        out = self.children[0].compile_words(idx)
        for c in self.children[1:]:
            out &= c.compile_words(idx)
        return out

    def to_obj(self):
        return ["and", [c.to_obj() for c in self.children]]

    def __repr__(self):
        return "(" + " & ".join(repr(c) for c in self.children) + ")"


@dataclasses.dataclass(frozen=True, eq=False, repr=False)
class Or(Predicate):
    children: tuple
    __slots__ = ("children",)

    def canonical(self) -> Predicate:
        flat = {c._encode(): c for c in _flatten(Or, self.children)}
        if len(flat) == 1:
            return next(iter(flat.values()))
        return Or(tuple(flat[k] for k in sorted(flat)))

    def _encode(self) -> bytes:
        return b"(or " + b" ".join(c._encode() for c in self.children) + b")"

    def matches(self, doc: dict) -> bool:
        return any(c.matches(doc) for c in self.children)

    def compile_words(self, idx) -> np.ndarray:
        out = self.children[0].compile_words(idx)
        for c in self.children[1:]:
            out |= c.compile_words(idx)
        return out

    def to_obj(self):
        return ["or", [c.to_obj() for c in self.children]]

    def __repr__(self):
        return "(" + " | ".join(repr(c) for c in self.children) + ")"


@dataclasses.dataclass(frozen=True, eq=False, repr=False)
class Not(Predicate):
    child: Predicate
    __slots__ = ("child",)

    def canonical(self) -> Predicate:
        c = self.child.canonical()
        if isinstance(c, Not):
            return c.child
        return Not(c)

    def _encode(self) -> bytes:
        return b"(not " + self.child._encode() + b")"

    def matches(self, doc: dict) -> bool:
        return not self.child.matches(doc)

    def compile_words(self, idx) -> np.ndarray:
        return idx.universe() & ~self.child.compile_words(idx)

    def to_obj(self):
        return ["not", self.child.to_obj()]

    def __repr__(self):
        return f"~{self.child!r}"


def _check_path(path: str) -> str:
    """Reject paths the ingest side never indexes: a predicate over them
    would silently compile to an always-empty bitmap while ``matches()``
    (and the legacy callable path) would match — a parity break better
    surfaced at construction time."""
    path = str(path)
    if path in NON_INDEXED_PATHS:
        raise ValueError(
            f"path {path!r} is not property-indexed (it is the document "
            f"key — fetch by id instead of filtering on it)"
        )
    return path


class F:
    """Constructor namespace: ``F.eq/F.in_/F.range/F.and_/F.or_/F.not_``."""

    @staticmethod
    def eq(path: str, value: Any) -> Predicate:
        return Eq(_check_path(path), _check_scalar(value))

    @staticmethod
    def in_(path: str, values) -> Predicate:
        vals = tuple(_check_scalar(v) for v in values)
        if not vals:
            raise ValueError("F.in_ needs at least one value")
        return In(_check_path(path), vals)

    @staticmethod
    def range(path: str, lo: Any, hi: Any) -> Predicate:
        return Range(_check_path(path), _check_scalar(lo), _check_scalar(hi))

    @staticmethod
    def and_(*preds: Predicate) -> Predicate:
        if not preds:
            raise ValueError("F.and_ needs at least one predicate")
        return And(tuple(preds))

    @staticmethod
    def or_(*preds: Predicate) -> Predicate:
        if not preds:
            raise ValueError("F.or_ needs at least one predicate")
        return Or(tuple(preds))

    @staticmethod
    def not_(pred: Predicate) -> Predicate:
        return Not(pred)


def from_obj(obj) -> Predicate:
    """Inverse of ``Predicate.to_obj`` (wire format for SDK transport)."""
    kind = obj[0]
    if kind == "eq":
        return F.eq(obj[1], obj[2])
    if kind == "in":
        return F.in_(obj[1], obj[2])
    if kind == "range":
        return F.range(obj[1], obj[2], obj[3])
    if kind == "and":
        return F.and_(*(from_obj(c) for c in obj[1]))
    if kind == "or":
        return F.or_(*(from_obj(c) for c in obj[1]))
    if kind == "not":
        return F.not_(from_obj(obj[1]))
    raise ValueError(f"unknown predicate node kind {kind!r}")


# ---------------------------------------------------------------------------
# document-side term extraction (ingest path)
# ---------------------------------------------------------------------------

NON_INDEXED_PATHS = frozenset({"id"})


def property_items(doc: dict) -> tuple:
    """Extract the (path, value) property terms a document contributes to
    the inverted property-term index: every scalar leaf, nested paths
    joined with '/', list elements as multi-valued terms. The document key
    (``id``) is not a predicate term — it is served by point lookups."""
    out: list[tuple[str, Any]] = []

    def walk(prefix: str, node):
        if isinstance(node, dict):
            for k, v in node.items():
                p = f"{prefix}/{k}" if prefix else str(k)
                if p in NON_INDEXED_PATHS:
                    continue
                walk(p, v)
        elif isinstance(node, list):
            for v in node:
                if isinstance(v, Scalar):
                    out.append((prefix, v))
        elif isinstance(node, Scalar):
            out.append((prefix, node))

    walk("", doc)
    return tuple(out)
