"""VectorCollectionService — the user-facing query layer (§3.5).

Ties together everything the paper composes: JSON-ish documents with a
declared vector path, automatic index-term generation on ingest, the
VectorDistance query function with the planner's selectivity routing
(brute force / Q-Flat / graph ± filters), paginated queries with
client-side continuation tokens (the 5-second-preemption model), sharded
DiskANN for multi-tenancy, and cross-partition fan-out with RU accounting.

This is the host-side service; the device-parallel path for the same
operation is `repro.partition.fanout.distributed_search_fn`.
"""
from __future__ import annotations

import dataclasses
import pickle
from typing import Any, Callable, Optional, Sequence

import numpy as np

from ..core import GraphConfig
from ..core import flat as fmod
from ..partition import Collection, CollectionConfig, ReplicaSet
from ..partition.fanout import fanout_search, merge_topk


@dataclasses.dataclass
class VectorQuery:
    vector: np.ndarray
    k: int = 10
    filter: Optional[Callable[[dict], bool]] = None  # predicate over docs
    search_list_multiplier: float = 5.0  # searchListSizeMultiplier
    exact: bool = False  # VectorDistance(..., true) → brute force
    shard_key: Any = None  # route to a sharded-DiskANN tenant index


@dataclasses.dataclass
class QueryResult:
    ids: np.ndarray
    dists: np.ndarray
    ru: float
    plan: str
    continuation: Optional[bytes] = None


class VectorCollectionService:
    """A collection with vector indexing enabled on one path."""

    def __init__(
        self,
        dim: int,
        graph: Optional[GraphConfig] = None,
        max_vectors_per_partition: int = 100_000,
        initial_partitions: int = 1,
        replicas: int = 4,
        shard_key_path: Optional[str] = None,
    ):
        graph = graph or GraphConfig(capacity=max_vectors_per_partition + 1024)
        self.cfg = CollectionConfig(
            dim=dim,
            graph=graph,
            max_vectors_per_partition=max_vectors_per_partition,
            initial_partitions=initial_partitions,
            shard_key_path=shard_key_path,
        )
        self.collection = Collection(self.cfg)
        self.replica_sets = [
            ReplicaSet(p, num_replicas=replicas) for p in self.collection.partitions
        ]
        self.docs: dict[int, dict] = {}  # document store (JSON side)
        self.shard_key_path = shard_key_path
        # sharded DiskANN: tenant value → per-tenant collection
        self._tenant_collections: dict[Any, Collection] = {}

    # ------------------------------------------------------------------
    # ingest
    # ------------------------------------------------------------------
    def upsert(self, documents: Sequence[dict], vectors: np.ndarray,
               partition_keys: Optional[Sequence] = None) -> float:
        """Insert documents (dicts with 'id') + their embedding vectors."""
        ids = [int(d["id"]) for d in documents]
        pks = partition_keys or ids
        for d in documents:
            self.docs[int(d["id"])] = d
        ru = self.collection.insert(ids, pks, np.asarray(vectors, np.float32))
        if self.shard_key_path:
            groups: dict[Any, list[int]] = {}
            for i, d in enumerate(documents):
                groups.setdefault(d.get(self.shard_key_path), []).append(i)
            for key, rows in groups.items():
                ru += self._tenant(key).insert(
                    [ids[i] for i in rows], [pks[i] for i in rows],
                    np.asarray(vectors, np.float32)[rows],
                )
        return ru

    def delete(self, doc_ids: Sequence[int]) -> float:
        pks = [d for d in doc_ids]
        shard_groups: dict[Any, list[int]] = {}
        for d in doc_ids:
            doc = self.docs.pop(int(d), None)
            if doc is not None and self.shard_key_path:
                shard_groups.setdefault(doc.get(self.shard_key_path), []).append(int(d))
        ru = self.collection.delete(doc_ids, pks)
        for key, ids in shard_groups.items():
            ru += self._tenant(key).delete(ids, ids)
        return ru

    def _tenant(self, key) -> Collection:
        if key not in self._tenant_collections:
            g = self.cfg.graph
            self._tenant_collections[key] = Collection(
                dataclasses.replace(self.cfg, initial_partitions=1)
            )
        return self._tenant_collections[key]

    # ------------------------------------------------------------------
    # query (§3.5 routing)
    # ------------------------------------------------------------------
    def query(self, q: VectorQuery) -> QueryResult:
        qv = np.asarray(q.vector, np.float32)[None, :]
        target = (
            self._tenant(q.shard_key).partitions
            if q.shard_key is not None and self.shard_key_path
            else self.collection.partitions
        )

        if q.exact:
            ids_l, d_l, ru = [], [], 0.0
            for p in target:
                pv = p.providers
                import jax.numpy as jnp
                ids, dists = fmod.brute_force(
                    jnp.asarray(qv), jnp.asarray(pv.vectors), jnp.asarray(pv.live),
                    k=q.k, metric=p.index.cfg.metric,
                )
                ids_l.append(p.index._to_doc_ids(np.asarray(ids)))
                d_l.append(np.asarray(dists))
                ru += 0.5 * p.num_docs * 0.0125  # full scan in quantized-ish cost
            ids, dists = merge_topk(ids_l, d_l, q.k)
            return QueryResult(ids[0], dists[0], ru, "exact")

        if q.filter is not None:
            ids_l, d_l, ru = [], [], 0.0
            plan = ""
            for p in target:
                mask = np.zeros(p.index.cfg.capacity, bool)
                for doc, slot in p.index.doc_to_slot.items():
                    if doc in self.docs and q.filter(self.docs[doc]):
                        mask[slot] = True
                ids, dists, stats = p.index.filtered_search(qv, q.k, mask)
                ids_l.append(ids)
                d_l.append(dists)
                plan = stats.plan
                ru += p.providers.meter.ru(_stats_counters(stats))
            ids, dists = merge_topk(ids_l, d_l, q.k)
            return QueryResult(ids[0], dists[0], ru, f"filtered:{plan}")

        L = max(q.k, int(round(q.search_list_multiplier * q.k)))
        ids, dists, info = fanout_search(target, qv, q.k, L=L)
        return QueryResult(ids[0], dists[0], info["ru_total"], "graph")

    # ------------------------------------------------------------------
    # pagination / continuation tokens (§3.5 "Continuations")
    # ------------------------------------------------------------------
    def query_page(self, q: VectorQuery, continuation: Optional[bytes] = None,
                   page_size: int = 10) -> QueryResult:
        """Paginated query over partition 0 (single-partition pagination;
        cross-partition pagination merges client-side as in the SDK)."""
        part = self.collection.partitions[0]
        qv = np.asarray(q.vector, np.float32)
        if continuation is None:
            state = part.index.start_pagination(qv)
        else:
            state = pickle.loads(continuation)
        ids, dists, state = part.index.next_page(qv, state, k=page_size)
        token = pickle.dumps(state)
        return QueryResult(ids, dists, 0.0, "paginated", continuation=token)


def _stats_counters(stats):
    from ..store.ru import OpCounters

    return OpCounters(
        quant_reads=int(stats.cmps),
        adj_reads=int(stats.hops),
        full_reads=int(stats.full_reads),
    )
