"""VectorCollectionService — the user-facing query layer (§3.5).

Ties together everything the paper composes: JSON-ish documents with a
declared vector path, automatic index-term generation on ingest, the
VectorDistance query function with the planner's selectivity routing
(brute force / Q-Flat / graph ± filters), paginated queries with
client-side continuation tokens (the 5-second-preemption model), sharded
DiskANN for multi-tenancy, and cross-partition fan-out with RU accounting.

Since the serving PR this class is a thin façade over
``serve.vector_engine.VectorServeEngine``: every query path flows through
the engine (admission control, micro-batching, metrics, simulated clock),
and ingest rides the engine's interleaved mini-batch queue. The service
keeps what needs the document store — property-term extraction at ingest,
tenant routing, and pagination state. The engine's dispatch plane
(``EngineConfig.dispatch_mode``) gets this service's replica sets wired
in, so lane health routes reads and dead replicas re-probe.

This is the host-side service; the device-parallel path for the same
operation is `repro.partition.fanout.SpmdFanout` (the engine's
``dispatch_mode="spmd"``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import numpy as np

from ..core import GraphConfig
from ..core.graph import bitmap_words
from ..core.index import PAGE_BACKUP_CAP
from ..partition import Collection, CollectionConfig, ReplicaSet
from ..partition.fanout import (compile_partition_filter,
                                paged_fanout_fingerprint, paged_fanout_search,
                                start_paged_fanout)
from .continuation import (ContinuationError, decode_continuation,
                           encode_continuation)
from .predicate import Predicate, property_items
from .vector_engine import EngineConfig, ServeRequest, Throttled, VectorServeEngine


class DeadlineExceeded(Exception):
    """408-style abandonment: the request's deadline expired while it was
    still queued; no work was done and the RU reservation was refunded."""

    def __init__(self, tenant: Any, waited_ms: float):
        super().__init__(
            f"tenant {tenant!r} request abandoned after waiting "
            f"{waited_ms:.3f} ms past its deadline"
        )
        self.tenant = tenant
        self.waited_ms = waited_ms


@dataclasses.dataclass
class VectorQuery:
    vector: np.ndarray
    k: int = 10
    # WHERE clause: a declarative ``serve.predicate.Predicate``, compiled
    # to index-term bitmaps and batched through the engine. Opaque
    # callables are rejected with ``ValueError`` — the legacy host path
    # (``filtered-legacy[...]`` plans) is gone.
    filter: Optional[Predicate] = None
    search_list_multiplier: float = 5.0  # searchListSizeMultiplier
    exact: bool = False  # VectorDistance(..., true) → brute force
    shard_key: Any = None  # route to a sharded-DiskANN tenant index
    tenant: Any = "default"  # RU-admission principal (429s when over budget)
    beam_width: Optional[int] = None  # paged-path W override; None → engine cfg
    # queue-abandonment budget (ms): expires → DeadlineExceeded (408),
    # reservation refunded. None → EngineConfig.default_deadline_ms.
    deadline_ms: Optional[float] = None


@dataclasses.dataclass
class QueryResult:
    ids: np.ndarray
    dists: np.ndarray
    ru: float
    plan: str
    continuation: Optional[bytes] = None
    latency_ms: float = 0.0
    # False → one or more partitions were unreachable and the results
    # merge only the survivors (see the plan's ``+degraded[pids]`` marker)
    complete: bool = True


class VectorCollectionService:
    """A collection with vector indexing enabled on one path."""

    def __init__(
        self,
        dim: int,
        graph: Optional[GraphConfig] = None,
        max_vectors_per_partition: int = 100_000,
        initial_partitions: int = 1,
        replicas: int = 4,
        shard_key_path: Optional[str] = None,
        engine_cfg: EngineConfig = EngineConfig(),
        resident_frac: Optional[float] = None,
        vector_page_size: int = 64,
    ):
        graph = graph or GraphConfig(capacity=max_vectors_per_partition + 1024)
        self.cfg = CollectionConfig(
            dim=dim,
            graph=graph,
            max_vectors_per_partition=max_vectors_per_partition,
            initial_partitions=initial_partitions,
            shard_key_path=shard_key_path,
            resident_frac=resident_frac,
            vector_page_size=vector_page_size,
        )
        self.collection = Collection(self.cfg)
        self.replica_sets = [
            ReplicaSet(p, num_replicas=replicas) for p in self.collection.partitions
        ]
        self.docs: dict[int, dict] = {}  # document store (JSON side)
        self.shard_key_path = shard_key_path
        # sharded DiskANN: tenant value → per-tenant collection
        self._tenant_collections: dict[Any, Collection] = {}
        self.engine = VectorServeEngine(
            self.collection, cfg=engine_cfg, resolver=self._partitions_for,
            replica_sets=self.replica_sets,
        )

    def _partitions_for(self, shard_key: Any):
        if shard_key is not None and self.shard_key_path:
            return self._tenant(shard_key).partitions
        return self.collection.partitions

    def set_residency(self, frac: Optional[float]) -> None:
        """Resize every partition's paged full-precision tier to hold
        ``frac`` of its vector pages (None → fully resident). Search keeps
        answering out of the always-resident PQ codes + adjacency; only
        the final-rerank page fetches see the new budget."""
        for p in self.collection.partitions:
            p.set_residency(frac)

    # ------------------------------------------------------------------
    # ingest (through the engine's interleaved mini-batch queue)
    # ------------------------------------------------------------------
    def upsert(self, documents: Sequence[dict], vectors: np.ndarray,
               partition_keys: Optional[Sequence] = None,
               tenant: Any = "default") -> float:
        """Insert documents (dicts with 'id') + their embedding vectors.
        Synchronous: enqueues chunked ingest work on the engine and drains
        it before returning (use ``upsert_async`` to leave it interleaving
        with query traffic). ``tenant`` attributes the write RU in the
        engine's per-tenant cost registry."""
        total = self.upsert_async(documents, vectors, partition_keys,
                                  tenant=tenant)
        self.engine.flush_ingest()
        return total.value

    def upsert_async(self, documents: Sequence[dict], vectors: np.ndarray,
                     partition_keys: Optional[Sequence] = None,
                     tenant: Any = "default") -> "_RUTally":
        vectors = np.asarray(vectors, np.float32)
        ids = [int(d["id"]) for d in documents]
        pks = list(partition_keys) if partition_keys is not None else ids
        tally = _RUTally()
        chunk = self.engine.cfg.ingest_chunk
        for lo in range(0, len(documents), chunk):
            hi = min(lo + chunk, len(documents))
            docs_c = list(documents[lo:hi])
            ids_c, pks_c, vecs_c = ids[lo:hi], pks[lo:hi], vectors[lo:hi]
            self.engine.submit_ingest(
                "upsert",
                lambda d=docs_c, i=ids_c, p=pks_c, v=vecs_c:
                    tally.add(self._apply_upsert(d, i, p, v)),
                len(docs_c), tenant=tenant,
            )
        return tally

    def _apply_upsert(self, documents, ids, pks, vectors) -> float:
        ru = 0.0
        if self.shard_key_path:
            # sharded-DiskANN identity includes the shard key: re-upserting
            # a doc under a different shard value MOVES it — tombstone the
            # copy in the old tenant's index first, or that tenant serves
            # the deleted/stale document forever
            for d in documents:
                old = self.docs.get(int(d["id"]))
                if old is not None:
                    old_key = old.get(self.shard_key_path)
                    if old_key != d.get(self.shard_key_path):
                        ru += self._tenant(old_key).delete_by_id([int(d["id"])])
        for d in documents:
            self.docs[int(d["id"])] = d
        # property-term extraction happens ONCE at ingest: each partition's
        # inverted PROP_TERM postings track the doc from here on, so the
        # predicate query path never has to look at the document again
        props = [property_items(d) for d in documents]
        ru += self.collection.insert(ids, pks, vectors, props=props)
        if self.shard_key_path:
            groups: dict[Any, list[int]] = {}
            for i, d in enumerate(documents):
                groups.setdefault(d.get(self.shard_key_path), []).append(i)
            for key, rows in groups.items():
                ru += self._tenant(key).insert(
                    [ids[i] for i in rows], [pks[i] for i in rows],
                    vectors[rows], props=[props[i] for i in rows],
                )
        return ru

    def delete(self, doc_ids: Sequence[int], tenant: Any = "default") -> float:
        total = self.delete_async(doc_ids, tenant=tenant)
        self.engine.flush_ingest()
        return total.value

    def delete_async(self, doc_ids: Sequence[int],
                     tenant: Any = "default") -> "_RUTally":
        tally = _RUTally()
        chunk = self.engine.cfg.ingest_chunk
        doc_ids = list(doc_ids)
        for lo in range(0, len(doc_ids), chunk):
            ids_c = doc_ids[lo:lo + chunk]
            self.engine.submit_ingest(
                "delete", lambda i=ids_c: tally.add(self._apply_delete(i)),
                len(ids_c), tenant=tenant,
            )
        return tally

    def _apply_delete(self, doc_ids: Sequence[int]) -> float:
        doc_ids = [int(d) for d in doc_ids]
        shard_groups: dict[Any, list[int]] = {}
        for d in doc_ids:
            doc = self.docs.pop(d, None)
            if doc is not None and self.shard_key_path:
                shard_groups.setdefault(doc.get(self.shard_key_path), []).append(d)
        # route by each doc's OWNING partition (which recorded the pk at
        # ingest) — deleting "by id as pk" sends custom-keyed docs to the
        # wrong partition, where the tombstone is a silent no-op
        ru = self.collection.delete_by_id(doc_ids)
        for key, ids in shard_groups.items():
            ru += self._tenant(key).delete_by_id(ids)
        return ru

    def _tenant(self, key) -> Collection:
        if key not in self._tenant_collections:
            self._tenant_collections[key] = Collection(
                dataclasses.replace(self.cfg, initial_partitions=1)
            )
        return self._tenant_collections[key]

    # ------------------------------------------------------------------
    # query (§3.5 routing — thin façade over the engine)
    # ------------------------------------------------------------------
    def query(self, q: VectorQuery) -> QueryResult:
        """Route one query through the serving engine. Raises ``Throttled``
        when the tenant is over its RU budget (the 429 path) and
        ``DeadlineExceeded`` when ``q.deadline_ms`` (or the engine's
        default) expires while the request is queued (the 408 path — the
        reservation is refunded, no partition work happens). A query that
        merged only a subset of partitions (the rest down/faulted)
        returns ``complete=False`` rather than failing; only every
        partition failing raises (``partition.fanout.AllPartitionsFailed``).

        ``q.filter`` must be a declarative ``Predicate`` (or None): it
        flows through the engine's micro-batcher (same-predicate queries
        coalesce and share one compiled bitmap per partition — plan
        ``filtered-batched[...]`` / ``exact-filtered``). Opaque callables
        raise ``ValueError``: the legacy host path that served them
        (O(capacity) doc-store scan per partition per query, plans
        ``filtered-legacy[...]``) is retired."""
        qv = np.asarray(q.vector, np.float32)

        if q.filter is not None and not isinstance(q.filter, Predicate):
            raise ValueError(
                "callable filters are no longer supported (the legacy "
                "filtered-legacy[...] host path is retired); build a "
                "declarative predicate with repro.serve.F, e.g. "
                "F.eq('category', 3)"
            )

        L = max(q.k, int(round(q.search_list_multiplier * q.k)))
        rid = self.engine.next_rid()
        resp = self.engine.query_sync(ServeRequest(
            rid=rid, vector=qv, k=q.k, L=L, tenant=q.tenant,
            exact=q.exact, shard_key=q.shard_key, predicate=q.filter,
            deadline_ms=q.deadline_ms,
        ))
        if resp.status == 429:
            raise Throttled(q.tenant, resp.retry_after_s)
        if resp.status == 408:
            raise DeadlineExceeded(q.tenant, resp.wait_ms)
        return QueryResult(resp.ids, resp.dists, resp.ru, resp.plan,
                           latency_ms=resp.latency_ms,
                           complete=resp.complete)

    # ------------------------------------------------------------------
    # pagination / continuation tokens (§3.5 "Continuations")
    # ------------------------------------------------------------------
    def query_page(self, q: VectorQuery, continuation: Optional[bytes] = None,
                   page_size: int = 10) -> QueryResult:
        """One page of a cross-partition paginated query, through the
        engine.

        The continuation token carries one pagination cursor per physical
        partition (plus fetched-but-unemitted buffers and per-partition
        high-water marks); each page fans out ``next_page`` to whichever
        partitions need refilling and merges client-side, so pages never
        repeat or skip results across partitions. The page is RU-metered
        and admission-controlled exactly like the main path: an
        over-budget tenant gets ``Throttled`` (429 + retry-after) with no
        budget consumed, and every served page bills at least the request
        floor. ``shard_key`` routes to a sharded-DiskANN tenant index;
        ``q.beam_width`` overrides the engine's per-round hop batching.

        ``q.filter`` must be a declarative ``Predicate`` (or None): the
        compiled per-partition bitmap threads through
        ``paged_fanout_search`` so every emitted row satisfies the
        predicate, with no-match partitions exhausted at birth. Opaque
        callable filters are REJECTED here — the old behavior silently
        ignored them and returned unfiltered pages, which is worse than an
        error. The token binds to the predicate's canonical key: resuming
        a filtered pagination under a different predicate raises
        ``ContinuationError``.

        Returns ``continuation=None`` once every partition is exhausted
        and its buffers are drained. The client re-sends the SAME query
        vector (and predicate) with each token (the token deliberately
        excludes them, as in the SDK); resuming under a different shard
        key or after a partition split/merge raises ``ContinuationError``.
        """
        if q.filter is not None and not isinstance(q.filter, Predicate):
            raise ValueError(
                "query_page does not support callable filters (they were "
                "previously ignored, silently returning unfiltered pages); "
                "pass a declarative predicate built with repro.serve.F"
            )
        pred = q.filter
        pred_key = pred.key() if pred is not None else None
        qv = np.asarray(q.vector, np.float32)
        target = self._partitions_for(q.shard_key)
        W = int(q.beam_width or self.engine.cfg.beam_width)
        # beam_width is client input on this path: bound it here as a
        # client error, not a bare assert inside the jitted kernel
        W_max = min((p.index.cfg.L_search for p in target), default=1)
        if not 1 <= W <= W_max:
            raise ValueError(
                f"beam_width {W} out of range [1, {W_max}] for this "
                f"collection's search list size"
            )
        holder: dict[str, Any] = {}

        def body():
            # cursor construction / token decode / predicate compilation
            # happen HERE, behind the engine's admission check: a throttled
            # tenant (or a malformed token) must not trigger per-partition
            # work
            slot_filters = None
            compile_ru = 0.0
            if pred is not None:
                slot_filters = []
                for p in target:
                    if p.num_docs == 0:
                        slot_filters.append(None)
                        continue
                    mask, _words, nreads = compile_partition_filter(p, pred)
                    # compile cost bills like the batched path — a filtered
                    # page on a cold bitmap cache is not free
                    compile_ru += (
                        nreads * p.providers.meter.cfg.ru_per_prop_read
                    )
                    slot_filters.append(mask)
            if continuation is None:
                pstate = start_paged_fanout(target, qv, shard_key=q.shard_key,
                                            pred_key=pred_key,
                                            slot_filters=slot_filters)
            else:
                pstate = decode_continuation(continuation)
                if pstate.shard_fp != paged_fanout_fingerprint(
                        q.shard_key, target, pred_key):
                    raise ContinuationError(
                        "token does not match this query's routing "
                        "(different shard key or predicate, or the "
                        "partition set changed)"
                    )
                self._check_token_topology(pstate, target)
            if slot_filters is not None:
                # a partition whose match-set went empty since the last
                # page (ingest re-labelled / deleted its matches) must NOT
                # fall back to unfiltered fetches — a None slot_filter
                # means "no filter" downstream. Exhaust its cursor;
                # already-buffered rows (which matched at fetch time)
                # still drain.
                for cur, mask in zip(pstate.cursors, slot_filters):
                    if mask is None and not cur.exhausted:
                        cur.exhausted = True
                        cur.state = None
            holder["pstate"] = pstate
            # under a multi-lane dispatch plane, each refill round's
            # per-partition fetches schedule onto executor lanes (round
            # makespan, not sum); serial keeps the legacy max-of-sums
            # accounting byte-identical
            lane_exec = (self.engine.executor
                         if self.engine.cfg.dispatch_mode != "serial" else None)
            ids, dists, info = paged_fanout_search(
                target, qv, pstate, page_size, beam_width=W,
                slot_filters=slot_filters, executor=lane_exec,
            )
            # per-fetch child spans for the trace plane: one span per
            # partition page-fetch, labelled by refill round
            fetch_spans = [
                dict(name=f"page.fetch[p{e['pid']}]", stage="partition",
                     dur_ms=e["lat_ms"],
                     attrs=dict(pid=e["pid"], round=e["round"], ru=e["ru"]))
                for e in info["fetch_log"]
            ]
            return (ids, dists, info["ru_total"] + compile_ru,
                    info["service_latency_ms"],
                    "paginated" if pred is None else "paginated-filtered",
                    fetch_spans)

        resp = self.engine.execute_host(q.tenant, "paginated", body,
                                        is_page=True)
        pstate = holder["pstate"]
        token = None if pstate.exhausted() else encode_continuation(pstate)
        return QueryResult(resp.ids, resp.dists, resp.ru, resp.plan,
                           continuation=token, latency_ms=resp.latency_ms)

    @staticmethod
    def _check_token_topology(pstate, target) -> None:
        """Schema-level binding of a decoded token to the live partitions:
        cursor count, partition ids, visited-bitmap widths, AND the beam /
        backup array widths must all match the routing that will serve the
        next page. The width checks matter beyond correctness: array
        shapes are jit signatures, so a well-formed token with an
        arbitrary L would mint a fresh compile per request — an easy way
        for a client to break the serving layer's zero-recompile
        contract."""
        if len(pstate.cursors) != len(target):
            raise ContinuationError(
                f"token has {len(pstate.cursors)} cursors for "
                f"{len(target)} partitions"
            )
        for cur, p in zip(pstate.cursors, target):
            if cur.pid != p.pid:
                raise ContinuationError(
                    f"token cursor pid {cur.pid} != partition {p.pid}"
                )
            if cur.state is not None:
                L_want = p.index.cfg.L_search
                if cur.state.best_ids.shape[0] != L_want:
                    raise ContinuationError(
                        f"token beam width {cur.state.best_ids.shape[0]} "
                        f"!= configured L_search {L_want}"
                    )
                if cur.state.backup_ids.shape[0] != PAGE_BACKUP_CAP:
                    raise ContinuationError(
                        f"token backup width {cur.state.backup_ids.shape[0]}"
                        f" != {PAGE_BACKUP_CAP}"
                    )
                words = bitmap_words(p.index.cfg.capacity)
                if cur.state.bitmap.shape[0] != words:
                    raise ContinuationError(
                        f"token bitmap width {cur.state.bitmap.shape[0]} "
                        f"does not fit partition capacity "
                        f"{p.index.cfg.capacity}"
                    )


class _RUTally:
    """Accumulates RU across deferred ingest thunks (the async-upsert
    handle: read ``.value`` after the engine has drained the queue)."""

    def __init__(self):
        self.value = 0.0

    def add(self, ru: float) -> float:
        self.value += ru
        return ru
