"""VectorCollectionService — the user-facing query layer (§3.5).

Ties together everything the paper composes: JSON-ish documents with a
declared vector path, automatic index-term generation on ingest, the
VectorDistance query function with the planner's selectivity routing
(brute force / Q-Flat / graph ± filters), paginated queries with
client-side continuation tokens (the 5-second-preemption model), sharded
DiskANN for multi-tenancy, and cross-partition fan-out with RU accounting.

Since the serving PR this class is a thin façade over
``serve.vector_engine.VectorServeEngine``: every query path flows through
the engine (admission control, micro-batching, metrics, simulated clock),
and ingest rides the engine's interleaved mini-batch queue. The service
keeps what needs the document store — predicate→bitmap conversion for
filtered plans, tenant routing, and pagination state.

This is the host-side service; the device-parallel path for the same
operation is `repro.partition.fanout.distributed_search_fn`.
"""
from __future__ import annotations

import dataclasses
import pickle
from typing import Any, Callable, Optional, Sequence

import numpy as np

from ..core import GraphConfig
from ..partition import Collection, CollectionConfig, ReplicaSet
from ..partition.fanout import merge_topk
from ..store.ru import counters_for_latency, counters_for_ru
from .vector_engine import EngineConfig, ServeRequest, Throttled, VectorServeEngine


@dataclasses.dataclass
class VectorQuery:
    vector: np.ndarray
    k: int = 10
    filter: Optional[Callable[[dict], bool]] = None  # predicate over docs
    search_list_multiplier: float = 5.0  # searchListSizeMultiplier
    exact: bool = False  # VectorDistance(..., true) → brute force
    shard_key: Any = None  # route to a sharded-DiskANN tenant index
    tenant: Any = "default"  # RU-admission principal (429s when over budget)


@dataclasses.dataclass
class QueryResult:
    ids: np.ndarray
    dists: np.ndarray
    ru: float
    plan: str
    continuation: Optional[bytes] = None
    latency_ms: float = 0.0


class VectorCollectionService:
    """A collection with vector indexing enabled on one path."""

    def __init__(
        self,
        dim: int,
        graph: Optional[GraphConfig] = None,
        max_vectors_per_partition: int = 100_000,
        initial_partitions: int = 1,
        replicas: int = 4,
        shard_key_path: Optional[str] = None,
        engine_cfg: EngineConfig = EngineConfig(),
    ):
        graph = graph or GraphConfig(capacity=max_vectors_per_partition + 1024)
        self.cfg = CollectionConfig(
            dim=dim,
            graph=graph,
            max_vectors_per_partition=max_vectors_per_partition,
            initial_partitions=initial_partitions,
            shard_key_path=shard_key_path,
        )
        self.collection = Collection(self.cfg)
        self.replica_sets = [
            ReplicaSet(p, num_replicas=replicas) for p in self.collection.partitions
        ]
        self.docs: dict[int, dict] = {}  # document store (JSON side)
        self.shard_key_path = shard_key_path
        # sharded DiskANN: tenant value → per-tenant collection
        self._tenant_collections: dict[Any, Collection] = {}
        self.engine = VectorServeEngine(
            self.collection, cfg=engine_cfg, resolver=self._partitions_for
        )

    def _partitions_for(self, shard_key: Any):
        if shard_key is not None and self.shard_key_path:
            return self._tenant(shard_key).partitions
        return self.collection.partitions

    # ------------------------------------------------------------------
    # ingest (through the engine's interleaved mini-batch queue)
    # ------------------------------------------------------------------
    def upsert(self, documents: Sequence[dict], vectors: np.ndarray,
               partition_keys: Optional[Sequence] = None) -> float:
        """Insert documents (dicts with 'id') + their embedding vectors.
        Synchronous: enqueues chunked ingest work on the engine and drains
        it before returning (use ``upsert_async`` to leave it interleaving
        with query traffic)."""
        total = self.upsert_async(documents, vectors, partition_keys)
        self.engine.flush_ingest()
        return total.value

    def upsert_async(self, documents: Sequence[dict], vectors: np.ndarray,
                     partition_keys: Optional[Sequence] = None) -> "_RUTally":
        vectors = np.asarray(vectors, np.float32)
        ids = [int(d["id"]) for d in documents]
        pks = list(partition_keys) if partition_keys is not None else ids
        tally = _RUTally()
        chunk = self.engine.cfg.ingest_chunk
        for lo in range(0, len(documents), chunk):
            hi = min(lo + chunk, len(documents))
            docs_c = list(documents[lo:hi])
            ids_c, pks_c, vecs_c = ids[lo:hi], pks[lo:hi], vectors[lo:hi]
            self.engine.submit_ingest(
                "upsert",
                lambda d=docs_c, i=ids_c, p=pks_c, v=vecs_c:
                    tally.add(self._apply_upsert(d, i, p, v)),
                len(docs_c),
            )
        return tally

    def _apply_upsert(self, documents, ids, pks, vectors) -> float:
        for d in documents:
            self.docs[int(d["id"])] = d
        ru = self.collection.insert(ids, pks, vectors)
        if self.shard_key_path:
            groups: dict[Any, list[int]] = {}
            for i, d in enumerate(documents):
                groups.setdefault(d.get(self.shard_key_path), []).append(i)
            for key, rows in groups.items():
                ru += self._tenant(key).insert(
                    [ids[i] for i in rows], [pks[i] for i in rows], vectors[rows]
                )
        return ru

    def delete(self, doc_ids: Sequence[int]) -> float:
        total = self.delete_async(doc_ids)
        self.engine.flush_ingest()
        return total.value

    def delete_async(self, doc_ids: Sequence[int]) -> "_RUTally":
        tally = _RUTally()
        chunk = self.engine.cfg.ingest_chunk
        doc_ids = list(doc_ids)
        for lo in range(0, len(doc_ids), chunk):
            ids_c = doc_ids[lo:lo + chunk]
            self.engine.submit_ingest(
                "delete", lambda i=ids_c: tally.add(self._apply_delete(i)),
                len(ids_c),
            )
        return tally

    def _apply_delete(self, doc_ids: Sequence[int]) -> float:
        pks = [d for d in doc_ids]
        shard_groups: dict[Any, list[int]] = {}
        for d in doc_ids:
            doc = self.docs.pop(int(d), None)
            if doc is not None and self.shard_key_path:
                shard_groups.setdefault(doc.get(self.shard_key_path), []).append(int(d))
        ru = self.collection.delete(doc_ids, pks)
        for key, ids in shard_groups.items():
            ru += self._tenant(key).delete(ids, ids)
        return ru

    def _tenant(self, key) -> Collection:
        if key not in self._tenant_collections:
            self._tenant_collections[key] = Collection(
                dataclasses.replace(self.cfg, initial_partitions=1)
            )
        return self._tenant_collections[key]

    # ------------------------------------------------------------------
    # query (§3.5 routing — thin façade over the engine)
    # ------------------------------------------------------------------
    def query(self, q: VectorQuery) -> QueryResult:
        """Route one query through the serving engine. Raises ``Throttled``
        when the tenant is over its RU budget (the 429 path)."""
        qv = np.asarray(q.vector, np.float32)

        # precedence as before the engine rewire: VectorDistance(..., true)
        # forces the exact plan even when a filter is also set
        if q.filter is not None and not q.exact:
            resp = self.engine.execute_host(
                q.tenant, "filtered", lambda: self._run_filtered(q, qv)
            )
            return QueryResult(resp.ids, resp.dists, resp.ru, resp.plan,
                               latency_ms=resp.latency_ms)

        L = max(q.k, int(round(q.search_list_multiplier * q.k)))
        rid = self.engine.next_rid()
        resp = self.engine.query_sync(ServeRequest(
            rid=rid, vector=qv, k=q.k, L=L, tenant=q.tenant,
            exact=q.exact, shard_key=q.shard_key,
        ))
        if resp.status == 429:
            raise Throttled(q.tenant, resp.retry_after_s)
        return QueryResult(resp.ids, resp.dists, resp.ru, resp.plan,
                           latency_ms=resp.latency_ms)

    def _run_filtered(self, q: VectorQuery, qv: np.ndarray):
        """Filtered plan body (needs the doc store for the predicate →
        bitmap conversion; executed under the engine's accounting)."""
        target = self._partitions_for(q.shard_key)
        ids_l, d_l, ru, lat_ms = [], [], 0.0, 0.0
        plan = ""
        for p in target:
            mask = np.zeros(p.index.cfg.capacity, bool)
            for doc, slot in p.index.doc_to_slot.items():
                if doc in self.docs and q.filter(self.docs[doc]):
                    mask[slot] = True
            ids, dists, stats = p.index.filtered_search(qv[None, :], q.k, mask)
            ids_l.append(ids)
            d_l.append(dists)
            plan = stats.plan
            # RU charges the work done; latency sees the round-structured
            # critical path — same split as the batched fanout path
            ru += p.providers.meter.ru(counters_for_ru(stats))
            lat_ms = max(lat_ms, p.providers.meter.latency_ms(
                counters_for_latency(stats)))
        ids, dists = merge_topk(ids_l, d_l, q.k)
        return ids[0], dists[0], ru, lat_ms

    # ------------------------------------------------------------------
    # pagination / continuation tokens (§3.5 "Continuations")
    # ------------------------------------------------------------------
    def query_page(self, q: VectorQuery, continuation: Optional[bytes] = None,
                   page_size: int = 10) -> QueryResult:
        """Paginated query over partition 0 (single-partition pagination;
        cross-partition pagination merges client-side as in the SDK)."""
        part = self.collection.partitions[0]
        qv = np.asarray(q.vector, np.float32)
        if continuation is None:
            state = part.index.start_pagination(qv)
        else:
            state = pickle.loads(continuation)
        ids, dists, state = part.index.next_page(qv, state, k=page_size)
        token = pickle.dumps(state)
        return QueryResult(ids, dists, 0.0, "paginated", continuation=token)


class _RUTally:
    """Accumulates RU across deferred ingest thunks (the async-upsert
    handle: read ``.value`` after the engine has drained the queue)."""

    def __init__(self):
        self.value = 0.0

    def add(self, ru: float) -> float:
        self.value += ru
        return ru
