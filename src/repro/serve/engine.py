"""ServeEngine — batched LM serving (prefill + decode) for the arch pool.

Continuous-batching-lite: requests join a fixed-width slot table; prefill
fills a slot's KV cache, decode advances all active slots one token per
step, finished slots are recycled. Greedy sampling (temperature 0) keeps
tests deterministic. On TPU the same engine runs with the decode step's
sequence-sharded caches; here it exercises the identical code path on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as M
from ..models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 4,
                 s_max: int = 256, eos_id: Optional[int] = None):
        assert cfg.has_decode, "encoder-only archs cannot serve decode"
        self.cfg = cfg
        self.params = params
        self.slots: list[Optional[Request]] = [None] * batch_slots
        self.s_max = s_max
        self.eos = eos_id
        self.cache = M.init_cache(cfg, batch_slots, s_max, dtype=jnp.float32)
        self.cache_len = np.zeros(batch_slots, np.int32)
        self._decode = jax.jit(
            lambda p, t, c, l: M.decode_step(p, cfg, t, c, l)
        )
        self.queue: list[Request] = []
        self.completed: dict[int, Request] = {}

    # ------------------------------------------------------------------
    def submit(self, rid: int, prompt: np.ndarray, max_new_tokens: int = 16):
        self.queue.append(Request(rid, np.asarray(prompt, np.int32), max_new_tokens))

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                # per-slot prefill (simple; batched prefill is the TPU path)
                batch = {"tokens": jnp.asarray(req.prompt[None, :])}
                cache_i = M.init_cache(self.cfg, 1, self.s_max, dtype=jnp.float32)
                logits, cache_i = M.prefill(self.params, self.cfg, batch, cache_i)
                self._write_slot_cache(i, cache_i)
                self.cache_len[i] = len(req.prompt)
                tok = int(jnp.argmax(logits[0, 0]))
                req.out_tokens.append(tok)

    def _write_slot_cache(self, i: int, cache_i):
        # caches are lists of per-segment stacks with leaves (seg, B, ...)
        self.cache = jax.tree.map(
            lambda full, one: full.at[:, i : i + 1].set(one.astype(full.dtype)),
            self.cache, cache_i,
        )

    # ------------------------------------------------------------------
    def step(self):
        """Admit waiting requests, run one decode step for active slots."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return False
        tokens = np.zeros((len(self.slots), 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].out_tokens[-1]
        # decode uses max cache_len; per-slot masks come from position ≤ len.
        # Simple engine: step each active slot group with equal cache_len.
        for i in active:
            logits, self.cache = self._decode(
                self.params, jnp.asarray(tokens), self.cache,
                jnp.int32(int(self.cache_len[i])),
            )
            break  # one batched decode step; identical cache_len assumption
        for i in active:
            req = self.slots[i]
            tok = int(jnp.argmax(logits[i, 0]))
            req.out_tokens.append(tok)
            self.cache_len[i] += 1
            if len(req.out_tokens) >= req.max_new_tokens or (
                self.eos is not None and tok == self.eos
            ) or self.cache_len[i] >= self.s_max - 1:
                req.done = True
                self.completed[req.rid] = req
                self.slots[i] = None
        return True

    def run(self, max_steps: int = 1000) -> dict[int, list[int]]:
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)) and steps < max_steps:
            self.step()
            steps += 1
        return {rid: r.out_tokens for rid, r in self.completed.items()}
