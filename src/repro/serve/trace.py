"""Request-lifecycle tracing on simulated time — the serving sensor layer.

The paper's headline claims are *observability* claims: <20 ms query
latency, stable recall over updates, ~43×/12× lower query cost (§4,
Figs 10-13). Verifying them per request needs a stage-level decomposition
of where each millisecond and each RU goes — admission, queue wait,
batch formation, lane dispatch (hedge duplicates, fault retries),
per-partition fan-out, merge. This module provides that decomposition:

  * ``Span`` / ``Trace`` — one trace per query / page / ingest op, with
    child spans per lifecycle stage. All timestamps are **SimClock
    seconds** (the engine's deterministic simulated timeline), so traces
    are bit-reproducible offline and stage durations reconcile *exactly*
    with the latency the engine records: the root-level stage spans of a
    served request tile its [arrival, completion] interval, so
    ``sum(root span durations) == latency_ms`` (asserted by
    ``validate_trace_record`` and the tier-1 tests). Child spans under
    ``lane`` model the *parallel* structure (per-partition fan-out, the
    hedge duplicate) and deliberately overlap.

  * ``Tracer`` — the factory the engine owns. ``enabled=False`` makes
    ``begin`` return ``None`` and every hot path guards on that, so a
    disabled tracer costs one attribute read per request — nothing is
    allocated, nothing is retained.

  * ``FlightRecorder`` — a bounded ring buffer of recent trace records
    plus a *separate* bounded ring for anomalous traces (throttles,
    faults, hedges, SLO violations), so a burst of healthy traffic can
    never evict the interesting evidence.

  * Exporters — ``Tracer.dump_jsonl`` writes the retained records as
    JSON lines; ``validate_trace_record`` is the schema contract the
    benchmark gate re-checks on every emitted line.

Stage taxonomy (``STAGES``):

  admission   point event: the RU-governance decision (reserved estimate)
  queue       [arrival → lane start]: batching + lane queue wait
  batch_form  point event at dispatch: batch size / bucket / plan
  lane        [lane start → completion]: the dispatch-plane service
  partition   child of lane: one span per physical partition searched,
              carrying that partition's RU and the search counters the
              RU/latency split is computed from (hops / expansions /
              cmps — see ``store.ru.counters_for_ru`` /
              ``counters_for_latency``)
  hedge       child of lane: the straggler duplicate (RU billed in full)
  retry       child of lane: a lane fault burned before the work ran
  merge       child of lane: host-side merge / dispatch overhead
  ingest      root span of an ingest mini-batch trace
  deadline    point event: the request's deadline expired while it was
              still queued; the engine abandoned it (status 408) and
              refunded the admission reservation
  policy      point event: a control-plane action (serve/policy.py) —
              a topology split / replica scale-out with the signals
              that triggered it, so scaling is attributable in traces
"""
from __future__ import annotations

import dataclasses
import json
from collections import deque
from typing import Any, Optional

from .metrics import SimClock

STAGES = ("admission", "queue", "batch_form", "lane", "partition", "hedge",
          "retry", "merge", "ingest", "deadline", "policy", "rerank")

TRACE_KINDS = ("query", "page", "ingest", "policy")

# anomaly tags the flight recorder always captures
ANOMALY_THROTTLE = "throttle"
ANOMALY_HEDGE = "hedge"
ANOMALY_FAULT = "fault_retry"
ANOMALY_SLO = "slo_violation"
ANOMALY_DEADLINE = "deadline_exceeded"
ANOMALY_DEGRADED = "degraded"


@dataclasses.dataclass
class Span:
    """One lifecycle stage of one request, on SimClock time."""

    name: str
    stage: str
    t0_s: float
    t1_s: float
    parent: int = -1  # index into the owning trace's span list
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def dur_ms(self) -> float:
        return (self.t1_s - self.t0_s) * 1000.0


@dataclasses.dataclass
class Trace:
    """One request's lifecycle: a flat span list with parent links."""

    trace_id: int
    kind: str  # query | page | ingest
    tenant: Any
    rid: int
    t0_s: float = 0.0
    t1_s: float = 0.0
    status: int = 0
    ru: float = 0.0
    latency_ms: float = 0.0
    anomalies: list = dataclasses.field(default_factory=list)
    spans: list = dataclasses.field(default_factory=list)

    def span(self, name: str, stage: str, t0_s: float, t1_s: float,
             parent: int = -1, **attrs) -> int:
        """Append a span; returns its index (usable as a parent link)."""
        self.spans.append(Span(name, stage, float(t0_s), float(t1_s),
                               parent, attrs))
        return len(self.spans) - 1

    def stage_totals(self) -> dict:
        """Root-span duration per stage (ms). Root spans are sequential —
        they tile [t0, t1] — so their sum reconciles with latency_ms;
        children (partition fan-out, hedge) model parallel structure and
        are excluded."""
        out: dict[str, float] = {}
        for s in self.spans:
            if s.parent == -1:
                out[s.stage] = out.get(s.stage, 0.0) + s.dur_ms
        return out

    def has_stage(self, stage: str) -> bool:
        return any(s.stage == stage for s in self.spans)

    def to_record(self) -> dict:
        """The JSON-lines export shape (see ``validate_trace_record``)."""
        return dict(
            trace_id=self.trace_id,
            kind=self.kind,
            tenant=str(self.tenant),
            rid=self.rid,
            status=self.status,
            t0_s=self.t0_s,
            t1_s=self.t1_s,
            latency_ms=self.latency_ms,
            ru=self.ru,
            anomalies=list(self.anomalies),
            spans=[
                dict(name=s.name, stage=s.stage, t0_s=s.t0_s, t1_s=s.t1_s,
                     dur_ms=s.dur_ms, parent=s.parent, attrs=s.attrs)
                for s in self.spans
            ],
        )


class FlightRecorder:
    """Bounded retention of recent + anomalous traces.

    ``ring`` holds the last ``capacity`` traces of *any* outcome;
    ``anomalous`` is a separate ring that only anomalous traces enter, so
    throttles / faults / hedges / SLO violations survive arbitrarily long
    bursts of healthy traffic (they fall out only to newer anomalies).

    Retained entries are live ``Trace`` objects — serialization to the
    record dict happens lazily in ``records()`` (the export/read path),
    never on the per-request hot path.
    """

    def __init__(self, capacity: int = 256):
        self.capacity = int(capacity)
        self.ring: deque = deque(maxlen=self.capacity)
        self.anomalous: deque = deque(maxlen=self.capacity)
        self.recorded = 0
        self.anomalies_seen = 0

    def record(self, tr: "Trace"):
        self.recorded += 1
        self.ring.append(tr)
        if tr.anomalies:
            self.anomalies_seen += 1
            self.anomalous.append(tr)

    def records(self) -> list:
        """Every retained record dict, dedup'd by trace id (ring ∪
        anomalous), serialized on demand."""
        seen = set()
        out = []
        for tr in list(self.ring) + list(self.anomalous):
            if tr.trace_id in seen:
                continue
            seen.add(tr.trace_id)
            out.append(tr.to_record())
        out.sort(key=lambda r: r["trace_id"])
        return out


class Tracer:
    """The engine's trace factory on the shared SimClock.

    Zero-overhead when disabled: ``begin`` returns ``None`` and callers
    guard span emission on that — no allocation, no retention. When
    enabled, ``finish`` derives anomaly tags (throttle / hedge / fault /
    SLO) and hands the record to the flight recorder.
    """

    def __init__(self, clock: SimClock, enabled: bool = True,
                 capacity: int = 256, slo_ms: Optional[float] = None):
        self.clock = clock
        self.enabled = bool(enabled)
        self.slo_ms = slo_ms
        self._capacity = int(capacity)
        self.recorder = FlightRecorder(capacity)
        self.started = 0
        self.finished = 0
        self._next_id = 0

    def reset(self):
        """Fresh recorder + counters (benchmark warmup boundary)."""
        self.recorder = FlightRecorder(self._capacity)
        self.started = 0
        self.finished = 0
        self._next_id = 0

    def begin(self, kind: str, tenant: Any, rid: int) -> Optional[Trace]:
        if not self.enabled:
            return None
        self.started += 1
        tid = self._next_id
        self._next_id += 1
        return Trace(trace_id=tid, kind=kind, tenant=tenant, rid=rid,
                     t0_s=self.clock.now())

    def finish(self, tr: Trace, status: int, ru: float, latency_ms: float,
               t0_s: Optional[float] = None, t1_s: Optional[float] = None,
               anomalies: tuple = ()):
        tr.status = int(status)
        tr.ru = float(ru)
        tr.latency_ms = float(latency_ms)
        if t0_s is not None:
            tr.t0_s = float(t0_s)
        tr.t1_s = float(t1_s) if t1_s is not None else self.clock.now()
        tags = list(anomalies)
        if status == 429 and ANOMALY_THROTTLE not in tags:
            tags.append(ANOMALY_THROTTLE)
        if status == 408 and ANOMALY_DEADLINE not in tags:
            tags.append(ANOMALY_DEADLINE)
        stages = {s.stage for s in tr.spans}
        if "hedge" in stages:
            tags.append(ANOMALY_HEDGE)
        if "retry" in stages:
            tags.append(ANOMALY_FAULT)
        if (self.slo_ms is not None and tr.kind != "ingest"
                and latency_ms > self.slo_ms):
            tags.append(ANOMALY_SLO)
        tr.anomalies = tags
        self.finished += 1
        self.recorder.record(tr)

    # ------------------------------------------------------------------
    # exporters
    # ------------------------------------------------------------------
    def dump_jsonl(self, path) -> int:
        """Write every retained trace record as one JSON object per line.
        Returns the number of records written."""
        recs = self.recorder.records()
        with open(path, "w") as f:
            for rec in recs:
                f.write(json.dumps(rec) + "\n")
        return len(recs)

    def stats(self) -> dict:
        return dict(
            enabled=self.enabled,
            started=self.started,
            finished=self.finished,
            recorded=self.recorder.recorded,
            retained=len(self.recorder.ring),
            anomalies_seen=self.recorder.anomalies_seen,
            anomalies_retained=len(self.recorder.anomalous),
            slo_ms=self.slo_ms,
        )


# ---------------------------------------------------------------------------
# schema contract (the benchmark gate re-validates every exported line)
# ---------------------------------------------------------------------------

_REQUIRED = {
    "trace_id": int, "kind": str, "tenant": str, "rid": int, "status": int,
    "t0_s": (int, float), "t1_s": (int, float),
    "latency_ms": (int, float), "ru": (int, float),
    "anomalies": list, "spans": list,
}

_SPAN_REQUIRED = {
    "name": str, "stage": str, "t0_s": (int, float), "t1_s": (int, float),
    "dur_ms": (int, float), "parent": int, "attrs": dict,
}


def validate_trace_record(rec: dict) -> None:
    """Raise ``ValueError`` unless ``rec`` is a well-formed trace record.

    Beyond structural checks (keys, types, stage taxonomy, parent links),
    this enforces the cost-attribution contract: for a served (status
    200) or deadline-abandoned (status 408) request, the root-level
    stage spans tile the request interval, so their summed duration
    equals ``latency_ms`` within clock resolution. That is the
    invariant that makes per-stage dashboards trustworthy — stages can
    never silently leak time, even for requests that never reached a
    lane.
    """
    if not isinstance(rec, dict):
        raise ValueError("trace record must be a dict")
    for key, typ in _REQUIRED.items():
        if key not in rec:
            raise ValueError(f"trace record missing key {key!r}")
        if not isinstance(rec[key], typ):
            raise ValueError(f"trace record key {key!r} has wrong type "
                             f"{type(rec[key]).__name__}")
    if rec["kind"] not in TRACE_KINDS:
        raise ValueError(f"unknown trace kind {rec['kind']!r}")
    if rec["t1_s"] < rec["t0_s"]:
        raise ValueError("trace t1_s < t0_s")
    spans = rec["spans"]
    if rec["status"] in (200, 408) and not spans:
        raise ValueError("served trace has no spans")
    for i, s in enumerate(spans):
        if not isinstance(s, dict):
            raise ValueError(f"span {i} is not a dict")
        for key, typ in _SPAN_REQUIRED.items():
            if key not in s:
                raise ValueError(f"span {i} missing key {key!r}")
            if not isinstance(s[key], typ):
                raise ValueError(f"span {i} key {key!r} has wrong type")
        if s["stage"] not in STAGES:
            raise ValueError(f"span {i} stage {s['stage']!r} not in taxonomy")
        if s["t1_s"] < s["t0_s"]:
            raise ValueError(f"span {i} t1_s < t0_s")
        if not -1 <= s["parent"] < i:
            raise ValueError(f"span {i} parent {s['parent']} must point at "
                             f"an earlier span (or -1)")
    if rec["status"] in (200, 408):
        root_ms = sum(s["dur_ms"] for s in spans if s["parent"] == -1)
        tol = 1e-6 + 1e-9 * abs(rec["latency_ms"])
        if abs(root_ms - rec["latency_ms"]) > tol:
            raise ValueError(
                f"stage decomposition leaks time: root spans sum to "
                f"{root_ms:.9f} ms but latency_ms is "
                f"{rec['latency_ms']:.9f} ms"
            )
