"""The dispatch plane: replica-concurrent lanes under simulated time.

The engine used to execute every micro-batch inline — one simulated
executor, so offered load beyond one lane's service rate piled up as
queue wait no matter how many replicas the topology declared. This
module models the server's dispatch plane instead: ``LaneExecutor``
owns N replica lanes, each a busy-interval timeline under the shared
``SimClock``. Dispatching a batch books the earliest-free healthy lane
(FIFO within a lane, earliest-finish across lanes), so independent
micro-batches genuinely overlap in simulated time and queue wait shows
up in the latency percentiles instead of disappearing.

Straggler hedging lives here now (lifted from ``fanout_search``): when
a lane's jittered service time trips ``hedge_at_ms``, a second healthy
lane runs a duplicate and the earliest finisher wins — the duplicate's
RU is billed, never free (§4.4 tail-tolerance, paid for in RU).

Lane health: an injected fault marks the lane down and the scheduler
retries the dispatch on another lane; a down lane is re-probed after a
cooldown and revived (callbacks let the engine mirror this into
``ReplicaSet`` kill / rebuild / read routing).

Modes:
  * ``serial``  — one lane, clock advanced inline: byte-identical to
    the pre-dispatch-plane engine.
  * ``replica`` — N lanes, future-scheduled: the clock does NOT advance
    on dispatch; lane timelines run ahead of it and ``quiesce`` brings
    the clock to the horizon on drain.
  * ``spmd``    — one lane (the whole mesh is one executor); the
    parallelism lives inside the jitted program, not the lane plane.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import numpy as np

from .metrics import SimClock

DISPATCH_MODES = ("serial", "replica", "spmd")


@dataclasses.dataclass
class LaneState:
    """One replica lane's timeline: busy horizon + health."""

    lane_id: int
    busy_until_s: float = 0.0
    down: bool = False
    down_since_s: float = 0.0
    dispatches: int = 0
    busy_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class DispatchOutcome:
    """Where and when a dispatch ran on the lane plane."""

    payload: Any
    lane: int
    start_s: float
    end_s: float
    ru: float
    hedged: bool = False
    hedge_ru: float = 0.0
    hedge_lane: int = -1
    hedge_won: bool = False
    retried_lanes: tuple = ()
    # trace-plane detail: the hedge duplicate's own lane interval, and
    # whether the primary's service time was straggler-inflated
    hedge_start_s: float = 0.0
    hedge_end_s: float = 0.0
    straggled: bool = False


class LaneExecutor:
    """N replica lanes scheduling work on a shared simulated clock.

    ``run`` thunks passed to :meth:`dispatch` return
    ``(payload, service_ms, ru)``; the executor decides *where* and
    *when* that service time is spent, never *what* runs.
    """

    def __init__(self, clock: SimClock, lanes: int = 1, mode: str = "serial",
                 hedge_at_ms: Optional[float] = None,
                 straggler_p: float = 0.0, straggler_factor: float = 4.0,
                 reprobe_after_s: float = 5.0, seed: int = 0,
                 on_lane_down: Optional[Callable[[int, float], None]] = None,
                 on_lane_up: Optional[Callable[[int, float], None]] = None,
                 on_lane_read: Optional[Callable[[int], None]] = None):
        if mode not in DISPATCH_MODES:
            raise ValueError(f"dispatch mode {mode!r} not in {DISPATCH_MODES}")
        self.mode = mode
        self.clock = clock
        n = max(1, int(lanes)) if mode == "replica" else 1
        self.lanes = [LaneState(i) for i in range(n)]
        self.hedge_at_ms = hedge_at_ms
        self.straggler_p = float(straggler_p)
        self.straggler_factor = float(straggler_factor)
        self.reprobe_after_s = float(reprobe_after_s)
        self.on_lane_down = on_lane_down
        self.on_lane_up = on_lane_up
        self.on_lane_read = on_lane_read
        self._rng = np.random.RandomState(seed)
        self._armed_faults: dict[int, int] = {}
        self.hedges = 0
        self.hedges_won = 0
        self.hedge_ru_total = 0.0
        self.faults = 0
        self.recoveries = 0
        self.retries = 0
        self._born_s = clock.now()

    # ------------------------------------------------------------------
    # health
    # ------------------------------------------------------------------
    def inject_fault(self, lane_id: int, count: int = 1):
        """Arm the lane to fail its next `count` selections (test hook /
        fault model): the failure fires on selection, BEFORE the work
        runs, so a retried dispatch executes exactly once."""
        self._armed_faults[lane_id] = self._armed_faults.get(lane_id, 0) + count

    def healthy_lanes(self) -> list:
        return [ln for ln in self.lanes if not ln.down]

    def add_lane(self) -> int:
        """Scale-out actuation (serve.policy): a new healthy lane joins
        the plane and starts taking dispatches immediately — its empty
        timeline makes it the earliest-free pick, so it absorbs the
        backlog first. Returns the new lane id. Only meaningful under
        ``replica`` dispatch (serial/spmd planes are one lane by
        construction)."""
        ln = LaneState(len(self.lanes))
        self.lanes.append(ln)
        return ln.lane_id

    def _probe(self, now_s: float):
        """Revive lanes whose down-cooldown has elapsed (the re-probe
        path: a dead lane is not dead forever)."""
        for ln in self.lanes:
            if ln.down and now_s - ln.down_since_s >= self.reprobe_after_s:
                ln.down = False
                self.recoveries += 1
                if self.on_lane_up is not None:
                    self.on_lane_up(ln.lane_id, now_s)

    def _mark_down(self, ln: LaneState, now_s: float):
        ln.down = True
        ln.down_since_s = now_s
        self.faults += 1
        if self.on_lane_down is not None:
            self.on_lane_down(ln.lane_id, now_s)

    def _pick(self, now_s: float, exclude: Sequence[int] = ()) -> Optional[LaneState]:
        """Earliest-free healthy lane; ties break to the lowest id."""
        cands = [ln for ln in self.healthy_lanes() if ln.lane_id not in exclude]
        if not cands:
            return None
        return min(cands, key=lambda ln: (max(ln.busy_until_s, now_s), ln.lane_id))

    def _select(self, now_s: float) -> LaneState:
        """Pick a lane, burning armed faults (each fires once, marks the
        lane down, and the scheduler retries elsewhere)."""
        retried: list[int] = []
        while True:
            ln = self._pick(now_s, exclude=retried)
            if ln is None:
                raise RuntimeError(
                    "dispatch failed: no healthy lanes"
                    + (f" (faulted: {retried})" if retried else "")
                )
            if self._armed_faults.get(ln.lane_id, 0) > 0:
                self._armed_faults[ln.lane_id] -= 1
                self._mark_down(ln, now_s)
                self.retries += 1
                retried.append(ln.lane_id)
                continue
            ln._retried = tuple(retried)  # stashed for the outcome
            return ln

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------
    def _jitter_ms(self, service_ms: float) -> float:
        if self.straggler_p > 0.0 and self._rng.random_sample() < self.straggler_p:
            return service_ms * self.straggler_factor
        return service_ms

    def _book(self, ln: LaneState, start_s: float, dur_s: float) -> float:
        end_s = start_s + dur_s
        ln.busy_until_s = end_s
        ln.busy_s += dur_s
        ln.dispatches += 1
        if self.on_lane_read is not None:
            self.on_lane_read(ln.lane_id)
        return end_s

    def dispatch(self, run: Callable[[], tuple], occupy: bool = True) -> DispatchOutcome:
        """Run a unit of work on the lane plane.

        ``run() -> (payload, service_ms, ru)``. With ``occupy=False`` no
        lane is booked (host-path work whose internals already schedule
        their own lane rounds); otherwise the earliest-free healthy lane
        hosts the work, hedging a duplicate when the (jittered) service
        time trips ``hedge_at_ms``. Serial mode advances the clock to
        the finish, preserving the inline-execution timeline exactly.
        """
        now = self.clock.now()
        self._probe(now)
        if not occupy:
            payload, service_ms, ru = run()
            end = now + service_ms / 1000.0
            if self.mode == "serial":
                self.clock.advance(service_ms / 1000.0)
            return DispatchOutcome(payload, -1, now, end, ru)

        ln = self._select(now)
        retried = ln._retried
        payload, service_ms, ru = run()
        start = max(now, ln.busy_until_s)
        eff_ms = self._jitter_ms(service_ms)
        end = self._book(ln, start, eff_ms / 1000.0)

        hedged = hedge_won = False
        hedge_ru = 0.0
        hedge_lane = -1
        hedge_start = hedge_end = 0.0
        if (self.mode == "replica" and self.hedge_at_ms is not None
                and eff_ms > self.hedge_at_ms):
            ln2 = self._pick(now, exclude=(ln.lane_id,))
            if ln2 is not None:
                hedged = True
                self.hedges += 1
                hedge_ru = ru  # the duplicate execution bills in full
                self.hedge_ru_total += ru
                hedge_lane = ln2.lane_id
                start2 = max(start + self.hedge_at_ms / 1000.0,
                             ln2.busy_until_s, now)
                end2 = self._book(ln2, start2, self._jitter_ms(service_ms) / 1000.0)
                hedge_start, hedge_end = start2, end2
                if end2 < end:  # earliest finisher answers the client
                    hedge_won = True
                    self.hedges_won += 1
                    end = end2

        if self.mode == "serial":
            self.clock.advance(end - now)
        return DispatchOutcome(payload, ln.lane_id, start, end, ru,
                               hedged, hedge_ru, hedge_lane, hedge_won,
                               retried, hedge_start, hedge_end,
                               eff_ms > service_ms)

    def schedule_round(self, durations_ms: Sequence[float]) -> float:
        """Book one multi-cursor round — each duration on the earliest-
        free healthy lane — and return the round's makespan in ms.

        This is how a page refill's per-partition ``next_page`` fetches
        become ONE dispatch: with ≥ P lanes the round costs the max
        fetch, with 1 lane it degenerates to the host-loop sum.
        """
        now = self.clock.now()
        self._probe(now)
        end_max = now
        for ms in durations_ms:
            ln = self._select(now)
            start = max(now, ln.busy_until_s)
            end_max = max(end_max, self._book(ln, start, ms / 1000.0))
        return (end_max - now) * 1000.0

    def quiesce(self):
        """Advance the clock to the lane horizon (drain semantics)."""
        horizon = max((ln.busy_until_s for ln in self.lanes), default=0.0)
        now = self.clock.now()
        if horizon > now:
            self.clock.advance(horizon - now)

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        now = self.clock.now()
        horizon = max([ln.busy_until_s for ln in self.lanes] + [now])
        elapsed = max(horizon - self._born_s, 1e-9)
        return {
            "mode": self.mode,
            "lanes": len(self.lanes),
            "lane_busy_s": [round(ln.busy_s, 6) for ln in self.lanes],
            "lane_dispatches": [ln.dispatches for ln in self.lanes],
            "lane_down": [ln.down for ln in self.lanes],
            "lane_occupancy": [round(ln.busy_s / elapsed, 4) for ln in self.lanes],
            "hedges": self.hedges,
            "hedges_won": self.hedges_won,
            "hedge_ru_total": round(self.hedge_ru_total, 3),
            "faults": self.faults,
            "recoveries": self.recoveries,
            "retries": self.retries,
        }
