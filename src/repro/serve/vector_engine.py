"""VectorServeEngine — batched, admission-controlled vector-query serving.

The paper's headline numbers are *service-level*: <20 ms query latency over
10M vectors under sustained multi-tenant traffic, with RU-based resource
governance deciding who gets served (§2.2, §4). This engine models that
serving layer in front of the collection/partition stack:

  * **dynamic micro-batching** — independent client requests accumulate up
    to ``max_batch`` / ``max_wait_s`` and dispatch as ONE fixed-shape
    vmapped search (`partition.fanout.batched_fanout_search`), turning many
    small host calls into dense device work;
  * **shape bucketing** — batches pad to a small set of static
    (batch, L, k) signatures (`core.search.BATCH_BUCKETS`) so steady-state
    traffic triggers zero recompiles; the jit cache size is exported as a
    metric precisely because compile stalls are the tail-latency failure
    mode this design removes;
  * **dispatch plane** — micro-batches route through a ``LaneExecutor``
    (`serve.executor`): N replica lanes running concurrently under the
    simulated clock (``dispatch_mode="replica"``), straggler hedging with
    RU billed for duplicates, lane-health → replica routing; or ONE jitted
    shard_map program driving every partition's search as a data-parallel
    SPMD dispatch (``dispatch_mode="spmd"``, `partition.fanout.SpmdFanout`);
  * **RU-based admission control** — each tenant owns a
    ``store.ru.ResourceGovernor``; over-budget tenants get a 429-style
    `Throttled` rejection with a retry-after instead of degrading everyone
    (the paper's resource-governance story). Estimates come from an EMA of
    observed per-query RU and are settled against actuals post-execution;
  * **interleaved ingest** — upserts/deletes flow through a background
    mini-batch queue that alternates with query batches, so recall stays
    stable and query latency bounded *during* updates (§3.4, Fig 12/13);
  * **deterministic simulated clock + metrics** — service time comes from
    the calibrated §4.4 access-time model, arrivals from the workload
    generator, so p50/p95/p99, QPS, RU/s, batch occupancy and recompile
    counts are all reproducible offline (`serve.metrics`).

`VectorCollectionService` is a thin façade over this engine; later scale
PRs (caching, replication pressure, multi-backend) plug in here.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ..core import flat as fmod
from ..core import search as smod
from ..partition.fanout import (AllPartitionsFailed, SpmdFanout,
                                batched_fanout_search,
                                batched_filtered_fanout_search,
                                compile_partition_filter, merge_topk,
                                spmd_jit_cache_size)
from ..store.faults import CrashError
from ..store.ru import OpCounters, ResourceGovernor
from .executor import LaneExecutor
from .metrics import EngineMetrics, SimClock
from .obs import MetricsRegistry
from .policy import ControlPolicy, PolicySignals, make_policy
from .predicate import Predicate
from .trace import ANOMALY_DEGRADED, Tracer


def serving_jit_cache_size() -> int:
    """Total compiled-signature count across the serving hot path (graph
    search + re-rank + brute force + the spmd fan-out program). Flat
    trajectory == zero recompiles."""
    n = max(smod.jit_cache_size(), 0)
    for f in (fmod.brute_force, fmod.rerank):
        try:
            n += int(f._cache_size())
        except AttributeError:
            pass
    return n + spmd_jit_cache_size()


class Throttled(Exception):
    """429-style rejection: the tenant is over its provisioned RU budget."""

    def __init__(self, tenant: Any, retry_after_s: float):
        super().__init__(
            f"tenant {tenant!r} over RU budget; retry after {retry_after_s:.3f}s"
        )
        self.tenant = tenant
        self.retry_after_s = retry_after_s


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 16  # micro-batch dispatch threshold
    max_wait_s: float = 0.002  # oldest request never waits longer than this
    batch_buckets: tuple[int, ...] = smod.BATCH_BUCKETS
    beam_width: int = 4  # W-way frontier expansion per search round (§3.2
    #   beamWidth): ~W× fewer sequential rounds on the lockstep hot path
    search_list_multiplier: float = 5.0  # L = multiplier * k when unset
    dispatch_overhead_ms: float = 0.1  # host-side per-batch overhead
    tenant_ru_s: float = 10_000.0  # default per-tenant provisioned budget
    admission_control: bool = True
    admission_estimate_ru: float = 20.0  # prior until an EMA exists
    ru_ema_alpha: float = 0.25
    ingest_chunk: int = 64  # docs per interleaved ingest mini-batch
    ingest_interleave: int = 1  # ingest chunks drained per query batch
    ingest_ms_per_ru: float = 0.4  # §4.4: ~65 RU, ~25 ms per insert
    # ---- dispatch plane (serve.executor) ----
    dispatch_mode: str = "serial"  # serial | replica | spmd
    lanes: int = 4  # replica lanes when dispatch_mode == "replica"
    hedge_at_ms: Optional[float] = None  # straggler hedge threshold (replica)
    straggler_p: float = 0.0  # per-dispatch straggler probability
    straggler_factor: float = 4.0  # service-time inflation when straggling
    lane_reprobe_after_s: float = 5.0  # down-lane re-probe cooldown
    dispatch_seed: int = 0  # lane-plane RNG seed (straggler draws)
    # ---- observability (serve.trace / serve.obs) ----
    trace: bool = True  # per-request lifecycle traces; off = zero overhead
    flight_recorder: int = 256  # trace records retained (ring + anomaly ring)
    trace_slo_ms: Optional[float] = 50.0  # SLO-violating traces always captured
    # ---- fault tolerance ----
    # engine-wide deadline bound: every request's effective deadline is
    # min(request deadline, this). None → unbounded unless the request
    # sets one. Deadlines are *queue-abandonment* budgets: a request whose
    # deadline expires while still queued is answered 408 with its RU
    # reservation refunded, before any lane work is spent on it.
    default_deadline_ms: Optional[float] = None
    # ---- adaptive control plane (serve.policy) ----
    # "static" keeps every knob at its configured value (bit-identical to
    # the pre-policy engine); "adaptive" closes the loop: beam width,
    # ingest yield and topology actuate per ``pump()`` tick from the
    # observability rollups (see serve/policy.py for the decision rules)
    policy: str = "static"
    # the W decision ladder. Warmup must compile every (bucket, L, W)
    # signature in this set once — the engine clamps every policy W into
    # it, so steady-state adaptive traffic never recompiles
    policy_widths: tuple[int, ...] = (1, 2, 4)


@dataclasses.dataclass
class ServeRequest:
    rid: int
    vector: np.ndarray  # (D,)
    k: int = 10
    L: Optional[int] = None  # search list size; None → multiplier * k
    tenant: Any = "default"
    exact: bool = False
    shard_key: Any = None
    # declarative WHERE clause (serve.predicate). Predicates are hashable
    # by canonical key, so same-predicate requests coalesce into one
    # micro-batch sharing one compiled bitmap per partition — filtered
    # queries ride the batched path instead of falling off to host code.
    predicate: Optional[Predicate] = None
    # offered arrival time; < 0 → stamped with the clock at submit(). A
    # workload generator passes the true arrival so queueing delay under
    # overload is charged to latency even when the engine is running behind.
    arrival_s: float = -1.0
    reserved_ru: float = 0.0  # admission reservation, reconciled at dispatch
    admit_s: float = -1.0  # when the admission decision was made (trace plane)
    # queue-abandonment budget (ms from arrival). None → engine default.
    # A request still queued past its deadline is abandoned with a 408
    # and its reservation refunded; a request already dispatched runs to
    # completion (its answer may arrive "late" but is still a 200).
    deadline_ms: Optional[float] = None
    deadline_s: float = np.inf  # absolute expiry, stamped at submit()


@dataclasses.dataclass
class ServeResponse:
    rid: int
    status: int  # 200 served, 408 deadline-abandoned, 429 throttled
    ids: Optional[np.ndarray] = None  # (k,)
    dists: Optional[np.ndarray] = None
    ru: float = 0.0
    plan: str = ""
    latency_ms: float = 0.0  # queue wait + modelled service time
    wait_ms: float = 0.0
    retry_after_s: float = 0.0
    batch_size: int = 0  # true lanes in the dispatching micro-batch
    # False → degraded: one or more partitions were down/faulted and the
    # results merge only the survivors (the plan carries a
    # ``+degraded[pids]`` marker naming the missing partitions)
    complete: bool = True


class VectorServeEngine:
    """Batched, admission-controlled serving in front of a Collection."""

    def __init__(
        self,
        collection,  # partition.Collection
        cfg: EngineConfig = EngineConfig(),
        clock: Optional[SimClock] = None,
        resolver: Optional[Callable[[Any], Sequence]] = None,
        replica_sets: Optional[Sequence] = None,  # partition.ReplicaSet list
        spmd_mesh=None,  # jax Mesh for dispatch_mode="spmd"; None → default
        policy: Optional[ControlPolicy] = None,  # None → from cfg.policy
    ):
        self.collection = collection
        self.cfg = cfg
        self.clock = clock or SimClock()
        # shard_key → partition list (the service wires tenant collections in)
        self._resolve = resolver or (lambda _sk: collection.partitions)
        # lane health mirrors into replica health: a down lane kills its
        # replica in every set (reads stop routing there), a re-probed lane
        # rebuilds it through the real snapshot+WAL recovery path
        self.replica_sets = list(replica_sets) if replica_sets else []
        # partition → its replica set, for per-partition health checks at
        # dispatch time (degradation: a partition whose replica set is
        # entirely down is skipped, not fatal)
        self._rs_by_partition = {id(rs.partition): rs
                                 for rs in self.replica_sets}
        on_down = on_up = on_read = None
        if self.replica_sets:
            def on_down(lane: int, now_s: float):
                for rs in self.replica_sets:
                    rs.kill(lane % len(rs.replicas), now_s=now_s)

            def on_up(lane: int, now_s: float):
                for rs in self.replica_sets:
                    rs.probe_dead(now_s)

            def on_read(lane: int):
                for rs in self.replica_sets:
                    rs.note_read(lane % len(rs.replicas))
        self.executor = LaneExecutor(
            self.clock, lanes=cfg.lanes, mode=cfg.dispatch_mode,
            hedge_at_ms=cfg.hedge_at_ms, straggler_p=cfg.straggler_p,
            straggler_factor=cfg.straggler_factor,
            reprobe_after_s=cfg.lane_reprobe_after_s, seed=cfg.dispatch_seed,
            on_lane_down=on_down, on_lane_up=on_up, on_lane_read=on_read,
        )
        self._spmd_mesh = spmd_mesh
        self._spmd_fanout: Optional[SpmdFanout] = None
        self.queue: list[ServeRequest] = []
        self._ingest_q: deque[tuple[str, Callable[[], float], int, Any]] = deque()
        self.responses: dict[int, ServeResponse] = {}
        self.tenants: dict[Any, ResourceGovernor] = {}
        self._ru_ema: dict[Any, float] = {}
        self._next_rid = 0
        self.metrics = EngineMetrics(started_s=self.clock.now())
        # observability plane: always-on labeled registry (cheap), plus the
        # lifecycle tracer (zero-cost when cfg.trace is off — begin()
        # returns None and every emission site guards on it)
        self.obs = MetricsRegistry()
        self.tracer = Tracer(self.clock, enabled=cfg.trace,
                             capacity=cfg.flight_recorder,
                             slo_ms=cfg.trace_slo_ms)
        # control plane (serve.policy): disabled policies short-circuit
        # before signal collection — the static path never pays for them
        self.policy = policy if policy is not None else make_policy(cfg)
        self._allowed_widths = tuple(sorted(set(cfg.policy_widths))) \
            or (cfg.beam_width,)
        self._decision = self.policy.initial()
        self._last_scale: Optional[dict] = None

    def reset_metrics(self):
        """Metrics epoch boundary (benchmark warmup): fresh aggregates,
        fresh labeled registry, fresh flight recorder. Tenant governors
        keep their budgets — only the telemetry resets. The policy's
        rollup window re-bases with the registry (its deltas would
        otherwise go negative against the fresh epoch)."""
        self.metrics = EngineMetrics(started_s=self.clock.now())
        self.obs = MetricsRegistry()
        self.tracer.reset()
        self.policy.reset_epoch()

    # ------------------------------------------------------------------
    # admission control
    # ------------------------------------------------------------------
    def tenant_governor(self, tenant: Any) -> ResourceGovernor:
        if tenant not in self.tenants:
            self.tenants[tenant] = ResourceGovernor(self.cfg.tenant_ru_s)
            self.tenants[tenant].clock_s = self.clock.now()
        return self.tenants[tenant]

    def set_tenant_budget(self, tenant: Any, provisioned_ru_s: float):
        gov = ResourceGovernor(provisioned_ru_s)
        gov.clock_s = self.clock.now()
        self.tenants[tenant] = gov

    def _admit(self, tenant: Any) -> tuple[Optional[ServeResponse], float]:
        """(None, reserved_ru) when admitted — the estimate is consumed
        upfront so a burst of submits can't all pass against the same
        untouched balance; (429-response, 0) when throttled."""
        if not self.cfg.admission_control:
            return None, 0.0
        gov = self.tenant_governor(tenant)
        est = self._ru_ema.get(tenant, self.cfg.admission_estimate_ru)
        decision = gov.try_admit(est, now_s=self.clock.now())
        if decision.admitted:
            gov.settle(est, now_s=self.clock.now())  # reserve; reconciled later
            return None, est
        self.metrics.queries_throttled += 1
        return ServeResponse(
            rid=-1, status=429, retry_after_s=decision.retry_after_s
        ), 0.0

    def _settle(self, tenant: Any, actual_ru: float, reserved_ru: float):
        """Reconcile the upfront reservation against the actual cost and
        fold the actual into the tenant's admission estimate (EMA)."""
        self.tenant_governor(tenant).settle(
            actual_ru - reserved_ru, now_s=self.clock.now()
        )
        a = self.cfg.ru_ema_alpha
        prev = self._ru_ema.get(tenant, actual_ru)
        self._ru_ema[tenant] = (1 - a) * prev + a * actual_ru

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def submit(self, req: ServeRequest) -> Optional[ServeResponse]:
        """Enqueue a query. Returns a 429 response immediately when the
        tenant is over budget, else None (the answer arrives at dispatch)."""
        rejected, reserved = self._admit(req.tenant)
        if rejected is not None:
            resp = dataclasses.replace(rejected, rid=req.rid)
            self.responses[req.rid] = resp
            self._note_throttle("query", req.rid, req.tenant,
                                resp.retry_after_s)
            return resp
        req.reserved_ru = reserved
        req.admit_s = self.clock.now()
        if req.arrival_s < 0:
            req.arrival_s = self.clock.now()
        dl = req.deadline_ms
        if self.cfg.default_deadline_ms is not None:
            dl = (self.cfg.default_deadline_ms if dl is None
                  else min(dl, self.cfg.default_deadline_ms))
        if dl is not None:
            req.deadline_s = req.arrival_s + dl / 1000.0
        self.queue.append(req)
        return None

    def submit_query(self, vector: np.ndarray, k: int = 10,
                     L: Optional[int] = None, tenant: Any = "default",
                     exact: bool = False, shard_key: Any = None,
                     arrival_s: float = -1.0,
                     predicate: Optional[Predicate] = None,
                     deadline_ms: Optional[float] = None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.submit(ServeRequest(rid=rid, vector=np.asarray(vector, np.float32),
                                 k=k, L=L, tenant=tenant, exact=exact,
                                 shard_key=shard_key, arrival_s=arrival_s,
                                 predicate=predicate, deadline_ms=deadline_ms))
        return rid

    def submit_ingest(self, kind: str, apply_fn: Callable[[], float],
                      n_ops: int, tenant: Any = "default"):
        """Enqueue one pre-chunked ingest thunk (returns its RU charge).
        The service layer slices upserts/deletes into ``ingest_chunk``-sized
        thunks; the engine alternates them with query batches. ``tenant``
        attributes the write RU in the observability registry."""
        self._ingest_q.append((kind, apply_fn, n_ops, tenant))

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------
    def _group_key(self, r: ServeRequest):
        L = r.L or max(r.k, int(round(self.cfg.search_list_multiplier * r.k)))
        pk = r.predicate.key() if r.predicate is not None else None
        return (r.shard_key, r.k, L, r.exact, pk)

    def _due_groups(self, force: bool) -> list[tuple]:
        groups: dict[tuple, list[ServeRequest]] = {}
        for r in self.queue:
            groups.setdefault(self._group_key(r), []).append(r)
        now = self.clock.now()
        due = []
        for key, reqs in groups.items():
            oldest = min(r.arrival_s for r in reqs)
            if force or len(reqs) >= self.cfg.max_batch \
                    or now - oldest >= self.cfg.max_wait_s:
                due.append((oldest, key, reqs))
        due.sort(key=lambda t: t[0])  # oldest group first
        return [(key, reqs) for _, key, reqs in due]

    def pump(self, force: bool = False) -> int:
        """Dispatch due micro-batches (and interleave ingest). Returns the
        number of queries served this pump. With an enabled control
        policy every loop iteration opens with a policy tick — the
        beam-width / ingest-yield decision is PER MICRO-BATCH, re-read
        from the rollups as the backlog drains, and a tick may fire a
        topology action (split / lane scale-out)."""
        served = 0
        progressed = True
        while progressed:
            progressed = False
            self._policy_tick()
            for key, reqs in self._due_groups(force):
                batch = reqs[: self.cfg.max_batch]
                self._dispatch(key, batch)
                served += len(batch)
                self._interleave_ingest()
                progressed = True
                break  # re-derive groups: the clock moved
        if not served:
            self._idle_ingest()
        return served

    def drain(self) -> dict[int, ServeResponse]:
        """Run to quiescence: every queued query answered, ingest applied."""
        while self.queue or self._ingest_q:
            if not self.pump(force=False) and self.queue:
                self.pump(force=True)
        # replica lanes are future-scheduled: bring the clock to the lane
        # horizon so drained == everything actually finished
        self.executor.quiesce()
        return self.responses

    def query_sync(self, req: ServeRequest) -> ServeResponse:
        """Submit + force a flush — the façade path for blocking callers.
        Anything already queued for the same signature rides along (so even
        'synchronous' traffic coalesces under concurrency). The response is
        collected (popped), so sustained façade traffic doesn't accumulate
        state in ``responses``."""
        rejected = self.submit(req)
        if rejected is not None:
            self.responses.pop(req.rid, None)
            return rejected
        while req.rid not in self.responses:
            self.pump(force=True)
        return self.responses.pop(req.rid)

    def pop_response(self, rid: int) -> Optional[ServeResponse]:
        """Collect (and free) a response. Async submitters should prefer
        this over reading ``responses`` directly — uncollected responses
        are retained for the engine's lifetime."""
        return self.responses.pop(rid, None)

    # ------------------------------------------------------------------
    # dispatch
    # ------------------------------------------------------------------
    def _dispatch(self, key: tuple, batch: list[ServeRequest]):
        in_batch = set(id(r) for r in batch)
        self.queue = [r for r in self.queue if id(r) not in in_batch]
        # deadline sweep: a request whose budget expired while it queued is
        # abandoned HERE — before any lane work is spent on it — with its
        # admission reservation refunded (the 408 path)
        now = self.clock.now()
        expired = [r for r in batch if r.deadline_s <= now]
        if expired:
            batch = [r for r in batch if r.deadline_s > now]
            for r in expired:
                self._expire(r, now)
            if not batch:
                return
        # a batch beyond the largest bucket is split into top-bucket chunks
        # instead of minting a new padded shape (each extra shape is a
        # compile stall — the tail-latency failure mode bucketing removes)
        top = max(self.cfg.batch_buckets)
        chunks = [batch[lo : lo + top] for lo in range(0, len(batch), top)]
        for i, chunk in enumerate(chunks):
            try:
                self._dispatch_chunk(key, chunk)
            except Exception:
                # the failing chunk refunds itself (below); the undispatched
                # remainder was already pulled off the queue, so hand its
                # admission reservations back too before propagating
                for r in (q for c in chunks[i + 1 :] for q in c):
                    self.tenant_governor(r.tenant).refund(r.reserved_ru)
                raise

    def _partition_health(self, p) -> bool:
        """False when the partition's entire replica set is down (degrade:
        skip it); partitions without a replica set are always healthy."""
        rs = self._rs_by_partition.get(id(p))
        return rs is None or bool(rs.healthy())

    def _expire(self, r: ServeRequest, now_s: float):
        """Abandon one deadline-expired queued request: refund the
        admission reservation (no work was done on the tenant's dime),
        answer 408, and emit a trace whose root spans — admission point,
        queue [arrival → expiry], deadline point — tile the waited
        interval exactly like a served request's do."""
        self.tenant_governor(r.tenant).refund(r.reserved_ru)
        waited_ms = (now_s - r.arrival_s) * 1000.0
        assert r.rid not in self.responses
        self.responses[r.rid] = ServeResponse(
            rid=r.rid, status=408, latency_ms=waited_ms, wait_ms=waited_ms,
        )
        self.metrics.queries_deadline += 1
        ts = str(r.tenant)
        self.obs.inc("serve_requests_total", tenant=ts, kind="query",
                     status="408")
        self.obs.inc("serve_deadline_total", tenant=ts)
        tr = self.tracer.begin("query", r.tenant, r.rid)
        if tr is None:
            return
        tr.span("admission", "admission", r.admit_s, r.admit_s,
                reserved_ru=r.reserved_ru, refunded=True)
        tr.span("queue", "queue", r.arrival_s, now_s)
        tr.span("deadline", "deadline", now_s, now_s,
                deadline_ms=(r.deadline_s - r.arrival_s) * 1000.0,
                waited_ms=waited_ms)
        self.tracer.finish(tr, status=408, ru=0.0, latency_ms=waited_ms,
                           t0_s=r.arrival_s, t1_s=now_s)

    def _dispatch_chunk(self, key: tuple, batch: list[ServeRequest]):
        shard_key, k, L, exact, _pred_key = key
        predicate = batch[0].predicate  # whole group shares one canonical key
        queries = np.stack([r.vector for r in batch]).astype(np.float32)
        health = self._partition_health if self.replica_sets else None
        # ONE resolved chunk-plan beam width: every search flavor below
        # shares it, and the control policy may move it per micro-batch
        # (clamped into the compiled policy_widths signature set)
        beam_width = self._chunk_beam_width()

        def run():
            # the plan body: the executor decides WHERE/WHEN this service
            # time is spent, never what runs
            partitions = self._resolve(shard_key)
            if exact:
                ids, dists, ru_total, service_ms, plan, pspans, failed = \
                    self._exact_scan(partitions, queries, k,
                                     predicate=predicate, health=health)
            else:
                if predicate is not None:
                    ids, dists, info = batched_filtered_fanout_search(
                        partitions, queries, k, predicate, L=L,
                        batch_buckets=self.cfg.batch_buckets,
                        beam_width=beam_width, health=health,
                    )
                    plan = info["plan"]
                elif self.cfg.dispatch_mode == "spmd":
                    ids, dists, info = self._spmd().search(
                        partitions, queries, k, L=L,
                        batch_buckets=self.cfg.batch_buckets,
                        beam_width=beam_width,
                        rerank_multiplier=self.cfg.search_list_multiplier,
                        health=health,
                    )
                    plan = "graph-spmd"
                else:
                    ids, dists, info = batched_fanout_search(
                        partitions, queries, k, L=L,
                        batch_buckets=self.cfg.batch_buckets,
                        beam_width=beam_width, health=health,
                    )
                    plan = "graph"
                ru_total = info["ru_total"]
                service_ms = info["service_latency_ms"]
                pspans = self._partition_spans(info)
                failed = list(info.get("failed_partitions", ()))
                pstats = info["stats_per_partition"]
                if pstats:
                    self.metrics.note_hops(
                        float(np.mean([s.hops for s in pstats])), len(batch)
                    )
            # degraded fan-out: the survivors answered; record each missing
            # partition as a zero-duration failure span under the lane
            for pid, err in failed:
                pspans.append((0.0, dict(pid=int(pid), failed=True,
                                         error=str(err), ru=0.0)))
            service_ms += self.cfg.dispatch_overhead_ms
            return (ids, dists, plan, pspans, failed), service_ms, ru_total

        try:
            out = self.executor.dispatch(run)
        except Exception:
            # hand the admission reservations back — a failed dispatch must
            # not bleed the tenants' budgets
            for r in batch:
                self.tenant_governor(r.tenant).refund(r.reserved_ru)
            raise

        ids, dists, plan, pspans, failed = out.payload
        complete = not failed
        if failed:
            plan += "+degraded[" + ",".join(str(p) for p, _ in failed) + "]"
        # paged-tier accounting (ISSUE 10): per-query hit/miss shares from
        # the partition stats, surfaced as metrics + rerank child spans
        tier_h, tier_m = self._tier_totals(pspans)
        rerank_spans = self._rerank_spans(pspans)
        ru_work = out.ru  # the batch's search work, hedge surcharge apart
        ru_total = out.ru + out.hedge_ru  # hedged duplicates bill in full
        service_ms = (out.end_s - out.start_s) * 1000.0
        if out.hedged:
            self.metrics.note_hedge(out.hedge_won, out.hedge_ru)

        B = len(batch)
        bucket = smod.next_bucket(B, self.cfg.batch_buckets)
        self.metrics.note_batch(B, bucket, service_ms, ru_work,
                                serving_jit_cache_size())
        ru_q = ru_total / B  # what the client is billed (hedge included)
        work_q = ru_work / B
        hedge_q = out.hedge_ru / B
        for i, r in enumerate(batch):
            # start_s includes lane queue wait: under replica dispatch a
            # batch that finds every lane busy pays that wait in its
            # latency percentiles, exactly like a real executor pool
            wait_ms = (out.start_s - r.arrival_s) * 1000.0
            lat_ms = (out.end_s - r.arrival_s) * 1000.0
            assert r.rid not in self.responses, (
                f"rid {r.rid} already answered: one admitted request must "
                f"produce exactly one response/latency sample (hedge and "
                f"retry duplicates are lane-plane internals)"
            )
            self.responses[r.rid] = ServeResponse(
                rid=r.rid, status=200, ids=ids[i], dists=dists[i], ru=ru_q,
                plan=plan, latency_ms=lat_ms, wait_ms=wait_ms, batch_size=B,
                complete=complete,
            )
            self.metrics.queries_ok += 1
            if not complete:
                self.metrics.queries_degraded += 1
                self.obs.inc("serve_degraded_total", tenant=str(r.tenant))
            self.metrics.latency_ms.observe(lat_ms)
            self.metrics.wait_ms.observe(wait_ms)
            self._settle(r.tenant, ru_q, r.reserved_ru)
            ts = str(r.tenant)
            self.obs.inc("serve_requests_total", tenant=ts, kind="query",
                         status="200")
            self.obs.inc("serve_ru_total", work_q, tenant=ts, op="query")
            if out.hedge_ru:
                self.obs.inc("serve_ru_total", hedge_q, tenant=ts, op="hedge")
            self.obs.observe("serve_latency_ms", lat_ms, tenant=ts)
            self.obs.observe("serve_stage_ms", wait_ms, stage="queue")
            self.obs.observe("serve_stage_ms", lat_ms - wait_ms, stage="lane")
            if tier_h or tier_m:
                self.obs.inc("serve_tier_total", tier_h, tenant=ts,
                             tier="vector", outcome="hit")
                self.obs.inc("serve_tier_total", tier_m, tenant=ts,
                             tier="vector", outcome="miss")
            self._emit_trace("query", r.rid, r.tenant, r.arrival_s,
                             r.admit_s, r.reserved_ru, out, plan, B, bucket,
                             ru_q, lat_ms, pspans=pspans,
                             extra_spans=rerank_spans,
                             anomalies=() if complete
                             else (ANOMALY_DEGRADED,),
                             beam_width=beam_width)

    # ------------------------------------------------------------------
    # trace plane
    # ------------------------------------------------------------------
    @staticmethod
    def _partition_spans(info: dict) -> list:
        """(latency_ms, attrs) per searched partition from a fan-out info
        dict — the RU plus the hop/expansion/cmps counters the RU/latency
        split is computed from (store.ru.counters_for_ru /
        counters_for_latency)."""
        pids = info.get("partition_ids", ())
        stats = info.get("stats_per_partition") or [None] * len(pids)
        out = []
        for pid, ru_i, lat_i, st in zip(pids, info["ru_per_partition"],
                                        info["server_latencies_ms"], stats):
            attrs = dict(pid=int(pid), ru=float(ru_i))
            if st is not None:
                attrs.update(hops=float(st.hops),
                             expansions=float(st.expansions),
                             cmps=float(st.cmps), plan=st.plan,
                             tier_hits=float(getattr(st, "tier_hits", 0.0)),
                             tier_misses=float(
                                 getattr(st, "tier_misses", 0.0)))
            out.append((float(lat_i), attrs))
        return out

    def _tier_totals(self, pspans: Sequence) -> tuple[float, float]:
        """Per-query paged-tier touches summed over the fan-out (partition
        stats carry per-query means, so the sum IS the per-request
        share)."""
        h = sum(float(a.get("tier_hits", 0.0)) for _, a in pspans)
        m = sum(float(a.get("tier_misses", 0.0)) for _, a in pspans)
        return h, m

    def _rerank_spans(self, pspans: Sequence) -> list:
        """One rerank child span per partition that touched the paged
        vector tier: duration = the modelled miss-fetch time, attrs carry
        the hit/miss counts (the trace-plane face of ISSUE 10)."""
        us_pp = 0.0
        parts = self.collection.partitions
        if parts:
            us_pp = parts[0].providers.meter.cfg.us_per_vector_page
        out = []
        for _, a in pspans:
            if "tier_hits" not in a:
                continue
            th, tm = a["tier_hits"], a["tier_misses"]
            if th == 0.0 and tm == 0.0:
                continue
            out.append(dict(
                name=f"rerank[p{a['pid']}]", stage="rerank",
                dur_ms=tm * us_pp / 1000.0,
                attrs=dict(pid=a["pid"], tier_hits=th, tier_misses=tm),
            ))
        return out

    def _note_throttle(self, kind: str, rid: int, tenant: Any,
                       retry_after_s: float):
        """Registry + trace bookkeeping for a 429 rejection."""
        ts = str(tenant)
        self.obs.inc("serve_requests_total", tenant=ts, kind=kind,
                     status="429")
        self.obs.inc("serve_throttled_total", tenant=ts)
        tr = self.tracer.begin(kind, tenant, rid)
        if tr is None:
            return
        now = self.clock.now()
        tr.span("admission", "admission", now, now, throttled=True,
                retry_after_s=retry_after_s)
        self.tracer.finish(tr, status=429, ru=0.0, latency_ms=0.0,
                           t0_s=now, t1_s=now)

    def _emit_trace(self, kind: str, rid: int, tenant: Any, arrival_s: float,
                    admit_s: float, reserved_ru: float, out, plan: str,
                    batch_size: int, bucket: int, ru: float, lat_ms: float,
                    pspans: Sequence = (), extra_spans: Sequence = (),
                    anomalies: tuple = (),
                    beam_width: Optional[int] = None):
        """Record one served request's lifecycle trace from its dispatch
        outcome. The root spans — queue [arrival → lane start] and lane
        [lane start → completion] — tile the request interval, so their
        summed duration equals the recorded latency (the reconciliation
        invariant ``trace.validate_trace_record`` enforces). admission and
        batch_form are point events on the root; partition fan-out, the
        hedge duplicate, fault retries and the merge hang off the lane
        span as its parallel decomposition."""
        tr = self.tracer.begin(kind, tenant, rid)
        if tr is None:
            return
        start, end = out.start_s, out.end_s
        q1 = min(max(start, arrival_s), end)  # tiling-safe lane boundary
        tr.span("admission", "admission", admit_s, admit_s,
                reserved_ru=reserved_ru)
        tr.span("queue", "queue", arrival_s, q1)
        attrs = dict(batch_size=batch_size, bucket=bucket, plan=plan)
        if beam_width is not None:  # the resolved chunk-plan W (policy-set)
            attrs["beam_width"] = beam_width
        tr.span("batch_form", "batch_form", q1, q1, **attrs)
        lane = tr.span("lane", "lane", q1, end, lane=out.lane,
                       hedged=out.hedged, straggled=out.straggled,
                       retried_lanes=list(out.retried_lanes), ru=ru)
        for lat_i, attrs in pspans:
            tr.span(f"partition[p{attrs['pid']}]", "partition",
                    start, start + lat_i / 1000.0, parent=lane, **attrs)
        for sp in extra_spans:
            tr.span(sp["name"], sp["stage"], start,
                    start + sp["dur_ms"] / 1000.0, parent=lane,
                    **sp.get("attrs", {}))
        for lid in out.retried_lanes:
            tr.span(f"retry[lane{lid}]", "retry", start, start, parent=lane,
                    lane=lid)
        if out.hedged:
            tr.span("hedge", "hedge", out.hedge_start_s, out.hedge_end_s,
                    parent=lane, lane=out.hedge_lane, won=out.hedge_won,
                    ru=out.hedge_ru)
        ov = min(self.cfg.dispatch_overhead_ms / 1000.0, end - start)
        tr.span("merge", "merge", end - max(ov, 0.0), end, parent=lane)
        self.tracer.finish(tr, status=200, ru=ru, latency_ms=lat_ms,
                           t0_s=arrival_s, t1_s=end, anomalies=anomalies)

    def _spmd(self) -> SpmdFanout:
        if self._spmd_fanout is None:
            mesh = self._spmd_mesh
            if mesh is None:
                from ..launch.mesh import make_serve_mesh
                mesh = make_serve_mesh()
            self._spmd_fanout = SpmdFanout(mesh)
        return self._spmd_fanout

    def _exact_scan(self, partitions, queries: np.ndarray, k: int,
                    predicate: Optional[Predicate] = None, health=None):
        """Batched VectorDistance(..., true): bucketed brute force per
        partition + merge (the paper's full-scan plan, RU-costed as a
        quantized-ish scan). With ``predicate`` the flat scan runs over
        the FILTERED subset — the compiled bitmap masks the scan, so
        ``WHERE`` + ``VectorDistance(..., true)`` brute-forces exactly the
        matching documents instead of silently ignoring the filter.
        ``health``-failed or faulting partitions degrade (skipped, listed
        in the returned ``failed``); only every partition failing raises
        ``AllPartitionsFailed``."""
        B = len(queries)
        plan = "exact" if predicate is None else "exact-filtered"
        failed: list = []  # (pid, error) per unreachable partition
        if not partitions:  # empty tenant collection: nothing to scan
            return (np.full((B, k), -1, np.int64), np.full((B, k), np.inf),
                    0.0, 0.0, plan, [], failed)
        padded = smod.pad_batch_np(
            queries, smod.next_bucket(B, self.cfg.batch_buckets)
        )
        ids_l, d_l, ru, service_ms = [], [], 0.0, 0.0
        pspans: list = []  # (latency_ms, attrs) per scanned partition
        answered = 0
        for p in partitions:
            if health is not None and not health(p):
                failed.append((p.pid, "replica set down"))
                continue
            try:
                pv = p.providers
                scan_mask = pv.live
                n_scan = p.num_docs
                ru_p = 0.0
                if predicate is not None:
                    if p.num_docs == 0:
                        answered += 1
                        continue
                    mask, _words, nreads = compile_partition_filter(p, predicate)
                    # bill the compile's posting lookups even when the
                    # partition is then skipped as a no-match
                    ru_p += nreads * pv.meter.cfg.ru_per_prop_read
                    if mask is None:
                        ru += ru_p
                        answered += 1
                        continue
                    scan_mask = mask & pv.live
                    n_scan = int(scan_mask.sum())
                ids, dists = fmod.brute_force(
                    jnp.asarray(padded), jnp.asarray(pv.vectors),
                    jnp.asarray(scan_mask), k=k, metric=p.index.cfg.metric,
                )
            except CrashError:
                raise  # injected process kill: never degrade past it
            except Exception as e:  # noqa: BLE001 — degrade, don't fail
                failed.append((p.pid, f"{type(e).__name__}: {e}"))
                continue
            answered += 1
            ids_l.append(p.index._to_doc_ids(np.asarray(ids))[:B])
            d_l.append(np.asarray(dists)[:B])
            # every lane scans the (filtered) subset: full scan at
            # quantized-ish cost, PER QUERY (RU must not deflate with
            # batch size)
            ru_p += 0.5 * n_scan * 0.0125 * B
            # paged-tier touch (ISSUE 10): an exact scan streams every
            # scanned vector through once, so non-resident pages bill one
            # fetch for the whole batch (shared stream, NOT ×B) and the
            # sequential sweep must not evict the working set (admit=False
            # scan resistance)
            th = tm = 0
            pages = getattr(pv, "pages", None)
            if pages is not None and n_scan:
                th, tm, _ = pages.touch(np.nonzero(np.asarray(scan_mask))[0],
                                        admit=False)
                ru_p += tm * pv.meter.cfg.ru_per_vector_page
            ru += ru_p
            # partitions scan in parallel — client latency tracks the worst
            # partition (§4.3), same model as the graph path
            lat_p = pv.meter.latency_ms(OpCounters(quant_reads=n_scan,
                                                   vector_page_misses=tm))
            service_ms = max(service_ms, lat_p)
            pspans.append((lat_p, dict(pid=int(p.pid), ru=ru_p,
                                       n_scan=n_scan, plan=plan,
                                       tier_hits=float(th) / max(B, 1),
                                       tier_misses=float(tm) / max(B, 1))))
        if failed and answered == 0:
            raise AllPartitionsFailed(
                f"exact scan: all partitions failed: {failed}"
            )
        if not ids_l:  # predicate matched nothing anywhere
            return (np.full((B, k), -1, np.int64), np.full((B, k), np.inf),
                    ru, service_ms, plan, pspans, failed)
        ids, dists = merge_topk(ids_l, d_l, k)
        return ids, dists, ru, service_ms, plan, pspans, failed

    # ------------------------------------------------------------------
    # host-path execution (filtered plans need the document store; the
    # service builds the per-partition masks, the engine still owns
    # admission, clock, RU settlement and metrics)
    # ------------------------------------------------------------------
    def execute_host(self, tenant: Any, plan: str,
                     fn: Callable[[], tuple],
                     is_page: bool = False) -> ServeResponse:
        """Run one host-side plan body under engine accounting: admission
        (raises ``Throttled`` with the reservation untouched), clock, RU
        settlement + EMA, and metrics. ``fn`` returns (ids, dists, ru,
        service_ms) or (ids, dists, ru, service_ms, plan) — the 5-tuple
        form lets the body report the plan it actually executed (e.g. the
        per-partition aggregate of a filtered query). A 6th element may
        carry trace child spans — dicts of (name, stage, dur_ms, attrs) —
        which land under the request's lane span (e.g. a page's
        per-partition fetch rounds from ``paged_fanout_search``)."""
        kind = "page" if is_page else "query"
        rejected, reserved = self._admit(tenant)
        if rejected is not None:
            self._note_throttle(kind, -1, tenant, rejected.retry_after_s)
            raise Throttled(tenant, rejected.retry_after_s)
        submit_s = self.clock.now()

        def run():
            out = fn()
            ids, dists, ru, service_ms = out[:4]
            body_plan = out[4] if len(out) > 4 else plan
            extra_spans = out[5] if len(out) > 5 else ()
            return ((ids, dists, body_plan, extra_spans),
                    service_ms + self.cfg.dispatch_overhead_ms, ru)

        # page bodies schedule their own multi-cursor refill rounds on the
        # lanes (paged_fanout_search), so they must not also book a lane
        try:
            out = self.executor.dispatch(run, occupy=not is_page)
        except Exception:
            # e.g. a user filter predicate raising: refund the reservation
            self.tenant_governor(tenant).refund(reserved)
            raise
        ids, dists, plan_out, extra_spans = out.payload
        ru_work = out.ru
        ru = out.ru + out.hedge_ru
        if out.hedged:
            self.metrics.note_hedge(out.hedge_won, out.hedge_ru)
        service_ms = (out.end_s - out.start_s) * 1000.0
        wait_ms = (out.start_s - submit_s) * 1000.0
        lat_ms = (out.end_s - submit_s) * 1000.0
        self._settle(tenant, ru, reserved)
        self.metrics.queries_ok += 1
        if is_page:
            self.metrics.pages_served += 1
        self.metrics.latency_ms.observe(lat_ms)
        self.metrics.wait_ms.observe(wait_ms)
        self.metrics.note_batch(1, 1, service_ms, ru_work,
                                serving_jit_cache_size())
        ts = str(tenant)
        self.obs.inc("serve_requests_total", tenant=ts, kind=kind,
                     status="200")
        self.obs.inc("serve_ru_total", ru_work, tenant=ts, op=kind)
        if out.hedge_ru:
            self.obs.inc("serve_ru_total", out.hedge_ru, tenant=ts,
                         op="hedge")
        self.obs.observe("serve_latency_ms", lat_ms, tenant=ts)
        self.obs.observe("serve_stage_ms", wait_ms, stage="queue")
        self.obs.observe("serve_stage_ms", lat_ms - wait_ms, stage="lane")
        self._emit_trace(kind, -1, tenant, submit_s, submit_s, reserved,
                         out, plan_out, 1, 1, ru, lat_ms,
                         extra_spans=extra_spans)
        return ServeResponse(rid=-1, status=200, ids=ids, dists=dists, ru=ru,
                             plan=plan_out, latency_ms=lat_ms, wait_ms=wait_ms,
                             batch_size=1)

    # ------------------------------------------------------------------
    # control plane (serve.policy)
    # ------------------------------------------------------------------
    def _chunk_beam_width(self) -> int:
        """The resolved per-micro-batch W. Static policy → the config
        constant, untouched. Active policy → the current decision,
        clamped into ``policy_widths`` (the compiled signature set) so a
        policy bug can never mint a compile stall mid-traffic."""
        if not self.policy.enabled:
            return self.cfg.beam_width
        W = self._decision.beam_width
        if W in self._allowed_widths:
            return W
        return min(self._allowed_widths, key=lambda w: abs(w - W))

    def _policy_tick(self):
        """One control-loop evaluation at the top of ``pump()``: collect
        rollup signals, ask the policy, record knob moves in the
        ``serve_policy_total`` metric family, actuate topology."""
        if not self.policy.enabled:
            return
        prev = self._decision
        sig = self._policy_signals()
        dec = self.policy.tick(sig)
        self.metrics.policy_ticks += 1
        if dec.beam_width != prev.beam_width:
            self.metrics.policy_w_changes += 1
            self.obs.inc("serve_policy_total", knob="beam_width",
                         action=f"w{dec.beam_width}")
        if dec.ingest_interleave != prev.ingest_interleave:
            self.obs.inc("serve_policy_total", knob="ingest",
                         action=f"interleave{dec.ingest_interleave}")
        if dec.idle_ingest != prev.idle_ingest:
            self.obs.inc("serve_policy_total", knob="ingest",
                         action=f"idle{dec.idle_ingest}")
        self._decision = dec
        if dec.cache_step:
            self._apply_cache_step(dec.cache_step)
        if dec.scale is not None:
            self._apply_scale(dec, sig)

    def _policy_signals(self) -> PolicySignals:
        """The policy's view of the plane, derived from the same rollups
        operators read (``observability_summary``) — never raw counters."""
        summ = self.observability_summary()
        stages = {name: (int(row["count"]), float(row["total_ms"]))
                  for name, row in summ["stages"].items()}
        ru_total = sum(
            row["ru_query"] + row["ru_page"] + row["ru_hedge"]
            + row["ru_ingest"] for row in summ["per_tenant"].values()
        )
        disp = self.executor.snapshot()
        occ = disp["lane_occupancy"]
        mem = self.memory_snapshot()["vector_tier"]
        return PolicySignals(
            now_s=self.clock.now(),
            queue_depth=len(self.queue),
            ingest_backlog_chunks=len(self._ingest_q),
            ingest_backlog_ops=self.ingest_backlog,
            slo_ms=self.cfg.trace_slo_ms,
            stages=stages,
            ru_total=float(ru_total),
            lanes_busy_s=float(sum(disp["lane_busy_s"])),
            lane_occupancy=float(sum(occ) / len(occ)) if occ else 0.0,
            lanes=len(self.executor.lanes),
            partitions=len(self.collection.partitions),
            # cumulative page-cache counters straight off the stores (NOT
            # the registry: they survive metrics-epoch resets, so the
            # policy's windowed deltas never go negative at a warmup
            # boundary)
            tier_hits=float(mem["hits"]),
            tier_misses=float(mem["misses"]),
            tier_resident_frac=float(mem["resident_frac"]),
            tiered=bool(mem["tiered"]),
        )

    def _apply_cache_step(self, step: int):
        """Actuate one page-cache sizing impulse: every finite-budget
        partition's paged tier grows/shrinks by ~10% of its page count,
        clamped into [10%, 100%] residency. Fully-resident (budget=None)
        partitions are NEVER touched — the policy may only resize a tier
        the operator already opted into."""
        moved = False
        for p in self.collection.partitions:
            pages = getattr(p.providers, "pages", None)
            if pages is None or pages.budget_pages is None:
                continue
            delta = max(1, pages.n_pages // 10)
            lo = max(1, int(round(0.1 * pages.n_pages)))
            new = int(np.clip(pages.budget_pages + step * delta,
                              lo, pages.n_pages))
            if new != pages.budget_pages:
                pages.resize_budget(new)
                moved = True
        if moved:
            self.metrics.policy_cache_resizes += 1
            self.obs.inc("serve_policy_total", knob="cache",
                         action="grow" if step > 0 else "shrink")

    def _apply_scale(self, dec, sig: PolicySignals):
        """Actuate one topology decision: a replica-lane scale-out (the
        executor grows a lane and every replica set gains a member) or a
        partition split (the fullest partition halves). The action is
        attributable: a ``policy``-kind trace records the triggering
        signals, and ``serve_policy_total{knob="topology"}`` counts it."""
        now = self.clock.now()
        detail = ""
        if dec.scale == "scale_out" and self.cfg.dispatch_mode == "replica":
            lane_id = self.executor.add_lane()
            for rs in self.replica_sets:
                rs.add_replica()
            self.metrics.policy_lanes_added += 1
            detail = f"lane{lane_id}"
        elif dec.scale in ("split", "scale_out"):
            # scale_out outside the replica plane degrades to a split —
            # the only topology lever the serial/spmd planes have
            j, (left, right) = self.collection.split_hottest()
            self.metrics.policy_splits += 1
            detail = f"j{j}->p{left.pid},p{right.pid}"
        else:
            raise ValueError(f"unknown scale action {dec.scale!r}")
        self._last_scale = dict(action=dec.scale, t_s=now, detail=detail,
                                reason=dec.reason)
        self.obs.inc("serve_policy_total", knob="topology", action=dec.scale)
        tr = self.tracer.begin("policy", "engine", -1)
        if tr is not None:
            tr.span(f"policy[{dec.scale}]", "policy", now, now,
                    action=dec.scale, detail=detail, reason=dec.reason,
                    queue_depth=sig.queue_depth, lanes=sig.lanes,
                    partitions=sig.partitions)
            self.tracer.finish(tr, status=200, ru=0.0, latency_ms=0.0,
                               t0_s=now, t1_s=now)

    def _interleave_ingest(self):
        """Post-batch ingest drain. Static policy: exactly the configured
        interleave (the pre-policy behavior). Active policy: the current
        yield decision — 0 under latency pressure (the deferral is
        recorded as catch-up debt), ``catchup_chunks`` when the queue is
        empty."""
        if not self.policy.enabled:
            self._drain_ingest(self.cfg.ingest_interleave)
            return
        n = self._decision.ingest_interleave
        if self._ingest_q and n < self.cfg.ingest_interleave:
            self.metrics.ingest_deferred_chunks += min(
                self.cfg.ingest_interleave - n, len(self._ingest_q))
        self._drain_ingest(n)

    def _idle_ingest(self):
        """Idle-pump ingest drain. Static policy: the 1-chunk trickle.
        Active policy: the decision's idle allowance (≥ 1 — deferral
        must never starve the backlog forever); chunks beyond the
        trickle count as repaid catch-up debt."""
        if not self._ingest_q:
            return
        if not self.policy.enabled:
            self._drain_ingest(1)
            return
        drained = self._drain_ingest(max(1, self._decision.idle_ingest))
        if drained > 1:
            self.metrics.ingest_catchup_chunks += drained - 1

    def policy_state(self) -> dict:
        """The control plane's externally visible state (also under
        ``snapshot()["policy"]``): current knob positions, decision
        counters, the ingest catch-up debt ledger, and the last topology
        action with the signals that triggered it."""
        m = self.metrics
        return dict(
            mode="adaptive" if self.policy.enabled else "static",
            enabled=self.policy.enabled,
            beam_width=self._chunk_beam_width(),
            ingest_interleave=(self._decision.ingest_interleave
                               if self.policy.enabled
                               else self.cfg.ingest_interleave),
            idle_ingest=(self._decision.idle_ingest
                         if self.policy.enabled else 1),
            widths=list(self._allowed_widths),
            ticks=m.policy_ticks,
            w_changes=m.policy_w_changes,
            splits=m.policy_splits,
            lanes_added=m.policy_lanes_added,
            cache_resizes=m.policy_cache_resizes,
            last_scale=self._last_scale,
            ingest_debt=dict(
                backlog_chunks=len(self._ingest_q),
                backlog_ops=self.ingest_backlog,
                deferred_chunks=m.ingest_deferred_chunks,
                catchup_chunks=m.ingest_catchup_chunks,
            ),
        )

    # ------------------------------------------------------------------
    # interleaved ingest
    # ------------------------------------------------------------------
    def _drain_ingest(self, n_chunks: int) -> int:
        """Apply up to ``n_chunks`` queued ingest mini-batches; returns
        how many actually drained."""
        drained = 0
        for _ in range(n_chunks):
            if not self._ingest_q:
                return drained
            kind, apply_fn, n_ops, tenant = self._ingest_q.popleft()
            drained += 1
            t0 = self.clock.now()
            ru = float(apply_fn())
            t1 = self.clock.advance(ru * self.cfg.ingest_ms_per_ru / 1000.0)
            self.metrics.ingest_ops += n_ops
            self.metrics.ingest_batches += 1
            self.metrics.ru_ingest_total += ru
            ts = str(tenant)
            self.obs.inc("serve_requests_total", tenant=ts, kind="ingest",
                         status="200")
            self.obs.inc("serve_ru_total", ru, tenant=ts, op="ingest")
            tr = self.tracer.begin("ingest", tenant, -1)
            if tr is not None:
                tr.span(f"ingest[{kind}]", "ingest", t0, t1, op=kind,
                        n_ops=n_ops, ru=ru)
                self.tracer.finish(tr, status=200, ru=ru,
                                   latency_ms=(t1 - t0) * 1000.0,
                                   t0_s=t0, t1_s=t1)
        return drained

    def flush_ingest(self):
        """Apply every queued ingest mini-batch now (synchronous ingest)."""
        self._drain_ingest(len(self._ingest_q))

    @property
    def ingest_backlog(self) -> int:
        return sum(n for _, _, n, _ in self._ingest_q)

    def next_rid(self) -> int:
        rid = self._next_rid
        self._next_rid += 1
        return rid

    # ------------------------------------------------------------------
    def memory_snapshot(self) -> dict:
        """Per-tier residency accounting (ISSUE 10): what is pinned in
        memory per partition (PQ codes, adjacency, postings metadata) vs
        what lives in the paged full-precision tier, plus the page cache's
        capacity/occupancy and cumulative hit/miss counters."""
        resident = dict(pq_codes_bytes=0, adjacency_bytes=0,
                        tombstone_bytes=0)
        per_partition = []
        agg = dict(total_bytes=0, resident_bytes=0, capacity_pages=0,
                   resident_pages=0, hits=0, misses=0, evictions=0)
        tiered = False
        for p in self.collection.partitions:
            pv = p.providers
            resident["pq_codes_bytes"] += int(pv.codes.nbytes
                                              + pv.versions.nbytes)
            resident["adjacency_bytes"] += int(pv.neighbors.nbytes)
            resident["tombstone_bytes"] += int(pv.live.nbytes)
            pages = getattr(pv, "pages", None)
            if pages is None:
                continue
            st = pages.state()
            st["pid"] = int(p.pid)
            per_partition.append(st)
            cap = st["budget_pages"]
            if cap is None:
                cap = st["n_pages"]
            else:
                tiered = True
            agg["total_bytes"] += st["total_bytes"]
            agg["resident_bytes"] += st["resident_bytes"]
            agg["capacity_pages"] += cap
            agg["resident_pages"] += st["resident_pages"]
            agg["hits"] += st["hits"]
            agg["misses"] += st["misses"]
            agg["evictions"] += st["evictions"]
        touches = agg["hits"] + agg["misses"]
        return dict(
            resident=resident,
            vector_tier=dict(
                tiered=tiered,
                hit_rate=agg["hits"] / touches if touches else 1.0,
                resident_frac=(agg["resident_bytes"] / agg["total_bytes"]
                               if agg["total_bytes"] else 1.0),
                **agg,
            ),
            per_partition=per_partition,
        )

    def snapshot(self) -> dict:
        snap = self.metrics.snapshot(self.clock.now())
        snap["queue_depth"] = len(self.queue)
        snap["ingest_backlog"] = self.ingest_backlog
        snap["dispatch"] = self.executor.snapshot()
        snap["policy"] = self.policy_state()
        snap["memory"] = self.memory_snapshot()
        snap["tenants"] = {
            t: dict(available_ru=g.available, consumed_ru=g.consumed,
                    throttle_events=g.throttle_events,
                    settlements=g.settlements, refunded_ru=g.refunded)
            for t, g in self.tenants.items()
        }
        snap["observability"] = self.observability_summary()
        return snap

    def observability_summary(self) -> dict:
        """The cost-attribution read-out: per-stage latency decomposition,
        per-tenant RU/QPS/throttle/p95 breakdown, tracer health."""
        elapsed = max(self.clock.now() - self.metrics.started_s, 1e-9)
        stages = {}
        for labels, h in self.obs.series("serve_stage_ms"):
            stages[labels["stage"]] = dict(
                count=h.count, total_ms=h.sum, mean_ms=h.mean(),
                p95_ms=h.percentile(95))
        per_tenant = {}
        for t in self.obs.label_values("serve_requests_total", "tenant"):
            lat = self.obs.histogram("serve_latency_ms", tenant=t)
            served = self.obs.total("serve_requests_total", tenant=t,
                                    status="200")
            per_tenant[t] = dict(
                requests=served,
                qps=served / elapsed,
                throttled=self.obs.counter_value("serve_throttled_total",
                                                 tenant=t),
                deadline_exceeded=self.obs.counter_value(
                    "serve_deadline_total", tenant=t),
                degraded=self.obs.counter_value("serve_degraded_total",
                                                tenant=t),
                ru_query=self.obs.counter_value("serve_ru_total", tenant=t,
                                                op="query"),
                ru_page=self.obs.counter_value("serve_ru_total", tenant=t,
                                               op="page"),
                ru_hedge=self.obs.counter_value("serve_ru_total", tenant=t,
                                                op="hedge"),
                ru_ingest=self.obs.counter_value("serve_ru_total", tenant=t,
                                                 op="ingest"),
                p95_ms=lat.percentile(95) if lat is not None else 0.0,
            )
        return dict(stages=stages, per_tenant=per_tenant,
                    tracer=self.tracer.stats())
