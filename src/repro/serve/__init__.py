"""repro.serve — serving: the Cosmos-style vector service + engines."""
from .continuation import (ContinuationError, decode_continuation,
                           encode_continuation)
from .engine import ServeEngine
from .metrics import (EngineMetrics, ExactHistogram, Histogram, SimClock,
                      poisson_arrivals)
from .obs import MetricsRegistry, RollupWindow
from .policy import (AdaptivePolicy, ControlPolicy, PolicyDecision,
                     PolicySignals, StaticPolicy, make_policy)
from .predicate import F, Predicate, from_obj, property_items
from .trace import (FlightRecorder, Span, Trace, Tracer,
                    validate_trace_record)
from .vector_engine import (EngineConfig, ServeRequest, ServeResponse,
                            Throttled, VectorServeEngine)
from .vector_service import (DeadlineExceeded, VectorCollectionService,
                             VectorQuery)

__all__ = [
    "VectorCollectionService", "VectorQuery", "ServeEngine",
    "VectorServeEngine", "EngineConfig", "ServeRequest", "ServeResponse",
    "Throttled", "DeadlineExceeded",
    "EngineMetrics", "SimClock", "poisson_arrivals",
    "Histogram", "ExactHistogram", "MetricsRegistry", "RollupWindow",
    "ControlPolicy", "AdaptivePolicy", "StaticPolicy", "PolicyDecision",
    "PolicySignals", "make_policy",
    "Span", "Trace", "Tracer", "FlightRecorder", "validate_trace_record",
    "ContinuationError", "encode_continuation", "decode_continuation",
    "F", "Predicate", "from_obj", "property_items",
]
