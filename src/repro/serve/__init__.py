"""repro.serve — serving: the Cosmos-style vector service + engines."""
from .continuation import (ContinuationError, decode_continuation,
                           encode_continuation)
from .engine import ServeEngine
from .metrics import EngineMetrics, SimClock, poisson_arrivals
from .predicate import F, Predicate, from_obj, property_items
from .vector_engine import (EngineConfig, ServeRequest, ServeResponse,
                            Throttled, VectorServeEngine)
from .vector_service import VectorCollectionService, VectorQuery

__all__ = [
    "VectorCollectionService", "VectorQuery", "ServeEngine",
    "VectorServeEngine", "EngineConfig", "ServeRequest", "ServeResponse",
    "Throttled", "EngineMetrics", "SimClock", "poisson_arrivals",
    "ContinuationError", "encode_continuation", "decode_continuation",
    "F", "Predicate", "from_obj", "property_items",
]
