"""repro.serve — serving: the Cosmos-style vector service + LM engine."""
from .vector_service import VectorCollectionService, VectorQuery
from .engine import ServeEngine

__all__ = ["VectorCollectionService", "VectorQuery", "ServeEngine"]
