"""Adaptive serving control plane — closed-loop actuation of engine knobs.

The paper's serving economics hinge on knobs this repo used to pin at
build time: beam width trades RU for latency (§3.2), background ingest
catch-up yields to query latency (§3.4, Fig 12/13), and sustained
overload is answered by partition split / replica scale-out rather than
unbounded queueing (§ partitioning). This module closes the loop: a
``ControlPolicy`` is ticked once per ``pump()`` on SimClock time with
signals derived from ``engine.observability_summary()`` — the rollup
read-out, never raw counters — and returns one ``PolicyDecision`` the
engine actuates for the next micro-batch.

Design constraints the default ``AdaptivePolicy`` honors:

  * **windowed signals** — the observability histograms are cumulative
    (they never decay), so cumulative percentiles go sticky under
    changing load. The policy differences each stage's (count, total_ms)
    rollup between ticks (``obs.RollupWindow``): count/sum deltas window
    exactly where percentiles can't. A shrinking cumulative value means
    a metrics-epoch reset (``reset_metrics`` at a warmup boundary) and
    re-bases instead of producing a negative delta.
  * **compiled-signature confinement** — W decisions come from the fixed
    ``widths`` ladder and the engine clamps them into
    ``EngineConfig.policy_widths``; after warmup compiles every
    (bucket, L, W) signature once, steady-state recompiles stay at zero.
  * **hysteresis everywhere** — W moves one ladder step per tick inside
    a hold band (wide/narrow thresholds never overlap); topology actions
    require the overload predicate to hold for ``window_s`` of SimClock
    time and are rate-limited by ``cooldown_s``, so a single burst never
    flaps a split/scale-out.
  * **determinism** — every input is derived from the deterministic
    clock/rollups, so the same seed + arrival schedule reproduces the
    same ``decision_log`` bit for bit.

``StaticPolicy`` (the default, ``EngineConfig.policy="static"``) is
disabled: the engine short-circuits before signal collection and behaves
bit-identically to the pre-policy code.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Protocol, runtime_checkable

from .obs import RollupWindow


@dataclasses.dataclass(frozen=True)
class PolicySignals:
    """One tick's view of the serving plane, derived from
    ``observability_summary()`` plus queue/topology state. ``stages``
    carries each stage's cumulative (count, total_ms) rollup; the policy
    windows them itself (see ``RollupWindow``)."""

    now_s: float
    queue_depth: int
    ingest_backlog_chunks: int
    ingest_backlog_ops: int
    slo_ms: Optional[float]
    stages: Mapping[str, tuple[int, float]]
    ru_total: float  # cumulative settled RU across tenants (query+page+hedge+ingest)
    lanes_busy_s: float  # cumulative busy time summed over lanes
    lane_occupancy: float  # cumulative busy/elapsed mean (display only)
    lanes: int
    partitions: int
    # paged vector tier (ISSUE 10). Cumulative page-cache touch counters
    # summed over partitions (epoch-independent: read from the stores, not
    # the registry) plus current residency; ``tiered`` is False when every
    # partition is fully resident — the cache knob stays dormant then.
    tier_hits: float = 0.0
    tier_misses: float = 0.0
    tier_resident_frac: float = 1.0
    tiered: bool = False


@dataclasses.dataclass(frozen=True)
class PolicyDecision:
    """What the engine actuates until the next tick. ``idle_ingest`` is
    the chunk allowance of an idle pump (min 1 — the drain loop must
    always make progress); ``scale`` fires at most one topology action."""

    beam_width: int
    ingest_interleave: int
    idle_ingest: int = 1
    # page-cache impulse: +1 grow / -1 shrink the paged tier's budget by
    # one step (engine clamps into [min_frac, 1.0]); 0 = hold. Only ever
    # nonzero when the signals say some partition runs a finite budget.
    cache_step: int = 0
    scale: Optional[str] = None  # "split" | "scale_out"
    reason: str = ""


@runtime_checkable
class ControlPolicy(Protocol):
    """The engine's control-plane contract. ``enabled=False`` policies
    are never ticked — the engine keeps its static fast path."""

    enabled: bool

    def initial(self) -> PolicyDecision: ...

    def tick(self, sig: PolicySignals) -> PolicyDecision: ...

    def reset_epoch(self) -> None: ...


class StaticPolicy:
    """The knobs as configured, forever — bit-identical to the
    pre-policy engine (the engine never even collects signals)."""

    enabled = False

    def __init__(self, cfg):
        self._decision = PolicyDecision(
            beam_width=cfg.beam_width,
            ingest_interleave=cfg.ingest_interleave,
            idle_ingest=1,
        )

    def initial(self) -> PolicyDecision:
        return self._decision

    def tick(self, sig: PolicySignals) -> PolicyDecision:
        return self._decision

    def reset_epoch(self) -> None:
        pass


class AdaptivePolicy:
    """Default closed-loop policy: W ladder + ingest yield + topology
    hysteresis, all on windowed rollup deltas.

    Knob (a) — beam width: a ladder over ``widths``. Deep backlog
    (``queue_depth >= wide_backlog``) or windowed queue wait above
    ``wide_wait_frac * slo`` steps one rung wider; a near-empty queue
    (``<= narrow_backlog``) with low wait steps one rung narrower; the
    band between holds. Idle traffic therefore settles at ``widths[0]``
    (W=1, the lowest-RU point) and bursts climb to ``widths[-1]``.

    Knob (b) — ingest yield: under latency pressure (windowed e2e above
    ``yield_latency_frac * slo``, or deep backlog) the per-batch
    interleave drops to 0 (queries stop paying for catch-up); with an
    empty queue it rises to ``catchup_chunks`` so the deferred debt
    drains during idle. Idle pumps always drain at least 1 chunk so the
    backlog is never starved forever.

    Knob (c) — topology: when overload (deep backlog + busy lanes +
    windowed wait at/over SLO) persists for ``window_s`` of SimClock
    time (or ``window_s`` of per-lane service booked while overloaded —
    the replica plane commits a backlog at one instant) AND
    ``cooldown_s`` has passed since the last action, fire ONE
    action: a replica-lane scale-out when the dispatch plane is
    ``replica`` and under ``max_lanes``, else a partition split (up to
    ``max_partitions``). The persistence window plus cooldown is the
    hysteresis: a single burst shorter than ``window_s`` fires nothing.
    """

    enabled = True

    def __init__(self, cfg, *, widths: Optional[tuple] = None,
                 wide_backlog: Optional[int] = None,
                 narrow_backlog: Optional[int] = None,
                 wide_wait_frac: float = 0.5,
                 narrow_wait_frac: float = 0.2,
                 yield_latency_frac: float = 0.5,
                 catchup_chunks: int = 4,
                 overload_backlog: Optional[int] = None,
                 overload_occupancy: float = 0.5,
                 window_s: float = 0.05,
                 cooldown_s: float = 0.5,
                 max_lanes: int = 8,
                 max_partitions: int = 8,
                 topology: bool = True,
                 cache_grow_miss: float = 0.5,
                 cache_shrink_miss: float = 0.05,
                 cache_cooldown_s: float = 0.25,
                 cache_min_frac: float = 0.1):
        self.widths = tuple(sorted(set(
            widths if widths is not None else cfg.policy_widths
        ))) or (cfg.beam_width,)
        self.wide_backlog = (wide_backlog if wide_backlog is not None
                             else cfg.max_batch)
        self.narrow_backlog = (narrow_backlog if narrow_backlog is not None
                               else max(1, cfg.max_batch // 4))
        assert self.narrow_backlog < self.wide_backlog, (
            "hold band is empty: narrow_backlog must sit below wide_backlog")
        self.wide_wait_frac = wide_wait_frac
        self.narrow_wait_frac = narrow_wait_frac
        self.yield_latency_frac = yield_latency_frac
        self.base_interleave = cfg.ingest_interleave
        self.catchup_chunks = max(1, int(catchup_chunks))
        self.overload_backlog = (overload_backlog if overload_backlog is not None
                                 else 4 * cfg.max_batch)
        self.overload_occupancy = overload_occupancy
        self.window_s = window_s
        self.cooldown_s = cooldown_s
        self.max_lanes = max_lanes
        self.max_partitions = max_partitions
        self.topology = topology
        # knob (d) — page-cache sizing (ISSUE 10). Grow when the windowed
        # miss RATE says rerank keeps faulting pages in; shrink only when
        # the cache is demonstrably oversized (near-zero misses) AND the
        # queue is idle. Its own cooldown so the cache never flaps with W.
        self.cache_grow_miss = cache_grow_miss
        self.cache_shrink_miss = cache_shrink_miss
        assert cache_shrink_miss < cache_grow_miss, (
            "cache hold band is empty: shrink threshold must sit below grow")
        self.cache_cooldown_s = cache_cooldown_s
        self.cache_min_frac = cache_min_frac
        self._last_cache_s = -float("inf")
        self.dispatch_mode = cfg.dispatch_mode
        self._slo_ms = cfg.trace_slo_ms if cfg.trace_slo_ms else 50.0
        # idle engines start at the cheapest point of the ladder; the
        # first backlogged tick climbs from there
        self._idx = 0
        # benchmark warmup hook: pin W to compile each (bucket, L, W)
        # signature in turn, then unpin before the measured epoch
        self.pinned_width: Optional[int] = None
        self._win = RollupWindow()
        self._last_tick_s: Optional[float] = None
        self._occ = 0.0  # windowed occupancy, held across dt==0 ticks
        self._over_since: Optional[float] = None
        self._over_booked = 0.0  # lane service booked while overloaded
        self._last_action_s = -float("inf")
        self._last: Optional[PolicyDecision] = None
        self.ticks = 0
        # (t_s, W, interleave, idle_ingest, scale) appended on every
        # decision CHANGE and every scale action — the determinism test
        # compares two runs' logs bit for bit
        self.decision_log: list[tuple] = []

    def initial(self) -> PolicyDecision:
        return PolicyDecision(beam_width=self.widths[self._idx],
                              ingest_interleave=self.base_interleave,
                              idle_ingest=1)

    def reset_epoch(self) -> None:
        """Metrics-epoch boundary (``engine.reset_metrics``): drop the
        rollup window and telemetry; actuation state (current W rung,
        cooldown clock) persists — the plant didn't reset."""
        self._win.reset()
        self._last_tick_s = None
        self._occ = 0.0
        self._over_since = None
        self._over_booked = 0.0
        self.ticks = 0
        self.decision_log = []

    # ------------------------------------------------------------------
    def tick(self, sig: PolicySignals) -> PolicyDecision:
        self.ticks += 1
        slo = sig.slo_ms if sig.slo_ms is not None else self._slo_ms

        # windowed rollup deltas (cumulative → per-window)
        qc, qt = sig.stages.get("queue", (0, 0.0))
        _lc, lt = sig.stages.get("lane", (0, 0.0))
        d_n = self._win.delta("queue_count", float(qc))
        d_wait = self._win.delta("queue_total_ms", qt)
        d_lane = self._win.delta("lane_total_ms", lt)
        d_busy = self._win.delta("lanes_busy_s", sig.lanes_busy_s)
        wait_ms = d_wait / d_n if d_n else 0.0
        e2e_ms = (d_wait + d_lane) / d_n if d_n else 0.0
        dt = (sig.now_s - self._last_tick_s
              if self._last_tick_s is not None else 0.0)
        self._last_tick_s = sig.now_s
        if dt > 0:
            # replica lanes book service into the future, so clamp
            self._occ = min(d_busy / (max(sig.lanes, 1) * dt), 1.0)
        elif d_busy > 0:
            # the replica plane dispatched a whole backlog at one
            # simulated instant: busy time grew while no time passed —
            # saturation by definition
            self._occ = 1.0
        occ = self._occ

        # (a) beam width: one ladder step per tick inside a hold band
        if self.pinned_width is not None:
            self._idx = min(range(len(self.widths)),
                            key=lambda i: abs(self.widths[i]
                                              - self.pinned_width))
        elif (sig.queue_depth >= self.wide_backlog
                or wait_ms >= self.wide_wait_frac * slo):
            self._idx = min(self._idx + 1, len(self.widths) - 1)
        elif (sig.queue_depth <= self.narrow_backlog
                and wait_ms <= self.narrow_wait_frac * slo):
            self._idx = max(self._idx - 1, 0)
        W = self.widths[self._idx]

        # (b) ingest yield
        pressure = (e2e_ms >= self.yield_latency_frac * slo
                    or sig.queue_depth >= self.wide_backlog)
        if pressure:
            inter, idle = 0, 1
        elif sig.queue_depth == 0 and sig.ingest_backlog_chunks:
            inter, idle = self.catchup_chunks, self.catchup_chunks
        else:
            inter, idle = self.base_interleave, 1

        # (c) topology: persistence window + cooldown hysteresis. The
        # window is satisfied by SimClock time elapsed while overloaded
        # OR by window_s of per-lane service booked while overloaded —
        # the replica plane dispatches a backlog at one instant, so its
        # persistence is measured in committed lane work, not wall time.
        scale = None
        overloaded = (self.topology
                      and sig.queue_depth >= self.overload_backlog
                      and occ >= self.overload_occupancy
                      and wait_ms >= slo)
        if overloaded:
            if self._over_since is None:
                self._over_since = sig.now_s
                self._over_booked = 0.0
            self._over_booked += d_busy
            sustained = (sig.now_s - self._over_since >= self.window_s
                         or self._over_booked
                         >= self.window_s * max(sig.lanes, 1))
            if (sustained
                    and sig.now_s - self._last_action_s >= self.cooldown_s):
                if (self.dispatch_mode == "replica"
                        and sig.lanes < self.max_lanes):
                    scale = "scale_out"
                elif sig.partitions < self.max_partitions:
                    scale = "split"
                if scale is not None:
                    self._last_action_s = sig.now_s
                    self._over_since = None
        else:
            self._over_since = None

        # (d) page-cache sizing: DORMANT unless some partition actually
        # runs a finite budget — an untiered engine's decisions (and its
        # idle-RU profile) must be unchanged by this knob existing
        cache = 0
        if sig.tiered:
            d_hit = self._win.delta("tier_hits", sig.tier_hits)
            d_miss = self._win.delta("tier_misses", sig.tier_misses)
            touches = d_hit + d_miss
            miss_rate = d_miss / touches if touches else 0.0
            if (touches
                    and sig.now_s - self._last_cache_s
                    >= self.cache_cooldown_s):
                if (miss_rate >= self.cache_grow_miss
                        and sig.tier_resident_frac < 1.0):
                    cache = 1
                elif (miss_rate <= self.cache_shrink_miss
                        and sig.queue_depth == 0
                        and sig.tier_resident_frac > self.cache_min_frac):
                    cache = -1
                if cache:
                    self._last_cache_s = sig.now_s

        dec = PolicyDecision(
            beam_width=W, ingest_interleave=inter, idle_ingest=idle,
            cache_step=cache, scale=scale,
            reason=(f"depth={sig.queue_depth} wait={wait_ms:.3f}ms "
                    f"e2e={e2e_ms:.3f}ms occ={occ:.3f} "
                    f"backlog={sig.ingest_backlog_chunks}"),
        )
        prev = self._last
        if (scale is not None or cache or prev is None
                or dec.beam_width != prev.beam_width
                or dec.ingest_interleave != prev.ingest_interleave
                or dec.idle_ingest != prev.idle_ingest):
            self.decision_log.append(
                (round(sig.now_s, 9), W, inter, idle, scale or "", cache))
        self._last = dec
        return dec


def make_policy(cfg) -> ControlPolicy:
    """EngineConfig.policy → a policy instance. Unknown names raise —
    a typo'd "adative" must not silently serve static."""
    if cfg.policy == "static":
        return StaticPolicy(cfg)
    if cfg.policy == "adaptive":
        return AdaptivePolicy(cfg)
    raise ValueError(
        f"unknown EngineConfig.policy {cfg.policy!r} (want static|adaptive)")
