"""Version-portability layer for JAX idioms that moved between releases.

Everything in the repo that touches a version-sensitive JAX surface goes
through here, so a JAX upgrade (or downgrade) is a one-file audit:

  * mesh construction — ``jax.make_mesh`` grew an ``axis_types=`` kwarg
    (``jax.sharding.AxisType``) in newer releases; 0.4.x rejects it.
  * ``shard_map`` — ``jax.shard_map(..., check_vma=)`` in new JAX vs
    ``jax.experimental.shard_map.shard_map(..., check_rep=)`` in 0.4.x.
  * abstract-mesh contexts — ``jax.sharding.use_abstract_mesh`` /
    ``get_abstract_mesh`` (sharding-in-types) do not exist in 0.4.x; the
    fallbacks are a null context and ``None`` (explicit ``in_shardings`` on
    ``jax.jit`` carry the sharding instead, which 0.4.x supports).
  * Pallas dynamic indexing — raw python ints mixed into ``pl.store`` /
    ``pl.load`` index tuples crash 0.4.x interpret mode
    (``AttributeError: 'int' object has no attribute 'shape'``); every
    dynamic index must be a ``pl.Slice`` built via :func:`ds` / :func:`ds1`.

Supported range: JAX 0.4.35 – 0.7.x (tested on 0.4.37; the new-API branches
are taken automatically when the installed JAX exposes them).
"""
from __future__ import annotations

import contextlib
import inspect
from typing import Any, Callable, Sequence

import jax
from jax.experimental import pallas as pl

# ---------------------------------------------------------------------------
# feature detection
# ---------------------------------------------------------------------------


def _version_tuple(v: str) -> tuple[int, ...]:
    parts = []
    for p in v.split("."):
        digits = ""
        for ch in p:  # leading digits only: "38rc1" is 38, not 381
            if not ch.isdigit():
                break
            digits += ch
        if not digits:
            break
        parts.append(int(digits))
    return tuple(parts) or (0,)


JAX_VERSION: tuple[int, ...] = _version_tuple(jax.__version__)


def jax_at_least(*version: int) -> bool:
    """True when the installed JAX is >= the given (major, minor[, patch])."""
    return JAX_VERSION >= tuple(version)


def has_api(obj: Any, name: str) -> bool:
    """Feature-detect an attribute without tripping deprecation getattrs."""
    try:
        return getattr(obj, name, None) is not None
    except Exception:  # noqa: BLE001 — deprecated attrs may raise on access
        return False


def supports_axis_types() -> bool:
    """Does ``jax.make_mesh`` take ``axis_types=`` (jax.sharding.AxisType)?"""
    return has_api(jax.sharding, "AxisType")


def supports_abstract_mesh_context() -> bool:
    """Does this JAX have ``jax.sharding.use_abstract_mesh``?"""
    return has_api(jax.sharding, "use_abstract_mesh")


def pallas_interpret_default() -> bool:
    """Pallas kernels compile only on TPU; everywhere else interpret."""
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    devices=None,
) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API exists.

    New JAX wants explicit axis types (Auto for everything here — the repo
    shards via explicit in/out shardings, not sharding-in-types); 0.4.x has
    no ``AxisType`` and its ``make_mesh`` rejects the kwarg entirely.
    """
    kwargs: dict = {}
    if devices is not None:
        kwargs["devices"] = devices
    if supports_axis_types():
        auto = jax.sharding.AxisType.Auto
        kwargs["axis_types"] = (auto,) * len(tuple(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def use_abstract_mesh(mesh: jax.sharding.Mesh):
    """Context manager setting the ambient abstract mesh (no-op on 0.4.x)."""
    if supports_abstract_mesh_context() and has_api(mesh, "abstract_mesh"):
        return jax.sharding.use_abstract_mesh(mesh.abstract_mesh)
    return contextlib.nullcontext()


def get_abstract_mesh():
    """The ambient abstract mesh, or None when unsupported / unset.

    Callers treat None as "no ambient mesh" and skip sharding constraints —
    on 0.4.x the explicit jit in/out shardings still place every array.
    """
    if not has_api(jax.sharding, "get_abstract_mesh"):
        return None
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:  # noqa: BLE001
        return None
    if m is None or not getattr(m, "axis_names", ()):
        return None
    return m


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------


def shard_map(
    f: Callable,
    mesh: jax.sharding.Mesh,
    *,
    in_specs,
    out_specs,
    check: bool = False,
):
    """Portable ``shard_map``: new JAX's ``check_vma`` vs older ``check_rep``.

    Mid-range releases expose ``jax.shard_map`` while still spelling the
    kwarg ``check_rep``, so the kwarg is detected from the signature rather
    than from the function's existence.
    """
    if has_api(jax, "shard_map"):
        sm = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as sm
    params = inspect.signature(sm).parameters
    check_kw = "check_vma" if "check_vma" in params else "check_rep"
    return sm(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **{check_kw: check},
    )


# ---------------------------------------------------------------------------
# Pallas dynamic-slice index helpers
# ---------------------------------------------------------------------------

# The Slice class moved modules across releases; pl.ds is the stable
# constructor. Re-exported so kernels import indexing through compat.
Slice = pl.Slice
ds = pl.ds


def ds1(i) -> pl.Slice:
    """Size-1 dynamic slice for per-element ref addressing.

    ``ref[ds1(0), ds1(j)]`` is the portable spelling of ``ref[0, j]`` inside
    ``pl.load``/``pl.store`` index tuples: 0.4.x interpret mode requires
    every dynamic index to be a Slice object, never a raw python int.
    """
    return pl.ds(i, 1)


def ds_index(*idx) -> tuple:
    """Normalize a mixed index tuple so scalar indices become size-1 Slices.

    Only python ints and scalar (0-d) traced values are wrapped — ``Slice``
    objects, python slices, and non-scalar arrays pass through unchanged.
    """
    def norm(i):
        if isinstance(i, pl.Slice):
            return i
        if isinstance(i, int) or getattr(i, "ndim", None) == 0:
            return ds1(i)
        return i

    return tuple(norm(i) for i in idx)
