"""Pallas TPU kernels for the paper's compute hot spots.

The paper's profile (Fig 11) is dominated by quantized-vector access and
distance computation; the query path touches ~3500 quantized vectors and
~50 full-precision vectors per search (§3.2). The kernels here tile exactly
those loops for the TPU memory hierarchy:

    pq_adc       ADC distance scan: LUT in VMEM, PQ codes streamed in tiles,
                 table lookups expressed as one-hot × LUT contractions (MXU)
    pq_encode    PQ encoding: per-subspace nearest-centroid (MXU matmuls)
    topk_select  blockwise partial top-k for candidate selection
    flat_l2      tiled full-precision distance matrix (re-rank / brute force)

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with an interpret-mode fallback for CPU), ref.py (pure-jnp oracle).
TPU is the *target*; on this CPU container kernels run under interpret=True
and are validated against the oracles across shape/dtype sweeps in
tests/test_kernels.py.
"""
from .pq_adc import ops as pq_adc_ops
from .pq_encode import ops as pq_encode_ops
from .topk_select import ops as topk_ops
from .flat_l2 import ops as flat_l2_ops

__all__ = ["pq_adc_ops", "pq_encode_ops", "topk_ops", "flat_l2_ops"]
