"""Pure-jnp oracle for pq_encode (same math as repro.core.pq.encode)."""
import jax
import jax.numpy as jnp


@jax.jit
def pq_encode_ref(x: jax.Array, codebooks: jax.Array) -> jax.Array:
    """x (N, D), codebooks (M, K, dsub) -> (N, M) uint8."""
    N, D = x.shape
    M, K, dsub = codebooks.shape
    sub = x.reshape(N, M, dsub)
    d = (
        jnp.sum(sub * sub, -1, keepdims=True)
        - 2.0 * jnp.einsum("nmd,mkd->nmk", sub, codebooks)
        + jnp.sum(codebooks * codebooks, -1)[None]
    )
    return jnp.argmin(d, axis=-1).astype(jnp.uint8)
