"""jit'd dispatch wrapper for pq_encode."""
from __future__ import annotations

import jax

from ... import compat
from .kernel import pq_encode_pallas
from .ref import pq_encode_ref


def pq_encode(x: jax.Array, codebooks: jax.Array, *, block_n: int = 256,
              use_pallas: bool | None = None) -> jax.Array:
    if use_pallas is None:
        use_pallas = True
    interpret = compat.pallas_interpret_default()
    if not use_pallas:
        return pq_encode_ref(x, codebooks)
    return pq_encode_pallas(x, codebooks, block_n=block_n, interpret=interpret)
