"""pq_encode — PQ encoding (nearest centroid per subspace) tiled for TPU.

Workload: x (N, D) float32, codebooks (M, K, dsub) → codes (N, M).
Per subspace m: scores (Nb, K) = ‖x_m‖² − 2·x_m·C_mᵀ + ‖c‖² → argmin.

Grid: (N/Nb, M). Per step the (Nb, dsub) slice of x and the (K, dsub)
codebook for subspace m sit in VMEM; the −2·x·Cᵀ term is an MXU matmul.
The ‖x‖² term is constant across K and irrelevant to the argmin, so the
kernel skips it — scores are shifted but the codes are identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _encode_kernel(x_ref, cent_ref, out_ref):
    """x_ref: (Nb, 1, dsub); cent_ref: (1, K, dsub); out_ref: (Nb, 1) i32."""
    x = x_ref[:, 0, :]  # (Nb, dsub)
    cent = cent_ref[0]  # (K, dsub)
    scores = -2.0 * jnp.dot(x, cent.T) + jnp.sum(cent * cent, -1)[None, :]
    out_ref[:, 0] = jnp.argmin(scores, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def pq_encode_pallas(
    x: jax.Array,  # (N, D)
    codebooks: jax.Array,  # (M, K, dsub)
    *,
    block_n: int = 256,
    interpret: bool = False,
) -> jax.Array:
    N, D = x.shape
    M, K, dsub = codebooks.shape
    assert D == M * dsub
    Np = ((N + block_n - 1) // block_n) * block_n
    xp = jnp.pad(x, ((0, Np - N), (0, 0))) if Np != N else x

    out = pl.pallas_call(
        _encode_kernel,
        grid=(Np // block_n, M),
        in_specs=[
            # x viewed as (N, M, dsub): block (Nb, 1, dsub) → squeeze in spec
            pl.BlockSpec((block_n, 1, dsub), lambda n, m: (n, m, 0)),
            pl.BlockSpec((1, K, dsub), lambda n, m: (m, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda n, m: (n, m)),
        out_shape=jax.ShapeDtypeStruct((Np, M), jnp.int32),
        interpret=interpret,
    )(xp.reshape(Np, M, dsub), codebooks)
    return out[:N].astype(jnp.uint8)
