"""Pure-jnp oracle for flat_l2 (same math as repro.core.pq.pairwise_distance)."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("metric",))
def flat_l2_ref(q: jax.Array, x: jax.Array, *, metric: str = "l2") -> jax.Array:
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    if metric == "l2":
        d = (
            jnp.sum(q * q, -1, keepdims=True)
            - 2.0 * q @ x.T
            + jnp.sum(x * x, -1)[None, :]
        )
        return jnp.maximum(d, 0.0)
    return -(q @ x.T)
