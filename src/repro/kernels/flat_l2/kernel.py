"""flat_l2 — tiled full-precision distance matrix (re-rank / brute force).

Workload: queries (B, D) × vectors (N, D) → squared-L2 (or −IP) distances
(B, N). This backs the Fig 5 re-rank (C ≈ 50 vectors per query) and the
small-collection brute-force plan (§3).

Classic three-level matmul tiling: grid (B/Bb, N/Nb, D/Db) with the
contraction dimension innermost; the output block is revisited across the
D-steps and accumulated in place (f32). Block shapes keep every operand in
VMEM with MXU-aligned (multiple-of-128) matmul dims; norms are added on the
final contraction step so the kernel emits finished distances.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flat_kernel(q_ref, x_ref, q2_ref, x2_ref, out_ref, *, n_dsteps: int, metric: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(
        q_ref[...], x_ref[...].T, preferred_element_type=jnp.float32
    )

    @pl.when(k == n_dsteps - 1)
    def _finish():
        if metric == "l2":
            out_ref[...] = q2_ref[...].reshape(-1, 1) + x2_ref[...].reshape(1, -1) - 2.0 * out_ref[...]
        else:  # ip: negative inner product
            out_ref[...] = -out_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_n", "block_d", "metric", "interpret")
)
def flat_l2_pallas(
    q: jax.Array,  # (B, D)
    x: jax.Array,  # (N, D)
    *,
    block_b: int = 128,
    block_n: int = 256,
    block_d: int = 128,
    metric: str = "l2",
    interpret: bool = False,
) -> jax.Array:
    B, D = q.shape
    N = x.shape[0]
    bb, bn, bd = min(block_b, B), min(block_n, N), min(block_d, D)

    def pad_to(a, m0, m1):
        p0 = (-a.shape[0]) % m0
        p1 = (-a.shape[1]) % m1
        return jnp.pad(a, ((0, p0), (0, p1))) if (p0 or p1) else a

    qp = pad_to(q.astype(jnp.float32), bb, bd)
    xp = pad_to(x.astype(jnp.float32), bn, bd)
    Bp, Dp = qp.shape
    Np = xp.shape[0]
    q2 = jnp.sum(qp * qp, -1)
    x2 = jnp.sum(xp * xp, -1)
    n_dsteps = Dp // bd

    out = pl.pallas_call(
        functools.partial(_flat_kernel, n_dsteps=n_dsteps, metric=metric),
        grid=(Bp // bb, Np // bn, n_dsteps),
        in_specs=[
            pl.BlockSpec((bb, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bd), lambda i, j, k: (j, k)),
            pl.BlockSpec((bb,), lambda i, j, k: (i,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Np), jnp.float32),
        interpret=interpret,
    )(qp, xp, q2, x2)
    out = out[:B, :N]
    if metric == "l2":
        out = jnp.maximum(out, 0.0)
    return out
