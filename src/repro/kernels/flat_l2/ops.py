"""jit'd dispatch wrapper for flat_l2."""
from __future__ import annotations

import jax

from ... import compat
from .kernel import flat_l2_pallas
from .ref import flat_l2_ref


def flat_l2(q: jax.Array, x: jax.Array, *, metric: str = "l2",
            use_pallas: bool | None = None, **blocks) -> jax.Array:
    if use_pallas is None:
        use_pallas = True
    interpret = compat.pallas_interpret_default()
    if not use_pallas:
        return flat_l2_ref(q, x, metric=metric)
    return flat_l2_pallas(q, x, metric=metric, interpret=interpret, **blocks)
