"""Pure-jnp oracle for pq_adc."""
import jax
import jax.numpy as jnp


@jax.jit
def pq_adc_ref(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """lut (B, M, K) f32, codes (C, M) u8 -> (B, C) f32."""
    c = codes.astype(jnp.int32)  # (C, M)
    picked = jnp.take_along_axis(
        lut[:, None, :, :],  # (B, 1, M, K)
        c[None, :, :, None],  # (1, C, M, 1)
        axis=3,
    )[..., 0]  # (B, C, M)
    return picked.sum(-1)
