"""jit'd dispatch wrapper for pq_adc: Pallas on TPU, interpret elsewhere."""
from __future__ import annotations

import jax

from ... import compat
from .kernel import pq_adc_pallas
from .ref import pq_adc_ref


def pq_adc(lut: jax.Array, codes: jax.Array, *, block_c: int = 512,
           use_pallas: bool | None = None) -> jax.Array:
    """ADC distances (B, C). `use_pallas=None` → Pallas compiled on TPU,
    Pallas interpret mode elsewhere (bit-exact with the compiled kernel)."""
    if use_pallas is None:
        use_pallas = True
    interpret = compat.pallas_interpret_default()
    if not use_pallas:
        return pq_adc_ref(lut, codes)
    return pq_adc_pallas(lut, codes, block_c=block_c, interpret=interpret)
