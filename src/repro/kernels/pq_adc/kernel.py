"""pq_adc — ADC distance scan, the paper's hottest loop, tiled for TPU.

Workload: for a batch of queries with precomputed LUTs (B, M, K) and a set
of PQ codes (C, M) uint8, compute distances (B, C):

    out[b, c] = Σ_m  lut[b, m, codes[c, m]]

CPU DiskANN does this as L1-cache scalar lookups; a TPU has no scalar
gather path worth using, but it has an MXU. We rewrite the lookup as a
one-hot contraction

    out[b, c] = Σ_m  onehot(codes[c, m]) · lut[b, m, :]

and tile it: the full LUT for one query (M·K·4 B ≈ 16–64 KiB) lives in VMEM
across the whole scan; codes stream through VMEM in (Cb, M) tiles. The
one-hot never materializes in HBM — it is built in-register per (tile, m)
and fed straight to the MXU as a (Cb, K) × (K,) product.

Grid: (B, C/Cb) — one LUT residency per query row, codes tiles innermost so
the LUT block is reused across the entire scan (arithmetic intensity
M·Cb / (Cb·M + M·K) ≈ 1 FLOP/byte of code traffic, i.e. memory-bound by
design, matching the paper's "quantized vector access dominates" profile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adc_kernel(lut_ref, codes_ref, out_ref, *, K: int):
    """lut_ref: (1, M, K) f32; codes_ref: (Cb, M) i32; out_ref: (1, Cb) f32."""
    codes = codes_ref[...]  # (Cb, M)
    M = codes.shape[1]
    Cb = codes.shape[0]

    def body(m, acc):
        row = lut_ref[0, m, :]  # (K,)
        onehot = (codes[:, m][:, None] == jax.lax.iota(jnp.int32, K)[None, :])
        return acc + jnp.dot(onehot.astype(jnp.float32), row)

    acc = jax.lax.fori_loop(0, M, body, jnp.zeros((Cb,), jnp.float32))
    out_ref[0, :] = acc


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def pq_adc_pallas(
    lut: jax.Array,  # (B, M, K) float32
    codes: jax.Array,  # (C, M) uint8/int32
    *,
    block_c: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Distances (B, C) via the tiled one-hot ADC kernel."""
    B, M, K = lut.shape
    C = codes.shape[0]
    codes_i = codes.astype(jnp.int32)

    # pad C to a multiple of block_c
    Cp = ((C + block_c - 1) // block_c) * block_c
    if Cp != C:
        codes_i = jnp.pad(codes_i, ((0, Cp - C), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_adc_kernel, K=K),
        grid=(B, Cp // block_c),
        in_specs=[
            pl.BlockSpec((1, M, K), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((block_c, M), lambda b, c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c), lambda b, c: (b, c)),
        out_shape=jax.ShapeDtypeStruct((B, Cp), jnp.float32),
        interpret=interpret,
    )(lut, codes_i)
    return out[:, :C]
