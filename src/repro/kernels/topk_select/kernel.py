"""topk_select — blockwise partial top-k (smallest distances first).

Candidate selection after a Q-Flat scan (and the rerank cut) needs the L
smallest of N distances. A full sort is O(N log N) and serializes badly on
the VPU; instead each grid block extracts its local top-L by L iterated
masked argmins over a VMEM-resident tile (L ≪ Nb), and the host-side
wrapper merges the (num_blocks · L) survivors with one small `lax.top_k`.
This is the classic two-level TPU k-selection: the candidate set shrinks by
Nb/L per level while staying rectangular.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_kernel(d_ref, vals_ref, idx_ref, *, L: int, block_n: int):
    d = d_ref[0, :].astype(jnp.float32)  # (Nb,)
    base = pl.program_id(1) * block_n

    def body(i, dd):
        j = jnp.argmin(dd)
        pl.store(vals_ref, (0, pl.ds(i, 1)), dd[j][None])
        pl.store(idx_ref, (0, pl.ds(i, 1)), (base + j).astype(jnp.int32)[None])
        return dd.at[j].set(jnp.inf)

    jax.lax.fori_loop(0, L, body, d)


@functools.partial(jax.jit, static_argnames=("L", "block_n", "interpret"))
def topk_select_pallas(
    dists: jax.Array,  # (B, N) float32 — smaller is better
    *,
    L: int,
    block_n: int = 1024,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (vals (B, L), idx (B, L)) of the L smallest per row."""
    B, N = dists.shape
    Np = ((N + block_n - 1) // block_n) * block_n
    d = jnp.pad(dists, ((0, 0), (0, Np - N)), constant_values=jnp.inf) if Np != N else dists
    nblk = Np // block_n

    vals, idx = pl.pallas_call(
        functools.partial(_topk_kernel, L=L, block_n=block_n),
        grid=(B, nblk),
        in_specs=[pl.BlockSpec((1, block_n), lambda b, n: (b, n))],
        out_specs=[
            pl.BlockSpec((1, L), lambda b, n: (b, n)),
            pl.BlockSpec((1, L), lambda b, n: (b, n)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nblk * L), jnp.float32),
            jax.ShapeDtypeStruct((B, nblk * L), jnp.int32),
        ],
        interpret=interpret,
    )(d)

    # second level: merge block winners (small)
    neg, pos = jax.lax.top_k(-vals, L)
    out_idx = jnp.take_along_axis(idx, pos, axis=1)
    out_vals = -neg
    out_idx = jnp.where(jnp.isfinite(out_vals), out_idx, -1)
    return out_vals, out_idx
