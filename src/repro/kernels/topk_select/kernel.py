"""topk_select — two-stage blockwise partial top-k (smallest first).

Candidate selection after a Q-Flat scan (and the rerank cut) needs the L
smallest of N distances. A full sort is O(N log N) and serializes badly on
the VPU; instead the selection runs in two fixed-shape stages:

  stage 1 (Pallas, grid (B, N/Nb)): each block extracts its local top-L by
    L iterated masked argmins over a VMEM-resident (1, Nb) tile. The argmin
    is spelled as a min-reduce plus an iota comparison (first-index tie
    break, same as ``lax.top_k``) and the survivor mask as a ``where`` over
    the column iota — pure vector ops, no scatter, no per-element stores,
    so the kernel lowers on TPU Mosaic *and* runs under 0.4.x interpret
    mode (which rejects raw-int dynamic indices in ref stores). Each block
    writes its (1, L) winners with one full-block store.

  stage 2 (host-side, fixed shape): the (B, nblk·L) survivors merge with a
    single small ``lax.top_k``. When the row fits one block the stage-1
    output is already the sorted answer and the merge is skipped.

The candidate set shrinks by Nb/L per level while staying rectangular; at
large N stage 2 touches nblk·L ≪ N values, so the merge cost is negligible
and stage 1's two vector stores per block (vs 2·L scalar stores before the
rewrite) keep the VPU busy on the scan itself.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_block_kernel(d_ref, vals_ref, idx_ref, *, L: int, block_n: int):
    dd = d_ref[...].astype(jnp.float32)  # (1, Nb)
    base = pl.program_id(1) * block_n
    col = jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1)
    slot = jax.lax.broadcasted_iota(jnp.int32, (1, L), 1)

    def body(i, carry):
        dd, vals, idxs = carry
        v = jnp.min(dd)
        # first index attaining the min — lax.top_k's tie-break order
        j = jnp.min(jnp.where(dd == v, col, jnp.int32(block_n)))
        vals = jnp.where(slot == i, v, vals)
        idxs = jnp.where(slot == i, base + j, idxs)
        dd = jnp.where(col == j, jnp.inf, dd)
        return dd, vals, idxs

    init = (
        dd,
        jnp.full((1, L), jnp.inf, jnp.float32),
        jnp.full((1, L), -1, jnp.int32),
    )
    _, vals, idxs = jax.lax.fori_loop(0, L, body, init)
    vals_ref[...] = vals
    idx_ref[...] = idxs


@functools.partial(jax.jit, static_argnames=("L", "block_n", "interpret"))
def topk_select_pallas(
    dists: jax.Array,  # (B, N) float32 — smaller is better
    *,
    L: int,
    block_n: int = 1024,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (vals (B, L), idx (B, L)) of the L smallest per row."""
    B, N = dists.shape
    Np = ((N + block_n - 1) // block_n) * block_n
    d = jnp.pad(dists, ((0, 0), (0, Np - N)), constant_values=jnp.inf) if Np != N else dists
    nblk = Np // block_n

    vals, idx = pl.pallas_call(
        functools.partial(_topk_block_kernel, L=L, block_n=block_n),
        grid=(B, nblk),
        in_specs=[pl.BlockSpec((1, block_n), lambda b, n: (b, n))],
        out_specs=[
            pl.BlockSpec((1, L), lambda b, n: (b, n)),
            pl.BlockSpec((1, L), lambda b, n: (b, n)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nblk * L), jnp.float32),
            jax.ShapeDtypeStruct((B, nblk * L), jnp.int32),
        ],
        interpret=interpret,
    )(d)

    if nblk > 1:
        # stage 2: merge block winners (fixed shape, nblk·L ≪ N)
        neg, pos = jax.lax.top_k(-vals, L)
        out_vals = -neg
        out_idx = jnp.take_along_axis(idx, pos, axis=1)
    else:
        out_vals, out_idx = vals, idx  # already sorted ascending
    out_idx = jnp.where(jnp.isfinite(out_vals), out_idx, -1)
    return out_vals, out_idx
