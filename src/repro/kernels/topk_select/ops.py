"""jit'd dispatch wrapper for topk_select."""
from __future__ import annotations

import jax

from ... import compat
from .kernel import topk_select_pallas
from .ref import topk_select_ref


def topk_select(dists: jax.Array, *, L: int, block_n: int = 1024,
                use_pallas: bool | None = None) -> tuple[jax.Array, jax.Array]:
    if use_pallas is None:
        use_pallas = True
    interpret = compat.pallas_interpret_default()
    if not use_pallas:
        return topk_select_ref(dists, L=L)
    return topk_select_pallas(dists, L=L, block_n=block_n, interpret=interpret)
