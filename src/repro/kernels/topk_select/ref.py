"""Pure-jnp oracle for topk_select."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("L",))
def topk_select_ref(dists: jax.Array, *, L: int) -> tuple[jax.Array, jax.Array]:
    """(B, N) -> (vals (B, L), idx (B, L)), smallest first."""
    neg, idx = jax.lax.top_k(-dists, L)
    vals = -neg
    idx = jnp.where(jnp.isfinite(vals), idx, -1)
    return vals, idx.astype(jnp.int32)
