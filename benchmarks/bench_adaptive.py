"""Adaptive control-plane benchmark (ISSUE 9): bursty/diurnal arrivals,
static vs adaptive policy.

The measurement the closed loop is judged on: a diurnal square-wave
arrival schedule (idle trickle / burst phases, repeated) plus a stream
of ingest chunks landing at each burst's front edge, replayed bit-
identically against three engines over one prebuilt collection —

  * ``static_w4`` — the PR 8 configuration: W pinned wide, ingest
    interleave pinned at 1 (every burst batch pays for catch-up);
  * ``static_w1`` — W pinned at the cheapest point: lowest RU/query but
    the least burst throughput;
  * ``adaptive``  — ``EngineConfig(policy="adaptive")``: W rides the
    ladder (wide under backlog, W=1 at idle), ingest defers under
    latency pressure and repays the debt during idle, decisions confined
    to the warmed (bucket, L, W) signature set.

Acceptance floors (asserted here, emitted as the ``adaptive`` section of
``BENCH_serve.json`` / ``BENCH_serve.smoke.json``):

  * SLO compliance — the adaptive run answers ≥ 99% of admitted
    requests within ``trace_slo_ms``;
  * idle economics — the adaptive run's settled-idle RU/query is no
    worse than the static-W1 engine's (the W ladder actually parks at
    the cheapest compiled point when traffic is thin);
  * zero steady-state recompiles — every policy W move stays inside the
    warmed signature set;
  * the ingest ledger closes — bursts defer chunks (debt > 0), idle
    repays them (catch-up > 0), and the backlog fully drains;
  * the PR 8 chaos gates stay green WITH the policy enabled —
    ``bench_chaos.run_chaos(policy="adaptive")`` re-runs the fault
    schedule against an adaptive engine and self-asserts availability
    ≥ 0.99, recall Δ ≤ 0.01, exact RU conservation.

Ingest chunks here are synthetic fixed-RU thunks (no corpus mutation):
the three engines must see an identical corpus, and the yield policy
only reacts to the chunks' timing and cost. The real ingest path is
measured by ``bench_serve.measure_mixed_ingest`` and the chaos harness.

Standalone ``python -m benchmarks.bench_adaptive [--smoke]`` merges the
``adaptive`` section into an existing ``BENCH_serve[.smoke].json``;
``bench_serve.run()`` embeds it directly in full (non-smoke) mode.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import numpy as np

from repro.serve import EngineConfig, VectorServeEngine
from repro.serve.vector_engine import serving_jit_cache_size

from .bench_serve import build_service
from .common import pct

SLO_MS = 50.0
INGEST_CHUNK_RU = 25.0  # ~10 ms of simulated drain per chunk (0.4 ms/RU)
INGEST_CHUNK_OPS = 16
SETTLE_S = 0.05  # idle-phase samples start this far after the burst ends


def diurnal_schedule(rng: np.random.RandomState, t0: float, cycles: int,
                     idle_s: float, burst_s: float, idle_qps: float,
                     burst_qps: float):
    """Square-wave Poisson arrivals: ``cycles`` × (idle phase, burst
    phase). Returns (arrival times, phase label per arrival, phase
    windows as (name, start, end) for the per-phase metrics)."""
    ts, phases, windows = [], [], []
    t = t0
    for _ in range(cycles):
        for dur, rate, name in ((idle_s, idle_qps, "idle"),
                                (burst_s, burst_qps, "burst")):
            end = t + dur
            windows.append((name, t, end))
            while True:
                t += float(rng.exponential(1.0 / rate))
                if t >= end:
                    break
                ts.append(t)
                phases.append(name)
            t = end
    return np.asarray(ts), phases, windows


def warmup_widths(eng: VectorServeEngine, data: np.ndarray,
                  widths, k: int = 10):
    """Compile every (bucket, L, W) signature the policy may pick, then
    reset to a clean metrics epoch. Widths go in DESCENDING order so an
    adaptive policy's ladder ends parked at widths[0] — the state an
    idle engine would be in."""
    pol = eng.policy
    for W in sorted(widths, reverse=True):
        if pol.enabled:
            pol.pinned_width = W
        for B in (1, 2, 4, 8, 16):
            for q in data[:B]:
                eng.submit_query(q, k=k)
            eng.drain()
    if pol.enabled:
        pol.pinned_width = None
    eng.reset_metrics()


def _drive(eng: VectorServeEngine, queries: np.ndarray,
           arrivals: np.ndarray, ingest_events, k: int = 10):
    """bench_serve's arrival-driven event loop, extended with an ingest
    schedule: at each (time, n_chunks) event the backlog grows by
    ``n_chunks`` synthetic fixed-RU chunks, and the engine's yield
    policy (or the static interleave) decides when they drain."""
    ingest_events = list(ingest_events)
    j = 0
    i, n = 0, len(queries)
    rids = []
    while i < n or eng.queue:
        now = eng.clock.now()
        while j < len(ingest_events) and ingest_events[j][0] <= now:
            for _ in range(ingest_events[j][1]):
                eng.submit_ingest("upsert", lambda: INGEST_CHUNK_RU,
                                  INGEST_CHUNK_OPS)
            j += 1
        while i < n and arrivals[i] <= now:
            rids.append(eng.submit_query(queries[i], k=k,
                                         arrival_s=float(arrivals[i])))
            i += 1
        if eng.pump():
            continue
        events = []
        if i < n:
            events.append(float(arrivals[i]))
        if j < len(ingest_events):
            events.append(float(ingest_events[j][0]))
        if eng.queue:
            events.append(min(r.arrival_s for r in eng.queue)
                          + eng.cfg.max_wait_s)
        if not events:
            break
        eng.clock.advance(max(min(events) - now, 0.0))
        if min(events) <= now:  # deadline already passed → force the flush
            eng.pump(force=True)
    eng.drain()
    return rids


def _phase_rows(resps, arrivals, phases, windows):
    """Per-phase latency/RU rollups. ``idle_settled`` excludes the first
    ``SETTLE_S`` of each idle window — the ladder needs a tick or two to
    narrow after a burst, and the deferred ingest debt drains there; the
    settled tail is the steady idle economics the floor is about."""
    idle_windows = [(a, b) for name, a, b in windows if name == "idle"]
    rows = {}
    for sel in ("idle", "burst", "idle_settled", "all"):
        if sel == "all":
            idx = list(range(len(resps)))
        elif sel == "idle_settled":
            idx = [i for i, t in enumerate(arrivals)
                   if any(a + SETTLE_S <= t < b for a, b in idle_windows)]
        else:
            idx = [i for i, ph in enumerate(phases) if ph == sel]
        lat = [resps[i].latency_ms for i in idx]
        ru = [resps[i].ru for i in idx]
        rows[sel] = dict(
            n=len(idx),
            p50_ms=pct(lat, 50), p95_ms=pct(lat, 95), p99_ms=pct(lat, 99),
            ru_per_query=float(np.mean(ru)) if ru else 0.0,
            slo_ok=(float(np.mean([l <= SLO_MS for l in lat]))
                    if lat else 1.0),
        )
    return rows


def _run_policy(svc, data, queries, arrivals, phases, windows,
                ingest_events, policy: str, beam_width: int = 4) -> dict:
    cfg = EngineConfig(max_batch=16, beam_width=beam_width, policy=policy,
                       admission_control=False, trace_slo_ms=SLO_MS,
                       flight_recorder=64)
    eng = VectorServeEngine(svc.collection, cfg=cfg)
    widths = cfg.policy_widths if policy == "adaptive" else (beam_width,)
    warmup_widths(eng, data, widths)
    cache0 = serving_jit_cache_size()
    t0 = time.perf_counter()
    rids = _drive(eng, queries, arrivals + eng.clock.now(),
                  [(t + eng.clock.now(), k) for t, k in ingest_events])
    wall_s = time.perf_counter() - t0
    resps = [eng.pop_response(rid) for rid in rids]
    assert len(resps) == len(queries) and all(
        r is not None and r.status == 200 for r in resps)
    row = dict(
        policy=policy, beam_width=beam_width,
        phases=_phase_rows(resps, arrivals, phases, windows),
        recompiles_steady=serving_jit_cache_size() - cache0,
        wall_s=round(wall_s, 3),
        state=eng.snapshot()["policy"],
    )
    if policy == "adaptive":
        row["decisions"] = len(eng.policy.decision_log)
        row["widths_used"] = sorted(set(d[1] for d in eng.policy.decision_log))
    return row


def run(n: int = 1500, dim: int = 32, seed: int = 11,
        smoke: bool = False) -> dict:
    rng = np.random.RandomState(seed)
    if smoke:
        n = 600
        cycles, idle_s, burst_s = 2, 0.3, 0.1
        idle_qps, burst_qps = 80.0, 800.0
        chunks_per_burst = 8
    else:
        cycles, idle_s, burst_s = 3, 0.4, 0.2
        idle_qps, burst_qps = 100.0, 1500.0
        chunks_per_burst = 20
    svc, data, rng = build_service(n, dim, seed=seed)
    arrivals, phases, windows = diurnal_schedule(
        rng, 0.0, cycles, idle_s, burst_s, idle_qps, burst_qps)
    queries = data[rng.choice(n, len(arrivals), replace=True)] + 0.01
    # ingest chunks land at each burst's front edge — exactly when a
    # static interleave hurts most and an adaptive yield should defer
    ingest_events = [(t0, chunks_per_burst)
                     for name, t0, _ in windows if name == "burst"]

    runs = {}
    for label, policy, W in (("static_w4", "static", 4),
                             ("static_w1", "static", 1),
                             ("adaptive", "adaptive", 4)):
        runs[label] = _run_policy(svc, data, queries, arrivals, phases,
                                  windows, ingest_events, policy,
                                  beam_width=W)

    ad = runs["adaptive"]
    idle_ru = {k: runs[k]["phases"]["idle_settled"]["ru_per_query"]
               for k in runs}
    debt = ad["state"]["ingest_debt"]

    # ISSUE 8's chaos gates must stay green WITH the policy enabled (the
    # gate asserts availability/recall/RU floors internally)
    from . import bench_chaos
    chaos = bench_chaos.run(smoke=True, policy="adaptive") if smoke else \
        bench_chaos.run(smoke=False, policy="adaptive")

    out = dict(
        config=dict(n=n, dim=dim, seed=seed, slo_ms=SLO_MS,
                    cycles=cycles, idle_s=idle_s, burst_s=burst_s,
                    idle_qps=idle_qps, burst_qps=burst_qps,
                    chunks_per_burst=chunks_per_burst,
                    ingest_chunk_ru=INGEST_CHUNK_RU,
                    n_queries=len(arrivals)),
        runs=runs,
        slo_compliance_adaptive=ad["phases"]["all"]["slo_ok"],
        idle_ru_per_query=idle_ru,
        idle_ru_adaptive_vs_w1=idle_ru["adaptive"] / max(idle_ru["static_w1"],
                                                         1e-9),
        idle_ru_w4_vs_w1=idle_ru["static_w4"] / max(idle_ru["static_w1"],
                                                    1e-9),
        recompiles_steady_adaptive=ad["recompiles_steady"],
        ingest_debt=debt,
        chaos_adaptive=dict(
            availability=chaos["availability"],
            recall_delta=chaos["recall_delta"],
            ru_conservation_rel_err=chaos["ru_conservation_rel_err"],
            p95_ratio=chaos["p95_ratio"],
        ),
    )

    # acceptance floors (ISSUE 9)
    assert out["slo_compliance_adaptive"] >= 0.99, (
        f"adaptive SLO compliance {out['slo_compliance_adaptive']:.4f} "
        f"< 0.99 of admitted requests")
    assert out["idle_ru_adaptive_vs_w1"] <= 1.02, (
        f"adaptive settled-idle RU/query is "
        f"{out['idle_ru_adaptive_vs_w1']:.3f}x static-W1 (must be ≤ 1.02x)")
    assert out["recompiles_steady_adaptive"] == 0, (
        f"{out['recompiles_steady_adaptive']} steady-state recompiles — a "
        f"policy W decision left the warmed signature set")
    assert set(ad["widths_used"]) <= set(EngineConfig().policy_widths), (
        f"W decisions {ad['widths_used']} escaped policy_widths")
    assert debt["deferred_chunks"] > 0, \
        "bursts never deferred ingest — the yield policy did not engage"
    assert debt["catchup_chunks"] > 0, \
        "idle never repaid the deferred ingest debt"
    assert debt["backlog_chunks"] == 0 and debt["backlog_ops"] == 0, \
        f"ingest backlog did not drain: {debt}"
    return out


def main(smoke: bool = False):
    out = run(smoke=smoke)
    name = "BENCH_serve.smoke.json" if smoke else "BENCH_serve.json"
    path = Path(__file__).resolve().parent.parent / name
    doc = json.loads(path.read_text()) if path.exists() else {}
    doc["adaptive"] = out
    path.write_text(json.dumps(doc, indent=2))
    print(f"bench_adaptive → {path} (adaptive section)")
    for label, row in out["runs"].items():
        ph = row["phases"]
        print(f"  {label:10s} burst p95={ph['burst']['p95_ms']:7.2f}ms "
              f"slo_ok={ph['all']['slo_ok']:.4f} "
              f"idle RU/q={ph['idle_settled']['ru_per_query']:6.2f} "
              f"recompiles={row['recompiles_steady']}")
    ad = out["runs"]["adaptive"]
    print(f"  adaptive: {ad['decisions']} decisions over widths "
          f"{ad['widths_used']}, W now {ad['state']['beam_width']}, "
          f"w_changes={ad['state']['w_changes']}")
    d = out["ingest_debt"]
    print(f"  ingest ledger: deferred={d['deferred_chunks']} "
          f"caught_up={d['catchup_chunks']} backlog={d['backlog_chunks']}")
    print(f"  idle RU/query: adaptive/W1={out['idle_ru_adaptive_vs_w1']:.3f}x "
          f"(static W4/W1={out['idle_ru_w4_vs_w1']:.3f}x)")
    ch = out["chaos_adaptive"]
    print(f"  chaos(adaptive): availability={ch['availability']:.4f} "
          f"recallΔ={ch['recall_delta']:.3f} "
          f"ru_err={ch['ru_conservation_rel_err']:.1e} "
          f"p95_ratio={ch['p95_ratio']:.2f}")
    return out


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
