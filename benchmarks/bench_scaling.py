"""Fig 7/8: query cost vs index size and vs dimensionality.

Paper claims: latencies and RU increase < 2× for a 100× index-size increase
(logarithmic hop complexity), and dimensionality (100 → 768) barely moves
latency/RU. At bench scale we verify the *scaling exponent*: fit
cmps ≈ a + b·log N and report the predicted 100× growth factor, plus the
dim comparison at fixed N.
"""
from __future__ import annotations

import numpy as np

from repro.core import recall as rec

from .common import build_index, clustered, in_dist_queries, per_query_stats, query_ru


def run(sizes=(2000, 8000, 32000), dim: int = 64, L: int = 64, seed: int = 0):
    rng = np.random.RandomState(seed)
    rows = []
    for n in sizes:
        data = clustered(rng, n, dim)
        idx = build_index(data, R=16, M=16, L_build=48)
        q = in_dist_queries(data, rng, 32)
        ids, lat, ru = per_query_stats(idx, q, k=10, L=L)
        gt = rec.ground_truth(q, data, np.ones(n, bool), 10)
        rows.append(dict(n=n, ru=ru, p50=float(np.percentile(lat, 50)),
                         recall=rec.recall_at_k(ids, gt, 10)))
    # log fit: ru = a + b ln n → growth factor for 100×
    ns = np.array([r["n"] for r in rows], float)
    rus = np.array([r["ru"] for r in rows], float)
    b, a = np.polyfit(np.log(ns), rus, 1)
    ru_10m = a + b * np.log(1e7)
    ru_100k = a + b * np.log(1e5)
    growth_100x = ru_10m / max(ru_100k, 1e-9)
    return rows, growth_100x, ru_10m


def run_dim_compare(n: int = 8000, dims=(32, 96), L: int = 64, seed: int = 1):
    rng = np.random.RandomState(seed)
    out = []
    for d in dims:
        data = clustered(rng, n, d)
        idx = build_index(data, R=16, M=16 if d % 16 == 0 else 8, L_build=48)
        q = in_dist_queries(data, rng, 32)
        _, lat, ru = per_query_stats(idx, q, k=10, L=L)
        out.append(dict(dim=d, ru=ru, p50=float(np.percentile(lat, 50))))
    return out


def main():
    rows, growth, ru_10m = run()
    print("bench_scaling (Fig 7/8): N, RU, p50 modeled ms, recall")
    for r in rows:
        print(f"  N={r['n']:6d} RU={r['ru']:.1f} p50={r['p50']:.2f}ms recall={r['recall']:.3f}")
    print(f"  log-fit 100x growth factor: {growth:.2f} (paper: <2x)")
    print(f"  extrapolated RU at 10M: {ru_10m:.0f} (paper Table 1: 70)")
    dims = run_dim_compare()
    print("  dim comparison (Fig 8):",
          " vs ".join(f"D={d['dim']}: RU={d['ru']:.1f}" for d in dims))
    assert growth < 3.0, f"scaling factor {growth} way off the paper's <2x"
    return rows, growth, ru_10m, dims


if __name__ == "__main__":
    main()
