"""Fig 9: post-filtering vs filter-aware (β) search on labeled data — plus
the batched declarative-predicate section.

Paper: both reach high recall; β-search has much better tail latency/RU at
matched recall (10× p99 latency, 5× p99 cost at L=200 in the paper). At
bench scale we reproduce the qualitative ordering: β-search needs fewer
hops/comparisons (→ lower modeled p99) for comparable recall.

``run_batched`` measures the predicate-API redesign: N queries sharing ONE
canonical predicate through the engine's micro-batcher (compile the
predicate→bitmap once per partition from the inverted PROP_TERM postings,
broadcast through ``bucketed_batch_greedy_search``) versus N legacy
callable-filter queries (each rebuilding an O(capacity) mask by scanning
the doc store). Acceptance floors (``scripts/check.sh --smoke`` runs this):
batched speedup ≥ 2× wall clock, plans report ``filtered-batched[...]``,
recall parity within 0.01 of the host path.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import GraphConfig
from repro.core import recall as rec
from repro.serve import EngineConfig, F, VectorCollectionService, VectorQuery

from .common import (build_index, clustered, in_dist_queries, pct,
                     query_latency_ms, query_ru)


def run(n: int = 8000, dim: int = 48, seed: int = 0, match_frac: float = 0.12):
    rng = np.random.RandomState(seed)
    data = clustered(rng, n, dim)
    idx = build_index(data, R=16, M=8, L_build=48)
    labels = rng.randint(0, int(1 / match_frac), n)
    target = 0
    doc_filter = np.zeros(idx.cfg.capacity, bool)
    doc_filter[: n][labels == target] = True

    q = in_dist_queries(data[labels == target], rng, 24)
    live = np.zeros(n, bool)
    live[labels == target] = True
    gt = rec.ground_truth(q, data, live, 10)

    out = {}
    for mode in ("post", "beta"):
        for L in (50, 100):
            lats, rus, ids_all = [], [], []
            for i in range(len(q)):
                ids, _, st = idx.filtered_search(q[i : i + 1], 10, doc_filter,
                                                 L=L, mode=mode)
                ids_all.append(ids[0])
                lats.append(query_latency_ms(st))  # shared round-aware model
                rus.append(query_ru(st))
            r = rec.recall_at_k(np.asarray(ids_all), gt, 10)
            out[(mode, L)] = dict(recall=r, p50=pct(lats, 50), p99=pct(lats, 99),
                                  ru=float(np.mean(rus)))
    return out


def run_batched(n: int = 3000, dim: int = 32, n_queries: int = 64,
                seed: int = 0, n_labels: int = 8, k: int = 10,
                repeats: int = 3) -> dict:
    """Batched same-predicate queries (engine path) vs the legacy
    callable-filter host path, same workload on the same collection."""
    rng = np.random.RandomState(seed)
    g = GraphConfig(capacity=n + 1024, R=24, M=16, L_build=48, L_search=48,
                    bootstrap_sample=min(1000, max(128, n // 8)),
                    refine_sample=10**9, batch_size=100)
    svc = VectorCollectionService(
        dim=dim, graph=g, max_vectors_per_partition=n + 512,
        engine_cfg=EngineConfig(max_batch=16, admission_control=False),
    )
    data = clustered(rng, n, dim)
    labels = rng.randint(0, n_labels, n)
    svc.upsert([{"id": i, "label": int(labels[i])} for i in range(n)], data)

    target = 0
    pred = F.eq("label", target)
    legacy = lambda d: d["label"] == target  # noqa: E731
    match = labels == target
    queries = in_dist_queries(data[match], rng, n_queries)

    # filtered ground truth (exact, over the matching subset)
    live = np.zeros(n, bool)
    live[match] = True
    gt = rec.ground_truth(queries, data, live, k)

    def run_host():
        out = []
        for q in queries:
            out.append(svc.query(VectorQuery(vector=q, k=k, filter=legacy)))
        return out

    def run_engine():
        rids = [svc.engine.submit_query(q, k=k, predicate=pred)
                for q in queries]
        svc.engine.drain()
        return [svc.engine.pop_response(r) for r in rids]

    # warm both paths (compile signatures, prime the bitmap cache) before
    # timing; repeats interleave with best-of per side so a slow host
    # phase hits both measurements instead of skewing the ratio
    run_host()
    run_engine()
    t_host = t_batched = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        host = run_host()
        t_host = min(t_host, time.perf_counter() - t0)
        t0 = time.perf_counter()
        batched = run_engine()
        t_batched = min(t_batched, time.perf_counter() - t0)

    r_host = rec.recall_at_k(np.stack([r.ids for r in host]), gt, k)
    r_batched = rec.recall_at_k(np.stack([r.ids for r in batched]), gt, k)
    return dict(
        n=n, n_queries=n_queries, match_count=int(match.sum()),
        host_wall_s=t_host, batched_wall_s=t_batched,
        speedup=t_host / t_batched,
        host_qps_wall=n_queries / t_host,
        batched_qps_wall=n_queries / t_batched,
        recall_host=r_host, recall_batched=r_batched,
        recall_delta=abs(r_host - r_batched),
        plan_batched=batched[0].plan, plan_host=host[0].plan,
        ru_host_per_q=float(np.mean([r.ru for r in host])),
        ru_batched_per_q=float(np.mean([r.ru for r in batched])),
        mean_batch_size=float(np.mean([r.batch_size for r in batched])),
    )


def main(smoke: bool = False):
    out = run() if not smoke else run(n=2000, match_frac=0.2)
    print("bench_filtered (Fig 9): mode, L, recall, p50/p99 modeled ms, RU")
    for (mode, L), r in out.items():
        print(f"  {mode:5s} L={L:4d} recall={r['recall']:.3f} "
              f"p50={r['p50']:.2f} p99={r['p99']:.2f} RU={r['ru']:.1f}")

    b = run_batched() if not smoke else run_batched(n=1200, n_queries=32)
    out["batched"] = b
    print(f"  batched same-predicate: {b['speedup']:.2f}x wall "
          f"({b['host_qps_wall']:.1f} → {b['batched_qps_wall']:.1f} q/s), "
          f"plan {b['plan_host']} → {b['plan_batched']}, "
          f"recall {b['recall_host']:.3f} vs {b['recall_batched']:.3f}, "
          f"RU/q {b['ru_host_per_q']:.1f} → {b['ru_batched_per_q']:.1f}, "
          f"occupancy {b['mean_batch_size']:.1f}")

    # acceptance floors (ISSUE 5): same-predicate filtered queries must
    # execute through the engine's BATCHED path measurably faster than the
    # legacy per-query host path, at recall parity
    assert b["plan_batched"].startswith("filtered-batched["), \
        f"predicate path not batched: {b['plan_batched']}"
    assert b["plan_host"].startswith("filtered-legacy["), \
        f"legacy path lost its deprecation marker: {b['plan_host']}"
    assert b["speedup"] >= 2.0, \
        f"batched-filtered speedup {b['speedup']:.2f}x < 2.0x"
    assert b["recall_delta"] <= 0.01, \
        f"batched recall diverged from host path by {b['recall_delta']:.3f}"
    return out


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
