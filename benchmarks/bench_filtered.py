"""Fig 9: post-filtering vs filter-aware (β) search on labeled data — plus
the batched declarative-predicate section.

Paper: both reach high recall; β-search has much better tail latency/RU at
matched recall (10× p99 latency, 5× p99 cost at L=200 in the paper). At
bench scale we reproduce the qualitative ordering: β-search needs fewer
hops/comparisons (→ lower modeled p99) for comparable recall.

``run_batched`` measures the predicate micro-batching win: N queries
sharing ONE canonical predicate coalesce through the engine's
micro-batcher (compile the predicate→bitmap once per partition from the
inverted PROP_TERM postings, broadcast through
``bucketed_batch_greedy_search``) versus the same N queries dispatched
one at a time (each its own batch of 1 through the same engine path —
the legacy callable-filter host path is retired and raises).
Acceptance floors (``scripts/check.sh --smoke`` runs this): batched
speedup ≥ 2× wall clock, plans report ``filtered-batched[...]``,
recall parity within 0.01 of the per-query dispatch.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import GraphConfig
from repro.core import recall as rec
from repro.serve import EngineConfig, F, VectorCollectionService, VectorQuery

from .common import (build_index, clustered, in_dist_queries, pct,
                     query_latency_ms, query_ru)


def run(n: int = 8000, dim: int = 48, seed: int = 0, match_frac: float = 0.12):
    rng = np.random.RandomState(seed)
    data = clustered(rng, n, dim)
    idx = build_index(data, R=16, M=8, L_build=48)
    labels = rng.randint(0, int(1 / match_frac), n)
    target = 0
    doc_filter = np.zeros(idx.cfg.capacity, bool)
    doc_filter[: n][labels == target] = True

    q = in_dist_queries(data[labels == target], rng, 24)
    live = np.zeros(n, bool)
    live[labels == target] = True
    gt = rec.ground_truth(q, data, live, 10)

    out = {}
    for mode in ("post", "beta"):
        for L in (50, 100):
            lats, rus, ids_all = [], [], []
            for i in range(len(q)):
                ids, _, st = idx.filtered_search(q[i : i + 1], 10, doc_filter,
                                                 L=L, mode=mode)
                ids_all.append(ids[0])
                lats.append(query_latency_ms(st))  # shared round-aware model
                rus.append(query_ru(st))
            r = rec.recall_at_k(np.asarray(ids_all), gt, 10)
            out[(mode, L)] = dict(recall=r, p50=pct(lats, 50), p99=pct(lats, 99),
                                  ru=float(np.mean(rus)))
    return out


def run_batched(n: int = 3000, dim: int = 32, n_queries: int = 64,
                seed: int = 0, n_labels: int = 8, k: int = 10,
                repeats: int = 3) -> dict:
    """Batched same-predicate queries (one coalesced micro-batch) vs the
    same queries dispatched per-query (B=1 batches), same workload on the
    same collection and the same engine path."""
    rng = np.random.RandomState(seed)
    g = GraphConfig(capacity=n + 1024, R=24, M=16, L_build=48, L_search=48,
                    bootstrap_sample=min(1000, max(128, n // 8)),
                    refine_sample=10**9, batch_size=100)
    svc = VectorCollectionService(
        dim=dim, graph=g, max_vectors_per_partition=n + 512,
        engine_cfg=EngineConfig(max_batch=16, admission_control=False),
    )
    data = clustered(rng, n, dim)
    labels = rng.randint(0, n_labels, n)
    svc.upsert([{"id": i, "label": int(labels[i])} for i in range(n)], data)

    target = 0
    pred = F.eq("label", target)
    match = labels == target
    queries = in_dist_queries(data[match], rng, n_queries)

    # filtered ground truth (exact, over the matching subset)
    live = np.zeros(n, bool)
    live[match] = True
    gt = rec.ground_truth(queries, data, live, k)

    def run_unbatched():
        # one engine dispatch per query: each rides the same batched
        # predicate path, padded to a batch of 1
        return [svc.query(VectorQuery(vector=q, k=k, filter=pred))
                for q in queries]

    def run_engine():
        rids = [svc.engine.submit_query(q, k=k, predicate=pred)
                for q in queries]
        svc.engine.drain()
        return [svc.engine.pop_response(r) for r in rids]

    # warm both paths (compile signatures, prime the bitmap cache) before
    # timing; repeats interleave with best-of per side so a slow host
    # phase hits both measurements instead of skewing the ratio
    run_unbatched()
    run_engine()
    t_unbatched = t_batched = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        unbatched = run_unbatched()
        t_unbatched = min(t_unbatched, time.perf_counter() - t0)
        t0 = time.perf_counter()
        batched = run_engine()
        t_batched = min(t_batched, time.perf_counter() - t0)

    r_unbatched = rec.recall_at_k(np.stack([r.ids for r in unbatched]), gt, k)
    r_batched = rec.recall_at_k(np.stack([r.ids for r in batched]), gt, k)
    return dict(
        n=n, n_queries=n_queries, match_count=int(match.sum()),
        unbatched_wall_s=t_unbatched, batched_wall_s=t_batched,
        speedup=t_unbatched / t_batched,
        unbatched_qps_wall=n_queries / t_unbatched,
        batched_qps_wall=n_queries / t_batched,
        recall_unbatched=r_unbatched, recall_batched=r_batched,
        recall_delta=abs(r_unbatched - r_batched),
        plan_batched=batched[0].plan, plan_unbatched=unbatched[0].plan,
        ru_unbatched_per_q=float(np.mean([r.ru for r in unbatched])),
        ru_batched_per_q=float(np.mean([r.ru for r in batched])),
        mean_batch_size=float(np.mean([r.batch_size for r in batched])),
    )


def main(smoke: bool = False):
    out = run() if not smoke else run(n=2000, match_frac=0.2)
    print("bench_filtered (Fig 9): mode, L, recall, p50/p99 modeled ms, RU")
    for (mode, L), r in out.items():
        print(f"  {mode:5s} L={L:4d} recall={r['recall']:.3f} "
              f"p50={r['p50']:.2f} p99={r['p99']:.2f} RU={r['ru']:.1f}")

    b = run_batched() if not smoke else run_batched(n=1200, n_queries=32)
    out["batched"] = b
    print(f"  batched same-predicate: {b['speedup']:.2f}x wall "
          f"({b['unbatched_qps_wall']:.1f} → {b['batched_qps_wall']:.1f} q/s), "
          f"plan {b['plan_unbatched']} → {b['plan_batched']}, "
          f"recall {b['recall_unbatched']:.3f} vs {b['recall_batched']:.3f}, "
          f"RU/q {b['ru_unbatched_per_q']:.1f} → {b['ru_batched_per_q']:.1f}, "
          f"occupancy {b['mean_batch_size']:.1f}")

    # acceptance floors (ISSUE 5 / ISSUE 6): same-predicate filtered
    # queries must coalesce through the engine's BATCHED path measurably
    # faster than dispatching them one at a time, at recall parity.
    # (Since the legacy callable baseline is retired, both sides run the
    # same compiled-bitmap path — the speedup isolates micro-batching.)
    assert b["plan_batched"].startswith("filtered-batched["), \
        f"predicate path not batched: {b['plan_batched']}"
    assert b["plan_unbatched"].startswith("filtered-batched["), \
        f"per-query dispatch fell off the predicate path: {b['plan_unbatched']}"
    assert b["mean_batch_size"] >= 8.0, \
        f"same-predicate queries failed to coalesce: {b['mean_batch_size']:.1f}"
    assert b["speedup"] >= 2.0, \
        f"batched-filtered speedup {b['speedup']:.2f}x < 2.0x"
    assert b["recall_delta"] <= 0.01, \
        f"batched recall diverged from per-query path by {b['recall_delta']:.3f}"
    return out


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
