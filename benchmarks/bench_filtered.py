"""Fig 9: post-filtering vs filter-aware (β) search on labeled data.

Paper: both reach high recall; β-search has much better tail latency/RU at
matched recall (10× p99 latency, 5× p99 cost at L=200 in the paper). At
bench scale we reproduce the qualitative ordering: β-search needs fewer
hops/comparisons (→ lower modeled p99) for comparable recall.
"""
from __future__ import annotations

import numpy as np

from repro.core import recall as rec

from .common import (build_index, clustered, in_dist_queries, pct,
                     query_latency_ms, query_ru)


def run(n: int = 8000, dim: int = 48, seed: int = 0, match_frac: float = 0.12):
    rng = np.random.RandomState(seed)
    data = clustered(rng, n, dim)
    idx = build_index(data, R=16, M=8, L_build=48)
    labels = rng.randint(0, int(1 / match_frac), n)
    target = 0
    doc_filter = np.zeros(idx.cfg.capacity, bool)
    doc_filter[: n][labels == target] = True

    q = in_dist_queries(data[labels == target], rng, 24)
    live = np.zeros(n, bool)
    live[labels == target] = True
    gt = rec.ground_truth(q, data, live, 10)

    out = {}
    for mode in ("post", "beta"):
        for L in (50, 100):
            lats, rus, ids_all = [], [], []
            for i in range(len(q)):
                ids, _, st = idx.filtered_search(q[i : i + 1], 10, doc_filter,
                                                 L=L, mode=mode)
                ids_all.append(ids[0])
                lats.append(query_latency_ms(st))  # shared round-aware model
                rus.append(query_ru(st))
            r = rec.recall_at_k(np.asarray(ids_all), gt, 10)
            out[(mode, L)] = dict(recall=r, p50=pct(lats, 50), p99=pct(lats, 99),
                                  ru=float(np.mean(rus)))
    return out


def main():
    out = run()
    print("bench_filtered (Fig 9): mode, L, recall, p50/p99 modeled ms, RU")
    for (mode, L), r in out.items():
        print(f"  {mode:5s} L={L:4d} recall={r['recall']:.3f} "
              f"p50={r['p50']:.2f} p99={r['p99']:.2f} RU={r['ru']:.1f}")
    return out


if __name__ == "__main__":
    main()
