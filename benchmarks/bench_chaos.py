"""Chaos harness (ISSUE 8): a seeded fault schedule against steady traffic.

The fault-tolerance plane's acceptance measurement: one arrival-driven
run replays the SAME offered traffic twice — once fault-free (the
baseline), once under a deterministic chaos schedule — and the system
must hold its availability, recall, and accounting contracts while
replicas die and recover, lanes fault mid-dispatch, replica rebuilds
replay the real snapshot+WAL codec path, and a deadline-pressure wave
forces queue abandonment:

  * **availability ≥ 99%** — 200s over every admitted request (429s are
    governance, not faults, and are excluded; the run must produce none);
  * **recall Δ ≤ 0.01 on complete responses** — a response that claims
    ``complete=True`` under chaos must match the fault-free answer
    quality (degraded responses are exempt: they honestly carry
    ``complete=False`` and a ``+degraded[pids]`` plan marker);
  * **RU conservation, exactly** — per-tenant attributed RU (query +
    page + hedge) equals governor settlements to 1e-9 relative error,
    408 refunds included;
  * **bounded p95** — chaos p95 within 5× the fault-free p95 on
    identical traffic;
  * **every 408 reconciles** — the response's recorded wait covers its
    deadline budget, and every trace (200 and 408 alike) passes
    root-span tiling validation;
  * **crash-consistent recovery** — every in-run replica rebuild AND
    every armed-crash cycle (upsert/delete interrupted at a named
    barrier on a scratch partition pair) restores bit-for-bit parity
    via ``recovery_invariants``.

Standalone ``python -m benchmarks.bench_chaos [--smoke]`` merges the
``chaos`` section into an existing ``BENCH_serve.json`` (or writes a
fresh file holding only that section); ``bench_serve.run()`` embeds it
directly.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

import numpy as np

from repro.core import GraphConfig
from repro.core import recall as rec
from repro.partition import CollectionConfig
from repro.partition.partitioner import PhysicalPartition, hash_key
from repro.serve import (EngineConfig, VectorCollectionService,
                         VectorServeEngine, validate_trace_record)
from repro.store.faults import CrashError, FaultPlan, recovery_invariants
from repro.store.provider import StoreProviderSet

from .bench_serve import warmup
from .common import clustered

CRASH_BARRIERS = ("upsert:begin", "upsert:pre_commit", "upsert:post_full",
                  "delete:post_props", "delete:pre_commit")


def _build(n: int, dim: int, parts: int, replicas: int, seed: int):
    rng = np.random.RandomState(seed)
    g = GraphConfig(capacity=2 * (n // parts) + 256, R=16, M=8, L_build=32,
                    L_search=32, bootstrap_sample=48, refine_sample=10**9,
                    batch_size=64)
    svc = VectorCollectionService(
        dim=dim, graph=g, max_vectors_per_partition=2 * (n // parts),
        initial_partitions=parts, replicas=replicas,
    )
    data = clustered(rng, n, dim)
    svc.upsert([{"id": i} for i in range(n)], data,
               partition_keys=[f"pk{i}" for i in range(n)])
    for rs in svc.replica_sets:
        rs.reprobe_after_s = 0.05  # sim-time cooldown: deaths are transient
    return svc, data, rng


def _engine(svc, flight: int, lanes: int,
            policy: str = "static") -> VectorServeEngine:
    # admission ON with an unreachable budget: every RU flows through the
    # governors (reservation → settle/refund) so conservation is testable,
    # but no request 429s — the run measures faults, not throttling.
    # Replica dispatch + stragglers + hedging put the accounting under the
    # most adversarial load the engine has.
    cfg = EngineConfig(max_batch=8, dispatch_mode="replica", lanes=lanes,
                      admission_control=True, tenant_ru_s=10**9,
                      straggler_p=0.2, hedge_at_ms=0.5, dispatch_seed=7,
                      lane_reprobe_after_s=0.05, flight_recorder=flight,
                      policy=policy)
    return VectorServeEngine(svc.collection, cfg=cfg,
                             replica_sets=svc.replica_sets)


# ---------------------------------------------------------------------------
# the chaos schedule
# ---------------------------------------------------------------------------


def _schedule(rng: np.random.RandomState, t0: float, t1: float,
              n_kills: int, n_blackouts: int, n_rebuilds: int,
              n_lane_faults: int) -> list[tuple[float, str]]:
    """Seeded (time, kind) fault events over the traffic window. Lane
    faults are spaced at least two re-probe cooldowns apart so a burst
    cannot take a whole replica set below quorum through the lane plane."""
    span = t1 - t0
    ev = [(t0 + span * rng.uniform(0.05, 0.9), "kill")
          for _ in range(n_kills)]
    ev += [(t0 + span * (0.2 + 0.5 * i / max(n_blackouts, 1)), "blackout")
           for i in range(n_blackouts)]
    ev += [(t0 + span * rng.uniform(0.1, 0.85), "rebuild")
           for _ in range(n_rebuilds)]
    lane_ts = t0 + span * np.sort(rng.uniform(0.05, 0.9, size=n_lane_faults))
    lane_ts = np.maximum.accumulate(lane_ts + 0.12 * np.arange(n_lane_faults))
    ev += [(float(t), "lane_fault") for t in lane_ts]
    return sorted(ev, key=lambda e: e[0])


def _fire(event: tuple[float, str], svc, eng, rng, now: float,
          stats: dict):
    """Apply one chaos event to the live system."""
    kind = event[1]
    sets = svc.replica_sets
    if kind == "kill":
        rs = sets[rng.randint(len(sets))]
        alive = rs.healthy()
        if len(alive) > 1:  # a kill is a fault, not an extinction event
            rs.kill(alive[rng.randint(len(alive))].rid, now_s=now)
            stats["kills"] += 1
    elif kind == "blackout":
        # total partition loss for one re-probe window: every replica of
        # one set down at once — queries touching it must degrade, not fail
        rs = sets[rng.randint(len(sets))]
        for r in rs.replicas:
            r.alive = False
            r.down_since_s = now
        stats["blackouts"] += 1
    elif kind == "rebuild":
        # crash-recover cycle through the REAL durable path: kill a
        # replica, capture snapshot+WAL, rebuild from the bytes, and
        # demand bit-for-bit parity with the live provider set
        rs = sets[rng.randint(len(sets))]
        alive = rs.healthy()
        if len(alive) > 1:
            rid = alive[rng.randint(len(alive))].rid
            rs.kill(rid, now_s=now)
            fresh = rs.rebuild(rid, rs.capture())
            recovery_invariants(fresh, rs.partition.providers)
            stats["rebuild_cycles"] += 1
    elif kind == "lane_fault":
        # armed executor fault: fires on lane selection mid-dispatch; the
        # retry machine reroutes and the lane-health callbacks kill the
        # matching replica in every set (revived by the next re-probe)
        lanes = eng.executor.healthy_lanes()
        if len(lanes) > 1:
            eng.executor.inject_fault(lanes[rng.randint(len(lanes))].lane_id)
            stats["lane_faults"] += 1


def _run_traffic(eng, svc, queries, arrivals, deadlines=None,
                 schedule=(), rng=None, stats=None):
    """The arrival-driven event loop, with chaos events interleaved at
    their scheduled simulated times. Returns the per-query responses."""
    schedule = list(schedule)
    si, i, n = 0, 0, len(queries)
    rids = []
    while i < n or eng.queue:
        now = eng.clock.now()
        while si < len(schedule) and schedule[si][0] <= now:
            _fire(schedule[si], svc, eng, rng, now, stats)
            si += 1
        if schedule:
            for rs in svc.replica_sets:
                rs.probe_dead(now)
        while i < n and arrivals[i] <= now:
            dl = None if deadlines is None else deadlines[i]
            rids.append(eng.submit_query(
                queries[i], k=10, tenant=f"t{i % 2}",
                arrival_s=float(arrivals[i]), deadline_ms=dl))
            i += 1
        if eng.pump():
            continue
        events = []
        if i < n:
            events.append(float(arrivals[i]))
        if eng.queue:
            events.append(min(r.arrival_s for r in eng.queue)
                          + eng.cfg.max_wait_s)
        if si < len(schedule):
            events.append(float(schedule[si][0]))
        if not events:
            break
        eng.clock.advance(max(min(events) - now, 0.0))
        if min(events) <= now:
            eng.pump(force=True)
    eng.drain()
    return [eng.pop_response(r) for r in rids]


# ---------------------------------------------------------------------------
# armed-crash recovery cycles (scratch partition pairs)
# ---------------------------------------------------------------------------


def _crash_cycles(seed: int, barriers=CRASH_BARRIERS) -> dict:
    """Interrupt an upsert/delete at each named barrier on a scratch
    partition and recover from the durable bytes: the recovered provider
    must equal a twin that never attempted the op — arrays AND terms."""
    dim, n0 = 8, 20
    g = GraphConfig(capacity=96, R=8, M=4, L_build=16, L_search=24,
                    bootstrap_sample=16, refine_sample=10**9, batch_size=8)
    cc = CollectionConfig(dim=dim, graph=g, max_vectors_per_partition=80)
    parity = 0
    for bi, barrier in enumerate(barriers):
        rng = np.random.RandomState(seed + bi)
        subject, twin = (PhysicalPartition(cc, 0, 1 << 32, 0)
                         for _ in range(2))
        data = rng.randn(n0, dim).astype(np.float32)
        ids = list(range(n0))
        props = [(("cat", i % 3),) for i in ids]
        for p in (subject, twin):
            p.insert(ids, [hash_key(i) for i in ids], data, props=props)
        snap = subject.providers.snapshot_bytes()
        FaultPlan(seed=seed + bi).arm(barrier).attach(subject.providers)
        try:
            if barrier.startswith("upsert"):
                v = rng.randn(1, dim).astype(np.float32)
                subject.insert([n0], [hash_key(n0)], v,
                               props=[(("cat", 0),)])
            else:
                subject.delete([3])
            raise AssertionError(f"armed barrier {barrier} did not fire")
        except CrashError:
            pass
        fresh = StoreProviderSet(
            subject.providers.neighbors.shape[0],
            subject.providers.neighbors.shape[1],
            subject.providers.codes.shape[1],
            subject.providers.vectors.shape[1],
        )
        fresh.recover(snap, subject.providers.wal_bytes())
        recovery_invariants(fresh, twin.providers)
        parity += 1
    return dict(cycles=len(barriers), parity_ok=parity,
                barriers=list(barriers))


# ---------------------------------------------------------------------------
# the measurement
# ---------------------------------------------------------------------------


def run_chaos(n: int = 2000, dim: int = 32, parts: int = 3, replicas: int = 3,
              n_queries: int = 400, rate_qps: float = 400.0, seed: int = 29,
              n_tight_deadlines: int = 3, policy: str = "static",
              tiered: "float | None" = None) -> dict:
    svc, data, rng = _build(n, dim, parts, replicas, seed)
    if tiered is not None:
        # paged-tier chaos (ISSUE 10): the SAME fault gates must hold with
        # only `tiered` of each partition's vector pages resident. Both
        # the fault-free baseline and the chaos run see the tier, so the
        # recall/latency deltas still isolate the faults.
        svc.set_residency(tiered)
    queries = data[rng.choice(n, n_queries, replace=False)] + 0.01
    gt = rec.ground_truth(queries, data, np.ones(n, bool), 10)
    gaps = rng.exponential(1.0 / rate_qps, size=n_queries)

    # fault-free baseline on the identical arrival realization
    eng0 = _engine(svc, flight=4 * n_queries, lanes=replicas)
    warmup(eng0, data)
    base = _run_traffic(eng0, svc, queries,
                        eng0.clock.now() + np.cumsum(gaps))
    assert all(r is not None and r.status == 200 and r.complete
               for r in base), "baseline run must be fault-free"
    base_ids = np.stack([r.ids for r in base])
    base_recall = rec.recall_at_k(base_ids, gt, 10)
    base_p95 = eng0.metrics.latency_ms.percentile(95)

    # chaos run: same traffic + seeded fault schedule + a deadline wave
    # (a handful of sub-queue-wait budgets mid-stream MUST be abandoned).
    # With policy="adaptive" the SAME fault gates must hold while the
    # control loop actuates W / ingest yield mid-chaos (ISSUE 9).
    eng = _engine(svc, flight=4 * n_queries, lanes=replicas, policy=policy)
    if eng.policy.enabled:
        from .bench_adaptive import warmup_widths
        warmup_widths(eng, data, eng.cfg.policy_widths)
    else:
        warmup(eng, data)
    # governors survive the warmup metrics reset; conservation is checked
    # against what THIS epoch settles, so baseline the consumed totals
    consumed0 = {t: g.consumed for t, g in eng.tenants.items()}
    arrivals = eng.clock.now() + np.cumsum(gaps)
    deadlines = [None] * n_queries
    wave0 = n_queries // 2
    for j in range(n_tight_deadlines):
        deadlines[wave0 + 2 * j] = 0.0  # expired on arrival: certain 408
        deadlines[wave0 + 2 * j + 1] = 50.0  # generous: must still serve
    stats = dict(kills=0, blackouts=0, rebuild_cycles=0, lane_faults=0)
    sched = _schedule(rng, float(arrivals[0]), float(arrivals[-1]),
                      n_kills=4, n_blackouts=2,
                      n_rebuilds=max(2, parts - 1), n_lane_faults=3)
    resps = _run_traffic(eng, svc, queries, arrivals, deadlines=deadlines,
                         schedule=sched, rng=rng, stats=stats)
    assert all(r is not None for r in resps)

    ok = [r for r in resps if r.status == 200]
    aborted = [r for r in resps if r.status == 408]
    assert not any(r.status == 429 for r in resps), \
        "chaos run must not throttle (unreachable budget)"
    availability = len(ok) / max(len(resps), 1)
    complete = [(i, r) for i, r in enumerate(resps)
                if r.status == 200 and r.complete]
    degraded = [r for r in ok if not r.complete]
    crecall = rec.recall_at_k(
        np.stack([r.ids for _, r in complete]),
        gt[[i for i, _ in complete]], 10)
    p95 = eng.metrics.latency_ms.percentile(95)

    # every 408 reconciles: the wait the response records covers the
    # budget it was given, and its trace passes root-span tiling
    for i, r in enumerate(resps):
        if r.status == 408:
            assert deadlines[i] is not None and r.wait_ms >= deadlines[i], \
                f"408 rid={r.rid} waited {r.wait_ms}ms < {deadlines[i]}ms"
    recs = [t for t in eng.tracer.recorder.records() if t["kind"] == "query"]
    for t in recs:
        validate_trace_record(t)
    anomalies = [t for t in recs if t["anomalies"]]

    # RU conservation under faults, refunds included: attributed == settled
    ru_err = 0.0
    for t, gov in eng.tenants.items():
        attributed = sum(
            eng.obs.total("serve_ru_total", tenant=str(t), op=op)
            for op in ("query", "page", "hedge"))
        settled = gov.consumed - consumed0.get(t, 0.0)
        ru_err = max(ru_err, abs(attributed - settled)
                     / max(abs(settled), 1e-9))

    crash = _crash_cycles(seed)
    m = eng.metrics
    out = dict(
        config=dict(n=n, dim=dim, parts=parts, replicas=replicas,
                    n_queries=n_queries, rate_qps=rate_qps, seed=seed,
                    policy=policy, tiered=tiered),
        schedule=stats,
        availability=availability,
        served=len(ok), deadline_abandoned=len(aborted),
        degraded=len(degraded),
        recall_baseline=base_recall, recall_chaos_complete=crecall,
        recall_delta=abs(base_recall - crecall),
        p95_baseline_ms=base_p95, p95_chaos_ms=p95,
        p95_ratio=p95 / max(base_p95, 1e-9),
        ru_conservation_rel_err=ru_err,
        hedges=int(m.hedges),
        replica_recoveries=int(sum(rs.recoveries for rs in svc.replica_sets)),
        replica_failovers=int(sum(rs.failovers for rs in svc.replica_sets)),
        lane_faults_fired=int(eng.executor.snapshot()["faults"]),
        traces=len(recs), anomaly_traces=len(anomalies),
        crash_recovery=crash,
    )

    # acceptance floors (ISSUE 8)
    assert stats["kills"] >= 1 and stats["blackouts"] >= 1 \
        and stats["rebuild_cycles"] >= 1 and stats["lane_faults"] >= 1, \
        f"chaos schedule failed to fire every fault family: {stats}"
    assert len(aborted) >= 1, "deadline wave produced no 408s"
    assert len(degraded) >= 1, "blackouts produced no degraded responses"
    assert availability >= 0.99, \
        f"availability {availability:.4f} < 0.99 under chaos"
    assert out["recall_delta"] <= 0.01, \
        f"complete-response recall drifted {out['recall_delta']:.3f} > 0.01"
    assert ru_err <= 1e-9, \
        f"RU conservation broke under faults: rel err {ru_err:.2e}"
    assert out["p95_ratio"] <= 5.0, \
        f"chaos p95 {p95:.2f}ms > 5x baseline {base_p95:.2f}ms"
    assert out["replica_recoveries"] >= stats["kills"], \
        "killed replicas did not come back through the rebuild path"
    assert crash["parity_ok"] == crash["cycles"]
    assert len(recs) == len(resps), \
        f"retained {len(recs)} traces for {len(resps)} requests"
    assert len(anomalies) >= len(aborted) + len(degraded), \
        "408/degraded requests must surface as anomaly traces"
    return out


def run(smoke: bool = False, policy: str = "static") -> dict:
    if smoke:
        return run_chaos(n=600, dim=32, parts=3, replicas=3, n_queries=160,
                         rate_qps=400.0, n_tight_deadlines=1, policy=policy)
    return run_chaos(policy=policy)


def main(smoke: bool = False):
    out = run(smoke=smoke)
    name = "BENCH_serve.smoke.json" if smoke else "BENCH_serve.json"
    path = Path(__file__).resolve().parent.parent / name
    doc = json.loads(path.read_text()) if path.exists() else {}
    doc["chaos"] = out
    path.write_text(json.dumps(doc, indent=2))
    print(f"bench_chaos → {path} (chaos section)")
    st = out["schedule"]
    print(f"  schedule: kills={st['kills']} blackouts={st['blackouts']} "
          f"rebuilds={st['rebuild_cycles']} lane_faults={st['lane_faults']}")
    print(f"  availability={out['availability']:.4f} "
          f"(served={out['served']}, 408s={out['deadline_abandoned']}, "
          f"degraded={out['degraded']})")
    print(f"  recall: baseline {out['recall_baseline']:.3f} → chaos(complete) "
          f"{out['recall_chaos_complete']:.3f} (Δ={out['recall_delta']:.3f})")
    print(f"  p95: {out['p95_baseline_ms']:.2f}ms → {out['p95_chaos_ms']:.2f}ms "
          f"({out['p95_ratio']:.2f}x), hedges={out['hedges']}")
    print(f"  RU conservation rel err {out['ru_conservation_rel_err']:.2e}; "
          f"recoveries={out['replica_recoveries']} "
          f"failovers={out['replica_failovers']} "
          f"lane_faults_fired={out['lane_faults_fired']}")
    print(f"  crash recovery: {out['crash_recovery']['parity_ok']}"
          f"/{out['crash_recovery']['cycles']} barrier cycles bit-identical")
    return out


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
