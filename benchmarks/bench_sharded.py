"""Table 3: sharded DiskANN (per-tenant indices) vs one big index.

Paper (YFCC, year shards): sharded gives ~3× lower latency AND higher
recall (98 vs 66) than filtering a non-sharded index at the same L — and
beats even L=1000 non-sharded. We reproduce with tenant-labeled clusters.
"""
from __future__ import annotations

import numpy as np

from repro.core import recall as rec

from .common import build_index, clustered, pct, query_latency_ms


def run(n_tenants: int = 6, per_tenant: int = 1200, dim: int = 32, seed: int = 0):
    rng = np.random.RandomState(seed)
    # tenants share the embedding space (the YFCC year-shard regime):
    # per-tenant clusters interleave, so filtering the shared index must
    # wade through non-matching neighbors — the Table 3 setting
    tenant_data = [clustered(np.random.RandomState(seed + 100 + t), per_tenant, dim, k=8)
                   for t in range(n_tenants)]
    all_data = np.concatenate(tenant_data)
    labels = np.repeat(np.arange(n_tenants), per_tenant)

    big = build_index(all_data, R=16, M=8, L_build=48)
    shard = build_index(tenant_data[0], R=16, M=8, L_build=48, seed=1)

    target = 0
    q = tenant_data[target][rng.choice(per_tenant, 24)] + 0.02
    live = labels == target
    gt = rec.ground_truth(q, all_data, live, 10)

    def eval_filtered(L):
        doc_filter = np.zeros(big.cfg.capacity, bool)
        doc_filter[: len(all_data)][live] = True
        lats, ids_all = [], []
        for i in range(len(q)):
            ids, _, st = big.filtered_search(q[i : i + 1], 10, doc_filter,
                                             L=L, mode="beta")
            ids_all.append(ids[0])
            lats.append(query_latency_ms(st))  # shared round-aware model
        return rec.recall_at_k(np.asarray(ids_all), gt, 10), lats

    def eval_sharded(L):
        lats, ids_all = [], []
        gt_local = rec.ground_truth(q, tenant_data[target],
                                    np.ones(per_tenant, bool), 10)
        for i in range(len(q)):
            ids, _, st = shard.search(q[i : i + 1], 10, L=L)
            ids_all.append(ids[0])
            lats.append(query_latency_ms(st))  # shared round-aware model
        return rec.recall_at_k(np.asarray(ids_all), gt_local, 10), lats

    r_sh, lat_sh = eval_sharded(50)
    r_ns, lat_ns = eval_filtered(50)
    r_ns_big, lat_ns_big = eval_filtered(200)
    return dict(
        sharded=dict(recall=r_sh, p50=pct(lat_sh, 50), p99=pct(lat_sh, 99)),
        nonsharded_L50=dict(recall=r_ns, p50=pct(lat_ns, 50), p99=pct(lat_ns, 99)),
        nonsharded_L200=dict(recall=r_ns_big, p50=pct(lat_ns_big, 50),
                             p99=pct(lat_ns_big, 99)),
    )


def main():
    out = run()
    print("bench_sharded (Table 3): scenario, recall@10, p50/p99 modeled ms")
    for k, v in out.items():
        print(f"  {k:16s} recall={v['recall']:.3f} p50={v['p50']:.2f} p99={v['p99']:.2f}")
    assert out["sharded"]["recall"] >= out["nonsharded_L50"]["recall"] - 0.05
    assert out["sharded"]["p50"] <= out["nonsharded_L200"]["p50"]
    return out


if __name__ == "__main__":
    main()
