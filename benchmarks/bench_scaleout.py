"""Fig 10: scale-out — query cost/latency across partition counts.

Paper: RU grows ~linearly with partitions (fan-out) but logarithmically
with per-partition size; client latency tracks the max server latency, so
fewer, fuller partitions are better. We sweep partition counts at fixed
total N and report RU totals + simulated client latency (max over servers
with lognormal jitter), with and without hedging.
"""
from __future__ import annotations

import numpy as np

from repro.core import GraphConfig
from repro.partition import Collection, CollectionConfig
from repro.partition.fanout import fanout_search

from .common import clustered, in_dist_queries, pct


def run(total_n: int = 8000, dim: int = 32, parts=(1, 2, 4, 8), seed: int = 0):
    rng = np.random.RandomState(seed)
    data = clustered(rng, total_n, dim)
    q = in_dist_queries(data, rng, 16)
    rows = []
    for p in parts:
        g = GraphConfig(capacity=total_n // p + 256, R=12, M=8, L_build=40,
                        L_search=48, bootstrap_sample=128,
                        refine_sample=10**9, batch_size=64)
        cc = CollectionConfig(dim=dim, graph=g,
                              max_vectors_per_partition=total_n // p + 128,
                              initial_partitions=p)
        col = Collection(cc)
        col.insert(list(range(total_n)), list(range(total_n)), data)
        lat_model = lambda part, rr: float(np.exp(rr.normal(np.log(8), 0.35)))
        lats, rus = [], []
        for i in range(len(q)):
            _, _, info = fanout_search(col.partitions, q[i : i + 1], 10,
                                       latency_model=lat_model,
                                       rng=np.random.RandomState(seed + i))
            lats.append(info["client_latency_ms"])
            rus.append(info["ru_total"])
        lats_h = []
        for i in range(len(q)):
            _, _, info = fanout_search(col.partitions, q[i : i + 1], 10,
                                       latency_model=lat_model, hedge_at_ms=14,
                                       rng=np.random.RandomState(seed + i))
            lats_h.append(info["client_latency_ms"])
        rows.append(dict(partitions=p, ru=float(np.mean(rus)),
                         client_p50=pct(lats, 50), client_p99=pct(lats, 99),
                         client_p99_hedged=pct(lats_h, 99)))
    return rows


def main():
    rows = run()
    print("bench_scaleout (Fig 10): partitions, total RU, client p50/p99 (+hedged)")
    for r in rows:
        print(f"  P={r['partitions']} RU={r['ru']:.1f} p50={r['client_p50']:.1f}ms "
              f"p99={r['client_p99']:.1f}ms p99_hedged={r['client_p99_hedged']:.1f}ms")
    # fan-out cost should grow with partitions (paper: linear in partitions)
    assert rows[-1]["ru"] > rows[0]["ru"]
    return rows


if __name__ == "__main__":
    main()
