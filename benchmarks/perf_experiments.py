"""§Perf driver: hypothesis → change → re-lower → measure cycles.

Each experiment compiles one (arch × shape × mesh) cell with a named set of
overrides, extracts corrected roofline terms, and appends a row to
results/perf_log.json. Run AFTER the baseline dry-run exists:

    PYTHONPATH=src python -m benchmarks.perf_experiments --cell smollm-135m/train_4k --exp dots_remat
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import time

from repro.launch import dryrun as dr

from .roofline import analyse_cell


EXPERIMENTS = {
    # remat policy: keep matmul outputs → less recompute FLOPs, more memory
    "dots_remat": {"remat": "dots"},
    # no remat at all (upper bound on the memory cost of saving everything)
    "no_remat": {"remat": "none"},
    # deeper microbatching: activations shrink, collectives repeat
    "accum8": {"accum": 8},
    "accum2": {"accum": 2},
    # MoE dispatch group size (dispatch/combine tensor ∝ G·E·C)
    "moe_group_2048": {"cfg": lambda c: dataclasses.replace(
        c, moe=dataclasses.replace(c.moe, group_size=2048)) if c.moe else c},
    "moe_group_128": {"cfg": lambda c: dataclasses.replace(
        c, moe=dataclasses.replace(c.moe, group_size=128)) if c.moe else c},
    # context-parallel attention: shard S² attention over `model` via the
    # query-seq dim (tiny-head archs otherwise replicate it per model shard)
    "cp_attn": {"cfg": lambda c: dataclasses.replace(c, cp_attn=True)},
    # fp32 params (baseline bf16): measures the dtype lever on mem/collectives
    "fp32_params": {"cfg": lambda c: dataclasses.replace(c, param_dtype="float32")},
    # compute in f32 (collective/memory cost of not using bf16 activations)
    "fp32_compute": {"cfg": lambda c: dataclasses.replace(c, compute_dtype="float32")},
}


def run_experiment(cell: str, exp: str, mesh: str = "single",
                   out_dir: str = "results/perf") -> dict:
    arch, shape = cell.split("/")
    dr.OVERRIDES.clear()
    dr.OVERRIDES.update(EXPERIMENTS[exp])
    try:
        t0 = time.time()
        r = dr.run_cell(arch, shape, mesh, os.path.join(out_dir, exp), force=True)
        path = os.path.join(out_dir, exp, f"{arch}__{shape}__{mesh}.json")
        # accum override must be visible to roofline's re-multiply
        if "accum" in dr.OVERRIDES and r.get("accum"):
            r["accum"] = dr.OVERRIDES["accum"]
            with open(path, "w") as f:
                json.dump(r, f)
        row = analyse_cell(path)
        row["experiment"] = exp
        row["wall_s"] = round(time.time() - t0, 1)
        return row
    finally:
        dr.OVERRIDES.clear()


def append_log(row: dict, log_path: str = "results/perf_log.json"):
    log = []
    if os.path.exists(log_path):
        with open(log_path) as f:
            log = json.load(f)
    log.append(row)
    with open(log_path, "w") as f:
        json.dump(log, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch/shape")
    ap.add_argument("--exp", required=True, choices=sorted(EXPERIMENTS))
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    row = run_experiment(args.cell, args.exp, args.mesh)
    append_log(row)
    print(json.dumps({k: v for k, v in row.items()
                      if k in ("arch", "shape", "experiment", "t_compute",
                               "t_memory", "t_collective", "bottleneck",
                               "useful_ratio", "hbm_gib_per_device")}, indent=1))


if __name__ == "__main__":
    main()
