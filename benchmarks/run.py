"""Benchmark harness — one entry per paper table/figure.

``python -m benchmarks.run`` prints a human summary per benchmark and a
final machine-readable CSV: ``name,us_per_call,derived``.
`us_per_call` is the wall time of the benchmark's run on this CPU
container; `derived` is the benchmark's paper-comparable headline number
(see each module's docstring).
"""
from __future__ import annotations

import sys
import time
import traceback


def _entry(name, fn, derive):
    t0 = time.perf_counter()
    try:
        out = fn()
        elapsed = time.perf_counter() - t0
        return name, elapsed * 1e6, derive(out), None
    except Exception as e:  # noqa: BLE001
        traceback.print_exc()
        return name, 0.0, "", e


def main() -> None:
    from . import (bench_algo_compare, bench_cost, bench_filtered,
                   bench_ingest, bench_query, bench_runbooks, bench_scaleout,
                   bench_scaling, bench_serve, bench_sharded, bench_tiered)

    jobs = [
        ("serve_engine", bench_serve.main,
         lambda out: (f"speedup={out['speedup_batch16']['speedup']:.1f}x;"
                      f"recompiles={out['speedup_batch16']['recompiles_after_warmup']};"
                      f"p99@{out['loads'][-1]['offered_qps']:.0f}qps="
                      f"{out['loads'][-1]['p99_ms']:.1f}ms;"
                      f"page_ru_min={out['pagination']['ru_min_page']:.1f};"
                      f"scaling_gain={out['dispatch']['scaling_gain_lanes4']:.2f}x;"
                      f"trace_ovh={100 * out['observability']['overhead_frac']:.1f}%;"
                      f"adaptive_slo={out['adaptive']['slo_compliance_adaptive']:.3f};"
                      f"adaptive_idle_ru_vs_w1="
                      f"{out['adaptive']['idle_ru_adaptive_vs_w1']:.2f}x;"
                      f"stage_breakdown="
                      + "|".join(
                          f"{s}:{st['mean_ms']:.2f}ms"
                          for s, st in sorted(
                              out["loads"][-1]["stages"].items())))),
        ("tiered_residency", bench_tiered.main,
         lambda out: (f"recall_dmax={out['recall_delta_max']:.3f};"
                      f"hit_rate@0.5={out['hit_rate_half']:.2f};"
                      f"p95@0.25={out['p95_ratio_quarter']:.2f}x;"
                      f"ru@0.1={out['ru_ratio_tenth']:.2f}x;"
                      f"ids_bit_identical={out['ids_bit_identical']}")),
        ("fig6_query_vs_L", bench_query.main,
         lambda out: (f"recall@L100={out[0][-1]['recall']:.3f};"
                      f"p50={out[0][-1]['p50_ms']:.2f}ms;"
                      f"hops_w4/w1={out[1][-1]['hops'] / out[1][0]['hops']:.2f}")),
        ("fig7_8_scaling", bench_scaling.main,
         lambda out: f"growth100x={out[1]:.2f};ru10m={out[2]:.0f}"),
        ("table1_2_cost", bench_cost.main,
         lambda out: (f"pinecone_ratio={out['query_ratios']['pinecone']:.0f}x;"
                      f"zilliz_ratio={out['query_ratios']['zilliz']:.0f}x")),
        ("fig9_filtered", bench_filtered.main,
         lambda out: f"beta_p99={out[('beta', 100)]['p99']:.2f}ms;"
                     f"post_p99={out[('post', 100)]['p99']:.2f}ms;"
                     f"batched={out['batched']['speedup']:.1f}x"),
        ("fig10_scaleout", bench_scaleout.main,
         lambda rows: f"ru_p1={rows[0]['ru']:.0f};ru_p8={rows[-1]['ru']:.0f}"),
        ("fig11_12_ingest", bench_ingest.main,
         lambda traj: f"ms_per_insert={traj[-1]['ms_per_insert']:.2f}"),
        ("fig13_runbooks", bench_runbooks.main, lambda _: "see_table"),
        ("table3_sharded", bench_sharded.main,
         lambda out: f"sharded_recall={out['sharded']['recall']:.2f};"
                     f"nonsharded={out['nonsharded_L50']['recall']:.2f}"),
        ("fig14_algo_compare", bench_algo_compare.main,
         lambda out: f"graph_best_recall={max(out[1])[0]:.2f}"),
    ]

    rows = []
    failed = 0
    for name, fn, derive in jobs:
        print(f"\n################ {name} ################", flush=True)
        n, us, d, err = _entry(name, fn, derive)
        rows.append((n, us, d))
        failed += err is not None

    # roofline summary appended when dry-run artifacts exist
    try:
        from . import roofline
        rl = roofline.analyse_dir()
        ok_rows = [r for r in rl if "t_compute" in r]
        if ok_rows:
            worst = min(ok_rows, key=lambda r: r["roofline_fraction"])
            rows.append(("roofline_cells", 0.0,
                         f"cells={len(ok_rows)};worst={worst['arch']}/{worst['shape']}"))
    except Exception:  # noqa: BLE001
        traceback.print_exc()

    print("\nname,us_per_call,derived")
    for n, us, d in rows:
        print(f"{n},{us:.0f},{d}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
