"""Fig 6/16/17: query latency + RU vs search list size L, with recall@10.

Note: synthetic gaussian clusters at 64D are near-worst-case for PQ (no
low intrinsic dimension); M=32 (2 dims/subquantizer) matches the paper's
effective navigation precision on real embeddings.

Paper claim (10M × 768D): L=50 → p50 < 20 ms, recall ≈ 91.8%; larger L →
higher recall at higher latency/RU. At bench scale the same monotone
recall-vs-L and latency-vs-L curves must appear, and the modeled latency
through the §4.4 access-time constants lands in the paper's regime when
fed the paper's counter values.
"""
from __future__ import annotations

import numpy as np

from repro.core import recall as rec
from repro.kernels.topk_select import ops as topk_ops

from .common import build_index, clustered, in_dist_queries, pct, per_query_stats


def run(n: int = 8000, dim: int = 64, n_queries: int = 64, seed: int = 0):
    rng = np.random.RandomState(seed)
    data = clustered(rng, n, dim)
    idx = build_index(data, R=24, M=32, L_build=48)
    q = in_dist_queries(data, rng, n_queries)
    gt = rec.ground_truth(q, data, np.ones(n, bool), 10)

    # candidate-selection hot path: the Pallas topk_select kernel (interpret
    # off-TPU) must reproduce the brute-force top-10 on exact distances
    d = ((q * q).sum(1)[:, None] + (data * data).sum(1)[None, :]
         - 2.0 * q @ data.T).astype(np.float32)
    _, kernel_ids = topk_ops.topk_select(d, L=10)
    kernel_recall = rec.recall_at_k(np.asarray(kernel_ids), gt, 10)
    assert kernel_recall >= 0.999, f"topk_select kernel disagrees: {kernel_recall}"

    rows = []
    for L in (10, 25, 50, 100):
        ids, lat, ru = per_query_stats(idx, q, k=10, L=L)
        r = rec.recall_at_k(ids, gt, 10)
        rows.append(dict(L=L, recall=r, p50_ms=pct(lat, 50), p95_ms=pct(lat, 95),
                         p99_ms=pct(lat, 99), ru=ru))
    return rows


def main(smoke: bool = False):
    # smoke: tiny sizes so scripts/check.sh --smoke can exercise the whole
    # path (build → search → kernel cross-check → stats) in seconds
    rows = run(n=1500, dim=32, n_queries=16) if smoke else run()
    print("bench_query (Fig 6): L, recall@10, p50/p95/p99 modeled ms, RU")
    for r in rows:
        print(f"  L={r['L']:4d} recall={r['recall']:.3f} "
              f"p50={r['p50_ms']:.2f}ms p95={r['p95_ms']:.2f}ms "
              f"p99={r['p99_ms']:.2f}ms RU={r['ru']:.1f}")
    # monotone recall in L (more slack at smoke scale: 16 queries quantize
    # recall to 1/160 steps)
    slack = 0.05 if smoke else 0.02
    rc = [r["recall"] for r in rows]
    assert all(b >= a - slack for a, b in zip(rc, rc[1:])), "recall not monotone in L"
    return rows


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv[1:])
