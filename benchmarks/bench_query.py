"""Fig 6/16/17: query latency + RU vs search list size L, with recall@10.

Note: synthetic gaussian clusters at 64D are near-worst-case for PQ (no
low intrinsic dimension); M=32 (2 dims/subquantizer) matches the paper's
effective navigation precision on real embeddings.

Paper claim (10M × 768D): L=50 → p50 < 20 ms, recall ≈ 91.8%; larger L →
higher recall at higher latency/RU. At bench scale the same monotone
recall-vs-L and latency-vs-L curves must appear, and the modeled latency
through the §4.4 access-time constants lands in the paper's regime when
fed the paper's counter values.
"""
from __future__ import annotations

import numpy as np

from repro.core import recall as rec
from repro.kernels.topk_select import ops as topk_ops

from .common import (build_index, clustered, in_dist_queries, pct,
                     per_query_stats, query_latency_ms, query_ru)


def beamwidth_sweep(idx, q, gt, L: int = 50, widths=(1, 2, 4)):
    """W-way hop batching: recall must stay put while sequential rounds
    (n_hops) drop ~W× and modeled latency follows the shorter critical
    path. Returns one row per W."""
    rows = []
    for W in widths:
        ids, _, st = idx.search(q, k=10, L=L, beam_width=W)
        rows.append(dict(
            W=W, recall=rec.recall_at_k(ids, gt, 10),
            hops=st.hops, expansions=st.expansions, cmps=st.cmps,
            latency_ms=query_latency_ms(st), ru=query_ru(st),
        ))
    return rows


def run(n: int = 8000, dim: int = 64, n_queries: int = 64, seed: int = 0):
    rng = np.random.RandomState(seed)
    data = clustered(rng, n, dim)
    idx = build_index(data, R=24, M=32, L_build=48)
    q = in_dist_queries(data, rng, n_queries)
    gt = rec.ground_truth(q, data, np.ones(n, bool), 10)

    # candidate-selection hot path: the Pallas topk_select kernel (interpret
    # off-TPU) must reproduce the brute-force top-10 on exact distances
    d = ((q * q).sum(1)[:, None] + (data * data).sum(1)[None, :]
         - 2.0 * q @ data.T).astype(np.float32)
    _, kernel_ids = topk_ops.topk_select(d, L=10)
    kernel_recall = rec.recall_at_k(np.asarray(kernel_ids), gt, 10)
    assert kernel_recall >= 0.999, f"topk_select kernel disagrees: {kernel_recall}"

    rows = []
    for L in (10, 25, 50, 100):
        ids, lat, ru = per_query_stats(idx, q, k=10, L=L)
        r = rec.recall_at_k(ids, gt, 10)
        rows.append(dict(L=L, recall=r, p50_ms=pct(lat, 50), p95_ms=pct(lat, 95),
                         p99_ms=pct(lat, 99), ru=ru))
    wrows = beamwidth_sweep(idx, q, gt, L=50)
    return rows, wrows


def main(smoke: bool = False):
    # smoke: tiny sizes so scripts/check.sh --smoke can exercise the whole
    # path (build → search → kernel cross-check → stats) in seconds
    rows, wrows = run(n=1500, dim=32, n_queries=32) if smoke else run()
    print("bench_query (Fig 6): L, recall@10, p50/p95/p99 modeled ms, RU")
    for r in rows:
        print(f"  L={r['L']:4d} recall={r['recall']:.3f} "
              f"p50={r['p50_ms']:.2f}ms p95={r['p95_ms']:.2f}ms "
              f"p99={r['p99_ms']:.2f}ms RU={r['ru']:.1f}")
    print("bench_query beamwidth sweep (L=50): W, recall@10, rounds, "
          "expansions, cmps, modeled ms, RU")
    for w in wrows:
        print(f"  W={w['W']} recall={w['recall']:.3f} hops={w['hops']:6.1f} "
              f"exp={w['expansions']:6.1f} cmps={w['cmps']:7.1f} "
              f"lat={w['latency_ms']:.2f}ms RU={w['ru']:.1f}")
    # monotone recall in L (more slack at smoke scale: few queries quantize
    # recall to coarse steps)
    slack = 0.05 if smoke else 0.02
    rc = [r["recall"] for r in rows]
    assert all(b >= a - slack for a, b in zip(rc, rc[1:])), "recall not monotone in L"
    # beam-width contract: recall parity within 0.01 of W=1, rounds at W=4
    # down to ≤ 0.4×, modeled latency monotone non-increasing in W
    w1 = wrows[0]
    for w in wrows[1:]:
        assert abs(w["recall"] - w1["recall"]) <= 0.01, (w, w1)
        assert w["latency_ms"] <= w1["latency_ms"] + 1e-6, (w, w1)
    w4 = next(w for w in wrows if w["W"] == 4)
    assert w4["hops"] <= 0.4 * w1["hops"], (w4["hops"], w1["hops"])
    return rows, wrows


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv[1:])
