"""§Roofline: three-term roofline per (arch × shape × mesh) from dry-run JSON.

Terms (per training/serving step, per device, TPU v5e constants from spec):

    compute    = HLO_FLOPs_corrected / (devices × 197e12 bf16 FLOP/s)
    memory     = HLO_bytes_corrected / (devices × 819e9 B/s HBM)
    collective = collective_bytes    / (devices × 50e9 B/s per ICI link)

Loop-count correction (XLA's cost analysis counts `while` bodies once —
verified in launch/dryrun.py):

  * uniform archs compile unrolled L=1 / L=2 variants at the real shape:
        F_true = F(L1) + (num_layers − 1) · (F(L2) − F(L1))
  * zamba2 train/prefill compiles the full (python-looped) pattern at
    S ∈ {Q, 2Q, 4Q} with unrolled chunk loops and fits F(S) = a + b·S + c·S²
    (attention blocks are quadratic in S), evaluated at the real S;
  * cells with no inner loops use the full compile directly.

Collective bytes get ring factors: all-reduce ×2 (reduce-scatter +
all-gather phases), others ×1; bytes are per-device post-SPMD shapes.

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per train step (3·fwd
cost, incl. backward); decode/prefill use 2·N·D_tokens. The ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/recompute and attention overheads.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Optional

import numpy as np

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link

RING_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _coll_bytes(rec: dict) -> float:
    return sum(RING_FACTOR[k] * v["bytes"] for k, v in rec["collectives"].items())


def _rec(cell: dict, tag: str) -> Optional[dict]:
    for r in cell.get("records", []):
        if r["tag"] == tag:
            return r
    return None


def corrected_costs(cell: dict, num_layers: int, seq_len: int,
                    pattern: tuple[str, ...]) -> dict:
    """Returns dict(flops, bytes, coll_bytes) with loop corrections.

    See launch/dryrun.py's variant-plan comment for the formulas. `seq_scaled`
    (when set) means the L/M variants compiled at a reduced sequence S_v and
    are linearly rescaled by S/S_v (valid: those variants only cover
    linear-in-S blocks; quadratic attention comes from A-variants at full S).
    """
    full = _rec(cell, "full")
    l1, l2 = _rec(cell, "L1"), _rec(cell, "L2")
    m1, m2 = _rec(cell, "M1"), _rec(cell, "M2")
    a1, a2 = _rec(cell, "A1"), _rec(cell, "A2")
    sv = cell.get("seq_scaled") or seq_len
    scale = seq_len / sv

    def get(rec, metric):
        return rec[metric] if metric != "coll" else _coll_bytes(rec)

    def fix(metric):
        if l1 and l2:
            f1, f2 = get(l1, metric), get(l2, metric)
            return (f1 + (num_layers - 1) * (f2 - f1)) * scale
        if m1 and m2 and a1 and a2:
            n_m = sum(k == "mamba2" for k in pattern)
            n_a = sum(k == "attn" for k in pattern)
            dM = get(m2, metric) - get(m1, metric)
            dA = get(a2, metric) - get(a1, metric)
            ovh = get(m1, metric) - dM
            return (ovh + n_m * dM) * scale + n_a * dA
        return get(full, metric)

    accum = cell.get("accum", 1)  # identical microbatches → exact multiply
    return dict(
        flops=fix("flops") * accum,
        bytes=fix("bytes_accessed") * accum,
        coll_bytes=fix("coll") * accum,
        raw_flops=full["flops"],
        memory=full.get("memory", {}),
    )


def model_flops(arch: str, shape: str, params: int, active: int) -> float:
    train_tokens = {"train_4k": 256 * 4096}
    if shape == "train_4k":
        return 6.0 * active * train_tokens[shape]
    if shape == "prefill_32k":
        return 2.0 * active * 32 * 32768
    if shape == "decode_32k":
        return 2.0 * active * 128  # one token × batch
    if shape == "long_500k":
        return 2.0 * active * 1
    return 0.0


def analyse_cell(path: str) -> Optional[dict]:
    with open(path) as f:
        cell = json.load(f)
    if cell.get("skipped"):
        return dict(arch=cell["arch"], shape=cell["shape"], mesh=cell["mesh"],
                    skipped=cell["skipped"])
    if not cell.get("ok"):
        return dict(arch=cell["arch"], shape=cell["shape"], mesh=cell["mesh"],
                    error=cell.get("error", "?"))
    if cell["arch"] == "cosmosann":
        full = _rec(cell, "full")
        # the beam while-loop body is counted once; a search expands ≈1.4·L
        # nodes (measured hop counts, benchmarks/bench_query.py), so the
        # traversal portion is multiplied analytically.
        hops = 1.4 * cell.get("workload", {}).get("L_search", 100)
        costs = dict(flops=full["flops"] * hops, bytes=full["bytes_accessed"] * hops,
                     coll_bytes=_coll_bytes(full), raw_flops=full["flops"],
                     memory=full.get("memory", {}))
        params, active = 0, 0
    else:
        import sys
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
        from repro.configs import SHAPES, get_config
        cfg = get_config(cell["arch"])
        shape = SHAPES[cell["shape"]]
        costs = corrected_costs(cell, cfg.num_layers, shape.seq_len, cfg.pattern)
        params, active = cell.get("model_params", cfg.param_count()), cell.get(
            "active_params", cfg.active_param_count())

    # cost_analysis and memory_analysis of the post-SPMD module are
    # PER-DEVICE (verified against a hand-partitioned matmul).
    D = cell["devices"]
    t_compute = costs["flops"] / PEAK_FLOPS
    t_memory = costs["bytes"] / HBM_BW
    t_coll = costs["coll_bytes"] / ICI_BW
    terms = dict(compute=t_compute, memory=t_memory, collective=t_coll)
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cell["arch"], cell.get("shape", ""), params, active)
    mem = costs["memory"]
    hbm_gib = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)
               + mem.get("output_size_in_bytes", 0)
               - mem.get("alias_size_in_bytes", 0)) / 2**30
    out = dict(
        arch=cell["arch"], shape=cell["shape"], mesh=cell["mesh"], devices=D,
        flops=costs["flops"], bytes=costs["bytes"], coll_bytes=costs["coll_bytes"],
        t_compute=t_compute, t_memory=t_memory, t_collective=t_coll,
        bottleneck=bottleneck,
        model_flops=mf,
        useful_ratio=(mf / (costs["flops"] * D)) if costs["flops"] else 0.0,
        roofline_fraction=(
            terms[bottleneck] and t_compute / max(terms.values()) or 0.0
        ),
        hbm_gib_per_device=hbm_gib,
        fits_v5e=hbm_gib < 16.0,
        mem_per_device=costs["memory"],
    )
    return out


def analyse_dir(dry_dir: str = "results/dryrun") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dry_dir, "*.json"))):
        r = analyse_cell(path)
        if r:
            rows.append(r)
    return rows


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def print_table(rows: list[dict]):
    print(f"{'arch':24s} {'shape':12s} {'mesh':6s} "
          f"{'compute':>9s} {'memory':>9s} {'collect':>9s} {'bound':>10s} "
          f"{'useful':>7s} {'roofl%':>7s} {'HBM GiB':>8s}")
    for r in rows:
        if "skipped" in r:
            print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} "
                  f"{'SKIP: ' + r['skipped']}")
            continue
        if "error" in r:
            print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} FAIL {r['error'][:60]}")
            continue
        fits = "" if r["fits_v5e"] else " OVER!"
        print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} "
              f"{fmt_s(r['t_compute']):>9s} {fmt_s(r['t_memory']):>9s} "
              f"{fmt_s(r['t_collective']):>9s} {r['bottleneck']:>10s} "
              f"{r['useful_ratio']:7.2f} {100*r['roofline_fraction']:6.1f}% "
              f"{r['hbm_gib_per_device']:7.2f}{fits}")


def main():
    rows = analyse_dir()
    print_table(rows)
    with open("results/roofline.json", "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\n{len(rows)} cells -> results/roofline.json")
    return rows


if __name__ == "__main__":
    main()
