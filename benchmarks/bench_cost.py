"""Tables 1/2: query + insert cost vs the serverless competition.

Competitor figures are the paper's published numbers (as of 2025-07-14);
our side is the RU model fed with (a) the paper's own operating-point
counters and (b) counters measured at bench scale extrapolated to 10M via
the logarithmic hop fit. Outputs the headline ratios (≈43× vs Pinecone,
≈12× vs Zilliz on $/1M queries).
"""
from __future__ import annotations

import numpy as np

from repro.store.ru import OpCounters, RUConfig, RUMeter

from .bench_scaling import run as scaling_run

# Paper Table 1 (RU-equivalents per query; $ per 1M cost units; storage $)
TABLE1 = {
    "cosmosdb": dict(ru_per_query=70, usd_per_1m_units=0.25, storage=22.25),
    "pinecone": dict(ru_per_query=32, usd_per_1m_units=24.0, storage=11.55),
    "zilliz": dict(ru_per_query=55, usd_per_1m_units=4.0, storage=17.84),
    "datastax": dict(ru_per_query=768, usd_per_1m_units=0.04, storage=24.0),
}
# Paper Table 2 (insert costs for 10M 768D vectors)
TABLE2 = {
    "cosmosdb": dict(usd_per_1m_ru=0.25, ru_per_insert=65),
    "pinecone": dict(usd_per_1m_ru=6.0, ru_per_insert=4),
    "zilliz": dict(usd_per_1m_ru=4.0, ru_per_insert=0.75),
    "datastax": dict(usd_per_1m_ru=0.04, ru_per_insert=768),
}


def model_costs():
    meter = RUMeter(RUConfig())
    # §4's operating point: L=100, R=32 → ≈3500 quant reads, ≈50 full reads
    paper_query = OpCounters(quant_reads=3500, adj_reads=100, full_reads=25, cpu_ms=2.0)
    paper_insert = OpCounters(quant_reads=3200, adj_reads=130, adj_writes=33,
                              quant_writes=1, doc_writes=1, cpu_ms=3.0, vector_kb=3.0)
    return meter.ru(paper_query), meter.ru(paper_insert)


def main():
    ru_q_model, ru_i_model = model_costs()
    _, growth, ru_10m_measured = scaling_run(sizes=(2000, 8000), seed=2)

    print("bench_cost (Tables 1/2)")
    print(f"  modeled RU/query @paper counters: {ru_q_model:.1f} (paper: 70)")
    print(f"  measured->extrapolated RU/query @10M: {ru_10m_measured:.1f}")
    print(f"  modeled RU/insert @paper counters: {ru_i_model:.1f} (paper: 65)")

    us_cost_q = ru_q_model * TABLE1["cosmosdb"]["usd_per_1m_units"]  # $/1M q
    rows = []
    for name, t in TABLE1.items():
        if name == "cosmosdb":
            dollars = us_cost_q
        else:
            dollars = t["ru_per_query"] * t["usd_per_1m_units"]
        rows.append((name, dollars))
        print(f"  query $/1M: {name:10s} ${dollars:8.2f}")
    base = dict(rows)["cosmosdb"]
    ratio_pinecone = dict(rows)["pinecone"] / base
    ratio_zilliz = dict(rows)["zilliz"] / base
    print(f"  ratios vs cosmosdb: pinecone {ratio_pinecone:.1f}x (paper ~43x), "
          f"zilliz {ratio_zilliz:.1f}x (paper ~12x)")

    ins = []
    for name, t in TABLE2.items():
        ru = ru_i_model if name == "cosmosdb" else t["ru_per_insert"]
        total = ru * t["usd_per_1m_ru"] * 10  # 10M inserts / 1M units
        ins.append((name, total))
        print(f"  insert $ for 10M: {name:10s} ${total:8.1f}")
    return dict(query_ratios=dict(pinecone=ratio_pinecone, zilliz=ratio_zilliz),
                ru_q=ru_q_model, ru_i=ru_i_model, insert=dict(ins))


if __name__ == "__main__":
    main()
