"""Fig 14: distance comparisons vs recall — graph search vs clustering (IVF).

Paper's Appendix A point: graph traversal needs far fewer distance
comparisons than partition probing at high recall (that's why the system
uses graphs). We build a small IVF (k-means cells, probe sweep) and the
DiskANN graph over the same data and count comparisons at matched recall.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import recall as rec
from repro.core.pq import pairwise_distance

from .common import build_index, clustered, in_dist_queries


def ivf_search(data, centroids, assign, q, nprobe, k):
    """Exhaustive scan of the nprobe nearest cells; returns ids + #cmps."""
    dc = np.asarray(pairwise_distance(jnp.asarray(q), jnp.asarray(centroids)))
    cells = np.argsort(dc, 1)[:, :nprobe]
    ids_out, cmps = [], 0
    for i in range(len(q)):
        cand = np.nonzero(np.isin(assign, cells[i]))[0]
        cmps += len(cand) + len(centroids)
        d = ((data[cand] - q[i]) ** 2).sum(1)
        ids_out.append(cand[np.argsort(d)[:k]])
    return np.asarray(ids_out), cmps / len(q)


def run(n: int = 12000, dim: int = 32, seed: int = 0):
    rng = np.random.RandomState(seed)
    data = clustered(rng, n, dim, k=64)
    q = in_dist_queries(data, rng, 24)
    gt = rec.ground_truth(q, data, np.ones(n, bool), 5)

    # IVF baseline
    from repro.core.pq import _kmeans_one
    cents = np.asarray(_kmeans_one(jax.random.PRNGKey(0),
                                   jnp.asarray(data[rng.choice(n, 4000)]), 64, 8))
    assign = np.asarray(jnp.argmin(pairwise_distance(jnp.asarray(data),
                                                     jnp.asarray(cents)), 1))
    ivf_rows = []
    for nprobe in (1, 2, 4, 8, 16):
        ids, cmps = ivf_search(data, cents, assign, q, nprobe, 5)
        ivf_rows.append((rec.recall_at_k(ids, gt, 5), cmps))

    # graph
    idx = build_index(data, L_build=48)  # R=24, M=16 defaults
    graph_rows = []
    for L in (10, 20, 40, 80):
        cmps_total, ids_all = 0, []
        for i in range(len(q)):
            ids, _, st = idx.search(q[i : i + 1], 5, L=L)
            ids_all.append(ids[0])
            cmps_total += st.cmps
        graph_rows.append((rec.recall_at_k(np.asarray(ids_all), gt, 5),
                           cmps_total / len(q)))
    return ivf_rows, graph_rows


def main():
    ivf_rows, graph_rows = run()
    print("bench_algo_compare (Fig 14): recall@5 vs avg distance comparisons")
    for r, c in ivf_rows:
        print(f"  ivf    recall={r:.3f} cmps={c:8.0f}")
    for r, c in graph_rows:
        print(f"  graph  recall={r:.3f} cmps={c:8.0f}")
    # at the highest matched recall, the graph needs fewer comparisons
    best_graph = max(graph_rows)
    comparable = [c for r, c in ivf_rows if r >= best_graph[0] - 0.05]
    if comparable:
        assert best_graph[1] < min(comparable) * 1.2, "graph should need fewer cmps"
    return ivf_rows, graph_rows


if __name__ == "__main__":
    main()
