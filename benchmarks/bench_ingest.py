"""Figs 11/12: ingestion cost breakdown and throughput trajectory.

Paper: quantized-vector access dominates insert time; ingest rate declines
as the Bw-Tree grows (longer chains, costlier lookups); §4.4's napkin math
(10 µs/quant read, 25 µs/adj read, ~3 ms DiskANN CPU → ≈25 ms/insert,
≈40 inserts/s/thread) matches the steady state. We ingest through the
store-backed provider and report the same breakdown from real counters.
"""
from __future__ import annotations

import numpy as np

from repro.core import DiskANNIndex, GraphConfig
from repro.store.provider import StoreProviderSet
from repro.store.ru import OpCounters, RUConfig, RUMeter

from .common import clustered


def run(n: int = 4000, dim: int = 32, batch: int = 100, seed: int = 0):
    rng = np.random.RandomState(seed)
    data = clustered(rng, n, dim)
    cfg = GraphConfig(capacity=n + 64, R=16, M=8, L_build=48, L_search=48,
                      bootstrap_sample=256, refine_sample=10**9, batch_size=batch)
    pv = StoreProviderSet(cfg.capacity, cfg.R_slack, cfg.M, dim)
    idx = DiskANNIndex(cfg, dim, providers=pv)

    meter = RUMeter(RUConfig())
    traj = []
    for start in range(0, n, batch):
        pv.begin_op()
        ist = idx.insert(list(range(start, start + batch)), data[start : start + batch])
        # graph-maintenance reads go through the array cache (the Bw-Tree
        # page cache role); account them from the insert search stats, as
        # the paper's telemetry does (§4.4): ≈R·L_build quant reads/insert
        pv.op.quant_reads += int(ist.cmps)
        pv.op.adj_reads += int(ist.hops)
        ru, lat = pv.end_op()
        c = pv.op
        traj.append(dict(
            n=start + batch, ru_per_insert=ru / batch,
            ms_per_insert=lat / batch,
            quant_ms=meter.cfg.us_per_quant_read * c.quant_reads / batch / 1000,
            adj_ms=meter.cfg.us_per_adj_read * c.adj_reads / batch / 1000,
            chain_ms=meter.cfg.us_per_chain_record * c.chain_records / batch / 1000,
        ))
    return traj


def main():
    traj = run()
    print("bench_ingest (Fig 11/12): N, RU/insert, modeled ms/insert "
          "(quant | adj | chain components)")
    for t in traj[:: max(1, len(traj) // 8)]:
        print(f"  N={t['n']:5d} RU={t['ru_per_insert']:5.1f} "
              f"ms={t['ms_per_insert']:6.2f} "
              f"quant={t['quant_ms']:5.2f} adj={t['adj_ms']:5.2f} "
              f"chain={t['chain_ms']:5.2f}")
    # Fig 11's headline: quantized-vector access dominates the breakdown
    last = traj[-1]
    assert last["quant_ms"] > last["adj_ms"], "quant reads should dominate"
    # Fig 12's headline: per-insert cost grows as the index grows
    early = np.mean([t["ms_per_insert"] for t in traj[1:4]])
    late = np.mean([t["ms_per_insert"] for t in traj[-3:]])
    print(f"  early {early:.2f} ms/insert -> late {late:.2f} ms/insert "
          f"(rate declines as in Fig 12: {late >= early * 0.9})")
    return traj


if __name__ == "__main__":
    main()
