"""Fig 13: recall stability over update streams — in-place delete vs drop.

Two runbooks at bench scale: an expiration-time stream and a *clustered*
(distribution-shift) stream where inserts/deletes walk through clusters in
order — the adversarial case where the paper shows in-place deletes win by
up to 20 recall points.
"""
from __future__ import annotations

import numpy as np

from repro.core import DiskANNIndex, GraphConfig
from repro.core import recall as rec

from .common import clustered


def _mk_index(dim, cap, seed):
    cfg = GraphConfig(capacity=cap, R=12, M=6, L_build=32, L_search=64,
                      bootstrap_sample=128, refine_sample=10**9, batch_size=64)
    return DiskANNIndex(cfg, dim, seed=seed)


def expiration_runbook(policy: str, steps: int = 8, seed: int = 0):
    rng = np.random.RandomState(seed)
    dim = 24
    idx = _mk_index(dim, 4000, seed)
    pool = clustered(rng, 6000, dim)
    live, nxt, recalls = [], 0, []
    for step in range(steps):
        ids = list(range(nxt, nxt + 300))
        idx.insert(ids, pool[[i % 6000 for i in ids]])
        live += ids
        nxt += 300
        if step >= 2:
            expire = rng.choice(live, 150, replace=False).tolist()
            idx.delete(expire, policy=policy)
            live = [d for d in live if d not in set(expire)]
            idx.consolidate()
            pick = rng.choice(live, 16, replace=False)
            q = pool[[d % 6000 for d in pick]] + 0.03 * rng.randn(16, dim).astype(np.float32)
            ids_r, _, _ = idx.search(q, k=10)
            gt = rec.ground_truth(q, idx.pv.vectors, idx.pv.live, 10)
            gt_docs = np.where(gt >= 0, idx.slot_to_doc[np.maximum(gt, 0)], -1)
            recalls.append(rec.recall_at_k(ids_r, gt_docs, 10))
    return recalls


def clustered_runbook(policy: str, seed: int = 1):
    """Distribution shift: clusters arrive and expire in order."""
    rng = np.random.RandomState(seed)
    dim = 24
    n_clusters, per_cluster = 8, 400
    centers = rng.randn(n_clusters, dim).astype(np.float32)
    idx = _mk_index(dim, n_clusters * per_cluster + 512, seed)
    recalls, doc = [], 0
    windows = []  # (cluster, ids)
    for c in range(n_clusters):
        data = (centers[c] + 0.6 * rng.randn(per_cluster, dim)).astype(np.float32)
        ids = list(range(doc, doc + per_cluster))
        idx.insert(ids, data)
        windows.append((c, ids, data))
        doc += per_cluster
        if len(windows) > 3:  # expire the oldest cluster wholesale
            _, old_ids, _ = windows.pop(0)
            idx.delete(old_ids, policy=policy)
            idx.consolidate()
            # background maintenance after heavy churn (start point tracks
            # the live distribution; orphans re-inserted)
            idx.recompute_medoid()
            idx.repair_orphans()
        if c >= 3:
            _, qids, qdata = windows[-1]
            q = qdata[:16] + 0.03 * rng.randn(16, dim).astype(np.float32)
            ids_r, _, _ = idx.search(q, k=10)
            gt = rec.ground_truth(q, idx.pv.vectors, idx.pv.live, 10)
            gt_docs = np.where(gt >= 0, idx.slot_to_doc[np.maximum(gt, 0)], -1)
            recalls.append(rec.recall_at_k(ids_r, gt_docs, 10))
    return recalls


def main():
    print("bench_runbooks (Fig 13)")
    for name, fn in (("expiration", expiration_runbook), ("clustered", clustered_runbook)):
        r_in = fn("inplace")
        r_drop = fn("drop")
        print(f"  {name:10s} inplace: " + " ".join(f"{r:.2f}" for r in r_in))
        print(f"  {name:10s} drop:    " + " ".join(f"{r:.2f}" for r in r_drop))
        print(f"  {name:10s} mean inplace={np.mean(r_in):.3f} drop={np.mean(r_drop):.3f}")
    return True


if __name__ == "__main__":
    main()
