"""Shared benchmark helpers: data, index building, timing, reporting.

CPU-container scaling: the paper runs 10M×768D; these benchmarks run
2k–50k × 24–96D and report (a) raw measured numbers at bench scale and
(b) *derived* quantities comparable to the paper (RU model outputs, recall
curves, scaling exponents). EXPERIMENTS.md places both next to the paper's
claims.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import DiskANNIndex, GraphConfig
from repro.core import recall as rec
from repro.store.ru import (RUConfig, RUMeter, counters_for_latency,
                            counters_for_ru)


def clustered(rng: np.random.RandomState, n: int, dim: int, k: int = 32,
              spread: float = 0.15) -> np.ndarray:
    centers = rng.randn(k, dim).astype(np.float32)
    return (centers[rng.randint(0, k, n)] + spread * rng.randn(n, dim)).astype(np.float32)


def build_index(data: np.ndarray, R: int = 24, M: int = 16, L_build: int = 48,
                seed: int = 0, providers=None, batch_size: int = 100) -> DiskANNIndex:
    n, d = data.shape
    cfg = GraphConfig(capacity=n + 128, R=R, M=M, L_build=L_build,
                      L_search=L_build, bootstrap_sample=min(1000, max(128, n // 8)),
                      refine_sample=10**9, batch_size=batch_size)
    idx = DiskANNIndex(cfg, d, seed=seed, providers=providers)
    idx.insert(list(range(n)), data)
    return idx


def in_dist_queries(data: np.ndarray, rng: np.random.RandomState, n: int,
                    noise: float = 0.05) -> np.ndarray:
    pick = rng.choice(len(data), n, replace=False)
    return (data[pick] + noise * rng.randn(n, data.shape[1])).astype(np.float32)


def query_ru(stats, meter: RUMeter | None = None) -> float:
    """Modeled per-query RU from search counters (the §4 cost currency).
    Charges adjacency rows actually fetched — beam width buys latency,
    not free reads."""
    meter = meter or RUMeter(RUConfig())
    c = counters_for_ru(stats)
    c.cpu_ms = 0.02 * stats.cmps / 100
    return meter.ru(c)


def query_latency_ms(stats, meter: RUMeter | None = None) -> float:
    """Modeled single-replica latency from the §4.4 access-time constants,
    through the shared round-structured critical-path model
    (`store.ru.counters_for_latency` — same as the serving fanout path)."""
    meter = meter or RUMeter(RUConfig())
    return meter.latency_ms(counters_for_latency(stats))


def timed(fn, *args, repeat: int = 3, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def per_query_stats(idx: DiskANNIndex, queries: np.ndarray, k: int, L: int,
                    rerank_multiplier: float = 5.0):
    """Per-query modeled latencies (ms) + recall + mean RU."""
    lat, rus = [], []
    all_ids = []
    for i in range(len(queries)):
        ids, _, st = idx.search(queries[i : i + 1], k=k, L=L,
                                rerank_multiplier=rerank_multiplier)
        all_ids.append(ids[0])
        lat.append(query_latency_ms(st))
        rus.append(query_ru(st))
    return np.asarray(all_ids), np.asarray(lat), float(np.mean(rus))


def pct(a, p):
    return float(np.percentile(np.asarray(a), p))
