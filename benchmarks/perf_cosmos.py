"""§Perf experiments on the paper's own workload (cosmosann/query).

Baseline: one DiskANN shard per device, batched beam search (gather-based
ADC), all-gather top-k merge. Levers measured here:

  * query batch size (compute/byte amortization of the graph stream);
  * one-hot MXU ADC vs gather ADC inside the beam loop (the pq_adc kernel's
    contraction trick at the HLO level);
  * rerank candidate width (full-precision touches per query).

    PYTHONPATH=src python -m benchmarks.perf_cosmos
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import json
import time

import jax

from repro.configs import cosmosann as cz
from repro.launch.dryrun import _compile_one
from repro.launch.mesh import make_production_mesh
from repro.partition.fanout import distributed_search_fn

HOPS = 1.4 * 100  # beam while-body multiplier (see roofline.py)
PEAK, HBM, ICI = 197e12, 819e9, 50e9


def measure(tag: str, query_batch: int = 128, L: int = 100, k: int = 10):
    cfg = cz.VectorWorkloadConfig(query_batch=query_batch, L_search=L, k=k)
    mesh = make_production_mesh()
    n_dev = 256
    specs = cz.shard_specs(cfg, n_dev)
    fn = distributed_search_fn(mesh, L=cfg.L_search, k=cfg.k,
                               shard_axes=tuple(mesh.axis_names),
                               max_hops=-(-2 * cfg.L_search // cfg.beam_width),
                               beam_width=cfg.beam_width)
    args = (specs["neighbors"], specs["codes"], specs["versions"], specs["live"],
            specs["vectors"], specs["doc_ids"], specs["medoid"],
            specs["codebooks"], specs["queries"])
    rec = _compile_one(lambda: (fn, args), tag, want_memory=True)
    flops = rec["flops"] * HOPS
    bts = rec["bytes_accessed"] * HOPS
    coll = sum((2 if kk == "all-reduce" else 1) * v["bytes"]
               for kk, v in rec["collectives"].items())
    out = dict(
        tag=tag, query_batch=query_batch, L=L,
        t_compute=flops / PEAK, t_memory=bts / HBM, t_collective=coll / ICI,
        per_query_us=1e6 * max(flops / PEAK, bts / HBM, coll / ICI) / query_batch,
        mem_gib=(rec["memory"]["argument_size_in_bytes"]
                 + rec["memory"]["temp_size_in_bytes"]) / 2**30,
    )
    print(json.dumps(out, indent=1))
    return out


def main():
    rows = [
        measure("baseline_b128", 128),
        measure("b1024", 1024),  # amortize the graph stream over 8x queries
        measure("b4096", 4096),
    ]
    with open("results/perf_cosmos.json", "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
